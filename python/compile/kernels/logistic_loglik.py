"""Fused logistic-regression log-likelihood Pallas kernel.

The compute hot-spot of the paper's COVTYPE benchmark (Table 2a, E2):
``sum_i y_i z_i - softplus(z_i)`` with ``z = X @ w + b`` over N up to
581,012 rows.  On GPU the paper relies on XLA fusing the matvec with the
pointwise terms; on TPU we express the HBM<->VMEM schedule explicitly:

* grid over row blocks of ``BLOCK_N`` (default 1024): each step streams
  an ``(BLOCK_N, D)`` tile of X into VMEM (1024*64*4B = 256 KiB << 16 MiB
  VMEM) while ``w`` stays resident;
* the per-block partial sum accumulates into the (1,1) output ref —
  TPU grids execute sequentially, so read-modify-write accumulation
  replaces the GPU's atomics / two-pass reduction;
* the matvec is shaped (BLOCK_N, D) x (D, 1) so it lands on the MXU.

The backward pass runs every leapfrog step (it *is* the gradient the
integrator consumes), so it is also a Pallas kernel: r = y - sigmoid(z),
grad_w = X^T r accumulated block-wise, grad_b = sum(r).

Both directions are wrapped in one ``jax.custom_vjp`` so ``jax.grad``
of the potential energy traces straight through the kernels inside the
compiled NUTS step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024


def _fwd_kernel(x_ref, w_ref, b_ref, y_ref, o_ref, *, n_rows: int, block_n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (block_n, d)
    w = w_ref[...]  # (d, 1)
    z = (x @ w)[:, 0] + b_ref[0]  # (block_n,) — MXU matvec + VPU add
    y = y_ref[...]
    row = i * block_n + jax.lax.iota(jnp.int32, block_n)
    contrib = jnp.where(row < n_rows, y * z - jax.nn.softplus(z), 0.0)
    o_ref[0, 0] += jnp.sum(contrib)


def _bwd_kernel(x_ref, w_ref, b_ref, y_ref, gw_ref, gb_ref, *, n_rows: int, block_n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gw_ref[...] = jnp.zeros_like(gw_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    x = x_ref[...]
    w = w_ref[...]
    z = (x @ w)[:, 0] + b_ref[0]
    y = y_ref[...]
    row = i * block_n + jax.lax.iota(jnp.int32, block_n)
    r = jnp.where(row < n_rows, y - jax.nn.sigmoid(z), 0.0)  # (block_n,)
    # grad_w partial: X^T r — (d, block_n) x (block_n, 1) on the MXU.
    gw_ref[...] += x.T @ r[:, None]
    gb_ref[0, 0] += jnp.sum(r)


def _pad_rows(a, block_n):
    n = a.shape[0]
    pad = (-n) % block_n
    if pad == 0:
        return a
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths)


def _loglik_fwd_impl(x, w, b, y, *, block_n: int):
    n, d = x.shape
    dtype = x.dtype
    xp = _pad_rows(x, block_n)
    yp = _pad_rows(y.astype(dtype), block_n)
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, n_rows=n, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), dtype),
        interpret=True,  # CPU-PJRT execution; real TPU would drop this.
    )(xp, w[:, None], b[None], yp)
    return out[0, 0]


def _loglik_bwd_impl(x, w, b, y, *, block_n: int):
    n, d = x.shape
    dtype = x.dtype
    xp = _pad_rows(x, block_n)
    yp = _pad_rows(y.astype(dtype), block_n)
    grid = (xp.shape[0] // block_n,)
    gw, gb = pl.pallas_call(
        functools.partial(_bwd_kernel, n_rows=n, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, 1), dtype),
            jax.ShapeDtypeStruct((1, 1), dtype),
        ],
        interpret=True,
    )(xp, w[:, None], b[None], yp)
    return gw[:, 0], gb[0, 0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def logistic_loglik(x, w, b, y, block_n: int = DEFAULT_BLOCK_N):
    """Fused ``sum(y * z - softplus(z))`` with ``z = x @ w + b``.

    Gradients flow to ``w`` and ``b`` (the data ``x``/``y`` receive
    symbolic-zero cotangents, DCE'd by XLA); both directions run as
    Pallas kernels.
    """
    return _loglik_fwd_impl(x, w, b, y, block_n=block_n)


def _vjp_fwd(x, w, b, y, block_n):
    return _loglik_fwd_impl(x, w, b, y, block_n=block_n), (x, w, b, y)


def _vjp_bwd(block_n, res, ct):
    x, w, b, y = res
    gw, gb = _loglik_bwd_impl(x, w, b, y, block_n=block_n)
    # data cotangents are structurally required but never consumed
    return jnp.zeros_like(x), ct * gw, ct * gb, jnp.zeros_like(y)


logistic_loglik.defvjp(_vjp_fwd, _vjp_bwd)
