"""Layer-1 Pallas kernels (interpret mode on CPU; see DESIGN.md §6 for
the TPU mapping) plus the pure-jnp oracle in :mod:`ref`."""

from . import ref
from .hmm_forward import hmm_forward
from .logistic_loglik import logistic_loglik
from .skim_kernel import skim_kernel_matrix

__all__ = ["hmm_forward", "logistic_loglik", "ref", "skim_kernel_matrix"]
