"""HMM forward-algorithm Pallas kernel.

The hot-spot of the paper's HMM benchmark (Table 2a, E1): the log-space
forward recursion

    alpha_t = logsumexp(alpha_{t-1}[:, None] + log_A, axis=0) + log_B[:, y_t]

is strictly sequential in t, so the kernel runs a grid of T steps and
carries ``alpha`` in the *output ref* (its index map is constant, so the
block persists in VMEM across the sequential TPU grid — the canonical
carry/accumulator pattern).  The entire working set (alpha: K floats,
log_A: KxK, log_B: KxV) lives in VMEM for the whole recursion; on TPU
this kernel would never touch HBM inside the loop, which is exactly the
fusion the paper credits XLA with on GPU.

Differentiation: the backward recursion needs all intermediate alphas,
which the O(K)-memory forward kernel deliberately does not keep.  The
custom VJP therefore recomputes via the pure-jnp scan oracle
(``ref.hmm_forward``) and differentiates that — the standard
recompute-on-backward (checkpointing) trade, documented in DESIGN.md §6.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref


def _fwd_kernel(log_a_ref, log_b_ref, obs_ref, alpha0_ref, alpha_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        alpha_ref[...] = alpha0_ref[...]

    alpha = alpha_ref[...]  # (1, K) carry from previous grid step
    log_a = log_a_ref[...]  # (K, K)
    scores = alpha.T + log_a  # (K, K): scores[i, j] = alpha_i + log_a[i, j]
    m = jnp.max(scores, axis=0)
    new_alpha = m + jnp.log(jnp.sum(jnp.exp(scores - m[None, :]), axis=0))
    y_t = obs_ref[0]
    alpha_ref[...] = (new_alpha + log_b_ref[:, y_t])[None, :]


def _hmm_forward_impl(log_a, log_b, obs, alpha0):
    k, v = log_b.shape
    t_len = obs.shape[0]
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(t_len,),
        in_specs=[
            pl.BlockSpec((k, k), lambda t: (0, 0)),
            pl.BlockSpec((k, v), lambda t: (0, 0)),
            pl.BlockSpec((1,), lambda t: (t,)),
            pl.BlockSpec((1, k), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, k), log_a.dtype),
        interpret=True,  # CPU-PJRT execution; real TPU would drop this.
    )(log_a, log_b, obs, alpha0[None, :])
    return out[0]


@jax.custom_vjp
def hmm_forward(log_a, log_b, obs, alpha0):
    """Final log forward vector ``alpha_T``; marginal log-likelihood is
    ``logsumexp(alpha_T)``.  Differentiable wrt ``log_a``/``log_b``/
    ``alpha0`` (recompute-on-backward via the jnp oracle)."""
    return _hmm_forward_impl(log_a, log_b, obs, alpha0)


def _vjp_fwd(log_a, log_b, obs, alpha0):
    return _hmm_forward_impl(log_a, log_b, obs, alpha0), (log_a, log_b, obs, alpha0)


def _vjp_bwd(res, ct):
    log_a, log_b, obs, alpha0 = res
    _, vjp = jax.vjp(lambda a, b, z: ref.hmm_forward(a, b, obs, z), log_a, log_b, alpha0)
    g_a, g_b, g_alpha0 = vjp(ct)
    # integer observations take a float0 (symbolic zero) cotangent
    g_obs = np.zeros(obs.shape, dtype=jax.dtypes.float0)
    return g_a, g_b, g_obs, g_alpha0


hmm_forward.defvjp(_vjp_fwd, _vjp_bwd)
