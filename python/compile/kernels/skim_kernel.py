"""SKIM pairwise-interaction kernel-matrix Pallas kernel.

Hot-spot of the paper's Fig 2b benchmark (E3): the N x N Gram-style
kernel of the "kernel interaction trick" (Agrawal et al. 2019),

    K = 0.5*eta2sq*(1 + G)^2 - 0.5*eta2sq*G2 + (eta1sq - eta2sq)*G
        + (csq - 0.5*eta2sq),
    G  = kX kX^T,   G2 = kX^2 (kX^2)^T,   kX = kappa * X.

TPU mapping: the grid tiles the output into (BLOCK, BLOCK) MXU-sized
blocks; each step streams the (BLOCK, p) row-strips of kX for its block
row/column into VMEM, computes both Gram contractions on the MXU (two
(BLOCK x p) x (p x BLOCK) matmuls), and fuses the degree-2 polynomial
elementwise on the VPU — this replaces the GPU version's shared-memory
tiling (DESIGN.md §6).  For Fig 2b sizes (N=200, p<=512) a whole
(128, p) strip is ~256 KiB in f32, comfortably inside VMEM.

Backward: the VJP of K wrt (kX, scalars) is again two matmuls; it is
derived from the jnp oracle (cost symmetric to forward, fully fusable by
XLA), keeping the hand-written kernel budget on the forward path that
dominates the NUTS leapfrog.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 128


def _kernel(kx_row_ref, kx_col_ref, consts_ref, o_ref):
    kx_r = kx_row_ref[...]  # (block, p)
    kx_c = kx_col_ref[...]  # (block, p)
    eta1sq = consts_ref[0]
    eta2sq = consts_ref[1]
    csq = consts_ref[2]
    gram = kx_r @ kx_c.T  # MXU
    gram2 = jnp.square(kx_r) @ jnp.square(kx_c).T  # MXU
    o_ref[...] = (
        0.5 * eta2sq * jnp.square(1.0 + gram)
        - 0.5 * eta2sq * gram2
        + (eta1sq - eta2sq) * gram
        + (csq - 0.5 * eta2sq)
    )


def _skim_impl(k_x, eta1sq, eta2sq, csq, *, block: int):
    n, p = k_x.shape
    pad = (-n) % block
    kxp = jnp.pad(k_x, ((0, pad), (0, 0))) if pad else k_x
    np_ = kxp.shape[0]
    consts = jnp.stack([eta1sq, eta2sq, csq]).astype(k_x.dtype)
    grid = (np_ // block, np_ // block)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, p), lambda i, j: (i, 0)),
            pl.BlockSpec((block, p), lambda i, j: (j, 0)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), k_x.dtype),
        interpret=True,  # CPU-PJRT execution; real TPU would drop this.
    )(kxp, kxp, consts)
    return out[:n, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def skim_kernel_matrix(k_x, eta1sq, eta2sq, csq, block: int = DEFAULT_BLOCK):
    """N x N SKIM interaction kernel; differentiable wrt all array args."""
    return _skim_impl(k_x, eta1sq, eta2sq, csq, block=block)


def _vjp_fwd(k_x, eta1sq, eta2sq, csq, block):
    return _skim_impl(k_x, eta1sq, eta2sq, csq, block=block), (k_x, eta1sq, eta2sq, csq)


def _vjp_bwd(block, res, ct):
    k_x, eta1sq, eta2sq, csq = res
    _, vjp = jax.vjp(ref.skim_kernel_matrix, k_x, eta1sq, eta2sq, csq)
    return vjp(ct)


skim_kernel_matrix.defvjp(_vjp_fwd, _vjp_bwd)
