"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: pytest + hypothesis sweep shapes
and dtypes asserting ``kernel(x) ≈ ref(x)`` (and the same for gradients,
via the custom VJPs).  They are also the implementations used on the
backward pass where a hand-written backward kernel is not warranted (see
each kernel module's docstring).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp


def logistic_loglik(x, w, b, y):
    """Bernoulli-logit log-likelihood: sum_i y_i z_i - softplus(z_i),
    z = x @ w + b."""
    z = x @ w + b
    return jnp.sum(y * z - jax.nn.softplus(z))


def logistic_loglik_grad(x, w, b, y):
    """Closed-form gradient wrt (w, b): r = y - sigmoid(z)."""
    z = x @ w + b
    r = y - jax.nn.sigmoid(z)
    return x.T @ r, jnp.sum(r)


def hmm_forward(log_a, log_b, obs, alpha0):
    """Forward algorithm in log space.

    ``log_a[i, j] = log p(s_t = j | s_{t-1} = i)``;
    ``log_b[k, v] = log p(y = v | s = k)``; returns the final log
    forward vector ``alpha_T`` (marginal log-lik = logsumexp(alpha_T)).
    """

    def step(alpha, y_t):
        alpha = logsumexp(alpha[:, None] + log_a, axis=0) + log_b[:, y_t]
        return alpha, None

    alpha_t, _ = jax.lax.scan(step, alpha0, obs)
    return alpha_t


def skim_kernel_matrix(k_x, eta1sq, eta2sq, csq):
    """SKIM pairwise-interaction kernel (Agrawal et al. 2019, as used in
    the paper's Fig 2b benchmark): with G = kX kX^T and G2 = kX^2 (kX^2)^T,

        K = 0.5 eta2^2 (1 + G)^2 - 0.5 eta2^2 G2
            + (eta1^2 - eta2^2) G + (c^2 - 0.5 eta2^2)
    """
    gram = k_x @ k_x.T
    gram2 = jnp.square(k_x) @ jnp.square(k_x).T
    return (
        0.5 * eta2sq * jnp.square(1.0 + gram)
        - 0.5 * eta2sq * gram2
        + (eta1sq - eta2sq) * gram
        + (csq - 0.5 * eta2sq)
    )
