"""AOT lowering driver: JAX/Pallas (L1+L2)  ->  artifacts/*.hlo.txt (L3).

Runs ONCE at build time (``make artifacts``); the Rust coordinator then
loads, compiles (PJRT CPU) and executes the artifacts with Python never
on the request path.

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model (f32; run again under JAX_ENABLE_X64=1 for f64):

* ``potential_and_grad`` — (z, *data) -> (U, dU/dz).  One PJRT dispatch
  per leapfrog: this is the *Pyro-architecture baseline* of Table 2a.
* ``nuts_step`` — (key, z, step_size, inv_mass, *data) -> transition.
  The paper's headline: the whole iterative NUTS draw (Appendix A,
  Algorithm 2) as ONE XLA executable.  Step size / mass matrix are
  inputs so the Rust coordinator adapts without recompiling.
* ``nuts_step_vmapK`` — K chains per dispatch via vmap (§3.2, E7).
* covtype extras (Fig 1 / Appendix D): ``predict``, ``loglik``,
  ``elbo_and_grad``.

A ``manifest.json`` records every artifact's input/output signature,
parameter layout (site -> flat-vector span) and static workload metadata;
the Rust runtime is entirely manifest-driven.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import minippl as mp
from .infer.nuts import build_nuts_step
from .minippl import distributions as dist
from .models.hmm import HmmData, hmm_model, make_hmm_data
from .models.logistic import logistic_regression, logistic_regression_fused, make_covtype_like
from .models.skim import SkimHypers, make_skim_data, skim_model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def float_dtype():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def dtype_tag() -> str:
    return "f64" if jax.config.jax_enable_x64 else "f32"


def _spec(x) -> Dict[str, Any]:
    if isinstance(x, jax.ShapeDtypeStruct):
        return {"dtype": str(x.dtype), "shape": list(x.shape)}
    return {"dtype": str(jnp.asarray(x).dtype), "shape": list(jnp.shape(x))}


def _abstract(args: Sequence[Any]) -> List[Any]:
    return [jax.ShapeDtypeStruct(jnp.shape(a), jnp.asarray(a).dtype) for a in args]


class Lowerer:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: List[Dict[str, Any]] = []

    def lower(
        self,
        name: str,
        fn: Callable,
        example_args: Sequence[Any],
        input_names: Sequence[str],
        output_names: Sequence[str],
        meta: Dict[str, Any],
    ) -> None:
        tag = dtype_tag()
        fname = f"{name}_{tag}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        print(f"[aot] lowering {fname} ...", flush=True)
        lowered = jax.jit(fn).lower(*_abstract(example_args))
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *_abstract(example_args))
        out_list = outs if isinstance(outs, (tuple, list)) else (outs,)
        self.entries.append(
            {
                "name": f"{name}_{tag}",
                "file": fname,
                "dtype": tag,
                "inputs": [
                    {"name": n, **_spec(a)} for n, a in zip(input_names, example_args)
                ],
                "outputs": [
                    {"name": n, **_spec(o)} for n, o in zip(output_names, out_list)
                ],
                **meta,
            }
        )
        print(f"[aot]   wrote {len(text)} chars", flush=True)


NUTS_OUTPUTS = ["z_new", "accept_prob", "num_leapfrog", "potential", "diverging", "depth"]


def param_layout(model, *args) -> List[Dict[str, Any]]:
    """Site -> (offset, shape) in the flat unconstrained vector.

    ``ravel_pytree`` flattens dicts in sorted-key order; record it so the
    Rust side can label posterior columns."""
    probe = mp.infer_util.get_model_trace(model, jax.random.PRNGKey(0), *args)
    transforms = mp.infer_util.constrain_transforms(probe)
    layout = []
    offset = 0
    for name in sorted(transforms):
        site = probe[name]
        t = transforms[name]
        shape = t.inverse_shape(jnp.shape(site["value"]))
        size = 1
        for s in shape:
            size *= s
        layout.append(
            {
                "site": name,
                "unconstrained_shape": list(shape),
                "constrained_shape": list(jnp.shape(site["value"])),
                "offset": offset,
                "size": size,
                "support": repr(site["fn"].support),
            }
        )
        offset += size
    return layout


def lower_model_bundle(
    lw: Lowerer,
    model_name: str,
    model_builder: Callable,  # (*data) -> nullary model
    data: Tuple[Any, ...],
    data_names: Sequence[str],
    meta: Dict[str, Any],
    max_tree_depth: int = 10,
    vmap_chains: int = 0,
) -> None:
    """Lower potential_and_grad + nuts_step (+ vmapped variant)."""
    fdt = float_dtype()
    model0 = lambda: model_builder(*data)
    _, z0, unravel, _ = mp.initialize_model(model0, jax.random.PRNGKey(0))
    dim = z0.shape[0]
    layout = param_layout(model0)
    meta = {**meta, "model": model_name, "dim": dim, "param_layout": layout}

    def potential(z, *d):
        return mp.potential_energy(lambda: model_builder(*d), (), {}, unravel(z))

    def potential_and_grad(z, *d):
        return jax.value_and_grad(lambda zz: potential(zz, *d))(z)

    z_ex = jnp.zeros((dim,), fdt)
    lw.lower(
        f"{model_name}_potential_and_grad",
        potential_and_grad,
        (z_ex, *data),
        ["z", *data_names],
        ["potential", "grad"],
        {**meta, "kind": "potential_and_grad"},
    )

    def nuts_step(key_raw, z, step_size, inv_mass, *d):
        key = jax.random.wrap_key_data(key_raw)
        pg = lambda zz: jax.value_and_grad(lambda q: potential(q, *d))(zz)
        step = build_nuts_step(pg, max_tree_depth)
        return step(key, z, step_size, inv_mass)

    key_ex = jnp.zeros((2,), jnp.uint32)
    eps_ex = jnp.asarray(0.1, fdt)
    mass_ex = jnp.ones((dim,), fdt)
    lw.lower(
        f"{model_name}_nuts_step",
        nuts_step,
        (key_ex, z_ex, eps_ex, mass_ex, *data),
        ["key", "z", "step_size", "inv_mass_diag", *data_names],
        NUTS_OUTPUTS,
        {**meta, "kind": "nuts_step", "max_tree_depth": max_tree_depth},
    )

    if vmap_chains > 1:
        k = vmap_chains
        vstep = jax.vmap(
            nuts_step, in_axes=(0, 0, 0, 0) + (None,) * len(data)
        )
        lw.lower(
            f"{model_name}_nuts_step_vmap{k}",
            vstep,
            (
                jnp.zeros((k, 2), jnp.uint32),
                jnp.zeros((k, dim), fdt),
                jnp.full((k,), 0.1, fdt),
                jnp.ones((k, dim), fdt),
                *data,
            ),
            ["keys", "zs", "step_sizes", "inv_mass_diags", *data_names],
            NUTS_OUTPUTS,
            {
                **meta,
                "kind": "nuts_step_vmap",
                "chains": k,
                "max_tree_depth": max_tree_depth,
            },
        )


# ---------------------------------------------------------------------------
# covtype extras: Fig 1 predictive/log-lik + Appendix D ELBO
# ---------------------------------------------------------------------------


def lower_covtype_extras(lw: Lowerer, x, y, num_samples: int, num_particles: int):
    fdt = float_dtype()
    n, d = x.shape

    # Fig 1c line 5-7: vmap over posterior draws, composing handlers.
    def predict_one(key_raw, m, b, xx):
        key = jax.random.wrap_key_data(key_raw)
        conditioned = mp.condition(logistic_regression, data={"m": m, "b": b})
        return mp.seed(conditioned, rng_key=key)(xx)

    def predict(keys, ms, bs, xx):
        return jax.vmap(lambda k, m, b: predict_one(k, m, b, xx))(keys, ms, bs)

    s = num_samples
    keys_ex = jnp.zeros((s, 2), jnp.uint32)
    ms_ex = jnp.zeros((s, d), fdt)
    bs_ex = jnp.zeros((s,), fdt)
    lw.lower(
        "covtype_predict",
        predict,
        (keys_ex, ms_ex, bs_ex, x),
        ["keys", "m_samples", "b_samples", "x"],
        ["y_pred"],
        {"model": "covtype", "kind": "predict", "num_samples": s},
    )

    def loglik_one(m, b, xx, yy):
        tr = mp.trace(
            mp.substitute(logistic_regression, data={"m": m, "b": b})
        ).get_trace(xx, y=yy)
        site = tr["y"]
        return jnp.sum(site["fn"].log_prob(site["value"]))

    def loglik(ms, bs, xx, yy):
        return jax.vmap(lambda m, b: loglik_one(m, b, xx, yy))(ms, bs)

    lw.lower(
        "covtype_loglik",
        loglik,
        (ms_ex, bs_ex, x, y),
        ["m_samples", "b_samples", "x", "y"],
        ["log_likelihood"],
        {"model": "covtype", "kind": "loglik", "num_samples": s},
    )

    # Appendix D: vectorized ELBO (mean-field normal guide on (m, b)).
    def elbo_and_grad(key_raw, loc, log_scale, xx, yy):
        key = jax.random.wrap_key_data(key_raw)

        def neg_elbo(params):
            loc_, log_scale_ = params
            scale = jnp.exp(log_scale_)

            def particle(k):
                eps = jax.random.normal(k, loc_.shape, fdt)
                zz = loc_ + scale * eps
                m, b = zz[:d], zz[d]
                logq = jnp.sum(dist.Normal(loc_, scale).log_prob(zz))
                logp, _ = mp.log_density(
                    logistic_regression, (xx,), {"y": yy}, {"m": m, "b": b}
                )
                return logp - logq

            ks = jax.random.split(key, num_particles)
            return -jnp.mean(jax.vmap(particle)(ks))

        value, grads = jax.value_and_grad(neg_elbo)((loc, log_scale))
        return -value, grads[0], grads[1]

    lw.lower(
        "covtype_elbo_and_grad",
        elbo_and_grad,
        (jnp.zeros((2,), jnp.uint32), jnp.zeros((d + 1,), fdt), jnp.zeros((d + 1,), fdt), x, y),
        ["key", "loc", "log_scale", "x", "y"],
        ["elbo", "grad_loc", "grad_log_scale"],
        {"model": "covtype", "kind": "elbo_and_grad", "num_particles": num_particles},
    )


def write_manifest(out_dir: str, entries: List[Dict[str, Any]]):
    path = os.path.join(out_dir, "manifest.json")
    existing: List[Dict[str, Any]] = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f).get("entries", [])
    merged = {e["name"]: e for e in existing}
    for e in entries:
        merged[e["name"]] = e
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": sorted(merged.values(), key=lambda e: e["name"])}, f, indent=1)
    print(f"[aot] manifest: {len(merged)} entries -> {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="hmm,covtype,covtype_small,skim",
        help="comma list: hmm,covtype,covtype_small,skim",
    )
    ap.add_argument("--covtype-n", type=int, default=50_000)
    ap.add_argument("--covtype-small-n", type=int, default=2_000)
    ap.add_argument("--skim-p", default="25,50,100,200")
    ap.add_argument("--skim-n", type=int, default=200)
    ap.add_argument("--seed", type=int, default=20191222)
    ap.add_argument("--vmap-chains", type=int, default=4)
    ap.add_argument(
        "--pallas-variants",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="also emit *_pallas artifact variants (interpret-mode L1 "
        "kernels end-to-end; the ablate-kernel experiment)",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    fdt = float_dtype()
    models = args.models.split(",")
    lw = Lowerer(args.out_dir)
    key = jax.random.PRNGKey(args.seed)

    # Kernel implementation policy (EXPERIMENTS.md §Perf): the default
    # hot-path artifacts use the pure-jnp reference implementations,
    # which XLA fuses into fast native loops on CPU; `*_pallas` variants
    # carry the L1 Pallas kernels through interpret mode — numerically
    # identical (asserted by `fugue experiment ablate-kernel` and the
    # cross-check tests) but paying the interpreter tax on CPU.  On a
    # real TPU the Pallas variants (without interpret) are the fast
    # path; see DESIGN.md §6.
    if "hmm" in models:
        data = make_hmm_data(key)
        hmm_meta = {
            "seq_len": int(data.obs.shape[0]),
            "num_supervised": int(data.sup_states.shape[0]),
        }
        lower_model_bundle(
            lw,
            "hmm",
            lambda obs, sup: hmm_model(HmmData(obs, sup), use_kernel=False),
            (data.obs, data.sup_states),
            ["obs", "sup_states"],
            {**hmm_meta, "kernel_impl": "ref"},
            vmap_chains=args.vmap_chains,
        )
        if args.pallas_variants:
            lower_model_bundle(
                lw,
                "hmm_pallas",
                lambda obs, sup: hmm_model(HmmData(obs, sup), use_kernel=True),
                (data.obs, data.sup_states),
                ["obs", "sup_states"],
                {**hmm_meta, "kernel_impl": "pallas"},
            )

    if "covtype" in models:
        x, y, _ = make_covtype_like(key, n=args.covtype_n, dtype=fdt)
        lower_model_bundle(
            lw,
            "covtype",
            lambda xx, yy: logistic_regression(xx, yy),
            (x, y),
            ["x", "y"],
            {"n": int(x.shape[0]), "d": int(x.shape[1]), "kernel_impl": "ref"},
        )

    if "covtype_small" in models:
        x, y, _ = make_covtype_like(key, n=args.covtype_small_n, dtype=fdt)
        ct_meta = {"n": int(x.shape[0]), "d": int(x.shape[1])}
        lower_model_bundle(
            lw,
            "covtype_small",
            lambda xx, yy: logistic_regression(xx, yy),
            (x, y),
            ["x", "y"],
            {**ct_meta, "kernel_impl": "ref"},
            vmap_chains=args.vmap_chains,
        )
        if args.pallas_variants:
            lower_model_bundle(
                lw,
                "covtype_small_pallas",
                lambda xx, yy: logistic_regression_fused(xx, yy),
                (x, y),
                ["x", "y"],
                {**ct_meta, "kernel_impl": "pallas"},
            )
        lower_covtype_extras(lw, x, y, num_samples=100, num_particles=8)

    if "skim" in models:
        for p in [int(s) for s in args.skim_p.split(",")]:
            xs, ys, _, _ = make_skim_data(key, n=args.skim_n, p=p, dtype=fdt)
            lower_model_bundle(
                lw,
                f"skim_p{p}",
                lambda xx, yy: skim_model(xx, yy, use_kernel=False),
                (xs, ys),
                ["x", "y"],
                {"n": int(xs.shape[0]), "p": p, "kernel_impl": "ref"},
            )
        if args.pallas_variants:
            p = int(args.skim_p.split(",")[0])
            xs, ys, _, _ = make_skim_data(key, n=args.skim_n, p=p, dtype=fdt)
            lower_model_bundle(
                lw,
                f"skim_p{p}_pallas",
                lambda xx, yy: skim_model(xx, yy, use_kernel=True),
                (xs, ys),
                ["x", "y"],
                {"n": int(xs.shape[0]), "p": p, "kernel_impl": "pallas"},
            )

    write_manifest(args.out_dir, lw.entries)


if __name__ == "__main__":
    main()
