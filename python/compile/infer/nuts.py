"""Iterative No-U-Turn Sampler — the paper's §3.1 / Appendix A.

The recursive ``BuildTree`` of Hoffman & Gelman (Algorithm 1) cannot be
traced by JAX (recursion + data-dependent control flow).  This module
implements ITERATIVEBUILDTREE (Algorithm 2): the 2^d leapfrog steps of a
trajectory doubling run inside a ``lax.while_loop``; even-numbered nodes
are stored at ``S[BitCount(n)]`` (so |S| = max tree depth, preserving the
O(log N) memory of the recursion); at odd nodes the U-turn condition is
checked against the candidate set C(n) obtained by progressively masking
trailing 1-bits of n.

The full transition kernel ``build_nuts_step`` — momentum refresh,
trajectory doubling with multinomial proposal sampling, divergence
checks, acceptance statistics — is one pure function of
``(rng_key, z, step_size, inverse mass)`` and therefore JIT-compiles
end-to-end into a single XLA executable, which is the paper's headline
(Table 2a).  Step size and mass matrix are *inputs*, so the Rust
coordinator performs warmup adaptation between calls without recompiling.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .hmc_util import (
    IntegratorState,
    bit_count,
    candidate_range,
    is_u_turn,
    kinetic_energy,
    velocity_verlet,
)

MAX_DELTA_ENERGY = 1000.0  # divergence threshold, as in NumPyro/Stan


class TreeState(NamedTuple):
    """State of the trajectory being built (both edges + proposal)."""

    z_left: jax.Array
    r_left: jax.Array
    grad_left: jax.Array
    z_right: jax.Array
    r_right: jax.Array
    grad_right: jax.Array
    z_proposal: jax.Array
    potential_proposal: jax.Array
    depth: jax.Array
    weight: jax.Array  # log sum of exp(-energy) over leaves
    turning: jax.Array
    diverging: jax.Array
    sum_accept_prob: jax.Array
    num_leapfrog: jax.Array
    r_sum: jax.Array  # sum of leaf momenta (generalized U-turn)


class _SubtreeCarry(NamedTuple):
    n: jax.Array  # leaf counter within this subtree (0-based)
    state: IntegratorState
    s_z: jax.Array  # (max_depth, D) even-node positions
    s_r: jax.Array  # (max_depth, D) even-node momenta
    z_first: jax.Array  # leftmost leaf of this subtree (S[0] in Alg. 2)
    r_first: jax.Array
    grad_first: jax.Array
    z_prop: jax.Array
    u_prop: jax.Array  # potential at proposal
    weight: jax.Array
    turning: jax.Array
    diverging: jax.Array
    sum_accept: jax.Array
    r_sum: jax.Array
    key: jax.Array


def _uturn_against_candidates(
    s_z, s_r, z, r, inv_mass_diag, i_min, i_max, going_right
) -> jax.Array:
    """Vectorized check of IsUTurn(S[k], z) for k in [i_min, i_max]
    (Algorithm 2's inner loop), other rows masked out.

    The criterion is orientation-sensitive: the chord must run from the
    *time-earlier* end to the *time-later* end.  Candidates precede node
    n in integration order, so for a forward subtree the chord is
    z - S[k]; for a backward subtree (negative step size) node n is the
    time-earlier end and the chord flips (this mirrors the eps-sign
    branch in rust/src/mcmc/nuts_iterative.rs)."""
    max_depth = s_z.shape[0]
    ks = jnp.arange(max_depth)
    active = (ks >= i_min) & (ks <= i_max)
    dz = z[None, :] - s_z  # (max_depth, D), candidate -> n
    dz = jnp.where(going_right, dz, -dz)  # time order
    vleft = jnp.einsum("kd,kd->k", dz, inv_mass_diag[None, :] * s_r)
    vright = dz @ (inv_mass_diag * r)
    turning = (vleft <= 0) | (vright <= 0)
    return jnp.any(turning & active)


def iterative_build_subtree(
    potential_and_grad: Callable,
    key: jax.Array,
    initial: IntegratorState,
    depth: jax.Array,
    step_size: jax.Array,  # signed: direction folded in
    inv_mass_diag: jax.Array,
    energy_0: jax.Array,
    max_depth: int,
):
    """Run up to 2^depth leapfrog steps (Algorithm 2), with early exit on
    U-turn or divergence.  Returns the subtree summary used by the outer
    doubling loop."""
    dim = initial.z.shape[0]
    dtype = initial.z.dtype
    num_leaves = jnp.asarray(1, jnp.int32) << depth

    carry = _SubtreeCarry(
        n=jnp.zeros((), jnp.int32),
        state=initial,
        s_z=jnp.zeros((max_depth, dim), dtype),
        s_r=jnp.zeros((max_depth, dim), dtype),
        z_first=initial.z,
        r_first=initial.r,
        grad_first=initial.grad,
        z_prop=initial.z,
        u_prop=initial.potential,
        weight=jnp.asarray(-jnp.inf, dtype),
        turning=jnp.zeros((), bool),
        diverging=jnp.zeros((), bool),
        sum_accept=jnp.zeros((), dtype),
        r_sum=jnp.zeros((dim,), dtype),
        key=key,
    )

    def cond(c: _SubtreeCarry):
        return (c.n < num_leaves) & ~c.turning & ~c.diverging

    def body(c: _SubtreeCarry):
        state = velocity_verlet(potential_and_grad, c.state, step_size, inv_mass_diag)
        energy = state.potential + kinetic_energy(state.r, inv_mass_diag)
        energy = jnp.where(jnp.isnan(energy), jnp.inf, energy)
        delta = energy - energy_0
        diverging = delta > MAX_DELTA_ENERGY
        # acceptance statistic (per-leaf MH ratio vs initial energy)
        accept = jnp.minimum(1.0, jnp.exp(-delta)).astype(c.sum_accept.dtype)

        # multinomial progressive sampling within the subtree:
        # leaf weight = -energy (relative weights exp(-H))
        leaf_w = (-energy).astype(c.weight.dtype)
        new_weight = jnp.logaddexp(c.weight, leaf_w)
        key, sub = jax.random.split(c.key)
        take_new = jax.random.uniform(sub, dtype=c.weight.dtype) < jnp.exp(
            leaf_w - new_weight
        )
        z_prop = jnp.where(take_new, state.z, c.z_prop)
        u_prop = jnp.where(take_new, state.potential, c.u_prop)

        # remember the subtree's leftmost leaf (n == 0) — Alg. 2's S[0]
        first = c.n == 0
        z_first = jnp.where(first, state.z, c.z_first)
        r_first = jnp.where(first, state.r, c.r_first)
        grad_first = jnp.where(first, state.grad, c.grad_first)

        n = c.n
        is_even = (n % 2) == 0
        # even: store node at S[BitCount(n)]
        idx = bit_count(n)
        s_z = jnp.where(
            is_even,
            c.s_z.at[idx].set(state.z),
            c.s_z,
        )
        s_r = jnp.where(
            is_even,
            c.s_r.at[idx].set(state.r),
            c.s_r,
        )
        # odd: U-turn check against candidate rows of S
        i_min, i_max = candidate_range(n)
        turning_odd = _uturn_against_candidates(
            c.s_z, c.s_r, state.z, state.r, inv_mass_diag, i_min, i_max,
            step_size > 0,
        )
        turning = jnp.where(is_even, c.turning, turning_odd)

        return _SubtreeCarry(
            n=n + 1,
            state=state,
            s_z=s_z,
            s_r=s_r,
            z_first=z_first,
            r_first=r_first,
            grad_first=grad_first,
            z_prop=z_prop,
            u_prop=u_prop,
            weight=new_weight,
            turning=turning,
            diverging=diverging,
            sum_accept=c.sum_accept + accept,
            r_sum=c.r_sum + state.r,
            key=key,
        )

    out = jax.lax.while_loop(cond, body, carry)
    return out


def build_nuts_step(
    potential_and_grad: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    max_tree_depth: int = 10,
):
    """Return ``nuts_step(key, z, step_size, inv_mass_diag)``: one NUTS
    transition as a single pure function (end-to-end jittable).

    Output: ``(z_new, accept_prob, num_leapfrog, potential_new,
    diverging, tree_depth)``.
    """

    def nuts_step(key, z, step_size, inv_mass_diag):
        dtype = z.dtype
        dim = z.shape[0]
        key_mom, key_loop = jax.random.split(key)

        potential_0, grad_0 = potential_and_grad(z)
        # momentum refresh: r ~ N(0, M), M = diag(1/inv_mass)
        eps = jax.random.normal(key_mom, (dim,), dtype)
        r0 = eps / jnp.sqrt(inv_mass_diag)
        energy_0 = potential_0 + kinetic_energy(r0, inv_mass_diag)

        init = TreeState(
            z_left=z,
            r_left=r0,
            grad_left=grad_0,
            z_right=z,
            r_right=r0,
            grad_right=grad_0,
            z_proposal=z,
            potential_proposal=potential_0,
            depth=jnp.zeros((), jnp.int32),
            weight=(-energy_0).astype(dtype),
            turning=jnp.zeros((), bool),
            diverging=jnp.zeros((), bool),
            sum_accept_prob=jnp.zeros((), dtype),
            num_leapfrog=jnp.zeros((), jnp.int32),
            r_sum=r0,
        )

        def cond(val):
            tree, _ = val
            return (tree.depth < max_tree_depth) & ~tree.turning & ~tree.diverging

        def body(val):
            tree, key = val
            key, key_dir, key_subtree, key_accept = jax.random.split(key, 4)
            going_right = jax.random.bernoulli(key_dir)
            signed_eps = jnp.where(going_right, step_size, -step_size).astype(dtype)

            edge = IntegratorState(
                z=jnp.where(going_right, tree.z_right, tree.z_left),
                r=jnp.where(going_right, tree.r_right, tree.r_left),
                potential=jnp.zeros((), dtype),  # unused by the integrator
                grad=jnp.where(going_right, tree.grad_right, tree.grad_left),
            )
            sub = iterative_build_subtree(
                potential_and_grad,
                key_subtree,
                edge,
                tree.depth,
                signed_eps,
                inv_mass_diag,
                energy_0,
                max_tree_depth,
            )

            # new outer edge = last state reached in the subtree
            z_left = jnp.where(going_right, tree.z_left, sub.state.z)
            r_left = jnp.where(going_right, tree.r_left, sub.state.r)
            grad_left = jnp.where(going_right, tree.grad_left, sub.state.grad)
            z_right = jnp.where(going_right, sub.state.z, tree.z_right)
            r_right = jnp.where(going_right, sub.state.r, tree.r_right)
            grad_right = jnp.where(going_right, sub.state.grad, tree.grad_right)

            subtree_complete = ~sub.turning & ~sub.diverging

            # biased progressive sampling across subtrees (NumPyro/Stan):
            # accept the subtree's proposal with prob min(1, w_sub / w_tree)
            log_ratio = sub.weight - tree.weight
            take_new = subtree_complete & (
                jnp.log(jax.random.uniform(key_accept, dtype=tree.weight.dtype))
                < log_ratio
            )
            z_proposal = jnp.where(take_new, sub.z_prop, tree.z_proposal)
            potential_proposal = jnp.where(
                take_new, sub.u_prop, tree.potential_proposal
            )
            weight = jnp.logaddexp(tree.weight, sub.weight)

            # U-turn across the merged tree (only meaningful if the new
            # subtree completed). Uses the full-trajectory endpoints.
            r_sum = tree.r_sum + sub.r_sum
            turning_merged = is_u_turn(z_left, z_right, r_left, r_right, inv_mass_diag)
            turning = sub.turning | (subtree_complete & turning_merged)

            new_tree = TreeState(
                z_left=z_left,
                r_left=r_left,
                grad_left=grad_left,
                z_right=z_right,
                r_right=r_right,
                grad_right=grad_right,
                z_proposal=z_proposal,
                potential_proposal=potential_proposal,
                depth=tree.depth + 1,
                weight=weight,
                turning=turning,
                diverging=sub.diverging,
                sum_accept_prob=tree.sum_accept_prob + sub.sum_accept,
                num_leapfrog=tree.num_leapfrog + sub.n,
                r_sum=r_sum,
            )
            return new_tree, key

        tree, _ = jax.lax.while_loop(cond, body, (init, key_loop))

        accept_prob = tree.sum_accept_prob / jnp.maximum(
            tree.num_leapfrog.astype(dtype), 1.0
        )
        return (
            tree.z_proposal,
            accept_prob,
            tree.num_leapfrog,
            tree.potential_proposal,
            tree.diverging,
            tree.depth,
        )

    return nuts_step
