"""Index-level oracles for the tree-building algorithms (test-only).

The correctness core of Appendix A is *which* U-turn checks the iterative
algorithm performs and *what* the storage array S contains when it
performs them.  These oracles replay both algorithms over abstract leaf
indices (no dynamics), so the test suite can assert:

* RECURSIVEBUILDTREE (Algorithm 1) checks exactly the pairs
  (leftmost leaf, rightmost leaf) of every balanced subtree;
* ITERATIVEBUILDTREE (Algorithm 2) checks, at every odd node n, the pairs
  (m, n) for m in C(n) — trailing 1-bits of n progressively masked;
* the S-array indexing scheme S[BitCount(k)] really does hold the needed
  candidate node when it is needed (the memory-efficiency claim).
"""

from __future__ import annotations

from typing import List, Set, Tuple


def bit_count(n: int) -> int:
    return bin(n).count("1")


def trailing_ones(n: int) -> int:
    count = 0
    while n & 1:
        count += 1
        n >>= 1
    return count


def candidate_set(n: int) -> List[int]:
    """C(n) per Appendix A: progressively mask trailing contiguous 1s.

    e.g. n=11=(1011): C = {(1010), (1000)} = {10, 8}."""
    out = []
    m = n
    for _ in range(trailing_ones(n)):
        # clear the lowest set bit (each clears one trailing 1)
        m = m & (m - 1)
        out.append(m)
    return out


def recursive_checks(base: int, depth: int) -> List[Tuple[int, int]]:
    """U-turn check pairs (left leaf, right leaf) performed by Algorithm 1
    on a tree of 2**depth leaves starting at ``base`` (no early exit)."""
    if depth == 0:
        return []
    half = 1 << (depth - 1)
    checks = recursive_checks(base, depth - 1)
    checks += recursive_checks(base + half, depth - 1)
    checks.append((base, base + (1 << depth) - 1))
    return checks


def iterative_checks(depth: int) -> List[Tuple[int, int]]:
    """U-turn check pairs performed by Algorithm 2 over 2**depth leaves
    (no early exit), *via the S-array mechanism*: at odd n, pairs
    (S[k], n) for k in [i_min, i_max].

    Raises AssertionError if S does not contain the candidate-set node it
    is supposed to (the memory-correctness claim of Appendix A)."""
    max_size = max(depth, 1)
    storage = [None] * max_size  # S[i] = even node index with bitcount i
    checks: List[Tuple[int, int]] = []
    for n in range(1 << depth):
        if n % 2 == 0:
            storage[bit_count(n)] = n
        else:
            expected = candidate_set(n)
            i_max = bit_count(n - 1)
            i_min = i_max - trailing_ones(n) + 1
            got = [storage[k] for k in range(i_min, i_max + 1)]
            assert sorted(x for x in got if x is not None) == sorted(expected), (
                f"S-array mismatch at n={n}: got {got}, expected {expected}"
            )
            for m in got:
                checks.append((m, n))
    return checks
