"""HMC building blocks shared by the iterative NUTS step.

Pure-and-statically-composed functions (§3): the leapfrog integrator
(with the in-graph gradient the paper highlights — ``jit`` composes with
``grad``), kinetic energy under a diagonal mass matrix, the U-turn
criterion, and the bit-twiddling helpers of Appendix A's
ITERATIVEBUILDTREE (candidate-set C(n) via trailing-ones masking).
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class IntegratorState(NamedTuple):
    z: jax.Array  # position (D,)
    r: jax.Array  # momentum (D,)
    potential: jax.Array  # U(z), scalar
    grad: jax.Array  # dU/dz (D,)


def velocity_verlet(
    potential_and_grad: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    state: IntegratorState,
    step_size: jax.Array,
    inv_mass_diag: jax.Array,
) -> IntegratorState:
    """One leapfrog step of the velocity-Verlet integrator.

    The gradient evaluation here is what Pyro pays a Python dispatch for
    on every call and what the fully-compiled step fuses away (§3.1).
    """
    z, r, _, grad = state
    r_half = r - 0.5 * step_size * grad
    z_new = z + step_size * (inv_mass_diag * r_half)
    potential_new, grad_new = potential_and_grad(z_new)
    r_new = r_half - 0.5 * step_size * grad_new
    return IntegratorState(z_new, r_new, potential_new, grad_new)


def kinetic_energy(r: jax.Array, inv_mass_diag: jax.Array) -> jax.Array:
    """K(r) = 0.5 r^T M^{-1} r for diagonal M."""
    return 0.5 * jnp.sum(inv_mass_diag * r * r)


def is_u_turn(
    z_left: jax.Array,
    z_right: jax.Array,
    r_left: jax.Array,
    r_right: jax.Array,
    inv_mass_diag: jax.Array,
) -> jax.Array:
    """Hoffman-Gelman termination criterion on a (sub)trajectory: the
    velocity at either end points back across the chord."""
    dz = z_right - z_left
    return (jnp.dot(dz, inv_mass_diag * r_left) <= 0) | (
        jnp.dot(dz, inv_mass_diag * r_right) <= 0
    )


# ---------------------------------------------------------------------------
# Appendix A bit-twiddling: candidate set C(n)
# ---------------------------------------------------------------------------


def bit_count(n: jax.Array) -> jax.Array:
    """Population count (index into the even-node storage S)."""
    return jax.lax.population_count(n.astype(jnp.uint32)).astype(jnp.int32)


def trailing_ones(n: jax.Array) -> jax.Array:
    """Number of trailing contiguous 1 bits of n = |C(n)|: the number of
    balanced subtrees for which node n is the rightmost leaf."""
    n = n.astype(jnp.uint32)
    return (jax.lax.population_count(n ^ (n + 1)) - 1).astype(jnp.int32)


def candidate_range(n: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Storage-index range [i_min, i_max] of C(n) inside S for odd n
    (Appendix A): i_max = BitCount(n-1); i_min = i_max - TrailingOnes(n) + 1."""
    i_max = bit_count(n - 1)
    i_min = i_max - trailing_ones(n) + 1
    return i_min, i_max


# ---------------------------------------------------------------------------
# Warmup adaptation primitives (also implemented on the Rust side; kept
# here so pure-python inference works end-to-end and for cross-testing)
# ---------------------------------------------------------------------------


class DualAverageState(NamedTuple):
    log_step: jax.Array
    log_step_avg: jax.Array
    grad_sum: jax.Array
    t: jax.Array
    mu: jax.Array


def dual_average_init(step_size: float) -> DualAverageState:
    z = jnp.zeros(())
    return DualAverageState(
        jnp.log(jnp.asarray(step_size)),
        jnp.zeros(()),
        z,
        jnp.zeros(()),
        jnp.log(10.0 * jnp.asarray(step_size)),
    )


def dual_average_update(
    state: DualAverageState,
    accept_prob: jax.Array,
    target: float = 0.8,
    gamma: float = 0.05,
    t0: float = 10.0,
    kappa: float = 0.75,
) -> DualAverageState:
    """Nesterov dual averaging on log step size (Hoffman-Gelman §3.2)."""
    log_step, log_step_avg, grad_sum, t, mu = state
    t = t + 1.0
    grad_sum = grad_sum + (target - accept_prob)
    # x_{t+1} = mu - sqrt(t)/gamma * (1/(t+t0)) * sum_i (delta - alpha_i)
    log_step = mu - jnp.sqrt(t) / gamma * grad_sum / (t + t0)
    eta = t ** (-kappa)
    log_step_avg = eta * log_step + (1.0 - eta) * log_step_avg
    return DualAverageState(log_step, log_step_avg, grad_sum, t, mu)


class WelfordState(NamedTuple):
    mean: jax.Array
    m2: jax.Array
    count: jax.Array


def welford_init(dim: int, dtype=jnp.float32) -> WelfordState:
    return WelfordState(
        jnp.zeros((dim,), dtype), jnp.zeros((dim,), dtype), jnp.zeros((), dtype)
    )


def welford_update(state: WelfordState, x: jax.Array) -> WelfordState:
    mean, m2, count = state
    count = count + 1.0
    delta = x - mean
    mean = mean + delta / count
    m2 = m2 + delta * (x - mean)
    return WelfordState(mean, m2, count)


def welford_variance(state: WelfordState, regularize: bool = True) -> jax.Array:
    """Sample variance, with Stan's shrinkage toward unit scale."""
    var = state.m2 / jnp.maximum(state.count - 1.0, 1.0)
    if regularize:
        n = state.count
        var = (n / (n + 5.0)) * var + 1e-3 * (5.0 / (n + 5.0))
    return var
