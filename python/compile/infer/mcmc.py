"""Pure-python MCMC driver over the compiled NUTS step.

This mirrors (and cross-validates) the Rust coordinator's chain loop:
Stan-style warmup schedule — fast dual-averaging intervals around slow
Welford mass-matrix windows — followed by sampling.  At build time it is
used by the test-suite to check statistical correctness of the in-graph
NUTS step; at run time the same logic lives in
``rust/src/coordinator/warmup.rs``.
"""

from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .hmc_util import (
    dual_average_init,
    dual_average_update,
    welford_init,
    welford_update,
    welford_variance,
)
from .nuts import build_nuts_step


class WarmupSchedule(NamedTuple):
    """Stan's three-phase warmup: initial fast interval, doubling slow
    windows (mass-matrix estimation), terminal fast interval."""

    initial_fast: int
    slow_windows: list
    terminal_fast: int

    @staticmethod
    def build(num_warmup: int) -> "WarmupSchedule":
        if num_warmup < 20:
            return WarmupSchedule(num_warmup, [], 0)
        initial = max(int(0.15 * num_warmup), 10)
        terminal = max(int(0.10 * num_warmup), 10)
        slow_total = num_warmup - initial - terminal
        windows = []
        w = 25
        remaining = slow_total
        while remaining > 0:
            if remaining >= 3 * w:
                windows.append(w)
                remaining -= w
                w *= 2
            else:
                windows.append(remaining)
                remaining = 0
        return WarmupSchedule(initial, windows, terminal)


def run_nuts(
    potential_fn: Callable,
    init_z: jax.Array,
    rng_key: jax.Array,
    num_warmup: int = 500,
    num_samples: int = 500,
    max_tree_depth: int = 10,
    init_step_size: float = 1.0,
    target_accept: float = 0.8,
    fixed_step_size: Optional[float] = None,
) -> Dict[str, np.ndarray]:
    """Run one NUTS chain; returns samples plus per-draw stats."""
    value_and_grad = jax.value_and_grad(potential_fn)
    step = jax.jit(build_nuts_step(lambda z: value_and_grad(z), max_tree_depth))
    dim = init_z.shape[0]
    dtype = init_z.dtype

    z = init_z
    inv_mass = jnp.ones((dim,), dtype)
    da = dual_average_init(init_step_size if fixed_step_size is None else fixed_step_size)
    step_size = jnp.exp(da.log_step)
    if fixed_step_size is not None:
        step_size = jnp.asarray(fixed_step_size, dtype)

    schedule = WarmupSchedule.build(num_warmup)
    # window boundaries in warmup iterations
    boundaries = []
    pos = schedule.initial_fast
    for w in schedule.slow_windows:
        pos += w
        boundaries.append(pos)
    slow_start = schedule.initial_fast
    slow_end = num_warmup - schedule.terminal_fast

    welford = welford_init(dim, dtype)
    keys = jax.random.split(rng_key, num_warmup + num_samples)

    samples = np.empty((num_samples, dim), np.float64)
    stats = {
        "accept_prob": np.empty(num_warmup + num_samples),
        "num_leapfrog": np.empty(num_warmup + num_samples, np.int64),
        "potential": np.empty(num_warmup + num_samples),
        "diverging": np.empty(num_warmup + num_samples, bool),
        "depth": np.empty(num_warmup + num_samples, np.int64),
    }

    for i in range(num_warmup + num_samples):
        z, accept, n_lf, pot, div, depth = step(keys[i], z, step_size, inv_mass)
        stats["accept_prob"][i] = float(accept)
        stats["num_leapfrog"][i] = int(n_lf)
        stats["potential"][i] = float(pot)
        stats["diverging"][i] = bool(div)
        stats["depth"][i] = int(depth)

        if i < num_warmup:
            if fixed_step_size is None:
                da = dual_average_update(da, accept, target=target_accept)
                step_size = jnp.exp(da.log_step)
            if slow_start <= i < slow_end:
                welford = welford_update(welford, z)
                if (i - slow_start + 1) in [
                    b - slow_start for b in boundaries
                ] or i == slow_end - 1:
                    # close the slow window: refresh mass matrix, reset
                    inv_mass = welford_variance(welford).astype(dtype)
                    welford = welford_init(dim, dtype)
                    if fixed_step_size is None:
                        da = dual_average_init(float(jnp.exp(da.log_step_avg)))
                        step_size = jnp.exp(da.log_step)
            if i == num_warmup - 1 and fixed_step_size is None:
                step_size = jnp.exp(da.log_step_avg)
        else:
            samples[i - num_warmup] = np.asarray(z, np.float64)

    return {"samples": samples, "step_size": float(step_size), "inv_mass": np.asarray(inv_mass), **stats}
