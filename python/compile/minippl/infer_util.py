"""Inference utilities: potential energy on unconstrained space.

This is the glue between the modeling language (handlers + primitives)
and HMC/NUTS: given a model and data, build a pure function
``U(theta_unconstrained) -> -log p(theta, data)`` including the
change-of-variables Jacobian terms, plus helpers to flatten the latent
pytree to the single vector the compiled NUTS step operates on.

Everything here is pure-and-statically-composed: ``potential_energy``
traces cleanly under ``jit``, ``grad`` and ``vmap`` (§3).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import handlers
from .primitives import sample  # noqa: F401  (re-export convenience)
from .transforms import biject_to


def get_model_trace(model, rng_key, *model_args, **model_kwargs):
    """Run the model under ``seed`` + ``trace`` and return the trace."""
    seeded = handlers.seed(model, rng_key=rng_key)
    return handlers.trace(seeded).get_trace(*model_args, **model_kwargs)


def latent_sites(model_trace) -> Dict[str, Any]:
    """Sites that HMC samples: unobserved ``sample`` sites."""
    return {
        name: site
        for name, site in model_trace.items()
        if site["type"] == "sample" and not site["is_observed"]
    }


def constrain_transforms(model_trace) -> Dict[str, Any]:
    """Per-latent-site bijection unconstrained -> support."""
    return {
        name: biject_to(site["fn"].support)
        for name, site in latent_sites(model_trace).items()
    }


def unconstrain_sample(model_trace) -> Dict[str, jax.Array]:
    """Pull the latent values of a trace back to unconstrained space."""
    transforms = constrain_transforms(model_trace)
    return {
        name: transforms[name].inv(site["value"])
        for name, site in latent_sites(model_trace).items()
    }


def log_density(model, model_args, model_kwargs, params) -> Tuple[jax.Array, Dict]:
    """``log p(params, data)`` — run the model with latents substituted to
    ``params`` (constrained space) and sum site log-probabilities,
    honouring ``mask`` and ``scale`` effects."""
    substituted = handlers.substitute(model, data=params)
    tr = handlers.trace(handlers.seed(substituted, rng_key=jax.random.PRNGKey(0))).get_trace(
        *model_args, **model_kwargs
    )
    logp = 0.0
    for site in tr.values():
        if site["type"] != "sample":
            continue
        lp = site["fn"].log_prob(site["value"])
        if site.get("mask") is not None:
            lp = jnp.where(site["mask"], lp, 0.0)
        if site.get("scale") is not None:
            lp = site["scale"] * lp
        logp = logp + jnp.sum(lp)
    return logp, tr


def potential_energy(model, model_args, model_kwargs, unconstrained: Dict[str, jax.Array]):
    """``U(theta) = -log p(f(theta), data) - log |det J_f(theta)|`` where
    ``f`` is the per-site bijection onto each latent's support."""
    # One throwaway trace to discover sites/supports (shapes are static, so
    # under jit this costs nothing at runtime).
    probe = get_model_trace(model, jax.random.PRNGKey(0), *model_args, **model_kwargs)
    transforms = constrain_transforms(probe)
    params = {}
    jac = 0.0
    for name, x in unconstrained.items():
        t = transforms[name]
        y = t(x)
        params[name] = y
        jac = jac + jnp.sum(t.log_abs_det_jacobian(x, y))
    logp, _ = log_density(model, model_args, model_kwargs, params)
    return -(logp + jac)


def initialize_model(model, rng_key, *model_args, **model_kwargs):
    """Return ``(potential_fn, init_vec, unravel, transforms)`` where
    ``potential_fn`` maps a flat unconstrained vector to scalar potential
    energy — exactly the signature the NUTS step consumes.

    Initialization follows NumPyro's ``init_to_uniform``: latents start at
    a uniform(-2, 2) draw in unconstrained space.
    """
    probe = get_model_trace(model, rng_key, *model_args, **model_kwargs)
    transforms = constrain_transforms(probe)
    init_unconstrained = {}
    key = rng_key
    for name, site in latent_sites(probe).items():
        t = transforms[name]
        shape = t.inverse_shape(jnp.shape(site["value"]))
        key, sub = jax.random.split(key)
        dtype = jnp.result_type(site["value"], float)
        init_unconstrained[name] = jax.random.uniform(
            sub, shape, minval=-2.0, maxval=2.0, dtype=dtype
        )
    init_vec, unravel = ravel_pytree(init_unconstrained)

    def potential_fn(z_flat):
        return potential_energy(model, model_args, model_kwargs, unravel(z_flat))

    return potential_fn, init_vec, unravel, transforms


def constrain_fn(model, model_args, model_kwargs, unravel) -> Callable:
    """Map a flat unconstrained vector to a dict of constrained latents."""
    probe = get_model_trace(model, jax.random.PRNGKey(0), *model_args, **model_kwargs)
    transforms = constrain_transforms(probe)

    def _constrain(z_flat):
        unc = unravel(z_flat)
        return {name: transforms[name](x) for name, x in unc.items()}

    return _constrain
