"""Core language primitives: ``sample`` and ``param``.

This module implements the effect-handling abstraction of the paper's §2:
primitive statements construct a *message* that travels down a stack of
handlers (``Messenger`` subclasses, see :mod:`minippl.handlers`), each of
which may modify it (``process_message``), then — after the default
behaviour runs — back up the stack (``postprocess_message``).

Because handlers operate entirely within the Python runtime on plain
dicts and JAX arrays, they are transparent to the JAX tracer and compose
freely with ``jit`` / ``grad`` / ``vmap`` (the paper's central point).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

# The global handler stack.  Entering a Messenger pushes it; exiting pops.
_HANDLER_STACK: List["Messenger"] = []


class Messenger:
    """Base effect handler.

    A ``Messenger`` wraps a callable ``fn``; while the wrapper executes,
    the messenger sits on the handler stack and sees every primitive
    message issued inside ``fn``.
    """

    def __init__(self, fn: Optional[Callable] = None):
        self.fn = fn

    def __enter__(self) -> "Messenger":
        _HANDLER_STACK.append(self)
        return self

    def __exit__(self, exc_type, exc_value, tb) -> None:
        popped = _HANDLER_STACK.pop()
        if exc_type is None:
            assert popped is self, "handler stack corrupted"

    def process_message(self, msg: Dict[str, Any]) -> None:
        """Hook run top-down *before* the default behaviour."""

    def postprocess_message(self, msg: Dict[str, Any]) -> None:
        """Hook run bottom-up *after* the default behaviour."""

    def __call__(self, *args, **kwargs):
        if self.fn is None:
            raise ValueError(
                f"{type(self).__name__} wraps no function; it can only be "
                "used as a context manager"
            )
        with self:
            return self.fn(*args, **kwargs)


def _default_sample(msg: Dict[str, Any]) -> None:
    """Default interpretation of a ``sample`` statement: draw from ``fn``."""
    if msg["value"] is None:
        rng_key = msg["kwargs"].get("rng_key")
        if rng_key is None:
            raise ValueError(
                f"site '{msg['name']}': no value and no PRNGKey. Wrap the "
                "model in the seed(...) handler (see Table 1 of the paper)."
            )
        msg["value"] = msg["fn"].sample(rng_key, msg["kwargs"].get("sample_shape", ()))


def apply_stack(msg: Dict[str, Any]) -> Dict[str, Any]:
    """Send ``msg`` through the handler stack (top-down), apply the default
    behaviour, then unwind (bottom-up)."""
    pointer = 0
    # Top of the stack is the innermost handler: traverse outermost-last,
    # i.e. iterate from the end (innermost) toward the beginning.
    for pointer, handler in enumerate(reversed(_HANDLER_STACK)):
        handler.process_message(msg)
        if msg.get("stop"):
            break
    if msg["type"] == "sample":
        _default_sample(msg)
    # Unwind only through the handlers that saw the message.
    for handler in _HANDLER_STACK[len(_HANDLER_STACK) - pointer - 1 :]:
        handler.postprocess_message(msg)
    return msg


def sample(
    name: str,
    fn,
    obs: Optional[jax.Array] = None,
    rng_key: Optional[jax.Array] = None,
    sample_shape: tuple = (),
):
    """Designate a random variable ``name ~ fn``.

    With no handlers on the stack this behaves like a direct draw
    (requiring ``rng_key``); handlers reinterpret it (record, condition,
    seed, replay...).
    """
    if not _HANDLER_STACK and obs is None and rng_key is None:
        raise ValueError(
            f"sample('{name}', ...) called outside any handler without "
            "obs/rng_key"
        )
    msg = {
        "type": "sample",
        "name": name,
        "fn": fn,
        "args": (),
        "kwargs": {"rng_key": rng_key, "sample_shape": sample_shape},
        "value": obs,
        "is_observed": obs is not None,
        "scale": None,
        "stop": False,
    }
    apply_stack(msg)
    return msg["value"]


def factor(name: str, log_factor) -> None:
    """Add an arbitrary log-density term to the model (a ``sample``
    statement against a degenerate :class:`~minippl.distributions.Unit`
    distribution).  Used e.g. for marginalized likelihoods."""
    from . import distributions as dist

    sample(name, dist.Unit(log_factor), obs=jnp.zeros(()))


def param(name: str, init_value: Optional[jax.Array] = None, **kwargs):
    """Designate a learnable parameter.

    The default behaviour returns ``init_value``; handlers like
    ``substitute`` replace it with optimizer state (used by SVI).
    """
    msg = {
        "type": "param",
        "name": name,
        "fn": lambda v: v,
        "args": (init_value,),
        "kwargs": kwargs,
        "value": None,
        "is_observed": False,
        "scale": None,
        "stop": False,
    }
    apply_stack(msg)
    if msg["value"] is None:
        msg["value"] = init_value
    return msg["value"]
