"""Bijective transforms between unconstrained space and distribution
supports, with log-abs-det Jacobians.

HMC/NUTS runs on unconstrained parameters; ``biject_to(support)`` selects
the transform that maps R^n onto the support of each latent site, and the
potential energy adds the Jacobian correction (§3.1 — this mirrors what
Stan and NumPyro do internally).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import constraints


class Transform:
    """Bijection ``y = f(x)`` from unconstrained ``x`` to constrained ``y``.

    ``event_dim_in``/``event_dim_out`` give the event dimensionality on
    each side (stick-breaking maps vectors to vectors of different size).
    ``log_abs_det_jacobian`` returns per-event values (already summed over
    event dims).
    """

    event_dim_in = 0
    event_dim_out = 0

    def __call__(self, x):
        raise NotImplementedError

    def inv(self, y):
        raise NotImplementedError

    def log_abs_det_jacobian(self, x, y):
        raise NotImplementedError

    # Shape of x needed to produce a constrained value of shape `shape`.
    def inverse_shape(self, shape):
        return shape


class IdentityTransform(Transform):
    def __call__(self, x):
        return x

    def inv(self, y):
        return y

    def log_abs_det_jacobian(self, x, y):
        return jnp.zeros(jnp.shape(x))


class ExpTransform(Transform):
    """R -> (0, inf), y = exp(x)."""

    def __call__(self, x):
        return jnp.exp(x)

    def inv(self, y):
        return jnp.log(y)

    def log_abs_det_jacobian(self, x, y):
        return x


class SigmoidTransform(Transform):
    """R -> (0, 1), y = sigmoid(x)."""

    def __call__(self, x):
        return jax.nn.sigmoid(x)

    def inv(self, y):
        return jnp.log(y) - jnp.log1p(-y)

    def log_abs_det_jacobian(self, x, y):
        # log sigmoid'(x) = log σ(x) + log σ(-x) = -softplus(-x) - softplus(x)
        return -jax.nn.softplus(x) - jax.nn.softplus(-x)


class AffineTransform(Transform):
    """y = loc + scale * x."""

    def __init__(self, loc, scale):
        self.loc = loc
        self.scale = scale

    def __call__(self, x):
        return self.loc + self.scale * x

    def inv(self, y):
        return (y - self.loc) / self.scale

    def log_abs_det_jacobian(self, x, y):
        return jnp.broadcast_to(jnp.log(jnp.abs(self.scale)), jnp.shape(x))


class ComposeTransform(Transform):
    """f = parts[-1] ∘ ... ∘ parts[0]."""

    def __init__(self, parts):
        self.parts = list(parts)
        self.event_dim_in = self.parts[0].event_dim_in
        self.event_dim_out = self.parts[-1].event_dim_out

    def __call__(self, x):
        for p in self.parts:
            x = p(x)
        return x

    def inv(self, y):
        for p in reversed(self.parts):
            y = p.inv(y)
        return y

    def log_abs_det_jacobian(self, x, y):
        total = 0.0
        for p in self.parts:
            y_p = p(x)
            total = total + p.log_abs_det_jacobian(x, y_p)
            x = y_p
        return total

    def inverse_shape(self, shape):
        for p in reversed(self.parts):
            shape = p.inverse_shape(shape)
        return shape


class StickBreakingTransform(Transform):
    """R^{K-1} -> K-simplex via the stick-breaking construction.

    With offsets o_i = log(K-1-i), z_i = sigmoid(x_i - o_i), remainder
    r_i = prod_{j<i}(1 - z_j):   y_i = z_i * r_i,  y_{K-1} = r_{K-1}.
    The offset makes x = 0 map to the uniform simplex point.
    """

    event_dim_in = 1
    event_dim_out = 1

    def __call__(self, x):
        k = x.shape[-1]
        offsets = jnp.log(jnp.arange(k, 0, -1, dtype=x.dtype))
        z = jax.nn.sigmoid(x - offsets)
        one_minus = 1.0 - z
        rem = jnp.concatenate(
            [jnp.ones(x.shape[:-1] + (1,), dtype=x.dtype), jnp.cumprod(one_minus, axis=-1)],
            axis=-1,
        )
        y = jnp.concatenate([z, jnp.ones(x.shape[:-1] + (1,), dtype=x.dtype)], axis=-1)
        return y * rem

    def inv(self, y):
        k = y.shape[-1] - 1
        offsets = jnp.log(jnp.arange(k, 0, -1, dtype=y.dtype))
        # remainder before index i: 1 - cumsum_{j<i} y_j
        cs = jnp.cumsum(y[..., :-1], axis=-1)
        rem = jnp.concatenate(
            [jnp.ones(y.shape[:-1] + (1,), dtype=y.dtype), 1.0 - cs[..., :-1]], axis=-1
        )
        z = jnp.clip(y[..., :-1] / rem, 1e-12, 1.0 - 1e-12)
        return jnp.log(z) - jnp.log1p(-z) + offsets

    def log_abs_det_jacobian(self, x, y):
        k = x.shape[-1]
        offsets = jnp.log(jnp.arange(k, 0, -1, dtype=x.dtype))
        xs = x - offsets
        # log z + log(1-z) per coordinate
        log_z = -jax.nn.softplus(-xs)
        log_1mz = -jax.nn.softplus(xs)
        one_minus = jax.nn.sigmoid(-xs)
        log_rem = jnp.concatenate(
            [
                jnp.zeros(x.shape[:-1] + (1,), dtype=x.dtype),
                jnp.cumsum(jnp.log(one_minus), axis=-1)[..., :-1],
            ],
            axis=-1,
        )
        return jnp.sum(log_z + log_1mz + log_rem, axis=-1)

    def inverse_shape(self, shape):
        return shape[:-1] + (shape[-1] - 1,)


class OrderedTransform(Transform):
    """R^K -> ordered vectors: y_0 = x_0, y_i = y_{i-1} + exp(x_i)."""

    event_dim_in = 1
    event_dim_out = 1

    def __call__(self, x):
        z = jnp.concatenate([x[..., :1], jnp.exp(x[..., 1:])], axis=-1)
        return jnp.cumsum(z, axis=-1)

    def inv(self, y):
        return jnp.concatenate(
            [y[..., :1], jnp.log(jnp.diff(y, axis=-1))], axis=-1
        )

    def log_abs_det_jacobian(self, x, y):
        return jnp.sum(x[..., 1:], axis=-1)


def biject_to(constraint) -> Transform:
    """Select the canonical bijection from unconstrained space onto the
    support described by ``constraint``."""
    if isinstance(constraint, constraints._Real):
        return IdentityTransform()
    if isinstance(constraint, constraints._Positive):
        return ExpTransform()
    if isinstance(constraint, constraints._UnitInterval):
        return SigmoidTransform()
    if isinstance(constraint, constraints._Interval):
        return ComposeTransform(
            [
                SigmoidTransform(),
                AffineTransform(constraint.low, constraint.high - constraint.low),
            ]
        )
    if isinstance(constraint, constraints._Simplex):
        return StickBreakingTransform()
    if isinstance(constraint, constraints._OrderedVector):
        return OrderedTransform()
    raise NotImplementedError(f"no bijection registered for {constraint}")
