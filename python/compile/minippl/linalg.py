"""Pure-JAX dense linear algebra (Cholesky + triangular solve).

``jnp.linalg.cholesky`` / ``jax.scipy.linalg.solve_triangular`` lower to
LAPACK *custom calls* on CPU (API_VERSION_TYPED_FFI) which the AOT
consumer (xla_extension 0.5.1 behind the Rust ``xla`` crate) cannot
compile.  These versions lower to plain HLO (fori_loop + dynamic
slicing), are reverse-mode differentiable, and are validated against the
LAPACK-backed implementations in the pytest suite.

Used by :class:`minippl.distributions.MultivariateNormal`, i.e. by the
SKIM marginal likelihood — N = 200, so the O(N) sequential loop with
O(N) vector body is cheap relative to the N x N kernel construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cholesky(a: jax.Array) -> jax.Array:
    """Lower Cholesky factor of an SPD matrix (Cholesky-Banachiewicz,
    column at a time)."""
    n = a.shape[-1]
    idx = jnp.arange(n)

    def body(j, l):
        # columns < j of `l` are final; the rest are zero.
        lj = l[j, :]  # row j: only entries < j are nonzero
        d = a[j, j] - jnp.dot(lj, lj)
        ljj = jnp.sqrt(d)
        # column j below the diagonal
        col = (a[:, j] - l @ lj) / ljj
        col = jnp.where(idx > j, col, 0.0)
        l = l.at[:, j].add(col)
        l = l.at[j, j].set(ljj)
        return l

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a), unroll=False)


def solve_lower(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L x = b for lower-triangular L (forward substitution)."""
    n = b.shape[0]

    def body(i, x):
        xi = (b[i] - jnp.dot(l[i, :], x)) / l[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b), unroll=False)


def mvn_logpdf(value: jax.Array, loc: jax.Array, scale_tril: jax.Array) -> jax.Array:
    """log N(value | loc, L L^T) without LAPACK custom calls."""
    dim = value.shape[-1]
    alpha = solve_lower(scale_tril, value - loc)
    half_logdet = jnp.sum(jnp.log(jnp.diagonal(scale_tril)))
    return (
        -0.5 * jnp.sum(alpha * alpha)
        - half_logdet
        - 0.5 * dim * jnp.log(2 * jnp.pi).astype(value.dtype)
    )
