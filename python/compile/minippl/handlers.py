"""Effect handlers (the paper's Table 1).

Each handler gives a nonstandard interpretation to ``sample`` / ``param``
statements.  Handlers are plain Python objects operating on message dicts,
hence invisible to the JAX tracer: ``vmap(lambda k: seed(model, k)(x))``
traces straight through them (§3.2).

=============  ====================  =========================================
handler        primitives affected   effect
=============  ====================  =========================================
``seed``       sample                split a PRNGKey for every sample site
``trace``      sample, param         record inputs/outputs of every site
``condition``  sample                fix *observed* values at given sites
``substitute`` sample, param         fix values (stay unobserved; for HMC/SVI)
``replay``     sample                replay values from a recorded trace
``mask``       sample                mask log-density contributions
``block``      sample, param         hide sites from outer handlers
``scale``      sample                rescale log-density contributions
=============  ====================  =========================================
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Optional

import jax

from .primitives import Messenger


class trace(Messenger):
    """Record the input, output and distribution of every ``sample`` /
    ``param`` statement into an ordered dict keyed by site name.

    Usage: ``tr = trace(fn).get_trace(*args)``.
    """

    def __enter__(self):
        super().__enter__()
        self._trace: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        return self._trace

    def postprocess_message(self, msg: Dict[str, Any]) -> None:
        if msg["type"] in ("sample", "param"):
            name = msg["name"]
            if name in self._trace:
                raise ValueError(f"duplicate site name '{name}' in trace")
            self._trace[name] = msg.copy()

    def get_trace(self, *args, **kwargs) -> "OrderedDict[str, Dict[str, Any]]":
        self(*args, **kwargs)
        return self._trace


class seed(Messenger):
    """Seed ``fn`` with a PRNGKey.  Every ``sample`` call splits the key to
    generate a fresh seed for subsequent calls, abstracting JAX's explicit
    functional PRNG away from the modeling language (§2)."""

    def __init__(self, fn: Optional[Callable] = None, rng_key: Optional[jax.Array] = None):
        if rng_key is None:
            raise ValueError("seed(...) requires an rng_key")
        # Accept raw uint32[2] key data as well as typed keys.
        if getattr(rng_key, "dtype", None) is not None and rng_key.dtype == jax.numpy.uint32:
            rng_key = jax.random.wrap_key_data(rng_key)
        self.rng_key = rng_key
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if (
            msg["type"] == "sample"
            and not msg["is_observed"]
            and msg["value"] is None
            and msg["kwargs"].get("rng_key") is None
        ):
            self.rng_key, subkey = jax.random.split(self.rng_key)
            msg["kwargs"]["rng_key"] = subkey


class substitute(Messenger):
    """Fix the value of matching sites to ``data[name]`` (or the result of
    ``substitute_fn(msg)``) *without* marking them observed.  Used to run a
    model at specific latent values, e.g. inside potential-energy
    evaluation for HMC/NUTS or parameter updates in SVI."""

    def __init__(
        self,
        fn: Optional[Callable] = None,
        data: Optional[Dict[str, jax.Array]] = None,
        substitute_fn: Optional[Callable] = None,
    ):
        if (data is None) == (substitute_fn is None):
            raise ValueError("substitute: provide exactly one of data / substitute_fn")
        self.data = data
        self.substitute_fn = substitute_fn
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if msg["type"] not in ("sample", "param"):
            return
        if self.data is not None:
            if msg["name"] in self.data:
                msg["value"] = self.data[msg["name"]]
        else:
            value = self.substitute_fn(msg)
            if value is not None:
                msg["value"] = value


class condition(Messenger):
    """Condition unobserved ``sample`` sites to the values in ``data``,
    marking them observed (they contribute to the likelihood and are not
    resampled)."""

    def __init__(self, fn: Optional[Callable] = None, data: Optional[Dict[str, jax.Array]] = None):
        if data is None:
            raise ValueError("condition(...) requires data")
        self.data = data
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if msg["type"] == "sample" and msg["name"] in self.data:
            if msg["is_observed"]:
                raise ValueError(
                    f"cannot condition already-observed site '{msg['name']}'"
                )
            msg["value"] = self.data[msg["name"]]
            msg["is_observed"] = True


class replay(Messenger):
    """Replay ``sample`` statements against values recorded in a trace
    (e.g. run the model at the guide's sampled latents when computing an
    ELBO)."""

    def __init__(self, fn: Optional[Callable] = None, guide_trace: Optional[Dict] = None):
        if guide_trace is None:
            raise ValueError("replay(...) requires a guide_trace")
        self.guide_trace = guide_trace
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if msg["type"] == "sample" and msg["name"] in self.guide_trace:
            site = self.guide_trace[msg["name"]]
            if site["type"] != "sample":
                return
            if msg["is_observed"]:
                return
            msg["value"] = site["value"]


class mask(Messenger):
    """Multiply the log-density contribution of matching sample sites by a
    boolean (or float) mask — used e.g. for ragged batches or
    semi-supervised likelihoods."""

    def __init__(self, fn: Optional[Callable] = None, mask: Any = True):
        self.mask = mask
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if msg["type"] == "sample":
            prev = msg.get("mask")
            msg["mask"] = self.mask if prev is None else prev & self.mask


class scale(Messenger):
    """Rescale the log-density of matching sites by a positive factor
    (used for data subsampling corrections)."""

    def __init__(self, fn: Optional[Callable] = None, scale_factor: float = 1.0):
        if not (scale_factor is not None):
            raise ValueError("scale(...) requires scale_factor")
        self.scale_factor = scale_factor
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if msg["type"] == "sample":
            prev = msg.get("scale")
            msg["scale"] = self.scale_factor if prev is None else prev * self.scale_factor


class block(Messenger):
    """Hide matching sites from handlers *outside* this one (stop message
    propagation).  ``hide_fn`` selects which sites to hide (default all)."""

    def __init__(self, fn: Optional[Callable] = None, hide_fn: Optional[Callable] = None):
        self.hide_fn = hide_fn if hide_fn is not None else (lambda msg: True)
        super().__init__(fn)

    def process_message(self, msg: Dict[str, Any]) -> None:
        if self.hide_fn(msg):
            msg["stop"] = True
