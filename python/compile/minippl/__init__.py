"""minippl — a from-scratch reimplementation of the paper's effect-handler
probabilistic programming layer (NumPyro §2) on JAX.

The modeling language is the paper's: ``sample``/``param`` primitives with
composable effect handlers (``seed``, ``trace``, ``condition``,
``substitute``, ``replay``, ``mask``, ...) that are transparent to the JAX
tracer and therefore compose with ``jit`` / ``grad`` / ``vmap``.
"""

from . import constraints, distributions, handlers, transforms
from .handlers import block, condition, mask, replay, scale, seed, substitute, trace
from .infer_util import (
    constrain_fn,
    initialize_model,
    log_density,
    potential_energy,
    unconstrain_sample,
)
from .primitives import factor, param, sample

__all__ = [
    "block",
    "condition",
    "constraints",
    "constrain_fn",
    "distributions",
    "factor",
    "handlers",
    "initialize_model",
    "log_density",
    "mask",
    "param",
    "potential_energy",
    "replay",
    "sample",
    "scale",
    "seed",
    "substitute",
    "trace",
    "transforms",
    "unconstrain_sample",
]
