"""Support constraints for distributions.

Each distribution declares its ``support``; ``transforms.biject_to`` maps
a constraint to the bijection HMC uses to run on unconstrained space.
Constraints are also *checkable* (``constraint(x)`` returns a boolean
mask), which the test-suite uses to property-check samplers.
"""

from __future__ import annotations

import jax.numpy as jnp


class Constraint:
    event_dim = 0

    def __call__(self, x):
        raise NotImplementedError


class _Real(Constraint):
    def __call__(self, x):
        return jnp.isfinite(x)

    def __repr__(self):
        return "Real()"


class _Positive(Constraint):
    def __call__(self, x):
        return x > 0

    def __repr__(self):
        return "Positive()"


class _UnitInterval(Constraint):
    def __call__(self, x):
        return (x > 0) & (x < 1)

    def __repr__(self):
        return "UnitInterval()"


class _Interval(Constraint):
    def __init__(self, low, high):
        self.low = low
        self.high = high

    def __call__(self, x):
        return (x > self.low) & (x < self.high)

    def __repr__(self):
        return f"Interval({self.low}, {self.high})"


class _Simplex(Constraint):
    event_dim = 1

    def __call__(self, x):
        return (x >= 0).all(-1) & (jnp.abs(x.sum(-1) - 1.0) < 1e-5)

    def __repr__(self):
        return "Simplex()"


class _OrderedVector(Constraint):
    event_dim = 1

    def __call__(self, x):
        return (jnp.diff(x, axis=-1) > 0).all(-1)

    def __repr__(self):
        return "OrderedVector()"


class _IntegerInterval(Constraint):
    def __init__(self, low, high):
        self.low = low
        self.high = high

    def __call__(self, x):
        return (x >= self.low) & (x <= self.high) & (x == jnp.floor(x))

    def __repr__(self):
        return f"IntegerInterval({self.low}, {self.high})"


class _Boolean(Constraint):
    def __call__(self, x):
        return (x == 0) | (x == 1)

    def __repr__(self):
        return "Boolean()"


real = _Real()
positive = _Positive()
unit_interval = _UnitInterval()
simplex = _Simplex()
ordered_vector = _OrderedVector()
boolean = _Boolean()


def interval(low, high) -> _Interval:
    return _Interval(low, high)


def integer_interval(low, high) -> _IntegerInterval:
    return _IntegerInterval(low, high)
