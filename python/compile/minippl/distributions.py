"""JAX distributions for minippl.

Every distribution exposes ``sample(key, sample_shape)``, ``log_prob(x)``,
``support`` (a :mod:`constraints` object), ``batch_shape``/``event_shape``
and — where cheap — ``mean``/``variance`` (used by the test suite and the
moment-based diagnostics on the Rust side).

All densities are written with numerically-stable primitives from
``jax.scipy.special`` so they remain well-behaved under ``grad`` inside
the compiled NUTS step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import betaln, digamma, gammaln, xlog1py, xlogy

from . import constraints


def _promote(*args):
    return jnp.broadcast_arrays(*[jnp.asarray(a) for a in args])


class Distribution:
    support = constraints.real
    event_shape: tuple = ()

    def __init__(self, batch_shape=()):
        self.batch_shape = tuple(batch_shape)

    @property
    def event_dim(self) -> int:
        return len(self.event_shape)

    def shape(self, sample_shape=()) -> tuple:
        return tuple(sample_shape) + self.batch_shape + self.event_shape

    def sample(self, key, sample_shape=()):
        raise NotImplementedError

    def log_prob(self, value):
        raise NotImplementedError

    @property
    def mean(self):
        raise NotImplementedError

    @property
    def variance(self):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Continuous, univariate
# ---------------------------------------------------------------------------


class Normal(Distribution):
    support = constraints.real

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = _promote(loc, scale)
        super().__init__(jnp.shape(self.loc))

    def sample(self, key, sample_shape=()):
        eps = jax.random.normal(key, self.shape(sample_shape), dtype=jnp.result_type(self.loc, float))
        return self.loc + self.scale * eps

    def log_prob(self, value):
        z = (value - self.loc) / self.scale
        return -0.5 * z**2 - jnp.log(self.scale) - 0.5 * jnp.log(2 * jnp.pi)

    @property
    def mean(self):
        return self.loc

    @property
    def variance(self):
        return self.scale**2


class LogNormal(Distribution):
    support = constraints.positive

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = _promote(loc, scale)
        self._base = Normal(self.loc, self.scale)
        super().__init__(jnp.shape(self.loc))

    def sample(self, key, sample_shape=()):
        return jnp.exp(self._base.sample(key, sample_shape))

    def log_prob(self, value):
        return self._base.log_prob(jnp.log(value)) - jnp.log(value)

    @property
    def mean(self):
        return jnp.exp(self.loc + 0.5 * self.scale**2)

    @property
    def variance(self):
        return (jnp.exp(self.scale**2) - 1) * jnp.exp(2 * self.loc + self.scale**2)


class HalfNormal(Distribution):
    support = constraints.positive

    def __init__(self, scale=1.0):
        (self.scale,) = _promote(scale)
        super().__init__(jnp.shape(self.scale))

    def sample(self, key, sample_shape=()):
        eps = jax.random.normal(key, self.shape(sample_shape), dtype=jnp.result_type(self.scale, float))
        return jnp.abs(self.scale * eps)

    def log_prob(self, value):
        z = value / self.scale
        return jnp.log(2.0) - 0.5 * z**2 - jnp.log(self.scale) - 0.5 * jnp.log(2 * jnp.pi)

    @property
    def mean(self):
        return self.scale * jnp.sqrt(2.0 / jnp.pi)

    @property
    def variance(self):
        return self.scale**2 * (1.0 - 2.0 / jnp.pi)


class Cauchy(Distribution):
    support = constraints.real

    def __init__(self, loc=0.0, scale=1.0):
        self.loc, self.scale = _promote(loc, scale)
        super().__init__(jnp.shape(self.loc))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), dtype=jnp.result_type(self.loc, float))
        return self.loc + self.scale * jnp.tan(jnp.pi * (u - 0.5))

    def log_prob(self, value):
        z = (value - self.loc) / self.scale
        return -jnp.log(jnp.pi) - jnp.log(self.scale) - jnp.log1p(z**2)


class HalfCauchy(Distribution):
    """Workhorse of sparsity-inducing priors (SKIM's local scales)."""

    support = constraints.positive

    def __init__(self, scale=1.0):
        (self.scale,) = _promote(scale)
        super().__init__(jnp.shape(self.scale))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), dtype=jnp.result_type(self.scale, float))
        return self.scale * jnp.tan(jnp.pi * u / 2.0)

    def log_prob(self, value):
        z = value / self.scale
        return jnp.log(2.0) - jnp.log(jnp.pi) - jnp.log(self.scale) - jnp.log1p(z**2)


class StudentT(Distribution):
    support = constraints.real

    def __init__(self, df, loc=0.0, scale=1.0):
        self.df, self.loc, self.scale = _promote(df, loc, scale)
        super().__init__(jnp.shape(self.loc))

    def sample(self, key, sample_shape=()):
        shape = self.shape(sample_shape)
        dtype = jnp.result_type(self.loc, float)
        return self.loc + self.scale * jax.random.t(key, self.df, shape, dtype=dtype)

    def log_prob(self, value):
        z = (value - self.loc) / self.scale
        nu = self.df
        return (
            gammaln(0.5 * (nu + 1.0))
            - gammaln(0.5 * nu)
            - 0.5 * jnp.log(nu * jnp.pi)
            - jnp.log(self.scale)
            - 0.5 * (nu + 1.0) * jnp.log1p(z**2 / nu)
        )


class Exponential(Distribution):
    support = constraints.positive

    def __init__(self, rate=1.0):
        (self.rate,) = _promote(rate)
        super().__init__(jnp.shape(self.rate))

    def sample(self, key, sample_shape=()):
        u = jax.random.exponential(key, self.shape(sample_shape), dtype=jnp.result_type(self.rate, float))
        return u / self.rate

    def log_prob(self, value):
        return jnp.log(self.rate) - self.rate * value

    @property
    def mean(self):
        return 1.0 / self.rate

    @property
    def variance(self):
        return 1.0 / self.rate**2


class Gamma(Distribution):
    support = constraints.positive

    def __init__(self, concentration, rate=1.0):
        self.concentration, self.rate = _promote(concentration, rate)
        super().__init__(jnp.shape(self.concentration))

    def sample(self, key, sample_shape=()):
        dtype = jnp.result_type(self.concentration, float)
        g = jax.random.gamma(key, self.concentration, self.shape(sample_shape), dtype=dtype)
        return g / self.rate

    def log_prob(self, value):
        a, b = self.concentration, self.rate
        return xlogy(a, b) + xlogy(a - 1.0, value) - b * value - gammaln(a)

    @property
    def mean(self):
        return self.concentration / self.rate

    @property
    def variance(self):
        return self.concentration / self.rate**2


class InverseGamma(Distribution):
    support = constraints.positive

    def __init__(self, concentration, rate=1.0):
        self.concentration, self.rate = _promote(concentration, rate)
        super().__init__(jnp.shape(self.concentration))

    def sample(self, key, sample_shape=()):
        dtype = jnp.result_type(self.concentration, float)
        g = jax.random.gamma(key, self.concentration, self.shape(sample_shape), dtype=dtype)
        return self.rate / g

    def log_prob(self, value):
        a, b = self.concentration, self.rate
        return xlogy(a, b) - xlogy(a + 1.0, value) - b / value - gammaln(a)


class Beta(Distribution):
    support = constraints.unit_interval

    def __init__(self, concentration1, concentration0):
        self.concentration1, self.concentration0 = _promote(concentration1, concentration0)
        super().__init__(jnp.shape(self.concentration1))

    def sample(self, key, sample_shape=()):
        dtype = jnp.result_type(self.concentration1, float)
        return jax.random.beta(
            key, self.concentration1, self.concentration0, self.shape(sample_shape), dtype=dtype
        )

    def log_prob(self, value):
        a, b = self.concentration1, self.concentration0
        return xlogy(a - 1.0, value) + xlog1py(b - 1.0, -value) - betaln(a, b)

    @property
    def mean(self):
        return self.concentration1 / (self.concentration1 + self.concentration0)


class Uniform(Distribution):
    def __init__(self, low=0.0, high=1.0):
        self.low, self.high = _promote(low, high)
        super().__init__(jnp.shape(self.low))

    @property
    def support(self):
        return constraints.interval(self.low, self.high)

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape), dtype=jnp.result_type(self.low, float))
        return self.low + (self.high - self.low) * u

    def log_prob(self, value):
        inside = (value >= self.low) & (value <= self.high)
        lp = -jnp.log(self.high - self.low)
        return jnp.where(inside, lp, -jnp.inf)

    @property
    def mean(self):
        return 0.5 * (self.low + self.high)


class Unit(Distribution):
    """Degenerate distribution carrying only a log-density factor.

    Backs the ``factor(name, log_factor)`` primitive (arbitrary
    log-density terms such as the HMM forward-algorithm marginal)."""

    support = constraints.real

    def __init__(self, log_factor):
        self.log_factor = jnp.asarray(log_factor)
        super().__init__(())

    def sample(self, key, sample_shape=()):
        return jnp.zeros(tuple(sample_shape))

    def log_prob(self, value):
        return self.log_factor


# ---------------------------------------------------------------------------
# Discrete
# ---------------------------------------------------------------------------


class Bernoulli(Distribution):
    support = constraints.boolean

    def __init__(self, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("Bernoulli: provide exactly one of probs / logits")
        if probs is not None:
            (self.probs,) = _promote(probs)
            self.logits = jnp.log(self.probs) - jnp.log1p(-self.probs)
        else:
            (self.logits,) = _promote(logits)
            self.probs = jax.nn.sigmoid(self.logits)
        super().__init__(jnp.shape(self.logits))

    def sample(self, key, sample_shape=()):
        u = jax.random.uniform(key, self.shape(sample_shape))
        return (u < self.probs).astype(jnp.int32)

    def log_prob(self, value):
        # x*l - softplus(l): stable for both classes.
        return value * self.logits - jax.nn.softplus(self.logits)

    @property
    def mean(self):
        return self.probs

    @property
    def variance(self):
        return self.probs * (1.0 - self.probs)


class Categorical(Distribution):
    def __init__(self, probs=None, logits=None):
        if (probs is None) == (logits is None):
            raise ValueError("Categorical: provide exactly one of probs / logits")
        if probs is not None:
            (self.probs,) = _promote(probs)
            self.logits = jnp.log(self.probs)
        else:
            (self.logits,) = _promote(logits)
            self.probs = jax.nn.softmax(self.logits, axis=-1)
        super().__init__(jnp.shape(self.logits)[:-1])

    @property
    def support(self):
        return constraints.integer_interval(0, jnp.shape(self.logits)[-1] - 1)

    def sample(self, key, sample_shape=()):
        return jax.random.categorical(
            key, self.logits, axis=-1, shape=self.shape(sample_shape)
        )

    def log_prob(self, value):
        logp = jax.nn.log_softmax(self.logits, axis=-1)
        value = jnp.asarray(value)[..., None]
        return jnp.take_along_axis(logp, value, axis=-1)[..., 0]

    @property
    def mean(self):
        k = jnp.arange(self.probs.shape[-1])
        return jnp.sum(self.probs * k, axis=-1)


# ---------------------------------------------------------------------------
# Multivariate
# ---------------------------------------------------------------------------


class Dirichlet(Distribution):
    support = constraints.simplex
    event_dim = 1

    def __init__(self, concentration):
        self.concentration = jnp.asarray(concentration)
        self.event_shape = jnp.shape(self.concentration)[-1:]
        super().__init__(jnp.shape(self.concentration)[:-1])

    def sample(self, key, sample_shape=()):
        dtype = jnp.result_type(self.concentration, float)
        shape = tuple(sample_shape) + self.batch_shape
        return jax.random.dirichlet(key, self.concentration, shape, dtype=dtype)

    def log_prob(self, value):
        a = self.concentration
        norm = jnp.sum(gammaln(a), axis=-1) - gammaln(jnp.sum(a, axis=-1))
        return jnp.sum(xlogy(a - 1.0, value), axis=-1) - norm

    @property
    def mean(self):
        return self.concentration / jnp.sum(self.concentration, axis=-1, keepdims=True)


class MultivariateNormal(Distribution):
    """MVN parameterized by a Cholesky factor (``scale_tril``) or a dense
    covariance (Cholesky taken internally).  This is the marginal-likelihood
    workhorse for SKIM's GP-style kernel formulation."""

    support = constraints.real
    event_dim = 1

    def __init__(self, loc=0.0, covariance_matrix=None, scale_tril=None):
        if (covariance_matrix is None) == (scale_tril is None):
            raise ValueError("MVN: provide exactly one of covariance_matrix / scale_tril")
        if scale_tril is None:
            # pure-JAX Cholesky: LAPACK custom-calls cannot be AOT-compiled
            # by the Rust-side XLA (see minippl/linalg.py)
            from . import linalg

            scale_tril = linalg.cholesky(covariance_matrix)
        self.scale_tril = jnp.asarray(scale_tril)
        dim = self.scale_tril.shape[-1]
        self.loc = jnp.broadcast_to(jnp.asarray(loc), jnp.shape(self.scale_tril)[:-2] + (dim,))
        self.event_shape = (dim,)
        super().__init__(jnp.shape(self.scale_tril)[:-2])

    def sample(self, key, sample_shape=()):
        dtype = jnp.result_type(self.loc, float)
        eps = jax.random.normal(key, self.shape(sample_shape), dtype=dtype)
        return self.loc + jnp.einsum("...ij,...j->...i", self.scale_tril, eps)

    def log_prob(self, value):
        from . import linalg

        return linalg.mvn_logpdf(value, self.loc, self.scale_tril)

    @property
    def mean(self):
        return self.loc

    @property
    def covariance_matrix(self):
        return jnp.einsum("...ij,...kj->...ik", self.scale_tril, self.scale_tril)
