"""Sparse Kernel Interaction Model (Fig 2b benchmark, E3).

The "kernel interaction trick" of Agrawal et al. (2019), as benchmarked
in the paper: Bayesian sparse regression with pairwise interactions,
marginalized through a GP-style kernel so that the per-datapoint latent
weights never appear.  The sparsity-inducing prior puts a HalfCauchy
local scale lambda_i on each of the p input dimensions — latent
dimension grows with p, which is exactly Fig 2b's x-axis.

Hyperpriors follow the NumPyro reference implementation
(``sparse_regression.py`` on the benchmarks branch):

    sigma  ~ HalfNormal(alpha3)
    eta1   ~ HalfCauchy(phi),   phi = sigma * S / ((P - S) sqrt(N))
    msq    ~ InverseGamma(alpha1, beta1)
    xisq   ~ InverseGamma(alpha2, beta2)
    lambda ~ HalfCauchy(1)^P
    eta2   = eta1^2 sqrt(xisq) / msq
    kappa  = sqrt(msq) lambda / sqrt(msq + (eta1 lambda)^2)
    Y      ~ MVN(0, K(kappa X) + (sigma^2 + jitter) I)

The N x N kernel matrix is the L1 Pallas kernel
(:mod:`compile.kernels.skim_kernel`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .. import minippl as mp
from ..kernels import ref
from ..kernels.skim_kernel import DEFAULT_BLOCK, skim_kernel_matrix
from ..minippl import distributions as dist


class SkimHypers(NamedTuple):
    expected_sparsity: float = 3.0
    alpha1: float = 3.0
    beta1: float = 1.0
    alpha2: float = 3.0
    beta2: float = 1.0
    alpha3: float = 1.0
    c: float = 1.0
    jitter: float = 1e-4


def skim_model(x, y, hypers: SkimHypers = SkimHypers(), use_kernel: bool = True):
    n, p = x.shape
    s = hypers.expected_sparsity

    sigma = mp.sample("sigma", dist.HalfNormal(hypers.alpha3))
    phi = sigma * (s / jnp.sqrt(n)) / (p - s)
    eta1 = mp.sample("eta1", dist.HalfCauchy(phi))
    msq = mp.sample("msq", dist.InverseGamma(hypers.alpha1, hypers.beta1))
    xisq = mp.sample("xisq", dist.InverseGamma(hypers.alpha2, hypers.beta2))
    lam = mp.sample("lambda", dist.HalfCauchy(jnp.ones(p)))

    eta2 = jnp.square(eta1) * jnp.sqrt(xisq) / msq
    kappa = jnp.sqrt(msq) * lam / jnp.sqrt(msq + jnp.square(eta1 * lam))

    k_x = kappa * x
    kern = skim_kernel_matrix if use_kernel else ref.skim_kernel_matrix
    k = kern(
        k_x,
        jnp.square(eta1).astype(x.dtype),
        jnp.square(eta2).astype(x.dtype),
        jnp.asarray(hypers.c**2, x.dtype),
    )
    k = k + (jnp.square(sigma) + hypers.jitter) * jnp.eye(n, dtype=x.dtype)
    return mp.sample("y", dist.MultivariateNormal(0.0, covariance_matrix=k), obs=y)


def make_skim_data(rng_key, n: int = 200, p: int = 100, num_pairs: int = 3, dtype=jnp.float32):
    """The paper's Appendix C synthetic SKIM data: N=200 points, 3 random
    pairwise interactions among the p covariates (plus matching main
    effects and observation noise)."""
    kx, kp, kc, ke = jax.random.split(rng_key, 4)
    x = jax.random.normal(kx, (n, p), dtype)
    idx = jax.random.choice(kp, p, (num_pairs, 2), replace=False)
    coefs = 1.0 + jnp.abs(jax.random.normal(kc, (num_pairs,), dtype))
    y = jnp.zeros((n,), dtype)
    for q in range(num_pairs):
        i, j = idx[q, 0], idx[q, 1]
        y = y + coefs[q] * x[:, i] * x[:, j] + 0.5 * (x[:, i] + x[:, j])
    y = y + 0.3 * jax.random.normal(ke, (n,), dtype)
    return x, y, idx, coefs
