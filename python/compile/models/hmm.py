"""Semi-supervised Hidden Markov Model (Table 2a's HMM benchmark, E1).

Follows Stan User's Guide §2.6 (the reference the paper cites): K=3
latent states, V=10 output categories, T=600 observations with the first
100 latent states supervised.  Dirichlet(1) priors on the rows of the
transition matrix theta (K x K) and the emission matrix phi (K x V).

Density =  prod Dir(theta_k) * prod Dir(phi_k)
         * prod_{t<T_sup} theta[z_{t-1}, z_t] * phi[z_t, y_t]   (supervised)
         * p(y_{T_sup:} | z_{T_sup-1})                          (forward alg.)

The marginalized tail runs through the L1 Pallas forward-algorithm
kernel and enters the density via the ``factor`` primitive.  The
unconstrained latent space is (K*(K-1) + K*(V-1)) = 33-dimensional via
stick-breaking — small data, loop-heavy gradients: exactly the regime
where the paper reports the 340x win over Pyro.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from .. import minippl as mp
from ..kernels.hmm_forward import hmm_forward
from ..kernels import ref
from ..minippl import distributions as dist

NUM_STATES = 3
NUM_CATEGORIES = 10
SEQ_LEN = 600
NUM_SUPERVISED = 100


class HmmData(NamedTuple):
    obs: jax.Array  # (T,) int32 in [0, V)
    sup_states: jax.Array  # (T_sup,) int32 in [0, K)


def hmm_model(data: HmmData, num_states: int = NUM_STATES, num_categories: int = NUM_CATEGORIES, use_kernel: bool = True):
    """Semi-supervised HMM in the minippl modeling language."""
    k, v = num_states, num_categories
    theta = mp.sample("theta", dist.Dirichlet(jnp.ones((k, k))))  # transitions
    phi = mp.sample("phi", dist.Dirichlet(jnp.ones((k, v))))  # emissions

    sup = data.sup_states
    t_sup = sup.shape[0]
    # supervised transitions z_{t-1} -> z_t and emissions y_t | z_t
    mp.sample("z_sup", dist.Categorical(probs=theta[sup[:-1]]), obs=sup[1:])
    mp.sample("y_sup", dist.Categorical(probs=phi[sup]), obs=data.obs[:t_sup])

    # unsupervised tail: marginalize latent states with the forward
    # algorithm, seeded from the last supervised state
    log_a = jnp.log(theta)
    log_b = jnp.log(phi)
    unsup = data.obs[t_sup:]
    alpha0 = log_a[sup[-1]] + log_b[:, unsup[0]]
    fwd = hmm_forward if use_kernel else ref.hmm_forward
    alpha_t = fwd(log_a, log_b, unsup[1:], alpha0)
    mp.factor("y_unsup", logsumexp(alpha_t))
    return theta, phi


def make_hmm_data(
    rng_key,
    seq_len: int = SEQ_LEN,
    num_supervised: int = NUM_SUPERVISED,
    num_states: int = NUM_STATES,
    num_categories: int = NUM_CATEGORIES,
) -> HmmData:
    """Sample a synthetic dataset from fixed, well-conditioned transition
    and emission matrices (the paper samples 600 points the same way)."""
    k_t, k_e, k_z, k_y = jax.random.split(rng_key, 4)
    # sticky transitions + informative emissions so the chain is learnable
    theta = jax.random.dirichlet(k_t, jnp.ones(num_states) + 4.0 * jnp.eye(num_states))
    base = jnp.ones(num_categories)
    bias = 6.0 * jax.nn.one_hot(
        jnp.arange(num_states) * (num_categories // num_states), num_categories
    )
    phi = jax.random.dirichlet(k_e, base + bias)

    def step(carry, key):
        z = carry
        kz, ky = jax.random.split(key)
        z_next = jax.random.categorical(kz, jnp.log(theta[z]))
        y = jax.random.categorical(ky, jnp.log(phi[z_next]))
        return z_next, (z_next, y)

    keys = jax.random.split(k_z, seq_len)
    _, (zs, ys) = jax.lax.scan(step, jnp.asarray(0), keys)
    return HmmData(obs=ys.astype(jnp.int32), sup_states=zs[:num_supervised].astype(jnp.int32))
