"""Logistic regression (the paper's Fig 1a and the COVTYPE benchmark E2).

Two variants of the same model:

* :func:`logistic_regression` — the paper's Fig 1a verbatim (pure
  minippl + jnp); used for the handler/vmap demos (E5) and as oracle.
* :func:`logistic_regression_fused` — identical density, but the
  Bernoulli likelihood is evaluated through the fused Pallas kernel
  (:mod:`compile.kernels.logistic_loglik`), which is what the compiled
  NUTS step runs in its leapfrog hot loop.

The paper's dataset is Forest CoverType (581,012 x 54, binarized).  We
substitute a synthetic design matrix of the same shape and statistics
(standardized features, logit-linear labels) — see DESIGN.md §5: the
benchmark measures time per leapfrog, which depends on shape/dtype, not
on the actual covariate values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import minippl as mp
from ..kernels.logistic_loglik import DEFAULT_BLOCK_N, logistic_loglik
from ..minippl import constraints, distributions as dist

COVTYPE_N = 581_012
COVTYPE_D = 54


class FusedBernoulliLogits(dist.Distribution):
    """Bernoulli(logits = x @ w + b) over all N rows as one event, with
    ``log_prob`` routed through the fused Pallas kernel."""

    support = constraints.boolean

    def __init__(self, x, w, b, block_n: int = DEFAULT_BLOCK_N):
        self.x, self.w, self.b = x, w, b
        self.block_n = block_n
        self.event_shape = (x.shape[0],)
        super().__init__(())

    def sample(self, key, sample_shape=()):
        logits = self.x @ self.w + self.b
        u = jax.random.uniform(key, tuple(sample_shape) + logits.shape)
        return (u < jax.nn.sigmoid(logits)).astype(jnp.int32)

    def log_prob(self, value):
        return logistic_loglik(
            self.x, self.w, self.b, value.astype(self.x.dtype), self.block_n
        )


def logistic_regression(x, y=None):
    """The paper's Fig 1a model, verbatim."""
    ndims = jnp.shape(x)[-1]
    m = mp.sample("m", dist.Normal(0.0, jnp.ones(ndims)))
    b = mp.sample("b", dist.Normal(0.0, 1.0))
    return mp.sample("y", dist.Bernoulli(logits=x @ m + b), obs=y)


def logistic_regression_fused(x, y=None, block_n: int = DEFAULT_BLOCK_N):
    """Same density; likelihood through the L1 Pallas kernel."""
    ndims = jnp.shape(x)[-1]
    m = mp.sample("m", dist.Normal(0.0, jnp.ones(ndims)))
    b = mp.sample("b", dist.Normal(0.0, 1.0))
    return mp.sample("y", FusedBernoulliLogits(x, m, b, block_n), obs=y)


def make_covtype_like(rng_key, n: int = 50_000, d: int = COVTYPE_D, dtype=jnp.float32):
    """Synthetic CovType substitute: standardized features, labels from a
    sparse-ish logit-linear ground truth (class imbalance ~ the merged
    binary CovType task)."""
    kx, kw, ky = jax.random.split(rng_key, 3)
    x = jax.random.normal(kx, (n, d), dtype)
    w_true = jax.random.normal(kw, (d,), dtype) * (
        jax.random.uniform(jax.random.fold_in(kw, 1), (d,)) < 0.3
    )
    logits = x @ w_true - 0.5
    y = (jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(logits)).astype(jnp.int32)
    return x, y, w_true
