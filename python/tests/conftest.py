import os
import sys

# tests run from python/ (see Makefile); make `compile` importable when
# invoked from the repo root too.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
