"""Pure-JAX linalg (custom-call-free Cholesky path) vs the LAPACK-backed
implementations, including gradients — this is what keeps the SKIM
artifacts compilable by the Rust-side XLA."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.minippl import linalg

SETTINGS = dict(deadline=None, max_examples=15)


def random_spd(key, n, jitter=None):
    b = jax.random.normal(key, (n, n))
    return b @ b.T + (jitter if jitter is not None else n) * jnp.eye(n)


@settings(**SETTINGS)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_cholesky_matches_lapack(n, seed):
    a = random_spd(jax.random.PRNGKey(seed), n)
    np.testing.assert_allclose(
        linalg.cholesky(a), jnp.linalg.cholesky(a), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_solve_lower_matches_lapack(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    l = jnp.linalg.cholesky(random_spd(k1, n))
    b = jax.random.normal(k2, (n,))
    got = linalg.solve_lower(l, b)
    want = jax.scipy.linalg.solve_triangular(l, b, lower=True)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_mvn_logpdf_matches_scipy():
    import scipy.stats as ss

    key = jax.random.PRNGKey(0)
    n = 12
    cov = np.asarray(random_spd(key, n, jitter=2.0), np.float64)
    y = np.linspace(-1, 1, n)
    got = float(
        linalg.mvn_logpdf(
            jnp.asarray(y, jnp.float32),
            jnp.zeros(n, jnp.float32),
            linalg.cholesky(jnp.asarray(cov, jnp.float32)),
        )
    )
    want = ss.multivariate_normal(np.zeros(n), cov).logpdf(y)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_cholesky_gradient_matches_lapack_gradient():
    key = jax.random.PRNGKey(3)
    n = 8
    a = random_spd(key, n)
    y = jax.random.normal(jax.random.PRNGKey(4), (n,))
    f_ours = lambda a: linalg.mvn_logpdf(y, 0.0, linalg.cholesky(a))

    def f_lapack(a):
        l = jnp.linalg.cholesky(a)
        alpha = jax.scipy.linalg.solve_triangular(l, y, lower=True)
        return (
            -0.5 * jnp.sum(alpha**2)
            - jnp.sum(jnp.log(jnp.diag(l)))
            - 0.5 * n * jnp.log(2 * jnp.pi)
        )

    g1 = jax.grad(f_ours)(a)
    g2 = jax.grad(f_lapack)(a)
    # our cholesky reads only the lower triangle, so its cotangent lands
    # there; the *symmetrized* gradients (the well-defined object for a
    # function of a symmetric matrix) must agree.
    sym = lambda g: 0.5 * (g + g.T)
    np.testing.assert_allclose(sym(g1), sym(g2), rtol=1e-3, atol=1e-4)


def test_no_custom_calls_in_lowered_hlo():
    # the property the Rust consumer depends on
    n = 6
    a = random_spd(jax.random.PRNGKey(0), n)
    y = jnp.arange(n, dtype=jnp.float32)
    f = lambda a: linalg.mvn_logpdf(y, 0.0, linalg.cholesky(a))
    hlo = jax.jit(f).lower(a).compiler_ir("hlo").as_hlo_text()
    assert "custom-call" not in hlo, "LAPACK custom call leaked into the lowering"
