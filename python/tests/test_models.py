"""The three benchmark models: potential finiteness + gradients, fused
(Pallas) vs reference (pure-jnp) density agreement, and workload
generator sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.minippl as mp
from compile.models.hmm import HmmData, hmm_model, make_hmm_data
from compile.models.logistic import (
    logistic_regression,
    logistic_regression_fused,
    make_covtype_like,
)
from compile.models.skim import SkimHypers, make_skim_data, skim_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def hmm_data():
    return make_hmm_data(KEY, seq_len=120, num_supervised=30)


@pytest.fixture(scope="module")
def covtype_data():
    return make_covtype_like(KEY, n=500, d=10)


@pytest.fixture(scope="module")
def skim_data():
    return make_skim_data(KEY, n=50, p=12)


def test_hmm_dims_and_gradient(hmm_data):
    pf, z0, _, _ = mp.initialize_model(lambda: hmm_model(hmm_data), KEY)
    assert z0.shape == (3 * 9 + 3 * 2,)
    u = pf(z0)
    g = jax.grad(pf)(z0)
    assert jnp.isfinite(u)
    assert bool(jnp.isfinite(g).all())


def test_hmm_kernel_and_reference_densities_agree(hmm_data):
    pf_k, z0, _, _ = mp.initialize_model(lambda: hmm_model(hmm_data, use_kernel=True), KEY)
    pf_r, _, _, _ = mp.initialize_model(lambda: hmm_model(hmm_data, use_kernel=False), KEY)
    for seed in range(3):
        z = jax.random.normal(jax.random.PRNGKey(seed), z0.shape)
        np.testing.assert_allclose(pf_k(z), pf_r(z), rtol=1e-5)
        np.testing.assert_allclose(jax.grad(pf_k)(z), jax.grad(pf_r)(z), rtol=1e-3, atol=1e-4)


def test_logistic_fused_matches_reference(covtype_data):
    x, y, _ = covtype_data
    pf_f, z0, _, _ = mp.initialize_model(lambda: logistic_regression_fused(x, y), KEY)
    pf_r, _, _, _ = mp.initialize_model(lambda: logistic_regression(x, y), KEY)
    for seed in range(3):
        z = jax.random.normal(jax.random.PRNGKey(seed), z0.shape) * 0.5
        np.testing.assert_allclose(pf_f(z), pf_r(z), rtol=1e-4)
        np.testing.assert_allclose(
            jax.grad(pf_f)(z), jax.grad(pf_r)(z), rtol=1e-3, atol=1e-3
        )


def test_skim_kernel_and_reference_densities_agree(skim_data):
    x, y, _, _ = skim_data
    pf_k, z0, _, _ = mp.initialize_model(lambda: skim_model(x, y, use_kernel=True), KEY)
    pf_r, _, _, _ = mp.initialize_model(lambda: skim_model(x, y, use_kernel=False), KEY)
    for seed in range(3):
        z = jax.random.normal(jax.random.PRNGKey(seed), z0.shape) * 0.3
        np.testing.assert_allclose(pf_k(z), pf_r(z), rtol=5e-4)
        np.testing.assert_allclose(
            jax.grad(pf_k)(z), jax.grad(pf_r)(z), rtol=5e-3, atol=5e-3
        )


def test_skim_latent_dim_grows_with_p():
    for p in [7, 17]:
        x, y, _, _ = make_skim_data(KEY, n=30, p=p)
        _, z0, _, _ = mp.initialize_model(lambda: skim_model(x, y), KEY)
        assert z0.shape == (p + 4,)


def test_hmm_generator_shapes(hmm_data):
    assert hmm_data.obs.shape == (120,)
    assert hmm_data.sup_states.shape == (30,)
    assert int(hmm_data.obs.max()) < 10
    assert int(hmm_data.sup_states.max()) < 3


def test_covtype_generator_classes_balanced_ish(covtype_data):
    _, y, _ = covtype_data
    rate = float(jnp.mean(y))
    assert 0.1 < rate < 0.9


def test_potentials_jit_and_vmap(covtype_data):
    x, y, _ = covtype_data
    pf, z0, _, _ = mp.initialize_model(lambda: logistic_regression_fused(x, y), KEY)
    zs = jax.random.normal(KEY, (4,) + z0.shape) * 0.1
    us = jax.jit(jax.vmap(pf))(zs)
    assert us.shape == (4,)
    assert bool(jnp.isfinite(us).all())


def test_param_layout_is_sorted_and_contiguous(covtype_data):
    from compile.aot import param_layout

    x, y, _ = covtype_data
    layout = param_layout(lambda: logistic_regression_fused(x, y))
    sites = [e["site"] for e in layout]
    assert sites == sorted(sites) == ["b", "m"]
    offset = 0
    for e in layout:
        assert e["offset"] == offset
        offset += e["size"]
