"""§2 / Table 1 semantics: every effect handler's contract, plus
composition with jit/vmap/grad (the paper's central claim)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.minippl as mp
from compile.minippl import distributions as dist


def model(x, y=None):
    m = mp.sample("m", dist.Normal(0.0, jnp.ones(x.shape[-1])))
    b = mp.sample("b", dist.Normal(0.0, 1.0))
    return mp.sample("y", dist.Bernoulli(logits=x @ m + b), obs=y)


@pytest.fixture
def x():
    return jax.random.normal(jax.random.PRNGKey(0), (20, 3))


def test_seed_provides_keys_and_is_deterministic(x):
    y1 = mp.seed(model, rng_key=jax.random.PRNGKey(1))(x)
    y2 = mp.seed(model, rng_key=jax.random.PRNGKey(1))(x)
    y3 = mp.seed(model, rng_key=jax.random.PRNGKey(2))(x)
    np.testing.assert_array_equal(y1, y2)
    assert not np.array_equal(y1, y3)


def test_unseeded_sample_raises(x):
    with pytest.raises(ValueError, match="seed"):
        mp.trace(model).get_trace(x)


def test_trace_records_all_sites(x):
    tr = mp.trace(mp.seed(model, rng_key=jax.random.PRNGKey(0))).get_trace(x)
    assert list(tr.keys()) == ["m", "b", "y"]
    assert not tr["m"]["is_observed"]
    assert not tr["y"]["is_observed"]  # no obs passed
    tr2 = mp.trace(mp.seed(model, rng_key=jax.random.PRNGKey(0))).get_trace(
        x, y=jnp.zeros(20, dtype=jnp.int32)
    )
    assert tr2["y"]["is_observed"]


def test_trace_rejects_duplicate_sites():
    def bad():
        mp.sample("a", dist.Normal(0.0, 1.0))
        mp.sample("a", dist.Normal(0.0, 1.0))

    with pytest.raises(ValueError, match="duplicate"):
        mp.trace(mp.seed(bad, rng_key=jax.random.PRNGKey(0))).get_trace()


def test_condition_fixes_and_observes(x):
    data = {"m": jnp.ones(3), "b": jnp.asarray(0.5)}
    tr = mp.trace(mp.seed(mp.condition(model, data=data), rng_key=jax.random.PRNGKey(0))).get_trace(x)
    np.testing.assert_array_equal(tr["m"]["value"], data["m"])
    assert tr["m"]["is_observed"]
    assert tr["b"]["is_observed"]


def test_condition_on_observed_site_raises(x):
    y = jnp.zeros(20, dtype=jnp.int32)
    cond = mp.condition(model, data={"y": y})
    with pytest.raises(ValueError, match="observed"):
        mp.seed(cond, rng_key=jax.random.PRNGKey(0))(x, y=y)


def test_substitute_fixes_without_observing(x):
    tr = mp.trace(
        mp.seed(mp.substitute(model, data={"b": jnp.asarray(2.0)}), rng_key=jax.random.PRNGKey(0))
    ).get_trace(x)
    assert float(tr["b"]["value"]) == 2.0
    assert not tr["b"]["is_observed"]


def test_replay_reuses_trace(x):
    key = jax.random.PRNGKey(3)
    tr = mp.trace(mp.seed(model, rng_key=key)).get_trace(x)
    tr2 = mp.trace(
        mp.seed(mp.replay(model, guide_trace=tr), rng_key=jax.random.PRNGKey(99))
    ).get_trace(x)
    np.testing.assert_array_equal(tr["m"]["value"], tr2["m"]["value"])
    np.testing.assert_array_equal(tr["b"]["value"], tr2["b"]["value"])


def test_block_hides_sites(x):
    def fn():
        mp.sample("hidden", dist.Normal(0.0, 1.0))
        return mp.sample("visible", dist.Normal(0.0, 1.0))

    # seed must sit *inside* block so the hidden site still gets a key:
    # block hides sites from handlers OUTSIDE it (here: trace).
    seeded = mp.seed(fn, rng_key=jax.random.PRNGKey(0))
    blocked = mp.block(seeded, hide_fn=lambda msg: msg["name"] == "hidden")
    tr = mp.trace(blocked).get_trace()
    assert "hidden" not in tr and "visible" in tr


def test_mask_zeroes_log_prob():
    def fn():
        with mp.mask(mask=jnp.asarray(False)):
            mp.sample("a", dist.Normal(0.0, 1.0), obs=jnp.asarray(3.0))
        mp.sample("b", dist.Normal(0.0, 1.0), obs=jnp.asarray(0.0))

    logp, _ = mp.log_density(fn, (), {}, {})
    expect = dist.Normal(0.0, 1.0).log_prob(0.0)
    np.testing.assert_allclose(logp, expect, rtol=1e-6)


def test_scale_multiplies_log_prob():
    def fn():
        with mp.handlers.scale(scale_factor=2.5):
            mp.sample("a", dist.Normal(0.0, 1.0), obs=jnp.asarray(1.0))

    logp, _ = mp.log_density(fn, (), {}, {})
    expect = 2.5 * dist.Normal(0.0, 1.0).log_prob(1.0)
    np.testing.assert_allclose(logp, expect, rtol=1e-6)


def test_factor_adds_arbitrary_term():
    def fn():
        mp.factor("f", jnp.asarray(-7.25))

    logp, _ = mp.log_density(fn, (), {}, {})
    np.testing.assert_allclose(logp, -7.25)


def test_nested_handlers_compose(x):
    # condition inside substitute: substitute wins where it applies
    inner = mp.condition(model, data={"b": jnp.asarray(1.0)})
    outer = mp.substitute(inner, data={"m": jnp.zeros(3)})
    tr = mp.trace(mp.seed(outer, rng_key=jax.random.PRNGKey(0))).get_trace(x)
    np.testing.assert_array_equal(tr["m"]["value"], jnp.zeros(3))
    assert float(tr["b"]["value"]) == 1.0


# ---- composition with JAX transformations (§3.2) ----


def test_handlers_compose_with_vmap(x):
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    ys = jax.vmap(lambda k: mp.seed(model, rng_key=k)(x))(keys)
    assert ys.shape == (8, 20)
    # different keys -> different draws somewhere
    assert np.unique(np.asarray(ys), axis=0).shape[0] > 1


def test_handlers_compose_with_jit_and_grad(x):
    y = mp.seed(model, rng_key=jax.random.PRNGKey(5))(x)

    def loss(params):
        logp, _ = mp.log_density(model, (x,), {"y": y}, params)
        return -logp

    params = {"m": jnp.zeros(3), "b": jnp.asarray(0.0)}
    g = jax.jit(jax.grad(loss))(params)
    assert g["m"].shape == (3,)
    assert jnp.isfinite(g["b"])


def test_vmap_log_density_over_param_batch(x):
    y = mp.seed(model, rng_key=jax.random.PRNGKey(5))(x)
    ms = jax.random.normal(jax.random.PRNGKey(1), (6, 3))
    bs = jnp.zeros(6)
    lls = jax.vmap(lambda m, b: mp.log_density(model, (x,), {"y": y}, {"m": m, "b": b})[0])(ms, bs)
    assert lls.shape == (6,)
    assert bool(jnp.all(jnp.isfinite(lls)))
