"""AOT lowering contract: HLO text is parseable and custom-call-free,
manifest entries are complete and consistent, f64 path toggles dtypes.

These are fast (tiny shapes) — the full-size artifacts are exercised by
`fugue artifacts-check` and the Rust integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

import compile.minippl as mp
from compile.aot import Lowerer, lower_model_bundle, param_layout, to_hlo_text, write_manifest
from compile.models.logistic import logistic_regression_fused, make_covtype_like

KEY = jax.random.PRNGKey(0)


@pytest.fixture()
def tiny_bundle(tmp_path):
    x, y, _ = make_covtype_like(KEY, n=64, d=4)
    lw = Lowerer(str(tmp_path))
    lower_model_bundle(
        lw,
        "tiny",
        lambda xx, yy: logistic_regression_fused(xx, yy, block_n=32),
        (x, y),
        ["x", "y"],
        {"n": 64, "d": 4},
        max_tree_depth=5,
        vmap_chains=2,
    )
    write_manifest(str(tmp_path), lw.entries)
    return tmp_path


def test_bundle_files_and_manifest(tiny_bundle):
    files = sorted(os.listdir(tiny_bundle))
    assert "manifest.json" in files
    assert any("tiny_nuts_step_f32" in f for f in files)
    assert any("tiny_potential_and_grad_f32" in f for f in files)
    assert any("tiny_nuts_step_vmap2_f32" in f for f in files)
    with open(tiny_bundle / "manifest.json") as f:
        manifest = json.load(f)
    entries = {e["name"]: e for e in manifest["entries"]}
    step = entries["tiny_nuts_step_f32"]
    assert step["dim"] == 5
    assert [i["name"] for i in step["inputs"]] == [
        "key",
        "z",
        "step_size",
        "inv_mass_diag",
        "x",
        "y",
    ]
    assert [o["name"] for o in step["outputs"]] == [
        "z_new",
        "accept_prob",
        "num_leapfrog",
        "potential",
        "diverging",
        "depth",
    ]
    assert step["max_tree_depth"] == 5
    layout = step["param_layout"]
    assert [e["site"] for e in layout] == ["b", "m"]
    assert layout[1]["offset"] == 1 and layout[1]["size"] == 4


def test_hlo_text_is_wellformed_and_custom_call_free(tiny_bundle):
    for fname in os.listdir(tiny_bundle):
        if not fname.endswith(".hlo.txt"):
            continue
        text = (tiny_bundle / fname).read_text()
        assert text.startswith("HloModule"), fname
        assert "custom-call" not in text, f"{fname} contains a custom call"
        assert "ENTRY" in text


def test_manifest_merge_replaces_by_name(tmp_path):
    write_manifest(str(tmp_path), [{"name": "a", "v": 1}])
    write_manifest(str(tmp_path), [{"name": "a", "v": 2}, {"name": "b", "v": 3}])
    with open(tmp_path / "manifest.json") as f:
        entries = {e["name"]: e for e in json.load(f)["entries"]}
    assert entries["a"]["v"] == 2
    assert set(entries) == {"a", "b"}


def test_to_hlo_text_roundtrip_simple():
    f = lambda x: (x @ x.T,)
    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((3, 3), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")


def test_param_layout_spans_are_disjoint_and_ordered():
    x, y, _ = make_covtype_like(KEY, n=32, d=3)
    layout = param_layout(lambda: logistic_regression_fused(x, y))
    end = 0
    for e in layout:
        assert e["offset"] == end
        end = e["offset"] + e["size"]
    assert end == 4
