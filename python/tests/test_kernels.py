"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
with hypothesis sweeping shapes and dtypes (the mandated correctness
signal for the kernel layer)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hmm_forward import hmm_forward
from compile.kernels.logistic_loglik import logistic_loglik
from compile.kernels.skim_kernel import skim_kernel_matrix

SETTINGS = dict(deadline=None, max_examples=12)


def _tol(dtype):
    return dict(rtol=2e-3, atol=2e-3) if dtype == jnp.float32 else dict(rtol=1e-8, atol=1e-8)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3000),
    d=st.integers(1, 64),
    block_n=st.sampled_from([64, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_logistic_loglik_matches_ref(n, d, block_n, seed):
    k = jax.random.PRNGKey(seed)
    kx, kw, kb, ky = jax.random.split(k, 4)
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (d,))
    b = jax.random.normal(kb, ())
    y = (jax.random.uniform(ky, (n,)) < 0.5).astype(jnp.float32)
    got = logistic_loglik(x, w, b, y, block_n)
    want = ref.logistic_loglik(x, w, b, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3 * n)


@settings(**SETTINGS)
@given(
    n=st.integers(2, 800),
    d=st.integers(1, 54),
    seed=st.integers(0, 2**31 - 1),
)
def test_logistic_loglik_gradient_matches_ref(n, d, seed):
    k = jax.random.PRNGKey(seed)
    kx, kw, ky = jax.random.split(k, 3)
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (d,)) * 0.5
    b = jnp.float32(0.2)
    y = (jax.random.uniform(ky, (n,)) < 0.5).astype(jnp.float32)
    gw, gb = jax.grad(lambda w, b: logistic_loglik(x, w, b, y, 256), argnums=(0, 1))(w, b)
    ew, eb = ref.logistic_loglik_grad(x, w, b, y)
    np.testing.assert_allclose(gw, ew, rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(gb, eb, rtol=1e-3, atol=1e-2)


@settings(**SETTINGS)
@given(
    k_states=st.integers(2, 5),
    v_cats=st.integers(2, 12),
    t_len=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_hmm_forward_matches_ref(k_states, v_cats, t_len, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    log_a = jax.nn.log_softmax(jax.random.normal(k1, (k_states, k_states)), axis=1)
    log_b = jax.nn.log_softmax(jax.random.normal(k2, (k_states, v_cats)), axis=1)
    obs = jax.random.randint(k3, (t_len,), 0, v_cats)
    alpha0 = jnp.full((k_states,), -jnp.log(k_states))
    got = hmm_forward(log_a, log_b, obs, alpha0)
    want = ref.hmm_forward(log_a, log_b, obs, alpha0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_hmm_forward_gradient_matches_ref():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    K, V, T = 3, 10, 80
    log_a = jax.nn.log_softmax(jax.random.normal(k1, (K, K)), axis=1)
    log_b = jax.nn.log_softmax(jax.random.normal(k2, (K, V)), axis=1)
    obs = jax.random.randint(k3, (T,), 0, V)
    alpha0 = jnp.zeros((K,))
    f = lambda fwd, a, b: jax.scipy.special.logsumexp(fwd(a, b, obs, alpha0))
    g1 = jax.grad(lambda a: f(hmm_forward, a, log_b))(log_a)
    g2 = jax.grad(lambda a: f(ref.hmm_forward, a, log_b))(log_a)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    n=st.integers(2, 300),
    p=st.integers(1, 64),
    block=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_skim_kernel_matches_ref(n, p, block, seed):
    key = jax.random.PRNGKey(seed)
    kx = jax.random.normal(key, (n, p))
    args = (jnp.float32(1.3), jnp.float32(0.4), jnp.float32(1.0))
    got = skim_kernel_matrix(kx, *args, block)
    want = ref.skim_kernel_matrix(kx, *args)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_skim_kernel_gradients_match_ref():
    key = jax.random.PRNGKey(7)
    kx = jax.random.normal(key, (50, 9))
    loss = lambda kern, kx, e1, e2: jnp.sum(kern(kx, e1, e2, jnp.float32(1.0)))
    g1 = jax.grad(lambda kx, e1, e2: loss(lambda *a: skim_kernel_matrix(*a, 32), kx, e1, e2), argnums=(0, 1, 2))(
        kx, jnp.float32(1.3), jnp.float32(0.4)
    )
    g2 = jax.grad(lambda kx, e1, e2: loss(ref.skim_kernel_matrix, kx, e1, e2), argnums=(0, 1, 2))(
        kx, jnp.float32(1.3), jnp.float32(0.4)
    )
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_kernels_work_under_jit():
    x = jax.random.normal(jax.random.PRNGKey(0), (500, 8))
    w = jnp.ones(8) * 0.1
    y = jnp.ones(500)
    f = jax.jit(lambda w: logistic_loglik(x, w, jnp.float32(0.0), y, 256))
    np.testing.assert_allclose(f(w), ref.logistic_loglik(x, w, 0.0, y), rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_logistic_kernel_padding_edge(dtype):
    # N exactly one below/above a block boundary
    for n in [1023, 1024, 1025]:
        x = jax.random.normal(jax.random.PRNGKey(n), (n, 4), dtype)
        w = jnp.ones(4, dtype)
        y = jnp.zeros(n, dtype)
        got = logistic_loglik(x, w, dtype(0.0), y, 1024)
        want = ref.logistic_loglik(x, w, 0.0, y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)
