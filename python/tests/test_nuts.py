"""Iterative NUTS correctness (the paper's Appendix A):

1. index-level equivalence of Algorithm 1 and Algorithm 2 (the U-turn
   check sets coincide and the S-array always holds C(n)) — the oracle
   in compile.infer.oracle raises if storage ever misses a candidate;
2. bit-twiddling helpers against Python integers;
3. statistical correctness: the end-to-end jitted step samples known
   Gaussians (mean/cov recovery, acceptance near target);
4. structural invariants: leapfrog counts bounded by 2^max_depth,
   divergence flag on absurd step sizes, determinism in the PRNGKey.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.infer import oracle
from compile.infer.hmc_util import bit_count, candidate_range, trailing_ones
from compile.infer.mcmc import run_nuts
from compile.infer.nuts import build_nuts_step


@pytest.mark.parametrize("depth", range(1, 11))
def test_recursive_and_iterative_checks_coincide(depth):
    rec = set(oracle.recursive_checks(0, depth))
    it = set(oracle.iterative_checks(depth))  # asserts S-array correctness
    assert rec == it
    assert len(rec) == (1 << depth) - 1


@settings(deadline=None, max_examples=60)
@given(n=st.integers(0, 2**20))
def test_bit_helpers_match_python(n):
    assert int(bit_count(jnp.uint32(n))) == bin(n).count("1")
    assert int(trailing_ones(jnp.uint32(n))) == oracle.trailing_ones(n)
    if n % 2 == 1:
        i_min, i_max = candidate_range(jnp.uint32(n))
        assert int(i_max) == oracle.bit_count(n - 1)
        assert int(i_max) - int(i_min) + 1 == oracle.trailing_ones(n)


def test_candidate_set_paper_example():
    # n = 11 = (1011)_2 -> C(11) = {10, 8}
    assert oracle.candidate_set(11) == [10, 8]


def _gauss_potential(prec):
    return lambda z: 0.5 * z @ prec @ z


def test_nuts_step_deterministic_in_key():
    U = _gauss_potential(jnp.eye(3))
    step = jax.jit(build_nuts_step(jax.value_and_grad(U), 8))
    z = jnp.array([0.5, -0.2, 1.0])
    key = jax.random.PRNGKey(3)
    out1 = step(key, z, jnp.asarray(0.5), jnp.ones(3))
    out2 = step(key, z, jnp.asarray(0.5), jnp.ones(3))
    np.testing.assert_array_equal(out1[0], out2[0])
    out3 = step(jax.random.PRNGKey(4), z, jnp.asarray(0.5), jnp.ones(3))
    assert not np.array_equal(out1[0], out3[0])


def test_nuts_step_bounded_by_max_depth():
    U = _gauss_potential(jnp.eye(2))
    max_depth = 6
    step = jax.jit(build_nuts_step(jax.value_and_grad(U), max_depth))
    # microscopic step size -> tree always full
    _, _, n_lf, _, _, depth = step(
        jax.random.PRNGKey(0), jnp.zeros(2), jnp.asarray(1e-5), jnp.ones(2)
    )
    assert int(n_lf) <= 2**max_depth
    assert int(depth) <= max_depth


def test_nuts_step_flags_divergence():
    # steep quadratic + enormous step size = divergence
    U = lambda z: 5000.0 * jnp.sum(z**2)
    step = jax.jit(build_nuts_step(jax.value_and_grad(U), 10))
    _, _, _, _, div, _ = step(
        jax.random.PRNGKey(0), jnp.ones(2) * 3.0, jnp.asarray(10.0), jnp.ones(2)
    )
    assert bool(div)


def test_nuts_recovers_correlated_gaussian():
    cov = jnp.array([[2.0, 0.8], [0.8, 1.0]])
    prec = jnp.linalg.inv(cov)
    out = run_nuts(
        _gauss_potential(prec),
        jnp.zeros(2),
        jax.random.PRNGKey(0),
        num_warmup=300,
        num_samples=700,
    )
    s = out["samples"]
    assert abs(s[:, 0].mean()) < 0.2
    assert abs(s[:, 1].mean()) < 0.15
    emp_cov = np.cov(s.T)
    np.testing.assert_allclose(emp_cov, cov, rtol=0.35, atol=0.1)
    accept = out["accept_prob"][300:].mean()
    assert 0.6 < accept <= 1.0


def test_nuts_adapts_mass_matrix_to_scales():
    # strongly anisotropic target: adaptation must pick up the scales
    var = jnp.array([100.0, 0.01])
    U = lambda z: 0.5 * jnp.sum(z**2 / var)
    out = run_nuts(
        U, jnp.array([1.0, 0.1]), jax.random.PRNGKey(1), num_warmup=500, num_samples=500
    )
    ratio = out["inv_mass"][0] / out["inv_mass"][1]
    assert ratio > 100, f"inv mass ratio {ratio} (want ~1e4)"
    s = out["samples"]
    np.testing.assert_allclose(s[:, 0].var(), 100.0, rtol=0.5)
    np.testing.assert_allclose(s[:, 1].var(), 0.01, rtol=0.5)


def test_backward_subtrees_do_not_terminate_early():
    # Regression: the candidate U-turn check must flip orientation for
    # backward-built subtrees; with the wrong orientation they die after
    # ~1 leapfrog and mean trajectory length collapses.  For a standard
    # 1-d Gaussian at eps = 0.4 the turnaround is ~pi/eps ~ 8 steps, so
    # trajectories must average well above 3 leapfrogs.
    U = _gauss_potential(jnp.eye(1))
    step = jax.jit(build_nuts_step(jax.value_and_grad(U), 10))
    z = jnp.zeros(1)
    key = jax.random.PRNGKey(0)
    total = 0
    for _ in range(150):
        key, sub = jax.random.split(key)
        z, _, n_lf, _, _, _ = step(sub, z, jnp.asarray(0.4), jnp.ones(1))
        total += int(n_lf)
    mean_lf = total / 150
    assert mean_lf > 3.5, f"mean leapfrogs {mean_lf} — backward subtrees dying early?"


def test_fixed_step_size_skips_adaptation():
    U = _gauss_potential(jnp.eye(2))
    out = run_nuts(
        U,
        jnp.zeros(2),
        jax.random.PRNGKey(2),
        num_warmup=50,
        num_samples=50,
        fixed_step_size=0.25,
    )
    assert out["step_size"] == pytest.approx(0.25)
