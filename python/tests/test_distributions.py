"""minippl distribution correctness: densities against scipy, samplers
against their own densities (moment checks), support/constraint
consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.stats as ss

from compile.minippl import constraints, distributions as dist

KEY = jax.random.PRNGKey(0)


DENSITY_CASES = [
    (dist.Normal(0.5, 1.3), ss.norm(0.5, 1.3), [-2.0, 0.0, 0.5, 3.1]),
    (dist.HalfNormal(0.7), ss.halfnorm(scale=0.7), [0.1, 0.5, 2.0]),
    (dist.Cauchy(1.0, 2.0), ss.cauchy(1.0, 2.0), [-5.0, 0.0, 1.0, 4.0]),
    (dist.HalfCauchy(1.5), ss.halfcauchy(scale=1.5), [0.1, 1.0, 10.0]),
    (dist.Exponential(2.0), ss.expon(scale=0.5), [0.1, 1.0, 3.0]),
    (dist.Gamma(3.0, 2.0), ss.gamma(3.0, scale=0.5), [0.2, 1.0, 4.0]),
    (dist.InverseGamma(3.0, 2.0), ss.invgamma(3.0, scale=2.0), [0.2, 1.0, 4.0]),
    (dist.Beta(2.0, 3.0), ss.beta(2.0, 3.0), [0.1, 0.4, 0.9]),
    (dist.LogNormal(0.2, 0.8), ss.lognorm(0.8, scale=np.exp(0.2)), [0.2, 1.0, 5.0]),
    (dist.Uniform(-1.0, 2.0), ss.uniform(-1.0, 3.0), [-0.5, 0.0, 1.9]),
    (dist.StudentT(4.0, 0.5, 1.2), ss.t(4.0, 0.5, 1.2), [-3.0, 0.5, 2.0]),
]


@pytest.mark.parametrize("d,ref,points", DENSITY_CASES, ids=lambda c: type(c).__name__)
def test_log_prob_matches_scipy(d, ref, points):
    for x in points:
        got = float(d.log_prob(jnp.asarray(x)))
        want = ref.logpdf(x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_bernoulli_logits_and_probs_agree():
    logits = jnp.asarray(0.7)
    d1 = dist.Bernoulli(logits=logits)
    d2 = dist.Bernoulli(probs=jax.nn.sigmoid(logits))
    for v in [0, 1]:
        np.testing.assert_allclose(d1.log_prob(v), d2.log_prob(v), rtol=1e-6)
    with pytest.raises(ValueError):
        dist.Bernoulli()
    with pytest.raises(ValueError):
        dist.Bernoulli(probs=0.5, logits=0.0)


def test_categorical_log_prob_normalizes():
    d = dist.Categorical(logits=jnp.asarray([0.1, -0.5, 2.0, 1.0]))
    total = sum(float(jnp.exp(d.log_prob(jnp.asarray(k)))) for k in range(4))
    np.testing.assert_allclose(total, 1.0, rtol=1e-6)


def test_dirichlet_matches_scipy():
    conc = jnp.asarray([2.0, 3.0, 0.5])
    d = dist.Dirichlet(conc)
    x = np.array([0.3, 0.5, 0.2])
    np.testing.assert_allclose(
        float(d.log_prob(jnp.asarray(x))),
        ss.dirichlet(np.asarray(conc)).logpdf(x),
        rtol=1e-5,
    )


def test_mvn_matches_scipy():
    cov = np.array([[2.0, 0.6], [0.6, 1.0]])
    d = dist.MultivariateNormal(jnp.zeros(2), covariance_matrix=jnp.asarray(cov))
    x = np.array([0.7, -1.1])
    np.testing.assert_allclose(
        float(d.log_prob(jnp.asarray(x))),
        ss.multivariate_normal(np.zeros(2), cov).logpdf(x),
        rtol=1e-5,
    )


SAMPLER_CASES = [
    dist.Normal(1.0, 2.0),
    dist.HalfNormal(1.5),
    dist.Exponential(0.7),
    dist.Gamma(4.0, 2.0),
    dist.Beta(2.0, 5.0),
    dist.LogNormal(0.0, 0.5),
    dist.Uniform(-2.0, 1.0),
]


@pytest.mark.parametrize("d", SAMPLER_CASES, ids=lambda d: type(d).__name__)
def test_sampler_moments_match_mean(d):
    xs = d.sample(KEY, (20000,))
    np.testing.assert_allclose(
        float(jnp.mean(xs)), float(d.mean), rtol=0.06, atol=0.02
    )


@pytest.mark.parametrize(
    "d",
    [
        dist.HalfNormal(1.0),
        dist.HalfCauchy(1.0),
        dist.Gamma(2.0, 1.0),
        dist.Beta(2.0, 2.0),
        dist.Dirichlet(jnp.ones(4)),
    ],
    ids=lambda d: type(d).__name__,
)
def test_samples_respect_support(d):
    xs = d.sample(KEY, (500,))
    assert bool(jnp.all(d.support(xs)))


def test_unit_distribution_carries_factor():
    d = dist.Unit(jnp.asarray(-3.25))
    np.testing.assert_allclose(d.log_prob(jnp.zeros(())), -3.25)


def test_batched_normal_shapes():
    d = dist.Normal(jnp.zeros((4, 3)), jnp.ones((4, 3)))
    assert d.batch_shape == (4, 3)
    xs = d.sample(KEY, (7,))
    assert xs.shape == (7, 4, 3)
    assert d.log_prob(xs).shape == (7, 4, 3)


def test_dirichlet_batch_shapes():
    d = dist.Dirichlet(jnp.ones((5, 3)))
    xs = d.sample(KEY)
    assert xs.shape == (5, 3)
    assert d.log_prob(xs).shape == (5,)
