"""Transform correctness: round-trips, Jacobians vs autodiff, and the
biject_to registry, hypothesis-swept."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.minippl import constraints
from compile.minippl.transforms import (
    AffineTransform,
    ComposeTransform,
    ExpTransform,
    OrderedTransform,
    SigmoidTransform,
    StickBreakingTransform,
    biject_to,
)

SETTINGS = dict(deadline=None, max_examples=25)


def autodiff_logdet(t, x):
    """log |det J| via jacfwd (square part for dimension-changing maps)."""
    if t.event_dim_in == 0:
        return jnp.log(jnp.abs(jax.grad(lambda v: t(v))(x)))
    if isinstance(t, StickBreakingTransform):
        J = jax.jacfwd(lambda v: t(v)[:-1])(x)
    else:
        J = jax.jacfwd(t)(x)
    return jnp.linalg.slogdet(J)[1]


@settings(**SETTINGS)
@given(x=st.floats(-5, 5))
def test_exp_transform(x):
    t = ExpTransform()
    x = jnp.asarray(x, jnp.float32)
    y = t(x)
    assert y > 0
    np.testing.assert_allclose(t.inv(y), x, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        t.log_abs_det_jacobian(x, y), autodiff_logdet(t, x), rtol=1e-4, atol=1e-5
    )


@settings(**SETTINGS)
@given(x=st.floats(-4, 4))
def test_sigmoid_transform(x):
    t = SigmoidTransform()
    x = jnp.asarray(x, jnp.float32)
    y = t(x)
    assert 0 < y < 1
    np.testing.assert_allclose(t.inv(y), x, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(
        t.log_abs_det_jacobian(x, y), autodiff_logdet(t, x), rtol=1e-4, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    k=st.integers(2, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_stick_breaking(k, seed):
    t = StickBreakingTransform()
    x = jax.random.normal(jax.random.PRNGKey(seed), (k - 1,)) * 2.0
    y = t(x)
    np.testing.assert_allclose(jnp.sum(y), 1.0, rtol=1e-5)
    assert bool(jnp.all(y > 0))
    np.testing.assert_allclose(t.inv(y), x, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(
        t.log_abs_det_jacobian(x, y), autodiff_logdet(t, x), rtol=1e-3, atol=1e-3
    )
    assert t.inverse_shape((k,)) == (k - 1,)


@settings(**SETTINGS)
@given(k=st.integers(2, 8), seed=st.integers(0, 2**31 - 1))
def test_ordered_transform(k, seed):
    t = OrderedTransform()
    x = jax.random.normal(jax.random.PRNGKey(seed), (k,))
    y = t(x)
    assert bool(jnp.all(jnp.diff(y) > 0))
    np.testing.assert_allclose(t.inv(y), x, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        t.log_abs_det_jacobian(x, y), autodiff_logdet(t, x), rtol=1e-4, atol=1e-4
    )


def test_compose_and_affine():
    t = ComposeTransform([SigmoidTransform(), AffineTransform(-1.0, 3.0)])
    x = jnp.asarray(0.3)
    y = t(x)
    assert -1 < y < 2
    np.testing.assert_allclose(t.inv(y), x, rtol=1e-5)
    np.testing.assert_allclose(
        t.log_abs_det_jacobian(x, y), autodiff_logdet(t, x), rtol=1e-5
    )


def test_biject_to_registry():
    assert isinstance(biject_to(constraints.positive), ExpTransform)
    assert isinstance(biject_to(constraints.unit_interval), SigmoidTransform)
    assert isinstance(biject_to(constraints.simplex), StickBreakingTransform)
    t = biject_to(constraints.interval(2.0, 5.0))
    y = t(jnp.asarray(0.0))
    assert 2.0 < float(y) < 5.0


def test_stick_breaking_zero_is_uniform():
    t = StickBreakingTransform()
    y = t(jnp.zeros(4))
    np.testing.assert_allclose(y, jnp.full(5, 0.2), rtol=1e-6)
