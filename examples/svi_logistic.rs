//! Appendix D: stochastic variational inference with the vectorized
//! (vmapped-particle) ELBO, Adam in Rust, compiled gradient on the
//! request path.
//!
//!     make artifacts && cargo run --release --example svi_logistic

use anyhow::Result;
use fugue::harness::builders::Workload;
use fugue::runtime::engine::Engine;
use fugue::svi::run_svi;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    let workload = Workload::for_model(&engine, "covtype_small", 42)?;
    let entry = engine.manifest.get("covtype_elbo_and_grad_f32")?.clone();
    let dt = entry.inputs[3].dtype; // x dtype

    let result = run_svi(
        &engine,
        "covtype_elbo_and_grad_f32",
        &workload.tensors(dt)?,
        600,
        0.05,
        42,
    )?;
    let trace = &result.elbo_trace;
    for (i, chunk) in trace.chunks(100).enumerate() {
        let mean = chunk.iter().sum::<f64>() / chunk.len() as f64;
        println!("steps {:>4}-{:>4}: mean ELBO {:>12.2}", i * 100, i * 100 + chunk.len(), mean);
    }
    let w_true = match &workload {
        Workload::Logistic(l) => l.w_true.clone(),
        _ => unreachable!(),
    };
    // guide layout (m..., b)
    let m = &result.loc[..w_true.len()];
    let dot: f64 = m.iter().zip(&w_true).map(|(a, b)| a * b).sum();
    let na = m.iter().map(|v| v * v).sum::<f64>().sqrt();
    let nb = w_true.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!(
        "\n{} steps in {:.2}s | corr(guide mean, truth) = {:.3}",
        result.steps,
        result.secs,
        dot / (na * nb)
    );
    Ok(())
}
