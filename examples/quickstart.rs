//! Quickstart: load the end-to-end-compiled NUTS artifact for a small
//! logistic-regression model, run one adaptively-warmed chain, print a
//! posterior summary.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the paper's headline loop in ~30 lines of user code: the
//! entire NUTS transition (Appendix A, Algorithm 2 — leapfrog, in-graph
//! gradients, U-turn checks, proposal sampling) is ONE compiled XLA
//! executable; Rust owns warmup adaptation and diagnostics.

use anyhow::Result;
use fugue::coordinator::{run_chain, FusedSampler, NutsOptions};
use fugue::diagnostics::summary::{render_table, summarize};
use fugue::harness::builders::{init_z, Workload};
use fugue::runtime::engine::Engine;
use fugue::runtime::NutsStep;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    let model = "covtype_small";

    // workload data is an artifact *input*: generate once, upload once
    let workload = Workload::for_model(&engine, model, 42)?;
    let entry = engine.manifest.find(model, "nuts_step", "f32")?;
    let data = workload.tensors(entry.inputs[1].dtype)?;
    let step = NutsStep::new(&engine, &format!("{model}_nuts_step_f32"), &data)?;
    let dim = step.dim;
    println!("loaded {model}: {dim}-dimensional posterior");

    let mut sampler = FusedSampler::new(step);
    let opts = NutsOptions {
        num_warmup: 300,
        num_samples: 500,
        seed: 42,
        ..Default::default()
    };
    let res = run_chain(&mut sampler, &init_z(dim, 42), &opts)?;

    let rows = summarize(&[res.samples.clone()], dim, &entry.param_layout);
    println!("{}", render_table(&rows));
    println!(
        "adapted step size {:.4} | {:.4} ms/leapfrog | {} dispatches for {} draws",
        res.step_size,
        res.ms_per_leapfrog(),
        sampler.step.dispatches,
        opts.num_warmup + opts.num_samples,
    );
    Ok(())
}
