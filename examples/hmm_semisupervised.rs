//! Semi-supervised HMM inference (the paper's Table 2a HMM workload),
//! comparing all three architectures on the same dataset and checking
//! the posterior recovers the generating transition matrix.
//!
//!     make artifacts && cargo run --release --example hmm_semisupervised

use anyhow::Result;
use fugue::coordinator::{run_chain, NutsOptions};
use fugue::harness::builders::{build_sampler, init_z, Backend, Workload};
use fugue::ppl::transforms::stick_breaking;
use fugue::runtime::engine::Engine;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    let seed = 7;
    let workload = Workload::for_model(&engine, "hmm", seed)?;
    let truth = match &workload {
        Workload::Hmm(h) => h.theta_true.clone(),
        _ => unreachable!(),
    };
    println!("true transition matrix:");
    for row in 0..3 {
        println!(
            "  [{:.3} {:.3} {:.3}]",
            truth[row * 3],
            truth[row * 3 + 1],
            truth[row * 3 + 2]
        );
    }

    for (backend, dtype) in [
        (Backend::Fused, "f32"),
        (Backend::Fused, "f64"),
        (Backend::Native, "f64"),
    ] {
        let mut sampler = build_sampler(&engine, "hmm", backend, dtype, &workload, 10)?;
        let dim = sampler.dim();
        let opts = NutsOptions {
            num_warmup: 300,
            num_samples: 300,
            seed,
            ..Default::default()
        };
        let res = run_chain(&mut sampler, &init_z(dim, seed), &opts)?;
        // posterior-mean unconstrained theta sticks -> simplex rows
        let n = (res.samples.len() / dim) as f64;
        let mut mean = vec![0.0; dim];
        for row in res.samples.chunks(dim) {
            for (m, v) in mean.iter_mut().zip(row) {
                *m += v / n;
            }
        }
        let mut err = 0.0;
        println!("\n{} {dtype}:", backend.paper_name());
        for row in 0..3 {
            let (simplex, _) = stick_breaking(&mean[27 + row * 2..27 + (row + 1) * 2]);
            println!(
                "  [{:.3} {:.3} {:.3}]",
                simplex[0], simplex[1], simplex[2]
            );
            for j in 0..3 {
                err += (simplex[j] - truth[row * 3 + j]).abs() / 9.0;
            }
        }
        println!(
            "  mean |err| {err:.3} | {:.4} ms/leapfrog | {} leapfrogs",
            res.ms_per_leapfrog(),
            res.sample_leapfrogs
        );
    }
    Ok(())
}
