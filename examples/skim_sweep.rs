//! SKIM dimensionality sweep (Fig 2b regeneration as an example):
//! sparse-interaction discovery with the kernel trick, ms/effective
//! sample vs p for the fused and native pipelines, plus a check that
//! the posterior's local scales single out the true interacting
//! covariates.
//!
//!     make artifacts && cargo run --release --example skim_sweep

use anyhow::Result;
use fugue::coordinator::{run_chain, NutsOptions};
use fugue::diagnostics::summary::{min_ess, summarize};
use fugue::harness::builders::{build_sampler, init_z, Backend, Workload};
use fugue::runtime::engine::Engine;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    let seed = 20191222;
    let mut ps: Vec<usize> = engine
        .manifest
        .models()
        .iter()
        .filter_map(|m| m.strip_prefix("skim_p").and_then(|s| s.parse().ok()))
        .collect();
    ps.sort_unstable();

    println!(
        "{:>6} {:<26} {:>12} {:>10} {:>14}",
        "p", "backend", "ms/ESS(min)", "sample s", "top-λ hits true"
    );
    for &p in &ps {
        let model = format!("skim_p{p}");
        let workload = Workload::for_model(&engine, &model, seed)?;
        let true_idx: Vec<usize> = match &workload {
            Workload::Skim(s) => s.pairs.iter().flat_map(|&(a, b)| [a, b]).collect(),
            _ => unreachable!(),
        };
        for (backend, dtype) in [(Backend::Fused, "f32"), (Backend::Native, "f64")] {
            let mut sampler = build_sampler(&engine, &model, backend, dtype, &workload, 10)?;
            let dim = sampler.dim();
            let opts = NutsOptions {
                num_warmup: 250,
                num_samples: 250,
                seed,
                ..Default::default()
            };
            let res = run_chain(&mut sampler, &init_z(dim, seed), &opts)?;
            let rows = summarize(&[res.samples.clone()], dim, &[]);
            // lambda block sits at offsets 1..1+p (sorted sites:
            // eta1, lambda, msq, sigma, xisq); rank by posterior mean
            let mut lam: Vec<(usize, f64)> = (0..p)
                .map(|i| (i, rows[1 + i].mean))
                .collect();
            lam.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let top: Vec<usize> = lam.iter().take(true_idx.len()).map(|t| t.0).collect();
            let hits = top.iter().filter(|i| true_idx.contains(i)).count();
            println!(
                "{:>6} {:<26} {:>12.2} {:>10.2} {:>10}/{}",
                p,
                format!("{} {dtype}", backend.paper_name()),
                1e3 * res.sample_secs / min_ess(&rows).max(1.0),
                res.sample_secs,
                hits,
                true_idx.len()
            );
        }
    }
    Ok(())
}
