//! End-to-end driver (DESIGN.md E2/E5): the full system on a real small
//! workload, proving all layers compose.
//!
//! 1. generate a CovType-like dataset (L3 data substrate);
//! 2. run 2 NUTS chains through the fused artifact (L1 Pallas likelihood
//!    kernel inside the L2 compiled transition) with Stan-style warmup;
//! 3. convergence diagnostics (split R-hat, ESS);
//! 4. vectorized posterior predictive + log-likelihood through the
//!    Fig 1c artifacts (vmap composed with seed/condition/trace);
//! 5. report accuracy, time/leapfrog, ms/ESS — the run recorded in
//!    EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example logistic_e2e

use anyhow::Result;
use fugue::coordinator::{run_chains, FusedSampler, NutsOptions};
use fugue::diagnostics::summary::{mean_ess, min_ess, render_table, summarize};
use fugue::harness::builders::Workload;
use fugue::ppl::special::log_sum_exp;
use fugue::rng::Rng;
use fugue::runtime::engine::{literal_to_f64, Engine, HostTensor};
use fugue::runtime::NutsStep;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    let model = "covtype_small";
    let seed = 20191222;
    let num_chains = 2;

    // --- data ---
    let workload = Workload::for_model(&engine, model, seed)?;
    let (x, y, n, d) = match &workload {
        Workload::Logistic(l) => (l.x.clone(), l.y.clone(), l.n, l.d),
        _ => unreachable!(),
    };
    println!("dataset: {n} x {d} (CovType substitute, DESIGN.md §5)");

    // --- inference ---
    let entry = engine.manifest.find(model, "nuts_step", "f32")?.clone();
    let step = NutsStep::new(
        &engine,
        &format!("{model}_nuts_step_f32"),
        &workload.tensors(entry.inputs[1].dtype)?,
    )?;
    let dim = step.dim;
    let mut sampler = FusedSampler::new(step);
    let opts = NutsOptions {
        num_warmup: 400,
        num_samples: 400,
        seed,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let results = run_chains(&mut sampler, num_chains, &opts)?;
    let wall = t0.elapsed().as_secs_f64();

    let chains: Vec<Vec<f64>> = results.iter().map(|r| r.samples.clone()).collect();
    let rows = summarize(&chains, dim, &entry.param_layout);
    println!("{}", render_table(&rows[..8.min(rows.len())]));
    let max_rhat = rows.iter().map(|r| r.rhat).fold(0.0, f64::max);
    let leapfrogs: u64 = results.iter().map(|r| r.sample_leapfrogs).sum();
    let sample_secs: f64 = results.iter().map(|r| r.sample_secs).sum();
    println!(
        "chains: {num_chains} | wall {wall:.1}s | max split-Rhat {max_rhat:.3} | min ESS {:.0} | mean ESS {:.0}",
        min_ess(&rows),
        mean_ess(&rows)
    );
    println!(
        "{:.4} ms/leapfrog | {:.2} ms/effective sample",
        1e3 * sample_secs / leapfrogs.max(1) as f64,
        1e3 * sample_secs / min_ess(&rows)
    );

    // --- vectorized posterior predictive (Fig 1c) ---
    let predict = engine.executable("covtype_predict_f32")?;
    let s = predict.entry.meta_usize("num_samples").unwrap_or(100);
    let all: Vec<f64> = chains.concat();
    let total_draws = all.len() / dim;
    let stride = (total_draws / s).max(1);
    let mut m_samples = Vec::with_capacity(s * (dim - 1));
    let mut b_samples = Vec::with_capacity(s);
    for i in 0..s {
        let row = &all[(i * stride % total_draws) * dim..];
        b_samples.push(row[0]);
        m_samples.extend_from_slice(&row[1..dim]);
    }
    let mut rng = Rng::new(seed ^ 0xABCD);
    let keys: Vec<u32> = (0..s)
        .flat_map(|_| vec![(rng.next_u64() >> 32) as u32, rng.next_u64() as u32])
        .collect();
    let fdt = predict.entry.inputs[1].dtype;
    let keys_b = engine.upload(&HostTensor::U32(keys, vec![s, 2]))?;
    let m_b = engine.upload(&HostTensor::from_f64(&m_samples, &[s, dim - 1], fdt)?)?;
    let b_b = engine.upload(&HostTensor::from_f64(&b_samples, &[s], fdt)?)?;
    let x_b = engine.upload(&HostTensor::from_f64(&x, &[n, d], fdt)?)?;
    let outs = predict.run_buffers(&[&keys_b, &m_b, &b_b, &x_b])?;
    let y_pred = literal_to_f64(&outs[0])?;
    let mut correct = 0;
    for i in 0..n {
        let votes: f64 = (0..s).map(|k| y_pred[k * n + i]).sum();
        if ((votes / s as f64 > 0.5) as i32 as f64 - y[i]).abs() < 0.5 {
            correct += 1;
        }
    }
    println!(
        "posterior predictive accuracy: {:.3} ({} draws via compiled vmap(seed(condition(model))))",
        correct as f64 / n as f64,
        s
    );

    // --- vectorized log-likelihood (Fig 1c line 7-8) ---
    let loglik = engine.executable("covtype_loglik_f32")?;
    let y_b = engine.upload(&HostTensor::I32(
        y.iter().map(|&v| v as i32).collect(),
        vec![n],
    ))?;
    let outs = loglik.run_buffers(&[&m_b, &b_b, &x_b, &y_b])?;
    let lls = literal_to_f64(&outs[0])?;
    println!(
        "expected log-likelihood: {:.1} (coin-flip baseline {:.1})",
        log_sum_exp(&lls) - (s as f64).ln(),
        n as f64 * 0.5f64.ln()
    );
    Ok(())
}
