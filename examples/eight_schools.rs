//! Eight schools through the model compiler: the whole model is the
//! ~20 lines of `sample`/`observe` code in `compile::zoo` — no density,
//! no gradient, no parameter bookkeeping — yet it samples through the
//! zero-allocation native iterative NUTS engine across parallel chains.
//!
//!     cargo run --release --example eight_schools

use fugue::compile::zoo::EightSchools;
use fugue::compile::{compile, SiteLayout};
use fugue::coordinator::{run_compiled_chains, NutsOptions};
use fugue::diagnostics::summary::{render_table, summarize};

fn main() -> anyhow::Result<()> {
    let model = EightSchools::classic();

    // the compile-time trace pass alone: site discovery + layout
    let layout: SiteLayout = compile(model.clone(), 0)?.layout().clone();
    println!("discovered layout (sorted sites, dim {}):", layout.dim);
    for s in layout.sites.iter().filter(|s| !s.observed) {
        println!(
            "  {:<8} offset {:>2} len {:>2} transform {}",
            s.name,
            s.offset,
            s.event_len,
            s.transform.name()
        );
    }

    let opts = NutsOptions {
        num_warmup: 700,
        num_samples: 2000,
        seed: 42,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (layout, results) = run_compiled_chains(&model, 4, 10, &opts)?;
    let secs = t0.elapsed().as_secs_f64();

    // report in the constrained space (tau = exp(u_tau))
    let dim = layout.dim;
    let constrained: Vec<Vec<f64>> = results
        .iter()
        .map(|r| {
            let mut draws = r.samples.clone();
            for row in draws.chunks_mut(dim) {
                layout.constrain_row(row);
            }
            draws
        })
        .collect();
    println!("\n4 chains x {} draws in {secs:.2}s:\n", opts.num_samples);
    let rows = summarize(&constrained, dim, &layout.param_spans());
    println!("{}", render_table(&rows));

    let divergences: u64 = results.iter().map(|r| r.divergences).sum();
    println!("{divergences} divergences (non-centered parameterization)");
    Ok(())
}
