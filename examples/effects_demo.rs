//! Table 1 in Rust: the effect handlers of the paper's §2 running over a
//! native Rust model — seed, trace, condition, substitute, replay —
//! showing the same composability story on the L3 side (no Python, no
//! artifacts needed).
//!
//!     cargo run --release --example effects_demo

use fugue::effects::{
    log_density, traced, Condition, Interp, Plate, Replay, Seed, Substitute, TraceH,
};
use fugue::ppl::Dist;

/// A tiny hierarchical model: mu ~ N(0,1); y_i ~ N(mu, 0.5), i < 3.
fn model(i: &mut Interp) {
    let mu = i.sample(
        "mu",
        Dist::Normal {
            loc: 0.0,
            scale: 1.0,
        },
    )[0];
    for k in 0..3 {
        i.sample(
            &format!("y{k}"),
            Dist::Normal {
                loc: mu,
                scale: 0.5,
            },
        );
    }
}

fn main() {
    // seed + trace: record an execution
    let tr = traced(model, 7);
    println!("trace(seed(model, 7)):");
    for (name, site) in &tr {
        println!(
            "  {name:<4} value={:+.3} observed={} log_prob={:+.3}",
            site.value[0], site.is_observed, site.log_prob
        );
    }
    println!("joint log density: {:+.3}\n", log_density(&tr));

    // condition: fix the ys, making them likelihood terms
    let data = (0..3)
        .map(|k| (format!("y{k}"), vec![0.8]))
        .collect();
    let mut s = Seed::new(7);
    let mut c = Condition::new(data);
    let mut t = TraceH::default();
    {
        let mut interp = Interp::new(vec![&mut s, &mut c, &mut t]);
        model(&mut interp);
    }
    println!(
        "condition(y=0.8): mu draw {:+.3}, joint {:+.3}",
        t.trace["mu"].value[0],
        log_density(&t.trace)
    );

    // substitute: evaluate the joint at a chosen latent (HMC's view)
    for mu in [-1.0, 0.0, 0.76, 2.0] {
        let mut s = Seed::new(7);
        let mut sub = Substitute::new([("mu".to_string(), vec![mu])].into_iter().collect());
        let mut c = Condition::new((0..3).map(|k| (format!("y{k}"), vec![0.8])).collect());
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut sub, &mut c, &mut t]);
            model(&mut interp);
        }
        println!("  log p(mu={mu:+.2}, y=0.8^3) = {:+.3}", log_density(&t.trace));
    }

    // replay: re-execute against a recorded trace
    let mut s = Seed::new(999);
    let mut r = Replay::new(&tr);
    let mut t = TraceH::default();
    {
        let mut interp = Interp::new(vec![&mut s, &mut r, &mut t]);
        model(&mut interp);
    }
    assert_eq!(t.trace["mu"].value, tr["mu"].value);
    println!("\nreplay reproduces mu = {:+.3} under a different seed", t.trace["mu"].value[0]);

    // plate: one vectorized site holding a batch of iid draws
    let mut s = Seed::new(11);
    let mut t = TraceH::default();
    let mut p = Plate { size: 4 };
    {
        let mut interp = Interp::new(vec![&mut s, &mut t, &mut p]);
        interp.sample(
            "x",
            Dist::Normal {
                loc: 0.0,
                scale: 1.0,
            },
        );
    }
    println!(
        "plate(4): one site, {} iid draws, summed log_prob {:+.3}",
        t.trace["x"].value.len(),
        t.trace["x"].log_prob
    );
}
