//! Native SVI end-to-end, fully offline (no artifacts, no pjrt):
//! reparameterized ADVI on the eight-schools model, with the 8 ELBO
//! particles evaluated as one fused multi-lane sweep of the frozen tape
//! per step, followed by posterior-predictive replay through the
//! `Substitute` handler.
//!
//!     cargo run --release --example svi_native

use anyhow::Result;
use fugue::compile::zoo::EightSchools;
use fugue::compile::SiteLayout;
use fugue::coordinator::run_svi_native;
use fugue::diagnostics::summary::{render_table, summarize};
use fugue::rng::Rng;
use fugue::svi::{posterior_predictive_draws, Convergence, StepSchedule, SviOptions};

fn main() -> Result<()> {
    let model = EightSchools::classic();
    let steps = 2000;
    let opts = SviOptions {
        num_steps: steps,
        num_particles: 8,
        lr: 0.05,
        seed: 42,
        schedule: StepSchedule::ExponentialDecay {
            rate: 0.05,
            over: steps,
        },
        convergence: Some(Convergence {
            window: 200,
            rel_tol: 1e-5,
        }),
        ..Default::default()
    };
    let (layout, fit) = run_svi_native(&model, &opts)?;

    let chunk = (fit.steps / 8).max(1);
    for (i, c) in fit.elbo_trace.chunks(chunk).enumerate() {
        let mean = c.iter().sum::<f64>() / c.len() as f64;
        println!(
            "steps {:>4}-{:>4}: mean ELBO {:>10.3}",
            i * chunk,
            i * chunk + c.len(),
            mean
        );
    }
    println!(
        "\n{} steps in {:.2}s{} | final ELBO {:.3}",
        fit.steps,
        fit.secs,
        if fit.converged { " (converged)" } else { "" },
        fit.final_elbo(100)
    );

    // variational posterior, constrained space, labeled by site
    let mut rng = Rng::new(7);
    let draws = fit.guide.posterior_draws(&layout, &mut rng, 2000);
    let rows = summarize(&[draws], layout.dim, &layout.param_spans());
    println!("{}", render_table(&rows));

    // posterior predictive for each school via Substitute-handler replay
    let pred = posterior_predictive_draws(&model, &layout, &fit.guide, 11, 500);
    println!("posterior predictive (500 replicates):");
    for (site, vals) in &pred {
        let m = vals.iter().sum::<f64>() / vals.len() as f64;
        let v = vals.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / vals.len() as f64;
        println!("  {site:<6} mean {m:>8.2}  sd {:>7.2}", v.sqrt());
    }

    // sanity: the same layout the NUTS engines use
    let check = SiteLayout::trace(&model, 0)?;
    assert_eq!(check.dim, layout.dim);
    Ok(())
}
