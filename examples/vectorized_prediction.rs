//! Fig 1 of the paper, end to end: the three vectorized inference
//! subroutines — prior predictive, posterior predictive, log-likelihood
//! — each a single compiled executable built by composing `vmap` with
//! the `seed` / `condition` / `trace` effect handlers (§3.2).
//!
//!     make artifacts && cargo run --release --example vectorized_prediction

use anyhow::Result;
use fugue::coordinator::{run_chain, FusedSampler, NutsOptions};
use fugue::harness::builders::{init_z, Workload};
use fugue::ppl::special::log_sum_exp;
use fugue::rng::Rng;
use fugue::runtime::engine::{literal_to_f64, Engine, HostTensor};
use fugue::runtime::NutsStep;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    let seed = 11;
    let model = "covtype_small";
    let workload = Workload::for_model(&engine, model, seed)?;
    let (x, y, n, d) = match &workload {
        Workload::Logistic(l) => (l.x.clone(), l.y.clone(), l.n, l.d),
        _ => unreachable!(),
    };

    let predict = engine.executable("covtype_predict_f32")?;
    let s = predict.entry.meta_usize("num_samples").unwrap_or(100);
    let fdt = predict.entry.inputs[1].dtype;
    let mut rng = Rng::new(seed);
    let mut keys = |count: usize| -> Vec<u32> {
        (0..count)
            .flat_map(|_| vec![(rng.next_u64() >> 32) as u32, rng.next_u64() as u32])
            .collect()
    };
    let x_b = engine.upload(&HostTensor::from_f64(&x, &[n, d], fdt)?)?;

    // 1. prior predictive: prior draws of (m, b) through the same
    //    conditioned-predict artifact (vmap ∘ seed ∘ condition)
    let mut prior_m = vec![0.0; s * d];
    let mut prior_b = vec![0.0; s];
    let mut prior_rng = Rng::new(seed ^ 0x1234);
    prior_rng.fill_normal(&mut prior_m);
    prior_rng.fill_normal(&mut prior_b);
    let keys_b = engine.upload(&HostTensor::U32(keys(s), vec![s, 2]))?;
    let pm_b = engine.upload(&HostTensor::from_f64(&prior_m, &[s, d], fdt)?)?;
    let pb_b = engine.upload(&HostTensor::from_f64(&prior_b, &[s], fdt)?)?;
    let outs = predict.run_buffers(&[&keys_b, &pm_b, &pb_b, &x_b])?;
    let prior_pred = literal_to_f64(&outs[0])?;
    let prior_rate = prior_pred.iter().sum::<f64>() / prior_pred.len() as f64;
    println!("prior predictive positive rate:     {prior_rate:.3} (expect ~0.5 under N(0,1) priors)");

    // 2. posterior samples via the fused NUTS artifact
    let entry = engine.manifest.find(model, "nuts_step", "f32")?.clone();
    let step = NutsStep::new(
        &engine,
        &format!("{model}_nuts_step_f32"),
        &workload.tensors(entry.inputs[1].dtype)?,
    )?;
    let dim = step.dim;
    let mut sampler = FusedSampler::new(step);
    let opts = NutsOptions {
        num_warmup: 250,
        num_samples: s,
        seed,
        ..Default::default()
    };
    let res = run_chain(&mut sampler, &init_z(dim, seed), &opts)?;
    let mut post_m = Vec::with_capacity(s * d);
    let mut post_b = Vec::with_capacity(s);
    for row in res.samples.chunks(dim) {
        post_b.push(row[0]);
        post_m.extend_from_slice(&row[1..]);
    }

    // 3. posterior predictive + accuracy
    let keys_b = engine.upload(&HostTensor::U32(keys(s), vec![s, 2]))?;
    let mm_b = engine.upload(&HostTensor::from_f64(&post_m, &[s, d], fdt)?)?;
    let bb_b = engine.upload(&HostTensor::from_f64(&post_b, &[s], fdt)?)?;
    let outs = predict.run_buffers(&[&keys_b, &mm_b, &bb_b, &x_b])?;
    let post_pred = literal_to_f64(&outs[0])?;
    let mut correct = 0;
    for i in 0..n {
        let votes: f64 = (0..s).map(|k| post_pred[k * n + i]).sum();
        if ((votes / s as f64 > 0.5) as i32 as f64 - y[i]).abs() < 0.5 {
            correct += 1;
        }
    }
    println!(
        "posterior predictive accuracy:       {:.3}",
        correct as f64 / n as f64
    );

    // 4. vectorized log-likelihood (Fig 1c lines 7-8)
    let loglik = engine.executable("covtype_loglik_f32")?;
    let y_b = engine.upload(&HostTensor::I32(
        y.iter().map(|&v| v as i32).collect(),
        vec![n],
    ))?;
    let outs = loglik.run_buffers(&[&mm_b, &bb_b, &x_b, &y_b])?;
    let post_ll = literal_to_f64(&outs[0])?;
    let outs = loglik.run_buffers(&[&pm_b, &pb_b, &x_b, &y_b])?;
    let prior_ll = literal_to_f64(&outs[0])?;
    let e_post = log_sum_exp(&post_ll) - (s as f64).ln();
    let e_prior = log_sum_exp(&prior_ll) - (s as f64).ln();
    println!("expected log-lik (posterior draws):  {e_post:.1}");
    println!("expected log-lik (prior draws):      {e_prior:.1}");
    println!("\nposterior beats prior by {:.1} nats — handlers + vmap compose (§3.2)", e_post - e_prior);
    Ok(())
}
