//! Sparse regression with the horseshoe prior, compiled from pure
//! `sample`/`observe` source: global-local shrinkage recovers the two
//! true signals and crushes the noise coordinates — a model the seed
//! repo could not express without hand-deriving a gradient.
//!
//!     cargo run --release --example horseshoe

use fugue::compile::zoo::Horseshoe;
use fugue::coordinator::{run_compiled_chains, NutsOptions};

fn main() -> anyhow::Result<()> {
    let (n, p, signals) = (100, 10, 3);
    let model = Horseshoe::synthetic(7, n, p, signals);
    println!(
        "horseshoe regression: n={n} p={p}, true beta = [2.0 x {signals}, 0.0 x {}]",
        p - signals
    );

    let opts = NutsOptions {
        num_warmup: 600,
        num_samples: 1200,
        seed: 11,
        target_accept: 0.9,
        ..Default::default()
    };
    let (layout, results) = run_compiled_chains(&model, 2, 10, &opts)?;

    // reconstruct beta_j = tau * lambda_j * z_j from constrained draws
    let dim = layout.dim;
    let lam_off = layout.latent("lambda").unwrap().offset;
    let tau_off = layout.latent("tau").unwrap().offset;
    let z_off = layout.latent("z").unwrap().offset;
    let mut beta_mean = vec![0.0f64; p];
    let mut draws = 0usize;
    for r in &results {
        for row in r.samples.chunks(dim) {
            let tau = row[tau_off].exp();
            for (j, bm) in beta_mean.iter_mut().enumerate() {
                *bm += tau * row[lam_off + j].exp() * row[z_off + j];
            }
            draws += 1;
        }
    }
    println!("\nposterior mean beta ({draws} draws):");
    for (j, bm) in beta_mean.iter_mut().enumerate() {
        *bm /= draws as f64;
        let truth = if j < signals { 2.0 } else { 0.0 };
        println!("  beta[{j}] = {bm:+.3}   (truth {truth:+.1})");
    }

    let divergences: u64 = results.iter().map(|r| r.divergences).sum();
    println!("\n{divergences} divergences");
    Ok(())
}
