//! Statistical end-to-end tests: the samplers (all three architectures)
//! recover known posteriors.
//!
//! * Native (analytic potentials): conjugate-Gaussian posterior
//!   moments, funnel-free banana sanity, recursive == iterative in
//!   distribution (two-sample moment comparison).
//! * Fused artifacts (needs `artifacts/`): logistic posterior recovers
//!   the generating weights' signs; HMM posterior concentrates near the
//!   true sticky transition structure.

use fugue::coordinator::{run_chain, NativeSampler, NutsOptions, TreeAlgorithm};
use fugue::diagnostics::summary::summarize;
use fugue::mcmc::Potential;

/// Gaussian with known diagonal covariance.
struct DiagGauss {
    var: Vec<f64>,
}

impl Potential for DiagGauss {
    fn dim(&self) -> usize {
        self.var.len()
    }
    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        let mut u = 0.0;
        for i in 0..z.len() {
            grad[i] = z[i] / self.var[i];
            u += 0.5 * z[i] * z[i] / self.var[i];
        }
        u
    }
}

fn moments(samples: &[f64], dim: usize) -> (Vec<f64>, Vec<f64>) {
    let n = (samples.len() / dim) as f64;
    let mut mean = vec![0.0; dim];
    for row in samples.chunks(dim) {
        for (m, v) in mean.iter_mut().zip(row) {
            *m += v;
        }
    }
    mean.iter_mut().for_each(|m| *m /= n);
    let mut var = vec![0.0; dim];
    for row in samples.chunks(dim) {
        for i in 0..dim {
            var[i] += (row[i] - mean[i]).powi(2);
        }
    }
    var.iter_mut().for_each(|v| *v /= n - 1.0);
    (mean, var)
}

fn run_native(alg: TreeAlgorithm, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let var = vec![4.0, 1.0, 0.25, 9.0];
    let mut sampler = NativeSampler::new(DiagGauss { var: var.clone() }, alg, 10);
    let opts = NutsOptions {
        num_warmup: 400,
        num_samples: 2500,
        seed,
        ..Default::default()
    };
    let res = run_chain(&mut sampler, &[0.5; 4], &opts).unwrap();
    moments(&res.samples, 4)
}

#[test]
fn iterative_recovers_anisotropic_gaussian() {
    let (mean, var) = run_native(TreeAlgorithm::Iterative, 11);
    let expect: [f64; 4] = [4.0, 1.0, 0.25, 9.0];
    for d in 0..4 {
        assert!(mean[d].abs() < 0.35 * expect[d].sqrt(), "mean[{d}] = {}", mean[d]);
        assert!(
            (var[d] - expect[d]).abs() < 0.3 * expect[d],
            "var[{d}] = {} want {}",
            var[d],
            expect[d]
        );
    }
}

#[test]
fn recursive_and_iterative_agree_in_distribution() {
    let (m1, v1) = run_native(TreeAlgorithm::Iterative, 21);
    let (m2, v2) = run_native(TreeAlgorithm::Recursive, 22);
    for d in 0..4 {
        let scale = v1[d].sqrt();
        assert!(
            (m1[d] - m2[d]).abs() < 0.3 * scale,
            "means differ at {d}: {} vs {}",
            m1[d],
            m2[d]
        );
        assert!(
            (v1[d] / v2[d]).ln().abs() < 0.5,
            "vars differ at {d}: {} vs {}",
            v1[d],
            v2[d]
        );
    }
}

#[test]
fn adaptation_learns_the_scale() {
    // After warmup the inverse mass approximates the target variances.
    let var = vec![25.0, 0.04];
    let mut sampler = NativeSampler::new(DiagGauss { var: var.clone() }, TreeAlgorithm::Iterative, 10);
    let opts = NutsOptions {
        num_warmup: 600,
        num_samples: 10,
        seed: 5,
        ..Default::default()
    };
    let res = run_chain(&mut sampler, &[1.0, 0.1], &opts).unwrap();
    let ratio = res.inv_mass[0] / res.inv_mass[1];
    let expect = var[0] / var[1];
    assert!(
        (ratio / expect).ln().abs() < 1.2,
        "inv mass ratio {ratio} want ~{expect}"
    );
}

#[test]
fn nuts_beats_mistuned_hmc_per_leapfrog() {
    // The paper's §3.1 motivation: NUTS adapts trajectory length, HMC
    // with a mistuned static trajectory wastes leapfrogs. Compare ESS
    // per leapfrog on an anisotropic Gaussian.
    use fugue::mcmc::hmc::HmcSampler;

    let var = vec![9.0, 1.0, 0.1];
    let opts = NutsOptions {
        num_warmup: 300,
        num_samples: 1200,
        seed: 33,
        ..Default::default()
    };
    // mistuned HMC: 64 leapfrogs per draw, way past the turnaround
    let mut hmc = HmcSampler::new(DiagGauss { var: var.clone() }, 64);
    let hmc_res = run_chain(&mut hmc, &[1.0, 1.0, 0.1], &opts).unwrap();
    let mut nuts = NativeSampler::new(DiagGauss { var }, TreeAlgorithm::Iterative, 10);
    let nuts_res = run_chain(&mut nuts, &[1.0, 1.0, 0.1], &opts).unwrap();

    let ess_per_lf = |res: &fugue::coordinator::ChainResult| {
        let rows = summarize(&[res.samples.clone()], 3, &[]);
        rows.iter().map(|r| r.ess).fold(f64::INFINITY, f64::min)
            / res.sample_leapfrogs as f64
    };
    let e_hmc = ess_per_lf(&hmc_res);
    let e_nuts = ess_per_lf(&nuts_res);
    assert!(
        e_nuts > 1.5 * e_hmc,
        "NUTS {e_nuts:.4} vs mistuned HMC {e_hmc:.4} ESS/leapfrog"
    );
}

// ---- artifact-backed statistical tests (need the real PJRT runtime;
// the default build's stub handles cannot evaluate artifacts) ----

#[cfg(feature = "pjrt")]
mod artifact_backed {
    use super::moments;
    use fugue::coordinator::{run_chain, NutsOptions};
    use fugue::diagnostics::summary::summarize;
    use fugue::harness::builders::{build_sampler, init_z, Backend, Workload};
    use fugue::runtime::engine::Engine;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Engine::new("artifacts").expect("engine"))
    }

    #[test]
    fn fused_logistic_recovers_generating_signal() {
        let Some(engine) = engine() else { return };
        let model = "covtype_small";
        let seed = 20191222;
        let workload = Workload::for_model(&engine, model, seed).unwrap();
        let mut sampler =
            build_sampler(&engine, model, Backend::Fused, "f32", &workload, 10).unwrap();
        let dim = sampler.dim();
        let opts = NutsOptions {
            num_warmup: 300,
            num_samples: 300,
            seed,
            ..Default::default()
        };
        let res = run_chain(&mut sampler, &init_z(dim, seed), &opts).unwrap();
        let (mean, _) = moments(&res.samples, dim);
        let w_true = match &workload {
            Workload::Logistic(l) => l.w_true.clone(),
            _ => unreachable!(),
        };
        // posterior mean of m correlates strongly with the truth
        let m = &mean[1..];
        let dot: f64 = m.iter().zip(&w_true).map(|(a, b)| a * b).sum();
        let na: f64 = m.iter().map(|a| a * a).sum::<f64>().sqrt();
        let nb: f64 = w_true.iter().map(|a| a * a).sum::<f64>().sqrt();
        let corr = dot / (na * nb);
        assert!(corr > 0.8, "corr(posterior mean, truth) = {corr}");
        // rhat-ish sanity on a single chain
        let rows = summarize(&[res.samples.clone()], dim, &[]);
        let bad = rows.iter().filter(|r| r.rhat > 1.2).count();
        assert!(bad < dim / 4, "{bad} of {dim} params have split-rhat > 1.2");
    }

    #[test]
    fn fused_hmm_identifies_sticky_transitions() {
        let Some(engine) = engine() else { return };
        let seed = 20191222;
        let workload = Workload::for_model(&engine, "hmm", seed).unwrap();
        let mut sampler =
            build_sampler(&engine, "hmm", Backend::Fused, "f32", &workload, 10).unwrap();
        let dim = sampler.dim();
        let opts = NutsOptions {
            num_warmup: 300,
            num_samples: 300,
            seed,
            ..Default::default()
        };
        let res = run_chain(&mut sampler, &init_z(dim, seed), &opts).unwrap();
        let (mean_u, _) = moments(&res.samples, dim);
        // theta sticks live after the phi block: layout [phi (27), theta (6)]
        let theta_sticks = &mean_u[27..33];
        // map back through stick-breaking per row and compare to truth
        let truth = match &workload {
            Workload::Hmm(h) => h.theta_true.clone(),
            _ => unreachable!(),
        };
        let mut err = 0.0;
        for row in 0..3 {
            let (simplex, _) =
                fugue::ppl::transforms::stick_breaking(&theta_sticks[row * 2..(row + 1) * 2]);
            for j in 0..3 {
                err += (simplex[j] - truth[row * 3 + j]).abs();
            }
        }
        err /= 9.0;
        assert!(err < 0.12, "mean |theta - truth| = {err}");
    }
}
