//! Golden tests for the native SVI engine (reparameterized ADVI over
//! compiled effect-handler models):
//!
//! 1. **Gradient correctness**: the host-side chain-ruled ELBO gradient
//!    (through the frozen tape potential) matches central finite
//!    differences at 1e-6 relative tolerance on eight-schools and
//!    logistic — every transform and fused-likelihood path exercised.
//! 2. **Particle lanes**: the fused multi-lane ELBO is bitwise
//!    identical to the scalar particle loop under the same RNG stream
//!    (K in {4, 8}), on a model with constrained sites.
//! 3. **Exact inference**: on a conjugate normal-normal model the
//!    fitted guide converges to the *known* posterior (location, scale,
//!    and KL(q || p) -> 0).
//! 4. **Cross-engine agreement**: on the logistic zoo model the SVI
//!    posterior means agree with NUTS means within 6x the NUTS MCSE —
//!    the acceptance bar for the second inference engine.

use fugue::autodiff::finite_diff;
use fugue::compile::zoo::{EightSchools, LogisticModel, NormalMean};
use fugue::compile::{compile, compile_batched, EffModel};
use fugue::coordinator::{run_compiled_chains_method, run_svi_native, ChainMethod, NutsOptions};
use fugue::data;
use fugue::diagnostics::effective_sample_size;
use fugue::mcmc::Potential;
use fugue::rng::Rng;
use fugue::svi::{OptimKind, ReparamElbo, StepSchedule, SviOptions};

/// The analytic ELBO gradient at fixed reparameterization noise must
/// match central finite differences of the (deterministic, same-noise)
/// ELBO to 1e-6 relative tolerance.
fn assert_elbo_grad_matches_fd<M: EffModel + Clone>(model: M, particles: usize, seed: u64) {
    let mut pot = compile(model, 0).unwrap();
    let dim = pot.dim();
    let mut elbo = ReparamElbo::new(dim, particles);
    let mut rng = Rng::new(seed);
    elbo.draw_eps(&mut rng);

    // a generic point: mildly spread locs, sub-unit scales
    let mut params = vec![0.0; 2 * dim];
    for i in 0..dim {
        params[i] = 0.3 * rng.normal();
        params[dim + i] = -1.0 + 0.2 * rng.normal();
    }

    let mut grad = vec![0.0; 2 * dim];
    {
        let (loc, ls) = params.split_at(dim);
        let _ = elbo.eval_scalar(&mut pot, loc, ls, &mut grad);
    }

    let mut gtmp = vec![0.0; 2 * dim];
    let fd = finite_diff(
        &params,
        |p| {
            let (loc, ls) = p.split_at(dim);
            elbo.eval_scalar(&mut pot, loc, ls, &mut gtmp)
        },
        1e-6,
    );
    for i in 0..2 * dim {
        let scale = 1.0 + grad[i].abs().max(fd[i].abs());
        assert!(
            (grad[i] - fd[i]).abs() <= 1e-6 * scale,
            "grad[{i}]: analytic {} vs fd {} (rel {})",
            grad[i],
            fd[i],
            (grad[i] - fd[i]).abs() / scale
        );
    }
}

#[test]
fn eight_schools_elbo_gradient_matches_fd() {
    assert_elbo_grad_matches_fd(EightSchools::classic(), 3, 11);
}

#[test]
fn logistic_elbo_gradient_matches_fd() {
    let (n, d) = (60, 3);
    let dset = data::make_covtype_like(2, n, d);
    let model = LogisticModel {
        x: dset.x,
        y: dset.y,
        n,
        d,
    };
    assert_elbo_grad_matches_fd(model, 4, 13);
}

/// Scalar-loop and fused-lane particle evaluation must agree bitwise
/// under the same RNG stream — on a hierarchical model with exp/identity
/// transforms, across particle counts.
#[test]
fn eight_schools_scalar_and_batched_particles_agree_bitwise() {
    for &k in &[4usize, 8] {
        let mut spot = compile(EightSchools::classic(), 0).unwrap();
        let mut bpot = compile_batched(EightSchools::classic(), 0, k).unwrap();
        let dim = spot.dim();
        let mut es = ReparamElbo::new(dim, k);
        let mut eb = ReparamElbo::new(dim, k);
        let mut rng_s = Rng::new(101);
        let mut rng_b = Rng::new(101);
        let mut loc = vec![0.0; dim];
        let mut ls = vec![-1.5; dim];
        let mut prng = Rng::new(55);
        for v in loc.iter_mut() {
            *v = 0.4 * prng.normal();
        }
        for v in ls.iter_mut() {
            *v += 0.3 * prng.normal();
        }
        let mut gs = vec![0.0; 2 * dim];
        let mut gb = vec![0.0; 2 * dim];
        for it in 0..10 {
            let vs = es.value_and_grad_scalar(&mut spot, &loc, &ls, &mut rng_s, &mut gs);
            let vb = eb.value_and_grad_batched(&mut bpot, &loc, &ls, &mut rng_b, &mut gb);
            assert_eq!(vs.to_bits(), vb.to_bits(), "K={k} it={it}: ELBO");
            for i in 0..2 * dim {
                assert_eq!(gs[i].to_bits(), gb[i].to_bits(), "K={k} it={it}: grad[{i}]");
            }
        }
    }
}

/// Conjugate normal-normal: `mu ~ N(0,1)`, `y_i ~ N(mu, s)` has the
/// closed-form posterior `N(m_post, v_post)` with `1/v_post = 1 +
/// n/s^2`.  A mean-field normal guide can represent it exactly, so SVI
/// must drive KL(q || p) to ~0.
#[test]
fn conjugate_normal_normal_recovers_exact_posterior() {
    let s = 1.0;
    let mut rng = Rng::new(77);
    let y: Vec<f64> = (0..20).map(|_| 1.5 + s * rng.normal()).collect();
    let n = y.len() as f64;
    let v_post = 1.0 / (1.0 + n / (s * s));
    let m_post = y.iter().sum::<f64>() / (s * s) * v_post;
    let sd_post = v_post.sqrt();

    let steps = 4000;
    let opts = SviOptions {
        num_steps: steps,
        num_particles: 8,
        lr: 0.05,
        seed: 3,
        optimizer: OptimKind::Adam,
        schedule: StepSchedule::ExponentialDecay {
            rate: 0.01,
            over: steps,
        },
        vectorize_particles: true,
        convergence: None,
        tail_average: 0.25,
    };
    let (_, fit) = run_svi_native(&NormalMean { y, sigma: s }, &opts).unwrap();
    let mq = fit.guide.loc()[0];
    let sq = fit.guide.log_scale()[0].exp();
    assert!(
        (mq - m_post).abs() < 0.02,
        "guide loc {mq} vs posterior mean {m_post}"
    );
    assert!(
        (sq - sd_post).abs() / sd_post < 0.05,
        "guide sd {sq} vs posterior sd {sd_post}"
    );
    let kl = (sd_post / sq).ln() + (sq * sq + (mq - m_post) * (mq - m_post))
        / (2.0 * sd_post * sd_post)
        - 0.5;
    assert!(kl < 1e-3, "KL(q || p) = {kl}");
}

/// Pooled mean and MCSE (sd / sqrt(ESS)) of one parameter of a NUTS run.
fn nuts_mean_and_mcse(
    results: &[fugue::coordinator::ChainResult],
    dim: usize,
    d: usize,
) -> (f64, f64) {
    let chains: Vec<Vec<f64>> = results
        .iter()
        .map(|r| r.samples.chunks(dim).map(|row| row[d]).collect())
        .collect();
    let all: Vec<f64> = chains.iter().flatten().copied().collect();
    let n = all.len() as f64;
    let mean = all.iter().sum::<f64>() / n;
    let var = all.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    let ess = effective_sample_size(&chains).max(4.0);
    (mean, (var / ess).sqrt())
}

/// The acceptance bar: on the logistic zoo model (all-identity
/// transforms, so guide locs are posterior means directly), native SVI
/// means agree with NUTS means within 6x the NUTS MCSE.
#[test]
fn logistic_svi_means_agree_with_nuts_within_6_mcse() {
    let (n, d) = (120, 3);
    let dset = data::make_covtype_like(3, n, d);
    let model = LogisticModel {
        x: dset.x,
        y: dset.y,
        n,
        d,
    };
    let dim = d + 1;

    let nopts = NutsOptions {
        num_warmup: 200,
        num_samples: 400,
        seed: 17,
        ..Default::default()
    };
    let (_, nuts) =
        run_compiled_chains_method(&model, ChainMethod::Vectorized, 4, 10, &nopts).unwrap();

    let steps = 3000;
    let sopts = SviOptions {
        num_steps: steps,
        num_particles: 8,
        lr: 0.05,
        seed: 5,
        optimizer: OptimKind::Adam,
        schedule: StepSchedule::ExponentialDecay {
            rate: 0.02,
            over: steps,
        },
        vectorize_particles: true,
        convergence: None,
        tail_average: 0.25,
    };
    let (layout, fit) = run_svi_native(&model, &sopts).unwrap();
    assert_eq!(layout.dim, dim);
    for p in 0..dim {
        let (mean, mcse) = nuts_mean_and_mcse(&nuts, dim, p);
        let diff = (fit.guide.loc()[p] - mean).abs();
        let tol = 6.0 * mcse + 1e-3;
        assert!(
            diff < tol,
            "param {p}: SVI {} vs NUTS {mean} differ by {diff:.4} > {tol:.4} (MCSE {mcse:.5})",
            fit.guide.loc()[p]
        );
    }
}

/// The ELBO trace of a converging run must rise and then flatten; the
/// convergence window reports it.
#[test]
fn eight_schools_elbo_improves() {
    let opts = SviOptions {
        num_steps: 800,
        num_particles: 4,
        lr: 0.05,
        seed: 1,
        ..Default::default()
    };
    let (_, fit) = run_svi_native(&EightSchools::classic(), &opts).unwrap();
    let early: f64 = fit.elbo_trace[..50].iter().sum::<f64>() / 50.0;
    let late = fit.final_elbo(100);
    assert!(
        late > early,
        "ELBO failed to improve: {early:.3} -> {late:.3}"
    );
    // tau is exp-constrained: reported posterior draws must be positive
    let mut rng = Rng::new(9);
    let layout = fugue::compile::SiteLayout::trace(&EightSchools::classic(), 0).unwrap();
    let draws = fit.guide.posterior_draws(&layout, &mut rng, 100);
    let tau = layout.latent("tau").unwrap();
    for row in draws.chunks(layout.dim) {
        assert!(row[tau.offset] > 0.0, "constrained tau must be positive");
    }
}
