//! Statistical validation of the convergence diagnostics against
//! analytic ground truth: ESS on synthetic AR(1) chains with known
//! autocorrelation, and split R-hat behaviour on iid, shifted and
//! trending chains.

use fugue::diagnostics::{effective_sample_size, split_rhat};
use fugue::rng::Rng;

/// Stationary AR(1) with lag-1 correlation `rho` and unit marginal
/// variance: `x_t = rho x_{t-1} + sqrt(1-rho^2) eps_t`.
fn ar1(rng: &mut Rng, n: usize, rho: f64) -> Vec<f64> {
    let mut x = vec![0.0; n];
    x[0] = rng.normal();
    let sd = (1.0 - rho * rho).sqrt();
    for i in 1..n {
        x[i] = rho * x[i - 1] + sd * rng.normal();
    }
    x
}

/// For AR(1), the integrated autocorrelation time is
/// `tau = (1+rho)/(1-rho)`, so `ESS/N -> (1-rho)/(1+rho)`.
fn ar1_ess_fraction(rho: f64) -> f64 {
    (1.0 - rho) / (1.0 + rho)
}

#[test]
fn ess_matches_analytic_across_autocorrelations() {
    for (i, &rho) in [0.3, 0.6, 0.9].iter().enumerate() {
        let mut rng = Rng::new(100 + i as u64);
        let n = if rho < 0.8 { 8_000 } else { 24_000 };
        let chain = ar1(&mut rng, n, rho);
        let ess = effective_sample_size(&[chain]);
        let expect = n as f64 * ar1_ess_fraction(rho);
        assert!(
            (ess - expect).abs() < 0.3 * expect,
            "rho {rho}: ess {ess:.0} vs analytic {expect:.0}"
        );
    }
}

#[test]
fn ess_matches_analytic_with_multiple_chains() {
    let rho = 0.5;
    let m = 4;
    let n = 4_000;
    let mut rng = Rng::new(7);
    let chains: Vec<Vec<f64>> = (0..m).map(|_| ar1(&mut rng, n, rho)).collect();
    let ess = effective_sample_size(&chains);
    let expect = (m * n) as f64 * ar1_ess_fraction(rho);
    assert!(
        (ess - expect).abs() < 0.3 * expect,
        "ess {ess:.0} vs analytic {expect:.0}"
    );
}

#[test]
fn ess_of_iid_draws_is_near_n_and_clamped() {
    let mut rng = Rng::new(8);
    let n = 6_000;
    let chain: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let ess = effective_sample_size(&[chain]);
    assert!(ess > 0.75 * n as f64, "iid ess {ess:.0} too low");
    assert!(ess <= n as f64 + 1e-9, "iid ess {ess:.0} exceeds draw count");
}

/// Heavier autocorrelation must monotonically cost effective samples.
#[test]
fn ess_decreases_with_autocorrelation() {
    let n = 8_000;
    let mut prev = f64::INFINITY;
    for (i, &rho) in [0.2, 0.5, 0.8].iter().enumerate() {
        let mut rng = Rng::new(300 + i as u64);
        let ess = effective_sample_size(&[ar1(&mut rng, n, rho)]);
        assert!(
            ess < prev,
            "rho {rho}: ess {ess:.0} did not decrease (prev {prev:.0})"
        );
        prev = ess;
    }
}

#[test]
fn split_rhat_is_one_for_iid_chains() {
    let mut rng = Rng::new(21);
    let chains: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..3_000).map(|_| rng.normal()).collect())
        .collect();
    let r = split_rhat(&chains);
    assert!((r - 1.0).abs() < 0.02, "iid rhat {r}");
}

#[test]
fn split_rhat_flags_shifted_chains() {
    let mut rng = Rng::new(22);
    let a: Vec<f64> = (0..2_000).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..2_000).map(|_| rng.normal() + 4.0).collect();
    let c: Vec<f64> = (0..2_000).map(|_| rng.normal()).collect();
    let r = split_rhat(&[a, b, c]);
    assert!(r > 1.5, "shifted-chain rhat {r} should be >> 1");
}

/// The *split* in split-R-hat: a single chain whose halves live in
/// different places (a trend / non-stationarity) must be flagged even
/// though plain multi-chain R-hat would never see it.
#[test]
fn split_rhat_flags_within_chain_trend() {
    let mut rng = Rng::new(23);
    let n = 2_000;
    let trending: Vec<f64> = (0..n)
        .map(|i| rng.normal() + if i < n / 2 { 0.0 } else { 3.0 })
        .collect();
    let r = split_rhat(&[trending]);
    assert!(r > 1.5, "trending-chain split rhat {r} should be >> 1");
}

/// Scale invariance: diagnostics must not depend on the parameter's
/// units.
#[test]
fn diagnostics_are_scale_invariant() {
    let mut rng = Rng::new(24);
    let base: Vec<Vec<f64>> = (0..2).map(|_| ar1(&mut rng, 4_000, 0.4)).collect();
    let scaled: Vec<Vec<f64>> = base
        .iter()
        .map(|c| c.iter().map(|x| 1e6 * x + 5.0e3).collect())
        .collect();
    let (e1, e2) = (
        effective_sample_size(&base),
        effective_sample_size(&scaled),
    );
    assert!(
        (e1 - e2).abs() < 1e-6 * e1.abs().max(1.0) + 1.0,
        "ess not scale invariant: {e1} vs {e2}"
    );
    let (r1, r2) = (split_rhat(&base), split_rhat(&scaled));
    assert!(
        (r1 - r2).abs() < 1e-6,
        "rhat not scale invariant: {r1} vs {r2}"
    );
}
