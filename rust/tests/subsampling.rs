//! Integration tests for minibatch (subsampled) SVI: the Pyro
//! `plate(subsample_size)` contract over frozen tape programs.
//!
//! Pins the three contracts the subsampling engine rests on:
//!
//! 1. **Full-batch identity**: `B = N` through the subsampled path is
//!    bitwise identical to the plain SVI path on the equivalent model —
//!    the minibatch machinery (scheduler, data slots, scale node) must
//!    be invisible at full batch, on both particle backends.
//! 2. **Unbiasedness**: with `B | N`, the epoch average of the scaled
//!    minibatch ELBO (and its gradient) at fixed reparameterization
//!    noise equals the full-batch ELBO exactly up to float summation
//!    order — the N/B scale correction makes every row count once.
//! 3. **Resume**: the minibatch scheduler's cursor rides the SVI
//!    checkpoint, so a mid-epoch kill + JSON round-trip + resume walks
//!    the exact same minibatch sequence as an uninterrupted run.
//!
//! Plus the generic `observe_iid` fallback contract at K = 64: an
//! Exponential-likelihood model (no fused observation composite) must
//! agree bitwise between the scalar, batched and tiled backends.

use fugue::compile::zoo::LogisticModel;
use fugue::compile::{
    compile, compile_batched, tiled_from_layout, DistV, EffModel, ProbCtx, SiteLayout,
    SubsampleRebind, SubsampledLogistic,
};
use fugue::coordinator::{
    run_svi_native, run_svi_subsampled, run_svi_subsampled_checkpointed, CheckpointConfig,
};
use fugue::data::{make_covtype_like, InMemoryRows, MinibatchScheduler, SyntheticLogisticStream};
use fugue::mcmc::{BatchPotential, Potential};
use fugue::rng::Rng;
use fugue::svi::{
    scheduler_rng, NativeSvi, OptimKind, ReparamElbo, StepSchedule, SubsampledBatchedParticles,
    SviOptions,
};

fn svi_opts(steps: usize, particles: usize, vectorize: bool, seed: u64) -> SviOptions {
    SviOptions {
        num_steps: steps,
        num_particles: particles,
        lr: 0.05,
        seed,
        optimizer: OptimKind::Adam,
        schedule: StepSchedule::Constant,
        vectorize_particles: vectorize,
        convergence: None,
        tail_average: 0.0,
    }
}

fn logistic_pair(seed: u64, n: usize, d: usize) -> (LogisticModel, InMemoryRows) {
    let dset = make_covtype_like(seed, n, d);
    let full = LogisticModel {
        x: dset.x.clone(),
        y: dset.y.clone(),
        n,
        d,
    };
    (full, InMemoryRows::new(dset.x, dset.y, n, d))
}

/// Contract 1: the subsampled runner at B = N is bitwise identical to
/// the plain full-batch runner, on both particle backends.
#[test]
fn full_batch_subsampled_run_is_bitwise_identical_to_native_run() {
    let (full, rows) = logistic_pair(42, 120, 4);
    let sub = SubsampledLogistic::new(rows, 120);
    for (particles, vectorize) in [(4usize, true), (2, false), (1, true)] {
        let opts = svi_opts(50, particles, vectorize, 7);
        let (_, a) = run_svi_native(&full, &opts).unwrap();
        let (_, b) = run_svi_subsampled(&sub, &opts).unwrap();
        assert_eq!(a.steps, b.steps);
        for (x, y) in a.elbo_trace.iter().zip(&b.elbo_trace) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "ELBO trace diverged (particles={particles} vectorize={vectorize})"
            );
        }
        for (x, y) in a.guide.params().iter().zip(b.guide.params()) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "guide params diverged (particles={particles} vectorize={vectorize})"
            );
        }
    }
}

/// Contract 2: at fixed reparameterization noise, averaging the scaled
/// minibatch ELBO gradient over one epoch (B | N, each row visited
/// exactly once) reproduces the full-batch ELBO gradient to float
/// summation accuracy.  This is the linearity argument that makes the
/// minibatch estimator unbiased: E[(N/B) * L_batch] = L_total.
#[test]
fn epoch_averaged_minibatch_elbo_gradient_matches_full_batch() {
    let (n, d, batch) = (96, 4, 16);
    let (full, rows) = logistic_pair(5, n, d);
    let sub = SubsampledLogistic::new(rows, batch);

    let mut pot_full = compile(full, 11).unwrap();
    let mut pot_sub = compile(sub, 11).unwrap();
    let dim = pot_full.dim();

    let mut elbo = ReparamElbo::new(dim, 1);
    let mut rng = Rng::new(99);
    elbo.draw_eps(&mut rng);
    let eps: Vec<f64> = elbo.eps().to_vec();

    let loc: Vec<f64> = (0..dim).map(|i| 0.05 * (i as f64 + 1.0)).collect();
    let log_scale = vec![-1.0; dim];

    let mut g_full = vec![0.0; 2 * dim];
    let v_full = elbo.eval_scalar(&mut pot_full, &loc, &log_scale, &mut g_full);

    let mut sched = MinibatchScheduler::new(n, batch, scheduler_rng(3));
    let n_batches = sched.batches_per_epoch();
    assert_eq!(n_batches, n / batch);
    let mut v_avg = 0.0;
    let mut g_avg = vec![0.0; 2 * dim];
    let mut g = vec![0.0; 2 * dim];
    for _ in 0..n_batches {
        let idx: Vec<usize> = sched.next_batch().to_vec();
        pot_sub.set_minibatch(&idx);
        elbo.set_eps(&eps);
        let v = elbo.eval_scalar(&mut pot_sub, &loc, &log_scale, &mut g);
        v_avg += v / n_batches as f64;
        for (a, b) in g_avg.iter_mut().zip(&g) {
            *a += b / n_batches as f64;
        }
    }

    let tol = 1e-8 * (1.0 + v_full.abs());
    assert!(
        (v_avg - v_full).abs() < tol,
        "epoch-averaged ELBO {v_avg} != full-batch {v_full}"
    );
    for i in 0..2 * dim {
        let tol = 1e-8 * (1.0 + g_full[i].abs());
        assert!(
            (g_avg[i] - g_full[i]).abs() < tol,
            "grad[{i}]: epoch average {} != full batch {}",
            g_avg[i],
            g_full[i]
        );
    }
}

/// Contract 3 (engine level): export the cursor mid-epoch, round-trip
/// it through the checkpoint JSON, import into a fresh engine, and the
/// resumed run is bitwise identical to the uninterrupted one.
#[test]
fn mid_epoch_checkpoint_resume_is_bitwise_identical() {
    use fugue::coordinator::{load_svi_checkpoint, save_svi_checkpoint};

    let (_, rows) = logistic_pair(21, 64, 3);
    let model = SubsampledLogistic::new(rows, 16);
    let opts = svi_opts(30, 4, true, 13);
    let dim = SiteLayout::trace(&model, 13).unwrap().dim;

    let make_engine = || {
        let sched = MinibatchScheduler::new(64, 16, scheduler_rng(13));
        let pot = compile_batched(model.clone(), 13, 4).unwrap();
        NativeSvi::new(SubsampledBatchedParticles::new(pot, sched), &opts).unwrap()
    };

    // uninterrupted reference
    let mut a = make_engine();
    for _ in 0..30 {
        a.step();
    }

    // killed after 13 steps (mid-epoch: 4 batches per epoch), resumed
    // from the JSON checkpoint
    let mut b1 = make_engine();
    for _ in 0..13 {
        b1.step();
    }
    let path = std::env::temp_dir().join("fugue_subsampling_resume_test.json");
    save_svi_checkpoint(&path, 13, 30, &b1.export_cursor()).unwrap();
    let cur = load_svi_checkpoint(&path, 13, 30, dim).unwrap();
    assert!(cur.subsample.is_some(), "subsample cursor missing from checkpoint");
    let mut b2 = make_engine();
    b2.import_cursor(&cur).unwrap();
    for _ in 0..17 {
        b2.step();
    }
    let _ = std::fs::remove_file(&path);

    assert_eq!(a.elbo_trace().len(), b2.elbo_trace().len());
    for (x, y) in a.elbo_trace().iter().zip(b2.elbo_trace()) {
        assert_eq!(x.to_bits(), y.to_bits(), "ELBO trace diverged after resume");
    }
    for (x, y) in a.guide().params().iter().zip(b2.guide().params()) {
        assert_eq!(x.to_bits(), y.to_bits(), "guide params diverged after resume");
    }
}

/// Contract 3 (runner level): the checkpointed subsampled runner with a
/// checkpoint file and no interruption matches the plain subsampled
/// runner bitwise.
#[test]
fn checkpointed_subsampled_runner_matches_plain_runner() {
    let (_, rows) = logistic_pair(77, 48, 3);
    let model = SubsampledLogistic::new(rows, 12);
    let opts = svi_opts(20, 4, true, 5);
    let path = std::env::temp_dir().join("fugue_subsampling_runner_test.json");
    let cfg = CheckpointConfig {
        path: Some(path.clone()),
        resume: false,
        every: 6,
        max_seconds: None,
    };
    let (_, plain) = run_svi_subsampled(&model, &opts).unwrap();
    let (_, checked) = run_svi_subsampled_checkpointed(&model, &opts, &cfg).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(plain.steps, checked.steps);
    for (x, y) in plain.elbo_trace.iter().zip(&checked.elbo_trace) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in plain.guide.params().iter().zip(checked.guide.params()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// Fixed-memory streaming: SVI over a 10-million-row synthetic logistic
/// dataset whose rows are generated on demand.  The loader holds O(D)
/// state and the model O(B*D) staging — the full 10M x D matrix never
/// exists.  A few steps suffice to pin that the hot path works at this
/// scale; throughput is the bench's job.
#[test]
fn streaming_ten_million_rows_runs_at_fixed_memory() {
    let loader = SyntheticLogisticStream::new(3, 10_000_000, 4);
    let model = SubsampledLogistic::new(loader, 64);
    let opts = svi_opts(3, 2, true, 17);
    let (_, fit) = run_svi_subsampled(&model, &opts).unwrap();
    assert_eq!(fit.steps, 3);
    assert!(
        fit.elbo_trace.iter().all(|e| e.is_finite()),
        "non-finite ELBO on the streaming model: {:?}",
        fit.elbo_trace
    );
}

/// Exercises the generic (non-fused) `observe_iid` fallback: an
/// Exponential likelihood has no fused observation composite, so its
/// log-probs run lane-wise through the Alg ops and its observed
/// constants through the data-node registration path.
#[derive(Clone)]
struct ExpObs {
    y: Vec<f64>,
}

impl EffModel for ExpObs {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let d = c.half_normal(1.0);
        let rate = c.sample("rate", d);
        c.observe_iid("y", DistV::Exponential { rate }, &self.y);
    }
}

/// Satellite contract: generic `observe_iid` fallback at K = 64 —
/// scalar, batched and tiled backends agree bitwise per lane, on both
/// the first (recording) and later (frozen replay) evaluations.
#[test]
fn generic_observe_iid_scalar_batched_tiled_bitwise_at_k64() {
    let k = 64;
    let model = ExpObs {
        y: vec![0.5, 1.2, 0.1, 2.3, 0.9],
    };
    let layout = SiteLayout::trace(&model, 0).unwrap();
    let dim = layout.dim;
    assert_eq!(dim, 1);

    let mut batched = compile_batched(model.clone(), 0, k).unwrap();
    let mut tiled = tiled_from_layout(&model, &layout, k, 8);

    let mut rng = Rng::new(31);
    let mut u_b = vec![0.0; k];
    let mut g_b = vec![0.0; dim * k];
    let mut u_t = vec![0.0; k];
    let mut g_t = vec![0.0; dim * k];
    // round 0 records the tapes; round 1+ replays the frozen programs —
    // both must match the scalar path bitwise
    for round in 0..3 {
        let z: Vec<f64> = (0..dim * k).map(|_| 0.4 * rng.normal()).collect();
        batched.value_and_grad_batch(&z, &mut u_b, &mut g_b);
        tiled.value_and_grad_batch(&z, &mut u_t, &mut g_t);
        for lane in 0..k {
            let mut pot = compile(model.clone(), 0).unwrap();
            let zk: Vec<f64> = (0..dim).map(|i| z[i * k + lane]).collect();
            let mut g_s = vec![0.0; dim];
            let u_s = pot.value_and_grad(&zk, &mut g_s);
            assert_eq!(
                u_s.to_bits(),
                u_b[lane].to_bits(),
                "batched U diverged at lane {lane} round {round}"
            );
            assert_eq!(
                u_s.to_bits(),
                u_t[lane].to_bits(),
                "tiled U diverged at lane {lane} round {round}"
            );
            for i in 0..dim {
                assert_eq!(
                    g_s[i].to_bits(),
                    g_b[i * k + lane].to_bits(),
                    "batched grad diverged at lane {lane} dim {i} round {round}"
                );
                assert_eq!(
                    g_s[i].to_bits(),
                    g_t[i * k + lane].to_bits(),
                    "tiled grad diverged at lane {lane} dim {i} round {round}"
                );
            }
        }
    }
}
