//! Flight-recorder integration tests: the observability layer must be
//! invisible to the numerics and visible to the operator.
//!
//! Contracts pinned here:
//!
//! 1. **Bitwise neutrality** — installing the recorder changes no
//!    sampled bit: NUTS through all three chain methods (plus the tiled
//!    lane engine past the vectorization threshold), native SVI, and
//!    subsampled SVI all produce bitwise-identical results with the
//!    recorder on vs off.  The recorder observes values the engines
//!    already computed; it never consumes RNG or reorders float work.
//! 2. **It actually records** — the same instrumented runs leave
//!    nonzero draw/leapfrog/SVI-step/epoch counters behind.
//! 3. **Exporters** — the JSONL event stream round-trips through the
//!    crate's own JSON parser; the metrics snapshot carries the
//!    `fugue-metrics/v1` schema and is written atomically (no `.tmp`
//!    litter), including across a kill-and-resume checkpoint cycle.
//! 4. **ELBO MC-SE** — the convergence diagnostic is zero on degenerate
//!    traces, matches a hand computation, and lands in the SVI result.
//!
//! Tests that install the process-global recorder serialize on
//! `OBS_LOCK`; everything else uses private leaked registries.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use fugue::compile::zoo::EightSchools;
use fugue::compile::SubsampledLogistic;
use fugue::coordinator::{
    run_compiled_chains_checkpointed, run_compiled_chains_method, run_svi_native,
    run_svi_subsampled, ChainMethod, ChainResult, CheckpointConfig, NutsOptions,
};
use fugue::data::{make_covtype_like, InMemoryRows};
use fugue::obs::{
    install, progress_line, snapshot_json, uninstall, write_snapshot, Counter, Gauge,
    MetricsRegistry, Phase, Recorder, SpanKind, TraceWriter, Val, SNAPSHOT_SCHEMA,
};
use fugue::svi::{elbo_mcse, NativeSviResult, OptimKind, StepSchedule, SviOptions};
use fugue::util::json::Json;

/// Serializes every test that touches the process-global recorder so
/// parallel test threads cannot observe each other's installs.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fugue_obs_{}_{}.json", std::process::id(), name))
}

fn nuts(warmup: usize, samples: usize, seed: u64) -> NutsOptions {
    NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        seed,
        ..Default::default()
    }
}

fn svi_opts(steps: usize, particles: usize, vectorize: bool, seed: u64) -> SviOptions {
    SviOptions {
        num_steps: steps,
        num_particles: particles,
        lr: 0.05,
        seed,
        optimizer: OptimKind::Adam,
        schedule: StepSchedule::Constant,
        vectorize_particles: vectorize,
        convergence: None,
        tail_average: 0.0,
    }
}

fn assert_chains_bitwise_equal(a: &[ChainResult], b: &[ChainResult], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: chain count");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.samples, y.samples, "{what}: chain {k} samples");
        assert_eq!(x.step_size.to_bits(), y.step_size.to_bits(), "{what}: chain {k} step size");
        assert_eq!(x.inv_mass, y.inv_mass, "{what}: chain {k} inverse mass");
        assert_eq!(x.divergences, y.divergences, "{what}: chain {k} divergences");
        assert_eq!(x.quarantines, y.quarantines, "{what}: chain {k} quarantines");
        assert_eq!(x.total_leapfrogs, y.total_leapfrogs, "{what}: chain {k} leapfrogs");
        assert_eq!(x.stats.accept_prob, y.stats.accept_prob, "{what}: chain {k} accepts");
    }
}

fn assert_svi_bitwise_equal(a: &NativeSviResult, b: &NativeSviResult, what: &str) {
    assert_eq!(a.steps, b.steps, "{what}: step count");
    for (i, (x, y)) in a.elbo_trace.iter().zip(&b.elbo_trace).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: ELBO trace diverged at step {i}");
    }
    for (i, (x, y)) in a.guide.params().iter().zip(b.guide.params()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: guide param {i} diverged");
    }
    assert_eq!(a.elbo_mcse.to_bits(), b.elbo_mcse.to_bits(), "{what}: MC-SE diverged");
}

// ---------------------------------------------------------------------
// 1 + 2. bitwise neutrality across every engine, and proof-of-recording
// ---------------------------------------------------------------------

/// NUTS draws are bitwise identical with the recorder on vs off for
/// every chain method, including the tiled lane engine (128 chains is
/// past the vectorization threshold), and the enabled run leaves real
/// counters behind.
#[test]
fn recorder_is_bitwise_neutral_for_all_chain_methods() {
    let _g = obs_lock();
    let model = EightSchools::classic();
    let configs = [
        (ChainMethod::Sequential, 2, 40, 40),
        (ChainMethod::Parallel, 2, 40, 40),
        (ChainMethod::Vectorized, 4, 40, 40),
        // > TILED_LANE_THRESHOLD lanes: the tiled batch engine
        (ChainMethod::Vectorized, 128, 15, 15),
    ];
    for (method, chains, warmup, samples) in configs {
        let o = nuts(warmup, samples, 31);
        uninstall();
        let (_, off) = run_compiled_chains_method(&model, method, chains, 6, &o).unwrap();
        let rec = install();
        let (_, on) = run_compiled_chains_method(&model, method, chains, 6, &o).unwrap();
        let reg = rec.registry().expect("installed recorder has a registry");
        let draws = reg.counter(Counter::Draws);
        let leapfrogs = reg.counter(Counter::Leapfrogs);
        uninstall();
        assert_chains_bitwise_equal(&off, &on, &format!("{method:?} x{chains} on-vs-off"));
        assert!(
            draws >= (chains * (warmup + samples)) as u64,
            "{method:?} x{chains}: recorder saw only {draws} draws"
        );
        assert!(leapfrogs > 0, "{method:?} x{chains}: no leapfrogs recorded");
    }
}

/// Native SVI (scalar and batched particle backends) and subsampled
/// minibatch SVI are bitwise identical with the recorder on vs off;
/// the enabled runs record steps, epochs and streamed rows.
#[test]
fn recorder_is_bitwise_neutral_for_svi_and_subsampled_svi() {
    let _g = obs_lock();
    let (n, d) = (96, 4);
    let dset = make_covtype_like(42, n, d);
    let full = fugue::compile::zoo::LogisticModel {
        x: dset.x.clone(),
        y: dset.y.clone(),
        n,
        d,
    };
    let sub = SubsampledLogistic::new(InMemoryRows::new(dset.x, dset.y, n, d), 16);

    for (particles, vectorize) in [(4usize, true), (2, false)] {
        let opts = svi_opts(40, particles, vectorize, 9);

        uninstall();
        let (_, full_off) = run_svi_native(&full, &opts).unwrap();
        let (_, sub_off) = run_svi_subsampled(&sub, &opts).unwrap();

        let rec = install();
        let (_, full_on) = run_svi_native(&full, &opts).unwrap();
        let (_, sub_on) = run_svi_subsampled(&sub, &opts).unwrap();
        let reg = rec.registry().unwrap();
        let steps = reg.counter(Counter::SviSteps);
        let epochs = reg.counter(Counter::Epochs);
        let rows = reg.counter(Counter::RowsStreamed);
        uninstall();

        let tag = format!("particles={particles} vectorize={vectorize}");
        assert_svi_bitwise_equal(&full_off, &full_on, &format!("full-batch SVI {tag}"));
        assert_svi_bitwise_equal(&sub_off, &sub_on, &format!("subsampled SVI {tag}"));
        assert!(steps >= 40, "{tag}: recorder saw only {steps} SVI steps");
        assert!(epochs > 0, "{tag}: no minibatch epochs recorded");
        assert!(rows >= 40 * 16, "{tag}: only {rows} streamed rows recorded");
        assert!(full_on.elbo_mcse.is_finite() && full_on.elbo_mcse >= 0.0);
    }
}

/// The recorder stays neutral across an automated kill-and-resume
/// checkpoint cycle, and a snapshot written after every slice is
/// atomic: the final file parses and no `.tmp` is ever left behind.
#[test]
fn recorder_survives_kill_and_resume_with_atomic_snapshots() {
    let _g = obs_lock();
    let model = EightSchools::classic();
    let o = nuts(60, 80, 57);

    uninstall();
    let (_, plain) =
        run_compiled_chains_method(&model, ChainMethod::Sequential, 2, 6, &o).unwrap();

    let ck = tmp_path("kill_ck");
    let snap = tmp_path("kill_snap");
    let _ = std::fs::remove_file(&ck);
    let cfg = CheckpointConfig {
        path: Some(ck.clone()),
        resume: true,
        every: 7,
        max_seconds: Some(0.02),
    };
    let rec = install();
    let reg = rec.registry().unwrap();
    let mut slices = 0u32;
    let resumed = loop {
        let (_, results, completed) =
            run_compiled_chains_checkpointed(&model, ChainMethod::Sequential, 2, 6, &o, &cfg)
                .unwrap();
        write_snapshot(reg, &snap).unwrap();
        assert!(
            !snap.with_extension("json.tmp").exists() && !snap.with_extension("tmp").exists(),
            "snapshot tmp file left behind after slice {slices}"
        );
        slices += 1;
        assert!(slices < 10_000, "budgeted runner made no progress");
        if completed {
            break results;
        }
    };
    let checkpoint_writes = reg.counter(Counter::CheckpointWrites);
    let snapshot_writes = reg.counter(Counter::SnapshotWrites);
    uninstall();

    assert_chains_bitwise_equal(&plain, &resumed, "kill-and-resume with recorder on");
    assert!(checkpoint_writes > 0, "no checkpoint writes recorded");
    assert_eq!(snapshot_writes, slices as u64, "one snapshot per slice");

    let parsed = Json::parse(&std::fs::read_to_string(&snap).unwrap()).unwrap();
    assert_eq!(parsed.get("schema").and_then(Json::as_str), Some(SNAPSHOT_SCHEMA));
    let _ = std::fs::remove_file(&ck);
    let _ = std::fs::remove_file(&snap);
}

/// With nothing installed, the global recorder is disabled and every
/// recording call is a silent no-op.
#[test]
fn global_recorder_defaults_off_and_off_calls_are_inert() {
    let _g = obs_lock();
    uninstall();
    let rec = Recorder::global();
    assert!(!rec.enabled());
    assert!(rec.registry().is_none());
    rec.incr(Counter::Draws);
    rec.add(Counter::Leapfrogs, 100);
    rec.set_gauge(Gauge::StepSize, 0.5);
    rec.set_phase(Phase::Sampling);
    rec.record_draw(0.9, 3, 7, false, false);
    drop(rec.span(SpanKind::Draw));
    let off = Recorder::OFF;
    assert!(!off.enabled());
}

// ---------------------------------------------------------------------
// 3. exporters
// ---------------------------------------------------------------------

/// Every JSONL event line round-trips through the crate's own JSON
/// parser with its field types intact; non-finite floats serialize as
/// null rather than breaking the stream.
#[test]
fn trace_writer_jsonl_round_trips_through_json_parser() {
    let path = tmp_path("trace").with_extension("jsonl");
    let tw = TraceWriter::create(&path).unwrap();
    tw.event("run_start", &[("subcommand", Val::S("sample-model".to_string()))]).unwrap();
    tw.event(
        "phase",
        &[
            ("phase", Val::S("warmup".to_string())),
            ("draws", Val::U(123)),
            ("step_size", Val::F(0.375)),
            ("nan_field", Val::F(f64::NAN)),
        ],
    )
    .unwrap();
    tw.event("run_end", &[("ok", Val::B(true))]).unwrap();
    drop(tw);

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3, "one JSON object per event line");
    let events: Vec<Json> = lines.iter().map(|l| Json::parse(l).unwrap()).collect();

    let names: Vec<&str> =
        events.iter().map(|e| e.get("event").and_then(Json::as_str).unwrap()).collect();
    assert_eq!(names, ["run_start", "phase", "run_end"]);
    for e in &events {
        let ts = e.get("ts_ms").and_then(Json::as_f64).expect("every event has ts_ms");
        assert!(ts >= 0.0);
    }
    let phase = &events[1];
    assert_eq!(phase.get("phase").and_then(Json::as_str), Some("warmup"));
    assert_eq!(phase.get("draws").and_then(Json::as_usize), Some(123));
    assert_eq!(phase.get("step_size").and_then(Json::as_f64), Some(0.375));
    assert!(matches!(phase.get("nan_field"), Some(Json::Null)), "NaN must serialize as null");
    assert_eq!(events[2].get("ok").and_then(Json::as_bool), Some(true));
    let _ = std::fs::remove_file(&path);
}

/// The snapshot JSON exposes the full registry — schema tag, counters,
/// gauges, depth histogram, spans, trajectories — with values matching
/// what was recorded, using only a private registry (no global state).
#[test]
fn snapshot_json_reflects_recorded_state() {
    let reg = MetricsRegistry::leak();
    let rec = Recorder::new(reg);
    rec.set_phase(Phase::Sampling);
    for _ in 0..5 {
        rec.record_draw(0.8, 3, 7, false, false);
    }
    rec.record_draw(0.1, 2, 3, true, false);
    rec.record_step_size(0.25);
    rec.record_elbo(-12.5);
    rec.add_span_nanos(SpanKind::Warmup, 2_000_000);

    let j = snapshot_json(reg);
    assert_eq!(j.get("schema").and_then(Json::as_str), Some(SNAPSHOT_SCHEMA));
    assert_eq!(j.get("phase").and_then(Json::as_str), Some("sampling"));
    let counters = j.get("counters").unwrap();
    assert_eq!(counters.get("draws").and_then(Json::as_usize), Some(6));
    assert_eq!(counters.get("leapfrogs").and_then(Json::as_usize), Some(5 * 7 + 3));
    assert_eq!(counters.get("divergences").and_then(Json::as_usize), Some(1));
    let gauges = j.get("gauges").unwrap();
    assert_eq!(gauges.get("step_size").and_then(Json::as_f64), Some(0.25));
    assert_eq!(gauges.get("elbo").and_then(Json::as_f64), Some(-12.5));
    let hist = j.get("tree_depth_hist").and_then(Json::as_arr).unwrap();
    assert_eq!(hist[3].as_usize(), Some(5));
    assert_eq!(hist[2].as_usize(), Some(1));
    let warm = j.get("spans").and_then(|s| s.get("warmup")).unwrap();
    assert_eq!(warm.get("ms").and_then(Json::as_f64), Some(2.0));
    assert_eq!(warm.get("count").and_then(Json::as_usize), Some(1));

    // the registry also feeds the single-line progress report
    let line = progress_line(reg);
    assert!(line.contains("draws"), "progress line should mention draws: {line}");
}

// ---------------------------------------------------------------------
// 4. ELBO Monte-Carlo standard error
// ---------------------------------------------------------------------

#[test]
fn elbo_mcse_matches_hand_computation_and_degenerate_cases() {
    // degenerate traces: no noise estimate to report
    assert_eq!(elbo_mcse(&[], 10), 0.0);
    assert_eq!(elbo_mcse(&[1.0], 10), 0.0);
    assert_eq!(elbo_mcse(&[5.0; 100], 1), 0.0);
    // constant trace: zero variance exactly
    assert_eq!(elbo_mcse(&[3.0; 50], 20), 0.0);
    // hand computation over the final window of 4: values 1,2,3,4 have
    // sample variance 5/3, so MC-SE = sqrt(5/3/4)
    let trace = [99.0, -4.0, 1.0, 2.0, 3.0, 4.0];
    let expect = (5.0 / 3.0 / 4.0_f64).sqrt();
    assert!((elbo_mcse(&trace, 4) - expect).abs() < 1e-15);
    // window longer than the trace clamps to the whole trace
    let whole = elbo_mcse(&trace, 100);
    assert!(whole.is_finite() && whole > 0.0);
}
