//! Frozen-program property suite: for every `compile/zoo.rs` model,
//! the record-once / replay-many fast path must be **bitwise
//! indistinguishable** from the tape-interpreter path — potential
//! values and *all* input adjoints — at 100 random points, for the
//! scalar compiler and for the batched compiler at K ∈ {1, 4} lanes.
//!
//! Comparisons use `f64::to_bits` so non-finite excursions (overflowed
//! scales far in the tails) must match bit-for-bit too, not just
//! compare-equal.

use fugue::compile::zoo::{EightSchools, Horseshoe, LogisticModel, NormalMean};
use fugue::compile::{compile, compile_batched, EffModel};
use fugue::data;
use fugue::mcmc::{BatchPotential, Potential};
use fugue::rng::Rng;

const POINTS: usize = 100;

/// Scalar: a frozen-path model and a replay-only model must agree
/// bitwise at every point.
fn check_scalar<M: EffModel + Clone>(model: M, seed: u64) {
    let mut frozen = compile(model.clone(), 0).unwrap();
    let mut replay = compile(model, 0).unwrap();
    replay.set_frozen(false);
    let dim = frozen.dim();
    let mut rng = Rng::new(seed);
    let mut gf = vec![0.0; dim];
    let mut gr = vec![0.0; dim];
    let mut z = vec![0.0; dim];
    for it in 0..POINTS {
        for v in z.iter_mut() {
            *v = 0.8 * rng.normal();
        }
        let uf = frozen.value_and_grad(&z, &mut gf);
        let ur = replay.value_and_grad(&z, &mut gr);
        assert_eq!(uf.to_bits(), ur.to_bits(), "point {it}: U {uf} vs {ur}");
        for i in 0..dim {
            assert_eq!(
                gf[i].to_bits(),
                gr[i].to_bits(),
                "point {it}: grad[{i}] {} vs {}",
                gf[i],
                gr[i]
            );
        }
    }
    assert!(frozen.is_frozen(), "frozen model never recorded a program");
}

/// Batched: per lane count, frozen vs replay-only batched models must
/// agree bitwise (every lane's value and every input adjoint).
fn check_batched<M: EffModel + Clone>(model: M, lanes: usize, seed: u64) {
    let mut frozen = compile_batched(model.clone(), 0, lanes).unwrap();
    let mut replay = compile_batched(model, 0, lanes).unwrap();
    replay.set_frozen(false);
    let dim = frozen.dim();
    let mut rng = Rng::new(seed);
    let mut uf = vec![0.0; lanes];
    let mut ur = vec![0.0; lanes];
    let mut gf = vec![0.0; dim * lanes];
    let mut gr = vec![0.0; dim * lanes];
    let mut z = vec![0.0; dim * lanes];
    for it in 0..POINTS {
        for v in z.iter_mut() {
            *v = 0.8 * rng.normal();
        }
        frozen.value_and_grad_batch(&z, &mut uf, &mut gf);
        replay.value_and_grad_batch(&z, &mut ur, &mut gr);
        for k in 0..lanes {
            assert_eq!(
                uf[k].to_bits(),
                ur[k].to_bits(),
                "point {it}: lane {k} U {} vs {}",
                uf[k],
                ur[k]
            );
        }
        for i in 0..dim * lanes {
            assert_eq!(
                gf[i].to_bits(),
                gr[i].to_bits(),
                "point {it}: grad[{i}] {} vs {}",
                gf[i],
                gr[i]
            );
        }
    }
    assert!(frozen.is_frozen(), "frozen model never recorded a program");
}

fn check_model<M: EffModel + Clone>(model: M, seed: u64) {
    check_scalar(model.clone(), seed);
    for (j, &lanes) in [1usize, 4].iter().enumerate() {
        check_batched(model.clone(), lanes, seed ^ (0xB0 + j as u64));
    }
}

#[test]
fn eight_schools_frozen_equals_replay() {
    check_model(EightSchools::classic(), 101);
}

#[test]
fn horseshoe_frozen_equals_replay() {
    check_model(Horseshoe::synthetic(4, 25, 4, 2), 102);
}

#[test]
fn logistic_frozen_equals_replay() {
    let d = data::make_covtype_like(5, 50, 4);
    check_model(
        LogisticModel {
            x: d.x,
            y: d.y,
            n: 50,
            d: 4,
        },
        103,
    );
}

#[test]
fn normal_mean_frozen_equals_replay() {
    check_model(
        NormalMean {
            y: vec![0.4, -0.9, 1.3, 0.7],
            sigma: 1.5,
        },
        104,
    );
}
