//! The massive-lane test layer: proof that every lane of the tiled
//! K-lane engine is *exactly* a scalar chain.
//!
//! Three kinds of evidence, mirroring the contract chain
//! `scalar Tape == BatchTape == BatchTapeProgram == TiledBatchPotential`:
//!
//! 1. **Property tests** (shrink-free driver in `fugue::util::prop`):
//!    for random models, seeds, K ∈ {1..1024}, tile widths and thread
//!    counts, tiled gradient evaluations and full NUTS transitions are
//!    bitwise-equal to the untiled `BatchTape` engine and to scalar
//!    `draw_in_workspace` replays of sampled lanes.
//! 2. **Exhaustive tile widths** at fixed K: every width 1..=K gives
//!    bitwise-identical evaluations (including ragged remainder tiles).
//! 3. **Statistics at scale**: 1024 short eight-schools chains through
//!    the tiled vectorized engine match a long-chain sequential
//!    reference within Monte-Carlo standard error, with sane
//!    cross-chain split-R̂ — the many-short-chains regime the massive
//!    lane engine exists for.

use fugue::compile::zoo::{EightSchools, LogisticModel, NormalMean};
use fugue::compile::{compile, compile_batched, compile_tiled, EffModel};
use fugue::coordinator::{
    run_chains, run_compiled_chains_method, ChainMethod, NativeSampler, NutsOptions,
    TreeAlgorithm, TILED_LANE_THRESHOLD,
};
use fugue::diagnostics::summary::{max_cross_chain_rhat, summarize};
use fugue::mcmc::batch_nuts::draw_batch;
use fugue::mcmc::nuts_iterative::{draw_in_workspace, TreeWorkspace};
use fugue::mcmc::{
    auto_tile_width, BatchPotential, BatchTreeWorkspace, DrawStats, Potential,
    TiledBatchPotential,
};
use fugue::rng::Rng;
use fugue::util::prop::check;

fn zero_stats(lanes: usize) -> Vec<DrawStats> {
    vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
            poisoned: false,
        };
        lanes
    ]
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One property case: build tiled + untiled engines for `model` at a
/// random K/tile/threads, compare a gradient evaluation and a chained
/// pair of NUTS transitions bitwise, then replay sampled lanes through
/// the scalar engine and require bitwise equality there too.
fn tiled_case<M: EffModel + Clone + Send>(
    model: &M,
    rng: &mut Rng,
    max_k: usize,
    eps: f64,
    depth: u32,
) -> Result<(), String> {
    let seed = rng.next_u64();
    let k = 1 + rng.next_u64() as usize % max_k;
    let tile = 1 + rng.next_u64() as usize % k;
    let threads = [1usize, 2, 4][rng.next_u64() as usize % 3];

    let mut tiled = compile_tiled(model.clone(), seed, k, tile)
        .map_err(|e| format!("compile_tiled: {e}"))?
        .with_threads(threads);
    let mut wide =
        compile_batched(model.clone(), seed, k).map_err(|e| format!("compile_batched: {e}"))?;
    let dim = tiled.dim();
    let label = format!("K={k} tile={tile} threads={threads} dim={dim}");

    // gradient evaluation, bitwise
    let z0: Vec<f64> = (0..dim * k).map(|_| 0.3 * rng.normal()).collect();
    let mut u_t = vec![0.0; k];
    let mut g_t = vec![0.0; dim * k];
    let mut u_w = vec![0.0; k];
    let mut g_w = vec![0.0; dim * k];
    tiled.value_and_grad_batch(&z0, &mut u_t, &mut g_t);
    wide.value_and_grad_batch(&z0, &mut u_w, &mut g_w);
    if !bits_eq(&u_t, &u_w) {
        return Err(format!("{label}: tiled U diverged from untiled"));
    }
    if !bits_eq(&g_t, &g_w) {
        return Err(format!("{label}: tiled grad diverged from untiled"));
    }

    // two chained NUTS transitions, bitwise (proposals + statistics)
    let inv_mass = vec![1.0; dim * k];
    let step_szs = vec![eps; k];
    let mut ws_t = BatchTreeWorkspace::new(dim, k, depth);
    let mut ws_w = BatchTreeWorkspace::new(dim, k, depth);
    let mut st_t = zero_stats(k);
    let mut st_w = zero_stats(k);
    let mut rngs_t: Vec<Rng> = (0..k).map(|j| Rng::new(seed ^ (j as u64 + 1))).collect();
    let mut rngs_w: Vec<Rng> = (0..k).map(|j| Rng::new(seed ^ (j as u64 + 1))).collect();
    let mut z_t = z0.clone();
    let mut z_w = z0.clone();
    for draw in 0..2 {
        draw_batch(
            &mut tiled, &mut rngs_t, &mut ws_t, &z_t, &step_szs, &inv_mass, depth, &mut st_t,
        );
        draw_batch(
            &mut wide, &mut rngs_w, &mut ws_w, &z_w, &step_szs, &inv_mass, depth, &mut st_w,
        );
        if !bits_eq(ws_t.proposal(), ws_w.proposal()) {
            return Err(format!("{label}: draw {draw} proposals diverged"));
        }
        for j in 0..k {
            let (a, b) = (&st_t[j], &st_w[j]);
            if a.accept_prob.to_bits() != b.accept_prob.to_bits()
                || a.num_leapfrog != b.num_leapfrog
                || a.potential.to_bits() != b.potential.to_bits()
                || a.diverging != b.diverging
                || a.depth != b.depth
            {
                return Err(format!("{label}: draw {draw} lane {j} stats diverged"));
            }
        }
        z_t.copy_from_slice(ws_t.proposal());
        z_w.copy_from_slice(ws_w.proposal());
    }

    // scalar replays of sampled lanes: lane j of the tiled engine IS a
    // sequential chain
    let lanes_to_check: Vec<usize> = if k <= 3 {
        (0..k).collect()
    } else {
        vec![0, rng.next_u64() as usize % k, k - 1]
    };
    for &j in &lanes_to_check {
        let mut pot =
            compile(model.clone(), seed).map_err(|e| format!("scalar compile: {e}"))?;
        let mut srng = Rng::new(seed ^ (j as u64 + 1));
        let mut sws = TreeWorkspace::new(dim, depth);
        let mut z_lane: Vec<f64> = (0..dim).map(|i| z0[i * k + j]).collect();
        let inv_lane = vec![1.0; dim];
        let mut zrow = vec![0.0; dim];
        let mut rngs: Vec<Rng> = (0..k).map(|jj| Rng::new(seed ^ (jj as u64 + 1))).collect();
        let mut z = z0.clone();
        let mut st = zero_stats(k);
        for draw in 0..2 {
            draw_batch(
                &mut tiled, &mut rngs, &mut ws_t, &z, &step_szs, &inv_mass, depth, &mut st,
            );
            let sstat = draw_in_workspace(
                &mut pot, &mut srng, &mut sws, &z_lane, eps, &inv_lane, depth,
            );
            z_lane.copy_from_slice(sws.proposal());
            ws_t.proposal_lane(j, &mut zrow);
            if !bits_eq(&zrow, &z_lane) {
                return Err(format!("{label}: lane {j} draw {draw} != scalar replay"));
            }
            if st[j].num_leapfrog != sstat.num_leapfrog
                || st[j].accept_prob.to_bits() != sstat.accept_prob.to_bits()
            {
                return Err(format!("{label}: lane {j} draw {draw} stats != scalar"));
            }
            z.copy_from_slice(ws_t.proposal());
        }
    }
    Ok(())
}

#[test]
fn prop_tiled_is_bitwise_scalar_normal_mean() {
    check("tiled == untiled == scalar (normal-mean, K up to 1024)", 6, |rng| {
        let model = NormalMean {
            y: (0..4).map(|_| rng.normal()).collect(),
            sigma: 1.0 + rng.uniform(),
        };
        tiled_case(&model, rng, 1024, 0.2, 4)
    });
}

#[test]
fn prop_tiled_is_bitwise_scalar_eight_schools() {
    check("tiled == untiled == scalar (eight-schools, K up to 256)", 4, |rng| {
        tiled_case(&EightSchools::classic(), rng, 256, 0.1, 4)
    });
}

#[test]
fn prop_tiled_is_bitwise_scalar_logistic() {
    check("tiled == untiled == scalar (logistic, K up to 64)", 3, |rng| {
        let (n, d) = (24, 3);
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            for _ in 0..d {
                x.push(rng.normal());
            }
            y.push(if rng.uniform() < 0.5 { 0.0 } else { 1.0 });
        }
        let model = LogisticModel { x, y, n, d };
        tiled_case(&model, rng, 64, 0.05, 4)
    });
}

/// Every tile width 1..=K (ragged remainders included) evaluates
/// bitwise-identically to the untiled program at that K.
#[test]
fn all_tile_widths_are_bitwise_equal() {
    for k in [29usize, 64] {
        let model = NormalMean {
            y: vec![0.7, -1.1, 0.4],
            sigma: 1.3,
        };
        let mut wide = compile_batched(model.clone(), 11, k).unwrap();
        let dim = wide.dim();
        let mut rng = Rng::new(0xC0FFEE ^ k as u64);
        let z: Vec<f64> = (0..dim * k).map(|_| rng.normal()).collect();
        let mut u_ref = vec![0.0; k];
        let mut g_ref = vec![0.0; dim * k];
        wide.value_and_grad_batch(&z, &mut u_ref, &mut g_ref);
        for tile in 1..=k {
            let mut tiled = compile_tiled(model.clone(), 11, k, tile)
                .unwrap()
                .with_threads(if tile % 2 == 0 { 2 } else { 1 });
            let mut u = vec![0.0; k];
            let mut g = vec![0.0; dim * k];
            tiled.value_and_grad_batch(&z, &mut u, &mut g);
            assert!(bits_eq(&u, &u_ref), "U diverged at K={k} tile={tile}");
            assert!(bits_eq(&g, &g_ref), "grad diverged at K={k} tile={tile}");
        }
    }
}

/// The coordinator's lane-sharded regime (K past TILED_LANE_THRESHOLD
/// rides the tiled engine) stays bitwise-identical to the sequential
/// method — the threshold is an execution-strategy switch only.
#[test]
fn coordinator_tiled_regime_matches_sequential_bitwise() {
    let chains = TILED_LANE_THRESHOLD + 4;
    let model = NormalMean {
        y: vec![1.0, 2.0, 3.0],
        sigma: 2.0,
    };
    let opts = NutsOptions {
        num_warmup: 40,
        num_samples: 10,
        seed: 31,
        ..Default::default()
    };
    let (_, seq) =
        run_compiled_chains_method(&model, ChainMethod::Sequential, chains, 8, &opts).unwrap();
    let (_, vec_res) =
        run_compiled_chains_method(&model, ChainMethod::Vectorized, chains, 8, &opts).unwrap();
    assert_eq!(seq.len(), chains);
    assert_eq!(vec_res.len(), chains);
    for (k, (s, v)) in seq.iter().zip(&vec_res).enumerate() {
        assert!(bits_eq(&s.samples, &v.samples), "chain {k} samples diverged");
        assert_eq!(
            s.step_size.to_bits(),
            v.step_size.to_bits(),
            "chain {k} step size diverged"
        );
        assert_eq!(s.divergences, v.divergences, "chain {k} divergences");
    }
}

/// Many-short-chains statistics: 1024 tiled eight-schools chains x 8
/// kept draws match a long-chain sequential reference within
/// Monte-Carlo standard error, and the 1024-chain split-R̂ is sane.
#[test]
fn thousand_short_chains_match_long_reference_within_mcse() {
    let model = EightSchools::classic();

    // long-chain reference: 2 sequential chains, generous warmup
    let ref_opts = NutsOptions {
        num_warmup: 300,
        num_samples: 1200,
        seed: 7,
        ..Default::default()
    };
    let mut sampler = NativeSampler::new(
        compile(model.clone(), ref_opts.seed).unwrap(),
        TreeAlgorithm::Iterative,
        10,
    );
    let reference = run_chains(&mut sampler, 2, &ref_opts).unwrap();
    let ref_pooled: Vec<Vec<f64>> = reference.iter().map(|r| r.samples.clone()).collect();
    let dim = compile(model.clone(), 7).unwrap().dim();
    let ref_rows = summarize(&ref_pooled, dim, &[]);

    // 1024 short chains through the tiled massive-lane engine
    let k = 1024usize;
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let tile = auto_tile_width(k, threads);
    let mut tiled: TiledBatchPotential<_> = compile_tiled(model, 7, k, tile).unwrap();
    assert_eq!(tiled.lanes(), k);
    let short_opts = NutsOptions {
        num_warmup: 150,
        num_samples: 8,
        seed: 7,
        ..Default::default()
    };
    let results =
        fugue::coordinator::run_chains_vectorized(&mut tiled, &short_opts, 10).unwrap();
    assert_eq!(results.len(), k);
    let pooled: Vec<Vec<f64>> = results.iter().map(|r| r.samples.clone()).collect();
    let batch_rows = summarize(&pooled, dim, &[]);

    // pooled means agree within combined MCSE (6 sigma + slack)
    let n_batch = (k * 8) as f64;
    for d in 0..dim {
        let mcse_ref = ref_rows[d].sd / ref_rows[d].ess.max(4.0).sqrt();
        // conservative batch MCSE: treat only every 4th pooled draw as
        // independent
        let mcse_batch = batch_rows[d].sd / (n_batch / 4.0).sqrt();
        let tol = 6.0 * (mcse_ref + mcse_batch) + 0.05;
        let diff = (batch_rows[d].mean - ref_rows[d].mean).abs();
        assert!(
            diff <= tol,
            "coordinate {d}: |{} - {}| = {diff} > {tol}",
            batch_rows[d].mean,
            ref_rows[d].mean
        );
    }

    // cross-chain split-R-hat over all 1024 chains stays sane
    let rhat = max_cross_chain_rhat(&pooled, dim);
    assert!(rhat.is_finite() && rhat < 1.25, "split-Rhat {rhat} not sane");

    // and the run actually exercised lane-sharded tiling
    assert!(tiled.num_tiles() > 1, "expected more than one tile at K=1024");
}
