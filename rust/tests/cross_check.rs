//! Cross-language agreement: the native Rust (autodiff) potentials and
//! the AOT-compiled (JAX/minippl) potentials are the SAME density —
//! values and gradients agree at random unconstrained points, on the
//! same data.  This pins the whole reproduction together: Table 2a's
//! backends differ only in architecture, never in math.
//!
//! Requires `make artifacts` (skips gracefully when absent) and the
//! `pjrt` feature (the default build substitutes stub handles that
//! cannot evaluate artifacts).
#![cfg(feature = "pjrt")]

use fugue::harness::builders::Workload;
use fugue::rng::Rng;
use fugue::runtime::engine::Engine;
use fugue::runtime::PjrtPotential;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn check_model(engine: &Engine, model: &str, tol_val: f64, tol_grad: f64) {
    let name = format!("{model}_potential_and_grad_f64");
    let Ok(entry) = engine.manifest.get(&name) else {
        eprintln!("skipping {model}: no f64 artifact");
        return;
    };
    let dim = entry.dim;
    let workload = Workload::for_model(engine, model, 20191222).expect("workload");
    let dt = entry.inputs[0].dtype;
    let mut pjrt =
        PjrtPotential::new(engine, &name, &workload.tensors(dt).unwrap()).expect("pjrt potential");
    let mut native = workload.native_potential().expect("native potential");
    assert_eq!(native.dim(), dim, "{model}: dim mismatch");

    let mut rng = Rng::new(7);
    for case in 0..5 {
        let z: Vec<f64> = (0..dim).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
        let mut g_pjrt = vec![0.0; dim];
        let mut g_native = vec![0.0; dim];
        let u_pjrt = pjrt.eval(&z, &mut g_pjrt).expect("pjrt eval");
        let u_native = native.value_and_grad(&z, &mut g_native);
        let vdiff = (u_pjrt - u_native).abs() / (1.0 + u_native.abs());
        assert!(
            vdiff < tol_val,
            "{model} case {case}: potential {u_native} (native) vs {u_pjrt} (pjrt)"
        );
        for i in 0..dim {
            let gdiff = (g_pjrt[i] - g_native[i]).abs() / (1.0 + g_native[i].abs());
            assert!(
                gdiff < tol_grad,
                "{model} case {case}: grad[{i}] {} (native) vs {} (pjrt)",
                g_native[i],
                g_pjrt[i]
            );
        }
    }
}

#[test]
fn logistic_potentials_agree() {
    let Some(engine) = engine() else { return };
    check_model(&engine, "covtype_small", 1e-8, 1e-6);
}

#[test]
fn hmm_potentials_agree() {
    let Some(engine) = engine() else { return };
    check_model(&engine, "hmm", 1e-8, 1e-6);
}

#[test]
fn skim_potentials_agree() {
    let Some(engine) = engine() else { return };
    check_model(&engine, "skim_p25", 1e-6, 1e-4);
}

#[test]
fn fused_step_advances_from_native_point() {
    // The fused artifact and native sampler explore the same surface:
    // starting from the same z, a fused draw lands at finite potential
    // that the native potential reproduces.
    let Some(engine) = engine() else { return };
    let model = "hmm";
    let workload = Workload::for_model(&engine, model, 20191222).unwrap();
    let entry = engine.manifest.find(model, "nuts_step", "f64").unwrap();
    let dt = entry.inputs[1].dtype;
    let mut step = fugue::runtime::NutsStep::new(
        &engine,
        &format!("{model}_nuts_step_f64"),
        &workload.tensors(dt).unwrap(),
    )
    .unwrap();
    let dim = entry.dim;
    let z0 = vec![0.1; dim];
    let tr = step.step([3, 4], &z0, 0.05, &vec![1.0; dim]).unwrap();
    assert!(tr.num_leapfrog > 0);
    let mut native = workload.native_potential().unwrap();
    let mut g = vec![0.0; dim];
    let u_native = native.value_and_grad(&tr.z, &mut g);
    assert!(
        (u_native - tr.potential).abs() / (1.0 + u_native.abs()) < 1e-8,
        "fused landed at U={} but native says {}",
        tr.potential,
        u_native
    );
}
