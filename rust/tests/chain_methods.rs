//! Golden cross-method tests for the three chain execution strategies
//! (`Sequential` / `Parallel` / `Vectorized`): the execution strategy
//! must be statistically — and, with shared RNG streams, **bitwise** —
//! invisible.
//!
//! Two layers of evidence on the eight-schools and logistic zoo models:
//!
//! 1. **Bitwise**: all three methods derive chain `k`'s seed and init
//!    from the shared `chain_start`, so with identical options every
//!    per-chain sample trajectory, adapted step size, mass matrix and
//!    divergence count must agree exactly.
//! 2. **Statistical**: runs seeded *differently* must still estimate
//!    the same posterior — per-parameter means agree within a few
//!    Monte-Carlo standard errors (MCSE = sd / sqrt(ESS)).

use fugue::compile::zoo::{EightSchools, LogisticModel};
use fugue::compile::EffModel;
use fugue::coordinator::{run_compiled_chains_method, ChainMethod, ChainResult, NutsOptions};
use fugue::data;
use fugue::diagnostics::effective_sample_size;

fn run<M: EffModel + Clone + Sync>(
    model: &M,
    method: ChainMethod,
    chains: usize,
    opts: &NutsOptions,
) -> Vec<ChainResult> {
    let (_, results) = run_compiled_chains_method(model, method, chains, 10, opts).unwrap();
    results
}

fn assert_bitwise_equal(a: &[ChainResult], b: &[ChainResult], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: chain count");
    for (c, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.samples, y.samples, "{label}: chain {c} samples");
        assert_eq!(x.step_size, y.step_size, "{label}: chain {c} step size");
        assert_eq!(x.inv_mass, y.inv_mass, "{label}: chain {c} mass matrix");
        assert_eq!(x.divergences, y.divergences, "{label}: chain {c} divergences");
        assert_eq!(
            x.stats.accept_prob, y.stats.accept_prob,
            "{label}: chain {c} accept stats"
        );
        assert_eq!(
            x.total_leapfrogs, y.total_leapfrogs,
            "{label}: chain {c} leapfrogs"
        );
    }
}

/// Per-parameter draws of one parameter across chains.
fn param_chains(results: &[ChainResult], dim: usize, d: usize) -> Vec<Vec<f64>> {
    results
        .iter()
        .map(|r| r.samples.chunks(dim).map(|row| row[d]).collect())
        .collect()
}

/// Pooled mean and MCSE (sd / sqrt(ESS)) of one parameter.
fn mean_and_mcse(results: &[ChainResult], dim: usize, d: usize) -> (f64, f64) {
    let chains = param_chains(results, dim, d);
    let all: Vec<f64> = chains.iter().flatten().copied().collect();
    let n = all.len() as f64;
    let mean = all.iter().sum::<f64>() / n;
    let var = all.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    let ess = effective_sample_size(&chains).max(4.0);
    (mean, (var / ess).sqrt())
}

fn assert_posteriors_agree(
    a: &[ChainResult],
    b: &[ChainResult],
    dim: usize,
    label: &str,
) {
    for d in 0..dim {
        let (ma, sa) = mean_and_mcse(a, dim, d);
        let (mb, sb) = mean_and_mcse(b, dim, d);
        let tol = 6.0 * (sa * sa + sb * sb).sqrt() + 1e-3;
        assert!(
            (ma - mb).abs() < tol,
            "{label}: param {d} means {ma:.4} vs {mb:.4} differ beyond {tol:.4} \
             (MCSE {sa:.4} / {sb:.4})"
        );
    }
}

fn eight_schools_opts(seed: u64) -> NutsOptions {
    NutsOptions {
        num_warmup: 300,
        num_samples: 500,
        seed,
        ..Default::default()
    }
}

fn logistic_model(seed: u64) -> LogisticModel {
    let (n, d) = (120, 3);
    let dset = data::make_covtype_like(seed, n, d);
    LogisticModel {
        x: dset.x,
        y: dset.y,
        n,
        d,
    }
}

fn logistic_opts(seed: u64) -> NutsOptions {
    NutsOptions {
        num_warmup: 200,
        num_samples: 400,
        seed,
        ..Default::default()
    }
}

/// With the same options, every chain method must produce the exact
/// same chains on eight-schools — the vectorized lanes use the same
/// RNG streams as their sequential counterparts, so agreement is
/// bitwise, not just statistical.
#[test]
fn eight_schools_methods_agree_bitwise() {
    let model = EightSchools::classic();
    let opts = eight_schools_opts(42);
    let seq = run(&model, ChainMethod::Sequential, 3, &opts);
    let par = run(&model, ChainMethod::Parallel, 3, &opts);
    let vec_ = run(&model, ChainMethod::Vectorized, 3, &opts);
    assert_bitwise_equal(&seq, &par, "eight-schools seq vs par");
    assert_bitwise_equal(&seq, &vec_, "eight-schools seq vs vec");
}

#[test]
fn logistic_methods_agree_bitwise() {
    let model = logistic_model(7);
    let opts = logistic_opts(11);
    let seq = run(&model, ChainMethod::Sequential, 4, &opts);
    let par = run(&model, ChainMethod::Parallel, 4, &opts);
    let vec_ = run(&model, ChainMethod::Vectorized, 4, &opts);
    assert_bitwise_equal(&seq, &par, "logistic seq vs par");
    assert_bitwise_equal(&seq, &vec_, "logistic seq vs vec");
}

/// Differently-seeded runs across methods must still agree within
/// MCSE — the statistical half of the golden check (the bitwise tests
/// above would pass even if both engines were wrong in the same way;
/// this one ties them to the actual posterior).
#[test]
fn eight_schools_posteriors_agree_within_mcse() {
    let model = EightSchools::classic();
    let dim = 10;
    let seq = run(&model, ChainMethod::Sequential, 4, &eight_schools_opts(1001));
    let vec_ = run(&model, ChainMethod::Vectorized, 4, &eight_schools_opts(2002));
    let par = run(&model, ChainMethod::Parallel, 4, &eight_schools_opts(3003));
    assert_posteriors_agree(&seq, &vec_, dim, "eight-schools seq vs vec");
    assert_posteriors_agree(&seq, &par, dim, "eight-schools seq vs par");
}

#[test]
fn logistic_posteriors_agree_within_mcse() {
    let model = logistic_model(3);
    let dim = 4;
    let seq = run(&model, ChainMethod::Sequential, 4, &logistic_opts(17));
    let vec_ = run(&model, ChainMethod::Vectorized, 4, &logistic_opts(29));
    assert_posteriors_agree(&seq, &vec_, dim, "logistic seq vs vec");
}

/// Chain count 1 must also agree across methods (the vectorized
/// engine with a single lane is just sequential NUTS).
#[test]
fn single_chain_methods_agree_bitwise() {
    let model = EightSchools::classic();
    let opts = NutsOptions {
        num_warmup: 150,
        num_samples: 200,
        seed: 5,
        ..Default::default()
    };
    let seq = run(&model, ChainMethod::Sequential, 1, &opts);
    let vec_ = run(&model, ChainMethod::Vectorized, 1, &opts);
    assert_bitwise_equal(&seq, &vec_, "single-chain seq vs vec");
}
