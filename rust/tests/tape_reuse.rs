//! Tape-reuse regression suite: for every native model, gradients
//! computed on a *reused* tape (after many intervening evaluations at
//! other points) must be bitwise identical to a fresh potential's
//! first evaluation, and must match central finite differences.

use fugue::autodiff::finite_diff;
use fugue::data;
use fugue::mcmc::Potential;
use fugue::models::skim::SkimHypers;
use fugue::models::{HmmNative, LogisticNative, SkimNative};
use fugue::rng::Rng;

fn check_reuse<P, F>(make: F, scale: f64, tol: f64, seed: u64)
where
    P: Potential,
    F: Fn() -> P,
{
    let mut fresh = make();
    let dim = fresh.dim();
    let mut rng = Rng::new(seed);
    let z: Vec<f64> = (0..dim).map(|_| rng.normal() * scale).collect();
    let mut g_ref = vec![0.0; dim];
    let u_ref = fresh.value_and_grad(&z, &mut g_ref);

    // reused potential: pollute the tape at other points first
    let mut reused = make();
    let mut tmp = vec![0.0; dim];
    for k in 0..5 {
        let zk: Vec<f64> = z.iter().map(|v| v + 0.1 * (k as f64 + 1.0)).collect();
        let _ = reused.value_and_grad(&zk, &mut tmp);
    }
    let mut g = vec![0.0; dim];
    let u = reused.value_and_grad(&z, &mut g);
    assert_eq!(u, u_ref, "reused tape changed the value");
    assert_eq!(g, g_ref, "reused tape changed the gradient");

    // and the reused gradient still matches finite differences
    let fd = finite_diff(
        &z,
        |zz| {
            let mut t = vec![0.0; dim];
            reused.value_and_grad(zz, &mut t)
        },
        1e-6,
    );
    for i in 0..dim {
        assert!(
            (g[i] - fd[i]).abs() < tol * (1.0 + fd[i].abs()),
            "grad[{i}] {} vs fd {}",
            g[i],
            fd[i]
        );
    }
}

#[test]
fn logistic_tape_reuse() {
    let d = data::make_covtype_like(11, 80, 5);
    check_reuse(
        move || LogisticNative::new(d.x.clone(), d.y.clone(), 80, 5),
        0.5,
        1e-5,
        1,
    );
}

#[test]
fn hmm_tape_reuse() {
    let d = data::make_hmm(12, 80, 20, 3, 10);
    check_reuse(
        move || HmmNative::new(d.obs.clone(), d.sup_states.clone(), 3, 10),
        0.4,
        1e-4,
        2,
    );
}

#[test]
fn skim_tape_reuse() {
    let d = data::make_skim(13, 25, 6, 2);
    check_reuse(
        move || SkimNative::new(d.x.clone(), d.y.clone(), 25, 6, SkimHypers::default()),
        0.3,
        2e-4,
        3,
    );
}
