//! End-to-end tests for the model compiler: golden cross-check against
//! the hand-fused logistic potential, statistical correctness of
//! compiled-model NUTS (conjugate posterior + eight-schools vs a long
//! reference run), structural-change detection, and parallel/sequential
//! equivalence.

use std::cell::Cell;

use fugue::compile::zoo::{EightSchools, Horseshoe, LogisticModel, NormalMean};
use fugue::compile::{compile, EffModel, ProbCtx, SiteLayout};
use fugue::coordinator::{
    run_chains, run_compiled_chains, ChainResult, NativeSampler, NutsOptions, TreeAlgorithm,
};
use fugue::data;
use fugue::diagnostics::ess::effective_sample_size;
use fugue::mcmc::Potential;
use fugue::models::LogisticNative;
use fugue::rng::Rng;

/// Pooled mean and Monte-Carlo standard error of a *constrained*
/// scalar latent site.
fn posterior_stats(results: &[ChainResult], layout: &SiteLayout, site: &str) -> (f64, f64) {
    let dim = layout.dim;
    let spec = layout.latent(site).expect("latent site");
    let (off, tr) = (spec.offset, spec.transform);
    let per_chain: Vec<Vec<f64>> = results
        .iter()
        .map(|r| r.samples.chunks(dim).map(|row| tr.constrain(row[off])).collect())
        .collect();
    let all: Vec<f64> = per_chain.iter().flatten().copied().collect();
    let n = all.len() as f64;
    let mean = all.iter().sum::<f64>() / n;
    let sd = (all.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0)).sqrt();
    let ess = effective_sample_size(&per_chain).max(10.0);
    (mean, sd / ess.sqrt())
}

/// The compiled logistic-regression program must reproduce the
/// hand-fused `models::logistic` potential — same density, same
/// gradient — to 1e-10 (the only remaining difference is dot-product
/// summation order).
#[test]
fn compiled_logistic_matches_hand_coded_potential() {
    let (n, d) = (200, 8);
    let dset = data::make_covtype_like(11, n, d);
    let mut hand = LogisticNative::new(dset.x.clone(), dset.y.clone(), n, d);
    let mut comp = compile(
        LogisticModel {
            x: dset.x,
            y: dset.y,
            n,
            d,
        },
        0,
    )
    .unwrap();
    assert_eq!(comp.dim(), d + 1);
    assert_eq!(comp.dim(), hand.dim());

    let mut rng = Rng::new(5);
    for trial in 0..5 {
        let z: Vec<f64> = (0..d + 1).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
        let mut gh = vec![0.0; d + 1];
        let mut gc = vec![0.0; d + 1];
        let uh = hand.value_and_grad(&z, &mut gh);
        let uc = comp.value_and_grad(&z, &mut gc);
        assert!(
            (uh - uc).abs() < 1e-10,
            "trial {trial}: value {uh} vs {uc}"
        );
        for i in 0..=d {
            assert!(
                (gh[i] - gc[i]).abs() < 1e-10,
                "trial {trial} grad[{i}]: {} vs {}",
                gh[i],
                gc[i]
            );
        }
    }
}

/// Conjugate Normal-Normal: the compiled model's posterior mean and
/// variance must match the closed form.
#[test]
fn compiled_normal_mean_matches_conjugate_posterior() {
    let y = vec![1.2, 0.8, 1.5, 0.9, 1.1, 1.4];
    let n = y.len() as f64;
    let sum: f64 = y.iter().sum();
    let post_prec = 1.0 + n; // prior N(0,1), sigma = 1
    let post_mean = sum / post_prec;
    let model = NormalMean { y, sigma: 1.0 };
    let opts = NutsOptions {
        num_warmup: 300,
        num_samples: 2000,
        seed: 3,
        ..Default::default()
    };
    let (layout, results) = run_compiled_chains(&model, 2, 10, &opts).unwrap();
    assert_eq!(layout.dim, 1);
    let all: Vec<f64> = results
        .iter()
        .flat_map(|r| r.samples.iter().copied())
        .collect();
    let m = all.iter().sum::<f64>() / all.len() as f64;
    let v = all.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (all.len() as f64 - 1.0);
    assert!((m - post_mean).abs() < 0.05, "mean {m} vs {post_mean}");
    assert!(
        (v - 1.0 / post_prec).abs() < 0.05,
        "var {v} vs {}",
        1.0 / post_prec
    );
}

/// The acceptance gate: a model written only with sample/observe (no
/// hand-written gradient anywhere) sampled end-to-end by iterative
/// NUTS; its posterior mean on eight-schools matches a longer
/// independent reference run within Monte-Carlo standard error.
#[test]
fn eight_schools_end_to_end_matches_long_reference() {
    let model = EightSchools::classic();
    let short_opts = NutsOptions {
        num_warmup: 500,
        num_samples: 1500,
        seed: 7,
        ..Default::default()
    };
    let (layout, short) = run_compiled_chains(&model, 2, 10, &short_opts).unwrap();
    let long_opts = NutsOptions {
        num_warmup: 800,
        num_samples: 6000,
        seed: 1234,
        ..Default::default()
    };
    let (_, long) = run_compiled_chains(&model, 1, 10, &long_opts).unwrap();

    for site in ["mu", "tau"] {
        let (m_short, se_short) = posterior_stats(&short, &layout, site);
        let (m_long, se_long) = posterior_stats(&long, &layout, site);
        let tol = 5.0 * (se_short * se_short + se_long * se_long).sqrt() + 0.3;
        assert!(
            (m_short - m_long).abs() < tol,
            "{site}: short {m_short} vs long {m_long} (tol {tol})"
        );
    }
    // sanity band around the literature values for this prior
    // (mu ~ N(0,5), tau ~ HalfCauchy(5), non-centered)
    let (mu, _) = posterior_stats(&long, &layout, "mu");
    let (tau, _) = posterior_stats(&long, &layout, "tau");
    assert!((1.5..9.0).contains(&mu), "posterior mean mu {mu}");
    assert!((0.5..10.0).contains(&tau), "posterior mean tau {tau}");
    let divergences: u64 = long.iter().map(|r| r.divergences).sum();
    assert!(
        divergences < 300,
        "too many divergences for non-centered eight-schools: {divergences}"
    );
}

/// Horseshoe shrinkage: posterior |beta| on true-signal coordinates
/// must dominate the noise coordinates (beta_j = tau·lambda_j·z_j is
/// reconstructed from the constrained draws).
#[test]
fn horseshoe_separates_signals_from_noise() {
    let (n, p, signals) = (60, 6, 2);
    let model = Horseshoe::synthetic(9, n, p, signals);
    let opts = NutsOptions {
        num_warmup: 400,
        num_samples: 800,
        seed: 17,
        target_accept: 0.9,
        ..Default::default()
    };
    let (layout, results) = run_compiled_chains(&model, 1, 10, &opts).unwrap();
    let dim = layout.dim;
    let lam_off = layout.latent("lambda").unwrap().offset;
    let tau_off = layout.latent("tau").unwrap().offset;
    let z_off = layout.latent("z").unwrap().offset;
    let mut abs_beta = vec![0.0f64; p];
    let mut draws = 0usize;
    for r in &results {
        for row in r.samples.chunks(dim) {
            let tau = row[tau_off].exp();
            for (j, ab) in abs_beta.iter_mut().enumerate() {
                *ab += (tau * row[lam_off + j].exp() * row[z_off + j]).abs();
            }
            draws += 1;
        }
    }
    for ab in abs_beta.iter_mut() {
        *ab /= draws as f64;
    }
    let signal_mean = abs_beta[..signals].iter().sum::<f64>() / signals as f64;
    let noise_mean = abs_beta[signals..].iter().sum::<f64>() / (p - signals) as f64;
    assert!(
        signal_mean > 2.0 * noise_mean,
        "no shrinkage separation: signal {signal_mean} vs noise {noise_mean} ({abs_beta:?})"
    );
    assert!(signal_mean > 0.8, "signal coefficients not recovered: {abs_beta:?}");
}

/// Parallel compiled chains are bitwise identical to a sequential run
/// over the same compiled model.
#[test]
fn compiled_chains_parallel_matches_sequential() {
    let model = NormalMean {
        y: vec![0.2, 1.1, -0.4, 0.9],
        sigma: 1.0,
    };
    let opts = NutsOptions {
        num_warmup: 150,
        num_samples: 300,
        seed: 21,
        ..Default::default()
    };
    let (_, par) = run_compiled_chains(&model, 3, 10, &opts).unwrap();
    let mut sampler = NativeSampler::new(
        compile(model.clone(), opts.seed).unwrap(),
        TreeAlgorithm::Iterative,
        10,
    );
    let seq = run_chains(&mut sampler, 3, &opts).unwrap();
    assert_eq!(par.len(), seq.len());
    for (p, s) in par.iter().zip(&seq) {
        assert_eq!(p.samples, s.samples);
        assert_eq!(p.step_size, s.step_size);
    }
}

/// A program whose site structure depends on evaluation count violates
/// the static-structure contract and must be caught, not silently
/// mis-sampled.
struct Flaky {
    calls: Cell<usize>,
}

impl EffModel for Flaky {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let k = self.calls.get();
        self.calls.set(k + 1);
        let prior = c.normal(0.0, 1.0);
        if k == 0 {
            c.sample("a", prior);
        } else {
            c.sample("b", prior);
        }
    }
}

#[test]
#[should_panic(expected = "static structure")]
fn structure_change_is_detected() {
    let mut pot = compile(
        Flaky {
            calls: Cell::new(0),
        },
        0,
    )
    .unwrap();
    let mut g = vec![0.0];
    let _ = pot.value_and_grad(&[0.1], &mut g);
}
