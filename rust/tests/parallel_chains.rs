//! Integration tests of the parallel multi-chain engine over a real
//! native potential: bitwise agreement with the sequential runner,
//! scheduling independence, and cross-chain split-R̂ of the pooled
//! results.

use fugue::coordinator::{
    run_chains, NativeSampler, NutsOptions, ParallelChainRunner, TreeAlgorithm,
};
use fugue::data;
use fugue::diagnostics::summary::{cross_chain_rhat, max_cross_chain_rhat};
use fugue::models::LogisticNative;

fn make_sampler(seed: u64) -> NativeSampler<LogisticNative> {
    let d = data::make_covtype_like(seed, 200, 4);
    NativeSampler::new(
        LogisticNative::new(d.x, d.y, 200, 4),
        TreeAlgorithm::Iterative,
        10,
    )
}

fn opts() -> NutsOptions {
    NutsOptions {
        num_warmup: 150,
        num_samples: 300,
        seed: 20191222,
        ..Default::default()
    }
}

#[test]
fn parallel_logistic_matches_sequential_bitwise() {
    let par = ParallelChainRunner::new(4)
        .run(|_c| Ok(make_sampler(7)), &opts())
        .unwrap();
    let mut seq_sampler = make_sampler(7);
    let seq = run_chains(&mut seq_sampler, 4, &opts()).unwrap();
    assert_eq!(par.len(), 4);
    for (p, s) in par.iter().zip(&seq) {
        assert_eq!(p.samples, s.samples, "parallel chain diverged from sequential");
        assert_eq!(p.step_size, s.step_size);
        assert_eq!(p.inv_mass, s.inv_mass);
    }
}

#[test]
fn thread_cap_does_not_change_draws() {
    let a = ParallelChainRunner::with_threads(4, 1)
        .run(|_c| Ok(make_sampler(9)), &opts())
        .unwrap();
    let b = ParallelChainRunner::with_threads(4, 4)
        .run(|_c| Ok(make_sampler(9)), &opts())
        .unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.samples, y.samples);
    }
}

#[test]
fn pooled_chains_converge_under_split_rhat() {
    let results = ParallelChainRunner::new(4)
        .run(|_c| Ok(make_sampler(11)), &opts())
        .unwrap();
    let dim = results[0].dim;
    let pooled: Vec<Vec<f64>> = results.iter().map(|r| r.samples.clone()).collect();
    let rhats = cross_chain_rhat(&pooled, dim);
    assert_eq!(rhats.len(), dim);
    let worst = max_cross_chain_rhat(&pooled, dim);
    assert!(
        worst < 1.2,
        "well-conditioned logistic posterior should mix: max split-Rhat {worst} ({rhats:?})"
    );
}

#[test]
fn distinct_chains_explore_distinct_paths() {
    let results = ParallelChainRunner::new(3)
        .run(|_c| Ok(make_sampler(13)), &opts())
        .unwrap();
    assert_ne!(results[0].samples, results[1].samples);
    assert_ne!(results[1].samples, results[2].samples);
}
