//! Zero-allocation regression for the NUTS hot path: once the tape and
//! tree workspace have warmed up, a full draw via
//! `nuts_iterative::draw_in_workspace` over each native potential must
//! perform **zero** heap allocations.
//!
//! Counted with a thread-local tally inside a wrapping global
//! allocator, so the libtest harness threads cannot pollute the
//! measurement.  This file intentionally contains a single #[test].

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use fugue::data;
use fugue::mcmc::nuts_iterative::{draw_in_workspace, TreeWorkspace};
use fugue::mcmc::Potential;
use fugue::models::skim::SkimHypers;
use fugue::models::{HmmNative, LogisticNative, SkimNative};
use fugue::rng::Rng;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates to System; the counter is a plain thread-local Cell
// of a Drop-free type (no TLS destructor, const-initialized, so it is
// accessible from any allocation site on this thread).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocation_count() -> u64 {
    ALLOCS.with(|c| c.get())
}

fn assert_draws_alloc_free<P: Potential>(name: &str, mut pot: P, eps: f64, seed: u64) {
    let dim = pot.dim();
    let max_depth = 6;
    let mut ws = TreeWorkspace::new(dim, max_depth);
    let mut rng = Rng::new(seed);
    let mut z = vec![0.05; dim];
    let inv_mass = vec![1.0; dim];

    // warm-up: establish tape/arena/workspace capacity watermarks
    for _ in 0..5 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, eps, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }

    let before = allocation_count();
    for _ in 0..15 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, eps, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: steady-state draws performed {} heap allocations",
        after - before
    );
}

#[test]
fn steady_state_draws_are_allocation_free() {
    let l = data::make_covtype_like(0, 500, 8);
    assert_draws_alloc_free(
        "logistic",
        LogisticNative::new(l.x, l.y, 500, 8),
        1e-2,
        1,
    );

    let h = data::make_hmm(0, 80, 20, 3, 10);
    assert_draws_alloc_free("hmm", HmmNative::new(h.obs, h.sup_states, 3, 10), 1e-2, 2);

    let s = data::make_skim(0, 24, 5, 2);
    assert_draws_alloc_free(
        "skim",
        SkimNative::new(s.x, s.y, 24, 5, SkimHypers::default()),
        5e-3,
        3,
    );
}
