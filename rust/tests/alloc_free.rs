//! Zero-allocation regression for the NUTS hot path: once the tape and
//! tree workspace have warmed up, a full draw via
//! `nuts_iterative::draw_in_workspace` over each native potential —
//! hand-fused *and* compiler-generated — must perform **zero** heap
//! allocations.  The same bar applies to the vectorized chain engine:
//! a K-lane `batch_nuts::draw_batch` over a `BatchedCompiledModel` is
//! allocation-free per batched draw.
//!
//! Counted with a thread-local tally inside a wrapping global
//! allocator (libtest runs each #[test] on its own thread, so the
//! per-thread counters stay isolated).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use fugue::compile::zoo::{EightSchools, Horseshoe, LogisticModel, NormalMean};
use fugue::compile::{compile, compile_batched, compile_tiled};
use fugue::coordinator::{
    run_chains_checkpointed, CheckpointConfig, NativeSampler, NutsOptions, TreeAlgorithm,
};
use fugue::data;
use fugue::harness::fault::{Fault, FaultPlan, FaultSite, FaultyBatchPotential, FaultyPotential};
use fugue::mcmc::batch_nuts::{draw_batch, BatchTreeWorkspace};
use fugue::mcmc::hmc::{draw_in_workspace as hmc_draw_in_workspace, HmcWorkspace};
use fugue::mcmc::nuts_iterative::{draw_in_workspace, TreeWorkspace};
use fugue::mcmc::{BatchPotential, DrawStats, Potential};
use fugue::models::skim::SkimHypers;
use fugue::models::{HmmNative, LogisticNative, SkimNative};
use fugue::rng::Rng;
use fugue::svi::{
    BatchedParticles, ElboEngine, NativeSvi, ScalarParticles, StepSchedule, SviOptions,
};

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates to System; the counter is a plain thread-local Cell
// of a Drop-free type (no TLS destructor, const-initialized, so it is
// accessible from any allocation site on this thread).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocation_count() -> u64 {
    ALLOCS.with(|c| c.get())
}

fn assert_draws_alloc_free<P: Potential>(name: &str, mut pot: P, eps: f64, seed: u64) {
    let dim = pot.dim();
    let max_depth = 6;
    let mut ws = TreeWorkspace::new(dim, max_depth);
    let mut rng = Rng::new(seed);
    let mut z = vec![0.05; dim];
    let inv_mass = vec![1.0; dim];

    // warm-up: establish tape/arena/workspace capacity watermarks
    for _ in 0..5 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, eps, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }

    let before = allocation_count();
    for _ in 0..15 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, eps, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: steady-state draws performed {} heap allocations",
        after - before
    );
}

#[test]
fn steady_state_draws_are_allocation_free() {
    let l = data::make_covtype_like(0, 500, 8);
    assert_draws_alloc_free(
        "logistic",
        LogisticNative::new(l.x, l.y, 500, 8),
        1e-2,
        1,
    );

    let h = data::make_hmm(0, 80, 20, 3, 10);
    assert_draws_alloc_free("hmm", HmmNative::new(h.obs, h.sup_states, 3, 10), 1e-2, 2);

    let s = data::make_skim(0, 24, 5, 2);
    assert_draws_alloc_free(
        "skim",
        SkimNative::new(s.x, s.y, 24, 5, SkimHypers::default()),
        5e-3,
        3,
    );
}

/// Steady-state check for the **vectorized chain engine**: once the
/// multi-lane tape and the batched tree workspace have warmed up, a
/// full K-lane `draw_batch` — one fused gradient per leapfrog for all
/// chains, plus every lane's tree bookkeeping — must perform zero heap
/// allocations.
fn assert_batch_draws_alloc_free<BP: BatchPotential>(name: &str, mut pot: BP, eps: f64, seed: u64) {
    let dim = pot.dim();
    let lanes = pot.lanes();
    let max_depth = 6;
    let mut ws = BatchTreeWorkspace::new(dim, lanes, max_depth);
    let mut rngs: Vec<Rng> = (0..lanes).map(|k| Rng::new(seed + k as u64)).collect();
    let mut z = vec![0.05; dim * lanes];
    let inv_mass = vec![1.0; dim * lanes];
    let steps = vec![eps; lanes];
    let mut stats = vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
            poisoned: false,
        };
        lanes
    ];

    // warm-up: establish tape/arena/workspace capacity watermarks
    for _ in 0..5 {
        draw_batch(
            &mut pot, &mut rngs, &mut ws, &z, &steps, &inv_mass, max_depth, &mut stats,
        );
        z.copy_from_slice(ws.proposal());
    }

    let before = allocation_count();
    for _ in 0..15 {
        draw_batch(
            &mut pot, &mut rngs, &mut ws, &z, &steps, &inv_mass, max_depth, &mut stats,
        );
        z.copy_from_slice(ws.proposal());
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: steady-state batched draws performed {} heap allocations",
        after - before
    );
}

/// The vectorized engine's batched draws hit the same zero-allocation
/// bar as the scalar hot path, across lane counts and models.
#[test]
fn vectorized_batched_draws_are_allocation_free() {
    let es = compile_batched(EightSchools::classic(), 0, 4).unwrap();
    assert_batch_draws_alloc_free("batched eight-schools x4", es, 1e-2, 7);

    let l = data::make_covtype_like(4, 200, 8);
    let lm = compile_batched(
        LogisticModel {
            x: l.x,
            y: l.y,
            n: 200,
            d: 8,
        },
        0,
        8,
    )
    .unwrap();
    assert_batch_draws_alloc_free("batched logistic x8", lm, 1e-2, 8);

    let hs = compile_batched(Horseshoe::synthetic(5, 60, 6, 2), 0, 3).unwrap();
    assert_batch_draws_alloc_free("batched horseshoe x3", hs, 5e-3, 9);
}

/// Compiler-generated potentials must hit the same bar as the
/// hand-fused ones: after warmup, a full compiled-model NUTS draw
/// performs zero heap allocations.  Since PR 4 the steady state of a
/// compiled model is the **frozen tape program** (recorded on the
/// first evaluation), so these cases prove the frozen path's scalar
/// draws are allocation-free on eight-schools, logistic and horseshoe.
#[test]
fn compiled_model_draws_are_allocation_free() {
    let es = compile(EightSchools::classic(), 0).unwrap();
    assert_draws_alloc_free("compiled eight-schools", es, 1e-2, 4);

    let l = data::make_covtype_like(1, 200, 8);
    let lm = compile(
        LogisticModel {
            x: l.x,
            y: l.y,
            n: 200,
            d: 8,
        },
        0,
    )
    .unwrap();
    assert_draws_alloc_free("compiled logistic", lm, 1e-2, 5);

    let hs = compile(Horseshoe::synthetic(2, 60, 6, 2), 0).unwrap();
    assert_draws_alloc_free("compiled horseshoe", hs, 5e-3, 6);
}

/// Frozen-path steady state at the *potential* level: after the first
/// (recording) evaluation, scalar `value_and_grad` must be a pure
/// forward/backward sweep over the frozen program — zero allocations —
/// including the debug builds' periodic re-replay audit.
fn assert_frozen_evals_alloc_free<P: Potential>(name: &str, mut pot: P, seed: u64) {
    let dim = pot.dim();
    let mut rng = Rng::new(seed);
    let mut z = vec![0.0; dim];
    let mut g = vec![0.0; dim];
    // warm-up: the first eval records + freezes, a few more settle
    // every buffer's capacity
    for _ in 0..3 {
        for v in z.iter_mut() {
            *v = 0.3 * rng.normal();
        }
        let _ = pot.value_and_grad(&z, &mut g);
    }
    let before = allocation_count();
    for _ in 0..200 {
        for v in z.iter_mut() {
            *v = 0.3 * rng.normal();
        }
        let _ = pot.value_and_grad(&z, &mut g);
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: frozen-path evaluations performed {} heap allocations",
        after - before
    );
}

/// Batched twin of [`assert_frozen_evals_alloc_free`].
fn assert_frozen_batch_evals_alloc_free<BP: BatchPotential>(name: &str, mut pot: BP, seed: u64) {
    let dim = pot.dim();
    let lanes = pot.lanes();
    let mut rng = Rng::new(seed);
    let mut z = vec![0.0; dim * lanes];
    let mut u = vec![0.0; lanes];
    let mut g = vec![0.0; dim * lanes];
    for _ in 0..3 {
        for v in z.iter_mut() {
            *v = 0.3 * rng.normal();
        }
        pot.value_and_grad_batch(&z, &mut u, &mut g);
    }
    let before = allocation_count();
    for _ in 0..200 {
        for v in z.iter_mut() {
            *v = 0.3 * rng.normal();
        }
        pot.value_and_grad_batch(&z, &mut u, &mut g);
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: frozen-path batched evaluations performed {} heap allocations",
        after - before
    );
}

/// The frozen program serves every post-recording gradient without
/// touching the heap, scalar and batched, across the zoo models the
/// chain engines sample.
#[test]
fn frozen_program_evaluations_are_allocation_free() {
    assert_frozen_evals_alloc_free(
        "frozen eight-schools",
        compile(EightSchools::classic(), 0).unwrap(),
        21,
    );
    let l = data::make_covtype_like(6, 200, 8);
    assert_frozen_evals_alloc_free(
        "frozen logistic",
        compile(
            LogisticModel {
                x: l.x.clone(),
                y: l.y.clone(),
                n: 200,
                d: 8,
            },
            0,
        )
        .unwrap(),
        22,
    );
    assert_frozen_evals_alloc_free(
        "frozen horseshoe",
        compile(Horseshoe::synthetic(7, 60, 6, 2), 0).unwrap(),
        23,
    );

    assert_frozen_batch_evals_alloc_free(
        "frozen batched eight-schools x4",
        compile_batched(EightSchools::classic(), 0, 4).unwrap(),
        24,
    );
    assert_frozen_batch_evals_alloc_free(
        "frozen batched logistic x8",
        compile_batched(
            LogisticModel {
                x: l.x,
                y: l.y,
                n: 200,
                d: 8,
            },
            0,
            8,
        )
        .unwrap(),
        25,
    );
    assert_frozen_batch_evals_alloc_free(
        "frozen batched horseshoe x3",
        compile_batched(Horseshoe::synthetic(7, 60, 6, 2), 0, 3).unwrap(),
        26,
    );
}

/// The optimizing tape compiler's execution plan (on by default since
/// PR 9) serves scalar and batched frozen evaluations with zero
/// steady-state allocations — the plan and its register files are
/// built eagerly at freeze time, inside warmup.  The interpreter
/// fallback (`set_optimized(false)`) must hit the same bar, pinning
/// that *both* serving paths are allocation-free rather than one
/// masking the other.
#[test]
fn optimized_plan_evaluations_are_allocation_free() {
    // optimizer on (the default) — assert the plan is actually serving
    let mut es = compile(EightSchools::classic(), 0).unwrap();
    {
        let dim = es.dim();
        let z = vec![0.1; dim];
        let mut g = vec![0.0; dim];
        let _ = es.value_and_grad(&z, &mut g); // record + freeze + optimize
    }
    assert!(es.is_optimized(), "optimizer should be on by default");
    assert_frozen_evals_alloc_free("optimized eight-schools", es, 71);

    // optimizer off: the interpreter fallback, same zero bar
    let mut es_off = compile(EightSchools::classic(), 0).unwrap();
    es_off.set_optimized(false);
    {
        let dim = es_off.dim();
        let z = vec![0.1; dim];
        let mut g = vec![0.0; dim];
        let _ = es_off.value_and_grad(&z, &mut g);
    }
    assert!(!es_off.is_optimized());
    assert_frozen_evals_alloc_free("interpreted eight-schools", es_off, 72);

    // batched plan, K = 4
    let mut esb = compile_batched(EightSchools::classic(), 0, 4).unwrap();
    {
        let dim = esb.dim();
        let z = vec![0.1; dim * 4];
        let mut u = vec![0.0; 4];
        let mut g = vec![0.0; dim * 4];
        esb.value_and_grad_batch(&z, &mut u, &mut g);
    }
    assert!(esb.is_optimized(), "batched optimizer should be on by default");
    assert_frozen_batch_evals_alloc_free("optimized batched eight-schools x4", esb, 73);
}

/// Steady-state bar for the **native SVI engine**: once the guide, the
/// optimizer state, the ELBO scratch and the frozen tape have warmed
/// up, a full SVI step — noise draw, K-particle ELBO gradient,
/// scheduled Adam ascent, trace/averaging bookkeeping — performs zero
/// heap allocations.
fn assert_svi_steps_alloc_free<E: ElboEngine>(name: &str, engine: E, opts: &SviOptions) {
    let mut svi = NativeSvi::new(engine, opts).unwrap();
    // warm-up: the first step records + freezes the tape program and
    // settles every buffer's capacity
    for _ in 0..5 {
        svi.step();
    }
    let before = allocation_count();
    for _ in 0..25 {
        svi.step();
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: steady-state SVI steps performed {} heap allocations",
        after - before
    );
}

/// Zero allocations per SVI step, scalar-particle loop and K in {4, 8}
/// fused particle lanes, with the schedule and tail averaging active.
#[test]
fn svi_steps_are_allocation_free() {
    let opts = |particles: usize| SviOptions {
        num_steps: 100,
        num_particles: particles,
        lr: 0.02,
        seed: 41,
        schedule: StepSchedule::ExponentialDecay {
            rate: 0.1,
            over: 100,
        },
        tail_average: 1.0,
        ..Default::default()
    };

    let es = compile(EightSchools::classic(), 0).unwrap();
    assert_svi_steps_alloc_free(
        "svi scalar x4 eight-schools",
        ScalarParticles::new(es, 4),
        &opts(4),
    );

    let esb = compile_batched(EightSchools::classic(), 0, 4).unwrap();
    assert_svi_steps_alloc_free(
        "svi batched x4 eight-schools",
        BatchedParticles::new(esb),
        &opts(4),
    );

    let l = data::make_covtype_like(8, 200, 8);
    let lm = compile_batched(
        LogisticModel {
            x: l.x,
            y: l.y,
            n: 200,
            d: 8,
        },
        0,
        8,
    )
    .unwrap();
    assert_svi_steps_alloc_free("svi batched x8 logistic", BatchedParticles::new(lm), &opts(8));
}

/// The **massive-lane tiled engine** hits the same bar: once each
/// tile's frozen program and the K-lane tree workspace have warmed up,
/// a full tiled `draw_batch` — gather into per-tile staging, per-tile
/// frozen sweeps, scatter back — performs zero heap allocations per
/// steady-state draw at K=128 and K=512.
///
/// Measured on the inline (`with_threads(1)`) execution path:
/// `std::thread::scope` itself allocates per dispatch, so the
/// threaded path trades a few boxed-closure allocations per *batched
/// eval* for multicore throughput; the engine's own buffers are
/// steady-state either way, which is what this test pins.
#[test]
fn tiled_batched_draws_are_allocation_free() {
    let es = compile_tiled(EightSchools::classic(), 0, 128, 32)
        .unwrap()
        .with_threads(1);
    assert_batch_draws_alloc_free("tiled eight-schools K=128 (tile 32)", es, 1e-2, 61);

    let nm = compile_tiled(
        NormalMean {
            y: vec![0.4, -0.9, 1.3],
            sigma: 1.1,
        },
        0,
        512,
        64,
    )
    .unwrap()
    .with_threads(1);
    assert_batch_draws_alloc_free("tiled normal-mean K=512 (tile 64)", nm, 5e-2, 62);
}

/// SVI particle lanes ride the same tiled engine past the lane
/// threshold: a steady-state SVI step over a `BatchedParticles` wrapped
/// around a tiled potential — K=128 and K=512 particles — performs
/// zero heap allocations (inline tile path, as above).
#[test]
fn tiled_svi_particle_steps_are_allocation_free() {
    let opts = |particles: usize| SviOptions {
        num_steps: 100,
        num_particles: particles,
        lr: 0.02,
        seed: 63,
        schedule: StepSchedule::ExponentialDecay {
            rate: 0.1,
            over: 100,
        },
        tail_average: 1.0,
        ..Default::default()
    };

    let es = compile_tiled(EightSchools::classic(), 0, 128, 32)
        .unwrap()
        .with_threads(1);
    assert_svi_steps_alloc_free(
        "svi tiled x128 eight-schools",
        BatchedParticles::new(es),
        &opts(128),
    );

    let nm = compile_tiled(
        NormalMean {
            y: vec![0.4, -0.9, 1.3],
            sigma: 1.1,
        },
        0,
        512,
        64,
    )
    .unwrap()
    .with_threads(1);
    assert_svi_steps_alloc_free(
        "svi tiled x512 normal-mean",
        BatchedParticles::new(nm),
        &opts(512),
    );
}

/// The fault-containment path costs nothing on the heap: draws whose
/// potential/gradient comes back NaN — the poisoned-energy quarantine
/// and the ordinary mid-trajectory divergence rejection alike — must be
/// handled entirely within the pre-sized workspace, scalar and batched.
#[test]
fn contained_faulted_draws_are_allocation_free() {
    // scalar path: NaN every forward sweep from eval 150 on, so the
    // measured window is dominated by poisoned/diverging draws
    let evals: Vec<u64> = (150..5000).collect();
    let mut pot = FaultyPotential::new(
        compile(EightSchools::classic(), 0).unwrap(),
        FaultPlan::nan_forward_at(&evals),
    );
    let dim = pot.dim();
    let max_depth = 6;
    let mut ws = TreeWorkspace::new(dim, max_depth);
    let mut rng = Rng::new(51);
    let mut z = vec![0.05; dim];
    let inv_mass = vec![1.0; dim];
    for _ in 0..5 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, 1e-2, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }
    let before = allocation_count();
    let mut contained = 0u64;
    for _ in 0..15 {
        let st = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, 1e-2, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
        if st.diverging {
            contained += 1;
        }
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "scalar containment performed {} heap allocations",
        after - before
    );
    assert!(pot.injected > 0, "adversary never fired");
    assert!(contained > 0, "faults fired but no draw was contained");

    // batched path: lane 1 poisoned every eval from 150 on — the
    // quarantine/restart machinery must stay inside the batch workspace
    let plan = FaultPlan {
        faults: (150u64..5000)
            .map(|e| Fault {
                at_eval: e,
                site: FaultSite::Forward,
                value: f64::NAN,
                lane: Some(1),
            })
            .collect(),
    };
    let mut bpot = FaultyBatchPotential::new(
        compile_batched(EightSchools::classic(), 0, 4).unwrap(),
        plan,
    );
    let dim = bpot.dim();
    let lanes = bpot.lanes();
    let mut ws = BatchTreeWorkspace::new(dim, lanes, max_depth);
    let mut rngs: Vec<Rng> = (0..lanes).map(|k| Rng::new(52 + k as u64)).collect();
    let mut z = vec![0.05; dim * lanes];
    let inv_mass = vec![1.0; dim * lanes];
    let steps = vec![1e-2; lanes];
    let mut stats = vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
            poisoned: false,
        };
        lanes
    ];
    for _ in 0..5 {
        draw_batch(
            &mut bpot, &mut rngs, &mut ws, &z, &steps, &inv_mass, max_depth, &mut stats,
        );
        z.copy_from_slice(ws.proposal());
    }
    let before = allocation_count();
    let mut lane_contained = 0u64;
    for _ in 0..15 {
        draw_batch(
            &mut bpot, &mut rngs, &mut ws, &z, &steps, &inv_mass, max_depth, &mut stats,
        );
        z.copy_from_slice(ws.proposal());
        if stats[1].diverging {
            lane_contained += 1;
        }
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "batched lane containment performed {} heap allocations",
        after - before
    );
    assert!(bpot.injected > 0, "batch adversary never fired");
    assert!(lane_contained > 0, "lane faults fired but lane 1 was never contained");
}

/// The checkpoint-capable chain runner's per-draw bookkeeping (deadline
/// checks, checkpoint cadence, quarantine counters, cursor pushes into
/// pre-sized buffers) is allocation-free: growing a run by N sampling
/// draws costs exactly N extra allocations — the one pre-existing
/// proposal-vector `Transition` allocation per [`Sampler::draw`], and
/// nothing from the containment/checkpoint layer.
#[test]
fn checkpoint_bookkeeping_adds_no_per_draw_allocations() {
    fn allocs_for(samples: usize) -> u64 {
        let pot = compile(EightSchools::classic(), 0).unwrap();
        let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, 6);
        let opts = NutsOptions {
            num_warmup: 50,
            num_samples: samples,
            seed: 11,
            ..Default::default()
        };
        let cfg = CheckpointConfig {
            path: None,
            resume: false,
            every: 1_000_000,
            max_seconds: None,
        };
        let before = allocation_count();
        let (results, completed) =
            run_chains_checkpointed(&mut sampler, 1, &opts, &cfg).unwrap();
        assert!(completed);
        assert_eq!(results[0].samples.len() / results[0].dim, samples);
        allocation_count() - before
    }

    let small = allocs_for(100);
    let large = allocs_for(160);
    assert_eq!(
        large - small,
        60,
        "60 extra draws cost {} extra allocations (expected exactly 60: \
         one Transition proposal vector each, zero from bookkeeping)",
        large - small
    );
}

/// Static-trajectory HMC now follows the same workspace idiom as the
/// NUTS hot path: a steady-state `hmc::draw_in_workspace` over a warm
/// potential performs zero heap allocations.
fn assert_hmc_draws_alloc_free<P: Potential>(name: &str, mut pot: P, eps: f64, seed: u64) {
    let dim = pot.dim();
    let mut ws = HmcWorkspace::new(dim);
    let mut rng = Rng::new(seed);
    let mut z = vec![0.05; dim];
    let inv_mass = vec![1.0; dim];

    for _ in 0..5 {
        let _ = hmc_draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, eps, &inv_mass, 8);
        z.copy_from_slice(ws.proposal());
    }

    let before = allocation_count();
    for _ in 0..15 {
        let _ = hmc_draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, eps, &inv_mass, 8);
        z.copy_from_slice(ws.proposal());
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "{name}: steady-state HMC draws performed {} heap allocations",
        after - before
    );
}

/// The **enabled flight recorder** hits the same zero-allocation bar
/// as the disabled one: instrumented steady-state NUTS draws (scalar,
/// batched, tiled — draw spans, depth histogram, trajectory rings and
/// the 1-in-64 sampled sweep spans all live), SVI steps (ELBO ring,
/// gradient-norm gauge) and minibatch scheduling (epoch/row counters)
/// touch only preallocated atomics.
#[test]
fn instrumented_hot_paths_are_allocation_free() {
    use fugue::obs::{Counter, MetricsRegistry, Recorder};
    let rec = Recorder::new(MetricsRegistry::leak());
    let max_depth = 6;

    // scalar draws, recorder live on both the potential (sweep spans)
    // and the tree workspace (draw span + stats); 80 draws of depth-6
    // trees comfortably cross the 1-in-64 sweep sampling period
    let mut pot = compile(EightSchools::classic(), 0).unwrap();
    pot.set_recorder(rec);
    let dim = pot.dim();
    let mut ws = TreeWorkspace::new(dim, max_depth);
    ws.set_recorder(rec);
    let mut rng = Rng::new(81);
    let mut z = vec![0.05; dim];
    let inv_mass = vec![1.0; dim];
    for _ in 0..5 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, 1e-2, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }
    let before = allocation_count();
    for _ in 0..80 {
        let _ = draw_in_workspace(&mut pot, &mut rng, &mut ws, &z, 1e-2, &inv_mass, max_depth);
        z.copy_from_slice(ws.proposal());
    }
    assert_eq!(
        allocation_count() - before,
        0,
        "instrumented scalar draws allocated on the heap"
    );
    assert!(
        rec.registry().unwrap().counter(Counter::Draws) >= 85,
        "recorder missed instrumented draws"
    );

    // batched lanes, per-lane draw recording live
    let mut bpot = compile_batched(EightSchools::classic(), 0, 4).unwrap();
    let lanes = 4;
    let mut bws = BatchTreeWorkspace::new(bpot.dim(), lanes, max_depth);
    bws.set_recorder(rec);
    let mut rngs: Vec<Rng> = (0..lanes).map(|k| Rng::new(82 + k as u64)).collect();
    let mut zb = vec![0.05; bpot.dim() * lanes];
    let inv_mass_b = vec![1.0; bpot.dim() * lanes];
    let steps = vec![1e-2; lanes];
    let mut stats = vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
            poisoned: false,
        };
        lanes
    ];
    for _ in 0..5 {
        draw_batch(
            &mut bpot, &mut rngs, &mut bws, &zb, &steps, &inv_mass_b, max_depth, &mut stats,
        );
        zb.copy_from_slice(bws.proposal());
    }
    let before = allocation_count();
    for _ in 0..15 {
        draw_batch(
            &mut bpot, &mut rngs, &mut bws, &zb, &steps, &inv_mass_b, max_depth, &mut stats,
        );
        zb.copy_from_slice(bws.proposal());
    }
    assert_eq!(
        allocation_count() - before,
        0,
        "instrumented batched draws allocated on the heap"
    );

    // tiled engine (inline path), tile-eval spans + gather/scatter
    // counters live
    let mut tpot = compile_tiled(EightSchools::classic(), 0, 128, 32)
        .unwrap()
        .with_threads(1);
    tpot.set_recorder(rec);
    let lanes = 128;
    let mut tws = BatchTreeWorkspace::new(tpot.dim(), lanes, max_depth);
    tws.set_recorder(rec);
    let mut rngs: Vec<Rng> = (0..lanes).map(|k| Rng::new(83 + k as u64)).collect();
    let mut zt = vec![0.05; tpot.dim() * lanes];
    let inv_mass_t = vec![1.0; tpot.dim() * lanes];
    let steps_t = vec![1e-2; lanes];
    let mut stats_t = vec![
        DrawStats {
            accept_prob: 0.0,
            num_leapfrog: 0,
            potential: 0.0,
            diverging: false,
            depth: 0,
            poisoned: false,
        };
        lanes
    ];
    for _ in 0..3 {
        draw_batch(
            &mut tpot, &mut rngs, &mut tws, &zt, &steps_t, &inv_mass_t, max_depth, &mut stats_t,
        );
        zt.copy_from_slice(tws.proposal());
    }
    let before = allocation_count();
    for _ in 0..5 {
        draw_batch(
            &mut tpot, &mut rngs, &mut tws, &zt, &steps_t, &inv_mass_t, max_depth, &mut stats_t,
        );
        zt.copy_from_slice(tws.proposal());
    }
    assert_eq!(
        allocation_count() - before,
        0,
        "instrumented tiled draws allocated on the heap"
    );
    assert!(
        rec.registry().unwrap().counter(Counter::TileEvals) > 0,
        "recorder missed tiled evaluations"
    );

    // SVI steps with the ELBO ring and gradient-norm gauge live
    let mut spot = compile(EightSchools::classic(), 0).unwrap();
    spot.set_recorder(rec);
    let opts = SviOptions {
        num_steps: 100,
        num_particles: 4,
        lr: 0.02,
        seed: 84,
        ..Default::default()
    };
    let mut svi = NativeSvi::new(ScalarParticles::new(spot, 4), &opts).unwrap();
    svi.set_recorder(rec);
    for _ in 0..5 {
        svi.step();
    }
    let before = allocation_count();
    for _ in 0..25 {
        svi.step();
    }
    assert_eq!(
        allocation_count() - before,
        0,
        "instrumented SVI steps allocated on the heap"
    );
    assert!(
        rec.registry().unwrap().counter(Counter::SviSteps) >= 25,
        "recorder missed SVI steps"
    );

    // minibatch scheduling with epoch/row counters live
    let mut sched =
        fugue::data::MinibatchScheduler::new(64, 16, fugue::svi::scheduler_rng(7));
    sched.set_recorder(rec);
    let _ = sched.next_batch();
    let before = allocation_count();
    for _ in 0..50 {
        let _ = sched.next_batch();
    }
    assert_eq!(
        allocation_count() - before,
        0,
        "instrumented minibatch scheduling allocated on the heap"
    );
    assert!(
        rec.registry().unwrap().counter(Counter::Epochs) > 0,
        "recorder missed epoch boundaries"
    );
}

#[test]
fn hmc_draws_are_allocation_free() {
    let l = data::make_covtype_like(3, 300, 8);
    assert_hmc_draws_alloc_free(
        "hmc logistic (hand-fused)",
        LogisticNative::new(l.x, l.y, 300, 8),
        1e-2,
        31,
    );
    assert_hmc_draws_alloc_free(
        "hmc eight-schools (compiled, frozen)",
        compile(EightSchools::classic(), 0).unwrap(),
        1e-2,
        32,
    );
}
