//! The optimizing tape compiler's bitwise gate (`autodiff::opt`).
//!
//! The interpreter is the oracle: for every program the optimizer may
//! prune, fold, fuse, and re-slot however it likes, but the executed
//! plan must reproduce interpreted replay **bit for bit** — values,
//! gradients, and rebound-minibatch results alike.  Three layers:
//!
//! 1. **Property fuzz**: 500 randomly generated programs (random
//!    elementwise ops, fused composites, data regions with rebindable
//!    Nodes/Coeffs/Consts slots, dead branches, constant subgraphs,
//!    `scale(·, 1.0)` / `scale(·, 0.0)` shapes) across lane counts
//!    K ∈ {1 (scalar), 1, 4, 64 (batched)}, each compared bitwise
//!    against the interpreter before and after random data-slot
//!    rebinds.
//! 2. **Subsampling regression**: rebinding a minibatch *after*
//!    optimization (`SubsampleRebind::set_minibatch` on a
//!    `CompiledModel` serving from the optimized plan) must match a
//!    fresh interpreter-only compile on the same rows, for both
//!    B < N and the scale-free B == N case.
//! 3. **End-to-end**: full NUTS runs with the optimizer on vs off must
//!    be bitwise identical across all three chain methods.

use fugue::autodiff::{BatchTape, BatchTapeProgram, Tape, TapeProgram, Var};
use fugue::compile::zoo::EightSchools;
use fugue::compile::{compile, SubsampleRebind, SubsampledLogistic, SubsampledModel};
use fugue::coordinator::{
    run_compiled_chains_method, run_compiled_chains_method_opt, ChainMethod, ChainResult,
    NutsOptions,
};
use fugue::data::make_covtype_like;
use fugue::data::stream::InMemoryRows;
use fugue::mcmc::Potential;
use fugue::rng::Rng;

// ---------------------------------------------------------------------------
// random program generators
// ---------------------------------------------------------------------------

fn pick(rng: &mut Rng, pool: &[Var]) -> Var {
    pool[rng.below(pool.len())]
}

/// Record a random scalar program: a pool of inputs and constants grown
/// by randomly chosen ops.  Roughly half the pool never reaches the
/// output (DCE fodder), constant-only subgraphs appear naturally
/// (folding fodder), and data regions register every flavour of
/// rebindable slot.
fn random_scalar_program(seed: u64) -> TapeProgram {
    let mut rng = Rng::new(seed);
    let mut tape = Tape::new();
    let n_inputs = 1 + rng.below(4);
    let mut pool: Vec<Var> = (0..n_inputs).map(|_| tape.input(rng.normal())).collect();
    for _ in 0..(1 + rng.below(3)) {
        pool.push(tape.constant(rng.uniform_in(0.2, 3.0)));
    }
    let steps = 12 + rng.below(28);
    for _ in 0..steps {
        let x = pick(&mut rng, &pool);
        let y = pick(&mut rng, &pool);
        let v = match rng.below(24) {
            0 => tape.add(x, y),
            1 => tape.sub(x, y),
            2 => tape.mul(x, y),
            3 => tape.div(x, y),
            4 => tape.neg(x),
            5 => tape.exp(x),
            6 => tape.ln(x),
            7 => tape.log1p(x),
            8 => tape.sqrt(x),
            9 => tape.sigmoid(x),
            10 => tape.softplus(x),
            11 => tape.tanh(x),
            12 => tape.square(x),
            13 => tape.powi(x, rng.below(5) as i32 - 2),
            // the lik_scale shapes: exact 1.0 and exact 0.0 scales
            // must survive every pass untouched
            14 => tape.scale(x, 1.0),
            15 => tape.scale(x, 0.0),
            16 => tape.scale(x, rng.normal()),
            17 => tape.offset(x, rng.normal()),
            18 => {
                let k = 2 + rng.below(3);
                let xs: Vec<Var> = (0..k).map(|_| pick(&mut rng, &pool)).collect();
                tape.sum(&xs)
            }
            19 => {
                let k = 2 + rng.below(3);
                let xs: Vec<Var> = (0..k).map(|_| pick(&mut rng, &pool)).collect();
                tape.logsumexp(&xs)
            }
            20 => {
                let n = 1 + rng.below(5);
                let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                tape.normal_iid_obs(x, y, &ys)
            }
            21 => {
                let n = 1 + rng.below(5);
                let ys: Vec<f64> = (0..n).map(|_| rng.below(2) as f64).collect();
                tape.bernoulli_logits_iid_obs(x, &ys)
            }
            22 => {
                // a rebindable data block: Nodes, Coeffs, or Consts
                tape.begin_data_region();
                let v = match rng.below(3) {
                    0 => {
                        let n = 1 + rng.below(4);
                        let leaves: Vec<Var> =
                            (0..n).map(|_| tape.constant(rng.normal())).collect();
                        tape.register_data_nodes(&leaves);
                        let s = tape.sum(&leaves);
                        tape.add(s, x)
                    }
                    1 => {
                        let n = 1 + rng.below(4);
                        let ws: Vec<Var> = (0..n).map(|_| pick(&mut rng, &pool)).collect();
                        let cs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                        tape.dot_const(&ws, &cs)
                    }
                    _ => {
                        let n = 1 + rng.below(5);
                        let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                        tape.normal_iid_obs(x, y, &ys)
                    }
                };
                tape.end_data_region();
                v
            }
            _ => {
                let n = 1 + rng.below(4);
                let locs: Vec<Var> = (0..n).map(|_| pick(&mut rng, &pool)).collect();
                let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                tape.normal_plate_obs(&locs, y, &ys)
            }
        };
        pool.push(v);
    }
    // output mixes a few pool nodes; everything else is dead
    let mut out = pick(&mut rng, &pool);
    for _ in 0..rng.below(3) {
        let v = pick(&mut rng, &pool);
        out = tape.add(out, v);
    }
    tape.freeze(out)
}

/// Batched twin of [`random_scalar_program`] (no `tanh`/`logsumexp` —
/// the batch tape doesn't record them; `sum`/`dot_const` exercise the
/// lane-shared composite form instead).
fn random_batch_program(seed: u64, lanes: usize) -> BatchTapeProgram {
    let mut rng = Rng::new(seed);
    let mut tape = BatchTape::new(lanes);
    let n_inputs = 1 + rng.below(4);
    let mut pool: Vec<Var> = (0..n_inputs)
        .map(|_| {
            let vals: Vec<f64> = (0..lanes).map(|_| rng.normal()).collect();
            tape.input(&vals)
        })
        .collect();
    for _ in 0..(1 + rng.below(3)) {
        pool.push(tape.constant(rng.uniform_in(0.2, 3.0)));
    }
    let steps = 12 + rng.below(28);
    for _ in 0..steps {
        let x = pick(&mut rng, &pool);
        let y = pick(&mut rng, &pool);
        let v = match rng.below(22) {
            0 => tape.add(x, y),
            1 => tape.sub(x, y),
            2 => tape.mul(x, y),
            3 => tape.div(x, y),
            4 => tape.neg(x),
            5 => tape.exp(x),
            6 => tape.ln(x),
            7 => tape.log1p(x),
            8 => tape.sqrt(x),
            9 => tape.sigmoid(x),
            10 => tape.softplus(x),
            11 => tape.square(x),
            12 => tape.powi(x, rng.below(5) as i32 - 2),
            13 => tape.scale(x, 1.0),
            14 => tape.scale(x, 0.0),
            15 => tape.scale(x, rng.normal()),
            16 => tape.offset(x, rng.normal()),
            17 => {
                let k = 2 + rng.below(3);
                let xs: Vec<Var> = (0..k).map(|_| pick(&mut rng, &pool)).collect();
                tape.sum(&xs)
            }
            18 => {
                let n = 1 + rng.below(5);
                let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                tape.normal_iid_obs(x, y, &ys)
            }
            19 => {
                let n = 1 + rng.below(5);
                let ys: Vec<f64> = (0..n).map(|_| rng.below(2) as f64).collect();
                tape.bernoulli_logits_iid_obs(x, &ys)
            }
            20 => {
                tape.begin_data_region();
                let v = match rng.below(3) {
                    0 => {
                        let n = 1 + rng.below(4);
                        let leaves: Vec<Var> =
                            (0..n).map(|_| tape.constant(rng.normal())).collect();
                        tape.register_data_nodes(&leaves);
                        let s = tape.sum(&leaves);
                        tape.add(s, x)
                    }
                    1 => {
                        let n = 1 + rng.below(4);
                        let ws: Vec<Var> = (0..n).map(|_| pick(&mut rng, &pool)).collect();
                        let cs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                        tape.dot_const(&ws, &cs)
                    }
                    _ => {
                        let n = 1 + rng.below(5);
                        let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                        tape.normal_iid_obs(x, y, &ys)
                    }
                };
                tape.end_data_region();
                v
            }
            _ => {
                let n = 1 + rng.below(4);
                let locs: Vec<Var> = (0..n).map(|_| pick(&mut rng, &pool)).collect();
                let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                tape.normal_plate_obs(&locs, y, &ys)
            }
        };
        pool.push(v);
    }
    let mut out = pick(&mut rng, &pool);
    for _ in 0..rng.below(3) {
        let v = pick(&mut rng, &pool);
        out = tape.add(out, v);
    }
    tape.freeze(out)
}

// ---------------------------------------------------------------------------
// bitwise comparison drivers
// ---------------------------------------------------------------------------

fn compare_scalar(
    prog: &mut TapeProgram,
    opt: &mut fugue::autodiff::OptTapeProgram,
    rng: &mut Rng,
    points: usize,
    label: &str,
) {
    let n = prog.num_inputs();
    assert_eq!(opt.num_inputs(), n, "{label}: input count");
    let mut gi = vec![0.0; n];
    let mut go = vec![0.0; n];
    for p in 0..points {
        let z: Vec<f64> = (0..n).map(|_| 1.5 * rng.normal()).collect();
        let ui = prog.forward(&z);
        prog.backward();
        prog.input_adjoints(&mut gi);
        let uo = opt.forward(&z);
        opt.backward();
        opt.input_adjoints(&mut go);
        assert_eq!(
            ui.to_bits(),
            uo.to_bits(),
            "{label}: forward value diverged at point {p} ({ui} vs {uo})"
        );
        for i in 0..n {
            assert_eq!(
                gi[i].to_bits(),
                go[i].to_bits(),
                "{label}: grad[{i}] diverged at point {p} ({} vs {})",
                gi[i],
                go[i]
            );
        }
    }
}

fn compare_batch(
    prog: &mut BatchTapeProgram,
    opt: &mut fugue::autodiff::OptBatchTapeProgram,
    lanes: usize,
    rng: &mut Rng,
    points: usize,
    label: &str,
) {
    let n = prog.num_inputs();
    assert_eq!(opt.num_inputs(), n, "{label}: input count");
    assert_eq!(opt.lanes(), lanes, "{label}: lane count");
    let mut gi = vec![0.0; n * lanes];
    let mut go = vec![0.0; n * lanes];
    for p in 0..points {
        let z: Vec<f64> = (0..n * lanes).map(|_| 1.5 * rng.normal()).collect();
        prog.forward(&z);
        prog.backward();
        prog.input_adjoints(&mut gi);
        opt.forward(&z);
        opt.backward();
        opt.input_adjoints(&mut go);
        for (k, (ui, uo)) in prog
            .output_values()
            .iter()
            .zip(opt.output_values())
            .enumerate()
        {
            assert_eq!(
                ui.to_bits(),
                uo.to_bits(),
                "{label}: lane {k} value diverged at point {p} ({ui} vs {uo})"
            );
        }
        for i in 0..n * lanes {
            assert_eq!(
                gi[i].to_bits(),
                go[i].to_bits(),
                "{label}: grad[{i}] diverged at point {p}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// the 500-program property gate
// ---------------------------------------------------------------------------

/// 200 random scalar programs: optimized plan == interpreted replay,
/// bit for bit, before and after rebinding every data slot.
#[test]
fn fuzz_scalar_optimized_matches_interpreter_bitwise() {
    for seed in 0..200u64 {
        let mut prog = random_scalar_program(seed);
        let mut opt = prog.optimize();
        let mut rng = Rng::new(seed ^ 0xA5A5_5A5A);
        let label = format!("scalar seed {seed}");
        compare_scalar(&mut prog, &mut opt, &mut rng, 4, &label);
        // rebind every registered data slot on both paths and re-check
        for s in 0..prog.num_data_slots() {
            let len = prog.data_slot_len(s);
            let data: Vec<f64> = (0..len).map(|_| rng.uniform_in(0.1, 2.0)).collect();
            prog.rebind_data_slot(s, &data);
            opt.rebind_data_slot(s, &data);
        }
        if prog.num_data_slots() > 0 {
            let label = format!("scalar seed {seed} (rebound)");
            compare_scalar(&mut prog, &mut opt, &mut rng, 2, &label);
        }
    }
}

/// 300 random batched programs (100 per lane count, K in {1, 4, 64}):
/// same bitwise gate, lane for lane.
#[test]
fn fuzz_batched_optimized_matches_interpreter_bitwise() {
    for &lanes in &[1usize, 4, 64] {
        for seed in 0..100u64 {
            let mut prog = random_batch_program(seed, lanes);
            let mut opt = prog.optimize();
            let mut rng = Rng::new(seed ^ 0x5A5A_A5A5 ^ (lanes as u64) << 32);
            let label = format!("batch K={lanes} seed {seed}");
            compare_batch(&mut prog, &mut opt, lanes, &mut rng, 3, &label);
            for s in 0..prog.num_data_slots() {
                let len = prog.data_slot_len(s);
                let data: Vec<f64> = (0..len).map(|_| rng.uniform_in(0.1, 2.0)).collect();
                prog.rebind_data_slot(s, &data);
                opt.rebind_data_slot(s, &data);
            }
            if prog.num_data_slots() > 0 {
                let label = format!("batch K={lanes} seed {seed} (rebound)");
                compare_batch(&mut prog, &mut opt, lanes, &mut rng, 2, &label);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// subsampling regression (PR 8 interaction)
// ---------------------------------------------------------------------------

fn small_rows(n: usize, d: usize) -> InMemoryRows {
    let data = make_covtype_like(5, n, d);
    InMemoryRows::new(data.x, data.y, n, d)
}

/// Rebinding a minibatch on a `CompiledModel` serving from the
/// *optimized* plan must match a fresh interpreter-only compile on the
/// same rows — for B < N (a `lik_scale` Scale node in the program) and
/// B == N (scale exactly 1.0, no Scale node recorded).  Guards the
/// satellite hazard: neither the scale node nor the data slots may be
/// folded or pruned out from under the rebind.
#[test]
fn rebound_minibatch_after_optimization_matches_interpreter() {
    for &(n, d, bsz) in &[(10usize, 3usize, 4usize), (10, 3, 10)] {
        let rows = small_rows(n, d);
        let mut sub = compile(SubsampledLogistic::new(rows.clone(), bsz), 0).unwrap();
        let dim = sub.dim();
        let z = vec![0.2; dim];
        let mut g = vec![0.0; dim];
        let _ = sub.value_and_grad(&z, &mut g); // record + freeze + optimize
        assert!(sub.is_optimized(), "optimizer should be on by default");

        let idx: Vec<usize> = (0..bsz).map(|i| (3 * i + 1) % n).collect();
        sub.set_minibatch(&idx);
        let u = sub.value_and_grad(&z, &mut g);

        let mut fresh_model = SubsampledLogistic::new(rows, bsz);
        fresh_model.load_rows(&idx);
        let mut fresh = compile(fresh_model, 0).unwrap();
        fresh.set_optimized(false); // interpreter oracle
        let mut gf = vec![0.0; dim];
        let _ = fresh.value_and_grad(&z, &mut gf); // record + freeze
        let uf = fresh.value_and_grad(&z, &mut gf);
        assert!(!fresh.is_optimized());
        assert_eq!(u.to_bits(), uf.to_bits(), "B={bsz}: potential");
        for i in 0..dim {
            assert_eq!(g[i].to_bits(), gf[i].to_bits(), "B={bsz}: grad[{i}]");
        }
    }
}

/// Repeated minibatch swaps with the optimizer on vs off stay in
/// lockstep — the slot-remap tables keep working across many rebinds.
#[test]
fn minibatch_swaps_agree_optimized_vs_interpreted() {
    let (n, d, bsz) = (12usize, 3usize, 5usize);
    let rows = small_rows(n, d);
    let mut on = compile(SubsampledLogistic::new(rows.clone(), bsz), 0).unwrap();
    let mut off = compile(SubsampledLogistic::new(rows, bsz), 0).unwrap();
    off.set_optimized(false);
    let dim = on.dim();
    let mut rng = Rng::new(31);
    let mut ga = vec![0.0; dim];
    let mut gb = vec![0.0; dim];
    let z0 = vec![0.1; dim];
    let _ = on.value_and_grad(&z0, &mut ga);
    let _ = off.value_and_grad(&z0, &mut gb);
    for step in 0..6 {
        let idx = rng.choose(n, bsz);
        on.set_minibatch(&idx);
        off.set_minibatch(&idx);
        let z: Vec<f64> = (0..dim).map(|_| 0.5 * rng.normal()).collect();
        let ua = on.value_and_grad(&z, &mut ga);
        let ub = off.value_and_grad(&z, &mut gb);
        assert_eq!(ua.to_bits(), ub.to_bits(), "swap {step}: potential");
        for i in 0..dim {
            assert_eq!(ga[i].to_bits(), gb[i].to_bits(), "swap {step}: grad[{i}]");
        }
    }
}

// ---------------------------------------------------------------------------
// end-to-end: chains with the optimizer on vs off
// ---------------------------------------------------------------------------

fn assert_bitwise_equal(a: &[ChainResult], b: &[ChainResult], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: chain count");
    for (c, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.samples, y.samples, "{label}: chain {c} samples");
        assert_eq!(x.step_size, y.step_size, "{label}: chain {c} step size");
        assert_eq!(x.inv_mass, y.inv_mass, "{label}: chain {c} mass matrix");
        assert_eq!(x.divergences, y.divergences, "{label}: chain {c} divergences");
        assert_eq!(
            x.stats.accept_prob, y.stats.accept_prob,
            "{label}: chain {c} accept stats"
        );
        assert_eq!(
            x.total_leapfrogs, y.total_leapfrogs,
            "{label}: chain {c} leapfrogs"
        );
    }
}

/// Full NUTS runs — warmup adaptation, tree building, the lot — must be
/// bitwise identical with the optimizing compiler on (the default) and
/// off, for every chain method.
#[test]
fn chains_agree_optimized_vs_interpreted_all_methods() {
    let model = EightSchools::classic();
    let opts = NutsOptions {
        num_warmup: 150,
        num_samples: 200,
        seed: 42,
        ..Default::default()
    };
    for method in [
        ChainMethod::Sequential,
        ChainMethod::Parallel,
        ChainMethod::Vectorized,
    ] {
        let (_, on) = run_compiled_chains_method(&model, method, 3, 10, &opts).unwrap();
        let (_, off) =
            run_compiled_chains_method_opt(&model, method, 3, 10, &opts, false).unwrap();
        let label = format!("eight-schools {}", method.name());
        assert_bitwise_equal(&on, &off, &label);
    }
}

/// The optimizer must actually shrink the program on a real model, not
/// just match it: DCE'd/folded nodes, fused superblocks, and a register
/// file narrower than one slot per node.
#[test]
fn plan_stats_show_real_optimization_on_a_zoo_model() {
    let mut pot = compile(EightSchools::classic(), 0).unwrap();
    let dim = pot.dim();
    let z = vec![0.1; dim];
    let mut g = vec![0.0; dim];
    let _ = pot.value_and_grad(&z, &mut g);
    let st = pot.plan_stats().expect("optimized plan present");
    assert!(st.nodes_total > 0);
    assert!(st.nodes_live <= st.nodes_total);
    assert!(st.fused_runs >= 1, "no superblocks formed: {st:?}");
    assert!(st.micro_ops >= 1);
    assert!(
        st.peak_val_slots < st.nodes_total,
        "no slot reuse: {st:?}"
    );
    assert!(st.fwd_instrs < st.nodes_live.max(1) + 1);
}
