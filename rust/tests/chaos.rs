//! Chaos suite: proves the fault-containment invariants end-to-end by
//! driving the samplers through the deterministic fault-injection
//! harness (`fugue::harness::fault`) and the checkpoint/resume runners.
//!
//! Invariants pinned here:
//!
//! 1. **Containment** — every injected NaN/Inf (forward or adjoint
//!    sweep) becomes a counted divergence or quarantined draw; no
//!    non-finite value ever reaches the stored samples, and the chain
//!    keeps sampling after the fault window passes.
//! 2. **Lane quarantine** — poisoning one lane of the vectorized
//!    engine quarantines and restarts that lane only; every sibling
//!    lane stays **bitwise-equal** to an uninjected run.
//! 3. **SVI backoff** — non-finite ELBO/gradient steps are skipped
//!    with learning-rate backoff; the recorded ELBO trace stays finite
//!    and the fit completes.
//! 4. **Bitwise resume** — interrupting a run at arbitrary wall-clock
//!    cuts (checkpoint + `--max-seconds` style budget) and resuming
//!    until done reproduces the uninterrupted run bitwise, for all
//!    three chain methods and for SVI.
//! 5. **Divergence fingerprint** — the divergence counter that all of
//!    the above routes through is statistically sound: nonzero on
//!    Neal's funnel, zero on a conjugate normal-mean model.

use std::path::PathBuf;

use fugue::compile::zoo::{EightSchools, NealsFunnel, NormalMean};
use fugue::compile::{compile, compile_batched, compile_tiled};
use fugue::coordinator::{
    run_chain, run_chains_vectorized, run_compiled_chains_checkpointed,
    run_compiled_chains_method, run_svi_checkpointed, run_svi_native, ChainMethod,
    ChainResult, CheckpointConfig, NativeSampler, NutsOptions, TreeAlgorithm,
};
use fugue::harness::fault::{Fault, FaultPlan, FaultSite, FaultyBatchPotential, FaultyPotential};
use fugue::mcmc::Potential;
use fugue::svi::{NativeSvi, ScalarParticles, SviOptions};

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fugue_chaos_{}_{}.json", std::process::id(), name))
}

fn opts(warmup: usize, samples: usize, seed: u64) -> NutsOptions {
    NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        seed,
        ..Default::default()
    }
}

fn assert_finite_samples(r: &ChainResult, what: &str) {
    assert!(
        r.samples.iter().all(|x| x.is_finite()),
        "{what}: non-finite value escaped into the stored samples"
    );
    assert!(r.step_size.is_finite() && r.step_size > 0.0, "{what}: step size {}", r.step_size);
    assert!(
        r.inv_mass.iter().all(|x| x.is_finite() && *x > 0.0),
        "{what}: non-finite/non-positive inverse mass"
    );
}

// ---------------------------------------------------------------------
// 1. scalar containment
// ---------------------------------------------------------------------

/// A burst of forward-sweep NaNs long enough to cover several draw
/// boundaries: some faults land on a trajectory's starting energy
/// (poisoned draw → quarantine), the rest mid-trajectory (ordinary
/// counted divergence).  Both are contained; the chain finishes the run
/// with finite samples and keeps moving after the burst.
#[test]
fn scalar_nan_burst_is_contained() {
    let evals: Vec<u64> = (300..600).collect();
    let pot = FaultyPotential::new(
        compile(EightSchools::classic(), 0).unwrap(),
        FaultPlan::nan_forward_at(&evals),
    );
    let dim = pot.dim();
    let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, 6);
    let o = opts(200, 300, 17);
    let res = run_chain(&mut sampler, &vec![0.1; dim], &o).unwrap();

    assert!(sampler.potential.injected > 0, "adversary never fired");
    assert!(res.divergences > 0, "faults fired but none was counted as a divergence");
    assert!(
        res.quarantines > 0,
        "a 300-eval burst must poison at least one starting energy"
    );
    assert_finite_samples(&res, "scalar NaN burst");
    // the chain recovered: the last 20 draws are not stuck at one point
    let tail = &res.samples[res.samples.len() - 20 * dim..];
    let first = &tail[..dim];
    assert!(
        tail.chunks(dim).any(|row| row != first),
        "chain froze after the fault window"
    );
}

/// Same bar for Inf forward faults and NaN adjoint (gradient) faults:
/// a poisoned gradient NaNs the integrator state, which the energy
/// accounting maps to an infinite-energy (diverging) leaf that can
/// never be selected as the proposal.
#[test]
fn inf_and_adjoint_faults_are_contained() {
    let mut faults = FaultPlan::inf_forward_at(&[350, 351, 352, 450]).faults;
    faults.extend(FaultPlan::nan_adjoint_at(&[500, 501, 502, 601], 3).faults);
    let pot = FaultyPotential::new(
        compile(EightSchools::classic(), 0).unwrap(),
        FaultPlan { faults },
    );
    let dim = pot.dim();
    let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, 6);
    let o = opts(150, 200, 23);
    let res = run_chain(&mut sampler, &vec![0.1; dim], &o).unwrap();

    assert!(sampler.potential.injected > 0, "adversary never fired");
    assert!(res.divergences > 0, "no containment recorded");
    assert_finite_samples(&res, "Inf/adjoint faults");
}

/// Seeded random chaos sweep: a reproducible scatter of NaN/Inf,
/// forward/adjoint faults across the whole run.  Nothing escapes.
#[test]
fn seeded_chaos_sweep_is_contained() {
    let pot = FaultyPotential::new(
        compile(EightSchools::classic(), 0).unwrap(),
        FaultPlan::seeded(7, 40, 4000),
    );
    let dim = pot.dim();
    let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, 6);
    let o = opts(200, 300, 29);
    let res = run_chain(&mut sampler, &vec![0.1; dim], &o).unwrap();
    assert!(sampler.potential.injected > 0, "adversary never fired");
    assert_finite_samples(&res, "seeded chaos sweep");
}

// ---------------------------------------------------------------------
// 2. lane quarantine
// ---------------------------------------------------------------------

/// Poisoning lane 1 of a 4-lane vectorized run quarantines and restarts
/// that lane from its last good draw; lanes 0, 2, 3 must be
/// **bitwise-identical** to a run with no faults at all.
#[test]
fn quarantined_lane_leaves_siblings_bitwise_identical() {
    let o = opts(120, 150, 41);
    let lanes = 4;

    let mut clean = compile_batched(EightSchools::classic(), 0, lanes).unwrap();
    let clean_res = run_chains_vectorized(&mut clean, &o, 6).unwrap();

    let plan = FaultPlan {
        faults: (300u64..600)
            .map(|e| Fault {
                at_eval: e,
                site: FaultSite::Forward,
                value: f64::NAN,
                lane: Some(1),
            })
            .collect(),
    };
    let mut faulty = FaultyBatchPotential::new(
        compile_batched(EightSchools::classic(), 0, lanes).unwrap(),
        plan,
    );
    let faulty_res = run_chains_vectorized(&mut faulty, &o, 6).unwrap();
    assert!(faulty.injected > 0, "lane adversary never fired");

    // the poisoned lane was contained and kept sampling
    let lane1 = &faulty_res[1];
    assert!(lane1.quarantines > 0, "no draw was quarantined on the faulted lane");
    assert!(lane1.divergences >= lane1.quarantines);
    assert_finite_samples(lane1, "quarantined lane");

    // sibling lanes: bitwise equality with the uninjected run
    for k in [0usize, 2, 3] {
        let (c, f) = (&clean_res[k], &faulty_res[k]);
        assert_eq!(c.samples, f.samples, "lane {k} samples diverged from clean run");
        assert_eq!(c.step_size.to_bits(), f.step_size.to_bits(), "lane {k} step size");
        assert_eq!(c.inv_mass, f.inv_mass, "lane {k} inverse mass");
        assert_eq!(c.divergences, f.divergences, "lane {k} divergences");
        assert_eq!(c.total_leapfrogs, f.total_leapfrogs, "lane {k} leapfrogs");
        assert_eq!(c.stats.accept_prob, f.stats.accept_prob, "lane {k} accept probs");
        assert_eq!(f.quarantines, 0, "healthy lane {k} reported quarantines");
    }
}

/// Same quarantine bar at massive-lane scale, through the **tiled**
/// engine: poisoning one lane of a 256-lane multi-threaded tiled run
/// quarantines and restarts that lane only, and all 255 siblings stay
/// bitwise-identical to a clean *untiled* 256-lane run — so this pins
/// the fault-containment invariant and the tiled-vs-untiled bitwise
/// contract in one shot.
#[test]
fn quarantined_lane_in_tiled_run_leaves_255_siblings_bitwise_identical() {
    let o = opts(60, 60, 43);
    let lanes = 256;
    let faulted = 137usize;

    let mut clean = compile_batched(EightSchools::classic(), 0, lanes).unwrap();
    let clean_res = run_chains_vectorized(&mut clean, &o, 6).unwrap();

    let plan = FaultPlan {
        faults: (300u64..500)
            .map(|e| Fault {
                at_eval: e,
                site: FaultSite::Forward,
                value: f64::NAN,
                lane: Some(faulted),
            })
            .collect(),
    };
    let tiled = compile_tiled(EightSchools::classic(), 0, lanes, 64)
        .unwrap()
        .with_threads(2);
    let mut faulty = FaultyBatchPotential::new(tiled, plan);
    let faulty_res = run_chains_vectorized(&mut faulty, &o, 6).unwrap();
    assert!(faulty.injected > 0, "tiled lane adversary never fired");

    let bad = &faulty_res[faulted];
    assert!(bad.quarantines > 0, "no draw was quarantined on the faulted tiled lane");
    assert!(bad.divergences >= bad.quarantines);
    assert_finite_samples(bad, "quarantined tiled lane");

    for k in (0..lanes).filter(|&k| k != faulted) {
        let (c, f) = (&clean_res[k], &faulty_res[k]);
        assert_eq!(c.samples, f.samples, "tiled lane {k} samples diverged from clean run");
        assert_eq!(c.step_size.to_bits(), f.step_size.to_bits(), "tiled lane {k} step size");
        assert_eq!(c.inv_mass, f.inv_mass, "tiled lane {k} inverse mass");
        assert_eq!(c.divergences, f.divergences, "tiled lane {k} divergences");
        assert_eq!(c.total_leapfrogs, f.total_leapfrogs, "tiled lane {k} leapfrogs");
        assert_eq!(f.quarantines, 0, "healthy tiled lane {k} reported quarantines");
    }
}

// ---------------------------------------------------------------------
// 3. SVI backoff
// ---------------------------------------------------------------------

/// Non-finite ELBO/gradient steps (forward and adjoint faults on the
/// particle potential) are skipped with learning-rate backoff: the
/// recorded ELBO trace stays finite end to end, the skip counter is
/// surfaced, and the fit still completes every requested step.
#[test]
fn svi_backoff_recovers_finite_elbo_trace() {
    let particles = 4;
    // step s consumes particle evals [s*K, s*K+K): poison steps ~100-104
    // (forward) and ~150-151 (adjoint)
    let mut faults = FaultPlan::nan_forward_at(&[400, 401, 405, 410, 416]).faults;
    faults.extend(FaultPlan::nan_adjoint_at(&[600, 604], 2).faults);
    let engine = ScalarParticles::new(
        FaultyPotential::new(
            compile(EightSchools::classic(), 0).unwrap(),
            FaultPlan { faults },
        ),
        particles,
    );
    let o = SviOptions {
        num_steps: 400,
        num_particles: particles,
        lr: 0.05,
        seed: 3,
        convergence: None,
        ..Default::default()
    };
    let result = NativeSvi::new(engine, &o).unwrap().run();

    assert!(result.skipped > 0, "no step was skipped despite injected faults");
    assert!(result.completed, "containable faults must not abort the run");
    assert_eq!(result.steps, o.num_steps, "skipped steps must be retried, not dropped");
    assert!(
        result.elbo_trace.iter().all(|e| e.is_finite()),
        "non-finite ELBO leaked into the trace"
    );
    assert!(
        result.guide.params().iter().all(|p| p.is_finite()),
        "non-finite guide parameter after contained faults"
    );
}

// ---------------------------------------------------------------------
// 4. bitwise resume under arbitrary interruption
// ---------------------------------------------------------------------

/// Run the checkpointed runner in small wall-clock slices (budget +
/// checkpoint + resume) until it completes — an automated
/// kill-and-resume cycle with arbitrary cut points — and require the
/// result to be bitwise-identical to one uninterrupted run.
fn interrupted_until_done(method: ChainMethod, o: &NutsOptions, tag: &str) -> Vec<ChainResult> {
    let path = tmp_path(tag);
    let _ = std::fs::remove_file(&path);
    let cfg = CheckpointConfig {
        path: Some(path.clone()),
        resume: true,
        every: 7,
        max_seconds: Some(0.02),
    };
    let model = EightSchools::classic();
    let mut slices = 0u32;
    loop {
        let (_, results, completed) =
            run_compiled_chains_checkpointed(&model, method, 2, 6, o, &cfg).unwrap();
        slices += 1;
        assert!(slices < 10_000, "budgeted runner made no progress");
        if completed {
            let _ = std::fs::remove_file(&path);
            return results;
        }
    }
}

fn assert_bitwise_equal(a: &[ChainResult], b: &[ChainResult], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: chain count");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.samples, y.samples, "{what}: chain {k} samples");
        assert_eq!(x.step_size.to_bits(), y.step_size.to_bits(), "{what}: chain {k} step size");
        assert_eq!(x.inv_mass, y.inv_mass, "{what}: chain {k} inverse mass");
        assert_eq!(x.divergences, y.divergences, "{what}: chain {k} divergences");
        assert_eq!(x.quarantines, y.quarantines, "{what}: chain {k} quarantines");
        assert_eq!(x.total_leapfrogs, y.total_leapfrogs, "{what}: chain {k} leapfrogs");
        assert_eq!(x.stats.accept_prob, y.stats.accept_prob, "{what}: chain {k} accepts");
        assert_eq!(x.stats.num_leapfrog, y.stats.num_leapfrog, "{what}: chain {k} stats");
    }
}

#[test]
fn resume_is_bitwise_identical_sequential() {
    let o = opts(80, 100, 57);
    let (_, plain) =
        run_compiled_chains_method(&EightSchools::classic(), ChainMethod::Sequential, 2, 6, &o)
            .unwrap();
    let resumed = interrupted_until_done(ChainMethod::Sequential, &o, "seq");
    assert_bitwise_equal(&plain, &resumed, "sequential kill-and-resume");
}

#[test]
fn resume_is_bitwise_identical_parallel() {
    let o = opts(80, 100, 58);
    let (_, plain) =
        run_compiled_chains_method(&EightSchools::classic(), ChainMethod::Parallel, 2, 6, &o)
            .unwrap();
    let resumed = interrupted_until_done(ChainMethod::Parallel, &o, "par");
    assert_bitwise_equal(&plain, &resumed, "parallel kill-and-resume");
}

#[test]
fn resume_is_bitwise_identical_vectorized() {
    let o = opts(80, 100, 59);
    let (_, plain) =
        run_compiled_chains_method(&EightSchools::classic(), ChainMethod::Vectorized, 2, 6, &o)
            .unwrap();
    let resumed = interrupted_until_done(ChainMethod::Vectorized, &o, "vec");
    assert_bitwise_equal(&plain, &resumed, "vectorized kill-and-resume");
}

/// Kill-and-resume through the **tiled** regime: at 80 chains (past
/// `TILED_LANE_THRESHOLD`) the checkpointed vectorized runner rides
/// `TiledBatchPotential`; slicing it at arbitrary wall-clock cuts and
/// resuming until done must still reproduce the uninterrupted run
/// bitwise, because checkpoint state is per-lane and the tiled engine
/// is bitwise-invisible.
#[test]
fn tiled_resume_is_bitwise_identical() {
    use fugue::coordinator::TILED_LANE_THRESHOLD;
    let chains = TILED_LANE_THRESHOLD + 16;
    let o = opts(40, 40, 67);
    let model = EightSchools::classic();
    let (_, plain) =
        run_compiled_chains_method(&model, ChainMethod::Vectorized, chains, 6, &o).unwrap();

    let path = tmp_path("tiled_vec");
    let _ = std::fs::remove_file(&path);
    let cfg = CheckpointConfig {
        path: Some(path.clone()),
        resume: true,
        every: 7,
        max_seconds: Some(0.02),
    };
    let mut slices = 0u32;
    let resumed = loop {
        let (_, results, completed) =
            run_compiled_chains_checkpointed(&model, ChainMethod::Vectorized, chains, 6, &o, &cfg)
                .unwrap();
        slices += 1;
        assert!(slices < 10_000, "budgeted tiled runner made no progress");
        if completed {
            let _ = std::fs::remove_file(&path);
            break results;
        }
    };
    assert_bitwise_equal(&plain, &resumed, "tiled kill-and-resume");
}

/// SVI: slice the fit with budget + checkpoint + resume until done and
/// require the ELBO trace and fitted guide to match an uninterrupted
/// `run_svi_native` fit bitwise.
#[test]
fn svi_resume_is_bitwise_identical() {
    let o = SviOptions {
        num_steps: 300,
        num_particles: 4,
        lr: 0.05,
        seed: 61,
        convergence: None,
        ..Default::default()
    };
    let model = EightSchools::classic();
    let (_, plain) = run_svi_native(&model, &o).unwrap();

    let path = tmp_path("svi");
    let _ = std::fs::remove_file(&path);
    let cfg = CheckpointConfig {
        path: Some(path.clone()),
        resume: true,
        every: 11,
        max_seconds: Some(0.02),
    };
    let mut slices = 0u32;
    let resumed = loop {
        let (_, result) = run_svi_checkpointed(&model, &o, &cfg).unwrap();
        slices += 1;
        assert!(slices < 10_000, "budgeted SVI made no progress");
        if result.completed {
            break result;
        }
    };
    let _ = std::fs::remove_file(&path);

    assert_eq!(plain.steps, resumed.steps, "SVI resume: step count");
    assert_eq!(plain.elbo_trace, resumed.elbo_trace, "SVI resume: ELBO trace");
    assert_eq!(plain.guide.params(), resumed.guide.params(), "SVI resume: guide params");
    assert_eq!(plain.skipped, resumed.skipped);
}

// ---------------------------------------------------------------------
// 5. divergence fingerprint
// ---------------------------------------------------------------------

/// Statistical soundness of the divergence counter everything above
/// routes through: Neal's funnel — the canonical pathological geometry —
/// must produce divergences, while a conjugate normal-mean model must
/// produce none.  (Referenced from `compile::zoo::NealsFunnel` docs.)
#[test]
fn funnel_diverges_conjugate_does_not() {
    let o = opts(400, 400, 2024);
    let (_, funnel) =
        run_compiled_chains_method(&NealsFunnel::classic(), ChainMethod::Sequential, 2, 8, &o)
            .unwrap();
    let funnel_div: u64 = funnel.iter().map(|r| r.divergences).sum();
    assert!(
        funnel_div > 0,
        "NUTS reported zero divergences on Neal's funnel — divergence detection is broken"
    );
    // funnel divergences are the geometry's fault, not injected faults:
    // nothing should have been quarantined
    assert_eq!(funnel.iter().map(|r| r.quarantines).sum::<u64>(), 0);

    let y: Vec<f64> = (0..50).map(|i| 0.3 + 0.01 * i as f64).collect();
    let (_, conj) = run_compiled_chains_method(
        &NormalMean { y, sigma: 1.0 },
        ChainMethod::Sequential,
        2,
        8,
        &o,
    )
    .unwrap();
    let conj_div: u64 = conj.iter().map(|r| r.divergences).sum();
    assert_eq!(
        conj_div, 0,
        "a well-conditioned conjugate model must sample divergence-free"
    );
}
