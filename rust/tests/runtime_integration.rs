//! Integration tests over the PJRT runtime with real artifacts:
//! manifest-driven loading, buffer reuse, fused + vmapped transitions,
//! stepwise potential, predict/loglik/ELBO executables.
//!
//! Only built with `--features pjrt` (the default build substitutes
//! stub handles); all tests skip gracefully when `artifacts/` is absent.
#![cfg(feature = "pjrt")]

use fugue::harness::builders::Workload;
use fugue::runtime::engine::{literal_to_f64, Engine, HostTensor};
use fugue::runtime::{NutsStep, PjrtPotential};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

#[test]
fn manifest_lists_every_model_bundle() {
    let Some(engine) = engine() else { return };
    let models = engine.manifest.models();
    for expected in ["hmm", "covtype_small"] {
        assert!(
            models.iter().any(|m| m == expected),
            "manifest missing {expected}: {models:?}"
        );
    }
    // every nuts_step has a matching potential_and_grad with equal dim
    for e in engine.manifest.entries.values() {
        if e.kind == "nuts_step" {
            let pot = engine
                .manifest
                .find(&e.model, "potential_and_grad", &e.dtype)
                .expect("missing potential for nuts_step");
            assert_eq!(pot.dim, e.dim, "{}: dim mismatch", e.name);
        }
    }
}

#[test]
fn executable_cache_returns_same_instance() {
    let Some(engine) = engine() else { return };
    let a = engine.executable("hmm_potential_and_grad_f32").unwrap();
    let b = engine.executable("hmm_potential_and_grad_f32").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn fused_step_is_deterministic_in_key() {
    let Some(engine) = engine() else { return };
    let workload = Workload::for_model(&engine, "hmm", 1).unwrap();
    let entry = engine.manifest.find("hmm", "nuts_step", "f32").unwrap();
    let dt = entry.inputs[1].dtype;
    let mut step =
        NutsStep::new(&engine, "hmm_nuts_step_f32", &workload.tensors(dt).unwrap()).unwrap();
    let dim = entry.dim;
    let z = vec![0.3; dim];
    let a = step.step([7, 9], &z, 0.05, &vec![1.0; dim]).unwrap();
    let b = step.step([7, 9], &z, 0.05, &vec![1.0; dim]).unwrap();
    assert_eq!(a.z, b.z);
    assert_eq!(a.num_leapfrog, b.num_leapfrog);
    let c = step.step([7, 10], &z, 0.05, &vec![1.0; dim]).unwrap();
    assert_ne!(a.z, c.z, "different key must give different draw");
}

#[test]
fn fused_step_respects_max_tree_depth_budget() {
    let Some(engine) = engine() else { return };
    let workload = Workload::for_model(&engine, "hmm", 1).unwrap();
    let entry = engine.manifest.find("hmm", "nuts_step", "f32").unwrap();
    let dt = entry.inputs[1].dtype;
    let mut step =
        NutsStep::new(&engine, "hmm_nuts_step_f32", &workload.tensors(dt).unwrap()).unwrap();
    let dim = entry.dim;
    // tiny step size -> deep tree, still bounded by 2^max_depth
    let tr = step.step([1, 1], &vec![0.0; dim], 1e-4, &vec![1.0; dim]).unwrap();
    let max_leaves = 1u32 << entry.meta_usize("max_tree_depth").unwrap_or(10);
    assert!(tr.num_leapfrog <= max_leaves, "{} > {}", tr.num_leapfrog, max_leaves);
    assert!(tr.depth as usize <= entry.meta_usize("max_tree_depth").unwrap_or(10));
}

#[test]
fn vmap_step_matches_per_chain_shapes() {
    let Some(engine) = engine() else { return };
    let name = "hmm_nuts_step_vmap4_f32";
    if engine.manifest.get(name).is_err() {
        return;
    }
    let workload = Workload::for_model(&engine, "hmm", 1).unwrap();
    let entry = engine.manifest.get(name).unwrap().clone();
    let dt = entry.inputs[1].dtype;
    let mut step = NutsStep::new(&engine, name, &workload.tensors(dt).unwrap()).unwrap();
    let k = entry.meta_usize("chains").unwrap();
    let dim = entry.dim;
    let keys: Vec<[u32; 2]> = (0..k as u32).map(|i| [i, 100 + i]).collect();
    let trs = step
        .step_vmap(&keys, &vec![0.2; k * dim], &vec![0.05; k], &vec![1.0; k * dim])
        .unwrap();
    assert_eq!(trs.len(), k);
    for tr in &trs {
        assert_eq!(tr.z.len(), dim);
        assert!(tr.potential.is_finite());
    }
    // different keys -> chains decorrelate
    assert_ne!(trs[0].z, trs[1].z);
}

#[test]
fn stepwise_potential_counts_dispatches() {
    let Some(engine) = engine() else { return };
    let workload = Workload::for_model(&engine, "covtype_small", 1).unwrap();
    let entry = engine
        .manifest
        .find("covtype_small", "potential_and_grad", "f32")
        .unwrap();
    let dt = entry.inputs[0].dtype;
    let mut pot = PjrtPotential::new(
        &engine,
        "covtype_small_potential_and_grad_f32",
        &workload.tensors(dt).unwrap(),
    )
    .unwrap();
    let dim = entry.dim;
    let mut g = vec![0.0; dim];
    use fugue::mcmc::Potential;
    for i in 0..5 {
        let u = pot.value_and_grad(&vec![0.01 * i as f64; dim], &mut g);
        assert!(u.is_finite());
    }
    assert_eq!(pot.num_evals(), 5);
}

#[test]
fn f32_and_f64_artifacts_agree_on_potential() {
    let Some(engine) = engine() else { return };
    let workload = Workload::for_model(&engine, "hmm", 3).unwrap();
    let mut pots = Vec::new();
    for dtype in ["f32", "f64"] {
        let name = format!("hmm_potential_and_grad_{dtype}");
        let entry = engine.manifest.get(&name).unwrap();
        let dt = entry.inputs[0].dtype;
        pots.push((
            PjrtPotential::new(&engine, &name, &workload.tensors(dt).unwrap()).unwrap(),
            entry.dim,
        ));
    }
    let dim = pots[0].1;
    let z = vec![0.25; dim];
    let mut g32 = vec![0.0; dim];
    let mut g64 = vec![0.0; dim];
    let u32v = pots[0].0.eval(&z, &mut g32).unwrap();
    let u64v = pots[1].0.eval(&z, &mut g64).unwrap();
    assert!(
        (u32v - u64v).abs() / (1.0 + u64v.abs()) < 1e-4,
        "f32 {u32v} vs f64 {u64v}"
    );
}

#[test]
fn predict_artifact_produces_binary_labels() {
    let Some(engine) = engine() else { return };
    let Ok(exe) = engine.executable("covtype_predict_f32") else {
        return;
    };
    let entry = exe.entry.clone();
    let s = entry.meta_usize("num_samples").unwrap();
    let x_spec = &entry.inputs[3];
    let (n, d) = (x_spec.shape[0], x_spec.shape[1]);
    let keys = HostTensor::U32((0..2 * s as u32).collect(), vec![s, 2]);
    let ms = HostTensor::F32(vec![0.1; s * d], vec![s, d]);
    let bs = HostTensor::F32(vec![0.0; s], vec![s]);
    let x = HostTensor::F32(vec![0.5; n * d], vec![n, d]);
    let bufs: Vec<_> = [keys, ms, bs, x]
        .iter()
        .map(|t| engine.upload(t).unwrap())
        .collect();
    let arg_refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let outs = exe.run_buffers(&arg_refs).unwrap();
    let y = literal_to_f64(&outs[0]).unwrap();
    assert_eq!(y.len(), s * n);
    assert!(y.iter().all(|&v| v == 0.0 || v == 1.0));
}
