//! Unit tests for the warmup internals: dual-averaged step-size
//! adaptation must actually land at the target acceptance rate on a
//! model with a known geometry, and the streaming Welford moments must
//! match a closed-form two-pass reference to near machine precision.

use fugue::coordinator::{run_chain, NativeSampler, NutsOptions, TreeAlgorithm};
use fugue::mcmc::{DualAverage, Potential, Welford};
use fugue::rng::Rng;

/// Standard d-dimensional Gaussian: U(z) = 0.5 |z|^2.
struct StdGauss {
    dim: usize,
}

impl Potential for StdGauss {
    fn dim(&self) -> usize {
        self.dim
    }
    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        grad.copy_from_slice(z);
        0.5 * z.iter().map(|v| v * v).sum::<f64>()
    }
}

/// Mean acceptance probability over the sampling phase of a NUTS run
/// on a known Gaussian, for a given dual-averaging target.
fn sampled_accept(target: f64, seed: u64) -> f64 {
    let dim = 5;
    let mut sampler = NativeSampler::new(StdGauss { dim }, TreeAlgorithm::Iterative, 10);
    let opts = NutsOptions {
        num_warmup: 800,
        num_samples: 800,
        target_accept: target,
        seed,
        ..Default::default()
    };
    let init = vec![0.5; dim];
    let res = run_chain(&mut sampler, &init, &opts).unwrap();
    let accepts = &res.stats.accept_prob[opts.num_warmup..];
    accepts.iter().sum::<f64>() / accepts.len() as f64
}

/// Dual averaging must converge to the requested acceptance target on
/// a standard Gaussian — for the default 0.8 and a loose 0.6 target.
#[test]
fn dual_averaging_reaches_target_accept_on_gaussian() {
    let a80 = sampled_accept(0.8, 42);
    assert!(
        (a80 - 0.8).abs() < 0.1,
        "target 0.8: sampled accept {a80:.3}"
    );
    let a60 = sampled_accept(0.6, 43);
    assert!(
        (a60 - 0.6).abs() < 0.15,
        "target 0.6: sampled accept {a60:.3}"
    );
    // higher target must adapt to a smaller step size / higher accept
    assert!(a80 > a60 - 0.05, "targets not ordered: {a80:.3} vs {a60:.3}");
}

/// The dual-averaging iterate itself (no sampler in the loop) finds
/// the fixed point of a synthetic accept-vs-step curve for several
/// targets.
#[test]
fn dual_averaging_fixed_point_tracks_target() {
    for &target in &[0.6, 0.8, 0.95] {
        let mut da = DualAverage::new(1.0, target);
        for _ in 0..3000 {
            let eps = da.step_size();
            // accept falls smoothly with step size
            let accept = (-2.0 * eps).exp();
            da.update(accept);
        }
        let eps = da.final_step_size();
        let accept = (-2.0 * eps).exp();
        assert!(
            (accept - target).abs() < 0.03,
            "target {target}: converged accept {accept:.3} at eps {eps:.4}"
        );
    }
}

/// Streaming Welford moments vs the closed-form two-pass reference on
/// the same data: agreement to 1e-12 (relative), for mean and
/// variance, including after interleaved resets.
#[test]
fn welford_matches_two_pass_reference_to_1e12() {
    let dim = 3;
    let n = 2000;
    let mut rng = Rng::new(123);
    let data: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            (0..dim)
                .map(|d| 3.0 * rng.normal() + d as f64 * 10.0)
                .collect()
        })
        .collect();

    let mut w = Welford::new(dim);
    for x in &data {
        w.update(x);
    }

    for d in 0..dim {
        let mean_ref = data.iter().map(|x| x[d]).sum::<f64>() / n as f64;
        let var_ref = data
            .iter()
            .map(|x| (x[d] - mean_ref) * (x[d] - mean_ref))
            .sum::<f64>()
            / (n as f64 - 1.0);
        let tol_m = 1e-12 * (1.0 + mean_ref.abs());
        let tol_v = 1e-12 * (1.0 + var_ref.abs());
        assert!(
            (w.mean[d] - mean_ref).abs() < tol_m,
            "dim {d}: mean {} vs {}",
            w.mean[d],
            mean_ref
        );
        assert!(
            (w.variance()[d] - var_ref).abs() < tol_v,
            "dim {d}: var {} vs {}",
            w.variance()[d],
            var_ref
        );
    }
}

/// The Stan-style regularized variance must equal its closed form
/// `w * var + 1e-3 * 5/(n+5)` with `w = n/(n+5)` exactly (same
/// arithmetic), and shrink toward 1e-3 for tiny samples.
#[test]
fn welford_regularization_matches_closed_form() {
    let mut w = Welford::new(1);
    let xs = [2.0, 2.5, 1.5, 2.2, 1.8, 2.6, 1.4];
    for &x in &xs {
        w.update(&[x]);
    }
    let n = xs.len() as f64;
    let var = w.variance()[0];
    let expect = n / (n + 5.0) * var + 1e-3 * (5.0 / (n + 5.0));
    let got = w.regularized_variance()[0];
    assert!(
        (got - expect).abs() < 1e-15,
        "regularized {got} vs closed form {expect}"
    );

    // tiny sample: the shrinkage prior dominates
    let mut w2 = Welford::new(1);
    w2.update(&[100.0]);
    assert!(w2.regularized_variance()[0] < 0.01);
}

/// Welford reset must restore the exact fresh-estimator state.
#[test]
fn welford_reset_matches_fresh() {
    let mut rng = Rng::new(9);
    let a: Vec<Vec<f64>> = (0..50).map(|_| vec![rng.normal(), rng.normal()]).collect();
    let b: Vec<Vec<f64>> = (0..50).map(|_| vec![rng.normal(), rng.normal()]).collect();

    let mut reused = Welford::new(2);
    for x in &a {
        reused.update(x);
    }
    reused.reset();
    for x in &b {
        reused.update(x);
    }

    let mut fresh = Welford::new(2);
    for x in &b {
        fresh.update(x);
    }

    assert_eq!(reused.mean, fresh.mean);
    assert_eq!(reused.variance(), fresh.variance());
    assert_eq!(reused.count, fresh.count);
}
