//! Property tests (self-built driver, `fugue::util::prop`) on the
//! coordinator-side invariants: the Appendix A bit-twiddling and storage
//! scheme, Welford moments, dual-averaging behaviour, transforms,
//! autodiff vs finite differences, ESS sanity, JSON round-trips.

use fugue::autodiff::{finite_diff, Tape, Var};
use fugue::mcmc::nuts_iterative::{bit_count, candidate_range, trailing_ones};
use fugue::mcmc::{DualAverage, Welford};
use fugue::ppl::transforms::{stick_breaking, stick_breaking_inverse};
use fugue::util::json::Json;
use fugue::util::prop::{all_close, check, close};

/// Oracle: C(n) by progressively clearing trailing 1-bits (Appendix A).
fn candidate_set(n: u32) -> Vec<u32> {
    let mut out = Vec::new();
    let mut m = n;
    for _ in 0..trailing_ones(n) {
        m &= m - 1;
        out.push(m);
    }
    out
}

#[test]
fn prop_iterative_storage_always_holds_candidates() {
    // Replay the S[BitCount(k)] storage scheme over whole trees and
    // assert that at every odd n the storage rows [i_min, i_max] hold
    // exactly C(n) — the memory-efficiency claim of Appendix A.
    check("storage holds C(n)", 64, |rng| {
        let depth = 1 + rng.below(10) as u32;
        let mut storage: Vec<Option<u32>> = vec![None; depth.max(1) as usize + 1];
        for n in 0..(1u32 << depth) {
            if n % 2 == 0 {
                storage[bit_count(n) as usize] = Some(n);
            } else {
                let (i_min, i_max) = candidate_range(n);
                let got: Vec<u32> = (i_min..=i_max)
                    .map(|k| storage[k as usize].ok_or(format!("S[{k}] empty at n={n}")))
                    .collect::<Result<_, _>>()?;
                let mut expect = candidate_set(n);
                expect.sort_unstable();
                let mut got_sorted = got.clone();
                got_sorted.sort_unstable();
                if got_sorted != expect {
                    return Err(format!("n={n}: got {got_sorted:?}, want {expect:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_recursive_and_iterative_checks_coincide() {
    // Pair set (left leaf, right leaf) checked by Algorithm 1 ==
    // pairs checked by Algorithm 2 via C(n), for all depths.
    fn recursive_checks(base: u32, depth: u32, out: &mut Vec<(u32, u32)>) {
        if depth == 0 {
            return;
        }
        let half = 1 << (depth - 1);
        recursive_checks(base, depth - 1, out);
        recursive_checks(base + half, depth - 1, out);
        out.push((base, base + (1 << depth) - 1));
    }
    for depth in 1..=10u32 {
        let mut rec = Vec::new();
        recursive_checks(0, depth, &mut rec);
        let mut iter = Vec::new();
        for n in 0..(1u32 << depth) {
            if n % 2 == 1 {
                for m in candidate_set(n) {
                    iter.push((m, n));
                }
            }
        }
        rec.sort_unstable();
        iter.sort_unstable();
        assert_eq!(rec, iter, "depth {depth}");
    }
}

#[test]
fn prop_candidate_range_reproduces_appendix_a_sets() {
    // For every odd n: |C(n)| == TrailingOnes(n), i_max == BitCount(n-1),
    // and the candidates' bit counts tile [i_min, i_max] exactly — i.e.
    // candidate_range addresses precisely the storage rows holding C(n).
    check("candidate_range == C(n)", 300, |rng| {
        let n = ((rng.next_u64() as u32) & ((1 << 24) - 1)) | 1; // odd
        let set = candidate_set(n);
        if set.len() != trailing_ones(n) as usize {
            return Err(format!(
                "n={n}: |C(n)| = {} but trailing_ones = {}",
                set.len(),
                trailing_ones(n)
            ));
        }
        let (i_min, i_max) = candidate_range(n);
        if i_max != bit_count(n - 1) {
            return Err(format!("n={n}: i_max {} != BitCount(n-1) {}", i_max, bit_count(n - 1)));
        }
        let mut bcs: Vec<u32> = set.iter().map(|m| bit_count(*m)).collect();
        bcs.sort_unstable();
        let expect: Vec<u32> = (i_min..=i_max).collect();
        if bcs != expect {
            return Err(format!("n={n}: candidate bitcounts {bcs:?} != rows {expect:?}"));
        }
        Ok(())
    });
}

#[test]
fn appendix_a_worked_examples() {
    // the paper's worked example: n = 11 = 0b1011, C(11) = {10, 8}
    assert_eq!(candidate_set(11), vec![10, 8]);
    assert_eq!(candidate_range(11), (1, 2));
    // n = 7 = 0b111: C(7) = {6, 4, 0}
    assert_eq!(candidate_set(7), vec![6, 4, 0]);
    assert_eq!(candidate_range(7), (0, 2));
    // n = 5 = 0b101: C(5) = {4}
    assert_eq!(candidate_set(5), vec![4]);
    assert_eq!(candidate_range(5), (1, 1));
}

#[test]
fn prop_bitcount_bounds_storage_index() {
    // max BitCount of even n < 2^d is d-1 => storage of size d suffices
    check("bitcount bound", 200, |rng| {
        let d = 1 + rng.below(20) as u32;
        let n = (rng.next_u64() as u32) & ((1u32 << d) - 1) & !1; // even < 2^d
        if bit_count(n) > d.saturating_sub(1) {
            return Err(format!("even n={n} < 2^{d} has bitcount {}", bit_count(n)));
        }
        Ok(())
    });
}

#[test]
fn prop_welford_matches_two_pass() {
    check("welford == two-pass", 50, |rng| {
        let n = 2 + rng.below(300);
        let dim = 1 + rng.below(8);
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() * 3.0 + 1.0).collect())
            .collect();
        let mut w = Welford::new(dim);
        for x in &xs {
            w.update(x);
        }
        for d in 0..dim {
            let mean = xs.iter().map(|x| x[d]).sum::<f64>() / n as f64;
            let var =
                xs.iter().map(|x| (x[d] - mean).powi(2)).sum::<f64>() / (n as f64 - 1.0);
            close(w.mean[d], mean, 1e-10, 1e-10, "mean")?;
            close(w.variance()[d], var, 1e-9, 1e-9, "var")?;
        }
        Ok(())
    });
}

#[test]
fn prop_dual_averaging_fixed_point() {
    // For any smooth monotone accept(eps) crossing the target, dual
    // averaging settles where accept ~= target.
    check("dual averaging converges", 20, |rng| {
        let eps_star = 0.05 + rng.uniform() * 2.0;
        let sharp = 2.0 + rng.uniform() * 6.0;
        let target = 0.6 + rng.uniform() * 0.3;
        let accept = |eps: f64| (-(sharp) * (eps - eps_star)).exp().min(1.0);
        let mut da = DualAverage::new(1.0, target);
        for _ in 0..20_000 {
            let a = accept(da.step_size());
            da.update(a);
        }
        let final_accept = accept(da.final_step_size());
        close(final_accept, target, 0.15, 0.0, "final accept")
    });
}

#[test]
fn prop_stick_breaking_roundtrip_and_simplex() {
    check("stick breaking", 100, |rng| {
        let k = 2 + rng.below(12);
        let x: Vec<f64> = (0..k - 1).map(|_| rng.normal() * 2.0).collect();
        let (y, _ladj) = stick_breaking(&x);
        let sum: f64 = y.iter().sum();
        close(sum, 1.0, 1e-9, 0.0, "sum")?;
        if y.iter().any(|&v| v <= 0.0) {
            return Err("non-positive simplex coordinate".to_string());
        }
        let x2 = stick_breaking_inverse(&y);
        all_close(&x, &x2, 1e-6, 1e-6, "roundtrip")
    });
}

#[test]
fn prop_tape_gradients_match_finite_diff() {
    check("tape vs finite diff", 60, |rng| {
        let n = 2 + rng.below(6);
        let x: Vec<f64> = (0..n).map(|_| 0.2 + rng.uniform() * 2.0).collect();
        let build = |t: &mut Tape, v: &[Var]| {
            // mixed expression touching every op family
            let s = t.sum(v);
            let lse = t.logsumexp(v);
            let p = t.mul(v[0], v[1 % v.len()]);
            let e = t.exp(v[0]);
            let sq = t.sqrt(v[1 % v.len()]);
            let l = t.ln(s);
            let sp = t.softplus(p);
            let a = t.add(lse, l);
            let b = t.add(e, sq);
            let c = t.add(sp, b);
            let d = t.sub(a, c);
            let sg = t.sigmoid(d);
            t.mul(sg, s)
        };
        let eval = |xs: &[f64]| {
            let mut t = Tape::new();
            let vars: Vec<Var> = xs.iter().map(|&v| t.input(v)).collect();
            let out = build(&mut t, &vars);
            t.value(out)
        };
        let mut t = Tape::new();
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let out = build(&mut t, &vars);
        let adj = t.grad(out);
        let grads: Vec<f64> = vars.iter().map(|v| adj[v.0 as usize]).collect();
        let fd = finite_diff(&x, eval, 1e-7);
        all_close(&grads, &fd, 1e-5, 1e-4, "grad")
    });
}

#[test]
fn prop_json_roundtrip() {
    check("json roundtrip", 100, |rng| {
        // random JSON value
        fn gen(rng: &mut fugue::rng::Rng, depth: usize) -> Json {
            match if depth > 3 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Num((rng.normal() * 100.0).round()),
                3 => Json::Str(format!("s{}-\"q\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 0);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        if back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

#[test]
fn prop_ess_bounded_by_total_draws() {
    check("ess <= total", 30, |rng| {
        let n = 64 + rng.below(512);
        let rho = rng.uniform() * 0.9;
        let mut x = vec![0.0; n];
        for i in 1..n {
            x[i] = rho * x[i - 1] + rng.normal();
        }
        let ess = fugue::diagnostics::effective_sample_size(&[x]);
        if !(ess > 0.0 && ess <= n as f64 + 1e-9) {
            return Err(format!("ess {ess} out of (0, {n}]"));
        }
        Ok(())
    });
}
