//! Bench: the two design ablations — E7 (vmapped chains vs sequential
//! dispatch) and E8 (iterative vs recursive tree building).

use fugue::config::Settings;
use fugue::harness::ablations;
use fugue::runtime::engine::Engine;

fn main() {
    let mut settings = Settings::default();
    settings.quick = std::env::var("FUGUE_FULL").is_err();
    settings.full = !settings.quick;
    let engine = match Engine::new(&settings.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping bench (no artifacts): {e:#}");
            return;
        }
    };
    match ablations::ablate_tree(&engine, &settings) {
        Ok(report) => println!("{report}"),
        Err(e) => eprintln!("ablate-tree failed: {e:#}"),
    }
    match ablations::ablate_vmap(&engine, &settings) {
        Ok(report) => println!("{report}"),
        Err(e) => eprintln!("ablate-vmap failed: {e:#}"),
    }
}
