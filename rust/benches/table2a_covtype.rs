//! Bench: Table 2a, COVTYPE column (E2). CovType-substitute logistic
//! regression at the manifest's baked N (50k default; the paper's
//! 581,012 via `python -m compile.aot --covtype-n 581012`).

use fugue::config::Settings;
use fugue::harness::table2a;
use fugue::runtime::engine::Engine;

fn main() {
    let mut settings = Settings::default();
    settings.quick = std::env::var("FUGUE_FULL").is_err();
    settings.full = !settings.quick;
    let engine = match Engine::new(&settings.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping bench (no artifacts): {e:#}");
            return;
        }
    };
    for model in ["covtype", "covtype_small"] {
        match table2a::run(&engine, &settings, Some(model)) {
            Ok(report) => println!("{report}"),
            Err(e) => eprintln!("bench {model} failed: {e:#}"),
        }
    }
}
