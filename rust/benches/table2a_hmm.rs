//! Bench: Table 2a, HMM column (E1). Thin wrapper over the harness so
//! `cargo bench` regenerates the paper row with reduced defaults
//! (env FUGUE_FULL=1 for paper-scale).

use fugue::config::Settings;
use fugue::harness::table2a;
use fugue::runtime::engine::Engine;

fn main() {
    let mut settings = Settings::default();
    settings.quick = std::env::var("FUGUE_FULL").is_err();
    settings.full = !settings.quick;
    let engine = match Engine::new(&settings.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping bench (no artifacts): {e:#}");
            return;
        }
    };
    match table2a::run(&engine, &settings, Some("hmm")) {
        Ok(report) => println!("{report}"),
        Err(e) => eprintln!("bench failed: {e:#}"),
    }
}
