//! Micro-benchmarks of the L3 substrates (no artifacts needed): autodiff
//! tape throughput, native potentials, RNG, ESS, PJRT dispatch overhead
//! when artifacts exist.  These feed the §Perf log in EXPERIMENTS.md.

use fugue::data;
use fugue::mcmc::Potential;
use fugue::models::{HmmNative, LogisticNative, SkimNative};
use fugue::models::skim::SkimHypers;
use fugue::rng::Rng;
use fugue::util::timer::bench;

fn main() {
    println!("{:<44} {:>12} {:>12}", "microbench", "median", "mean");
    let mut report = |name: &str, t: fugue::util::timer::Timing| {
        println!(
            "{:<44} {:>9.3} ms {:>9.3} ms",
            name,
            t.median_ms(),
            t.mean_ms()
        );
    };

    // RNG throughput
    {
        let mut rng = Rng::new(0);
        let mut out = vec![0.0; 100_000];
        report(
            "rng: 100k normals",
            bench(3, 20, || rng.fill_normal(&mut out)),
        );
    }

    // native potential evaluations (the Stan-architecture leapfrog body)
    {
        let d = data::make_hmm(0, 600, 100, 3, 10);
        let mut pot = HmmNative::new(d.obs, d.sup_states, 3, 10);
        let z = vec![0.1; pot.dim()];
        let mut g = vec![0.0; pot.dim()];
        report(
            "hmm native potential_and_grad (T=600)",
            bench(3, 50, || {
                let _ = pot.value_and_grad(&z, &mut g);
            }),
        );
    }
    {
        let d = data::make_covtype_like(0, 50_000, 54);
        let mut pot = LogisticNative::new(d.x, d.y, 50_000, 54);
        let z = vec![0.05; pot.dim()];
        let mut g = vec![0.0; pot.dim()];
        report(
            "logistic native potential_and_grad (N=50k)",
            bench(2, 10, || {
                let _ = pot.value_and_grad(&z, &mut g);
            }),
        );
    }
    {
        let d = data::make_skim(0, 200, 100, 3);
        let mut pot = SkimNative::new(d.x, d.y, 200, 100, SkimHypers::default());
        let z = vec![0.1; pot.dim()];
        let mut g = vec![0.0; pot.dim()];
        report(
            "skim native potential_and_grad (N=200,p=100)",
            bench(2, 10, || {
                let _ = pot.value_and_grad(&z, &mut g);
            }),
        );
    }

    // ESS cost
    {
        let mut rng = Rng::new(1);
        let chain: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        let chains = [chain];
        report(
            "ess: 1 chain x 1000 draws",
            bench(3, 30, || {
                let _ = fugue::diagnostics::effective_sample_size(&chains);
            }),
        );
    }

    // PJRT dispatch overhead: potential_and_grad on the smallest model
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use fugue::harness::builders::Workload;
        use fugue::runtime::engine::Engine;
        use fugue::runtime::PjrtPotential;
        let engine = Engine::new("artifacts").unwrap();
        if let Ok(entry) = engine.manifest.get("hmm_potential_and_grad_f32") {
            let dim = entry.dim;
            let dt = entry.inputs[0].dtype;
            let workload = Workload::for_model(&engine, "hmm", 0).unwrap();
            let mut pot = PjrtPotential::new(
                &engine,
                "hmm_potential_and_grad_f32",
                &workload.tensors(dt).unwrap(),
            )
            .unwrap();
            let z = vec![0.1; dim];
            let mut g = vec![0.0; dim];
            report(
                "hmm PJRT potential_and_grad dispatch",
                bench(5, 50, || {
                    let _ = pot.eval(&z, &mut g).unwrap();
                }),
            );
        }
        if let Ok(entry) = engine.manifest.get("hmm_nuts_step_f32") {
            let dim = entry.dim;
            let dt = entry.inputs[1].dtype;
            let workload = Workload::for_model(&engine, "hmm", 0).unwrap();
            let mut step = fugue::runtime::NutsStep::new(
                &engine,
                "hmm_nuts_step_f32",
                &workload.tensors(dt).unwrap(),
            )
            .unwrap();
            let z = vec![0.1; dim];
            let mass = vec![1.0; dim];
            let mut k = 0u32;
            report(
                "hmm fused nuts_step dispatch (whole draw)",
                bench(5, 50, || {
                    k += 1;
                    let _ = step.step([k, 1], &z, 0.05, &mass).unwrap();
                }),
            );
        }
    } else {
        println!("(artifacts/ absent: skipping PJRT micro-benches)");
    }
}
