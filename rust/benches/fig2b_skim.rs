//! Bench: Fig 2b (E3) — SKIM ms/effective-sample vs dimensionality.

use fugue::config::Settings;
use fugue::harness::fig2b;
use fugue::runtime::engine::Engine;

fn main() {
    let mut settings = Settings::default();
    settings.quick = std::env::var("FUGUE_FULL").is_err();
    settings.full = !settings.quick;
    let engine = match Engine::new(&settings.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping bench (no artifacts): {e:#}");
            return;
        }
    };
    match fig2b::run(&engine, &settings) {
        Ok(report) => println!("{report}"),
        Err(e) => eprintln!("bench failed: {e:#}"),
    }
}
