//! The metrics registry and the [`Recorder`] handle the hot paths hold.
//!
//! Design contract (enforced by `tests/observability.rs` and
//! `tests/alloc_free.rs`):
//!
//! * **Zero-allocation in steady state.** Every slot a recording can
//!   touch — counters, gauges, the tree-depth histogram, the
//!   trajectory rings, the span accumulators — is preallocated when
//!   the registry is built.  Recording is a handful of relaxed atomic
//!   stores; the rings overwrite in place.
//! * **Bitwise-neutral by construction.** The recorder only *observes*
//!   values the engines already computed (draw statistics, step sizes,
//!   ELBO values).  It never consumes RNG draws, never reorders or
//!   introduces floating-point operations on the inference path, and
//!   nothing it stores is ever read back by an engine.  Recorder-on
//!   and recorder-off runs are therefore bitwise identical; the only
//!   thing recording can perturb is wall-clock time, which is already
//!   outside the bitwise contract (see `coordinator/checkpoint.rs`).
//! * **Always compiled, runtime-toggled.** [`Recorder`] is a `Copy`
//!   wrapper over `Option<&'static MetricsRegistry>`; the disabled
//!   handle costs one branch per call site.  Registries are leaked
//!   (`'static`) so handles can be copied freely across threads.

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Tree depths land in `min(depth, DEPTH_BUCKETS - 1)`; NUTS depth is
/// capped well below this in practice (`max_tree_depth` ≤ 10–12).
pub const DEPTH_BUCKETS: usize = 32;

/// Capacity of each trajectory ring (step size, acceptance statistic,
/// ELBO).  Rings overwrite oldest-first; `pushed` keeps the total so
/// exporters can report how much history was dropped.
pub const RING_CAPACITY: usize = 1024;

/// Forward/reverse sweep spans are sampled one-in-N evaluations so the
/// monotonic-clock reads stay far below the <1% overhead bar even for
/// sub-microsecond potentials.
pub const SWEEP_SAMPLE_PERIOD: u64 = 64;

/// Monotonic event counters, updated with relaxed `fetch_add`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Counter {
    /// completed NUTS draws (every lane of every chain, warmup included)
    Draws,
    /// leapfrog steps across all draws
    Leapfrogs,
    /// draws that ended diverging
    Divergences,
    /// draws quarantined at a non-finite starting energy
    Quarantines,
    /// accepted SVI steps
    SviSteps,
    /// SVI steps skipped on a non-finite ELBO/gradient
    SviSkips,
    /// completed passes over a subsampled dataset
    Epochs,
    /// minibatch rows served by the scheduler
    RowsStreamed,
    /// batched potential evaluations through the tiled engine
    TileEvals,
    /// per-tile gathers (lane-block copies in)
    TileGathers,
    /// per-tile scatters (lane-block copies out)
    TileScatters,
    /// checkpoint files written
    CheckpointWrites,
    /// metrics snapshots written
    SnapshotWrites,
    /// forward instructions in the active optimized plan (absolute, stored)
    PlanFwdInstrs,
    /// reverse instructions in the active optimized plan (absolute, stored)
    PlanBwdInstrs,
}

pub const NUM_COUNTERS: usize = 15;

impl Counter {
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::Draws,
        Counter::Leapfrogs,
        Counter::Divergences,
        Counter::Quarantines,
        Counter::SviSteps,
        Counter::SviSkips,
        Counter::Epochs,
        Counter::RowsStreamed,
        Counter::TileEvals,
        Counter::TileGathers,
        Counter::TileScatters,
        Counter::CheckpointWrites,
        Counter::SnapshotWrites,
        Counter::PlanFwdInstrs,
        Counter::PlanBwdInstrs,
    ];

    /// Stable snake_case name used by the exporters.
    pub fn name(self) -> &'static str {
        match self {
            Counter::Draws => "draws",
            Counter::Leapfrogs => "leapfrogs",
            Counter::Divergences => "divergences",
            Counter::Quarantines => "quarantines",
            Counter::SviSteps => "svi_steps",
            Counter::SviSkips => "svi_skips",
            Counter::Epochs => "epochs",
            Counter::RowsStreamed => "rows_streamed",
            Counter::TileEvals => "tile_evals",
            Counter::TileGathers => "tile_gathers",
            Counter::TileScatters => "tile_scatters",
            Counter::CheckpointWrites => "checkpoint_writes",
            Counter::SnapshotWrites => "snapshot_writes",
            Counter::PlanFwdInstrs => "plan_fwd_instrs",
            Counter::PlanBwdInstrs => "plan_bwd_instrs",
        }
    }
}

/// Last-value gauges, stored as `f64` bit patterns in an `AtomicU64`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Gauge {
    /// current NUTS step size (last recorded lane)
    StepSize,
    /// acceptance statistic of the last recorded draw
    AcceptProb,
    /// last SVI ELBO estimate
    Elbo,
    /// gradient L2 norm of the last SVI step
    GradNorm,
    /// ELBO Monte-Carlo standard error over the convergence window
    ElboMcse,
    /// current SVI learning-rate backoff factor (1.0 = healthy)
    LrBackoff,
}

pub const NUM_GAUGES: usize = 6;

impl Gauge {
    pub const ALL: [Gauge; NUM_GAUGES] = [
        Gauge::StepSize,
        Gauge::AcceptProb,
        Gauge::Elbo,
        Gauge::GradNorm,
        Gauge::ElboMcse,
        Gauge::LrBackoff,
    ];

    /// Stable snake_case name used by the exporters.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::StepSize => "step_size",
            Gauge::AcceptProb => "accept_prob",
            Gauge::Elbo => "elbo",
            Gauge::GradNorm => "grad_norm",
            Gauge::ElboMcse => "elbo_mcse",
            Gauge::LrBackoff => "lr_backoff",
        }
    }
}

/// Monotonic-clock timing spans aggregated as (total nanos, count).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum SpanKind {
    /// warmup phase wall-clock (one record per chain/run)
    Warmup,
    /// sampling phase wall-clock (one record per chain/run)
    Sampling,
    /// one NUTS draw (tree build), scalar path
    Draw,
    /// forward sweep of the frozen/optimized program (sampled 1-in-N)
    ForwardSweep,
    /// reverse sweep of the frozen/optimized program (sampled 1-in-N)
    ReverseSweep,
    /// checkpoint serialization + atomic write
    CheckpointIo,
    /// metrics snapshot serialization + atomic write
    SnapshotIo,
    /// one batched evaluation through the tiled engine
    TileEval,
}

pub const NUM_SPANS: usize = 8;

impl SpanKind {
    pub const ALL: [SpanKind; NUM_SPANS] = [
        SpanKind::Warmup,
        SpanKind::Sampling,
        SpanKind::Draw,
        SpanKind::ForwardSweep,
        SpanKind::ReverseSweep,
        SpanKind::CheckpointIo,
        SpanKind::SnapshotIo,
        SpanKind::TileEval,
    ];

    /// Stable snake_case name used by the exporters.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Warmup => "warmup",
            SpanKind::Sampling => "sampling",
            SpanKind::Draw => "draw",
            SpanKind::ForwardSweep => "forward_sweep",
            SpanKind::ReverseSweep => "reverse_sweep",
            SpanKind::CheckpointIo => "checkpoint_io",
            SpanKind::SnapshotIo => "snapshot_io",
            SpanKind::TileEval => "tile_eval",
        }
    }
}

/// Coarse run phase, for the progress line and the trace stream.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u64)]
pub enum Phase {
    Idle = 0,
    Warmup = 1,
    Sampling = 2,
    Optimizing = 3,
    Done = 4,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Idle => "idle",
            Phase::Warmup => "warmup",
            Phase::Sampling => "sampling",
            Phase::Optimizing => "optimizing",
            Phase::Done => "done",
        }
    }

    pub fn from_u64(v: u64) -> Phase {
        match v {
            1 => Phase::Warmup,
            2 => Phase::Sampling,
            3 => Phase::Optimizing,
            4 => Phase::Done,
            _ => Phase::Idle,
        }
    }
}

/// Fixed-capacity overwrite-oldest ring of `f64` values stored as bit
/// patterns.  Pushing is two relaxed atomic ops and never allocates.
struct Ring {
    /// total values ever pushed (the write head is `pushed % capacity`)
    pushed: AtomicU64,
    data: Box<[AtomicU64]>,
}

impl Ring {
    fn new(capacity: usize) -> Ring {
        let data: Vec<AtomicU64> = (0..capacity).map(|_| AtomicU64::new(0)).collect();
        Ring {
            pushed: AtomicU64::new(0),
            data: data.into_boxed_slice(),
        }
    }

    #[inline]
    fn push(&self, v: f64) {
        let i = self.pushed.fetch_add(1, Ordering::Relaxed) as usize % self.data.len();
        self.data[i].store(v.to_bits(), Ordering::Relaxed);
    }

    fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    /// Oldest-to-newest copy of the retained window.
    fn snapshot(&self) -> Vec<f64> {
        let n = self.pushed.load(Ordering::Relaxed) as usize;
        let cap = self.data.len();
        let len = n.min(cap);
        let start = if n > cap { n % cap } else { 0 };
        (0..len)
            .map(|k| f64::from_bits(self.data[(start + k) % cap].load(Ordering::Relaxed)))
            .collect()
    }
}

struct SpanCell {
    nanos: AtomicU64,
    count: AtomicU64,
}

/// Preallocated, all-atomic metrics storage shared by every engine.
///
/// One registry serves a whole process (or a whole test, when injected
/// locally through the `set_recorder` hooks): parallel chains and
/// tiled worker threads all record into the same atomics, so counters
/// are process totals and gauges/rings hold the latest interleaved
/// observations.
pub struct MetricsRegistry {
    start: Instant,
    counters: [AtomicU64; NUM_COUNTERS],
    gauges: [AtomicU64; NUM_GAUGES],
    depth_hist: [AtomicU64; DEPTH_BUCKETS],
    spans: [SpanCell; NUM_SPANS],
    phase: AtomicU64,
    step_size_traj: Ring,
    accept_traj: Ring,
    elbo_traj: Ring,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            start: Instant::now(),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicU64::new(0.0f64.to_bits())),
            depth_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            spans: std::array::from_fn(|_| SpanCell {
                nanos: AtomicU64::new(0),
                count: AtomicU64::new(0),
            }),
            phase: AtomicU64::new(Phase::Idle as u64),
            step_size_traj: Ring::new(RING_CAPACITY),
            accept_traj: Ring::new(RING_CAPACITY),
            elbo_traj: Ring::new(RING_CAPACITY),
        }
    }

    /// Allocate a registry that lives for the rest of the process —
    /// the backing store for every [`Recorder`] handle.
    pub fn leak() -> &'static MetricsRegistry {
        Box::leak(Box::new(MetricsRegistry::new()))
    }

    pub fn uptime(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize].load(Ordering::Relaxed)
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        f64::from_bits(self.gauges[g as usize].load(Ordering::Relaxed))
    }

    pub fn phase(&self) -> Phase {
        Phase::from_u64(self.phase.load(Ordering::Relaxed))
    }

    /// (bucket count)[depth], saturated at `DEPTH_BUCKETS - 1`.
    pub fn depth_histogram(&self) -> [u64; DEPTH_BUCKETS] {
        std::array::from_fn(|i| self.depth_hist[i].load(Ordering::Relaxed))
    }

    /// Accumulated (nanos, count) for a span kind.
    pub fn span_totals(&self, k: SpanKind) -> (u64, u64) {
        let cell = &self.spans[k as usize];
        (
            cell.nanos.load(Ordering::Relaxed),
            cell.count.load(Ordering::Relaxed),
        )
    }

    /// Direct counter bump, for callers holding a plain (non-leaked)
    /// registry reference — e.g. the exporters.
    pub fn add_counter(&self, c: Counter, n: u64) {
        self.counters[c as usize].fetch_add(n, Ordering::Relaxed);
    }

    /// Direct span accumulation, for callers holding a plain registry
    /// reference.
    pub fn add_span(&self, kind: SpanKind, nanos: u64) {
        let cell = &self.spans[kind as usize];
        cell.nanos.fetch_add(nanos, Ordering::Relaxed);
        cell.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Retained trajectory window (oldest first) plus total pushes.
    pub fn step_size_trajectory(&self) -> (Vec<f64>, u64) {
        (self.step_size_traj.snapshot(), self.step_size_traj.pushed())
    }

    pub fn accept_trajectory(&self) -> (Vec<f64>, u64) {
        (self.accept_traj.snapshot(), self.accept_traj.pushed())
    }

    pub fn elbo_trajectory(&self) -> (Vec<f64>, u64) {
        (self.elbo_traj.snapshot(), self.elbo_traj.pushed())
    }
}

/// The handle hot paths hold: `Copy`, always compiled, one branch when
/// disabled.  Build one from an installed global
/// ([`Recorder::global`]) or a leaked registry ([`Recorder::new`]).
#[derive(Clone, Copy, Default, Debug)]
pub struct Recorder(Option<&'static MetricsRegistry>);

/// Process-global registry pointer, installed by the CLI (or a bench
/// run) and read by every engine constructor as its default recorder.
/// Null (the default) means recording is off everywhere.
static GLOBAL: AtomicPtr<MetricsRegistry> = AtomicPtr::new(std::ptr::null_mut());

/// Install a fresh global registry and return its handle.  Intended
/// for binaries (CLI, bench); library tests should inject local
/// registries through the `set_recorder` hooks instead so parallel
/// tests cannot cross-contaminate counters.
pub fn install() -> Recorder {
    let reg = MetricsRegistry::leak();
    GLOBAL.store(reg as *const MetricsRegistry as *mut MetricsRegistry, Ordering::Release);
    Recorder(Some(reg))
}

/// Disable the global recorder.  Engines that already captured a
/// handle keep recording into the (leaked) registry harmlessly; newly
/// constructed engines come up disabled.
pub fn uninstall() {
    GLOBAL.store(std::ptr::null_mut(), Ordering::Release);
}

impl Recorder {
    /// The disabled recorder: every call is a no-op behind one branch.
    pub const OFF: Recorder = Recorder(None);

    pub fn new(reg: &'static MetricsRegistry) -> Recorder {
        Recorder(Some(reg))
    }

    /// The process-global recorder (disabled unless [`install`] ran).
    pub fn global() -> Recorder {
        let p = GLOBAL.load(Ordering::Acquire);
        if p.is_null() {
            Recorder(None)
        } else {
            // Safety: the pointer only ever comes from `Box::leak` in
            // `install`, so it is valid for 'static and never freed.
            Recorder(Some(unsafe { &*p }))
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    pub fn registry(&self) -> Option<&'static MetricsRegistry> {
        self.0
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        if let Some(r) = self.0 {
            r.counters[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn incr(&self, c: Counter) {
        self.add(c, 1);
    }

    /// Store an absolute counter value (for set-once facts like plan
    /// instruction counts).
    #[inline]
    pub fn store(&self, c: Counter, v: u64) {
        if let Some(r) = self.0 {
            r.counters[c as usize].store(v, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn set_gauge(&self, g: Gauge, v: f64) {
        if let Some(r) = self.0 {
            r.gauges[g as usize].store(v.to_bits(), Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn set_phase(&self, p: Phase) {
        if let Some(r) = self.0 {
            r.phase.store(p as u64, Ordering::Relaxed);
        }
    }

    /// Record one completed NUTS draw from its already-computed
    /// statistics.  Pure observation: nothing here feeds back into the
    /// sampler.
    #[inline]
    pub fn record_draw(
        &self,
        accept_prob: f64,
        depth: u32,
        num_leapfrog: u64,
        diverging: bool,
        poisoned: bool,
    ) {
        if let Some(r) = self.0 {
            r.counters[Counter::Draws as usize].fetch_add(1, Ordering::Relaxed);
            r.counters[Counter::Leapfrogs as usize].fetch_add(num_leapfrog, Ordering::Relaxed);
            if diverging {
                r.counters[Counter::Divergences as usize].fetch_add(1, Ordering::Relaxed);
            }
            if poisoned {
                r.counters[Counter::Quarantines as usize].fetch_add(1, Ordering::Relaxed);
            }
            let bucket = (depth as usize).min(DEPTH_BUCKETS - 1);
            r.depth_hist[bucket].fetch_add(1, Ordering::Relaxed);
            r.gauges[Gauge::AcceptProb as usize].store(accept_prob.to_bits(), Ordering::Relaxed);
            r.accept_traj.push(accept_prob);
        }
    }

    /// Record the current step size (gauge + trajectory ring).
    #[inline]
    pub fn record_step_size(&self, eps: f64) {
        if let Some(r) = self.0 {
            r.gauges[Gauge::StepSize as usize].store(eps.to_bits(), Ordering::Relaxed);
            r.step_size_traj.push(eps);
        }
    }

    /// Record one SVI ELBO estimate (gauge + trajectory ring).
    #[inline]
    pub fn record_elbo(&self, elbo: f64) {
        if let Some(r) = self.0 {
            r.gauges[Gauge::Elbo as usize].store(elbo.to_bits(), Ordering::Relaxed);
            r.elbo_traj.push(elbo);
        }
    }

    /// Record one batched evaluation through the tiled engine.
    #[inline]
    pub fn record_tile_eval(&self, num_tiles: u64) {
        if let Some(r) = self.0 {
            r.counters[Counter::TileEvals as usize].fetch_add(1, Ordering::Relaxed);
            r.counters[Counter::TileGathers as usize].fetch_add(num_tiles, Ordering::Relaxed);
            r.counters[Counter::TileScatters as usize].fetch_add(num_tiles, Ordering::Relaxed);
        }
    }

    /// Record the instruction counts of the active optimized plan.
    pub fn record_plan_instrs(&self, fwd: u64, bwd: u64) {
        self.store(Counter::PlanFwdInstrs, fwd);
        self.store(Counter::PlanBwdInstrs, bwd);
    }

    /// Open a timing span; elapsed nanos accumulate on drop.  Disabled
    /// recorders never read the clock.
    #[inline]
    pub fn span(&self, kind: SpanKind) -> SpanGuard {
        SpanGuard {
            open: self.0.map(|r| (r, kind, Instant::now())),
        }
    }

    /// Add an externally measured duration to a span accumulator.
    #[inline]
    pub fn add_span_nanos(&self, kind: SpanKind, nanos: u64) {
        if let Some(r) = self.0 {
            let cell = &r.spans[kind as usize];
            cell.nanos.fetch_add(nanos, Ordering::Relaxed);
            cell.count.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// [`Recorder::add_span_nanos`] from seconds.
    pub fn add_span_secs(&self, kind: SpanKind, secs: f64) {
        if secs.is_finite() && secs >= 0.0 {
            self.add_span_nanos(kind, (secs * 1e9) as u64);
        }
    }
}

/// RAII guard from [`Recorder::span`]: accumulates elapsed nanos into
/// the registry on drop.  Holds no allocation.
pub struct SpanGuard {
    open: Option<(&'static MetricsRegistry, SpanKind, Instant)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((r, kind, t0)) = self.open.take() {
            let cell = &r.spans[kind as usize];
            cell.nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            cell.count.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = Recorder::OFF;
        assert!(!rec.enabled());
        rec.incr(Counter::Draws);
        rec.set_gauge(Gauge::StepSize, 0.3);
        rec.record_draw(0.9, 3, 7, false, false);
        rec.record_step_size(0.1);
        rec.record_elbo(-10.0);
        rec.set_phase(Phase::Sampling);
        drop(rec.span(SpanKind::Draw));
    }

    #[test]
    fn counters_gauges_and_histogram_accumulate() {
        let reg = MetricsRegistry::leak();
        let rec = Recorder::new(reg);
        rec.record_draw(0.875, 3, 7, true, false);
        rec.record_draw(0.5, 40, 1, false, true);
        rec.record_step_size(0.25);
        assert_eq!(reg.counter(Counter::Draws), 2);
        assert_eq!(reg.counter(Counter::Leapfrogs), 8);
        assert_eq!(reg.counter(Counter::Divergences), 1);
        assert_eq!(reg.counter(Counter::Quarantines), 1);
        assert_eq!(reg.gauge(Gauge::StepSize).to_bits(), 0.25f64.to_bits());
        assert_eq!(reg.gauge(Gauge::AcceptProb).to_bits(), 0.5f64.to_bits());
        let hist = reg.depth_histogram();
        assert_eq!(hist[3], 1);
        assert_eq!(hist[DEPTH_BUCKETS - 1], 1, "deep draws saturate the last bucket");
    }

    #[test]
    fn ring_overwrites_oldest_and_reports_total() {
        let reg = MetricsRegistry::leak();
        let rec = Recorder::new(reg);
        let n = RING_CAPACITY + 10;
        for i in 0..n {
            rec.record_elbo(i as f64);
        }
        let (window, pushed) = reg.elbo_trajectory();
        assert_eq!(pushed, n as u64);
        assert_eq!(window.len(), RING_CAPACITY);
        assert_eq!(window[0], 10.0, "oldest retained value");
        assert_eq!(*window.last().unwrap(), (n - 1) as f64);
    }

    #[test]
    fn spans_accumulate_nanos_and_counts() {
        let reg = MetricsRegistry::leak();
        let rec = Recorder::new(reg);
        drop(rec.span(SpanKind::CheckpointIo));
        rec.add_span_nanos(SpanKind::CheckpointIo, 500);
        let (nanos, count) = reg.span_totals(SpanKind::CheckpointIo);
        assert!(nanos >= 500);
        assert_eq!(count, 2);
    }

    #[test]
    fn global_recorder_defaults_off() {
        // Never `install()` in library tests: this assertion is shared
        // state with every other test in the binary.
        assert!(!Recorder::global().enabled());
    }
}
