//! Flight recorder: zero-allocation metrics, timing spans, and
//! exporters shared by every inference engine.
//!
//! Three layers:
//!
//! 1. [`MetricsRegistry`] — preallocated counters, gauges, a
//!    tree-depth histogram, trajectory rings, and span accumulators,
//!    all atomics.  Hot paths update it through the `Copy`
//!    [`Recorder`] handle, which is always compiled and runtime
//!    toggled: disabled recording costs one branch, enabled recording
//!    is a few relaxed atomic stores, and neither consumes RNG nor
//!    touches any floating-point value on the inference path — so
//!    recorder-on and recorder-off runs are **bitwise identical**
//!    (enforced by `tests/observability.rs`) and instrumented draws
//!    stay **zero-allocation** (enforced by `tests/alloc_free.rs`).
//! 2. Timing spans ([`SpanKind`]) — monotonic-clock durations (warmup
//!    vs sampling, draws, forward/reverse sweeps sampled 1-in-N,
//!    checkpoint and snapshot I/O, per-tile evals) aggregated into the
//!    same registry.
//! 3. Exporters — the JSONL trace stream ([`TraceWriter`], CLI
//!    `--trace-out`), the atomic metrics snapshot ([`write_snapshot`],
//!    CLI `--metrics-out`/`--metrics-every`), and the one-line
//!    progress report ([`progress_line`], CLI `--progress`).
//!
//! Engines capture their recorder at construction from the process
//! global ([`Recorder::global`], installed only by binaries via
//! [`install`]) and expose `set_recorder` hooks so tests can inject
//! local registries without sharing state across parallel tests.

mod export;
mod registry;

pub use export::{progress_line, snapshot_json, write_snapshot, TraceWriter, Val, SNAPSHOT_SCHEMA};
pub use registry::{
    install, uninstall, Counter, Gauge, MetricsRegistry, Phase, Recorder, SpanGuard, SpanKind,
    DEPTH_BUCKETS, NUM_COUNTERS, NUM_GAUGES, NUM_SPANS, RING_CAPACITY, SWEEP_SAMPLE_PERIOD,
};
