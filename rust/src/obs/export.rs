//! Exporters: the JSONL trace stream, the atomic metrics snapshot,
//! and the single-line progress report.
//!
//! None of these run on an inference hot path — they read the
//! all-atomic [`MetricsRegistry`] from the outside (the CLI's exporter
//! thread, a test, or a run boundary), so they are free to allocate.
//!
//! Formats:
//!
//! * **Trace (`--trace-out`)**: one JSON object per line, each with a
//!   monotonic `ts_ms` (milliseconds since the writer was created) and
//!   an `event` name, plus event-specific fields.  Lines are flushed
//!   as written so a killed process keeps every completed event.
//! * **Snapshot (`--metrics-out` / `--metrics-every`)**: a single JSON
//!   document (`schema: "fugue-metrics/v1"`) with counters, gauges,
//!   the tree-depth histogram, span totals, and the retained
//!   trajectory windows; written via the same `.tmp` + rename idiom as
//!   checkpoints so readers never observe a torn file.
//! * **Progress**: a one-line human summary of the registry, suitable
//!   for `\r`-overwriting.

use std::collections::BTreeMap;
use std::fs;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use super::registry::{Counter, Gauge, MetricsRegistry, SpanKind};
use crate::util::json::Json;

/// Schema tag stamped into every metrics snapshot.
pub const SNAPSHOT_SCHEMA: &str = "fugue-metrics/v1";

/// A field value in a trace event.
#[derive(Debug, Clone)]
pub enum Val {
    U(u64),
    F(f64),
    S(String),
    B(bool),
}

impl Val {
    fn write(&self, out: &mut String) {
        match self {
            Val::U(n) => out.push_str(&n.to_string()),
            Val::F(x) if x.is_finite() => out.push_str(&format!("{x}")),
            Val::F(_) => out.push_str("null"),
            Val::S(s) => write_json_str(out, s),
            Val::B(b) => out.push_str(if *b { "true" } else { "false" }),
        }
    }
}

fn write_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Line-oriented JSONL event writer (`--trace-out`).  Thread-safe; an
/// event is one locked write + flush, so concurrent writers interleave
/// whole lines, never bytes.
pub struct TraceWriter {
    out: Mutex<BufWriter<fs::File>>,
    epoch: Instant,
    path: PathBuf,
}

impl TraceWriter {
    pub fn create(path: &Path) -> Result<TraceWriter> {
        let file = fs::File::create(path)
            .with_context(|| format!("creating trace stream {}", path.display()))?;
        Ok(TraceWriter {
            out: Mutex::new(BufWriter::new(file)),
            epoch: Instant::now(),
            path: path.to_path_buf(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one event line: `{"ts_ms":...,"event":NAME, fields...}`.
    pub fn event(&self, name: &str, fields: &[(&str, Val)]) -> Result<()> {
        let ts_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        let mut line = String::with_capacity(64 + fields.len() * 24);
        line.push_str("{\"ts_ms\":");
        line.push_str(&format!("{ts_ms:.3}"));
        line.push_str(",\"event\":");
        write_json_str(&mut line, name);
        for (k, v) in fields {
            line.push(',');
            write_json_str(&mut line, k);
            line.push(':');
            v.write(&mut line);
        }
        line.push_str("}\n");
        let mut out = self.out.lock().expect("trace writer poisoned");
        out.write_all(line.as_bytes())?;
        out.flush()?;
        Ok(())
    }
}

fn jnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Full registry state as one JSON document.
pub fn snapshot_json(reg: &MetricsRegistry) -> Json {
    let counters = jobj(
        Counter::ALL
            .iter()
            .map(|&c| (c.name(), Json::Num(reg.counter(c) as f64)))
            .collect(),
    );
    let gauges = jobj(
        Gauge::ALL
            .iter()
            .map(|&g| (g.name(), jnum(reg.gauge(g))))
            .collect(),
    );
    let spans = jobj(
        SpanKind::ALL
            .iter()
            .map(|&k| {
                let (nanos, count) = reg.span_totals(k);
                (
                    k.name(),
                    jobj(vec![
                        ("ms", Json::Num(nanos as f64 / 1e6)),
                        ("count", Json::Num(count as f64)),
                    ]),
                )
            })
            .collect(),
    );
    let hist = reg.depth_histogram();
    let depth_hist = Json::Arr(hist.iter().map(|&n| Json::Num(n as f64)).collect());
    let traj = |window: Vec<f64>, pushed: u64| {
        jobj(vec![
            ("total", Json::Num(pushed as f64)),
            ("window", Json::Arr(window.into_iter().map(jnum).collect())),
        ])
    };
    let (ss, ss_n) = reg.step_size_trajectory();
    let (ap, ap_n) = reg.accept_trajectory();
    let (el, el_n) = reg.elbo_trajectory();
    jobj(vec![
        ("schema", Json::Str(SNAPSHOT_SCHEMA.to_string())),
        ("uptime_ms", Json::Num(reg.uptime().as_secs_f64() * 1e3)),
        ("phase", Json::Str(reg.phase().name().to_string())),
        ("counters", counters),
        ("gauges", gauges),
        ("tree_depth_hist", depth_hist),
        ("spans", spans),
        (
            "trajectories",
            jobj(vec![
                ("step_size", traj(ss, ss_n)),
                ("accept_prob", traj(ap, ap_n)),
                ("elbo", traj(el, el_n)),
            ]),
        ),
    ])
}

/// Write a metrics snapshot atomically (`.tmp` + rename, the
/// checkpoint idiom): readers never observe a torn document, even if
/// the process dies mid-write.
pub fn write_snapshot(reg: &MetricsRegistry, path: &Path) -> Result<()> {
    let t0 = Instant::now();
    let text = snapshot_json(reg).to_string_pretty();
    write_atomic(path, &text)?;
    reg.add_span(SpanKind::SnapshotIo, t0.elapsed().as_nanos() as u64);
    reg.add_counter(Counter::SnapshotWrites, 1);
    Ok(())
}

fn write_atomic(path: &Path, text: &str) -> Result<()> {
    let tmp = path.with_extension("tmp");
    fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    Ok(())
}

/// One-line human progress summary of the registry, for `--progress`.
pub fn progress_line(reg: &MetricsRegistry) -> String {
    let secs = reg.uptime().as_secs_f64();
    let draws = reg.counter(Counter::Draws);
    let steps = reg.counter(Counter::SviSteps);
    if steps > 0 && draws == 0 {
        format!(
            "[{phase}] {secs:.1}s | svi steps {steps} | elbo {elbo:.4} | grad norm {gn:.3} | skips {skips} | backoff {bo:.3}",
            phase = reg.phase().name(),
            elbo = reg.gauge(Gauge::Elbo),
            gn = reg.gauge(Gauge::GradNorm),
            skips = reg.counter(Counter::SviSkips),
            bo = reg.gauge(Gauge::LrBackoff),
        )
    } else {
        format!(
            "[{phase}] {secs:.1}s | draws {draws} | leapfrogs {lf} | div {div} | quar {quar} | step {eps:.4} | accept {acc:.3}",
            phase = reg.phase().name(),
            lf = reg.counter(Counter::Leapfrogs),
            div = reg.counter(Counter::Divergences),
            quar = reg.counter(Counter::Quarantines),
            eps = reg.gauge(Gauge::StepSize),
            acc = reg.gauge(Gauge::AcceptProb),
        )
    }
}
