//! The reparameterized multi-particle ELBO over a compiled model's
//! **frozen tape** potential — the gradient core of the native SVI
//! engine.
//!
//! # Dataflow
//!
//! With the mean-field guide `q(z) = N(loc, sigma^2)`, `sigma =
//! exp(log_scale)`, and the reparameterization `z = loc + sigma * eps`,
//! the K-particle ELBO estimate is
//!
//! ```text
//!   ELBO ~= (1/K) sum_k log p(z_k, data)  +  H(q)
//! ```
//!
//! where `log p` is the compiled model's **unconstrained-space** joint
//! (priors + likelihood + log|det J| of the constraining bijections) —
//! exactly `-U` from the frozen [`TapeProgram`] the NUTS engines
//! already evaluate — and `H(q)` is the guide's closed-form entropy.
//! The chain rule then gives the variational gradients *host-side*,
//! with no extra tape passes:
//!
//! ```text
//!   dELBO/dloc_i       = (1/K) sum_k dlogp/dz_i(z_k)
//!   dELBO/dlog_scale_i = (1/K) sum_k dlogp/dz_i(z_k) * eps_ki * sigma_i  +  1
//! ```
//!
//! (the `+1` is `dH/dlog_scale_i`).  Since `dlogp/dz = -dU/dz`, every
//! piece comes straight out of the potentials the MCMC stack compiled —
//! SVI adds **zero** new autodiff machinery.
//!
//! # Particle lanes
//!
//! The K particles are embarrassingly parallel, so they map exactly
//! onto the vectorized chain engine's lanes: the batched path issues
//! **one** [`BatchPotential::value_and_grad_batch`] sweep per step —
//! all K particle gradients in a single fused lane-minor pass over the
//! frozen [`crate::autodiff::BatchTapeProgram`] — where the scalar path
//! loops K scalar evaluations.  Both paths draw `eps` in the same
//! particle-major order and share the same host-side accumulation
//! ([`ReparamElbo`] stores everything lane-minor), and lane `k` of a
//! batched evaluation is bitwise equal to the scalar evaluation at lane
//! `k`'s coordinates, so **scalar and batched ELBO steps agree
//! bitwise** — pinned by `rust/tests/svi_native.rs`.  `fugue bench`
//! reports the payoff as `svi_particle_batch_speedup`.
//!
//! All scratch lives on [`ReparamElbo`] and is sized at construction:
//! steady-state ELBO steps perform zero heap allocations
//! (`rust/tests/alloc_free.rs`).
//!
//! [`TapeProgram`]: crate::autodiff::TapeProgram
//! [`BatchPotential::value_and_grad_batch`]: crate::mcmc::BatchPotential::value_and_grad_batch

use crate::mcmc::{BatchPotential, Potential};
use crate::ppl::special::LN_2PI;
use crate::rng::Rng;

/// Reusable state for reparameterized K-particle ELBO evaluations:
/// noise draws, particle coordinates, per-particle potentials and
/// gradients, all in the lane-minor layout the batched compiler uses
/// (`buf[i * particles + k]` = coordinate `i` of particle `k`).
pub struct ReparamElbo {
    dim: usize,
    particles: usize,
    /// `exp(log_scale)`, refreshed every evaluation
    sigma: Vec<f64>,
    /// standard-normal noise, lane-minor `dim x K`
    eps: Vec<f64>,
    /// particle coordinates `z = loc + sigma * eps`, lane-minor
    z: Vec<f64>,
    /// per-particle potential `U(z_k) = -log p(z_k, data)`
    u: Vec<f64>,
    /// per-particle `dU/dz`, lane-minor
    grad_z: Vec<f64>,
    /// scalar-path scratch: one particle's coordinates / gradient
    zk: Vec<f64>,
    gk: Vec<f64>,
}

impl ReparamElbo {
    pub fn new(dim: usize, particles: usize) -> ReparamElbo {
        assert!(particles > 0, "ELBO needs at least one particle");
        ReparamElbo {
            dim,
            particles,
            sigma: vec![0.0; dim],
            eps: vec![0.0; dim * particles],
            z: vec![0.0; dim * particles],
            u: vec![0.0; particles],
            grad_z: vec![0.0; dim * particles],
            zk: vec![0.0; dim],
            gk: vec![0.0; dim],
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn particles(&self) -> usize {
        self.particles
    }

    /// Draw fresh reparameterization noise: particle-major consumption
    /// order (particle 0's coordinates first), lane-minor storage —
    /// so the scalar loop and the batched sweep see identical noise.
    pub fn draw_eps(&mut self, rng: &mut Rng) {
        let k_lanes = self.particles;
        for k in 0..k_lanes {
            for i in 0..self.dim {
                self.eps[i * k_lanes + k] = rng.normal();
            }
        }
    }

    /// Override the noise (lane-minor `dim x K`) — deterministic ELBO
    /// evaluations for the finite-difference gradient tests.
    pub fn set_eps(&mut self, eps: &[f64]) {
        assert_eq!(eps.len(), self.eps.len(), "eps: want dim x particles");
        self.eps.copy_from_slice(eps);
    }

    /// The current noise (lane-minor).
    pub fn eps(&self) -> &[f64] {
        &self.eps
    }

    /// ELBO and its gradient with **fresh** noise, particles evaluated
    /// one scalar [`Potential`] call at a time.  Writes
    /// `[dELBO/dloc..., dELBO/dlog_scale...]` into `grad` (length
    /// `2*dim`), returns the ELBO estimate.
    pub fn value_and_grad_scalar<P: Potential>(
        &mut self,
        pot: &mut P,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        self.draw_eps(rng);
        self.eval_scalar(pot, loc, log_scale, grad)
    }

    /// ELBO and its gradient with **fresh** noise, all K particles in
    /// one fused [`BatchPotential`] sweep (requires `pot.lanes() ==
    /// self.particles()`).  Bitwise equal to the scalar path under the
    /// same RNG state.
    pub fn value_and_grad_batched<BP: BatchPotential>(
        &mut self,
        pot: &mut BP,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        self.draw_eps(rng);
        self.eval_batched(pot, loc, log_scale, grad)
    }

    /// Deterministic scalar-path evaluation at the *current* noise
    /// (`draw_eps`/`set_eps` first).
    pub fn eval_scalar<P: Potential>(
        &mut self,
        pot: &mut P,
        loc: &[f64],
        log_scale: &[f64],
        grad: &mut [f64],
    ) -> f64 {
        assert_eq!(pot.dim(), self.dim, "potential/ELBO dimension mismatch");
        self.reparameterize(loc, log_scale);
        let k_lanes = self.particles;
        for k in 0..k_lanes {
            for i in 0..self.dim {
                self.zk[i] = self.z[i * k_lanes + k];
            }
            self.u[k] = pot.value_and_grad(&self.zk, &mut self.gk);
            for i in 0..self.dim {
                self.grad_z[i * k_lanes + k] = self.gk[i];
            }
        }
        self.finish(log_scale, grad)
    }

    /// Deterministic batched-path evaluation at the *current* noise.
    pub fn eval_batched<BP: BatchPotential>(
        &mut self,
        pot: &mut BP,
        loc: &[f64],
        log_scale: &[f64],
        grad: &mut [f64],
    ) -> f64 {
        assert_eq!(pot.dim(), self.dim, "potential/ELBO dimension mismatch");
        assert_eq!(
            pot.lanes(),
            self.particles,
            "batched ELBO: potential lanes must equal the particle count"
        );
        self.reparameterize(loc, log_scale);
        pot.value_and_grad_batch(&self.z, &mut self.u, &mut self.grad_z);
        self.finish(log_scale, grad)
    }

    /// `sigma = exp(log_scale)`; `z[i,k] = loc[i] + sigma[i] * eps[i,k]`.
    fn reparameterize(&mut self, loc: &[f64], log_scale: &[f64]) {
        assert_eq!(loc.len(), self.dim, "loc/ELBO dimension mismatch");
        assert_eq!(log_scale.len(), self.dim, "log_scale/ELBO dimension mismatch");
        let k_lanes = self.particles;
        for i in 0..self.dim {
            self.sigma[i] = log_scale[i].exp();
            let s = self.sigma[i];
            let l = loc[i];
            let row = &mut self.z[i * k_lanes..(i + 1) * k_lanes];
            let eps = &self.eps[i * k_lanes..(i + 1) * k_lanes];
            for (zv, &e) in row.iter_mut().zip(eps) {
                *zv = l + s * e;
            }
        }
    }

    /// Shared host-side accumulation: both evaluation paths land here
    /// with bitwise-identical `u`/`grad_z`, so the ELBO value and
    /// gradients agree bitwise by construction.
    fn finish(&mut self, log_scale: &[f64], grad: &mut [f64]) -> f64 {
        assert_eq!(grad.len(), 2 * self.dim, "grad: want [loc..., log_scale...]");
        let k_lanes = self.particles;
        let inv_k = 1.0 / k_lanes as f64;

        // E_q[log p]: mean of -U over the particles
        let mut sum_logp = 0.0;
        for &uk in &self.u {
            sum_logp += -uk;
        }

        // closed-form entropy of the mean-field guide
        let mut entropy = 0.5 * self.dim as f64 * (1.0 + LN_2PI);
        for &ls in log_scale {
            entropy += ls;
        }

        let (g_loc, g_ls) = grad.split_at_mut(self.dim);
        for i in 0..self.dim {
            let row = &self.grad_z[i * k_lanes..(i + 1) * k_lanes];
            let eps = &self.eps[i * k_lanes..(i + 1) * k_lanes];
            let mut s_loc = 0.0;
            let mut s_eps = 0.0;
            for k in 0..k_lanes {
                let dlogp = -row[k];
                s_loc += dlogp;
                s_eps += dlogp * eps[k];
            }
            g_loc[i] = s_loc * inv_k;
            g_ls[i] = s_eps * self.sigma[i] * inv_k + 1.0;
        }

        sum_logp * inv_k + entropy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::NormalMean;
    use crate::compile::{compile, compile_batched};

    fn toy() -> NormalMean {
        NormalMean {
            y: vec![0.4, -0.9, 1.3],
            sigma: 1.5,
        }
    }

    /// With sigma -> 0 and one particle at eps = 0, the ELBO collapses
    /// to `log p(loc) + H(q)` exactly.
    #[test]
    fn elbo_at_zero_noise_is_logp_plus_entropy() {
        let mut pot = compile(toy(), 0).unwrap();
        let mut elbo = ReparamElbo::new(1, 1);
        elbo.set_eps(&[0.0]);
        let (loc, ls) = ([0.3], [-3.0]);
        let mut grad = [0.0; 2];
        let e = elbo.eval_scalar(&mut pot, &loc, &ls, &mut grad);

        use crate::mcmc::Potential;
        let mut g1 = [0.0];
        let u = pot.value_and_grad(&[0.3], &mut g1);
        let entropy = -3.0 + 0.5 * (1.0 + LN_2PI);
        assert!((e - (-u + entropy)).abs() < 1e-12, "{e} vs {}", -u + entropy);
        // dELBO/dloc = dlogp/dz at the single particle
        assert!((grad[0] - (-g1[0])).abs() < 1e-12);
        // dELBO/dlog_scale = 0 * sigma + 1 at eps = 0
        assert!((grad[1] - 1.0).abs() < 1e-12);
    }

    /// The batched path must agree bitwise with the scalar loop under
    /// identical noise — the particle-lane contract.
    #[test]
    fn scalar_and_batched_particles_agree_bitwise() {
        for lanes in [1usize, 4] {
            let mut spot = compile(toy(), 0).unwrap();
            let mut bpot = compile_batched(toy(), 0, lanes).unwrap();
            let mut es = ReparamElbo::new(1, lanes);
            let mut eb = ReparamElbo::new(1, lanes);
            let mut rng_s = Rng::new(7);
            let mut rng_b = Rng::new(7);
            let (loc, ls) = ([0.2], [-1.0]);
            let mut gs = [0.0; 2];
            let mut gb = [0.0; 2];
            for _ in 0..20 {
                let vs = es.value_and_grad_scalar(&mut spot, &loc, &ls, &mut rng_s, &mut gs);
                let vb = eb.value_and_grad_batched(&mut bpot, &loc, &ls, &mut rng_b, &mut gb);
                assert_eq!(vs.to_bits(), vb.to_bits(), "{lanes} lanes: ELBO");
                for i in 0..2 {
                    assert_eq!(gs[i].to_bits(), gb[i].to_bits(), "{lanes} lanes: grad[{i}]");
                }
            }
        }
    }
}
