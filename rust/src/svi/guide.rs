//! The mean-field normal (ADVI) guide: a diagonal Gaussian
//! `q(z) = N(loc, diag(exp(log_scale))^2)` over a compiled model's
//! **unconstrained** parameter vector.
//!
//! The guide is parameterized directly over the [`SiteLayout`] the
//! model compiler assigns (the sorted-site `[b, m...]` flat layout), so
//! every latent site of every compilable [`crate::compile::EffModel`]
//! is covered automatically — constrained sites are handled by sampling
//! in the unconstrained space and mapping draws through the layout's
//! bijections ([`SiteLayout::constrain_row`]), exactly like NUTS draws.
//!
//! Parameters live in one flat `[loc..., log_scale...]` vector so the
//! optimizer ([`crate::svi::optim`]) and the ELBO gradient
//! ([`crate::svi::elbo`]) operate on a single slice with no
//! re-packing.

use std::collections::BTreeMap;

use crate::compile::SiteLayout;
use crate::ppl::special::LN_2PI;
use crate::rng::Rng;

/// Initial guide scale `exp(-2)` — matches the PJRT artifact path's
/// initialization so both backends start from the same variational
/// state.
pub const INIT_LOG_SCALE: f64 = -2.0;

/// Mean-field normal guide over a `dim`-dimensional unconstrained
/// space; the native counterpart of NumPyro's `AutoDiagonalNormal`.
#[derive(Debug, Clone)]
pub struct MeanFieldGuide {
    dim: usize,
    /// flat `[loc_0..loc_{d-1}, log_scale_0..log_scale_{d-1}]`
    params: Vec<f64>,
}

impl MeanFieldGuide {
    /// Fresh guide: `loc = 0`, `log_scale = `[`INIT_LOG_SCALE`].
    pub fn new(dim: usize) -> MeanFieldGuide {
        let mut params = vec![0.0; 2 * dim];
        params[dim..].fill(INIT_LOG_SCALE);
        MeanFieldGuide { dim, params }
    }

    /// Fresh guide sized for a compiled model's layout.
    pub fn for_layout(layout: &SiteLayout) -> MeanFieldGuide {
        MeanFieldGuide::new(layout.dim)
    }

    /// Unconstrained dimension (the model's, not the 2x parameter count).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The flat `[loc..., log_scale...]` parameter vector.
    pub fn params(&self) -> &[f64] {
        &self.params
    }

    /// Mutable access for the optimizer step.
    pub fn params_mut(&mut self) -> &mut [f64] {
        &mut self.params
    }

    /// Variational means (unconstrained space).
    pub fn loc(&self) -> &[f64] {
        &self.params[..self.dim]
    }

    /// Log standard deviations (unconstrained space).
    pub fn log_scale(&self) -> &[f64] {
        &self.params[self.dim..]
    }

    /// Closed-form entropy of the guide:
    /// `H(q) = sum_i log_scale_i + dim/2 * (1 + ln 2*pi)`.
    pub fn entropy(&self) -> f64 {
        let mut h = 0.5 * self.dim as f64 * (1.0 + LN_2PI);
        for &ls in self.log_scale() {
            h += ls;
        }
        h
    }

    /// One reparameterized draw `z = loc + exp(log_scale) * eps` with
    /// `eps ~ N(0, I)` written into `out` (unconstrained space).
    pub fn sample_unconstrained(&self, rng: &mut Rng, out: &mut [f64]) {
        assert_eq!(out.len(), self.dim, "guide draw: dimension mismatch");
        let (loc, ls) = (self.loc(), self.log_scale());
        for i in 0..self.dim {
            out[i] = loc[i] + ls[i].exp() * rng.normal();
        }
    }

    /// One draw mapped through the layout's constraining bijections —
    /// a posterior sample in the model's native space.
    pub fn sample_constrained(&self, layout: &SiteLayout, rng: &mut Rng, out: &mut [f64]) {
        self.sample_unconstrained(rng, out);
        layout.constrain_row(out);
    }

    /// `n` constrained posterior draws as an `(n x dim)` row-major
    /// matrix — the SVI analogue of a NUTS chain, ready for
    /// [`crate::diagnostics::summarize`].
    pub fn posterior_draws(&self, layout: &SiteLayout, rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut draws = vec![0.0; n * self.dim];
        for row in draws.chunks_mut(self.dim) {
            self.sample_constrained(layout, rng, row);
        }
        draws
    }

    /// One constrained draw split per latent site — the value map the
    /// [`crate::effects::Substitute`] handler consumes for
    /// posterior-predictive replay ([`crate::svi::predictive`]).
    pub fn site_values(&self, layout: &SiteLayout, rng: &mut Rng) -> BTreeMap<String, Vec<f64>> {
        let mut row = vec![0.0; self.dim];
        self.sample_constrained(layout, rng, &mut row);
        layout
            .sites
            .iter()
            .filter(|s| !s.observed)
            .map(|s| {
                (
                    s.name.clone(),
                    row[s.offset..s.offset + s.event_len].to_vec(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::EightSchools;

    #[test]
    fn fresh_guide_matches_artifact_initialization() {
        let g = MeanFieldGuide::new(3);
        assert_eq!(g.loc(), &[0.0, 0.0, 0.0]);
        assert_eq!(g.log_scale(), &[-2.0, -2.0, -2.0]);
        assert_eq!(g.params().len(), 6);
    }

    #[test]
    fn entropy_is_gaussian_closed_form() {
        let mut g = MeanFieldGuide::new(2);
        g.params_mut()[2] = 0.5;
        g.params_mut()[3] = -1.0;
        let expect = 0.5 + (-1.0) + (1.0 + LN_2PI);
        assert!((g.entropy() - expect).abs() < 1e-12);
    }

    #[test]
    fn draw_moments_match_parameters() {
        let mut g = MeanFieldGuide::new(2);
        g.params_mut().copy_from_slice(&[1.5, -0.5, -1.0, 0.2]);
        let mut rng = Rng::new(42);
        let n = 20_000;
        let (mut m, mut v) = (vec![0.0; 2], vec![0.0; 2]);
        let mut z = vec![0.0; 2];
        for _ in 0..n {
            g.sample_unconstrained(&mut rng, &mut z);
            for i in 0..2 {
                m[i] += z[i];
                v[i] += z[i] * z[i];
            }
        }
        for i in 0..2 {
            m[i] /= n as f64;
            v[i] = v[i] / n as f64 - m[i] * m[i];
            let (loc, sd) = (g.loc()[i], g.log_scale()[i].exp());
            assert!((m[i] - loc).abs() < 0.03, "mean[{i}] {} vs {loc}", m[i]);
            assert!(
                (v[i].sqrt() - sd).abs() < 0.03,
                "sd[{i}] {} vs {sd}",
                v[i].sqrt()
            );
        }
    }

    #[test]
    fn site_values_cover_every_latent_site() {
        let layout = SiteLayout::trace(&EightSchools::classic(), 0).unwrap();
        let g = MeanFieldGuide::for_layout(&layout);
        let mut rng = Rng::new(1);
        let vals = g.site_values(&layout, &mut rng);
        assert_eq!(vals.len(), 3);
        assert_eq!(vals["mu"].len(), 1);
        assert_eq!(vals["theta"].len(), 8);
        // tau is exp-constrained: the substituted value must be positive
        assert!(vals["tau"][0] > 0.0);
        assert!(!vals.contains_key("y"));
    }
}
