//! Posterior-predictive draws from a fitted guide, by **handler
//! composition** (the paper's Table-1 vocabulary): substitute a guide
//! draw for the latent sites with the existing
//! [`Substitute`] handler, strip the recorded data off the observed
//! sites, and let [`Seed`] resample them from the likelihood — the same
//! `EffModel` program that compiled into the SVI potential replays
//! unchanged.
//!
//! Stack (outermost first): `Seed | Substitute(guide draw) |
//! StripObserved | TraceH` — `process` runs innermost-first, so the
//! strip clears each observed site's value *before* `Substitute` pins
//! the latents and `Seed` redraws the now-valueless observation sites.

use std::collections::BTreeMap;

use crate::compile::{EffModel, HandlerCtx, SiteLayout};
use crate::effects::{Handler, Interp, Msg, Seed, Substitute, Trace, TraceH};
use crate::rng::Rng;
use crate::svi::guide::MeanFieldGuide;

/// Clears observed sites' values (and their observed flag) so an outer
/// [`Seed`] resamples them from their likelihood — turning a
/// conditioned model into its predictive distribution.
pub struct StripObserved;

impl Handler for StripObserved {
    fn process(&mut self, msg: &mut Msg) {
        if msg.is_observed {
            msg.value = None;
            msg.is_observed = false;
        }
    }
}

/// One posterior-predictive trace: latents fixed to a single guide
/// draw (constrained space), observation sites resampled from the
/// likelihood.  Every site of the program appears in the trace,
/// unobserved.
pub fn posterior_predictive_trace<M: EffModel>(
    model: &M,
    layout: &SiteLayout,
    guide: &MeanFieldGuide,
    seed: u64,
) -> Trace {
    let mut rng = Rng::new(seed);
    let values = guide.site_values(layout, &mut rng);
    let mut seed_h = Seed::new(rng.next_u64());
    let mut sub = Substitute::new(values);
    let mut strip = StripObserved;
    let mut trace = TraceH::default();
    {
        let mut interp = Interp::new(vec![&mut seed_h, &mut sub, &mut strip, &mut trace]);
        let mut ctx = HandlerCtx::new(&mut interp);
        model.run(&mut ctx);
    }
    trace.trace
}

/// `n` posterior-predictive replicates of every *observation* site,
/// keyed by trace site name (vectorized sites stay whole, per-element
/// sites appear as `"y.0"`, `"y.1"`, ... — the [`HandlerCtx`] naming),
/// each value the concatenation of the `n` replicates.
pub fn posterior_predictive_draws<M: EffModel>(
    model: &M,
    layout: &SiteLayout,
    guide: &MeanFieldGuide,
    seed: u64,
    n: usize,
) -> BTreeMap<String, Vec<f64>> {
    let mut out: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for rep in 0..n {
        let rep_seed = seed ^ (rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let trace = posterior_predictive_trace(model, layout, guide, rep_seed);
        for (name, site) in &trace {
            // latent sites replay the substituted guide draw; only
            // sites *not* in the layout's latent set are predictive
            if layout.latent(name).is_some() {
                continue;
            }
            out.entry(name.clone())
                .or_default()
                .extend_from_slice(&site.value);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::{EightSchools, NormalMean};

    #[test]
    fn latents_are_substituted_and_observations_resampled() {
        let model = EightSchools::classic();
        let layout = SiteLayout::trace(&model, 0).unwrap();
        let mut guide = MeanFieldGuide::for_layout(&layout);
        // pin the guide tight around known locs so the substitution is
        // recognizable in the trace
        for p in guide.params_mut()[10..].iter_mut() {
            *p = -9.0;
        }
        let trace = posterior_predictive_trace(&model, &layout, &guide, 11);
        // every site present, none observed (data was stripped)
        assert!(trace.values().all(|s| !s.is_observed));
        // tau was substituted with the constrained (positive) guide draw
        assert!(trace["tau"].value[0] > 0.0);
        // mu ~ q is tight around loc = 0
        assert!(trace["mu"].value[0].abs() < 1e-3);
        // predictive y.j were *resampled*, not the Rubin data
        let y0 = trace["y.0"].value[0];
        assert!((y0 - 28.0).abs() > 1e-9, "y.0 kept the observed value");
    }

    #[test]
    fn predictive_mean_tracks_guide_location_on_conjugate_model() {
        let model = NormalMean {
            y: vec![0.0; 4],
            sigma: 0.05,
        };
        let layout = SiteLayout::trace(&model, 0).unwrap();
        let mut guide = MeanFieldGuide::for_layout(&layout);
        guide.params_mut()[0] = 2.0; // loc
        guide.params_mut()[1] = -6.0; // nearly deterministic guide
        let draws = posterior_predictive_draws(&model, &layout, &guide, 5, 200);
        let y = &draws["y"];
        assert_eq!(y.len(), 4 * 200);
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        // y | mu ~ N(mu, 0.05), mu ~= 2.0  =>  predictive mean ~= 2.0
        assert!((mean - 2.0).abs() < 0.05, "predictive mean {mean}");
        assert!(!draws.contains_key("mu"));
    }
}
