//! Minibatch (subsampled) ELBO engines: the SVI half of the
//! Pyro-`plate(subsample_size)` contract (ROADMAP open item 4).
//!
//! Each engine wraps a full-batch particle backend with a
//! [`MinibatchScheduler`]: every step draws the next index window,
//! swaps it into the compiled potential via
//! [`SubsampleRebind::set_minibatch`] (a few `copy_from_slice` calls
//! into the frozen tape's data slots — **no re-recording, no
//! re-freezing**), and evaluates the ordinary reparameterized ELBO.
//! Because the compiled model scales its minibatch likelihood by
//! `N/B`, the step's ELBO gradient is an unbiased estimator of the
//! full-batch gradient over the scheduler's uniform minibatches —
//! pinned numerically in `rust/tests/subsampling.rs`.
//!
//! The scheduler draws from its **own** xoshiro stream
//! ([`scheduler_rng`], split off the run seed), so the eps noise
//! sequence is identical with and without subsampling; with `B == N`
//! the scheduler is the identity and never consumes randomness, making
//! the full-batch subsampled run bitwise equal to the plain SVI path.

use crate::compile::SubsampleRebind;
use crate::data::stream::{MinibatchScheduler, SubsampleCursor};
use crate::mcmc::{BatchPotential, Potential};
use crate::rng::Rng;
use crate::svi::elbo::ReparamElbo;
use crate::svi::native::ElboEngine;

/// The dedicated RNG stream for minibatch scheduling, split off the
/// run seed: deterministic per seed, independent of the eps stream
/// (`Rng::new(seed)`) the SVI driver itself consumes.
pub fn scheduler_rng(seed: u64) -> Rng {
    let mut base = Rng::new(seed);
    base.split(0x5B5A_11CE)
}

/// Minibatch particles evaluated one scalar [`Potential`] call at a
/// time — [`crate::svi::ScalarParticles`] plus a per-step minibatch
/// swap.
pub struct SubsampledScalarParticles<P: Potential + SubsampleRebind> {
    pot: P,
    elbo: ReparamElbo,
    sched: MinibatchScheduler,
}

impl<P: Potential + SubsampleRebind> SubsampledScalarParticles<P> {
    pub fn new(pot: P, particles: usize, sched: MinibatchScheduler) -> Self {
        let dim = pot.dim();
        SubsampledScalarParticles {
            pot,
            elbo: ReparamElbo::new(dim, particles),
            sched,
        }
    }
}

impl<P: Potential + SubsampleRebind> ElboEngine for SubsampledScalarParticles<P> {
    fn dim(&self) -> usize {
        self.elbo.dim()
    }

    fn particles(&self) -> usize {
        self.elbo.particles()
    }

    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        let idx = self.sched.next_batch();
        self.pot.set_minibatch(idx);
        self.elbo
            .value_and_grad_scalar(&mut self.pot, loc, log_scale, rng, grad)
    }

    fn subsample_cursor(&self) -> Option<SubsampleCursor> {
        Some(self.sched.cursor())
    }

    fn restore_subsample(&mut self, cur: &SubsampleCursor) {
        self.sched = MinibatchScheduler::from_cursor(self.sched.total(), self.sched.batch(), cur);
    }
}

/// Minibatch particles in one fused lane-minor [`BatchPotential`]
/// sweep per step — [`crate::svi::BatchedParticles`] plus a per-step
/// minibatch swap (the swap is lane-shared: one rebind serves all K
/// particle lanes, and every tile of a tiled potential).
pub struct SubsampledBatchedParticles<BP: BatchPotential + SubsampleRebind> {
    pot: BP,
    elbo: ReparamElbo,
    sched: MinibatchScheduler,
}

impl<BP: BatchPotential + SubsampleRebind> SubsampledBatchedParticles<BP> {
    pub fn new(pot: BP, sched: MinibatchScheduler) -> Self {
        let (dim, lanes) = (pot.dim(), pot.lanes());
        SubsampledBatchedParticles {
            pot,
            elbo: ReparamElbo::new(dim, lanes),
            sched,
        }
    }
}

impl<BP: BatchPotential + SubsampleRebind> ElboEngine for SubsampledBatchedParticles<BP> {
    fn dim(&self) -> usize {
        self.elbo.dim()
    }

    fn particles(&self) -> usize {
        self.elbo.particles()
    }

    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        let idx = self.sched.next_batch();
        self.pot.set_minibatch(idx);
        self.elbo
            .value_and_grad_batched(&mut self.pot, loc, log_scale, rng, grad)
    }

    fn subsample_cursor(&self) -> Option<SubsampleCursor> {
        Some(self.sched.cursor())
    }

    fn restore_subsample(&mut self, cur: &SubsampleCursor) {
        self.sched = MinibatchScheduler::from_cursor(self.sched.total(), self.sched.batch(), cur);
    }
}
