//! Stochastic Variational Inference — the second native inference
//! engine (Appendix D, E6).
//!
//! Two backends share this subsystem:
//!
//! * **Native** (default build, no artifacts): reparameterized ADVI
//!   over any compiled effect-handler model.  The mean-field guide
//!   ([`guide`]) is laid out over the model's unconstrained
//!   [`crate::compile::SiteLayout`]; the K-particle ELBO gradient
//!   ([`elbo`]) reuses the **frozen tape** potentials the NUTS engines
//!   already run — one fused [`crate::mcmc::BatchPotential`] lane sweep
//!   per step — and the chain rule to the variational parameters is
//!   closed-form host arithmetic.  The driver ([`native`]) adds
//!   Adam/SGD with schedules ([`optim`]), an ELBO trace, a convergence
//!   window and tail averaging, at zero steady-state allocations per
//!   step.  Entry points: [`crate::coordinator::run_svi_native`] and
//!   `fugue svi-model`.
//! * **PJRT artifact** ([`run_svi`], `--features pjrt` + `make
//!   artifacts`): the vectorized-particle ELBO gradient compiled by
//!   `aot.py`, with the same host-side optimizer loop.
//!
//! Both ascend with the **same** [`optim::Adam`] (the artifact loop's
//! Adam moved into [`optim`] so the native engine does not duplicate
//! it), and both report posteriors through the fitted
//! [`MeanFieldGuide`] — posterior-predictive replay composes the guide
//! with the existing [`crate::effects::Substitute`] handler
//! ([`predictive`]).

pub mod elbo;
pub mod guide;
pub mod native;
pub mod optim;
pub mod predictive;
pub mod subsample;

pub use elbo::ReparamElbo;
pub use guide::MeanFieldGuide;
pub use native::{
    elbo_mcse, BatchedParticles, Convergence, ElboEngine, NativeSvi, NativeSviResult,
    ScalarParticles, SviCursor, SviOptions, MAX_CONSECUTIVE_SKIPS,
};
pub use subsample::{scheduler_rng, SubsampledBatchedParticles, SubsampledScalarParticles};
pub use optim::{Adam, OptimKind, Optimizer, SgdMomentum, StepSchedule};
pub use predictive::{posterior_predictive_draws, posterior_predictive_trace, StripObserved};

use anyhow::{bail, Result};

use crate::rng::Rng;
use crate::runtime::engine::{literal_scalar_f64, literal_to_f64, Engine, HostTensor};

#[derive(Debug, Clone)]
pub struct SviResult {
    pub loc: Vec<f64>,
    pub log_scale: Vec<f64>,
    pub elbo_trace: Vec<f64>,
    pub steps: usize,
    pub secs: f64,
}

/// Run SVI against an `elbo_and_grad` artifact.
pub fn run_svi(
    engine: &Engine,
    artifact: &str,
    data: &[HostTensor],
    num_steps: usize,
    lr: f64,
    seed: u64,
) -> Result<SviResult> {
    let exe = engine.executable(artifact)?;
    if exe.entry.kind != "elbo_and_grad" {
        bail!("artifact {artifact} has kind {}, want elbo_and_grad", exe.entry.kind);
    }
    let dtype = exe.entry.inputs[1].dtype;
    let dim = exe.entry.inputs[1].elements();
    let data_bufs = data
        .iter()
        .map(|t| engine.upload(t))
        .collect::<Result<Vec<_>, _>>()?;

    let mut rng = Rng::new(seed);
    let mut loc = vec![0.0; dim];
    let mut log_scale = vec![guide::INIT_LOG_SCALE; dim];
    let mut adam = Adam::new(2 * dim, lr);
    let mut elbo_trace = Vec::with_capacity(num_steps);

    let t0 = std::time::Instant::now();
    for _ in 0..num_steps {
        let key = [
            (rng.next_u64() >> 32) as u32,
            (rng.next_u64() & 0xFFFF_FFFF) as u32,
        ];
        let key_b = engine.upload(&HostTensor::U32(key.to_vec(), vec![2]))?;
        let loc_b = engine.upload(&HostTensor::from_f64(&loc, &[dim], dtype)?)?;
        let ls_b = engine.upload(&HostTensor::from_f64(&log_scale, &[dim], dtype)?)?;
        let mut args = vec![&key_b, &loc_b, &ls_b];
        args.extend(data_bufs.iter());
        let outs = exe.run_buffers(&args)?;
        let elbo = literal_scalar_f64(&outs[0])?;
        let g_loc = literal_to_f64(&outs[1])?;
        let g_ls = literal_to_f64(&outs[2])?;
        elbo_trace.push(elbo);

        // the artifact returns d(-ELBO)/dparams (see aot.py); negate to
        // ascend the ELBO
        let mut params: Vec<f64> = loc.iter().chain(log_scale.iter()).copied().collect();
        let grad: Vec<f64> = g_loc.iter().chain(g_ls.iter()).map(|g| -g).collect();
        adam.step_ascent(&mut params, &grad);
        loc.copy_from_slice(&params[..dim]);
        log_scale.copy_from_slice(&params[dim..]);
    }

    Ok(SviResult {
        loc,
        log_scale,
        elbo_trace,
        steps: num_steps,
        secs: t0.elapsed().as_secs_f64(),
    })
}
