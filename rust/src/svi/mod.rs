//! Stochastic Variational Inference driver (Appendix D, E6).
//!
//! The vectorized-ELBO gradient (mean-field normal guide, vmapped over
//! particles) is compiled into the `*_elbo_and_grad` artifact; this
//! module supplies the host-side optimizer loop — a from-scratch Adam —
//! mirroring how NumPyro pairs `jit(ELBO.loss)` with a Python optimizer.

use anyhow::{bail, Result};

use crate::rng::Rng;
use crate::runtime::engine::{literal_scalar_f64, literal_to_f64, Engine, HostTensor};
/// Adam optimizer (Kingma & Ba), matching `numpyro.optim.Adam` defaults.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// Gradient-ascent step (we maximize the ELBO).
    pub fn step_ascent(&mut self, params: &mut [f64], grad: &[f64]) {
        self.t += 1;
        let t = self.t as f64;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] += self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[derive(Debug, Clone)]
pub struct SviResult {
    pub loc: Vec<f64>,
    pub log_scale: Vec<f64>,
    pub elbo_trace: Vec<f64>,
    pub steps: usize,
    pub secs: f64,
}

/// Run SVI against an `elbo_and_grad` artifact.
pub fn run_svi(
    engine: &Engine,
    artifact: &str,
    data: &[HostTensor],
    num_steps: usize,
    lr: f64,
    seed: u64,
) -> Result<SviResult> {
    let exe = engine.executable(artifact)?;
    if exe.entry.kind != "elbo_and_grad" {
        bail!("artifact {artifact} has kind {}, want elbo_and_grad", exe.entry.kind);
    }
    let dtype = exe.entry.inputs[1].dtype;
    let dim = exe.entry.inputs[1].elements();
    let data_bufs = data
        .iter()
        .map(|t| engine.upload(t))
        .collect::<Result<Vec<_>, _>>()?;

    let mut rng = Rng::new(seed);
    let mut loc = vec![0.0; dim];
    // exp(-2) initial guide scale
    let mut log_scale = vec![-2.0; dim];
    let mut adam = Adam::new(2 * dim, lr);
    let mut elbo_trace = Vec::with_capacity(num_steps);

    let t0 = std::time::Instant::now();
    for _ in 0..num_steps {
        let key = [
            (rng.next_u64() >> 32) as u32,
            (rng.next_u64() & 0xFFFF_FFFF) as u32,
        ];
        let key_b = engine.upload(&HostTensor::U32(key.to_vec(), vec![2]))?;
        let loc_b = engine.upload(&HostTensor::from_f64(&loc, &[dim], dtype)?)?;
        let ls_b = engine.upload(&HostTensor::from_f64(&log_scale, &[dim], dtype)?)?;
        let mut args = vec![&key_b, &loc_b, &ls_b];
        args.extend(data_bufs.iter());
        let outs = exe.run_buffers(&args)?;
        let elbo = literal_scalar_f64(&outs[0])?;
        let g_loc = literal_to_f64(&outs[1])?;
        let g_ls = literal_to_f64(&outs[2])?;
        elbo_trace.push(elbo);

        // the artifact returns d(-ELBO)/dparams (see aot.py); negate to
        // ascend the ELBO
        let mut params: Vec<f64> = loc.iter().chain(log_scale.iter()).copied().collect();
        let grad: Vec<f64> = g_loc.iter().chain(g_ls.iter()).map(|g| -g).collect();
        adam.step_ascent(&mut params, &grad);
        loc.copy_from_slice(&params[..dim]);
        log_scale.copy_from_slice(&params[dim..]);
    }

    Ok(SviResult {
        loc,
        log_scale,
        elbo_trace,
        steps: num_steps,
        secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // maximize -(x-3)^2 => x -> 3
        let mut adam = Adam::new(1, 0.05);
        let mut x = vec![0.0];
        for _ in 0..2000 {
            let g = vec![-2.0 * (x[0] - 3.0)];
            adam.step_ascent(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "x {}", x[0]);
    }

    #[test]
    fn adam_bias_correction_first_step() {
        let mut adam = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        adam.step_ascent(&mut x, &[1.0]);
        // first step magnitude ~ lr regardless of gradient scale
        assert!((x[0] - 0.1).abs() < 1e-6, "x {}", x[0]);
    }
}
