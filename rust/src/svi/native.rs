//! The native SVI driver: reparameterized ADVI steps over a compiled
//! model, host-side Adam/SGD, ELBO trace, convergence window, tail
//! (Polyak) averaging — the second inference engine next to NUTS, built
//! from the exact same compiled pieces.
//!
//! A step is: draw `eps`, evaluate the K-particle ELBO gradient through
//! the frozen tape ([`ReparamElbo`], one fused [`BatchPotential`] sweep
//! when `vectorize_particles`), take an optimizer ascent step on the
//! guide's flat `[loc..., log_scale...]` vector, record the ELBO.  All
//! buffers are sized at construction, so steady-state steps perform
//! **zero heap allocations** (`rust/tests/alloc_free.rs`).
//!
//! Entry points: [`crate::coordinator::run_svi_native`] (compiles the
//! model and picks the particle backend) and the `fugue svi-model` CLI.

use anyhow::{ensure, Result};

use crate::data::stream::SubsampleCursor;
use crate::mcmc::{BatchPotential, Potential};
use crate::obs::{Counter, Gauge, Phase, Recorder};
use crate::rng::Rng;
use crate::svi::elbo::ReparamElbo;
use crate::svi::guide::MeanFieldGuide;
use crate::svi::optim::{OptimKind, Optimizer, StepSchedule};

/// One K-particle ELBO gradient engine: the scalar-loop and
/// fused-lane backends behind [`NativeSvi`].
pub trait ElboEngine {
    fn dim(&self) -> usize;
    fn particles(&self) -> usize;
    /// Fresh-noise ELBO + gradient into `grad` (`2*dim`,
    /// `[dloc..., dlog_scale...]`).
    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64;

    /// Resume state of the engine's minibatch scheduler, when the
    /// engine subsamples ([`crate::svi::subsample`]); `None` for the
    /// full-batch engines, and the checkpoint omits the field.
    fn subsample_cursor(&self) -> Option<SubsampleCursor> {
        None
    }

    /// Restore the minibatch scheduler from a checkpointed cursor
    /// (no-op for full-batch engines).
    fn restore_subsample(&mut self, _cur: &SubsampleCursor) {}
}

/// K particles evaluated one scalar [`Potential`] call at a time —
/// the reference backend (and the `--no-vectorize-particles` path).
pub struct ScalarParticles<P: Potential> {
    pot: P,
    elbo: ReparamElbo,
}

impl<P: Potential> ScalarParticles<P> {
    pub fn new(pot: P, particles: usize) -> ScalarParticles<P> {
        let dim = pot.dim();
        ScalarParticles {
            pot,
            elbo: ReparamElbo::new(dim, particles),
        }
    }
}

impl<P: Potential> ElboEngine for ScalarParticles<P> {
    fn dim(&self) -> usize {
        self.elbo.dim()
    }

    fn particles(&self) -> usize {
        self.elbo.particles()
    }

    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        self.elbo
            .value_and_grad_scalar(&mut self.pot, loc, log_scale, rng, grad)
    }
}

/// All K particles in one fused lane-minor [`BatchPotential`] sweep per
/// step — the fast path (`svi_particle_batch_speedup` in
/// BENCH_native.json), bitwise equal to [`ScalarParticles`] under the
/// same RNG stream.
pub struct BatchedParticles<BP: BatchPotential> {
    pot: BP,
    elbo: ReparamElbo,
}

impl<BP: BatchPotential> BatchedParticles<BP> {
    pub fn new(pot: BP) -> BatchedParticles<BP> {
        let (dim, lanes) = (pot.dim(), pot.lanes());
        BatchedParticles {
            pot,
            elbo: ReparamElbo::new(dim, lanes),
        }
    }
}

impl<BP: BatchPotential> ElboEngine for BatchedParticles<BP> {
    fn dim(&self) -> usize {
        self.elbo.dim()
    }

    fn particles(&self) -> usize {
        self.elbo.particles()
    }

    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        self.elbo
            .value_and_grad_batched(&mut self.pot, loc, log_scale, rng, grad)
    }
}

/// Early-stopping rule: every `window` steps, compare the mean ELBO of
/// the last window against the window before it and stop when the
/// relative improvement falls below `rel_tol`.
#[derive(Debug, Clone, Copy)]
pub struct Convergence {
    pub window: usize,
    pub rel_tol: f64,
}

/// Options for a native SVI run.
#[derive(Debug, Clone)]
pub struct SviOptions {
    pub num_steps: usize,
    pub num_particles: usize,
    /// Base learning rate (modulated per step by `schedule`).
    pub lr: f64,
    pub seed: u64,
    pub optimizer: OptimKind,
    pub schedule: StepSchedule,
    /// Evaluate the K particles as one fused `BatchPotential` sweep
    /// (default) instead of a scalar-potential loop.
    pub vectorize_particles: bool,
    /// `Some`: stop early once the windowed ELBO stops improving.
    pub convergence: Option<Convergence>,
    /// Average the guide parameters over the final `tail_average`
    /// fraction of the run (Polyak tail averaging, `0.0` disables):
    /// smooths the stochastic-gradient wobble out of the reported
    /// posterior without touching the optimization itself.
    pub tail_average: f64,
}

impl Default for SviOptions {
    fn default() -> Self {
        SviOptions {
            num_steps: 1000,
            num_particles: 4,
            lr: 0.05,
            seed: 0,
            optimizer: OptimKind::Adam,
            schedule: StepSchedule::Constant,
            vectorize_particles: true,
            convergence: None,
            tail_average: 0.25,
        }
    }
}

/// Result of a native SVI run: the fitted guide (tail-averaged when
/// enabled), the raw final-state guide, and the ELBO trajectory.
#[derive(Debug, Clone)]
pub struct NativeSviResult {
    /// The fitted variational posterior.
    pub guide: MeanFieldGuide,
    /// Per-step ELBO estimates (length = steps actually run).
    pub elbo_trace: Vec<f64>,
    /// Steps actually run (< `num_steps` when converged early).
    pub steps: usize,
    /// Whether the convergence window triggered the early stop.
    pub converged: bool,
    pub secs: f64,
    /// Steps whose ELBO or gradient came back non-finite and were
    /// contained (optimizer step skipped, learning rate backed off).
    /// Always 0 on a healthy run.
    pub skipped: u64,
    /// False when a wall-clock deadline (or a run of
    /// [`MAX_CONSECUTIVE_SKIPS`] unrecoverable steps) cut the run
    /// short of `num_steps`/convergence.
    pub completed: bool,
    /// Monte-Carlo standard error of the ELBO over the convergence
    /// window (sample sd of the trace tail divided by `sqrt(window)`):
    /// the noise floor the windowed-mean convergence rule is comparing
    /// against.  `0.0` when fewer than two steps were recorded.
    pub elbo_mcse: f64,
}

/// Monte-Carlo standard error of the mean of the last `window` entries
/// of `trace`: sample standard deviation of the tail divided by
/// `sqrt(window)`.  Returns `0.0` when fewer than two entries exist.
pub fn elbo_mcse(trace: &[f64], window: usize) -> f64 {
    let n = trace.len();
    let w = window.min(n);
    if w < 2 {
        return 0.0;
    }
    let tail = &trace[n - w..];
    let mean = tail.iter().sum::<f64>() / w as f64;
    let var = tail.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>() / (w - 1) as f64;
    (var / w as f64).sqrt()
}

/// Abort threshold for the containment layer: this many non-finite
/// steps *in a row* means the ELBO is non-finite at the current
/// parameters themselves (not a transient noise draw) and retrying
/// cannot recover — the run stops with `completed = false`.
pub const MAX_CONSECUTIVE_SKIPS: u32 = 64;

/// The complete resumable state of a native SVI run between steps:
/// guide parameters, optimizer moments, RNG stream (incl. the cached
/// Box-Muller spare), ELBO trace, tail-average accumulator and the
/// containment bookkeeping.  Step boundaries are full checkpoints —
/// the gradient buffer is pure per-step scratch — so serializing a
/// cursor ([`crate::coordinator::checkpoint`]) and resuming continues
/// the fit **bitwise-identically**.
#[derive(Debug, Clone)]
pub struct SviCursor {
    /// Flat `[loc..., log_scale...]` guide parameters.
    pub params: Vec<f64>,
    /// Optimizer moment buffers ([`Optimizer::export_state`]).
    pub opt_moments: Vec<Vec<f64>>,
    /// Optimizer step counter (Adam bias correction).
    pub opt_t: u64,
    pub rng_s: [u64; 4],
    pub rng_spare: Option<f64>,
    pub elbo_trace: Vec<f64>,
    pub avg_params: Vec<f64>,
    pub avg_count: u64,
    pub backoff: f64,
    pub skipped: u64,
    /// Minibatch-scheduler resume state (`None` for full-batch runs —
    /// absent from, and backward-compatible with, pre-subsampling
    /// checkpoints).
    pub subsample: Option<SubsampleCursor>,
}

impl NativeSviResult {
    /// Mean ELBO over the final `window` recorded steps.
    pub fn final_elbo(&self, window: usize) -> f64 {
        let n = self.elbo_trace.len();
        let w = window.clamp(1, n.max(1));
        self.elbo_trace[n - w..].iter().sum::<f64>() / w as f64
    }
}

/// The SVI step loop over any [`ElboEngine`].  Owns the guide, the
/// optimizer and every scratch buffer; [`NativeSvi::step`] is the
/// zero-allocation unit the alloc-free tests pin.
pub struct NativeSvi<E: ElboEngine> {
    engine: E,
    guide: MeanFieldGuide,
    opt: Box<dyn Optimizer>,
    schedule: StepSchedule,
    base_lr: f64,
    rng: Rng,
    grad: Vec<f64>,
    elbo_trace: Vec<f64>,
    num_steps: usize,
    convergence: Option<Convergence>,
    /// running sum of guide params over the averaged tail
    avg_params: Vec<f64>,
    avg_count: u64,
    avg_from: usize,
    /// Containment: learning-rate multiplier, 1.0 while healthy
    /// (`lr * 1.0` is an IEEE identity, so healthy runs are untouched
    /// bitwise).  Halved on every skipped step, recovered by 1.5x
    /// (clamped to 1.0) on each healthy step after a fault.
    backoff: f64,
    /// Total steps skipped because the ELBO or gradient was non-finite.
    skipped: u64,
    /// Current run of consecutive skips (aborts the run at
    /// [`MAX_CONSECUTIVE_SKIPS`]).  Not checkpointed: a resume starts
    /// with a clean retry budget.
    consec_skips: u32,
    /// Flight recorder ([`crate::obs`]) — observes finished steps only;
    /// never consumes RNG or perturbs the optimization, so a recording
    /// run stays bitwise identical to a silent one.
    recorder: Recorder,
}

impl<E: ElboEngine> NativeSvi<E> {
    pub fn new(engine: E, opts: &SviOptions) -> Result<NativeSvi<E>> {
        ensure!(opts.num_steps > 0, "SVI needs at least one step");
        ensure!(
            opts.num_particles == engine.particles(),
            "engine evaluates {} particles, options ask for {}",
            engine.particles(),
            opts.num_particles
        );
        ensure!(
            (0.0..=1.0).contains(&opts.tail_average),
            "tail_average must be in [0, 1]"
        );
        if let Some(c) = &opts.convergence {
            ensure!(c.window > 0, "convergence window must be positive");
        }
        let dim = engine.dim();
        let guide = MeanFieldGuide::new(dim);
        let avg_from = if opts.tail_average > 0.0 {
            (opts.num_steps as f64 * (1.0 - opts.tail_average)).floor() as usize
        } else {
            opts.num_steps
        };
        Ok(NativeSvi {
            engine,
            guide,
            opt: opts.optimizer.build(2 * dim, opts.lr),
            schedule: opts.schedule,
            base_lr: opts.lr,
            rng: Rng::new(opts.seed),
            grad: vec![0.0; 2 * dim],
            elbo_trace: Vec::with_capacity(opts.num_steps),
            num_steps: opts.num_steps,
            convergence: opts.convergence,
            avg_params: vec![0.0; 2 * dim],
            avg_count: 0,
            avg_from,
            backoff: 1.0,
            skipped: 0,
            consec_skips: 0,
            recorder: Recorder::global(),
        })
    }

    /// Point this driver's flight-recorder hooks at an explicit
    /// registry (tests and benchmarks; normal construction picks up
    /// the process-global recorder).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    /// The guide in its current (raw, non-averaged) state.
    pub fn guide(&self) -> &MeanFieldGuide {
        &self.guide
    }

    /// ELBO estimates recorded so far.
    pub fn elbo_trace(&self) -> &[f64] {
        &self.elbo_trace
    }

    /// One SVI step: ELBO gradient through the frozen tape, scheduled
    /// optimizer ascent, trace bookkeeping.  Returns the step's ELBO
    /// estimate.  Allocation-free in the steady state.
    ///
    /// Containment: a non-finite ELBO or any non-finite gradient entry
    /// is a *skipped* step — the optimizer does not move, nothing is
    /// recorded in the trace, and the learning rate backs off by half
    /// for the retry (fresh noise, step index unchanged).  Healthy
    /// steps after a fault recover the rate by 1.5x up to its scheduled
    /// value.  A healthy run never skips, and its `backoff` stays 1.0,
    /// so it is bitwise-unchanged by this layer.
    pub fn step(&mut self) -> f64 {
        let t = self.elbo_trace.len();
        let lr = self.schedule.lr_at(self.base_lr, t) * self.backoff;
        let dim = self.guide.dim();
        let rec = self.recorder;
        let NativeSvi {
            engine,
            guide,
            opt,
            rng,
            grad,
            elbo_trace,
            avg_params,
            avg_count,
            avg_from,
            backoff,
            skipped,
            consec_skips,
            ..
        } = self;
        opt.set_lr(lr);
        let params = guide.params_mut();
        let elbo = {
            let (loc, log_scale) = params.split_at(dim);
            engine.elbo_and_grad(loc, log_scale, rng, grad)
        };
        if !elbo.is_finite() || grad.iter().any(|g| !g.is_finite()) {
            *skipped += 1;
            *consec_skips += 1;
            *backoff *= 0.5;
            rec.incr(Counter::SviSkips);
            rec.set_gauge(Gauge::LrBackoff, *backoff);
            return elbo;
        }
        *consec_skips = 0;
        if *backoff < 1.0 {
            *backoff = (*backoff * 1.5).min(1.0);
        }
        // pure observation of the finished gradient — the norm is
        // computed only when a recorder is live and feeds nothing back
        if rec.enabled() {
            rec.incr(Counter::SviSteps);
            rec.record_elbo(elbo);
            let norm = grad.iter().map(|g| g * g).sum::<f64>().sqrt();
            rec.set_gauge(Gauge::GradNorm, norm);
            rec.set_gauge(Gauge::LrBackoff, *backoff);
        }
        opt.step_ascent(params, grad);
        if t >= *avg_from {
            for (a, p) in avg_params.iter_mut().zip(params.iter()) {
                *a += *p;
            }
            *avg_count += 1;
        }
        // capacity was reserved for num_steps up front; steady-state
        // pushes never reallocate
        elbo_trace.push(elbo);
        elbo
    }

    /// Snapshot the complete resumable state (see [`SviCursor`]).
    pub fn export_cursor(&self) -> SviCursor {
        let (moments, opt_t) = self.opt.export_state();
        let (rng_s, rng_spare) = self.rng.state();
        SviCursor {
            params: self.guide.params().to_vec(),
            opt_moments: moments,
            opt_t,
            rng_s,
            rng_spare,
            elbo_trace: self.elbo_trace.clone(),
            avg_params: self.avg_params.clone(),
            avg_count: self.avg_count,
            backoff: self.backoff,
            skipped: self.skipped,
            subsample: self.engine.subsample_cursor(),
        }
    }

    /// Restore a [`SviCursor`] snapshot; subsequent steps continue
    /// bitwise-identically to the run the snapshot was taken from.
    pub fn import_cursor(&mut self, cur: &SviCursor) -> Result<()> {
        ensure!(
            cur.params.len() == self.guide.params().len(),
            "checkpoint has {} guide parameters, model needs {}",
            cur.params.len(),
            self.guide.params().len()
        );
        ensure!(
            cur.avg_params.len() == self.avg_params.len(),
            "checkpoint tail-average buffer has wrong length"
        );
        ensure!(
            cur.elbo_trace.len() <= self.num_steps,
            "checkpoint already has {} steps, options ask for {}",
            cur.elbo_trace.len(),
            self.num_steps
        );
        self.guide.params_mut().copy_from_slice(&cur.params);
        self.opt.import_state(&cur.opt_moments, cur.opt_t);
        self.rng = Rng::from_state(cur.rng_s, cur.rng_spare);
        self.elbo_trace = Vec::with_capacity(self.num_steps);
        self.elbo_trace.extend_from_slice(&cur.elbo_trace);
        self.avg_params.copy_from_slice(&cur.avg_params);
        self.avg_count = cur.avg_count;
        self.backoff = cur.backoff;
        self.skipped = cur.skipped;
        self.consec_skips = 0;
        if let Some(sc) = &cur.subsample {
            self.engine.restore_subsample(sc);
        }
        Ok(())
    }

    /// Whether the convergence rule fires at the current trace length.
    fn converged_now(&self) -> bool {
        let c = match self.convergence {
            Some(c) => c,
            None => return false,
        };
        let n = self.elbo_trace.len();
        if n < 2 * c.window || n % c.window != 0 {
            return false;
        }
        let recent: f64 =
            self.elbo_trace[n - c.window..].iter().sum::<f64>() / c.window as f64;
        let prev: f64 = self.elbo_trace[n - 2 * c.window..n - c.window]
            .iter()
            .sum::<f64>()
            / c.window as f64;
        (recent - prev).abs() <= c.rel_tol * (1.0 + prev.abs())
    }

    /// Run to `num_steps` (or early convergence) and package the
    /// result.  The reported guide is the tail average when at least
    /// one averaged step ran, else the raw final state.
    pub fn run(self) -> NativeSviResult {
        self.run_with(None, 0, &mut |_| Ok(()))
            .expect("no-op checkpoint sink cannot fail")
    }

    /// [`run`](NativeSvi::run) with fault-containment plumbing: an
    /// optional wall-clock `deadline` (crossed → stop at the next step
    /// boundary with `completed = false` and partial results), and a
    /// checkpoint `sink` invoked with a full [`SviCursor`] snapshot
    /// every `checkpoint_every` recorded steps (0 = never).
    pub fn run_with(
        mut self,
        deadline: Option<std::time::Instant>,
        checkpoint_every: usize,
        sink: &mut dyn FnMut(&SviCursor) -> Result<()>,
    ) -> Result<NativeSviResult> {
        let t0 = std::time::Instant::now();
        let rec = self.recorder;
        rec.set_phase(Phase::Optimizing);
        let mut converged = false;
        let mut completed = true;
        while self.elbo_trace.len() < self.num_steps {
            if let Some(d) = deadline {
                if std::time::Instant::now() >= d {
                    completed = false;
                    break;
                }
            }
            if self.consec_skips >= MAX_CONSECUTIVE_SKIPS {
                completed = false;
                break;
            }
            let before = self.elbo_trace.len();
            self.step();
            let n = self.elbo_trace.len();
            if checkpoint_every > 0 && n > before && n % checkpoint_every == 0 && n < self.num_steps
            {
                sink(&self.export_cursor())?;
            }
            if self.converged_now() {
                converged = true;
                break;
            }
        }
        if !completed {
            // final snapshot so the interrupted fit is resumable
            sink(&self.export_cursor())?;
        }
        let secs = t0.elapsed().as_secs_f64();
        let steps = self.elbo_trace.len();
        let skipped = self.skipped;
        let mcse_window = self.convergence.map_or((steps / 10).max(25), |c| c.window);
        let mcse = elbo_mcse(&self.elbo_trace, mcse_window);
        rec.set_gauge(Gauge::ElboMcse, mcse);
        rec.set_phase(Phase::Done);
        let mut guide = self.guide;
        if self.avg_count > 0 {
            let inv = 1.0 / self.avg_count as f64;
            for (p, a) in guide.params_mut().iter_mut().zip(&self.avg_params) {
                *p = *a * inv;
            }
        }
        Ok(NativeSviResult {
            guide,
            elbo_trace: self.elbo_trace,
            steps,
            converged,
            secs,
            skipped,
            completed,
            elbo_mcse: mcse,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::NormalMean;
    use crate::compile::{compile, compile_batched};

    fn toy() -> NormalMean {
        NormalMean {
            y: vec![1.0, 2.0, 0.5, 1.5],
            sigma: 1.0,
        }
    }

    #[test]
    fn elbo_increases_on_conjugate_model() {
        let pot = compile(toy(), 0).unwrap();
        let opts = SviOptions {
            num_steps: 400,
            num_particles: 2,
            lr: 0.05,
            seed: 3,
            vectorize_particles: false,
            tail_average: 0.0,
            ..Default::default()
        };
        let svi = NativeSvi::new(ScalarParticles::new(pot, 2), &opts).unwrap();
        let res = svi.run();
        assert_eq!(res.steps, 400);
        let early: f64 = res.elbo_trace[..50].iter().sum::<f64>() / 50.0;
        let late = res.final_elbo(50);
        assert!(late > early, "ELBO did not increase: {early} -> {late}");
    }

    #[test]
    fn convergence_window_stops_early() {
        let pot = compile_batched(toy(), 0, 4).unwrap();
        let opts = SviOptions {
            num_steps: 5000,
            num_particles: 4,
            lr: 0.05,
            seed: 1,
            convergence: Some(Convergence {
                window: 100,
                rel_tol: 0.02,
            }),
            ..Default::default()
        };
        let svi = NativeSvi::new(BatchedParticles::new(pot), &opts).unwrap();
        let res = svi.run();
        assert!(res.converged, "conjugate model should converge");
        assert!(res.steps < 5000, "ran all {} steps", res.steps);
    }

    #[test]
    fn particle_count_mismatch_is_rejected() {
        let pot = compile(toy(), 0).unwrap();
        let opts = SviOptions {
            num_particles: 8,
            ..Default::default()
        };
        assert!(NativeSvi::new(ScalarParticles::new(pot, 4), &opts).is_err());
    }
}
