//! The native SVI driver: reparameterized ADVI steps over a compiled
//! model, host-side Adam/SGD, ELBO trace, convergence window, tail
//! (Polyak) averaging — the second inference engine next to NUTS, built
//! from the exact same compiled pieces.
//!
//! A step is: draw `eps`, evaluate the K-particle ELBO gradient through
//! the frozen tape ([`ReparamElbo`], one fused [`BatchPotential`] sweep
//! when `vectorize_particles`), take an optimizer ascent step on the
//! guide's flat `[loc..., log_scale...]` vector, record the ELBO.  All
//! buffers are sized at construction, so steady-state steps perform
//! **zero heap allocations** (`rust/tests/alloc_free.rs`).
//!
//! Entry points: [`crate::coordinator::run_svi_native`] (compiles the
//! model and picks the particle backend) and the `fugue svi-model` CLI.

use anyhow::{ensure, Result};

use crate::mcmc::{BatchPotential, Potential};
use crate::rng::Rng;
use crate::svi::elbo::ReparamElbo;
use crate::svi::guide::MeanFieldGuide;
use crate::svi::optim::{OptimKind, Optimizer, StepSchedule};

/// One K-particle ELBO gradient engine: the scalar-loop and
/// fused-lane backends behind [`NativeSvi`].
pub trait ElboEngine {
    fn dim(&self) -> usize;
    fn particles(&self) -> usize;
    /// Fresh-noise ELBO + gradient into `grad` (`2*dim`,
    /// `[dloc..., dlog_scale...]`).
    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64;
}

/// K particles evaluated one scalar [`Potential`] call at a time —
/// the reference backend (and the `--no-vectorize-particles` path).
pub struct ScalarParticles<P: Potential> {
    pot: P,
    elbo: ReparamElbo,
}

impl<P: Potential> ScalarParticles<P> {
    pub fn new(pot: P, particles: usize) -> ScalarParticles<P> {
        let dim = pot.dim();
        ScalarParticles {
            pot,
            elbo: ReparamElbo::new(dim, particles),
        }
    }
}

impl<P: Potential> ElboEngine for ScalarParticles<P> {
    fn dim(&self) -> usize {
        self.elbo.dim()
    }

    fn particles(&self) -> usize {
        self.elbo.particles()
    }

    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        self.elbo
            .value_and_grad_scalar(&mut self.pot, loc, log_scale, rng, grad)
    }
}

/// All K particles in one fused lane-minor [`BatchPotential`] sweep per
/// step — the fast path (`svi_particle_batch_speedup` in
/// BENCH_native.json), bitwise equal to [`ScalarParticles`] under the
/// same RNG stream.
pub struct BatchedParticles<BP: BatchPotential> {
    pot: BP,
    elbo: ReparamElbo,
}

impl<BP: BatchPotential> BatchedParticles<BP> {
    pub fn new(pot: BP) -> BatchedParticles<BP> {
        let (dim, lanes) = (pot.dim(), pot.lanes());
        BatchedParticles {
            pot,
            elbo: ReparamElbo::new(dim, lanes),
        }
    }
}

impl<BP: BatchPotential> ElboEngine for BatchedParticles<BP> {
    fn dim(&self) -> usize {
        self.elbo.dim()
    }

    fn particles(&self) -> usize {
        self.elbo.particles()
    }

    fn elbo_and_grad(
        &mut self,
        loc: &[f64],
        log_scale: &[f64],
        rng: &mut Rng,
        grad: &mut [f64],
    ) -> f64 {
        self.elbo
            .value_and_grad_batched(&mut self.pot, loc, log_scale, rng, grad)
    }
}

/// Early-stopping rule: every `window` steps, compare the mean ELBO of
/// the last window against the window before it and stop when the
/// relative improvement falls below `rel_tol`.
#[derive(Debug, Clone, Copy)]
pub struct Convergence {
    pub window: usize,
    pub rel_tol: f64,
}

/// Options for a native SVI run.
#[derive(Debug, Clone)]
pub struct SviOptions {
    pub num_steps: usize,
    pub num_particles: usize,
    /// Base learning rate (modulated per step by `schedule`).
    pub lr: f64,
    pub seed: u64,
    pub optimizer: OptimKind,
    pub schedule: StepSchedule,
    /// Evaluate the K particles as one fused `BatchPotential` sweep
    /// (default) instead of a scalar-potential loop.
    pub vectorize_particles: bool,
    /// `Some`: stop early once the windowed ELBO stops improving.
    pub convergence: Option<Convergence>,
    /// Average the guide parameters over the final `tail_average`
    /// fraction of the run (Polyak tail averaging, `0.0` disables):
    /// smooths the stochastic-gradient wobble out of the reported
    /// posterior without touching the optimization itself.
    pub tail_average: f64,
}

impl Default for SviOptions {
    fn default() -> Self {
        SviOptions {
            num_steps: 1000,
            num_particles: 4,
            lr: 0.05,
            seed: 0,
            optimizer: OptimKind::Adam,
            schedule: StepSchedule::Constant,
            vectorize_particles: true,
            convergence: None,
            tail_average: 0.25,
        }
    }
}

/// Result of a native SVI run: the fitted guide (tail-averaged when
/// enabled), the raw final-state guide, and the ELBO trajectory.
#[derive(Debug, Clone)]
pub struct NativeSviResult {
    /// The fitted variational posterior.
    pub guide: MeanFieldGuide,
    /// Per-step ELBO estimates (length = steps actually run).
    pub elbo_trace: Vec<f64>,
    /// Steps actually run (< `num_steps` when converged early).
    pub steps: usize,
    /// Whether the convergence window triggered the early stop.
    pub converged: bool,
    pub secs: f64,
}

impl NativeSviResult {
    /// Mean ELBO over the final `window` recorded steps.
    pub fn final_elbo(&self, window: usize) -> f64 {
        let n = self.elbo_trace.len();
        let w = window.clamp(1, n.max(1));
        self.elbo_trace[n - w..].iter().sum::<f64>() / w as f64
    }
}

/// The SVI step loop over any [`ElboEngine`].  Owns the guide, the
/// optimizer and every scratch buffer; [`NativeSvi::step`] is the
/// zero-allocation unit the alloc-free tests pin.
pub struct NativeSvi<E: ElboEngine> {
    engine: E,
    guide: MeanFieldGuide,
    opt: Box<dyn Optimizer>,
    schedule: StepSchedule,
    base_lr: f64,
    rng: Rng,
    grad: Vec<f64>,
    elbo_trace: Vec<f64>,
    num_steps: usize,
    convergence: Option<Convergence>,
    /// running sum of guide params over the averaged tail
    avg_params: Vec<f64>,
    avg_count: u64,
    avg_from: usize,
}

impl<E: ElboEngine> NativeSvi<E> {
    pub fn new(engine: E, opts: &SviOptions) -> Result<NativeSvi<E>> {
        ensure!(opts.num_steps > 0, "SVI needs at least one step");
        ensure!(
            opts.num_particles == engine.particles(),
            "engine evaluates {} particles, options ask for {}",
            engine.particles(),
            opts.num_particles
        );
        ensure!(
            (0.0..=1.0).contains(&opts.tail_average),
            "tail_average must be in [0, 1]"
        );
        if let Some(c) = &opts.convergence {
            ensure!(c.window > 0, "convergence window must be positive");
        }
        let dim = engine.dim();
        let guide = MeanFieldGuide::new(dim);
        let avg_from = if opts.tail_average > 0.0 {
            (opts.num_steps as f64 * (1.0 - opts.tail_average)).floor() as usize
        } else {
            opts.num_steps
        };
        Ok(NativeSvi {
            engine,
            guide,
            opt: opts.optimizer.build(2 * dim, opts.lr),
            schedule: opts.schedule,
            base_lr: opts.lr,
            rng: Rng::new(opts.seed),
            grad: vec![0.0; 2 * dim],
            elbo_trace: Vec::with_capacity(opts.num_steps),
            num_steps: opts.num_steps,
            convergence: opts.convergence,
            avg_params: vec![0.0; 2 * dim],
            avg_count: 0,
            avg_from,
        })
    }

    /// The guide in its current (raw, non-averaged) state.
    pub fn guide(&self) -> &MeanFieldGuide {
        &self.guide
    }

    /// ELBO estimates recorded so far.
    pub fn elbo_trace(&self) -> &[f64] {
        &self.elbo_trace
    }

    /// One SVI step: ELBO gradient through the frozen tape, scheduled
    /// optimizer ascent, trace bookkeeping.  Returns the step's ELBO
    /// estimate.  Allocation-free in the steady state.
    pub fn step(&mut self) -> f64 {
        let t = self.elbo_trace.len();
        let lr = self.schedule.lr_at(self.base_lr, t);
        let dim = self.guide.dim();
        let NativeSvi {
            engine,
            guide,
            opt,
            rng,
            grad,
            elbo_trace,
            avg_params,
            avg_count,
            avg_from,
            ..
        } = self;
        opt.set_lr(lr);
        let params = guide.params_mut();
        let elbo = {
            let (loc, log_scale) = params.split_at(dim);
            engine.elbo_and_grad(loc, log_scale, rng, grad)
        };
        opt.step_ascent(params, grad);
        if t >= *avg_from {
            for (a, p) in avg_params.iter_mut().zip(params.iter()) {
                *a += *p;
            }
            *avg_count += 1;
        }
        // capacity was reserved for num_steps up front; steady-state
        // pushes never reallocate
        elbo_trace.push(elbo);
        elbo
    }

    /// Whether the convergence rule fires at the current trace length.
    fn converged_now(&self) -> bool {
        let c = match self.convergence {
            Some(c) => c,
            None => return false,
        };
        let n = self.elbo_trace.len();
        if n < 2 * c.window || n % c.window != 0 {
            return false;
        }
        let recent: f64 =
            self.elbo_trace[n - c.window..].iter().sum::<f64>() / c.window as f64;
        let prev: f64 = self.elbo_trace[n - 2 * c.window..n - c.window]
            .iter()
            .sum::<f64>()
            / c.window as f64;
        (recent - prev).abs() <= c.rel_tol * (1.0 + prev.abs())
    }

    /// Run to `num_steps` (or early convergence) and package the
    /// result.  The reported guide is the tail average when at least
    /// one averaged step ran, else the raw final state.
    pub fn run(mut self) -> NativeSviResult {
        let t0 = std::time::Instant::now();
        let mut converged = false;
        while self.elbo_trace.len() < self.num_steps {
            self.step();
            if self.converged_now() {
                converged = true;
                break;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let steps = self.elbo_trace.len();
        let mut guide = self.guide;
        if self.avg_count > 0 {
            let inv = 1.0 / self.avg_count as f64;
            for (p, a) in guide.params_mut().iter_mut().zip(&self.avg_params) {
                *p = *a * inv;
            }
        }
        NativeSviResult {
            guide,
            elbo_trace: self.elbo_trace,
            steps,
            converged,
            secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::NormalMean;
    use crate::compile::{compile, compile_batched};

    fn toy() -> NormalMean {
        NormalMean {
            y: vec![1.0, 2.0, 0.5, 1.5],
            sigma: 1.0,
        }
    }

    #[test]
    fn elbo_increases_on_conjugate_model() {
        let pot = compile(toy(), 0).unwrap();
        let opts = SviOptions {
            num_steps: 400,
            num_particles: 2,
            lr: 0.05,
            seed: 3,
            vectorize_particles: false,
            tail_average: 0.0,
            ..Default::default()
        };
        let svi = NativeSvi::new(ScalarParticles::new(pot, 2), &opts).unwrap();
        let res = svi.run();
        assert_eq!(res.steps, 400);
        let early: f64 = res.elbo_trace[..50].iter().sum::<f64>() / 50.0;
        let late = res.final_elbo(50);
        assert!(late > early, "ELBO did not increase: {early} -> {late}");
    }

    #[test]
    fn convergence_window_stops_early() {
        let pot = compile_batched(toy(), 0, 4).unwrap();
        let opts = SviOptions {
            num_steps: 5000,
            num_particles: 4,
            lr: 0.05,
            seed: 1,
            convergence: Some(Convergence {
                window: 100,
                rel_tol: 0.02,
            }),
            ..Default::default()
        };
        let svi = NativeSvi::new(BatchedParticles::new(pot), &opts).unwrap();
        let res = svi.run();
        assert!(res.converged, "conjugate model should converge");
        assert!(res.steps < 5000, "ran all {} steps", res.steps);
    }

    #[test]
    fn particle_count_mismatch_is_rejected() {
        let pot = compile(toy(), 0).unwrap();
        let opts = SviOptions {
            num_particles: 8,
            ..Default::default()
        };
        assert!(NativeSvi::new(ScalarParticles::new(pot, 4), &opts).is_err());
    }
}
