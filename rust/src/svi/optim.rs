//! First-order ascent optimizers shared by **both** SVI backends: the
//! native reparameterized-ADVI engine ([`crate::svi::NativeSvi`]) and
//! the PJRT artifact path ([`crate::svi::run_svi`]).
//!
//! The Adam implementation here is the one the artifact loop has used
//! since the seed (same Kingma & Ba defaults as `numpyro.optim.Adam`);
//! it moved out of `svi/mod.rs` so the native engine does not duplicate
//! it.  Everything operates on a flat `params` slice — for the
//! mean-field guide that is `[loc..., log_scale...]`
//! ([`crate::svi::MeanFieldGuide`]) — and **ascends** (SVI maximizes
//! the ELBO).
//!
//! All state (first/second moment vectors, velocity) is allocated at
//! construction, so steady-state steps are allocation-free — the same
//! bar as the rest of the hot path (`rust/tests/alloc_free.rs`).

use anyhow::{bail, Result};

/// A stateful first-order optimizer over a flat parameter vector.
///
/// `step_ascent` moves `params` **uphill** along `grad`; schedules
/// retune the learning rate between steps via [`Optimizer::set_lr`].
pub trait Optimizer {
    /// Gradient-ascent step (SVI maximizes the ELBO).
    fn step_ascent(&mut self, params: &mut [f64], grad: &[f64]);

    /// Retune the learning rate (used by [`StepSchedule`]s).
    fn set_lr(&mut self, lr: f64);

    /// Current learning rate.
    fn lr(&self) -> f64;

    /// Serialize the optimizer's mutable state for checkpointing:
    /// `(moment vectors, step counter)`.  Restoring via
    /// [`Optimizer::import_state`] must make subsequent steps continue
    /// bitwise-identically.
    fn export_state(&self) -> (Vec<Vec<f64>>, u64);

    /// Restore state captured by [`Optimizer::export_state`].
    fn import_state(&mut self, moments: &[Vec<f64>], t: u64);
}

/// Adam optimizer (Kingma & Ba), matching `numpyro.optim.Adam` defaults
/// (`beta1` 0.9, `beta2` 0.999, `eps` 1e-8).
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step_ascent(&mut self, params: &mut [f64], grad: &[f64]) {
        self.t += 1;
        let t = self.t as f64;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] += self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn export_state(&self) -> (Vec<Vec<f64>>, u64) {
        (vec![self.m.clone(), self.v.clone()], self.t)
    }

    fn import_state(&mut self, moments: &[Vec<f64>], t: u64) {
        assert_eq!(moments.len(), 2, "Adam state is [m, v]");
        self.m.copy_from_slice(&moments[0]);
        self.v.copy_from_slice(&moments[1]);
        self.t = t;
    }
}

/// SGD with classical momentum: `v = mu*v + g; params += lr * v`.
pub struct SgdMomentum {
    pub lr: f64,
    pub momentum: f64,
    v: Vec<f64>,
}

impl SgdMomentum {
    pub fn new(dim: usize, lr: f64, momentum: f64) -> Self {
        SgdMomentum {
            lr,
            momentum,
            v: vec![0.0; dim],
        }
    }
}

impl Optimizer for SgdMomentum {
    fn step_ascent(&mut self, params: &mut [f64], grad: &[f64]) {
        for i in 0..params.len() {
            self.v[i] = self.momentum * self.v[i] + grad[i];
            params[i] += self.lr * self.v[i];
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn export_state(&self) -> (Vec<Vec<f64>>, u64) {
        (vec![self.v.clone()], 0)
    }

    fn import_state(&mut self, moments: &[Vec<f64>], _t: u64) {
        assert_eq!(moments.len(), 1, "SGD state is [v]");
        self.v.copy_from_slice(&moments[0]);
    }
}

/// Which optimizer an SVI run uses (CLI-facing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    Adam,
    /// SGD with momentum 0.9.
    Sgd,
}

impl OptimKind {
    pub fn parse(s: &str) -> Result<OptimKind> {
        Ok(match s {
            "adam" => OptimKind::Adam,
            "sgd" => OptimKind::Sgd,
            other => bail!("unknown optimizer '{other}' (adam|sgd)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::Adam => "adam",
            OptimKind::Sgd => "sgd",
        }
    }

    /// Build the optimizer for a `dim`-element parameter vector.
    pub fn build(&self, dim: usize, lr: f64) -> Box<dyn Optimizer> {
        match self {
            OptimKind::Adam => Box::new(Adam::new(dim, lr)),
            OptimKind::Sgd => Box::new(SgdMomentum::new(dim, lr, 0.9)),
        }
    }
}

/// Step-size schedule over an SVI run: maps `(base_lr, step)` to the
/// learning rate applied at that step (step is 0-based).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepSchedule {
    /// `lr = base_lr` throughout.
    Constant,
    /// Smooth exponential decay: `lr = base_lr * rate^(step / over)` —
    /// reaches `base_lr * rate` after `over` steps.
    ExponentialDecay { rate: f64, over: usize },
    /// Linear ramp from `base_lr / steps` up to `base_lr` over the
    /// first `steps` steps, constant afterwards.
    Warmup { steps: usize },
}

impl StepSchedule {
    pub fn lr_at(&self, base_lr: f64, step: usize) -> f64 {
        match *self {
            StepSchedule::Constant => base_lr,
            StepSchedule::ExponentialDecay { rate, over } => {
                let frac = step as f64 / over.max(1) as f64;
                base_lr * rate.powf(frac)
            }
            StepSchedule::Warmup { steps } => {
                if step < steps {
                    base_lr * (step + 1) as f64 / steps as f64
                } else {
                    base_lr
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // maximize -(x-3)^2 => x -> 3
        let mut adam = Adam::new(1, 0.05);
        let mut x = vec![0.0];
        for _ in 0..2000 {
            let g = vec![-2.0 * (x[0] - 3.0)];
            adam.step_ascent(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "x {}", x[0]);
    }

    #[test]
    fn adam_bias_correction_first_step() {
        let mut adam = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        adam.step_ascent(&mut x, &[1.0]);
        // first step magnitude ~ lr regardless of gradient scale
        assert!((x[0] - 0.1).abs() < 1e-6, "x {}", x[0]);
    }

    #[test]
    fn sgd_momentum_maximizes_quadratic() {
        let mut sgd = SgdMomentum::new(1, 0.02, 0.9);
        let mut x = vec![0.0];
        for _ in 0..2000 {
            let g = vec![-2.0 * (x[0] - 3.0)];
            sgd.step_ascent(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-3, "x {}", x[0]);
    }

    #[test]
    fn schedules_map_steps_to_rates() {
        let c = StepSchedule::Constant;
        assert_eq!(c.lr_at(0.1, 0), 0.1);
        assert_eq!(c.lr_at(0.1, 999), 0.1);

        let d = StepSchedule::ExponentialDecay {
            rate: 0.1,
            over: 100,
        };
        assert!((d.lr_at(1.0, 0) - 1.0).abs() < 1e-12);
        assert!((d.lr_at(1.0, 100) - 0.1).abs() < 1e-12);
        assert!((d.lr_at(1.0, 50) - 0.1f64.sqrt()).abs() < 1e-12);

        let w = StepSchedule::Warmup { steps: 10 };
        assert!((w.lr_at(1.0, 0) - 0.1).abs() < 1e-12);
        assert!((w.lr_at(1.0, 9) - 1.0).abs() < 1e-12);
        assert_eq!(w.lr_at(1.0, 500), 1.0);
    }

    #[test]
    fn state_roundtrip_is_bitwise() {
        // run 3 steps, snapshot, run 4 more; vs restore-into-fresh and
        // run the same 4 — trajectories must match bit-for-bit
        for kind in [OptimKind::Adam, OptimKind::Sgd] {
            let mut a = kind.build(2, 0.05);
            let mut x = vec![0.1, -0.2];
            for s in 0..3 {
                a.step_ascent(&mut x, &[1.0 + s as f64, -0.5]);
            }
            let (moments, t) = a.export_state();
            let x_snap = x.clone();

            let mut b = kind.build(2, 0.05);
            b.import_state(&moments, t);
            let mut xb = x_snap.clone();
            for s in 0..4 {
                let g = [0.3 * s as f64, 0.7];
                a.step_ascent(&mut x, &g);
                b.step_ascent(&mut xb, &g);
            }
            assert_eq!(x, xb, "{:?} resume drifted", kind.name());
        }
    }

    #[test]
    fn optim_kind_parses() {
        assert_eq!(OptimKind::parse("adam").unwrap(), OptimKind::Adam);
        assert_eq!(OptimKind::parse("sgd").unwrap(), OptimKind::Sgd);
        assert!(OptimKind::parse("lbfgs").is_err());
        assert_eq!(OptimKind::Sgd.name(), "sgd");
    }
}
