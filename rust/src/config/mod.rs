//! Experiment/run configuration: defaults, optional JSON config file,
//! CLI flag overrides (in that precedence order).

use std::path::Path;

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Settings {
    pub artifacts_dir: String,
    pub results_dir: String,
    pub seed: u64,
    /// shrink workloads for smoke runs (`--quick`)
    pub quick: bool,
    /// paper-scale workloads (`--full`)
    pub full: bool,
    pub num_warmup: Option<usize>,
    pub num_samples: Option<usize>,
    pub num_chains: usize,
    pub target_accept: f64,
    pub max_tree_depth: u32,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            artifacts_dir: "artifacts".to_string(),
            results_dir: "results".to_string(),
            seed: 20191222,
            quick: false,
            full: false,
            num_warmup: None,
            num_samples: None,
            num_chains: 1,
            target_accept: 0.8,
            max_tree_depth: 10,
        }
    }
}

impl Settings {
    /// Load from an optional JSON file then apply CLI overrides.
    pub fn from_args(args: &Args) -> Result<Settings> {
        let mut s = Settings::default();
        if let Some(path) = args.get("config") {
            s.apply_json(path)?;
        }
        if let Some(v) = args.get("artifacts") {
            s.artifacts_dir = v.to_string();
        }
        if let Some(v) = args.get("results") {
            s.results_dir = v.to_string();
        }
        if let Some(v) = args.get_u64("seed")? {
            s.seed = v;
        }
        if args.has("quick") {
            s.quick = true;
        }
        if args.has("full") {
            s.full = true;
        }
        if let Some(v) = args.get_usize("warmup")? {
            s.num_warmup = Some(v);
        }
        if let Some(v) = args.get_usize("samples")? {
            s.num_samples = Some(v);
        }
        if let Some(v) = args.get_usize("chains")? {
            s.num_chains = v;
        }
        if let Some(v) = args.get_f64("target-accept")? {
            s.target_accept = v;
        }
        if let Some(v) = args.get_usize("max-tree-depth")? {
            s.max_tree_depth = v as u32;
        }
        Ok(s)
    }

    fn apply_json(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let j = Json::parse(&text).context("parsing config JSON")?;
        if let Some(v) = j.get("artifacts_dir").and_then(|v| v.as_str()) {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("results_dir").and_then(|v| v.as_str()) {
            self.results_dir = v.to_string();
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_i64()) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("num_chains").and_then(|v| v.as_usize()) {
            self.num_chains = v;
        }
        if let Some(v) = j.get("target_accept").and_then(|v| v.as_f64()) {
            self.target_accept = v;
        }
        if let Some(v) = j.get("num_warmup").and_then(|v| v.as_usize()) {
            self.num_warmup = Some(v);
        }
        if let Some(v) = j.get("num_samples").and_then(|v| v.as_usize()) {
            self.num_samples = Some(v);
        }
        Ok(())
    }

    /// Warmup/samples with quick/full scaling and per-experiment paper
    /// defaults.
    pub fn budget(&self, paper_warmup: usize, paper_samples: usize) -> (usize, usize) {
        let scale = |x: usize| {
            if self.quick {
                (x / 10).max(20)
            } else if self.full {
                x
            } else {
                (x / 2).max(50)
            }
        };
        (
            self.num_warmup.unwrap_or_else(|| scale(paper_warmup)),
            self.num_samples.unwrap_or_else(|| scale(paper_samples)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_scales() {
        let mut s = Settings::default();
        assert_eq!(s.budget(1000, 1000), (500, 500));
        s.quick = true;
        assert_eq!(s.budget(1000, 1000), (100, 100));
        s.quick = false;
        s.full = true;
        assert_eq!(s.budget(1000, 1000), (1000, 1000));
        s.num_warmup = Some(7);
        assert_eq!(s.budget(1000, 1000).0, 7);
    }
}
