//! Effect handlers in Rust — the paper's Table 1, ported to the native
//! pipeline.
//!
//! A model is any `Fn(&mut Interp)` that issues [`Interp::sample`] /
//! [`Interp::param`] statements.  Each statement builds a message that
//! travels through the handler stack exactly as in `minippl`
//! (`process` top-down, default behaviour, `postprocess` bottom-up):
//!
//! | handler        | affects        | effect                                   |
//! |----------------|----------------|------------------------------------------|
//! | [`Seed`]       | sample         | provides the RNG (split per site)        |
//! | [`TraceH`]     | sample, param  | records every site                       |
//! | [`Condition`]  | sample         | fixes values, marks observed             |
//! | [`Substitute`] | sample, param  | fixes values, stays unobserved           |
//! | [`Replay`]     | sample         | replays values from a recorded trace     |
//! | [`Block`]      | all            | hides matching sites from outer handlers |
//! | [`Plate`]      | sample         | broadcasts sites to i.i.d. batches       |
//!
//! Sites are addressed by name, but every [`Msg`] also carries a
//! pre-hashed [`Msg::key`] ([`site_key`]), and the value-substituting
//! handlers ([`Condition`], [`Substitute`], [`Replay`]) look sites up
//! by that interned key — a binary search over a sorted `(key, value)`
//! table, so the lookup itself does no string hashing or map traversal.
//! (Message construction still allocates the site name and matched
//! values are cloned; the truly allocation-free hot loop is the model
//! compiler's replay pass, which bypasses messages entirely.)
//!
//! The native models in [`crate::models`] use these for data generation
//! and prior/posterior predictive checks; the model compiler in
//! [`crate::compile`] turns the same `sample`/`observe` vocabulary into
//! differentiable NUTS potentials.  The Rust test-suite asserts handler
//! semantics match the Python implementation site-for-site.

use std::collections::BTreeMap;

use crate::ppl::dist::Dist;
use crate::rng::Rng;

/// FNV-1a hash of a site name: the interned key carried by [`Msg::key`]
/// and used by the value-substituting handlers.  Stable across runs (no
/// randomized state), allocation-free, and collision-safe in practice
/// for model-sized site sets (64-bit FNV).
pub fn site_key(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Message passed through the handler stack for every primitive site.
#[derive(Debug, Clone)]
pub struct Msg {
    pub name: String,
    /// Pre-hashed [`site_key`] of `name`, computed once per message so
    /// every handler on the stack can match sites without touching the
    /// string again.
    pub key: u64,
    pub dist: Option<Dist>,
    pub value: Option<Vec<f64>>,
    pub is_observed: bool,
    /// `Some(n)`: the site is a vectorized batch of `n` i.i.d. draws
    /// from `dist` (one message for the whole batch instead of `n`
    /// per-scalar messages).  Set by [`Interp::sample_plate`] /
    /// [`Interp::observe_plate`] or broadcast by a [`Plate`] handler.
    pub plate: Option<usize>,
    pub stop: bool,
}

/// One recorded site.
#[derive(Debug, Clone)]
pub struct Site {
    pub dist: Option<Dist>,
    pub value: Vec<f64>,
    pub is_observed: bool,
    pub log_prob: f64,
}

pub type Trace = BTreeMap<String, Site>;

/// Effect handler interface (Messenger in minippl).
pub trait Handler {
    fn process(&mut self, _msg: &mut Msg) {}
    fn postprocess(&mut self, _msg: &mut Msg) {}
}

/// Sorted `(site_key, value)` table shared by the value-substituting
/// handlers.
fn intern(data: BTreeMap<String, Vec<f64>>) -> Vec<(u64, Vec<f64>)> {
    let mut entries: Vec<(u64, Vec<f64>)> = data
        .into_iter()
        .map(|(name, value)| (site_key(&name), value))
        .collect();
    entries.sort_by_key(|e| e.0);
    entries
}

fn lookup(entries: &[(u64, Vec<f64>)], key: u64) -> Option<&Vec<f64>> {
    entries
        .binary_search_by_key(&key, |e| e.0)
        .ok()
        .map(|i| &entries[i].1)
}

/// Seeds sample statements with an RNG, splitting per site.
///
/// ```
/// use fugue::effects::{Interp, Seed};
/// use fugue::ppl::Dist;
///
/// let mut s = Seed::new(7);
/// let mut i = Interp::new(vec![&mut s]);
/// let x = i.sample("x", Dist::Normal { loc: 0.0, scale: 1.0 });
/// assert!(x[0].is_finite());
/// ```
pub struct Seed {
    rng: Rng,
}

impl Seed {
    pub fn new(seed: u64) -> Self {
        Seed {
            rng: Rng::new(seed),
        }
    }
}

impl Handler for Seed {
    fn process(&mut self, msg: &mut Msg) {
        if msg.value.is_none() {
            if let Some(d) = &msg.dist {
                let mut sub = self.rng.split(0);
                let value = match msg.plate {
                    None => d.sample(&mut sub),
                    Some(n) => {
                        let mut v = Vec::with_capacity(n * d.event_len());
                        for _ in 0..n {
                            v.extend(d.sample(&mut sub));
                        }
                        v
                    }
                };
                msg.value = Some(value);
            }
        }
    }
}

/// Records every site into a [`Trace`].
///
/// ```
/// use fugue::effects::{Interp, Seed, TraceH};
/// use fugue::ppl::Dist;
///
/// let mut s = Seed::new(0);
/// let mut t = TraceH::default();
/// {
///     let mut i = Interp::new(vec![&mut s, &mut t]);
///     let m = i.sample("m", Dist::Normal { loc: 0.0, scale: 1.0 });
///     i.observe("y", Dist::Normal { loc: m[0], scale: 0.5 }, vec![0.3]);
/// }
/// assert_eq!(t.trace.len(), 2);
/// assert!(!t.trace["m"].is_observed);
/// assert!(t.trace["y"].is_observed);
/// ```
#[derive(Default)]
pub struct TraceH {
    pub trace: Trace,
}

impl Handler for TraceH {
    fn postprocess(&mut self, msg: &mut Msg) {
        let value = msg.value.clone().expect("traced site must have a value");
        let log_prob = match &msg.dist {
            Some(d) => {
                if msg.plate.is_some() {
                    // vectorized site: sum over the i.i.d. events
                    let el = d.event_len().max(1);
                    value.chunks(el).map(|ev| d.log_prob(ev)).sum()
                } else {
                    d.log_prob(&value)
                }
            }
            None => 0.0,
        };
        let prev = self.trace.insert(
            msg.name.clone(),
            Site {
                dist: msg.dist.clone(),
                value,
                is_observed: msg.is_observed,
                log_prob,
            },
        );
        assert!(prev.is_none(), "duplicate site '{}'", msg.name);
    }
}

/// Conditions matching sites to observed values.
///
/// ```
/// use fugue::effects::{Condition, Interp, Seed, TraceH};
/// use fugue::ppl::Dist;
///
/// let mut s = Seed::new(0);
/// let mut c = Condition::new([("m".to_string(), vec![1.5])].into_iter().collect());
/// let mut t = TraceH::default();
/// {
///     let mut i = Interp::new(vec![&mut s, &mut c, &mut t]);
///     i.sample("m", Dist::Normal { loc: 0.0, scale: 1.0 });
/// }
/// assert_eq!(t.trace["m"].value, vec![1.5]);
/// assert!(t.trace["m"].is_observed);
/// ```
pub struct Condition {
    entries: Vec<(u64, Vec<f64>)>,
}

impl Condition {
    pub fn new(data: BTreeMap<String, Vec<f64>>) -> Condition {
        Condition {
            entries: intern(data),
        }
    }
}

impl Handler for Condition {
    fn process(&mut self, msg: &mut Msg) {
        if let Some(v) = lookup(&self.entries, msg.key) {
            assert!(
                !msg.is_observed,
                "cannot condition already-observed site '{}'",
                msg.name
            );
            msg.value = Some(v.clone());
            msg.is_observed = true;
        }
    }
}

/// Substitutes values without marking observed (HMC/SVI plumbing).
///
/// ```
/// use fugue::effects::{Interp, Seed, Substitute, TraceH};
/// use fugue::ppl::Dist;
///
/// let mut s = Seed::new(0);
/// let mut sub = Substitute::new([("m".to_string(), vec![-1.5])].into_iter().collect());
/// let mut t = TraceH::default();
/// {
///     let mut i = Interp::new(vec![&mut s, &mut sub, &mut t]);
///     i.sample("m", Dist::Normal { loc: 0.0, scale: 1.0 });
/// }
/// assert_eq!(t.trace["m"].value, vec![-1.5]);
/// assert!(!t.trace["m"].is_observed);
/// ```
pub struct Substitute {
    entries: Vec<(u64, Vec<f64>)>,
}

impl Substitute {
    pub fn new(data: BTreeMap<String, Vec<f64>>) -> Substitute {
        Substitute {
            entries: intern(data),
        }
    }
}

impl Handler for Substitute {
    fn process(&mut self, msg: &mut Msg) {
        if let Some(v) = lookup(&self.entries, msg.key) {
            msg.value = Some(v.clone());
        }
    }
}

/// Replays sample sites from a recorded trace.
///
/// ```
/// use fugue::effects::{traced, Interp, Replay, Seed, TraceH};
/// use fugue::ppl::Dist;
///
/// fn model(i: &mut Interp) {
///     i.sample("m", Dist::Normal { loc: 0.0, scale: 1.0 });
/// }
///
/// let first = traced(model, 3);
/// let mut s = Seed::new(99); // a different seed ...
/// let mut r = Replay::new(&first);
/// let mut t = TraceH::default();
/// {
///     let mut i = Interp::new(vec![&mut s, &mut r, &mut t]);
///     model(&mut i);
/// }
/// // ... yet the replayed value matches the recorded one
/// assert_eq!(t.trace["m"].value, first["m"].value);
/// ```
pub struct Replay {
    entries: Vec<(u64, Vec<f64>)>,
}

impl Replay {
    pub fn new(guide_trace: &Trace) -> Replay {
        let mut entries: Vec<(u64, Vec<f64>)> = guide_trace
            .iter()
            .map(|(name, site)| (site_key(name), site.value.clone()))
            .collect();
        entries.sort_by_key(|e| e.0);
        Replay { entries }
    }
}

impl Handler for Replay {
    fn process(&mut self, msg: &mut Msg) {
        if msg.is_observed {
            return;
        }
        if let Some(v) = lookup(&self.entries, msg.key) {
            msg.value = Some(v.clone());
        }
    }
}

/// Hides matching sites from outer handlers.
///
/// ```
/// use fugue::effects::{Block, Interp, Msg, Seed, TraceH};
/// use fugue::ppl::Dist;
///
/// let mut t = TraceH::default();
/// let mut b = Block { hide: |m: &Msg| m.name == "m" };
/// let mut s = Seed::new(1);
/// {
///     // seed innermost so hidden sites still get values
///     let mut i = Interp::new(vec![&mut t, &mut b, &mut s]);
///     i.sample("m", Dist::Normal { loc: 0.0, scale: 1.0 });
///     i.sample("y", Dist::Normal { loc: 0.0, scale: 1.0 });
/// }
/// assert!(!t.trace.contains_key("m")); // blocked from the outer trace
/// assert!(t.trace.contains_key("y"));
/// ```
pub struct Block<F: Fn(&Msg) -> bool> {
    pub hide: F,
}

impl<F: Fn(&Msg) -> bool> Handler for Block<F> {
    fn process(&mut self, msg: &mut Msg) {
        if (self.hide)(msg) {
            msg.stop = true;
        }
    }
}

/// Broadcasts enclosed sites to vectorized batches of `size` i.i.d.
/// draws: one message per site for the whole batch, instead of
/// per-scalar messages (the batched fast path the model compiler uses
/// for observation sites).
///
/// ```
/// use fugue::effects::{Interp, Plate, Seed, TraceH};
/// use fugue::ppl::Dist;
///
/// let mut s = Seed::new(0);
/// let mut t = TraceH::default();
/// let mut p = Plate { size: 3 };
/// {
///     let mut i = Interp::new(vec![&mut s, &mut t, &mut p]);
///     let draws = i.sample("x", Dist::Normal { loc: 0.0, scale: 1.0 });
///     assert_eq!(draws.len(), 3); // one site, three i.i.d. draws
/// }
/// assert_eq!(t.trace["x"].value.len(), 3);
/// assert!(t.trace["x"].log_prob.is_finite()); // summed over the batch
/// ```
pub struct Plate {
    pub size: usize,
}

impl Handler for Plate {
    fn process(&mut self, msg: &mut Msg) {
        // broadcast only value-less sample sites: observed sites and
        // params already carry their (fixed-size) values, and nested
        // plates keep the innermost size
        if msg.plate.is_none() && msg.value.is_none() && msg.dist.is_some() {
            msg.plate = Some(self.size);
        }
    }
}

/// Interpreter carrying the handler stack (innermost last).
pub struct Interp<'a> {
    handlers: Vec<&'a mut dyn Handler>,
}

impl<'a> Interp<'a> {
    pub fn new(handlers: Vec<&'a mut dyn Handler>) -> Self {
        Interp { handlers }
    }

    fn apply(&mut self, mut msg: Msg) -> Msg {
        // innermost (end of vec) first, like minippl's reversed stack
        let mut seen = 0;
        for h in self.handlers.iter_mut().rev() {
            seen += 1;
            h.process(&mut msg);
            if msg.stop {
                break;
            }
        }
        if msg.value.is_none() {
            panic!(
                "site '{}': no value and no Seed handler on the stack",
                msg.name
            );
        }
        let n = self.handlers.len();
        for h in self.handlers[n - seen..].iter_mut() {
            h.postprocess(&mut msg);
        }
        msg
    }

    fn msg(name: &str, dist: Option<Dist>, value: Option<Vec<f64>>, observed: bool) -> Msg {
        Msg {
            key: site_key(name),
            name: name.to_string(),
            dist,
            value,
            is_observed: observed,
            plate: None,
            stop: false,
        }
    }

    /// `sample(name, dist)` primitive; returns the site value.
    pub fn sample(&mut self, name: &str, dist: Dist) -> Vec<f64> {
        let msg = Self::msg(name, Some(dist), None, false);
        self.apply(msg).value.unwrap()
    }

    /// `sample(name, dist, obs)` — observed site.
    pub fn observe(&mut self, name: &str, dist: Dist, obs: Vec<f64>) -> Vec<f64> {
        let msg = Self::msg(name, Some(dist), Some(obs), true);
        self.apply(msg).value.unwrap()
    }

    /// `param(name, init)` primitive.
    pub fn param(&mut self, name: &str, init: Vec<f64>) -> Vec<f64> {
        let msg = Self::msg(name, None, Some(init), false);
        self.apply(msg).value.unwrap()
    }

    /// Vectorized `sample`: one site holding `n` i.i.d. draws from
    /// `dist` (a single message for the whole batch).
    pub fn sample_plate(&mut self, name: &str, dist: Dist, n: usize) -> Vec<f64> {
        let mut msg = Self::msg(name, Some(dist), None, false);
        msg.plate = Some(n);
        self.apply(msg).value.unwrap()
    }

    /// Vectorized `observe`: one site holding a batch of i.i.d.
    /// observations (`obs` concatenates the per-event values).
    pub fn observe_plate(&mut self, name: &str, dist: Dist, obs: &[f64]) -> Vec<f64> {
        let el = dist.event_len().max(1);
        assert_eq!(
            obs.len() % el,
            0,
            "site '{name}': observation length {} is not a multiple of the event length {el}",
            obs.len()
        );
        let n = obs.len() / el;
        let mut msg = Self::msg(name, Some(dist), Some(obs.to_vec()), true);
        msg.plate = Some(n);
        self.apply(msg).value.unwrap()
    }
}

/// Run `model` under Seed + Trace, returning the trace
/// (`trace(seed(model, key)).get_trace()` in the paper's notation).
pub fn traced<F: Fn(&mut Interp)>(model: F, seed: u64) -> Trace {
    let mut s = Seed::new(seed);
    let mut t = TraceH::default();
    {
        let mut interp = Interp::new(vec![&mut s, &mut t]);
        model(&mut interp);
    }
    t.trace
}

/// Joint log-density of a trace.
pub fn log_density(trace: &Trace) -> f64 {
    trace.values().map(|s| s.log_prob).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model(i: &mut Interp) {
        let m = i.sample(
            "m",
            Dist::Normal {
                loc: 0.0,
                scale: 1.0,
            },
        );
        i.observe(
            "y",
            Dist::Normal {
                loc: m[0],
                scale: 0.5,
            },
            vec![0.3],
        );
    }

    #[test]
    fn seed_trace_records_sites() {
        let tr = traced(toy_model, 1);
        assert_eq!(tr.len(), 2);
        assert!(!tr["m"].is_observed);
        assert!(tr["y"].is_observed);
        assert_eq!(tr["y"].value, vec![0.3]);
        assert!(log_density(&tr).is_finite());
    }

    #[test]
    fn seed_is_deterministic() {
        let a = traced(toy_model, 7);
        let b = traced(toy_model, 7);
        assert_eq!(a["m"].value, b["m"].value);
        let c = traced(toy_model, 8);
        assert_ne!(a["m"].value, c["m"].value);
    }

    #[test]
    fn condition_marks_observed() {
        let mut s = Seed::new(1);
        let mut c = Condition::new([("m".to_string(), vec![2.0])].into_iter().collect());
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut c, &mut t]);
            toy_model(&mut interp);
        }
        assert_eq!(t.trace["m"].value, vec![2.0]);
        assert!(t.trace["m"].is_observed);
        // N(2 | 0, 1) contributes to the joint
        let lp = t.trace["m"].log_prob;
        assert!((lp - Dist::Normal { loc: 0.0, scale: 1.0 }.log_prob(&[2.0])).abs() < 1e-12);
    }

    #[test]
    fn substitute_stays_unobserved() {
        let mut s = Seed::new(1);
        let mut sub = Substitute::new([("m".to_string(), vec![-1.5])].into_iter().collect());
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut sub, &mut t]);
            toy_model(&mut interp);
        }
        assert_eq!(t.trace["m"].value, vec![-1.5]);
        assert!(!t.trace["m"].is_observed);
    }

    #[test]
    fn replay_reuses_trace_values() {
        let first = traced(toy_model, 3);
        let mut s = Seed::new(99);
        let mut r = Replay::new(&first);
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut r, &mut t]);
            toy_model(&mut interp);
        }
        assert_eq!(t.trace["m"].value, first["m"].value);
    }

    #[test]
    fn block_hides_from_outer() {
        let mut s = Seed::new(1);
        let mut t = TraceH::default();
        let mut b = Block {
            hide: |m: &Msg| m.name == "m",
        };
        {
            // stack: seed, trace, block (innermost) — block stops "m"
            // before it reaches trace, but seed never sees it either, so
            // sampling must happen below block: put seed innermost.
            let mut interp = Interp::new(vec![&mut t, &mut b, &mut s]);
            toy_model(&mut interp);
        }
        assert!(!t.trace.contains_key("m"));
        assert!(t.trace.contains_key("y"));
    }

    #[test]
    fn site_key_is_stable_and_distinct() {
        assert_eq!(site_key("mu"), site_key("mu"));
        assert_ne!(site_key("mu"), site_key("tau"));
        assert_ne!(site_key(""), site_key("a"));
    }

    #[test]
    fn plate_batches_iid_draws() {
        let d = Dist::Normal {
            loc: 0.0,
            scale: 1.0,
        };
        let mut s = Seed::new(5);
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut t]);
            let v = interp.sample_plate("x", d.clone(), 4);
            assert_eq!(v.len(), 4);
        }
        let site = &t.trace["x"];
        assert_eq!(site.value.len(), 4);
        // summed log-prob over the batch
        let expect: f64 = site.value.iter().map(|&x| d.log_prob(&[x])).sum();
        assert!((site.log_prob - expect).abs() < 1e-12);
    }

    #[test]
    fn observe_plate_sums_likelihood() {
        let d = Dist::Normal {
            loc: 1.0,
            scale: 2.0,
        };
        let obs = [0.5, 1.5, -0.2];
        let mut s = Seed::new(0);
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut t]);
            interp.observe_plate("y", d.clone(), &obs);
        }
        let site = &t.trace["y"];
        assert!(site.is_observed);
        let expect: f64 = obs.iter().map(|&x| d.log_prob(&[x])).sum();
        assert!((site.log_prob - expect).abs() < 1e-12);
    }

    #[test]
    fn plate_handler_broadcasts_size() {
        let mut s = Seed::new(2);
        let mut t = TraceH::default();
        let mut p = Plate { size: 5 };
        {
            let mut interp = Interp::new(vec![&mut s, &mut t, &mut p]);
            let v = interp.sample(
                "x",
                Dist::Normal {
                    loc: 0.0,
                    scale: 1.0,
                },
            );
            assert_eq!(v.len(), 5);
        }
        assert_eq!(t.trace["x"].value.len(), 5);
    }

    #[test]
    fn condition_applies_to_plate_site() {
        let d = Dist::Normal {
            loc: 0.0,
            scale: 1.0,
        };
        let mut s = Seed::new(0);
        let mut c = Condition::new(
            [("x".to_string(), vec![0.1, 0.2, 0.3])]
                .into_iter()
                .collect(),
        );
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut c, &mut t]);
            interp.sample_plate("x", d, 3);
        }
        assert_eq!(t.trace["x"].value, vec![0.1, 0.2, 0.3]);
        assert!(t.trace["x"].is_observed);
    }
}
