//! Effect handlers in Rust — the paper's Table 1, ported to the native
//! pipeline.
//!
//! A model is any `Fn(&mut Interp)` that issues [`Interp::sample`] /
//! [`Interp::param`] statements.  Each statement builds a message that
//! travels through the handler stack exactly as in `minippl`
//! (`process` top-down, default behaviour, `postprocess` bottom-up):
//!
//! | handler        | affects        | effect                                   |
//! |----------------|----------------|------------------------------------------|
//! | [`Seed`]       | sample         | provides the RNG (split per site)        |
//! | [`TraceH`]     | sample, param  | records every site                       |
//! | [`Condition`]  | sample         | fixes values, marks observed             |
//! | [`Substitute`] | sample, param  | fixes values, stays unobserved           |
//! | [`Replay`]     | sample         | replays values from a recorded trace     |
//!
//! The native models in [`crate::models`] use these for data generation
//! and prior/posterior predictive checks; the Rust test-suite asserts
//! handler semantics match the Python implementation site-for-site.

use std::collections::BTreeMap;

use crate::ppl::dist::Dist;
use crate::rng::Rng;

/// Message passed through the handler stack for every primitive site.
#[derive(Debug, Clone)]
pub struct Msg {
    pub name: String,
    pub dist: Option<Dist>,
    pub value: Option<Vec<f64>>,
    pub is_observed: bool,
    pub stop: bool,
}

/// One recorded site.
#[derive(Debug, Clone)]
pub struct Site {
    pub dist: Option<Dist>,
    pub value: Vec<f64>,
    pub is_observed: bool,
    pub log_prob: f64,
}

pub type Trace = BTreeMap<String, Site>;

/// Effect handler interface (Messenger in minippl).
pub trait Handler {
    fn process(&mut self, _msg: &mut Msg) {}
    fn postprocess(&mut self, _msg: &mut Msg) {}
}

/// Seeds sample statements with an RNG, splitting per site.
pub struct Seed {
    rng: Rng,
}

impl Seed {
    pub fn new(seed: u64) -> Self {
        Seed {
            rng: Rng::new(seed),
        }
    }
}

impl Handler for Seed {
    fn process(&mut self, msg: &mut Msg) {
        if msg.value.is_none() {
            if let Some(d) = &msg.dist {
                let mut sub = self.rng.split(0);
                msg.value = Some(d.sample(&mut sub));
            }
        }
    }
}

/// Records every site into a [`Trace`].
#[derive(Default)]
pub struct TraceH {
    pub trace: Trace,
}

impl Handler for TraceH {
    fn postprocess(&mut self, msg: &mut Msg) {
        let value = msg.value.clone().expect("traced site must have a value");
        let log_prob = msg
            .dist
            .as_ref()
            .map(|d| d.log_prob(&value))
            .unwrap_or(0.0);
        let prev = self.trace.insert(
            msg.name.clone(),
            Site {
                dist: msg.dist.clone(),
                value,
                is_observed: msg.is_observed,
                log_prob,
            },
        );
        assert!(prev.is_none(), "duplicate site '{}'", msg.name);
    }
}

/// Conditions matching sites to observed values.
pub struct Condition {
    pub data: BTreeMap<String, Vec<f64>>,
}

impl Handler for Condition {
    fn process(&mut self, msg: &mut Msg) {
        if let Some(v) = self.data.get(&msg.name) {
            assert!(
                !msg.is_observed,
                "cannot condition already-observed site '{}'",
                msg.name
            );
            msg.value = Some(v.clone());
            msg.is_observed = true;
        }
    }
}

/// Substitutes values without marking observed (HMC/SVI plumbing).
pub struct Substitute {
    pub data: BTreeMap<String, Vec<f64>>,
}

impl Handler for Substitute {
    fn process(&mut self, msg: &mut Msg) {
        if let Some(v) = self.data.get(&msg.name) {
            msg.value = Some(v.clone());
        }
    }
}

/// Replays sample sites from a recorded trace.
pub struct Replay {
    pub guide_trace: Trace,
}

impl Handler for Replay {
    fn process(&mut self, msg: &mut Msg) {
        if msg.is_observed {
            return;
        }
        if let Some(site) = self.guide_trace.get(&msg.name) {
            msg.value = Some(site.value.clone());
        }
    }
}

/// Hides matching sites from outer handlers.
pub struct Block<F: Fn(&Msg) -> bool> {
    pub hide: F,
}

impl<F: Fn(&Msg) -> bool> Handler for Block<F> {
    fn process(&mut self, msg: &mut Msg) {
        if (self.hide)(msg) {
            msg.stop = true;
        }
    }
}

/// Interpreter carrying the handler stack (innermost last).
pub struct Interp<'a> {
    handlers: Vec<&'a mut dyn Handler>,
}

impl<'a> Interp<'a> {
    pub fn new(handlers: Vec<&'a mut dyn Handler>) -> Self {
        Interp { handlers }
    }

    fn apply(&mut self, mut msg: Msg) -> Msg {
        // innermost (end of vec) first, like minippl's reversed stack
        let mut seen = 0;
        for h in self.handlers.iter_mut().rev() {
            seen += 1;
            h.process(&mut msg);
            if msg.stop {
                break;
            }
        }
        if msg.value.is_none() {
            panic!(
                "site '{}': no value and no Seed handler on the stack",
                msg.name
            );
        }
        let n = self.handlers.len();
        for h in self.handlers[n - seen..].iter_mut() {
            h.postprocess(&mut msg);
        }
        msg
    }

    /// `sample(name, dist)` primitive; returns the site value.
    pub fn sample(&mut self, name: &str, dist: Dist) -> Vec<f64> {
        let msg = Msg {
            name: name.to_string(),
            dist: Some(dist),
            value: None,
            is_observed: false,
            stop: false,
        };
        self.apply(msg).value.unwrap()
    }

    /// `sample(name, dist, obs)` — observed site.
    pub fn observe(&mut self, name: &str, dist: Dist, obs: Vec<f64>) -> Vec<f64> {
        let msg = Msg {
            name: name.to_string(),
            dist: Some(dist),
            value: Some(obs),
            is_observed: true,
            stop: false,
        };
        self.apply(msg).value.unwrap()
    }

    /// `param(name, init)` primitive.
    pub fn param(&mut self, name: &str, init: Vec<f64>) -> Vec<f64> {
        let msg = Msg {
            name: name.to_string(),
            dist: None,
            value: Some(init),
            is_observed: false,
            stop: false,
        };
        self.apply(msg).value.unwrap()
    }
}

/// Run `model` under Seed + Trace, returning the trace
/// (`trace(seed(model, key)).get_trace()` in the paper's notation).
pub fn traced<F: Fn(&mut Interp)>(model: F, seed: u64) -> Trace {
    let mut s = Seed::new(seed);
    let mut t = TraceH::default();
    {
        let mut interp = Interp::new(vec![&mut s, &mut t]);
        model(&mut interp);
    }
    t.trace
}

/// Joint log-density of a trace.
pub fn log_density(trace: &Trace) -> f64 {
    trace.values().map(|s| s.log_prob).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model(i: &mut Interp) {
        let m = i.sample(
            "m",
            Dist::Normal {
                loc: 0.0,
                scale: 1.0,
            },
        );
        i.observe(
            "y",
            Dist::Normal {
                loc: m[0],
                scale: 0.5,
            },
            vec![0.3],
        );
    }

    #[test]
    fn seed_trace_records_sites() {
        let tr = traced(toy_model, 1);
        assert_eq!(tr.len(), 2);
        assert!(!tr["m"].is_observed);
        assert!(tr["y"].is_observed);
        assert_eq!(tr["y"].value, vec![0.3]);
        assert!(log_density(&tr).is_finite());
    }

    #[test]
    fn seed_is_deterministic() {
        let a = traced(toy_model, 7);
        let b = traced(toy_model, 7);
        assert_eq!(a["m"].value, b["m"].value);
        let c = traced(toy_model, 8);
        assert_ne!(a["m"].value, c["m"].value);
    }

    #[test]
    fn condition_marks_observed() {
        let mut s = Seed::new(1);
        let mut c = Condition {
            data: [("m".to_string(), vec![2.0])].into_iter().collect(),
        };
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut c, &mut t]);
            toy_model(&mut interp);
        }
        assert_eq!(t.trace["m"].value, vec![2.0]);
        assert!(t.trace["m"].is_observed);
        // N(2 | 0, 1) contributes to the joint
        let lp = t.trace["m"].log_prob;
        assert!((lp - Dist::Normal { loc: 0.0, scale: 1.0 }.log_prob(&[2.0])).abs() < 1e-12);
    }

    #[test]
    fn substitute_stays_unobserved() {
        let mut s = Seed::new(1);
        let mut sub = Substitute {
            data: [("m".to_string(), vec![-1.5])].into_iter().collect(),
        };
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut sub, &mut t]);
            toy_model(&mut interp);
        }
        assert_eq!(t.trace["m"].value, vec![-1.5]);
        assert!(!t.trace["m"].is_observed);
    }

    #[test]
    fn replay_reuses_trace_values() {
        let first = traced(toy_model, 3);
        let mut s = Seed::new(99);
        let mut r = Replay {
            guide_trace: first.clone(),
        };
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut r, &mut t]);
            toy_model(&mut interp);
        }
        assert_eq!(t.trace["m"].value, first["m"].value);
    }

    #[test]
    fn block_hides_from_outer() {
        let mut s = Seed::new(1);
        let mut t = TraceH::default();
        let mut b = Block {
            hide: |m: &Msg| m.name == "m",
        };
        {
            // stack: seed, trace, block (innermost) — block stops "m"
            // before it reaches trace, but seed never sees it either, so
            // sampling must happen below block: put seed innermost.
            let mut interp = Interp::new(vec![&mut t, &mut b, &mut s]);
            toy_model(&mut interp);
        }
        assert!(!t.trace.contains_key("m"));
        assert!(t.trace.contains_key("y"));
    }
}
