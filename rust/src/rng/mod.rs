//! Counter-friendly pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so this is a from-scratch
//! substrate: SplitMix64 for seeding/stream-splitting (mirroring JAX's
//! functional split semantics at the coordinator level) and
//! xoshiro256++ for the main stream, plus the samplers the native
//! pipeline needs (normal, gamma, beta, dirichlet, categorical, ...).
//!
//! Determinism contract: every coordinator-level decision (chain seeds,
//! data generation, native-NUTS momenta) derives from an explicit seed,
//! so runs replay bit-identically.

/// xoshiro256++ seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            spare_normal: None,
        }
    }

    /// Snapshot the full generator state for checkpointing: the four
    /// xoshiro256++ words plus the cached Box-Muller spare (which
    /// persists *across* draws, so a resumed stream would desync
    /// without it).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the restored
    /// stream continues bitwise-identically to the original.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Rng {
        Rng { s, spare_normal }
    }

    /// Derive an independent stream (JAX-style key split).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        // rejection-free Lemire reduction is overkill here
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill `out` with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out {
            *v = self.normal();
        }
    }

    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / rate
    }

    pub fn cauchy(&mut self, loc: f64, scale: f64) -> f64 {
        loc + scale * (std::f64::consts::PI * (self.uniform() - 0.5)).tan()
    }

    pub fn half_cauchy(&mut self, scale: f64) -> f64 {
        scale * (std::f64::consts::FRAC_PI_2 * self.uniform()).tan()
    }

    /// Gamma(shape, rate=1) via Marsaglia-Tsang; boosts shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a + 1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v3;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    pub fn gamma_rate(&mut self, shape: f64, rate: f64) -> f64 {
        self.gamma(shape) / rate
    }

    pub fn inverse_gamma(&mut self, shape: f64, rate: f64) -> f64 {
        rate / self.gamma(shape)
    }

    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let mut g: Vec<f64> = alpha.iter().map(|&a| self.gamma(a)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Sample an index proportional to `probs` (need not be normalized).
    pub fn categorical(&mut self, probs: &[f64]) -> usize {
        let total: f64 = probs.iter().sum();
        let mut u = self.uniform() * total;
        for (i, p) in probs.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::new(1);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Rng::new(3);
        for &shape in &[0.5, 1.0, 2.5, 10.0] {
            let n = 100_000;
            let mean: f64 = (0..n).map(|_| rng.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.05 * shape.max(1.0),
                "shape {shape}: mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Rng::new(4);
        let d = rng.dirichlet(&[1.0, 2.0, 3.0]);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Rng::new(5);
        let probs = [0.2, 0.5, 0.3];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.categorical(&probs)] += 1;
        }
        for i in 0..3 {
            let freq = counts[i] as f64 / n as f64;
            assert!((freq - probs[i]).abs() < 0.01, "i={i} freq={freq}");
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut base = Rng::new(9);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
