//! Minimal CLI argument parser (no `clap` in the offline crate set):
//! positional arguments + `--flag value` pairs + boolean `--switch`es.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeSet<String>,
}

/// Flags that take a value; everything else starting with `--` is a switch.
const VALUE_FLAGS: &[&str] = &[
    "artifacts",
    "results",
    "config",
    "seed",
    "warmup",
    "samples",
    "chains",
    "target-accept",
    "max-tree-depth",
    "model",
    "backend",
    "chain-method",
    "dtype",
    "step-size",
    "steps",
    "lr",
    "out",
    "hmc-steps",
    "particles",
    "optimizer",
    "predictive",
    "checkpoint",
    "checkpoint-every",
    "max-seconds",
    "subsample-size",
    "rows",
    "dim",
    "trace-out",
    "metrics-out",
    "metrics-every",
];

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if VALUE_FLAGS.contains(&name) {
                    let v = iter
                        .next()
                        .with_context(|| format!("flag --{name} expects a value"))?;
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.insert(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.contains(name)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse().with_context(|| format!("--{name} {v}: not an integer")))
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.get(name)
            .map(|v| v.parse().with_context(|| format!("--{name} {v}: not an integer")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse().with_context(|| format!("--{name} {v}: not a number")))
            .transpose()
    }

    pub fn subcommand(&self) -> Result<&str> {
        match self.positional.first() {
            Some(s) => Ok(s.as_str()),
            None => bail!("no subcommand; run `fugue help`"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn parses_mixed() {
        let a = parse("experiment table2a --model hmm --quick --seed 7");
        assert_eq!(a.positional, vec!["experiment", "table2a"]);
        assert_eq!(a.get("model"), Some("hmm"));
        assert!(a.has("quick"));
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("run --warmup=250 --dtype=f64");
        assert_eq!(a.get_usize("warmup").unwrap(), Some(250));
        assert_eq!(a.get("dtype"), Some("f64"));
    }

    #[test]
    fn checkpoint_flags_take_values() {
        let a = parse(
            "sample-model --checkpoint ck.json --resume --max-seconds 2.5 --checkpoint-every 100",
        );
        assert_eq!(a.get("checkpoint"), Some("ck.json"));
        assert!(a.has("resume"));
        assert_eq!(a.get_f64("max-seconds").unwrap(), Some(2.5));
        assert_eq!(a.get_usize("checkpoint-every").unwrap(), Some(100));
    }

    #[test]
    fn rejects_bad_numbers() {
        let a = parse("run --warmup abc");
        assert!(a.get_usize("warmup").is_err());
    }
}
