//! Native Rust potential energies for the three benchmark models — the
//! *Stan comparator* of Table 2a / Fig 2b (DESIGN.md §3): compiled
//! native code differentiated by the [`crate::autodiff`] tape, with the
//! model hot paths as fused composite primitives (the Stan math-library
//! pattern).
//!
//! Densities are kept numerically identical to the Python/minippl models
//! so unconstrained vectors and potentials agree across the native and
//! PJRT pipelines (cross-checked in `rust/tests/cross_check.rs`).

pub mod hmm;
pub mod logistic;
pub mod skim;

pub use hmm::HmmNative;
pub use logistic::LogisticNative;
pub use skim::SkimNative;
