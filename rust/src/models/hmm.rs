//! Native semi-supervised HMM potential (Table 2a HMM benchmark, E1).
//!
//! Density identical to `python/compile/models/hmm.py`: Dirichlet(1)
//! priors on the rows of theta (K x K transitions) and phi (K x V
//! emissions), supervised transition/emission likelihood as sufficient
//! statistics, and the marginalized tail through the log-space forward
//! algorithm — implemented as one fused composite primitive whose
//! partials come from the exact reverse recursion (stored alphas), the
//! Stan-style rev rule for an HMM marginal.
//!
//! Every per-evaluation buffer (tape, alphas, adjoint scratch, `Var`
//! lists, composite partials) is owned by the struct and reused, so
//! steady-state evaluations perform no heap allocation.
//!
//! Unconstrained layout (sorted site names, matching `ravel_pytree`):
//! `[phi sticks (K*(V-1)) row-major, theta sticks (K*(K-1))]`.

use crate::autodiff::{Tape, Var};
use crate::mcmc::Potential;
use crate::ppl::special::{ln_gamma, log_sum_exp};
use crate::ppl::transforms::stick_breaking_t_into;

pub struct HmmNative {
    pub num_states: usize,
    pub num_categories: usize,
    pub obs: Vec<usize>,
    pub sup_states: Vec<usize>,
    /// supervised transition counts (K x K)
    trans_counts: Vec<f64>,
    /// supervised emission counts (K x V)
    emis_counts: Vec<f64>,
    evals: u64,
    /// stored forward alphas for the composite backward (T_u x K)
    alphas: Vec<f64>,
    // ---- reusable hot-path scratch ----
    tape: Tape,
    /// log theta values (K x K) for the fused marginal
    la_vals: Vec<f64>,
    /// log phi values (K x V) for the fused marginal
    lb_vals: Vec<f64>,
    /// fused-marginal partials wrt (la, lb)
    partials: Vec<f64>,
    scores: Vec<f64>,
    abar: Vec<f64>,
    abar_prev: Vec<f64>,
    inputs: Vec<Var>,
    log_phi: Vec<Var>,
    log_theta: Vec<Var>,
    ladjs: Vec<Var>,
    sb_out: Vec<Var>,
    sb_scratch: Vec<Var>,
    sup_terms: Vec<Var>,
    parents: Vec<Var>,
}

impl HmmNative {
    pub fn new(obs: Vec<usize>, sup_states: Vec<usize>, num_states: usize, num_categories: usize) -> Self {
        let (k, v) = (num_states, num_categories);
        let mut trans_counts = vec![0.0; k * k];
        for w in sup_states.windows(2) {
            trans_counts[w[0] * k + w[1]] += 1.0;
        }
        let mut emis_counts = vec![0.0; k * v];
        for (t, &s) in sup_states.iter().enumerate() {
            emis_counts[s * v + obs[t]] += 1.0;
        }
        let t_unsup = obs.len() - sup_states.len();
        HmmNative {
            num_states,
            num_categories,
            obs,
            sup_states,
            trans_counts,
            emis_counts,
            evals: 0,
            alphas: vec![0.0; t_unsup * k],
            tape: Tape::new(),
            la_vals: vec![0.0; k * k],
            lb_vals: vec![0.0; k * v],
            partials: vec![0.0; k * k + k * v],
            scores: vec![0.0; k],
            abar: vec![0.0; k],
            abar_prev: vec![0.0; k],
            inputs: Vec::with_capacity(k * (v - 1) + k * (k - 1)),
            log_phi: Vec::with_capacity(k * v),
            log_theta: Vec::with_capacity(k * k),
            ladjs: Vec::with_capacity(2 * k),
            sb_out: Vec::with_capacity(v),
            sb_scratch: Vec::with_capacity(v),
            sup_terms: Vec::with_capacity(k * (k + v)),
            parents: Vec::with_capacity(k * k + k * v),
        }
    }

    /// Fused forward-algorithm marginal over `self.la_vals` (log theta,
    /// K*K) and `self.lb_vals` (log phi, K*V): returns log p(y_unsup)
    /// and writes partials wrt la then lb into `self.partials`.
    fn forward_marginal(&mut self) -> f64 {
        let k = self.num_states;
        let v = self.num_categories;
        let t_sup = self.sup_states.len();
        let s_last = *self.sup_states.last().unwrap();
        let HmmNative {
            obs,
            alphas,
            la_vals,
            lb_vals,
            partials,
            scores,
            abar,
            abar_prev,
            ..
        } = self;
        let la = &la_vals[..];
        let lb = &lb_vals[..];
        let unsup = &obs[t_sup..];
        let t_u = unsup.len();

        // forward pass, storing alphas
        for j in 0..k {
            alphas[j] = la[s_last * k + j] + lb[j * v + unsup[0]];
        }
        for t in 1..t_u {
            let (prev, cur) = alphas.split_at_mut(t * k);
            let prev = &prev[(t - 1) * k..];
            for j in 0..k {
                for i in 0..k {
                    scores[i] = prev[i] + la[i * k + j];
                }
                cur[j] = log_sum_exp(scores) + lb[j * v + unsup[t]];
            }
        }
        let last = &alphas[(t_u - 1) * k..t_u * k];
        let value = log_sum_exp(last);

        // reverse pass
        for p in partials.iter_mut() {
            *p = 0.0;
        }
        let (gla, glb) = partials.split_at_mut(k * k);
        for (dst, a) in abar.iter_mut().zip(last) {
            *dst = (a - value).exp();
        }
        for t in (1..t_u).rev() {
            let prev = &alphas[(t - 1) * k..t * k];
            let cur = &alphas[t * k..(t + 1) * k];
            abar_prev.iter_mut().for_each(|x| *x = 0.0);
            for j in 0..k {
                let aj = abar[j];
                if aj == 0.0 {
                    continue;
                }
                glb[j * v + unsup[t]] += aj;
                let s_t = cur[j] - lb[j * v + unsup[t]];
                for i in 0..k {
                    let w = (prev[i] + la[i * k + j] - s_t).exp();
                    gla[i * k + j] += aj * w;
                    abar_prev[i] += aj * w;
                }
            }
            std::mem::swap(abar, abar_prev);
        }
        // t = 0: alpha0_j = la[s_last, j] + lb[j, y_0]
        for j in 0..k {
            gla[s_last * k + j] += abar[j];
            glb[j * v + unsup[0]] += abar[j];
        }
        value
    }
}

impl Potential for HmmNative {
    fn dim(&self) -> usize {
        let (k, v) = (self.num_states, self.num_categories);
        k * (v - 1) + k * (k - 1)
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.evals += 1;
        let (k, v) = (self.num_states, self.num_categories);
        let n_phi = k * (v - 1);

        let mut t = std::mem::take(&mut self.tape);
        t.reset();
        self.inputs.clear();
        for &x in z {
            self.inputs.push(t.input(x));
        }

        // phi rows via stick-breaking
        self.log_phi.clear();
        self.log_theta.clear();
        self.ladjs.clear();
        for row in 0..k {
            self.sb_out.clear();
            let ladj = stick_breaking_t_into(
                &mut t,
                &self.inputs[row * (v - 1)..(row + 1) * (v - 1)],
                &mut self.sb_out,
                &mut self.sb_scratch,
            );
            self.ladjs.push(ladj);
            for &y in &self.sb_out {
                self.log_phi.push(t.ln(y));
            }
        }
        // theta rows
        for row in 0..k {
            let base = n_phi + row * (k - 1);
            self.sb_out.clear();
            let ladj = stick_breaking_t_into(
                &mut t,
                &self.inputs[base..base + (k - 1)],
                &mut self.sb_out,
                &mut self.sb_scratch,
            );
            self.ladjs.push(ladj);
            for &y in &self.sb_out {
                self.log_theta.push(t.ln(y));
            }
        }
        let ladj = t.sum(&self.ladjs);

        // Dirichlet(1) priors contribute the normalizing constants only
        let prior_const = k as f64 * (ln_gamma(v as f64) + ln_gamma(k as f64));

        // supervised sufficient statistics
        self.sup_terms.clear();
        for i in 0..k {
            for j in 0..k {
                let c = self.trans_counts[i * k + j];
                if c != 0.0 {
                    let lv = self.log_theta[i * k + j];
                    self.sup_terms.push(t.scale(lv, c));
                }
            }
            for w in 0..v {
                let c = self.emis_counts[i * v + w];
                if c != 0.0 {
                    let lv = self.log_phi[i * v + w];
                    self.sup_terms.push(t.scale(lv, c));
                }
            }
        }
        let sup_ll = t.sum(&self.sup_terms);

        // unsupervised tail: fused forward-algorithm composite
        for (dst, lv) in self.la_vals.iter_mut().zip(&self.log_theta) {
            *dst = t.value(*lv);
        }
        for (dst, lv) in self.lb_vals.iter_mut().zip(&self.log_phi) {
            *dst = t.value(*lv);
        }
        let marg = self.forward_marginal();
        self.parents.clear();
        self.parents.extend_from_slice(&self.log_theta);
        self.parents.extend_from_slice(&self.log_phi);
        let unsup_ll = t.composite(&self.parents, &self.partials, marg);

        let mut logp = t.add(sup_ll, unsup_ll);
        logp = t.add(logp, ladj);
        logp = t.offset(logp, prior_const);
        let u = t.neg(logp);
        let uval = t.value(u);
        let adj = t.grad(u);
        for (i, v_in) in self.inputs.iter().enumerate() {
            grad[i] = adj[v_in.0 as usize];
        }
        self.tape = t;
        uval
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::finite_diff;
    use crate::rng::Rng;

    fn toy() -> HmmNative {
        let mut rng = Rng::new(0);
        let (k, v, t_len, t_sup) = (3usize, 10usize, 60usize, 15usize);
        let obs: Vec<usize> = (0..t_len).map(|_| rng.below(v)).collect();
        let sup: Vec<usize> = (0..t_sup).map(|_| rng.below(k)).collect();
        HmmNative::new(obs, sup, k, v)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let mut pot = toy();
        let dim = pot.dim();
        assert_eq!(dim, 33);
        let mut rng = Rng::new(1);
        let z: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.5).collect();
        let mut g = vec![0.0; dim];
        let _ = pot.value_and_grad(&z, &mut g);
        let fd = finite_diff(&z, |zz| {
            let mut tmp = vec![0.0; dim];
            pot.value_and_grad(zz, &mut tmp)
        }, 1e-6);
        for i in 0..dim {
            assert!(
                (g[i] - fd[i]).abs() < 1e-4 * (1.0 + fd[i].abs()),
                "i={i}: {} vs {}",
                g[i],
                fd[i]
            );
        }
    }

    #[test]
    fn forward_marginal_matches_brute_force_tiny() {
        // 2 states, 2 categories, 3 unsupervised steps: enumerate paths.
        let obs = vec![0, 1, 0, 1]; // first is supervised
        let sup = vec![1];
        let mut pot = HmmNative::new(obs.clone(), sup.clone(), 2, 2);
        let theta: [[f64; 2]; 2] = [[0.7, 0.3], [0.4, 0.6]];
        let phi: [[f64; 2]; 2] = [[0.2, 0.8], [0.9, 0.1]];
        let la: Vec<f64> = theta.iter().flatten().map(|p| p.ln()).collect();
        let lb: Vec<f64> = phi.iter().flatten().map(|p| p.ln()).collect();
        pot.la_vals.copy_from_slice(&la);
        pot.lb_vals.copy_from_slice(&lb);
        let got = pot.forward_marginal();

        // brute force over z_1, z_2, z_3 given z_0 = 1
        let unsup = &obs[1..];
        let mut total: f64 = 0.0;
        for z1 in 0..2 {
            for z2 in 0..2 {
                for z3 in 0..2 {
                    total += theta[1][z1]
                        * phi[z1][unsup[0]]
                        * theta[z1][z2]
                        * phi[z2][unsup[1]]
                        * theta[z2][z3]
                        * phi[z3][unsup[2]];
                }
            }
        }
        assert!((got - total.ln()).abs() < 1e-12, "{got} vs {}", total.ln());
        // partials sum: d logp / d la rows: each abar distributes; sanity
        assert!(pot.partials.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn tape_reuse_is_bitwise_stable() {
        let mut pot = toy();
        let dim = pot.dim();
        let mut rng = Rng::new(2);
        let z: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.4).collect();
        let mut g0 = vec![0.0; dim];
        let u0 = pot.value_and_grad(&z, &mut g0);
        let mut tmp = vec![0.0; dim];
        let z2: Vec<f64> = z.iter().map(|v| v + 0.3).collect();
        let _ = pot.value_and_grad(&z2, &mut tmp);
        let mut g1 = vec![0.0; dim];
        let u1 = pot.value_and_grad(&z, &mut g1);
        assert_eq!(u0, u1);
        assert_eq!(g0, g1);
    }
}
