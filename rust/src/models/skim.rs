//! Native SKIM potential (Fig 2b benchmark, E3).
//!
//! Density identical to `python/compile/models/skim.py`: the
//! kernel-interaction-trick marginal likelihood (Agrawal et al. 2019)
//! with HalfCauchy local scales — latent dimension p + 4.
//!
//! The N x N kernel construction + MVN marginal is one fused composite
//! primitive (the Stan-analogue of a custom cholesky rev rule): forward
//! builds K(kappa, eta1sq, eta2sq, sigma_sq), factorizes, evaluates the
//! marginal; backward forms Kbar = 0.5 (beta beta^T - K^{-1}) with
//! beta = K^{-1} y and contracts analytically to the parameter partials
//! (see DESIGN.md §2 and the derivation in this file).
//!
//! All O(N^2)/O(NP) work buffers (kernel, Gram matrices, Cholesky
//! factor, Kbar, contraction scratch) plus the tape live in
//! `SkimScratch` on the struct and are reused across evaluations —
//! the hot path is allocation free and Kbar/Gbar overwrite their
//! source buffers in place.
//!
//! Unconstrained layout (sorted site names): [eta1, lambda (p), msq,
//! sigma, xisq], all positive -> exp transform.

use crate::autodiff::{Tape, Var};
use crate::mcmc::Potential;
use crate::ppl::special::LN_2PI;
use crate::util::linalg::{
    cholesky, gram, log_det_from_chol, solve_lower, solve_lower_t, spd_inverse_from_chol_into,
};

pub struct SkimHypers {
    pub expected_sparsity: f64,
    pub alpha1: f64,
    pub beta1: f64,
    pub alpha2: f64,
    pub beta2: f64,
    pub alpha3: f64,
    pub c: f64,
    pub jitter: f64,
}

impl Default for SkimHypers {
    fn default() -> Self {
        SkimHypers {
            expected_sparsity: 3.0,
            alpha1: 3.0,
            beta1: 1.0,
            alpha2: 3.0,
            beta2: 1.0,
            alpha3: 1.0,
            c: 1.0,
            jitter: 1e-4,
        }
    }
}

/// Reusable per-evaluation work buffers for the fused marginal.
struct SkimScratch {
    /// kappa-scaled design kX (n x p)
    kx: Vec<f64>,
    /// elementwise square of kX (n x p)
    kx2: Vec<f64>,
    /// G = kX kX^T (n x n); overwritten by Gbar in the backward pass
    g: Vec<f64>,
    /// G2 = kX^2 (kX^2)^T (n x n)
    g2: Vec<f64>,
    /// kernel K, factorized in place to its Cholesky factor L
    l: Vec<f64>,
    /// L^{-1} y, then K^{-1} y
    beta: Vec<f64>,
    /// K^{-1}, overwritten by Kbar = 0.5 (beta beta^T - K^{-1})
    kbar: Vec<f64>,
    /// column scratch for the SPD inverse
    col: Vec<f64>,
    m_buf: Vec<f64>,
    m2_buf: Vec<f64>,
}

impl SkimScratch {
    fn new(n: usize, p: usize) -> Self {
        SkimScratch {
            kx: vec![0.0; n * p],
            kx2: vec![0.0; n * p],
            g: vec![0.0; n * n],
            g2: vec![0.0; n * n],
            l: vec![0.0; n * n],
            beta: vec![0.0; n],
            kbar: vec![0.0; n * n],
            col: vec![0.0; n],
            m_buf: vec![0.0; n * p],
            m2_buf: vec![0.0; n * p],
        }
    }
}

pub struct SkimNative {
    /// row-major (n, p)
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub n: usize,
    pub p: usize,
    pub hypers: SkimHypers,
    evals: u64,
    scratch: SkimScratch,
    tape: Tape,
    /// fused-marginal partials wrt (kappa_0..kappa_{p-1}, e1sq, e2sq, sigsq)
    partials: Vec<f64>,
    kappa_vals: Vec<f64>,
    inputs: Vec<Var>,
    lam_vars: Vec<Var>,
    kappa_vars: Vec<Var>,
    ladj_parents: Vec<Var>,
    p_lam_terms: Vec<Var>,
    parents: Vec<Var>,
}

impl SkimNative {
    pub fn new(x: Vec<f64>, y: Vec<f64>, n: usize, p: usize, hypers: SkimHypers) -> Self {
        assert_eq!(x.len(), n * p);
        assert_eq!(y.len(), n);
        SkimNative {
            x,
            y,
            n,
            p,
            hypers,
            evals: 0,
            scratch: SkimScratch::new(n, p),
            tape: Tape::new(),
            partials: vec![0.0; p + 3],
            kappa_vals: vec![0.0; p],
            inputs: Vec::with_capacity(p + 4),
            lam_vars: Vec::with_capacity(p),
            kappa_vars: Vec::with_capacity(p),
            ladj_parents: Vec::with_capacity(p + 4),
            p_lam_terms: Vec::with_capacity(p),
            parents: Vec::with_capacity(p + 3),
        }
    }

    /// Fused marginal over `self.kappa_vals`: value = log MVN(y | 0,
    /// K + (sigma^2 + jitter) I); writes partials wrt (kappa_0..
    /// kappa_{p-1}, eta1sq, eta2sq, sigma_sq) into `self.partials`.
    fn marginal(&mut self, eta1sq: f64, eta2sq: f64, sigma_sq: f64) -> Result<f64, String> {
        let (n, p) = (self.n, self.p);
        let csq = self.hypers.c * self.hypers.c;
        let jitter = self.hypers.jitter;
        let SkimNative {
            x,
            y,
            kappa_vals,
            partials,
            scratch,
            ..
        } = self;
        let x = &x[..];
        let kappa = &kappa_vals[..];
        let SkimScratch {
            kx,
            kx2,
            g,
            g2,
            l,
            beta,
            kbar,
            col,
            m_buf,
            m2_buf,
        } = scratch;

        // kX and kX^2
        for i in 0..n {
            for d in 0..p {
                let v = kappa[d] * x[i * p + d];
                kx[i * p + d] = v;
                kx2[i * p + d] = v * v;
            }
        }
        // G = kX kX^T, G2 = kX^2 (kX^2)^T
        let kx = &kx[..];
        let kx2 = &kx2[..];
        gram(kx, kx, n, p, g);
        gram(kx2, kx2, n, p, g2);

        // K = 0.5 e2 (1+G)^2 - 0.5 e2 G2 + (e1 - e2) G + (c^2 - 0.5 e2)
        //     + (sigma^2 + jitter) I    (built into l, factorized there)
        for i in 0..n * n {
            let gi = g[i];
            l[i] = 0.5 * eta2sq * (1.0 + gi) * (1.0 + gi) - 0.5 * eta2sq * g2[i]
                + (eta1sq - eta2sq) * gi
                + (csq - 0.5 * eta2sq);
        }
        for i in 0..n {
            l[i * n + i] += sigma_sq + jitter;
        }

        // factorize + marginal
        cholesky(l, n)?;
        beta.copy_from_slice(y);
        solve_lower(l, n, beta);
        let quad: f64 = beta.iter().map(|b| b * b).sum();
        let value = -0.5 * quad - 0.5 * log_det_from_chol(l, n) - 0.5 * n as f64 * LN_2PI;
        solve_lower_t(l, n, beta); // now beta = K^{-1} y

        // Kbar = 0.5 (beta beta^T - K^{-1}), overwriting K^{-1} in place
        spd_inverse_from_chol_into(l, n, kbar, col);
        for i in 0..n {
            for j in 0..n {
                kbar[i * n + j] = 0.5 * (beta[i] * beta[j] - kbar[i * n + j]);
            }
        }

        // partials wrt scalars
        let mut d_e1 = 0.0;
        let mut d_e2 = 0.0;
        let mut d_sig = 0.0;
        for i in 0..n {
            for j in 0..n {
                let kb = kbar[i * n + j];
                let gi = g[i * n + j];
                d_e1 += kb * gi;
                d_e2 += kb * (0.5 * (1.0 + gi) * (1.0 + gi) - 0.5 * g2[i * n + j] - gi - 0.5);
            }
            d_sig += kbar[i * n + i];
        }

        // partials wrt kappa: Gbar = Kbar * dK/dG (overwrites G in
        // place), G2bar = -0.5 e2 Kbar;
        // grad_kappa_d = 2 kappa_d (X^T Gbar X)_dd + 4 kappa_d^3 (X2^T G2bar X2)_dd
        for i in 0..n * n {
            g[i] = kbar[i] * (eta2sq * (1.0 + g[i]) + eta1sq - eta2sq);
        }
        let gbar = &g[..];
        // M = Gbar X (n x p); diag_d = sum_i x_id M_id
        m_buf.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n {
            for j in 0..n {
                let gb = gbar[i * n + j];
                if gb == 0.0 {
                    continue;
                }
                let xj = &x[j * p..(j + 1) * p];
                let mi = &mut m_buf[i * p..(i + 1) * p];
                for d in 0..p {
                    mi[d] += gb * xj[d];
                }
            }
        }
        for d in 0..p {
            let mut acc = 0.0;
            for i in 0..n {
                acc += x[i * p + d] * m_buf[i * p + d];
            }
            partials[d] = 2.0 * kappa[d] * acc;
        }
        // second term with X2 = X o X and G2bar
        m2_buf.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n {
            for j in 0..n {
                let g2b = -0.5 * eta2sq * kbar[i * n + j];
                let xj = &x[j * p..(j + 1) * p];
                let mi = &mut m2_buf[i * p..(i + 1) * p];
                for d in 0..p {
                    mi[d] += g2b * xj[d] * xj[d];
                }
            }
        }
        for d in 0..p {
            let mut acc = 0.0;
            for i in 0..n {
                let xi = x[i * p + d];
                acc += xi * xi * m2_buf[i * p + d];
            }
            partials[d] += 4.0 * kappa[d].powi(3) * acc;
        }
        partials[p] = d_e1;
        partials[p + 1] = d_e2;
        partials[p + 2] = d_sig;
        Ok(value)
    }
}

/// log HalfCauchy(x; scale) on the tape (x, scale both Vars).
fn half_cauchy_lpdf(t: &mut Tape, x: Var, scale: Var) -> Var {
    let z = t.div(x, scale);
    let z2 = t.square(z);
    let l1p = t.log1p(z2);
    let ls = t.ln(scale);
    let sum = t.add(l1p, ls);
    let neg = t.neg(sum);
    t.offset(neg, (2.0 / std::f64::consts::PI).ln())
}

impl Potential for SkimNative {
    fn dim(&self) -> usize {
        self.p + 4
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.evals += 1;
        let p = self.p;
        let h = &self.hypers;
        let phi_coef =
            (h.expected_sparsity / (self.n as f64).sqrt()) / (p as f64 - h.expected_sparsity);
        let (alpha1, beta1, alpha2, beta2, alpha3) =
            (h.alpha1, h.beta1, h.alpha2, h.beta2, h.alpha3);

        let mut t = std::mem::take(&mut self.tape);
        t.reset();
        self.inputs.clear();
        for &v in z {
            self.inputs.push(t.input(v));
        }
        // layout (sorted): eta1, lambda[p], msq, sigma, xisq
        let u_eta1 = self.inputs[0];
        let u_msq = self.inputs[1 + p];
        let u_sigma = self.inputs[2 + p];
        let u_xisq = self.inputs[3 + p];

        // exp transforms; ladj = sum of unconstrained values
        let eta1 = t.exp(u_eta1);
        self.lam_vars.clear();
        for i in 0..p {
            let u = self.inputs[1 + i];
            self.lam_vars.push(t.exp(u));
        }
        let msq = t.exp(u_msq);
        let sigma = t.exp(u_sigma);
        let xisq = t.exp(u_xisq);
        self.ladj_parents.clear();
        self.ladj_parents.push(u_eta1);
        self.ladj_parents.push(u_msq);
        self.ladj_parents.push(u_sigma);
        self.ladj_parents.push(u_xisq);
        self.ladj_parents.extend_from_slice(&self.inputs[1..1 + p]);
        let ladj = t.sum(&self.ladj_parents);

        // priors
        // sigma ~ HalfNormal(alpha3)
        let zsig = t.scale(sigma, 1.0 / alpha3);
        let zsig2 = t.square(zsig);
        let p_sigma_core = t.scale(zsig2, -0.5);
        let p_sigma = t.offset(p_sigma_core, 2f64.ln() - alpha3.ln() - 0.5 * LN_2PI);
        // eta1 ~ HalfCauchy(phi), phi = sigma * S/sqrt(N) / (P - S)
        let phi = t.scale(sigma, phi_coef);
        let p_eta1 = half_cauchy_lpdf(&mut t, eta1, phi);
        // msq ~ InverseGamma(a1, b1); xisq ~ InverseGamma(a2, b2)
        let ig = |t: &mut Tape, x: Var, a: f64, b: f64| {
            let lx = t.ln(x);
            let term1 = t.scale(lx, -(a + 1.0));
            let inv = t.div_const_by(b, x);
            let diff = t.sub(term1, inv);
            t.offset(diff, a * b.ln() - crate::ppl::special::ln_gamma(a))
        };
        let p_msq = ig(&mut t, msq, alpha1, beta1);
        let p_xisq = ig(&mut t, xisq, alpha2, beta2);
        // lambda_d ~ HalfCauchy(1)
        self.p_lam_terms.clear();
        for &l in &self.lam_vars {
            let l2 = t.square(l);
            let l1p = t.log1p(l2);
            let neg = t.neg(l1p);
            self.p_lam_terms.push(t.offset(neg, (2.0 / std::f64::consts::PI).ln()));
        }
        let p_lam = t.sum(&self.p_lam_terms);

        // derived quantities
        let eta1sq = t.square(eta1);
        // eta2 = eta1^2 sqrt(xisq) / msq  =>  eta2sq = eta1^4 xisq / msq^2
        let eta1_4 = t.square(eta1sq);
        let num = t.mul(eta1_4, xisq);
        let msq2 = t.square(msq);
        let eta2sq = t.div(num, msq2);
        // kappa_d = sqrt(msq) lam / sqrt(msq + (eta1 lam)^2)
        let sqrt_msq = t.sqrt(msq);
        self.kappa_vars.clear();
        for &l in &self.lam_vars {
            let el = t.mul(eta1, l);
            let el2 = t.square(el);
            let denom_in = t.add(msq, el2);
            let denom = t.sqrt(denom_in);
            let num_l = t.mul(sqrt_msq, l);
            self.kappa_vars.push(t.div(num_l, denom));
        }
        let sigma_sq = t.square(sigma);

        // fused marginal composite
        for (dst, kv) in self.kappa_vals.iter_mut().zip(&self.kappa_vars) {
            *dst = t.value(*kv);
        }
        let (e1v, e2v, ssv) = (t.value(eta1sq), t.value(eta2sq), t.value(sigma_sq));
        let marg = match self.marginal(e1v, e2v, ssv) {
            Ok(v) => v,
            Err(_) => {
                // non-PD kernel: zero the partials so no stale gradient
                // leaks through the composite (seed semantics)
                for q in self.partials.iter_mut() {
                    *q = 0.0;
                }
                f64::NEG_INFINITY
            }
        };
        self.parents.clear();
        self.parents.extend_from_slice(&self.kappa_vars);
        self.parents.push(eta1sq);
        self.parents.push(eta2sq);
        self.parents.push(sigma_sq);
        let lik = t.composite(&self.parents, &self.partials, marg);

        let prior_terms = [p_sigma, p_eta1, p_msq, p_xisq, p_lam, lik, ladj];
        let logp = t.sum(&prior_terms);
        let u = t.neg(logp);
        let uval = t.value(u);
        let adj = t.grad(u);
        for (i, v_in) in self.inputs.iter().enumerate() {
            grad[i] = adj[v_in.0 as usize];
        }
        self.tape = t;
        uval
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::finite_diff;
    use crate::rng::Rng;

    fn toy(n: usize, p: usize) -> SkimNative {
        let mut rng = Rng::new(0);
        let x: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        SkimNative::new(x, y, n, p, SkimHypers::default())
    }

    #[test]
    fn grad_matches_finite_diff() {
        let mut pot = toy(20, 5);
        let dim = pot.dim();
        let mut rng = Rng::new(1);
        let z: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
        let mut g = vec![0.0; dim];
        let _ = pot.value_and_grad(&z, &mut g);
        let fd = finite_diff(&z, |zz| {
            let mut tmp = vec![0.0; dim];
            pot.value_and_grad(zz, &mut tmp)
        }, 1e-6);
        for i in 0..dim {
            assert!(
                (g[i] - fd[i]).abs() < 2e-4 * (1.0 + fd[i].abs()),
                "i={i}: {} vs {}",
                g[i],
                fd[i]
            );
        }
    }

    #[test]
    fn value_is_finite_at_origin() {
        let mut pot = toy(15, 4);
        let z = vec![0.0; pot.dim()];
        let mut g = vec![0.0; pot.dim()];
        let u = pot.value_and_grad(&z, &mut g);
        assert!(u.is_finite());
        assert!(g.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn tape_reuse_is_bitwise_stable() {
        let mut pot = toy(12, 3);
        let dim = pot.dim();
        let mut rng = Rng::new(4);
        let z: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
        let mut g0 = vec![0.0; dim];
        let u0 = pot.value_and_grad(&z, &mut g0);
        let z2: Vec<f64> = z.iter().map(|v| v - 0.2).collect();
        let mut tmp = vec![0.0; dim];
        let _ = pot.value_and_grad(&z2, &mut tmp);
        let mut g1 = vec![0.0; dim];
        let u1 = pot.value_and_grad(&z, &mut g1);
        assert_eq!(u0, u1);
        assert_eq!(g0, g1);
    }
}
