//! Native logistic regression potential (COVTYPE benchmark, E2).
//!
//! Density identical to `python/compile/models/logistic.py`:
//! unit-normal priors on weights `m` (D) and intercept `b`, Bernoulli
//! likelihood with logits `X m + b`.
//!
//! The likelihood is one fused composite node — the exact analogue of
//! Stan's `bernoulli_logit_glm_lpmf`: forward computes
//! `sum_i y_i z_i - softplus(z_i)` and the partials
//! `d/dm_j = sum_i (y_i - sigmoid(z_i)) x_ij`, `d/db = sum_i (y_i - s_i)`
//! in the same O(ND) sweep.
//!
//! Parameter layout matches the artifact manifest: `ravel_pytree` sorts
//! site names, so the flat vector is `[b, m_0..m_{D-1}]`.

use crate::autodiff::{Tape, Var};
use crate::mcmc::Potential;
use crate::ppl::special::{sigmoid, softplus, LN_2PI};

pub struct LogisticNative {
    /// row-major (n, d)
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub n: usize,
    pub d: usize,
    evals: u64,
    /// scratch logits buffer (reused across evaluations)
    z_buf: Vec<f64>,
}

impl LogisticNative {
    pub fn new(x: Vec<f64>, y: Vec<f64>, n: usize, d: usize) -> Self {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n);
        LogisticNative {
            x,
            y,
            n,
            d,
            evals: 0,
            z_buf: vec![0.0; n],
        }
    }

    /// Fused GLM log-likelihood: value + partials wrt (m_0..m_{D-1}, b).
    fn glm_loglik(&mut self, m: &[f64], b: f64, grad_out: &mut [f64]) -> f64 {
        let (n, d) = (self.n, self.d);
        let mut value = 0.0;
        for g in grad_out.iter_mut() {
            *g = 0.0;
        }
        for i in 0..n {
            let xi = &self.x[i * d..(i + 1) * d];
            let mut z = b;
            for j in 0..d {
                z += xi[j] * m[j];
            }
            self.z_buf[i] = z;
            value += self.y[i] * z - softplus(z);
            let r = self.y[i] - sigmoid(z);
            for j in 0..d {
                grad_out[j] += r * xi[j];
            }
            grad_out[d] += r;
        }
        value
    }
}

impl Potential for LogisticNative {
    fn dim(&self) -> usize {
        self.d + 1
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.evals += 1;
        let d = self.d;
        // layout: [b, m...] (sorted site names: "b" < "m")
        let b_val = z[0];
        let m_vals = &z[1..];

        let mut t = Tape::new();
        let b = t.input(b_val);
        let m: Vec<Var> = m_vals.iter().map(|&v| t.input(v)).collect();

        // priors: N(0,1) on b and each m_j
        let mut prior_terms = Vec::with_capacity(d + 1);
        for &v in std::iter::once(&b).chain(m.iter()) {
            let sq = t.square(v);
            let half = t.scale(sq, -0.5);
            prior_terms.push(t.offset(half, -0.5 * LN_2PI));
        }
        let log_prior = t.sum(&prior_terms);

        // fused likelihood composite
        let mut partials = vec![0.0; d + 1];
        let ll_value = self.glm_loglik(m_vals, b_val, &mut partials);
        let mut parents: Vec<Var> = m.clone();
        parents.push(b);
        let log_lik = t.composite(&parents, &partials, ll_value);

        let logp = t.add(log_prior, log_lik);
        let u = t.neg(logp);
        let adj = t.grad(u);
        grad[0] = adj[b.0 as usize];
        for j in 0..d {
            grad[1 + j] = adj[m[j].0 as usize];
        }
        t.value(u)
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::finite_diff;
    use crate::rng::Rng;

    fn toy() -> LogisticNative {
        let mut rng = Rng::new(0);
        let (n, d) = (50, 3);
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        LogisticNative::new(x, y, n, d)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let mut pot = toy();
        let z = [0.3, -0.5, 0.8, 0.1];
        let mut g = vec![0.0; 4];
        let _ = pot.value_and_grad(&z, &mut g);
        let fd = finite_diff(&z, |zz| {
            let mut tmp = vec![0.0; 4];
            pot.value_and_grad(zz, &mut tmp)
        }, 1e-6);
        for i in 0..4 {
            assert!((g[i] - fd[i]).abs() < 1e-5, "i={i}: {} vs {}", g[i], fd[i]);
        }
    }

    #[test]
    fn value_matches_direct_formula() {
        let mut pot = toy();
        let z = [0.2, 0.4, -0.3, 0.9];
        let mut g = vec![0.0; 4];
        let u = pot.value_and_grad(&z, &mut g);
        // direct: -sum prior - sum lik
        let (b, m) = (z[0], &z[1..]);
        let mut logp = 0.0;
        for v in z.iter() {
            logp += -0.5 * v * v - 0.5 * LN_2PI;
        }
        for i in 0..pot.n {
            let xi = &pot.x[i * pot.d..(i + 1) * pot.d];
            let zi = b + xi.iter().zip(m).map(|(a, c)| a * c).sum::<f64>();
            logp += pot.y[i] * zi - softplus(zi);
        }
        assert!((u + logp).abs() < 1e-10, "{u} vs {}", -logp);
    }
}
