//! Native logistic regression potential (COVTYPE benchmark, E2).
//!
//! Density identical to `python/compile/models/logistic.py`:
//! unit-normal priors on weights `m` (D) and intercept `b`, Bernoulli
//! likelihood with logits `X m + b`.
//!
//! The likelihood is one fused composite node — the exact analogue of
//! Stan's `bernoulli_logit_glm_lpmf`: forward computes
//! `sum_i y_i z_i - softplus(z_i)` and the partials
//! `d/dm_j = sum_i (y_i - sigmoid(z_i)) x_ij`, `d/db = sum_i (y_i - s_i)`
//! in the same O(ND) sweep.  The sweep is cache-blocked (logits +
//! residuals for a block of rows first, then the rank-1 gradient
//! accumulation over the same hot rows) and computes sigmoid and
//! softplus from a *single* shared `exp` per observation.
//!
//! All per-evaluation storage — the [`Tape`], the composite partials,
//! the `Var` scratch lists and the residual block buffer — lives on the
//! struct and is reused, so steady-state evaluations are allocation
//! free.
//!
//! Parameter layout matches the artifact manifest: `ravel_pytree` sorts
//! site names, so the flat vector is `[b, m_0..m_{D-1}]`.

use crate::autodiff::{Tape, Var};
use crate::mcmc::Potential;
use crate::ppl::special::{softplus_sigmoid, LN_2PI};

/// Rows per cache block of the fused likelihood sweep.
const BLOCK: usize = 64;

/// Four-accumulator dot product: breaks the serial FP dependency chain
/// of a naive `z += x[j] * m[j]` loop (strict IEEE semantics forbid the
/// compiler from doing this reassociation itself).
#[inline(always)]
fn dot4(xi: &[f64], m: &[f64]) -> f64 {
    let n = xi.len().min(m.len());
    let (mut a0, mut a1, mut a2, mut a3) = (0.0, 0.0, 0.0, 0.0);
    let chunks = n & !3;
    let mut j = 0;
    while j < chunks {
        a0 += xi[j] * m[j];
        a1 += xi[j + 1] * m[j + 1];
        a2 += xi[j + 2] * m[j + 2];
        a3 += xi[j + 3] * m[j + 3];
        j += 4;
    }
    let mut tail = 0.0;
    while j < n {
        tail += xi[j] * m[j];
        j += 1;
    }
    (a0 + a1) + (a2 + a3) + tail
}

pub struct LogisticNative {
    /// row-major (n, d)
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub n: usize,
    pub d: usize,
    evals: u64,
    /// residual buffer (y_i - sigmoid(z_i)), reused across evaluations
    z_buf: Vec<f64>,
    /// reusable tape (reset between evaluations, capacity kept)
    tape: Tape,
    /// fused-likelihood partials wrt (m_0..m_{D-1}, b)
    partials: Vec<f64>,
    m_vars: Vec<Var>,
    prior_vars: Vec<Var>,
    parent_vars: Vec<Var>,
}

impl LogisticNative {
    pub fn new(x: Vec<f64>, y: Vec<f64>, n: usize, d: usize) -> Self {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n);
        LogisticNative {
            x,
            y,
            n,
            d,
            evals: 0,
            z_buf: vec![0.0; n],
            tape: Tape::new(),
            partials: vec![0.0; d + 1],
            m_vars: Vec::with_capacity(d),
            prior_vars: Vec::with_capacity(d + 1),
            parent_vars: Vec::with_capacity(d + 1),
        }
    }

    /// Fused GLM log-likelihood over `z = [b, m...]`: returns the value
    /// and writes partials wrt (m_0..m_{D-1}, b) into `self.partials`.
    fn glm_loglik(&mut self, z: &[f64]) -> f64 {
        let (n, d) = (self.n, self.d);
        let b = z[0];
        let m = &z[1..];
        let LogisticNative {
            x,
            y,
            z_buf,
            partials,
            ..
        } = self;
        for g in partials.iter_mut() {
            *g = 0.0;
        }
        let mut value = 0.0;
        let mut start = 0;
        while start < n {
            let end = (start + BLOCK).min(n);
            // pass 1: block logits; sigmoid + softplus share one exp:
            //   z >= 0: e = exp(-z), softplus = z + log1p(e), sig = 1/(1+e)
            //   z <  0: e = exp(z),  softplus = log1p(e),     sig = e/(1+e)
            for i in start..end {
                let xi = &x[i * d..(i + 1) * d];
                let zl = b + dot4(xi, m);
                let (sp, sig) = softplus_sigmoid(zl);
                value += y[i] * zl - sp;
                z_buf[i] = y[i] - sig;
            }
            // pass 2: rank-1 gradient accumulation over the same block
            // while its rows of X are still cache-resident
            for i in start..end {
                let r = z_buf[i];
                let xi = &x[i * d..(i + 1) * d];
                for j in 0..d {
                    partials[j] += r * xi[j];
                }
                partials[d] += r;
            }
            start = end;
        }
        value
    }
}

impl Potential for LogisticNative {
    fn dim(&self) -> usize {
        self.d + 1
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.evals += 1;
        let d = self.d;
        // layout: [b, m...] (sorted site names: "b" < "m")
        let b_val = z[0];
        let ll_value = self.glm_loglik(z);

        // move the tape out so scratch fields stay borrowable
        let mut t = std::mem::take(&mut self.tape);
        t.reset();
        let b = t.input(b_val);
        self.m_vars.clear();
        for &v in &z[1..] {
            self.m_vars.push(t.input(v));
        }

        // priors: N(0,1) on b and each m_j
        self.prior_vars.clear();
        for i in 0..=d {
            let v = if i == 0 { b } else { self.m_vars[i - 1] };
            let sq = t.square(v);
            let half = t.scale(sq, -0.5);
            self.prior_vars.push(t.offset(half, -0.5 * LN_2PI));
        }
        let log_prior = t.sum(&self.prior_vars);

        // fused likelihood composite (parents: m..., b)
        self.parent_vars.clear();
        self.parent_vars.extend_from_slice(&self.m_vars);
        self.parent_vars.push(b);
        let log_lik = t.composite(&self.parent_vars, &self.partials, ll_value);

        let logp = t.add(log_prior, log_lik);
        let u = t.neg(logp);
        let uval = t.value(u);
        let adj = t.grad(u);
        grad[0] = adj[b.0 as usize];
        for j in 0..d {
            grad[1 + j] = adj[self.m_vars[j].0 as usize];
        }
        self.tape = t;
        uval
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::finite_diff;
    use crate::ppl::special::softplus;
    use crate::rng::Rng;

    fn toy() -> LogisticNative {
        let mut rng = Rng::new(0);
        let (n, d) = (50, 3);
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        LogisticNative::new(x, y, n, d)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let mut pot = toy();
        let z = [0.3, -0.5, 0.8, 0.1];
        let mut g = vec![0.0; 4];
        let _ = pot.value_and_grad(&z, &mut g);
        let fd = finite_diff(&z, |zz| {
            let mut tmp = vec![0.0; 4];
            pot.value_and_grad(zz, &mut tmp)
        }, 1e-6);
        for i in 0..4 {
            assert!((g[i] - fd[i]).abs() < 1e-5, "i={i}: {} vs {}", g[i], fd[i]);
        }
    }

    #[test]
    fn value_matches_direct_formula() {
        let mut pot = toy();
        let z = [0.2, 0.4, -0.3, 0.9];
        let mut g = vec![0.0; 4];
        let u = pot.value_and_grad(&z, &mut g);
        // direct: -sum prior - sum lik
        let (b, m) = (z[0], &z[1..]);
        let mut logp = 0.0;
        for v in z.iter() {
            logp += -0.5 * v * v - 0.5 * LN_2PI;
        }
        for i in 0..pot.n {
            let xi = &pot.x[i * pot.d..(i + 1) * pot.d];
            let zi = b + xi.iter().zip(m).map(|(a, c)| a * c).sum::<f64>();
            logp += pot.y[i] * zi - softplus(zi);
        }
        assert!((u + logp).abs() < 1e-10, "{u} vs {}", -logp);
    }

    #[test]
    fn tape_reuse_is_bitwise_stable() {
        // the same point evaluated repeatedly on the reused tape must
        // reproduce the very first evaluation exactly
        let mut pot = toy();
        let z = [0.3, -0.5, 0.8, 0.1];
        let mut g0 = vec![0.0; 4];
        let u0 = pot.value_and_grad(&z, &mut g0);
        // interleave an unrelated point to perturb the scratch
        let mut tmp = vec![0.0; 4];
        let _ = pot.value_and_grad(&[1.0, 2.0, -3.0, 0.4], &mut tmp);
        let mut g1 = vec![0.0; 4];
        let u1 = pot.value_and_grad(&z, &mut g1);
        assert_eq!(u0, u1);
        assert_eq!(g0, g1);
    }
}
