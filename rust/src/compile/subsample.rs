//! Subsampled (minibatch) compiled models — the compile-layer half of
//! Pyro's `plate(..., subsample_size=B)` contract (ROADMAP open item
//! 4, the paper's tall-data regime).
//!
//! A subsampled model is an ordinary [`EffModel`] whose observation
//! section is wrapped in [`ProbCtx::subsample`] /
//! [`ProbCtx::end_subsample`] and reads its data from small **staging
//! buffers** of `B` rows instead of the full `N`-row dataset.  Under a
//! tape context that wrapper does two things:
//!
//! 1. every observation log-density term inside the scope is scaled by
//!    `N/B` (one recorded `Scale` node), so the joint log-density is an
//!    unbiased estimator of the full-data one over uniformly drawn
//!    minibatches — exactly the correction NumPyro's `scale` handler
//!    applies under a subsampled plate;
//! 2. a **data region** is opened on the tape, registering every
//!    constant fed to the fused observation composites (dot-product
//!    coefficient runs, observed-value runs, generic-fallback constant
//!    nodes) as a rebindable [`crate::autodiff::Tape`] data slot.
//!
//! Because the recorded op *structure* is independent of which rows
//! occupy the staging buffers, swapping minibatches never re-records:
//! [`SubsampleRebind::set_minibatch`] gathers the new rows into staging
//! and patches the frozen `TapeProgram` / `BatchTapeProgram` slots in
//! place — a handful of `copy_from_slice` calls per step, not a
//! re-freeze.  With `B == N` the scale is exactly 1.0, no `Scale` node
//! is recorded, and the program is **bitwise identical** to the plain
//! full-batch model (`rust/tests/subsampling.rs`).

use crate::compile::{EffModel, ProbCtx};
use crate::data::stream::RowLoader;

/// A model whose observations read from minibatch staging buffers.
/// The compiled wrappers ([`crate::compile::CompiledModel`],
/// [`crate::compile::BatchedCompiledModel`] and the tiled potential)
/// use this interface to implement [`SubsampleRebind`]: `load_rows`
/// refills the staging buffers, and `num_slots`/`slot_data` expose the
/// staged constants in **tape registration order** so each frozen data
/// slot can be rebound from the matching staging span.
pub trait SubsampledModel: EffModel {
    /// Population size `N`.
    fn total_rows(&self) -> usize;
    /// Minibatch size `B` (fixed at compile time — the recorded
    /// program has exactly `B` observation rows).
    fn batch_rows(&self) -> usize;
    /// Gather the rows named by `idx` (length `B`) into staging.
    fn load_rows(&mut self, idx: &[usize]);
    /// Number of rebindable data slots the model registers while
    /// recording (must equal the frozen program's slot count).
    fn num_slots(&self) -> usize;
    /// The staged constants for slot `slot`, in registration order.
    fn slot_data(&self, slot: usize) -> &[f64];
}

/// Swap the active minibatch of a compiled potential without
/// re-recording or re-freezing — implemented by the scalar, batched
/// and tiled compiled wrappers.  Call it before each ELBO evaluation;
/// the next `value_and_grad` sees the new rows.
pub trait SubsampleRebind {
    fn set_minibatch(&mut self, idx: &[usize]);
}

/// Bayesian logistic regression over a [`RowLoader`], subsampled:
/// the same priors, logits and Bernoulli likelihood as
/// [`crate::compile::zoo::LogisticModel`] — the identical operation
/// sequence, in fact, which is what makes the `B == N` case bitwise
/// equal — but evaluated on a `B`-row staging window of an `N`-row
/// (possibly virtual, never-materialized) dataset.
///
/// Flat layout (sorted names): `[b, m_0..m_{d-1}]`.
#[derive(Debug, Clone)]
pub struct SubsampledLogistic<L: RowLoader> {
    loader: L,
    d: usize,
    batch: usize,
    /// staging: minibatch covariates, row-major (B, d)
    x_batch: Vec<f64>,
    /// staging: minibatch labels (B)
    y_batch: Vec<f64>,
}

impl<L: RowLoader> SubsampledLogistic<L> {
    /// Wrap `loader` with a `batch`-row staging window, pre-filled with
    /// rows `0..batch` so the model is evaluable (and traceable)
    /// before the first [`SubsampleRebind::set_minibatch`].
    pub fn new(loader: L, batch: usize) -> SubsampledLogistic<L> {
        let (n, d) = (loader.num_rows(), loader.dim());
        assert!(
            batch > 0 && batch <= n,
            "SubsampledLogistic: need 0 < batch ({batch}) <= rows ({n})"
        );
        let mut m = SubsampledLogistic {
            loader,
            d,
            batch,
            x_batch: vec![0.0; batch * d],
            y_batch: vec![0.0; batch],
        };
        let idx: Vec<usize> = (0..batch).collect();
        m.load_rows(&idx);
        m
    }

    /// The wrapped row source.
    pub fn loader(&self) -> &L {
        &self.loader
    }
}

impl<L: RowLoader> EffModel for SubsampledLogistic<L> {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let prior = c.normal(0.0, 1.0);
        let b = c.sample("b", prior);
        let prior = c.normal(0.0, 1.0);
        let mut m = c.vec_take();
        c.sample_vec("m", prior, self.d, &mut m);
        c.subsample(self.loader.num_rows(), self.batch);
        let mut logits = c.vec_take();
        for i in 0..self.batch {
            let xi = &self.x_batch[i * self.d..(i + 1) * self.d];
            let dm = c.dot(&m, xi);
            let zl = c.add(b, dm);
            logits.push(zl);
        }
        c.observe_bernoulli_logits("y", &logits, &self.y_batch);
        c.end_subsample();
        c.vec_put(logits);
        c.vec_put(m);
    }
}

impl<L: RowLoader> SubsampledModel for SubsampledLogistic<L> {
    fn total_rows(&self) -> usize {
        self.loader.num_rows()
    }

    fn batch_rows(&self) -> usize {
        self.batch
    }

    fn load_rows(&mut self, idx: &[usize]) {
        assert_eq!(
            idx.len(),
            self.batch,
            "SubsampledLogistic: minibatch must have exactly {} rows",
            self.batch
        );
        for (j, &i) in idx.iter().enumerate() {
            self.y_batch[j] = self
                .loader
                .load_row(i, &mut self.x_batch[j * self.d..(j + 1) * self.d]);
        }
    }

    // Registration order inside the data region: one dot-product
    // coefficient run per row (B Coeffs slots), then the observed
    // labels of the fused Bernoulli composite (1 Consts slot).
    fn num_slots(&self) -> usize {
        self.batch + 1
    }

    fn slot_data(&self, slot: usize) -> &[f64] {
        if slot < self.batch {
            &self.x_batch[slot * self.d..(slot + 1) * self.d]
        } else if slot == self.batch {
            &self.y_batch
        } else {
            panic!("SubsampledLogistic: slot {slot} out of range");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::compile;
    use crate::compile::zoo::LogisticModel;
    use crate::data::make_covtype_like;
    use crate::data::stream::InMemoryRows;
    use crate::mcmc::Potential;
    use crate::rng::Rng;

    fn small_rows(n: usize, d: usize) -> InMemoryRows {
        let data = make_covtype_like(5, n, d);
        InMemoryRows::new(data.x, data.y, n, d)
    }

    /// B == N: the subsampled model must be bitwise identical to the
    /// plain LogisticModel — same ops, no scale node, no divergence on
    /// the frozen path either.
    #[test]
    fn full_batch_subsampled_is_bitwise_identical_to_plain() {
        let (n, d) = (12, 3);
        let rows = small_rows(n, d);
        let plain = LogisticModel {
            x: rows.x.clone(),
            y: rows.y.clone(),
            n,
            d,
        };
        let mut a = compile(plain, 0).unwrap();
        let mut b = compile(SubsampledLogistic::new(rows, n), 0).unwrap();
        assert_eq!(a.dim(), b.dim());
        let dim = a.dim();
        let mut rng = Rng::new(2);
        let mut ga = vec![0.0; dim];
        let mut gb = vec![0.0; dim];
        for _ in 0..5 {
            let z: Vec<f64> = (0..dim).map(|_| 0.5 * rng.normal()).collect();
            let ua = a.value_and_grad(&z, &mut ga);
            let ub = b.value_and_grad(&z, &mut gb);
            assert_eq!(ua.to_bits(), ub.to_bits());
            for i in 0..dim {
                assert_eq!(ga[i].to_bits(), gb[i].to_bits(), "grad[{i}]");
            }
        }
    }

    /// Rebinding a minibatch on the frozen program must equal
    /// compiling a fresh model whose staging holds the same rows.
    #[test]
    fn rebound_minibatch_matches_fresh_compile_bitwise() {
        let (n, d, bsz) = (10, 3, 4);
        let rows = small_rows(n, d);
        let mut sub = compile(SubsampledLogistic::new(rows.clone(), bsz), 0).unwrap();
        let dim = sub.dim();
        let z = vec![0.2; dim];
        let mut g = vec![0.0; dim];
        let _ = sub.value_and_grad(&z, &mut g); // record + freeze

        let idx = [7usize, 1, 9, 3];
        sub.set_minibatch(&idx);
        let u = sub.value_and_grad(&z, &mut g);

        let mut fresh_model = SubsampledLogistic::new(rows, bsz);
        fresh_model.load_rows(&idx);
        let mut fresh = compile(fresh_model, 0).unwrap();
        let mut gf = vec![0.0; dim];
        let uf = fresh.value_and_grad(&z, &mut gf);
        assert_eq!(u.to_bits(), uf.to_bits());
        for i in 0..dim {
            assert_eq!(g[i].to_bits(), gf[i].to_bits(), "grad[{i}]");
        }
    }

    /// The N/B scale correction: a minibatch potential with scale N/B
    /// equals prior + (N/B) * minibatch likelihood, checked against a
    /// hand-assembled combination of plain compiled models.
    #[test]
    fn scale_correction_is_n_over_b() {
        let (n, d, bsz) = (8, 2, 2);
        let rows = small_rows(n, d);
        let idx = [5usize, 2];
        let mut sub_model = SubsampledLogistic::new(rows.clone(), bsz);
        sub_model.load_rows(&idx);
        let mut sub = compile(sub_model, 0).unwrap();
        let dim = sub.dim();
        let z = vec![0.3; dim];
        let mut g = vec![0.0; dim];
        let u_sub = sub.value_and_grad(&z, &mut g);

        // plain model on exactly the minibatch rows (scale 1)
        let xb: Vec<f64> = idx
            .iter()
            .flat_map(|&i| rows.x[i * d..(i + 1) * d].to_vec())
            .collect();
        let yb: Vec<f64> = idx.iter().map(|&i| rows.y[i]).collect();
        let mut mini = compile(
            LogisticModel {
                x: xb,
                y: yb,
                n: bsz,
                d,
            },
            0,
        )
        .unwrap();
        // prior-only: a model with zero observations is rejected by
        // the compiler, so recover the prior from two mini evaluations
        // is not possible either; instead use the identity
        //   U_sub = prior + (N/B) lik_mini
        //   U_mini = prior + lik_mini
        // => U_sub - U_mini = (N/B - 1) lik_mini, with lik_mini
        // recovered from a second model holding the batch twice:
        //   U_twice = prior + 2 lik_mini
        let xb2: Vec<f64> = idx
            .iter()
            .chain(idx.iter())
            .flat_map(|&i| rows.x[i * d..(i + 1) * d].to_vec())
            .collect();
        let yb2: Vec<f64> = idx.iter().chain(idx.iter()).map(|&i| rows.y[i]).collect();
        let mut twice = compile(
            LogisticModel {
                x: xb2,
                y: yb2,
                n: 2 * bsz,
                d,
            },
            0,
        )
        .unwrap();
        let mut gm = vec![0.0; dim];
        let u_mini = mini.value_and_grad(&z, &mut gm);
        let u_twice = twice.value_and_grad(&z, &mut gm);
        let lik = u_twice - u_mini; // -(lik_mini) in potential sign
        let scale = n as f64 / bsz as f64;
        let expect = u_mini + (scale - 1.0) * lik;
        assert!(
            (u_sub - expect).abs() < 1e-9,
            "{u_sub} vs {expect} (scale {scale})"
        );
    }
}
