//! The compiler's evaluation pass: replay the program against the
//! reusable autodiff [`Tape`], turning it into a
//! [`crate::mcmc::Potential`] the NUTS engines can sample.
//!
//! # Record once, replay many
//!
//! Compiled models have **static structure** (the site sequence cannot
//! depend on sampled values — violated structure panics), so the tape
//! recorded on the *first* evaluation is the tape of *every*
//! evaluation.  [`CompiledModel`] therefore records once and freezes:
//!
//! 1. **First evaluation** — replay the program under the tape
//!    interpreter (`TapeCtx`): each latent site reads its span, applies
//!    its [`SiteTransform`] bijection (log-|det J| recorded as an extra
//!    log-density term) and contributes its prior log-prob; vectorized
//!    observation sites become *fused composite nodes* recorded through
//!    the tape's replayable builders (the Stan math-library pattern).
//!    The finished tape is then frozen into a
//!    [`crate::autodiff::TapeProgram`].
//! 2. **Every later evaluation** — `forward`/`backward` sweeps over the
//!    frozen flat op stream: no `EffModel::run`, no site matching, no
//!    `Alg` dispatch, no node pushing — just arithmetic.  The frozen
//!    kernels are the *same functions* the record path ran, so frozen
//!    results are **bitwise identical** to a fresh replay
//!    (`rust/tests/frozen_tape.rs`), and in debug builds every
//!    [`REPLAY_CHECK_PERIOD`]-th evaluation re-replays the interpreter
//!    path and asserts bitwise agreement (which also re-checks the
//!    static-structure contract).
//!
//! All scratch (tape, frozen program, input list, term list, the
//! model's pooled vectors) lives on the [`CompiledModel`] and is
//! reused, so steady-state evaluations — and therefore steady-state
//! NUTS draws — perform **zero heap allocations**
//! (`rust/tests/alloc_free.rs` enforces this with a counting
//! allocator).

use crate::autodiff::{OptTapeProgram, PlanStats, Tape, TapeProgram, Var};
use crate::compile::layout::{SiteLayout, SiteTransform};
use crate::compile::subsample::{SubsampleRebind, SubsampledModel};
use crate::compile::{pool_take, DistV, EffModel, ProbCtx};
use crate::effects::site_key;
use crate::mcmc::Potential;
use crate::obs::{Recorder, SpanKind, SWEEP_SAMPLE_PERIOD};

/// In debug builds, every N-th frozen evaluation re-runs the
/// interpreter path and asserts the frozen program still agrees
/// bitwise (a cheap continuous audit of the record-once assumption).
pub const REPLAY_CHECK_PERIOD: u64 = 64;

/// A compiled effect-handler program: caches the site layout and every
/// evaluation buffer, and implements [`Potential`] by recording the
/// program on the tape once, then serving all later evaluations from
/// the frozen [`TapeProgram`].  Build one with
/// [`crate::compile::compile`].
pub struct CompiledModel<M: EffModel> {
    model: M,
    layout: SiteLayout,
    tape: Tape,
    /// one input Var per flat unconstrained coordinate
    z_vars: Vec<Var>,
    /// accumulated log-density terms (priors, likelihoods, Jacobians)
    terms: Vec<Var>,
    /// pooled scratch vectors handed to the model via `vec_take`
    pool: Vec<Vec<Var>>,
    /// the frozen program (recorded on the first evaluation)
    program: Option<TapeProgram>,
    /// the optimized execution plan compiled from the frozen program
    /// (built eagerly at freeze time when `opt_enabled`)
    opt: Option<OptTapeProgram>,
    /// false = always interpret (the pre-freeze behaviour, kept for
    /// benchmarking and the bitwise cross-checks)
    frozen_enabled: bool,
    /// false = serve frozen evaluations from the tape interpreter
    /// instead of the optimized plan (kept for benchmarking and the
    /// bitwise cross-checks)
    opt_enabled: bool,
    /// gradient scratch for the debug re-replay audit
    #[cfg(debug_assertions)]
    check_grad: Vec<f64>,
    evals: u64,
    /// flight-recorder handle; times forward/reverse sweeps on a
    /// 1-in-[`SWEEP_SAMPLE_PERIOD`] sample of evaluations (see
    /// [`crate::obs`])
    recorder: Recorder,
}

impl<M: EffModel> CompiledModel<M> {
    pub(crate) fn new(model: M, layout: SiteLayout) -> CompiledModel<M> {
        let dim = layout.dim;
        CompiledModel {
            model,
            layout,
            tape: Tape::new(),
            z_vars: Vec::with_capacity(dim),
            terms: Vec::new(),
            pool: Vec::new(),
            program: None,
            opt: None,
            frozen_enabled: true,
            opt_enabled: true,
            #[cfg(debug_assertions)]
            check_grad: vec![0.0; dim],
            evals: 0,
            recorder: Recorder::global(),
        }
    }

    /// Override the flight recorder captured at construction (tests
    /// inject local registries here; the default is the process
    /// global, which is disabled outside the CLI).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    /// The compiled parameter layout (site spans, transforms, labels).
    pub fn layout(&self) -> &SiteLayout {
        &self.layout
    }

    /// The underlying program.
    pub fn model(&self) -> &M {
        &self.model
    }

    /// Enable/disable the frozen-program fast path (enabled by
    /// default).  Disabling drops any recorded program and re-runs the
    /// tape interpreter on every evaluation — the pre-freeze cost
    /// model, kept so `fugue bench` can measure
    /// `frozen_speedup_vs_replay` and the property tests can compare
    /// the two paths bitwise.
    pub fn set_frozen(&mut self, enabled: bool) {
        self.frozen_enabled = enabled;
        if !enabled {
            self.program = None;
            self.opt = None;
        }
    }

    /// Whether a frozen program has been recorded and is serving
    /// evaluations.
    pub fn is_frozen(&self) -> bool {
        self.program.is_some()
    }

    /// Enable/disable the optimizing tape compiler (enabled by
    /// default).  When enabled, the frozen program is compiled into a
    /// DCE'd, fused, re-slotted [`crate::autodiff::OptTapeProgram`] at
    /// freeze time and all later evaluations run the optimized plan;
    /// when disabled, frozen evaluations fall back to the tape
    /// interpreter.  Both paths are bitwise identical — the switch
    /// exists so `fugue bench` can measure
    /// `opt_speedup_vs_interpreted` and the property tests can compare
    /// the two bitwise.
    pub fn set_optimized(&mut self, enabled: bool) {
        self.opt_enabled = enabled;
        if !enabled {
            self.opt = None;
        } else if self.opt.is_none() {
            if let Some(prog) = self.program.as_ref() {
                self.opt = Some(prog.optimize());
            }
        }
    }

    /// Whether an optimized plan is compiled and serving evaluations.
    pub fn is_optimized(&self) -> bool {
        self.opt.is_some()
    }

    /// Compiler statistics for the optimized plan, if one is built.
    pub fn plan_stats(&self) -> Option<PlanStats> {
        self.opt.as_ref().map(|o| o.stats())
    }

    /// One full interpreter replay: reset the tape, rebuild the graph
    /// by running the model through `TapeCtx`, sweep, and write the
    /// gradient.  Returns the potential value and the output node (for
    /// freezing).
    fn replay(&mut self, z: &[f64], grad: &mut [f64]) -> (f64, Var) {
        let CompiledModel {
            model,
            layout,
            tape,
            z_vars,
            terms,
            pool,
            ..
        } = self;
        assert_eq!(z.len(), layout.dim, "compiled model: dimension mismatch");
        tape.reset();
        z_vars.clear();
        for &zi in z {
            z_vars.push(tape.input(zi));
        }
        terms.clear();
        {
            let mut ctx = TapeCtx {
                tape: &mut *tape,
                layout: &*layout,
                z_vars: z_vars.as_slice(),
                cursor: 0,
                terms: &mut *terms,
                pool: &mut *pool,
                lik_scale: 1.0,
            };
            model.run(&mut ctx);
            assert_eq!(
                ctx.cursor,
                layout.visit.len(),
                "model visited fewer sites than the compile-time trace — compiled models require static structure"
            );
        }
        let logp = tape.sum(&terms[..]);
        let u = tape.neg(logp);
        let uval = tape.value(u);
        let adj = tape.grad(u);
        for (g, v) in grad.iter_mut().zip(z_vars.iter()) {
            *g = adj[v.0 as usize];
        }
        (uval, u)
    }

    /// Debug-only audit: re-replay the interpreter path and assert it
    /// agrees bitwise with the frozen result just served.
    #[cfg(debug_assertions)]
    fn audit_frozen(&mut self, z: &[f64], u: f64, grad: &[f64]) {
        let mut cg = std::mem::take(&mut self.check_grad);
        let (u2, _) = self.replay(z, &mut cg);
        assert!(
            u.to_bits() == u2.to_bits(),
            "frozen program diverged from replay: U {u} vs {u2} — \
             the model's structure or data changed after compilation"
        );
        for (i, (a, b)) in grad.iter().zip(cg.iter()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "frozen program diverged from replay at grad[{i}]: {a} vs {b} — \
                 the model's structure or data changed after compilation"
            );
        }
        self.check_grad = cg;
    }
}

impl<M: EffModel> Potential for CompiledModel<M> {
    fn dim(&self) -> usize {
        self.layout.dim
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.evals += 1;
        if !self.frozen_enabled {
            return self.replay(z, grad).0;
        }
        if self.program.is_none() {
            // record once: the first evaluation both answers the query
            // and leaves the complete graph behind to freeze
            let (u, out) = self.replay(z, grad);
            let prog = self.tape.freeze(out);
            if self.opt_enabled {
                // compile eagerly so steady-state evaluations never
                // allocate — the plan build is absorbed into warmup
                self.opt = Some(prog.optimize());
                if let Some(st) = self.opt.as_ref().map(|o| o.stats()) {
                    self.recorder
                        .record_plan_instrs(st.fwd_instrs as u64, st.bwd_instrs as u64);
                }
            }
            self.program = Some(prog);
            // release builds never interpret again (no periodic audit),
            // so drop the recording buffers — the frozen program holds
            // its own copies; debug builds keep them warm for the audit
            #[cfg(not(debug_assertions))]
            self.tape.clear_and_shrink();
            return u;
        }
        // Sweep timing is *sampled* (1 in SWEEP_SAMPLE_PERIOD evals) so
        // the clock reads stay far under the observability overhead bar
        // even for sub-microsecond potentials.  Pure observation: the
        // arithmetic below is identical whether or not it is timed.
        let rec = self.recorder;
        let timed = rec.enabled() && self.evals % SWEEP_SAMPLE_PERIOD == 0;
        let u = if let Some(opt) = self.opt.as_mut() {
            let fwd = if timed {
                Some(std::time::Instant::now())
            } else {
                None
            };
            let u = opt.forward(z);
            let bwd = fwd.map(|t0| {
                rec.add_span_nanos(SpanKind::ForwardSweep, t0.elapsed().as_nanos() as u64);
                std::time::Instant::now()
            });
            opt.backward();
            opt.input_adjoints(grad);
            if let Some(t0) = bwd {
                rec.add_span_nanos(SpanKind::ReverseSweep, t0.elapsed().as_nanos() as u64);
            }
            u
        } else {
            let prog = self.program.as_mut().expect("frozen program present");
            let fwd = if timed {
                Some(std::time::Instant::now())
            } else {
                None
            };
            let u = prog.forward(z);
            let bwd = fwd.map(|t0| {
                rec.add_span_nanos(SpanKind::ForwardSweep, t0.elapsed().as_nanos() as u64);
                std::time::Instant::now()
            });
            prog.backward();
            prog.input_adjoints(grad);
            if let Some(t0) = bwd {
                rec.add_span_nanos(SpanKind::ReverseSweep, t0.elapsed().as_nanos() as u64);
            }
            u
        };
        #[cfg(debug_assertions)]
        {
            if self.evals % REPLAY_CHECK_PERIOD == 0 {
                self.audit_frozen(z, u, grad);
            }
        }
        u
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

impl<M: SubsampledModel> SubsampleRebind for CompiledModel<M> {
    /// Gather the indexed rows into the model's staging buffers and, if
    /// a frozen program is serving evaluations, rebind its data slots
    /// in place.  Staging and program are updated *together*, so the
    /// debug replay audit (which re-records from staging) keeps
    /// agreeing with the frozen result, and a not-yet-frozen model
    /// simply records its first program from the fresh staging data.
    fn set_minibatch(&mut self, idx: &[usize]) {
        let CompiledModel {
            model,
            program,
            opt,
            ..
        } = self;
        model.load_rows(idx);
        if let Some(prog) = program.as_mut() {
            assert_eq!(
                prog.num_data_slots(),
                model.num_slots(),
                "subsample rebind: slot count mismatch between frozen program and model"
            );
            for s in 0..prog.num_data_slots() {
                prog.rebind_data_slot(s, model.slot_data(s));
            }
        }
        // the optimized plan keeps its own copies of the partial /
        // const arenas and a slot-remap table for re-slotted data
        // nodes, so it rebinds independently but in lockstep
        if let Some(o) = opt.as_mut() {
            assert_eq!(
                o.num_data_slots(),
                model.num_slots(),
                "subsample rebind: slot count mismatch between optimized plan and model"
            );
            for s in 0..o.num_data_slots() {
                o.rebind_data_slot(s, model.slot_data(s));
            }
        }
    }
}

/// The evaluation interpreter: value domain = tape [`Var`]s.  Matches
/// program sites to the compiled layout with a cursor over the recorded
/// visit order plus a pre-hashed key check — no string lookups, no
/// allocation.  Fused observation sites are recorded through the
/// tape's *replayable* composite builders so the finished tape can be
/// frozen.
struct TapeCtx<'a> {
    tape: &'a mut Tape,
    layout: &'a SiteLayout,
    z_vars: &'a [Var],
    cursor: usize,
    terms: &'a mut Vec<Var>,
    pool: &'a mut Vec<Vec<Var>>,
    /// active subsample scale correction (N/B inside a subsample scope,
    /// 1.0 otherwise — a scale of exactly 1.0 records no extra node, so
    /// full-batch subsampled programs are bitwise identical to their
    /// plain counterparts)
    lik_scale: f64,
}

impl TapeCtx<'_> {
    /// Advance the visit cursor to the next site, checking that the
    /// program's structure still matches the compile-time trace.
    fn next_site(&mut self, name: &str, observed: bool, event_len: usize) -> (usize, SiteTransform) {
        let idx = match self.layout.visit.get(self.cursor) {
            Some(&i) => i,
            None => panic!(
                "site '{name}': model visited more sites than the compile-time trace — \
                 compiled models require static structure"
            ),
        };
        self.cursor += 1;
        let site = &self.layout.sites[idx];
        assert!(
            site.key == site_key(name),
            "site '{name}' visited where '{}' was traced — compiled models require static structure",
            site.name
        );
        assert!(
            site.observed == observed,
            "site '{name}': latent/observed role changed since the compile-time trace"
        );
        assert!(
            site.event_len == event_len,
            "site '{name}': event length changed since the compile-time trace ({} -> {event_len})",
            site.event_len
        );
        (site.offset, site.transform)
    }

    /// Push an observation log-density term, applying the active
    /// subsample scale correction (one recorded `Scale` node when
    /// inside a subsample scope, nothing otherwise).
    fn push_obs_term(&mut self, lp: Var) {
        let lp = if self.lik_scale != 1.0 {
            self.tape.scale(lp, self.lik_scale)
        } else {
            lp
        };
        self.terms.push(lp);
    }

    /// Apply the site's constraining bijection to one unconstrained
    /// input, pushing its log-|det J| contribution onto the term list.
    fn constrain(&mut self, u: Var, tr: SiteTransform) -> Var {
        match tr {
            SiteTransform::Identity => u,
            SiteTransform::Exp => {
                let y = self.tape.exp(u);
                self.terms.push(u); // log|d exp(u)/du| = u
                y
            }
            SiteTransform::Interval { low, high } => {
                let s = self.tape.sigmoid(u);
                let scaled = self.tape.scale(s, high - low);
                let y = self.tape.offset(scaled, low);
                let sp = self.tape.softplus(u);
                let nu = self.tape.neg(u);
                let sn = self.tape.softplus(nu);
                let both = self.tape.add(sp, sn);
                let neg = self.tape.neg(both);
                let ladj = self.tape.offset(neg, (high - low).ln());
                self.terms.push(ladj);
                y
            }
        }
    }
}

impl ProbCtx for TapeCtx<'_> {
    type V = Var;
    type A = Tape;

    fn alg(&mut self) -> &mut Tape {
        &mut *self.tape
    }

    fn sample(&mut self, name: &str, d: DistV<Var>) -> Var {
        let (offset, tr) = self.next_site(name, false, 1);
        let u = self.z_vars[offset];
        let y = self.constrain(u, tr);
        let lp = d.log_prob(self.tape, y);
        self.terms.push(lp);
        y
    }

    fn sample_vec(&mut self, name: &str, d: DistV<Var>, n: usize, out: &mut Vec<Var>) {
        let (offset, tr) = self.next_site(name, false, n);
        for j in 0..n {
            let u = self.z_vars[offset + j];
            let y = self.constrain(u, tr);
            let lp = d.log_prob(self.tape, y);
            self.terms.push(lp);
            out.push(y);
        }
    }

    fn observe(&mut self, name: &str, d: DistV<Var>, y: f64) {
        let _ = self.next_site(name, true, 1);
        let x = self.tape.constant(y);
        let lp = d.log_prob(self.tape, x);
        self.push_obs_term(lp);
    }

    fn observe_iid(&mut self, name: &str, d: DistV<Var>, ys: &[f64]) {
        let _ = self.next_site(name, true, ys.len());
        match d {
            DistV::Normal { loc, scale } => {
                let node = self.tape.normal_iid_obs(loc, scale, ys);
                self.push_obs_term(node);
            }
            DistV::BernoulliLogits { logits } => {
                let node = self.tape.bernoulli_logits_iid_obs(logits, ys);
                self.push_obs_term(node);
            }
            _ => {
                // generic fallback: per-element log-probs on the tape.
                // Constants are pushed first as one contiguous run so a
                // subsample data region can register them as a single
                // rebindable node slot; term order (and therefore every
                // bit of the sum and the reverse sweep) is unchanged.
                let mut xs = self.vec_take();
                for &y in ys {
                    let x = self.tape.constant(y);
                    xs.push(x);
                }
                self.tape.register_data_nodes(&xs);
                for i in 0..xs.len() {
                    let lp = d.log_prob(self.tape, xs[i]);
                    self.push_obs_term(lp);
                }
                self.vec_put(xs);
            }
        }
    }

    fn observe_normal(&mut self, name: &str, locs: &[Var], scale: Var, ys: &[f64]) {
        assert_eq!(
            locs.len(),
            ys.len(),
            "site '{name}': locations/observations length mismatch"
        );
        let _ = self.next_site(name, true, ys.len());
        let node = self.tape.normal_plate_obs(locs, scale, ys);
        self.push_obs_term(node);
    }

    fn observe_normal_fixed(&mut self, name: &str, locs: &[Var], sigmas: &[f64], ys: &[f64]) {
        assert_eq!(
            locs.len(),
            ys.len(),
            "site '{name}': locations/observations length mismatch"
        );
        assert_eq!(
            sigmas.len(),
            ys.len(),
            "site '{name}': scales/observations length mismatch"
        );
        let _ = self.next_site(name, true, ys.len());
        let node = self.tape.normal_fixed_plate_obs(locs, sigmas, ys);
        self.push_obs_term(node);
    }

    fn observe_bernoulli_logits(&mut self, name: &str, logits: &[Var], ys: &[f64]) {
        assert_eq!(
            logits.len(),
            ys.len(),
            "site '{name}': logits/observations length mismatch"
        );
        let _ = self.next_site(name, true, ys.len());
        let node = self.tape.bernoulli_logits_plate_obs(logits, ys);
        self.push_obs_term(node);
    }

    fn subsample(&mut self, total: usize, batch: usize) {
        assert!(
            batch > 0 && batch <= total,
            "subsample: need 0 < batch ({batch}) <= total ({total})"
        );
        self.lik_scale = total as f64 / batch as f64;
        self.tape.begin_data_region();
    }

    fn end_subsample(&mut self) {
        self.lik_scale = 1.0;
        self.tape.end_data_region();
    }

    fn dot(&mut self, ws: &[Var], xs: &[f64]) -> Var {
        self.tape.dot_const(ws, xs)
    }

    fn vec_take(&mut self) -> Vec<Var> {
        pool_take(&mut self.pool)
    }

    fn vec_put(&mut self, buf: Vec<Var>) {
        self.pool.push(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::finite_diff;
    use crate::compile::compile;
    use crate::ppl::special::LN_2PI;

    /// mu ~ N(0,1); tau ~ HalfCauchy(2); p ~ Uniform(-1, 2);
    /// y_i ~ N(mu * p, tau)  — exercises all three transforms and the
    /// shared-scale fused Normal plate.
    struct Mixed {
        y: Vec<f64>,
    }

    impl EffModel for Mixed {
        fn run<C: ProbCtx>(&self, c: &mut C) {
            let d = c.normal(0.0, 1.0);
            let mu = c.sample("mu", d);
            let d = c.half_cauchy(2.0);
            let tau = c.sample("tau", d);
            let p = c.sample(
                "p",
                DistV::Uniform {
                    low: -1.0,
                    high: 2.0,
                },
            );
            let mut locs = c.vec_take();
            for _ in 0..self.y.len() {
                locs.push(c.mul(mu, p));
            }
            c.observe_normal("y", &locs, tau, &self.y);
            c.vec_put(locs);
        }
    }

    fn mixed() -> Mixed {
        Mixed {
            y: vec![0.4, -0.9, 1.3, 0.2],
        }
    }

    /// Reference log-joint in plain f64 (transforms + densities spelled
    /// out by hand) for the finite-difference cross-check.
    fn mixed_logp(z: &[f64]) -> f64 {
        use crate::ppl::special::{sigmoid, softplus};
        let mu = z[0];
        // p before tau: sorted sites are mu < p < tau
        let (pu, tu) = (z[1], z[2]);
        let tau = tu.exp();
        let p = -1.0 + 3.0 * sigmoid(pu);
        let mut lp = -0.5 * mu * mu - 0.5 * LN_2PI; // N(0,1)
        lp += tu; // exp ladj
        lp += 3.0f64.ln() - softplus(pu) - softplus(-pu); // interval ladj
        // HalfCauchy(2) on tau
        let zt = tau / 2.0;
        lp += std::f64::consts::LN_2 - std::f64::consts::PI.ln() - 2.0f64.ln()
            - (zt * zt).ln_1p();
        // Uniform(-1,2) on p
        lp += -(3.0f64).ln();
        for &y in &mixed().y {
            let r = (y - mu * p) / tau;
            lp += -0.5 * r * r - tau.ln() - 0.5 * LN_2PI;
        }
        lp
    }

    #[test]
    fn value_and_grad_match_reference_and_fd() {
        let mut pot = compile(mixed(), 0).unwrap();
        assert_eq!(pot.dim(), 3);
        let z = [0.3, -0.7, 0.4];
        let mut g = vec![0.0; 3];
        let u = pot.value_and_grad(&z, &mut g);
        assert!(
            (u + mixed_logp(&z)).abs() < 1e-10,
            "{u} vs {}",
            -mixed_logp(&z)
        );
        let fd = finite_diff(&z, |zz| -mixed_logp(zz), 1e-6);
        for i in 0..3 {
            assert!(
                (g[i] - fd[i]).abs() < 1e-5,
                "grad[{i}]: {} vs {}",
                g[i],
                fd[i]
            );
        }
    }

    #[test]
    fn repeated_evaluations_are_bitwise_stable() {
        let mut pot = compile(mixed(), 0).unwrap();
        let z = [0.3, -0.7, 0.4];
        let mut g0 = vec![0.0; 3];
        let u0 = pot.value_and_grad(&z, &mut g0);
        // perturb scratch with a different point, then re-evaluate
        let mut tmp = vec![0.0; 3];
        let _ = pot.value_and_grad(&[-1.0, 0.2, 2.0], &mut tmp);
        let mut g1 = vec![0.0; 3];
        let u1 = pot.value_and_grad(&z, &mut g1);
        assert_eq!(u0, u1);
        assert_eq!(g0, g1);
    }

    /// The frozen fast path (default) and the interpreter path
    /// (`set_frozen(false)`) must agree bitwise, value and gradient, at
    /// arbitrary points — the record-once contract.
    #[test]
    fn frozen_path_matches_interpreter_path_bitwise() {
        let mut frozen = compile(mixed(), 0).unwrap();
        let mut replay = compile(mixed(), 0).unwrap();
        replay.set_frozen(false);
        let mut gf = vec![0.0; 3];
        let mut gr = vec![0.0; 3];
        let points = [
            [0.3, -0.7, 0.4],
            [-1.5, 2.2, 0.05],
            [4.0, -3.0, 1.7],
            [0.0, 0.0, 0.0],
        ];
        for z in &points {
            let uf = frozen.value_and_grad(z, &mut gf);
            let ur = replay.value_and_grad(z, &mut gr);
            assert_eq!(uf.to_bits(), ur.to_bits(), "value at {z:?}");
            for i in 0..3 {
                assert_eq!(gf[i].to_bits(), gr[i].to_bits(), "grad[{i}] at {z:?}");
            }
        }
        assert!(frozen.is_frozen());
        assert!(!replay.is_frozen());
    }

    #[test]
    fn tape_capacity_stabilizes_after_first_evaluation() {
        let mut pot = compile(mixed(), 0).unwrap();
        let z = [0.1, 0.2, -0.3];
        let mut g = vec![0.0; 3];
        let _ = pot.value_and_grad(&z, &mut g);
        let nodes = pot.tape.node_capacity();
        let arena = pot.tape.arena_capacity();
        for _ in 0..10 {
            let _ = pot.value_and_grad(&z, &mut g);
            assert_eq!(pot.tape.node_capacity(), nodes);
            assert_eq!(pot.tape.arena_capacity(), arena);
        }
    }

    /// Generic-fallback observe_iid (no fused path) against fd.
    struct ExpObs {
        y: Vec<f64>,
    }
    impl EffModel for ExpObs {
        fn run<C: ProbCtx>(&self, c: &mut C) {
            let d = c.half_normal(1.0);
            let rate = c.sample("rate", d);
            c.observe_iid("y", DistV::Exponential { rate }, &self.y);
        }
    }

    #[test]
    fn generic_observe_iid_fallback_matches_fd() {
        let mut pot = compile(
            ExpObs {
                y: vec![0.5, 1.2, 0.1],
            },
            0,
        )
        .unwrap();
        let z = [0.3];
        let mut g = vec![0.0];
        let _ = pot.value_and_grad(&z, &mut g);
        let fd = finite_diff(
            &z,
            |zz| {
                let rate = zz[0].exp();
                let mut lp = -0.5 * rate * rate - 0.5 * LN_2PI + std::f64::consts::LN_2 + zz[0];
                for &y in &[0.5, 1.2, 0.1] {
                    lp += rate.ln() - rate * y;
                }
                -lp
            },
            1e-7,
        );
        assert!((g[0] - fd[0]).abs() < 1e-5, "{} vs {}", g[0], fd[0]);
    }
}
