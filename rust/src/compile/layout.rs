//! The compiler's discovery pass: run the program once over the `f64`
//! algebra with prior draws (a `trace` + `substitute` composition in
//! the paper's vocabulary), record every site, and assign the flat
//! unconstrained parameter layout.
//!
//! # Layout invariant
//!
//! Latent sites are packed in **sorted site-name order** — the JAX
//! `ravel_pytree` convention the whole repo shares (see
//! `ARCHITECTURE.md`): the logistic model's flat vector is
//! `[b, m_0..m_{D-1}]` because `"b" < "m"`.  Observed sites occupy no
//! span.  Every site also remembers the program *visit order*, which
//! the evaluation pass uses to replay the program without any string
//! lookups (an O(1) cursor + pre-hashed key check per site).

use anyhow::{anyhow, bail, Result};

use crate::autodiff::F64Alg;
use crate::compile::{pool_take, DistV, EffModel, ProbCtx};
use crate::effects::site_key;
use crate::ppl::dist::Support;
use crate::ppl::special::sigmoid;
use crate::rng::Rng;
use crate::runtime::ParamSpan;

/// Unconstraining bijection of one latent site (applied elementwise).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SiteTransform {
    /// Real support: identity, no Jacobian term.
    Identity,
    /// Positive support: `y = exp(u)`, `log|J| = u`.
    Exp,
    /// Bounded support: `y = low + (high-low)·σ(u)`,
    /// `log|J| = ln(high-low) - softplus(u) - softplus(-u)`.
    Interval { low: f64, high: f64 },
}

impl SiteTransform {
    fn for_latent(support: Support, interval: Option<(f64, f64)>) -> Result<SiteTransform> {
        Ok(match support {
            Support::Real => SiteTransform::Identity,
            Support::Positive => SiteTransform::Exp,
            Support::UnitInterval => {
                let (low, high) = interval.unwrap_or((0.0, 1.0));
                SiteTransform::Interval { low, high }
            }
            Support::Simplex => {
                bail!("simplex-supported latent sites are not compilable yet")
            }
            Support::Discrete => {
                bail!("discrete latent sites cannot be sampled by NUTS (marginalize or observe them)")
            }
        })
    }

    /// Map one unconstrained coordinate onto the site's support (plain
    /// `f64`; used for reporting draws in the constrained space).
    pub fn constrain(&self, u: f64) -> f64 {
        match *self {
            SiteTransform::Identity => u,
            SiteTransform::Exp => u.exp(),
            SiteTransform::Interval { low, high } => low + (high - low) * sigmoid(u),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SiteTransform::Identity => "real",
            SiteTransform::Exp => "positive",
            SiteTransform::Interval { .. } => "interval",
        }
    }
}

/// One site discovered by the trace pass.
#[derive(Debug, Clone)]
pub struct SiteSpec {
    pub name: String,
    /// Pre-hashed [`site_key`] of `name` (the evaluation pass matches
    /// sites by this key — no string hashing in the hot loop).
    pub key: u64,
    /// Number of scalar events at the site.
    pub event_len: usize,
    /// Span start in the flat unconstrained vector (latent sites only).
    pub offset: usize,
    pub observed: bool,
    pub transform: SiteTransform,
}

/// The compiled parameter layout: all sites in sorted-name order plus
/// the program visit order and the total unconstrained dimension.
#[derive(Debug, Clone)]
pub struct SiteLayout {
    /// All sites, sorted by name (the `[b, m...]` invariant).
    pub sites: Vec<SiteSpec>,
    /// Program visit order → index into [`SiteLayout::sites`].
    pub visit: Vec<usize>,
    /// Total unconstrained dimension (sum of latent spans).
    pub dim: usize,
}

impl SiteLayout {
    /// Run the discovery pass over `model` and build its layout.
    pub fn trace<M: EffModel>(model: &M, seed: u64) -> Result<SiteLayout> {
        let mut ctx = TraceCtx::new(seed);
        model.run(&mut ctx);
        SiteLayout::build(ctx.recs)
    }

    fn build(recs: Vec<TraceRec>) -> Result<SiteLayout> {
        let mut order: Vec<usize> = (0..recs.len()).collect();
        order.sort_by(|&a, &b| recs[a].name.cmp(&recs[b].name));
        for w in order.windows(2) {
            if recs[w[0]].name == recs[w[1]].name {
                bail!("duplicate site '{}'", recs[w[0]].name);
            }
        }
        let mut sites = Vec::with_capacity(recs.len());
        let mut visit = vec![0usize; recs.len()];
        let mut dim = 0usize;
        for (pos, &ri) in order.iter().enumerate() {
            let r = &recs[ri];
            let transform = if r.observed {
                SiteTransform::Identity
            } else {
                SiteTransform::for_latent(r.support, r.interval)
                    .map_err(|e| anyhow!("site '{}': {e}", r.name))?
            };
            let offset = if r.observed {
                0
            } else {
                let o = dim;
                dim += r.event_len;
                o
            };
            visit[ri] = pos;
            sites.push(SiteSpec {
                name: r.name.clone(),
                key: r.key,
                event_len: r.event_len,
                offset,
                observed: r.observed,
                transform,
            });
        }
        if dim == 0 {
            bail!("model has no latent sites (nothing for NUTS to sample)");
        }
        Ok(SiteLayout { sites, visit, dim })
    }

    /// Latent-site spans in flat order, as manifest-style
    /// [`ParamSpan`]s (labels for posterior summaries).
    pub fn param_spans(&self) -> Vec<ParamSpan> {
        self.sites
            .iter()
            .filter(|s| !s.observed)
            .map(|s| ParamSpan {
                site: s.name.clone(),
                offset: s.offset,
                size: s.event_len,
                unconstrained_shape: vec![s.event_len],
                constrained_shape: vec![s.event_len],
                support: s.transform.name().to_string(),
            })
            .collect()
    }

    /// Apply each latent site's constraining transform elementwise to a
    /// flat unconstrained row (to report draws in the constrained
    /// space).
    pub fn constrain_row(&self, row: &mut [f64]) {
        assert_eq!(row.len(), self.dim, "constrain_row: dimension mismatch");
        for s in self.sites.iter().filter(|s| !s.observed) {
            for u in &mut row[s.offset..s.offset + s.event_len] {
                *u = s.transform.constrain(*u);
            }
        }
    }

    /// The latent site named `name`, if any.
    pub fn latent(&self, name: &str) -> Option<&SiteSpec> {
        self.sites.iter().find(|s| !s.observed && s.name == name)
    }
}

/// One record of the discovery pass, in program visit order.
pub(crate) struct TraceRec {
    name: String,
    key: u64,
    event_len: usize,
    observed: bool,
    support: Support,
    interval: Option<(f64, f64)>,
}

/// The discovery interpreter: `f64` algebra, prior draws for latent
/// values (their numeric values are discarded — only the site metadata
/// survives into the layout).
pub(crate) struct TraceCtx {
    alg: F64Alg,
    rng: Rng,
    pool: Vec<Vec<f64>>,
    pub(crate) recs: Vec<TraceRec>,
}

impl TraceCtx {
    pub(crate) fn new(seed: u64) -> TraceCtx {
        TraceCtx {
            alg: F64Alg,
            rng: Rng::new(seed),
            pool: Vec::new(),
            recs: Vec::new(),
        }
    }

    fn record_latent(&mut self, name: &str, d: &DistV<f64>, event_len: usize) {
        self.recs.push(TraceRec {
            name: name.to_string(),
            key: site_key(name),
            event_len,
            observed: false,
            support: d.support(),
            interval: d.interval(),
        });
    }

    fn record_obs(&mut self, name: &str, event_len: usize) {
        self.recs.push(TraceRec {
            name: name.to_string(),
            key: site_key(name),
            event_len,
            observed: true,
            support: Support::Real,
            interval: None,
        });
    }

    fn draw(&mut self, d: &DistV<f64>) -> f64 {
        let mut sub = self.rng.split(0);
        d.to_dist().sample(&mut sub)[0]
    }
}

impl ProbCtx for TraceCtx {
    type V = f64;
    type A = F64Alg;

    fn alg(&mut self) -> &mut F64Alg {
        &mut self.alg
    }

    fn sample(&mut self, name: &str, d: DistV<f64>) -> f64 {
        self.record_latent(name, &d, 1);
        self.draw(&d)
    }

    fn sample_vec(&mut self, name: &str, d: DistV<f64>, n: usize, out: &mut Vec<f64>) {
        self.record_latent(name, &d, n);
        for _ in 0..n {
            let v = self.draw(&d);
            out.push(v);
        }
    }

    fn observe(&mut self, name: &str, _d: DistV<f64>, _y: f64) {
        self.record_obs(name, 1);
    }

    fn observe_iid(&mut self, name: &str, _d: DistV<f64>, ys: &[f64]) {
        self.record_obs(name, ys.len());
    }

    fn observe_normal(&mut self, name: &str, locs: &[f64], _scale: f64, ys: &[f64]) {
        assert_eq!(
            locs.len(),
            ys.len(),
            "site '{name}': locations/observations length mismatch"
        );
        self.record_obs(name, ys.len());
    }

    fn observe_normal_fixed(&mut self, name: &str, locs: &[f64], sigmas: &[f64], ys: &[f64]) {
        assert_eq!(
            locs.len(),
            ys.len(),
            "site '{name}': locations/observations length mismatch"
        );
        assert_eq!(
            sigmas.len(),
            ys.len(),
            "site '{name}': scales/observations length mismatch"
        );
        self.record_obs(name, ys.len());
    }

    fn observe_bernoulli_logits(&mut self, name: &str, logits: &[f64], ys: &[f64]) {
        assert_eq!(
            logits.len(),
            ys.len(),
            "site '{name}': logits/observations length mismatch"
        );
        self.record_obs(name, ys.len());
    }

    fn vec_take(&mut self) -> Vec<f64> {
        pool_take(&mut self.pool)
    }

    fn vec_put(&mut self, buf: Vec<f64>) {
        self.pool.push(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::{EightSchools, Horseshoe, LogisticModel};
    use crate::data;

    #[test]
    fn eight_schools_layout_is_sorted() {
        let layout = SiteLayout::trace(&EightSchools::classic(), 0).unwrap();
        // sorted names: mu < tau < theta (y is observed, no span)
        assert_eq!(layout.dim, 10);
        let mu = layout.latent("mu").unwrap();
        let tau = layout.latent("tau").unwrap();
        let theta = layout.latent("theta").unwrap();
        assert_eq!((mu.offset, mu.event_len), (0, 1));
        assert_eq!((tau.offset, tau.event_len), (1, 1));
        assert_eq!((theta.offset, theta.event_len), (2, 8));
        assert_eq!(mu.transform, SiteTransform::Identity);
        assert_eq!(tau.transform, SiteTransform::Exp);
        assert!(layout.latent("y").is_none());
    }

    #[test]
    fn logistic_layout_matches_ravel_pytree_invariant() {
        let d = data::make_covtype_like(0, 20, 3);
        let m = LogisticModel {
            x: d.x,
            y: d.y,
            n: 20,
            d: 3,
        };
        let layout = SiteLayout::trace(&m, 0).unwrap();
        // "b" < "m": intercept first, then weights — [b, m...]
        assert_eq!(layout.dim, 4);
        assert_eq!(layout.latent("b").unwrap().offset, 0);
        assert_eq!(layout.latent("m").unwrap().offset, 1);
    }

    #[test]
    fn horseshoe_layout() {
        let m = Horseshoe::synthetic(0, 12, 4, 2);
        let layout = SiteLayout::trace(&m, 0).unwrap();
        // lambda(4) < sigma < tau < z(4)
        assert_eq!(layout.dim, 10);
        assert_eq!(layout.latent("lambda").unwrap().offset, 0);
        assert_eq!(layout.latent("sigma").unwrap().offset, 4);
        assert_eq!(layout.latent("tau").unwrap().offset, 5);
        assert_eq!(layout.latent("z").unwrap().offset, 6);
        let spans = layout.param_spans();
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].site, "lambda");
        assert_eq!(spans[0].support, "positive");
    }

    struct DupSite;
    impl EffModel for DupSite {
        fn run<C: ProbCtx>(&self, c: &mut C) {
            let d = c.normal(0.0, 1.0);
            c.sample("x", d);
            let d = c.normal(0.0, 1.0);
            c.sample("x", d);
        }
    }

    #[test]
    fn duplicate_sites_are_rejected() {
        let err = SiteLayout::trace(&DupSite, 0).unwrap_err();
        assert!(err.to_string().contains("duplicate site"));
    }

    struct DiscreteLatent;
    impl EffModel for DiscreteLatent {
        fn run<C: ProbCtx>(&self, c: &mut C) {
            let l = c.lit(0.3);
            c.sample("k", DistV::BernoulliLogits { logits: l });
        }
    }

    #[test]
    fn discrete_latents_are_rejected() {
        let err = SiteLayout::trace(&DiscreteLatent, 0).unwrap_err();
        assert!(err.to_string().contains("discrete"), "{err}");
    }

    struct NoLatents;
    impl EffModel for NoLatents {
        fn run<C: ProbCtx>(&self, c: &mut C) {
            let d = c.normal(0.0, 1.0);
            c.observe("y", d, 0.5);
        }
    }

    #[test]
    fn models_without_latents_are_rejected() {
        let err = SiteLayout::trace(&NoLatents, 0).unwrap_err();
        assert!(err.to_string().contains("no latent sites"));
    }

    #[test]
    fn constrain_row_applies_transforms() {
        let layout = SiteLayout::trace(&EightSchools::classic(), 0).unwrap();
        let mut row = vec![0.5; 10];
        layout.constrain_row(&mut row);
        assert_eq!(row[0], 0.5); // mu: identity
        assert!((row[1] - 0.5f64.exp()).abs() < 1e-15); // tau: exp
        assert_eq!(row[2], 0.5); // theta: identity
    }
}
