//! The compiler's **batched** evaluation pass: replay an effect-handler
//! program once on a multi-lane [`BatchTape`] so K chains' joint
//! log-densities *and* gradients come out of a single fused pass — the
//! potential side of the vectorized chain engine
//! ([`crate::mcmc::batch_nuts`]).
//!
//! `BatchTapeCtx` is the lane-parallel twin of the scalar `TapeCtx`
//! ([`crate::compile::potential`]): the same site-cursor replay, the
//! same constraining bijections, the same fused likelihood composites —
//! except every tape node now carries `lanes` primal values and the
//! fused composites carry per-lane partials.  Each lane is an
//! independent scalar evaluation with identical operation order and
//! branch structure, so lane `k` of [`BatchedCompiledModel`] is
//! **bitwise identical** to a scalar [`crate::compile::CompiledModel`]
//! evaluation at lane `k`'s coordinates (pinned by this module's tests
//! and `rust/tests/chain_methods.rs`).
//!
//! # Record once, replay many
//!
//! Like the scalar [`crate::compile::CompiledModel`], the batched model
//! records its (static-structure) program on the **first** evaluation
//! and freezes the multi-lane tape into a
//! [`crate::autodiff::BatchTapeProgram`]; every later evaluation is a
//! lane-minor forward/backward sweep over the frozen flat op stream —
//! no model interpretation, no site matching, no node pushing, with
//! contiguous per-lane inner loops the autovectorizer turns into SIMD.
//! Frozen results are bitwise identical to the interpreter path (same
//! kernel functions), and debug builds re-replay every
//! [`crate::compile::potential::REPLAY_CHECK_PERIOD`]-th evaluation to
//! assert it.
//!
//! All scratch (tape, frozen program, input list, term list, pooled
//! vectors) lives on the [`BatchedCompiledModel`] and is reused, so
//! steady-state batched evaluations — and therefore steady-state
//! vectorized NUTS draws — perform **zero heap allocations**
//! (`rust/tests/alloc_free.rs`).

use anyhow::Result;

use crate::autodiff::{BatchTape, BatchTapeProgram, OptBatchTapeProgram, PlanStats, Var};
use crate::compile::layout::{SiteLayout, SiteTransform};
#[cfg(debug_assertions)]
use crate::compile::potential::REPLAY_CHECK_PERIOD;
use crate::compile::subsample::{SubsampleRebind, SubsampledModel};
use crate::compile::{pool_take, DistV, EffModel, ProbCtx};
use crate::effects::site_key;
use crate::mcmc::{tile_partition, BatchPotential, TiledBatchPotential};

/// A compiled effect-handler program evaluated over `lanes` chains at
/// once: caches the site layout and every evaluation buffer, records
/// the program on the multi-lane [`BatchTape`] once, and serves all
/// later [`BatchPotential`] calls from the frozen
/// [`BatchTapeProgram`].  Build one with [`compile_batched`].
pub struct BatchedCompiledModel<M: EffModel> {
    model: M,
    layout: SiteLayout,
    lanes: usize,
    tape: BatchTape,
    /// one input Var per flat unconstrained coordinate (all lanes)
    z_vars: Vec<Var>,
    /// accumulated log-density terms (priors, likelihoods, Jacobians)
    terms: Vec<Var>,
    /// pooled scratch vectors handed to the model via `vec_take`
    pool: Vec<Vec<Var>>,
    /// the frozen program (recorded on the first evaluation)
    program: Option<BatchTapeProgram>,
    /// the optimized execution plan compiled from the frozen program
    /// (built eagerly at freeze time when `opt_enabled`)
    opt: Option<OptBatchTapeProgram>,
    /// false = always interpret (benchmark / cross-check mode)
    frozen_enabled: bool,
    /// false = serve frozen evaluations from the interpreter instead
    /// of the optimized plan (benchmark / cross-check mode)
    opt_enabled: bool,
    /// scratch for the debug re-replay audit
    #[cfg(debug_assertions)]
    check_u: Vec<f64>,
    #[cfg(debug_assertions)]
    check_grad: Vec<f64>,
    evals: u64,
}

impl<M: EffModel> BatchedCompiledModel<M> {
    pub(crate) fn new(model: M, layout: SiteLayout, lanes: usize) -> BatchedCompiledModel<M> {
        let dim = layout.dim;
        BatchedCompiledModel {
            model,
            layout,
            lanes,
            tape: BatchTape::new(lanes),
            z_vars: Vec::with_capacity(dim),
            terms: Vec::new(),
            pool: Vec::new(),
            program: None,
            opt: None,
            frozen_enabled: true,
            opt_enabled: true,
            #[cfg(debug_assertions)]
            check_u: vec![0.0; lanes],
            #[cfg(debug_assertions)]
            check_grad: vec![0.0; dim * lanes],
            evals: 0,
        }
    }

    /// The compiled parameter layout (site spans, transforms, labels).
    pub fn layout(&self) -> &SiteLayout {
        &self.layout
    }

    /// The underlying program.
    pub fn model(&self) -> &M {
        &self.model
    }

    /// Enable/disable the frozen-program fast path (enabled by
    /// default); see [`crate::compile::CompiledModel::set_frozen`].
    pub fn set_frozen(&mut self, enabled: bool) {
        self.frozen_enabled = enabled;
        if !enabled {
            self.program = None;
            self.opt = None;
        }
    }

    /// Whether a frozen program has been recorded and is serving
    /// evaluations.
    pub fn is_frozen(&self) -> bool {
        self.program.is_some()
    }

    /// Enable/disable the optimizing tape compiler (enabled by
    /// default); see [`crate::compile::CompiledModel::set_optimized`].
    pub fn set_optimized(&mut self, enabled: bool) {
        self.opt_enabled = enabled;
        if !enabled {
            self.opt = None;
        } else if self.opt.is_none() {
            if let Some(prog) = self.program.as_ref() {
                self.opt = Some(prog.optimize());
            }
        }
    }

    /// Whether an optimized plan is compiled and serving evaluations.
    pub fn is_optimized(&self) -> bool {
        self.opt.is_some()
    }

    /// Compiler statistics for the optimized plan, if one is built.
    pub fn plan_stats(&self) -> Option<PlanStats> {
        self.opt.as_ref().map(|o| o.stats())
    }

    /// One full interpreter replay on the multi-lane tape.  Returns the
    /// output node (for freezing).
    fn replay(&mut self, z: &[f64], u: &mut [f64], grad: &mut [f64]) -> Var {
        let BatchedCompiledModel {
            model,
            layout,
            lanes,
            tape,
            z_vars,
            terms,
            pool,
            ..
        } = self;
        let l = *lanes;
        let dim = layout.dim;
        assert_eq!(z.len(), dim * l, "batched model: z must be dim x lanes");
        assert_eq!(u.len(), l, "batched model: u must have one slot per lane");
        assert_eq!(grad.len(), dim * l, "batched model: grad must be dim x lanes");
        tape.reset();
        z_vars.clear();
        for i in 0..dim {
            z_vars.push(tape.input(&z[i * l..(i + 1) * l]));
        }
        terms.clear();
        {
            let mut ctx = BatchTapeCtx {
                tape: &mut *tape,
                layout: &*layout,
                z_vars: z_vars.as_slice(),
                cursor: 0,
                terms: &mut *terms,
                pool: &mut *pool,
                lik_scale: 1.0,
            };
            model.run(&mut ctx);
            assert_eq!(
                ctx.cursor,
                layout.visit.len(),
                "model visited fewer sites than the compile-time trace — compiled models require static structure"
            );
        }
        let logp = tape.sum(&terms[..]);
        let un = tape.neg(logp);
        u.copy_from_slice(tape.lane_values(un));
        let adj = tape.grad(un);
        for (i, v) in z_vars.iter().enumerate() {
            let s = v.0 as usize * l;
            grad[i * l..(i + 1) * l].copy_from_slice(&adj[s..s + l]);
        }
        un
    }

    /// Debug-only audit: re-replay the interpreter path and assert it
    /// agrees bitwise with the frozen result just served.
    #[cfg(debug_assertions)]
    fn audit_frozen(&mut self, z: &[f64], u: &[f64], grad: &[f64]) {
        let mut cu = std::mem::take(&mut self.check_u);
        let mut cg = std::mem::take(&mut self.check_grad);
        let _ = self.replay(z, &mut cu, &mut cg);
        for (k, (a, b)) in u.iter().zip(cu.iter()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "frozen batched program diverged from replay at u[{k}]: {a} vs {b} — \
                 the model's structure or data changed after compilation"
            );
        }
        for (i, (a, b)) in grad.iter().zip(cg.iter()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "frozen batched program diverged from replay at grad[{i}]: {a} vs {b} — \
                 the model's structure or data changed after compilation"
            );
        }
        self.check_u = cu;
        self.check_grad = cg;
    }
}

impl<M: EffModel> BatchPotential for BatchedCompiledModel<M> {
    fn dim(&self) -> usize {
        self.layout.dim
    }

    fn lanes(&self) -> usize {
        self.lanes
    }

    fn value_and_grad_batch(&mut self, z: &[f64], u: &mut [f64], grad: &mut [f64]) {
        self.evals += 1;
        if !self.frozen_enabled {
            let _ = self.replay(z, u, grad);
            return;
        }
        if self.program.is_none() {
            let out = self.replay(z, u, grad);
            let prog = self.tape.freeze(out);
            if self.opt_enabled {
                // compile eagerly so steady-state evaluations never
                // allocate — the plan build is absorbed into warmup
                self.opt = Some(prog.optimize());
                // one-time freeze event: surface the compiled plan's
                // instruction counts to the flight recorder
                if let Some(st) = self.opt.as_ref().map(|o| o.stats()) {
                    crate::obs::Recorder::global()
                        .record_plan_instrs(st.fwd_instrs as u64, st.bwd_instrs as u64);
                }
            }
            self.program = Some(prog);
            // release builds never interpret again (no periodic audit),
            // so drop the recording buffers — the frozen program holds
            // its own copies; debug builds keep them warm for the audit
            #[cfg(not(debug_assertions))]
            self.tape.clear_and_shrink();
            return;
        }
        if let Some(opt) = self.opt.as_mut() {
            opt.forward(z);
            u.copy_from_slice(opt.output_values());
            opt.backward();
            opt.input_adjoints(grad);
        } else {
            let prog = self.program.as_mut().expect("frozen program present");
            prog.forward(z);
            u.copy_from_slice(prog.output_values());
            prog.backward();
            prog.input_adjoints(grad);
        }
        #[cfg(debug_assertions)]
        {
            if self.evals % REPLAY_CHECK_PERIOD == 0 {
                self.audit_frozen(z, u, grad);
            }
        }
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

impl<M: SubsampledModel> SubsampleRebind for BatchedCompiledModel<M> {
    /// Gather the indexed rows into the model's staging buffers and, if
    /// a frozen program is serving evaluations, rebind its lane-shared
    /// data slots in place — the batched mirror of the scalar
    /// [`crate::compile::CompiledModel`] impl (staging and program
    /// updated together, so the debug replay audit stays consistent).
    fn set_minibatch(&mut self, idx: &[usize]) {
        let BatchedCompiledModel {
            model,
            program,
            opt,
            ..
        } = self;
        model.load_rows(idx);
        if let Some(prog) = program.as_mut() {
            assert_eq!(
                prog.num_data_slots(),
                model.num_slots(),
                "subsample rebind: slot count mismatch between frozen program and model"
            );
            for s in 0..prog.num_data_slots() {
                prog.rebind_data_slot(s, model.slot_data(s));
            }
        }
        // the optimized plan keeps its own copies of the shared /
        // const arenas and a slot-remap table for re-slotted data
        // nodes, so it rebinds independently but in lockstep
        if let Some(o) = opt.as_mut() {
            assert_eq!(
                o.num_data_slots(),
                model.num_slots(),
                "subsample rebind: slot count mismatch between optimized plan and model"
            );
            for s in 0..o.num_data_slots() {
                o.rebind_data_slot(s, model.slot_data(s));
            }
        }
    }
}

impl<M: EffModel> TiledBatchPotential<BatchedCompiledModel<M>> {
    /// Enable/disable the optimizing tape compiler on every tile; see
    /// [`crate::compile::CompiledModel::set_optimized`].
    pub fn set_optimized(&mut self, enabled: bool) {
        for tile in self.tiles_mut() {
            tile.set_optimized(enabled);
        }
    }

    /// Whether every tile is serving from an optimized plan.
    pub fn is_optimized(&self) -> bool {
        !self.tiles().is_empty() && self.tiles().iter().all(|t| t.is_optimized())
    }

    /// Compiler statistics from the first tile's plan (all tiles share
    /// one recorded structure, so one plan is representative).
    pub fn plan_stats(&self) -> Option<PlanStats> {
        self.tiles().first().and_then(|t| t.plan_stats())
    }
}

impl<M: EffModel + Clone + Send + SubsampledModel> SubsampleRebind
    for TiledBatchPotential<BatchedCompiledModel<M>>
{
    /// Every tile holds its own clone of the model and its own frozen
    /// program, so the minibatch swap fans out to each tile — the lane
    /// data is shared across lanes within a tile (lane-shared slots),
    /// identical across tiles.
    fn set_minibatch(&mut self, idx: &[usize]) {
        for tile in self.tiles_mut() {
            tile.set_minibatch(idx);
        }
    }
}

/// The batched evaluation interpreter: value domain = multi-lane tape
/// [`Var`]s.  Site matching is the same cursor-over-visit-order scheme
/// as the scalar `TapeCtx` — no string lookups, no allocation.  Fused
/// observation sites are recorded through the batched tape's
/// *replayable* composite builders so the finished tape can be frozen.
struct BatchTapeCtx<'a> {
    tape: &'a mut BatchTape,
    layout: &'a SiteLayout,
    z_vars: &'a [Var],
    cursor: usize,
    terms: &'a mut Vec<Var>,
    pool: &'a mut Vec<Vec<Var>>,
    /// active subsample scale correction (N/B inside a subsample scope,
    /// 1.0 otherwise — a scale of exactly 1.0 records no extra node, so
    /// full-batch subsampled programs are bitwise identical to their
    /// plain counterparts)
    lik_scale: f64,
}

impl BatchTapeCtx<'_> {
    /// Advance the visit cursor to the next site, checking that the
    /// program's structure still matches the compile-time trace.
    fn next_site(&mut self, name: &str, observed: bool, event_len: usize) -> (usize, SiteTransform) {
        let idx = match self.layout.visit.get(self.cursor) {
            Some(&i) => i,
            None => panic!(
                "site '{name}': model visited more sites than the compile-time trace — \
                 compiled models require static structure"
            ),
        };
        self.cursor += 1;
        let site = &self.layout.sites[idx];
        assert!(
            site.key == site_key(name),
            "site '{name}' visited where '{}' was traced — compiled models require static structure",
            site.name
        );
        assert!(
            site.observed == observed,
            "site '{name}': latent/observed role changed since the compile-time trace"
        );
        assert!(
            site.event_len == event_len,
            "site '{name}': event length changed since the compile-time trace ({} -> {event_len})",
            site.event_len
        );
        (site.offset, site.transform)
    }

    /// Push an observation log-density term, applying the active
    /// subsample scale correction (one recorded lane-wise `Scale` node
    /// when inside a subsample scope, nothing otherwise) — the exact
    /// mirror of the scalar `TapeCtx::push_obs_term`.
    fn push_obs_term(&mut self, lp: Var) {
        let lp = if self.lik_scale != 1.0 {
            self.tape.scale(lp, self.lik_scale)
        } else {
            lp
        };
        self.terms.push(lp);
    }

    /// Apply the site's constraining bijection lane-wise (identical op
    /// sequence to the scalar `TapeCtx::constrain`, so every lane's
    /// log-|det J| matches bitwise).
    fn constrain(&mut self, u: Var, tr: SiteTransform) -> Var {
        match tr {
            SiteTransform::Identity => u,
            SiteTransform::Exp => {
                let y = self.tape.exp(u);
                self.terms.push(u); // log|d exp(u)/du| = u
                y
            }
            SiteTransform::Interval { low, high } => {
                let s = self.tape.sigmoid(u);
                let scaled = self.tape.scale(s, high - low);
                let y = self.tape.offset(scaled, low);
                let sp = self.tape.softplus(u);
                let nu = self.tape.neg(u);
                let sn = self.tape.softplus(nu);
                let both = self.tape.add(sp, sn);
                let neg = self.tape.neg(both);
                let ladj = self.tape.offset(neg, (high - low).ln());
                self.terms.push(ladj);
                y
            }
        }
    }
}

impl ProbCtx for BatchTapeCtx<'_> {
    type V = Var;
    type A = BatchTape;

    fn alg(&mut self) -> &mut BatchTape {
        &mut *self.tape
    }

    fn sample(&mut self, name: &str, d: DistV<Var>) -> Var {
        let (offset, tr) = self.next_site(name, false, 1);
        let u = self.z_vars[offset];
        let y = self.constrain(u, tr);
        let lp = d.log_prob(self.tape, y);
        self.terms.push(lp);
        y
    }

    fn sample_vec(&mut self, name: &str, d: DistV<Var>, n: usize, out: &mut Vec<Var>) {
        let (offset, tr) = self.next_site(name, false, n);
        for j in 0..n {
            let u = self.z_vars[offset + j];
            let y = self.constrain(u, tr);
            let lp = d.log_prob(self.tape, y);
            self.terms.push(lp);
            out.push(y);
        }
    }

    fn observe(&mut self, name: &str, d: DistV<Var>, y: f64) {
        let _ = self.next_site(name, true, 1);
        let x = self.tape.constant(y);
        let lp = d.log_prob(self.tape, x);
        self.push_obs_term(lp);
    }

    fn observe_iid(&mut self, name: &str, d: DistV<Var>, ys: &[f64]) {
        let _ = self.next_site(name, true, ys.len());
        match d {
            DistV::Normal { loc, scale } => {
                let node = self.tape.normal_iid_obs(loc, scale, ys);
                self.push_obs_term(node);
            }
            DistV::BernoulliLogits { logits } => {
                let node = self.tape.bernoulli_logits_iid_obs(logits, ys);
                self.push_obs_term(node);
            }
            _ => {
                // generic fallback: per-element log-probs on the tape
                // (lane-wise through the Alg ops).  Constants are
                // pushed first as one contiguous run so a subsample
                // data region can register them as a single rebindable
                // node slot; term order (and therefore every bit of
                // the sum and the reverse sweep) is unchanged.
                let mut xs = self.vec_take();
                for &y in ys {
                    let x = self.tape.constant(y);
                    xs.push(x);
                }
                self.tape.register_data_nodes(&xs);
                for i in 0..xs.len() {
                    let lp = d.log_prob(self.tape, xs[i]);
                    self.push_obs_term(lp);
                }
                self.vec_put(xs);
            }
        }
    }

    fn observe_normal(&mut self, name: &str, locs: &[Var], scale: Var, ys: &[f64]) {
        assert_eq!(
            locs.len(),
            ys.len(),
            "site '{name}': locations/observations length mismatch"
        );
        let _ = self.next_site(name, true, ys.len());
        let node = self.tape.normal_plate_obs(locs, scale, ys);
        self.push_obs_term(node);
    }

    fn observe_normal_fixed(&mut self, name: &str, locs: &[Var], sigmas: &[f64], ys: &[f64]) {
        assert_eq!(
            locs.len(),
            ys.len(),
            "site '{name}': locations/observations length mismatch"
        );
        assert_eq!(
            sigmas.len(),
            ys.len(),
            "site '{name}': scales/observations length mismatch"
        );
        let _ = self.next_site(name, true, ys.len());
        let node = self.tape.normal_fixed_plate_obs(locs, sigmas, ys);
        self.push_obs_term(node);
    }

    fn observe_bernoulli_logits(&mut self, name: &str, logits: &[Var], ys: &[f64]) {
        assert_eq!(
            logits.len(),
            ys.len(),
            "site '{name}': logits/observations length mismatch"
        );
        let _ = self.next_site(name, true, ys.len());
        let node = self.tape.bernoulli_logits_plate_obs(logits, ys);
        self.push_obs_term(node);
    }

    fn subsample(&mut self, total: usize, batch: usize) {
        assert!(
            batch > 0 && batch <= total,
            "subsample: need 0 < batch ({batch}) <= total ({total})"
        );
        self.lik_scale = total as f64 / batch as f64;
        self.tape.begin_data_region();
    }

    fn end_subsample(&mut self) {
        self.lik_scale = 1.0;
        self.tape.end_data_region();
    }

    fn dot(&mut self, ws: &[Var], xs: &[f64]) -> Var {
        self.tape.dot_const(ws, xs)
    }

    fn vec_take(&mut self) -> Vec<Var> {
        pool_take(&mut self.pool)
    }

    fn vec_put(&mut self, buf: Vec<Var>) {
        self.pool.push(buf);
    }
}

/// Compile an effect-handler program into a [`BatchedCompiledModel`]
/// evaluating `lanes` chains per call: runs the discovery pass once
/// (same validation as [`crate::compile::compile`]) and caches the
/// layout plus all batched evaluation scratch.
pub fn compile_batched<M: EffModel>(
    model: M,
    seed: u64,
    lanes: usize,
) -> Result<BatchedCompiledModel<M>> {
    let layout = SiteLayout::trace(&model, seed)?;
    Ok(BatchedCompiledModel::new(model, layout, lanes))
}

/// Build a [`TiledBatchPotential`] over an already-traced layout: one
/// [`BatchedCompiledModel`] per tile of at most `tile` lanes (see
/// [`crate::mcmc::tile_partition`]), each recording and freezing its
/// own narrow program.  Worker threads default to the machine's
/// available parallelism; cap with
/// [`TiledBatchPotential::with_threads`].
pub fn tiled_from_layout<M: EffModel + Clone + Send>(
    model: &M,
    layout: &SiteLayout,
    lanes: usize,
    tile: usize,
) -> TiledBatchPotential<BatchedCompiledModel<M>> {
    let tiles: Vec<BatchedCompiledModel<M>> = tile_partition(lanes, tile)
        .into_iter()
        .map(|w| BatchedCompiledModel::new(model.clone(), layout.clone(), w))
        .collect();
    TiledBatchPotential::new(tiles)
}

/// Compile an effect-handler program into a tiled batched potential
/// spanning `lanes` lanes in tiles of at most `tile` lanes — the
/// massive-lane entry point for K far beyond the SIMD width (thousands
/// of short NUTS chains, hundreds of SVI particles).  Every lane is
/// bitwise-identical to [`compile_batched`] at the same K, which is
/// bitwise-identical to the scalar [`crate::compile::compile`]
/// (`rust/tests/lane_scaling.rs`).
pub fn compile_tiled<M: EffModel + Clone + Send>(
    model: M,
    seed: u64,
    lanes: usize,
    tile: usize,
) -> Result<TiledBatchPotential<BatchedCompiledModel<M>>> {
    let layout = SiteLayout::trace(&model, seed)?;
    Ok(tiled_from_layout(&model, &layout, lanes, tile))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::compile;
    use crate::compile::zoo::{EightSchools, Horseshoe, LogisticModel, NormalMean};
    use crate::data;
    use crate::mcmc::Potential;
    use crate::rng::Rng;

    /// Every lane of the batched evaluation must be bitwise identical
    /// to the scalar compiled model at that lane's coordinates — value
    /// and gradient — across the whole zoo (every fused observe path
    /// plus the generic fallback is exercised by some model).
    fn assert_lanes_match_scalar<M: EffModel + Clone>(model: M, dim: usize, seed: u64) {
        let lanes = 3;
        let mut rng = Rng::new(seed);
        let mut z = vec![0.0; dim * lanes];
        for v in z.iter_mut() {
            *v = 0.4 * rng.normal();
        }

        let mut batched = compile_batched(model.clone(), 0, lanes).unwrap();
        let mut u = vec![0.0; lanes];
        let mut g = vec![0.0; dim * lanes];
        batched.value_and_grad_batch(&z, &mut u, &mut g);

        let mut scalar = compile(model, 0).unwrap();
        let mut zk = vec![0.0; dim];
        let mut gk = vec![0.0; dim];
        for k in 0..lanes {
            for i in 0..dim {
                zk[i] = z[i * lanes + k];
            }
            let uk = scalar.value_and_grad(&zk, &mut gk);
            assert_eq!(u[k], uk, "lane {k} potential");
            for i in 0..dim {
                assert_eq!(g[i * lanes + k], gk[i], "lane {k} grad[{i}]");
            }
        }
    }

    #[test]
    fn eight_schools_lanes_match_scalar_bitwise() {
        assert_lanes_match_scalar(EightSchools::classic(), 10, 1);
    }

    #[test]
    fn logistic_lanes_match_scalar_bitwise() {
        let d = data::make_covtype_like(2, 40, 3);
        let m = LogisticModel {
            x: d.x,
            y: d.y,
            n: 40,
            d: 3,
        };
        assert_lanes_match_scalar(m, 4, 2);
    }

    #[test]
    fn horseshoe_lanes_match_scalar_bitwise() {
        assert_lanes_match_scalar(Horseshoe::synthetic(3, 15, 3, 1), 8, 3);
    }

    #[test]
    fn normal_mean_lanes_match_scalar_bitwise() {
        let m = NormalMean {
            y: vec![0.4, -0.9, 1.3],
            sigma: 1.5,
        };
        assert_lanes_match_scalar(m, 1, 4);
    }

    /// Exercises the generic (non-fused) observe_iid fallback, which
    /// runs lane-wise through the Alg ops.
    #[derive(Clone)]
    struct ExpObs {
        y: Vec<f64>,
    }
    impl EffModel for ExpObs {
        fn run<C: ProbCtx>(&self, c: &mut C) {
            let d = c.half_normal(1.0);
            let rate = c.sample("rate", d);
            c.observe_iid("y", DistV::Exponential { rate }, &self.y);
        }
    }

    #[test]
    fn generic_observe_iid_fallback_lanes_match_scalar_bitwise() {
        assert_lanes_match_scalar(
            ExpObs {
                y: vec![0.5, 1.2, 0.1],
            },
            1,
            5,
        );
    }

    /// The frozen batched fast path and the interpreter path must agree
    /// bitwise at arbitrary points (per lane, values and gradients).
    #[test]
    fn frozen_batched_path_matches_interpreter_path_bitwise() {
        let lanes = 4;
        let mut frozen = compile_batched(EightSchools::classic(), 0, lanes).unwrap();
        let mut replay = compile_batched(EightSchools::classic(), 0, lanes).unwrap();
        replay.set_frozen(false);
        let dim = frozen.dim();
        let mut rng = Rng::new(11);
        let mut uf = vec![0.0; lanes];
        let mut ur = vec![0.0; lanes];
        let mut gf = vec![0.0; dim * lanes];
        let mut gr = vec![0.0; dim * lanes];
        for _ in 0..10 {
            let z: Vec<f64> = (0..dim * lanes).map(|_| 0.6 * rng.normal()).collect();
            frozen.value_and_grad_batch(&z, &mut uf, &mut gf);
            replay.value_and_grad_batch(&z, &mut ur, &mut gr);
            for k in 0..lanes {
                assert_eq!(uf[k].to_bits(), ur[k].to_bits(), "lane {k} potential");
            }
            for i in 0..dim * lanes {
                assert_eq!(gf[i].to_bits(), gr[i].to_bits(), "grad[{i}]");
            }
        }
        assert!(frozen.is_frozen());
        assert!(!replay.is_frozen());
    }

    #[test]
    fn tape_capacity_stabilizes_after_first_batched_evaluation() {
        let mut pot = compile_batched(EightSchools::classic(), 0, 4).unwrap();
        let dim = pot.dim();
        let z = vec![0.1; dim * 4];
        let mut u = vec![0.0; 4];
        let mut g = vec![0.0; dim * 4];
        pot.value_and_grad_batch(&z, &mut u, &mut g);
        let nodes = pot.tape.node_capacity();
        let arena = pot.tape.arena_capacity();
        for _ in 0..10 {
            pot.value_and_grad_batch(&z, &mut u, &mut g);
            assert_eq!(pot.tape.node_capacity(), nodes);
            assert_eq!(pot.tape.arena_capacity(), arena);
        }
    }
}
