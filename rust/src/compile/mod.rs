//! The model compiler: effect-handler programs → differentiable NUTS
//! potentials.
//!
//! This is the bridge that makes the paper's composability claim real
//! on the native side (Phan et al. 2019, §2–3): a model written once
//! with `sample`/`observe` statements is *traced* to discover its latent
//! sites, *conditioned* on its data, *transformed* to unconstrained
//! space, and *differentiated* — producing a [`CompiledModel`] that the
//! zero-allocation iterative NUTS engine ([`crate::mcmc`]) samples
//! without a single hand-written gradient.
//!
//! # Pipeline
//!
//! ```text
//!   EffModel (sample/observe program, generic over ProbCtx)
//!       │
//!       │  1. trace pass  — TraceCtx (f64 algebra, prior draws):
//!       │     discovers sites, shapes, supports; sorts names and
//!       │     assigns the flat unconstrained layout ([b, m...])
//!       ▼
//!   SiteLayout (sorted sites + spans + visit order)
//!       │
//!       │  2. evaluation pass — TapeCtx (tape algebra), per z:
//!       │     z[span] → constrain (exp / affine-sigmoid) + log|det J|
//!       │     replay program; priors + vectorized likelihoods become
//!       │     tape nodes / fused composites
//!       ▼
//!   CompiledModel: Potential   —  U(z) = -log p(z, data), ∇U from the
//!       reusable Tape; scratch buffers cached so steady-state
//!       evaluations are allocation-free
//! ```
//!
//! The same program also runs under the Table-1 handler stack through
//! [`HandlerCtx`], so tracing, conditioning and replay compose with
//! compilation exactly as in the paper.
//!
//! # Example
//!
//! A conjugate-normal model, compiled and differentiated — no gradient
//! code anywhere:
//!
//! ```
//! use fugue::compile::{compile, EffModel, ProbCtx};
//! use fugue::mcmc::Potential;
//! use fugue::ppl::DistV;
//!
//! // mu ~ N(0, 1);  y_i ~ N(mu, 1)  i.i.d.
//! struct Toy {
//!     y: Vec<f64>,
//! }
//!
//! impl EffModel for Toy {
//!     fn run<C: ProbCtx>(&self, c: &mut C) {
//!         let prior = c.normal(0.0, 1.0);
//!         let mu = c.sample("mu", prior);
//!         let one = c.lit(1.0);
//!         c.observe_iid("y", DistV::Normal { loc: mu, scale: one }, &self.y);
//!     }
//! }
//!
//! let mut pot = compile(Toy { y: vec![0.5, -0.2, 0.9] }, 0).unwrap();
//! assert_eq!(pot.dim(), 1);
//! let mut grad = [0.0];
//! let u = pot.value_and_grad(&[0.3], &mut grad);
//! assert!(u.is_finite());
//! // conjugate form: dU/dmu = (n+1) mu - sum(y)
//! assert!((grad[0] - (4.0 * 0.3 - 1.2)).abs() < 1e-12);
//! ```
//!
//! Sampling a compiled model end-to-end:
//! [`crate::coordinator::run_compiled_chains`], the `fugue
//! sample-model` CLI, and the `eight_schools` / `horseshoe` examples.

pub mod batch_potential;
pub mod handler_ctx;
pub mod layout;
pub mod potential;
pub mod subsample;
pub mod zoo;

use anyhow::Result;

use crate::autodiff::Alg;

pub use crate::ppl::distv::DistV;

pub use batch_potential::{compile_batched, compile_tiled, tiled_from_layout, BatchedCompiledModel};
pub use handler_ctx::HandlerCtx;
pub use layout::{SiteLayout, SiteSpec, SiteTransform};
pub use potential::CompiledModel;
pub use subsample::{SubsampleRebind, SubsampledLogistic, SubsampledModel};

/// A probabilistic program, written once and runnable over any
/// [`ProbCtx`] — the `Fn(&mut Interp)` of the effects module, made
/// generic over the value domain so the *same* model code serves the
/// trace pass (`f64`), the handler stack (`f64`, via [`HandlerCtx`])
/// and the differentiable evaluation pass (tape [`crate::autodiff::Var`]s).
///
/// Programs must have **static structure**: the sequence of site
/// statements (names, latent/observed roles, event lengths) may not
/// depend on the sampled values.  The compiler checks this on every
/// evaluation and panics with a descriptive message if violated.
pub trait EffModel {
    fn run<C: ProbCtx>(&self, c: &mut C);
}

/// The interpreter interface a probabilistic program is written
/// against: effectful primitives (`sample`, `observe`, vectorized
/// plate observations) plus the scalar algebra of the underlying value
/// domain.
///
/// The vectorized `observe_*` methods are the compiled counterpart of
/// the [`crate::effects::Plate`] handler: one *site* (and in the tape
/// domain, one fused composite node with precomputed partials) for a
/// whole batch of i.i.d. observations, instead of per-scalar messages.
///
/// `vec_take`/`vec_put` hand out pooled scratch buffers so model code
/// can build per-row quantities (logits, location vectors) without
/// allocating on the steady-state evaluation path — return every
/// buffer you take.
pub trait ProbCtx {
    /// Scalar value handle (`f64` or a tape `Var`).
    type V: Copy + std::fmt::Debug;
    /// The underlying algebra instance.
    type A: Alg<V = Self::V>;

    fn alg(&mut self) -> &mut Self::A;

    /// Scalar latent site: returns the (constrained) site value.
    fn sample(&mut self, name: &str, d: DistV<Self::V>) -> Self::V;

    /// Vectorized latent site: `n` i.i.d. draws from `d` as one site;
    /// values are appended to `out` (take it from [`ProbCtx::vec_take`]).
    fn sample_vec(&mut self, name: &str, d: DistV<Self::V>, n: usize, out: &mut Vec<Self::V>);

    /// Scalar observation site.
    fn observe(&mut self, name: &str, d: DistV<Self::V>, y: f64);

    /// Vectorized i.i.d. observation site with shared parameters (one
    /// fused likelihood node on the tape for `Normal` and
    /// `BernoulliLogits`).
    fn observe_iid(&mut self, name: &str, d: DistV<Self::V>, ys: &[f64]);

    /// Vectorized Normal observations with per-element locations and a
    /// shared (latent) scale: `ys[i] ~ N(locs[i], scale)`.
    fn observe_normal(&mut self, name: &str, locs: &[Self::V], scale: Self::V, ys: &[f64]);

    /// Vectorized Normal observations with per-element locations and
    /// *known* per-element scales: `ys[i] ~ N(locs[i], sigmas[i])`
    /// (the eight-schools likelihood).
    fn observe_normal_fixed(&mut self, name: &str, locs: &[Self::V], sigmas: &[f64], ys: &[f64]);

    /// Vectorized Bernoulli observations with per-element logits (the
    /// GLM fast path: one fused composite, partials `y_i - σ(z_i)`).
    fn observe_bernoulli_logits(&mut self, name: &str, logits: &[Self::V], ys: &[f64]);

    /// Enter a subsampled observation scope — the compiled counterpart
    /// of Pyro's `plate(..., subsample_size=B)`: the observation
    /// statements until [`ProbCtx::end_subsample`] carry a minibatch of
    /// `batch` rows drawn from a population of `total`, and their
    /// log-likelihood terms are scaled by `total / batch` so the joint
    /// log-density stays an **unbiased** estimator of the full-data one
    /// (in expectation over uniformly drawn minibatches).  Tape
    /// contexts additionally open a rebindable data region so a frozen
    /// program can swap the minibatch without re-recording.  Default:
    /// no-op (trace pass).
    fn subsample(&mut self, _total: usize, _batch: usize) {}

    /// Leave the subsampled observation scope opened by
    /// [`ProbCtx::subsample`].  Default: no-op.
    fn end_subsample(&mut self) {}

    /// dot(ws, xs) for constant coefficients `xs` (a single fused node
    /// in the tape domain).
    fn dot(&mut self, ws: &[Self::V], xs: &[f64]) -> Self::V {
        let mut acc = self.lit(0.0);
        for (&w, &x) in ws.iter().zip(xs) {
            let t = self.scale(w, x);
            acc = self.add(acc, t);
        }
        acc
    }

    /// Borrow a cleared scratch buffer from the context's pool.
    fn vec_take(&mut self) -> Vec<Self::V>;
    /// Return a buffer taken with [`ProbCtx::vec_take`] to the pool.
    fn vec_put(&mut self, buf: Vec<Self::V>);

    // -- scalar algebra conveniences (forwarded to the Alg instance) --

    fn lit(&mut self, x: f64) -> Self::V {
        self.alg().lit(x)
    }
    /// Primal (forward) value of `v`.
    fn val(&mut self, v: Self::V) -> f64 {
        self.alg().val(v)
    }
    fn add(&mut self, a: Self::V, b: Self::V) -> Self::V {
        self.alg().add(a, b)
    }
    fn sub(&mut self, a: Self::V, b: Self::V) -> Self::V {
        self.alg().sub(a, b)
    }
    fn mul(&mut self, a: Self::V, b: Self::V) -> Self::V {
        self.alg().mul(a, b)
    }
    fn div(&mut self, a: Self::V, b: Self::V) -> Self::V {
        self.alg().div(a, b)
    }
    fn neg(&mut self, a: Self::V) -> Self::V {
        self.alg().neg(a)
    }
    fn exp(&mut self, a: Self::V) -> Self::V {
        self.alg().exp(a)
    }
    fn ln(&mut self, a: Self::V) -> Self::V {
        self.alg().ln(a)
    }
    fn sqrt(&mut self, a: Self::V) -> Self::V {
        self.alg().sqrt(a)
    }
    fn square(&mut self, a: Self::V) -> Self::V {
        self.alg().square(a)
    }
    fn scale(&mut self, a: Self::V, c: f64) -> Self::V {
        self.alg().scale(a, c)
    }
    fn offset(&mut self, a: Self::V, c: f64) -> Self::V {
        self.alg().offset(a, c)
    }

    // -- distribution constructors with constant parameters --

    fn normal(&mut self, loc: f64, scale: f64) -> DistV<Self::V> {
        let l = self.lit(loc);
        let s = self.lit(scale);
        DistV::Normal { loc: l, scale: s }
    }
    fn half_normal(&mut self, scale: f64) -> DistV<Self::V> {
        let s = self.lit(scale);
        DistV::HalfNormal { scale: s }
    }
    fn half_cauchy(&mut self, scale: f64) -> DistV<Self::V> {
        let s = self.lit(scale);
        DistV::HalfCauchy { scale: s }
    }
    fn exponential(&mut self, rate: f64) -> DistV<Self::V> {
        let r = self.lit(rate);
        DistV::Exponential { rate: r }
    }
    fn log_normal(&mut self, loc: f64, scale: f64) -> DistV<Self::V> {
        let l = self.lit(loc);
        let s = self.lit(scale);
        DistV::LogNormal { loc: l, scale: s }
    }
}

/// Pop a cleared scratch buffer from a `vec_take` pool (capacity
/// preserved — the shared implementation behind every [`ProbCtx`]).
pub(crate) fn pool_take<V>(pool: &mut Vec<Vec<V>>) -> Vec<V> {
    match pool.pop() {
        Some(mut v) => {
            v.clear();
            v
        }
        None => Vec::new(),
    }
}

/// Compile an effect-handler program into a differentiable
/// [`CompiledModel`] (a [`crate::mcmc::Potential`]).
///
/// Runs the trace pass once (prior draws seeded by `seed` — the values
/// are discarded, only sites/shapes/supports matter), validates the
/// model (no discrete or simplex latents, unique site names, at least
/// one latent site) and caches the site layout plus all evaluation
/// scratch.
pub fn compile<M: EffModel>(model: M, seed: u64) -> Result<CompiledModel<M>> {
    let layout = SiteLayout::trace(&model, seed)?;
    Ok(CompiledModel::new(model, layout))
}
