//! Ready-made compiled models: every one of these is *pure*
//! `sample`/`observe` code — no hand-written density, no hand-written
//! gradient — yet samples through the zero-allocation iterative NUTS
//! engine at native speed once compiled.
//!
//! Used by the `fugue sample-model` CLI, the `eight_schools` /
//! `horseshoe` examples, and the golden cross-check tests.

use crate::compile::{DistV, EffModel, ProbCtx};
use crate::rng::Rng;

/// The classic eight-schools hierarchical model (Rubin 1981), in the
/// non-centered parameterization NUTS likes:
///
/// ```text
/// mu ~ N(0, 5);  tau ~ HalfCauchy(5);  theta_j ~ N(0, 1)
/// y_j ~ N(mu + tau * theta_j, sigma_j)      j = 1..8
/// ```
///
/// Flat layout (sorted names): `[mu, tau, theta_0..theta_7]`, dim 10.
#[derive(Debug, Clone)]
pub struct EightSchools {
    pub y: Vec<f64>,
    pub sigma: Vec<f64>,
}

impl EightSchools {
    /// Rubin's original data: treatment effects and standard errors.
    pub fn classic() -> EightSchools {
        EightSchools {
            y: vec![28.0, 8.0, -3.0, 7.0, -1.0, 1.0, 18.0, 12.0],
            sigma: vec![15.0, 10.0, 16.0, 11.0, 9.0, 11.0, 10.0, 18.0],
        }
    }
}

impl EffModel for EightSchools {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let k = self.y.len();
        let prior = c.normal(0.0, 5.0);
        let mu = c.sample("mu", prior);
        let prior = c.half_cauchy(5.0);
        let tau = c.sample("tau", prior);
        let prior = c.normal(0.0, 1.0);
        let mut theta = c.vec_take();
        c.sample_vec("theta", prior, k, &mut theta);
        let mut locs = c.vec_take();
        for &t in theta.iter() {
            let s = c.mul(tau, t);
            let l = c.add(mu, s);
            locs.push(l);
        }
        c.observe_normal_fixed("y", &locs, &self.sigma, &self.y);
        c.vec_put(locs);
        c.vec_put(theta);
    }
}

/// Sparse linear regression with the horseshoe prior (Carvalho,
/// Polson & Scott 2009), non-centered:
///
/// ```text
/// tau ~ HalfCauchy(tau0);  lambda_j ~ HalfCauchy(1);  z_j ~ N(0, 1)
/// sigma ~ HalfNormal(1);   beta_j = tau * lambda_j * z_j
/// y_i ~ N(x_i . beta, sigma)
/// ```
///
/// Flat layout (sorted names): `[lambda_0..lambda_{p-1}, sigma, tau,
/// z_0..z_{p-1}]`, dim 2p + 2.
#[derive(Debug, Clone)]
pub struct Horseshoe {
    /// row-major (n, p)
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub n: usize,
    pub p: usize,
    /// global-shrinkage scale (smaller = sparser)
    pub tau0: f64,
}

impl Horseshoe {
    /// Synthetic sparse-regression dataset: the first `signals`
    /// coefficients are 2.0, the rest exactly zero; noise sd 0.5.
    pub fn synthetic(seed: u64, n: usize, p: usize, signals: usize) -> Horseshoe {
        let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        let x: Vec<f64> = (0..n * p).map(|_| rng.normal()).collect();
        let mut beta = vec![0.0; p];
        for b in beta.iter_mut().take(signals.min(p)) {
            *b = 2.0;
        }
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let xi = &x[i * p..(i + 1) * p];
                let mu: f64 = xi.iter().zip(&beta).map(|(a, b)| a * b).sum();
                mu + 0.5 * rng.normal()
            })
            .collect();
        Horseshoe {
            x,
            y,
            n,
            p,
            tau0: 0.1,
        }
    }
}

impl EffModel for Horseshoe {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let (n, p) = (self.n, self.p);
        let prior = c.half_cauchy(self.tau0);
        let tau = c.sample("tau", prior);
        let prior = c.half_cauchy(1.0);
        let mut lambda = c.vec_take();
        c.sample_vec("lambda", prior, p, &mut lambda);
        let prior = c.normal(0.0, 1.0);
        let mut z = c.vec_take();
        c.sample_vec("z", prior, p, &mut z);
        let prior = c.half_normal(1.0);
        let sigma = c.sample("sigma", prior);
        let mut beta = c.vec_take();
        for j in 0..p {
            let tl = c.mul(tau, lambda[j]);
            let bj = c.mul(tl, z[j]);
            beta.push(bj);
        }
        let mut locs = c.vec_take();
        for i in 0..n {
            let xi = &self.x[i * p..(i + 1) * p];
            let mu = c.dot(&beta, xi);
            locs.push(mu);
        }
        c.observe_normal("y", &locs, sigma, &self.y);
        c.vec_put(locs);
        c.vec_put(beta);
        c.vec_put(z);
        c.vec_put(lambda);
    }
}

/// Bayesian logistic regression, density-identical to the hand-coded
/// [`crate::models::LogisticNative`] (unit-normal priors on intercept
/// `b` and weights `m`, Bernoulli likelihood with logits `X m + b`) —
/// the golden cross-check model proving the compiler reproduces a
/// hand-fused potential to ~1e-12.
///
/// Flat layout (sorted names): `[b, m_0..m_{d-1}]`.
#[derive(Debug, Clone)]
pub struct LogisticModel {
    /// row-major (n, d)
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub n: usize,
    pub d: usize,
}

impl EffModel for LogisticModel {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let prior = c.normal(0.0, 1.0);
        let b = c.sample("b", prior);
        let prior = c.normal(0.0, 1.0);
        let mut m = c.vec_take();
        c.sample_vec("m", prior, self.d, &mut m);
        let mut logits = c.vec_take();
        for i in 0..self.n {
            let xi = &self.x[i * self.d..(i + 1) * self.d];
            let dm = c.dot(&m, xi);
            let zl = c.add(b, dm);
            logits.push(zl);
        }
        c.observe_bernoulli_logits("y", &logits, &self.y);
        c.vec_put(logits);
        c.vec_put(m);
    }
}

/// Neal's funnel (Neal 2003) — the canonical divergence benchmark:
///
/// ```text
/// v ~ N(0, 3);  x_i ~ N(0, exp(v / 2))      i = 1..dim
/// ```
///
/// The neck of the funnel (`v` very negative) forces step sizes far
/// below what the warmup-adapted step can track, so a correct NUTS
/// implementation reports **nonzero divergences** here while staying
/// divergence-free on well-conditioned models — the statistical
/// fingerprint the robustness suite pins
/// (`rust/tests/chaos.rs::funnel_diverges_conjugate_does_not`).
///
/// Flat layout (sorted names): `[v, x_0..x_{dim-1}]`, dim + 1 total.
#[derive(Debug, Clone)]
pub struct NealsFunnel {
    /// Number of `x` coordinates (9 in Neal's original).
    pub dim: usize,
}

impl NealsFunnel {
    /// Neal's original 10-dimensional funnel (one `v`, nine `x`).
    pub fn classic() -> NealsFunnel {
        NealsFunnel { dim: 9 }
    }
}

impl EffModel for NealsFunnel {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let prior = c.normal(0.0, 3.0);
        let v = c.sample("v", prior);
        let half_v = c.scale(v, 0.5);
        let s = c.exp(half_v);
        let zero = c.lit(0.0);
        let mut x = c.vec_take();
        c.sample_vec("x", DistV::Normal { loc: zero, scale: s }, self.dim, &mut x);
        c.vec_put(x);
    }
}

/// A conjugate Normal-Normal toy (known posterior) for statistical
/// smoke tests: `mu ~ N(0, 1); y_i ~ N(mu, sigma)`.
#[derive(Debug, Clone)]
pub struct NormalMean {
    pub y: Vec<f64>,
    pub sigma: f64,
}

impl EffModel for NormalMean {
    fn run<C: ProbCtx>(&self, c: &mut C) {
        let prior = c.normal(0.0, 1.0);
        let mu = c.sample("mu", prior);
        let s = c.lit(self.sigma);
        c.observe_iid("y", DistV::Normal { loc: mu, scale: s }, &self.y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::compile;
    use crate::mcmc::Potential;

    #[test]
    fn zoo_models_compile_and_evaluate() {
        let mut es = compile(EightSchools::classic(), 0).unwrap();
        let mut g = vec![0.0; es.dim()];
        let u = es.value_and_grad(&vec![0.1; es.dim()], &mut g);
        assert!(u.is_finite());
        assert!(g.iter().all(|x| x.is_finite()));

        let mut hs = compile(Horseshoe::synthetic(1, 20, 4, 2), 0).unwrap();
        let mut g = vec![0.0; hs.dim()];
        let u = hs.value_and_grad(&vec![0.05; hs.dim()], &mut g);
        assert!(u.is_finite());
        assert!(g.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn funnel_compiles_and_scale_depends_on_v() {
        let mut pot = compile(NealsFunnel::classic(), 0).unwrap();
        assert_eq!(pot.dim(), 10);
        let mut g = vec![0.0; 10];
        let u = pot.value_and_grad(&vec![0.1; 10], &mut g);
        assert!(u.is_finite());
        assert!(g.iter().all(|x| x.is_finite()));
        // density must couple v and x: dU/dv changes with x
        let mut g2 = vec![0.0; 10];
        let mut z2 = vec![0.1; 10];
        z2[1] = 3.0;
        let _ = pot.value_and_grad(&z2, &mut g2);
        assert!((g[0] - g2[0]).abs() > 1e-9, "funnel decoupled: {} {}", g[0], g2[0]);
    }

    #[test]
    fn normal_mean_posterior_gradient_is_conjugate() {
        // posterior precision 1 + n/s^2; dU/dmu = (1 + n/s^2) mu - sum(y)/s^2
        let y = vec![1.0, 2.0, 3.0];
        let mut pot = compile(NormalMean { y, sigma: 2.0 }, 0).unwrap();
        let mut g = vec![0.0];
        let _ = pot.value_and_grad(&[0.4], &mut g);
        let expect = (1.0 + 3.0 / 4.0) * 0.4 - 6.0 / 4.0;
        assert!((g[0] - expect).abs() < 1e-12, "{} vs {expect}", g[0]);
    }
}
