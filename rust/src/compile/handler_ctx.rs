//! Adapter running an [`crate::compile::EffModel`] under the Table-1
//! handler stack: the same program that compiles to a NUTS potential
//! also traces, conditions, substitutes and replays through
//! [`crate::effects`] — handlers compose with the compiler, which is
//! the paper's point.
//!
//! ```
//! use fugue::compile::{EffModel, HandlerCtx, ProbCtx};
//! use fugue::effects::{Interp, Seed, TraceH};
//! use fugue::ppl::DistV;
//!
//! struct Toy;
//! impl EffModel for Toy {
//!     fn run<C: ProbCtx>(&self, c: &mut C) {
//!         let prior = c.normal(0.0, 1.0);
//!         let mu = c.sample("mu", prior);
//!         let s = c.lit(0.5);
//!         c.observe("y", DistV::Normal { loc: mu, scale: s }, 0.3);
//!     }
//! }
//!
//! let mut s = Seed::new(1);
//! let mut t = TraceH::default();
//! {
//!     let mut interp = Interp::new(vec![&mut s, &mut t]);
//!     let mut ctx = HandlerCtx::new(&mut interp);
//!     Toy.run(&mut ctx);
//! }
//! assert_eq!(t.trace.len(), 2);
//! assert!(t.trace["y"].is_observed);
//! ```

use std::fmt::Write as _;

use crate::autodiff::F64Alg;
use crate::compile::{pool_take, DistV, ProbCtx};
use crate::effects::Interp;
use crate::ppl::dist::Dist;

/// Runs a generic program against an effects-handler [`Interp`] stack
/// (value domain `f64`).  Vectorized sites map onto plate messages;
/// per-element-parameter plates expand to indexed scalar observations
/// (`"name.0"`, `"name.1"`, ...).
pub struct HandlerCtx<'a, 'h> {
    interp: &'a mut Interp<'h>,
    alg: F64Alg,
    pool: Vec<Vec<f64>>,
    name_buf: String,
}

impl<'a, 'h> HandlerCtx<'a, 'h> {
    pub fn new(interp: &'a mut Interp<'h>) -> HandlerCtx<'a, 'h> {
        HandlerCtx {
            interp,
            alg: F64Alg,
            pool: Vec::new(),
            name_buf: String::new(),
        }
    }
}

impl ProbCtx for HandlerCtx<'_, '_> {
    type V = f64;
    type A = F64Alg;

    fn alg(&mut self) -> &mut F64Alg {
        &mut self.alg
    }

    fn sample(&mut self, name: &str, d: DistV<f64>) -> f64 {
        self.interp.sample(name, d.to_dist())[0]
    }

    fn sample_vec(&mut self, name: &str, d: DistV<f64>, n: usize, out: &mut Vec<f64>) {
        let v = self.interp.sample_plate(name, d.to_dist(), n);
        out.extend_from_slice(&v);
    }

    fn observe(&mut self, name: &str, d: DistV<f64>, y: f64) {
        self.interp.observe(name, d.to_dist(), vec![y]);
    }

    fn observe_iid(&mut self, name: &str, d: DistV<f64>, ys: &[f64]) {
        self.interp.observe_plate(name, d.to_dist(), ys);
    }

    fn observe_normal(&mut self, name: &str, locs: &[f64], scale: f64, ys: &[f64]) {
        for (i, (&loc, &y)) in locs.iter().zip(ys).enumerate() {
            self.name_buf.clear();
            let _ = write!(self.name_buf, "{name}.{i}");
            let dist = Dist::Normal { loc, scale };
            self.interp.observe(&self.name_buf, dist, vec![y]);
        }
    }

    fn observe_normal_fixed(&mut self, name: &str, locs: &[f64], sigmas: &[f64], ys: &[f64]) {
        for i in 0..ys.len() {
            self.name_buf.clear();
            let _ = write!(self.name_buf, "{name}.{i}");
            let dist = Dist::Normal {
                loc: locs[i],
                scale: sigmas[i],
            };
            self.interp.observe(&self.name_buf, dist, vec![ys[i]]);
        }
    }

    fn observe_bernoulli_logits(&mut self, name: &str, logits: &[f64], ys: &[f64]) {
        for i in 0..ys.len() {
            self.name_buf.clear();
            let _ = write!(self.name_buf, "{name}.{i}");
            let dist = Dist::BernoulliLogits { logits: logits[i] };
            self.interp.observe(&self.name_buf, dist, vec![ys[i]]);
        }
    }

    fn vec_take(&mut self) -> Vec<f64> {
        pool_take(&mut self.pool)
    }

    fn vec_put(&mut self, buf: Vec<f64>) {
        self.pool.push(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compile::zoo::EightSchools;
    use crate::compile::EffModel;
    use crate::effects::{log_density, Condition, Seed, TraceH};

    #[test]
    fn eight_schools_runs_under_handler_stack() {
        let model = EightSchools::classic();
        let mut s = Seed::new(3);
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut t]);
            let mut ctx = HandlerCtx::new(&mut interp);
            model.run(&mut ctx);
        }
        // mu, tau, theta + 8 per-school observations
        assert_eq!(t.trace.len(), 11);
        assert_eq!(t.trace["theta"].value.len(), 8);
        assert!(t.trace["y.0"].is_observed);
        assert!(log_density(&t.trace).is_finite());
    }

    #[test]
    fn conditioning_composes_with_the_same_program() {
        let model = EightSchools::classic();
        let mut s = Seed::new(3);
        let mut c = Condition::new([("mu".to_string(), vec![1.25])].into_iter().collect());
        let mut t = TraceH::default();
        {
            let mut interp = Interp::new(vec![&mut s, &mut c, &mut t]);
            let mut ctx = HandlerCtx::new(&mut interp);
            model.run(&mut ctx);
        }
        assert_eq!(t.trace["mu"].value, vec![1.25]);
        assert!(t.trace["mu"].is_observed);
    }
}
