//! Table 2a: time (ms) per leapfrog step across framework architectures
//! (E1: HMM, E2: COVTYPE-substitute logistic regression).
//!
//! Paper protocol (Appendix C):
//! * HMM — 1000 warmup + 1000 draws for Stan/NumPyro; Pyro is so slow
//!   it runs 40 draws at fixed eps = 0.1.  We apply the same split:
//!   native/fused get the full budget, stepwise gets 40 draws fixed-eps.
//! * COVTYPE — fixed eps = 0.0015, 40 draws, for every framework.
//!
//! Shape checks (EXPERIMENTS.md): fused << stepwise (orders of magnitude
//! on HMM); the gap narrows on COVTYPE where the matvec dominates;
//! f32 < f64 per step.

use anyhow::Result;

use crate::config::Settings;
use crate::coordinator::{run_chain, NutsOptions};
use crate::harness::builders::{build_sampler, init_z, Backend, Workload};
use crate::runtime::engine::Engine;

pub struct Row {
    pub label: String,
    pub ms_per_leapfrog: f64,
    pub sample_leapfrogs: u64,
    pub dispatches: u64,
    pub draws: usize,
    pub divergences: u64,
}

#[allow(clippy::too_many_arguments)]
fn measure(
    engine: &Engine,
    model: &str,
    backend: Backend,
    dtype: &str,
    warmup: usize,
    samples: usize,
    fixed_eps: Option<f64>,
    settings: &Settings,
) -> Result<Row> {
    let workload = Workload::for_model(engine, model, settings.seed)?;
    let mut sampler = build_sampler(
        engine,
        model,
        backend,
        dtype,
        &workload,
        settings.max_tree_depth,
    )?;
    let dim = sampler.dim();
    let opts = NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        fixed_step_size: fixed_eps,
        adapt_mass: fixed_eps.is_none(),
        target_accept: settings.target_accept,
        init_step_size: 0.1,
        seed: settings.seed,
    };
    let res = run_chain(&mut sampler, &init_z(dim, settings.seed), &opts)?;
    Ok(Row {
        label: format!("{:<24} {dtype}", backend.paper_name()),
        ms_per_leapfrog: res.ms_per_leapfrog(),
        sample_leapfrogs: res.sample_leapfrogs,
        dispatches: sampler.dispatches(),
        draws: samples,
        divergences: res.divergences,
    })
}

/// Stepwise with an emulated Python-dispatch penalty (µs per leapfrog).
fn measure_penalized(
    engine: &Engine,
    model: &str,
    draws: usize,
    fixed_eps: Option<f64>,
    settings: &Settings,
    penalty_us: u64,
) -> Result<Row> {
    use crate::coordinator::{NativeSampler, TreeAlgorithm};
    use crate::harness::builders::PenalizedPotential;
    use crate::runtime::PjrtPotential;

    let workload = Workload::for_model(engine, model, settings.seed)?;
    let name = format!("{model}_potential_and_grad_f32");
    let entry = engine.manifest.get(&name)?;
    let dt = entry.inputs[0].dtype;
    let dim = entry.dim;
    let pot = PenalizedPotential {
        inner: PjrtPotential::new(engine, &name, &workload.tensors(dt)?)?,
        penalty: std::time::Duration::from_micros(penalty_us),
    };
    let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Recursive, settings.max_tree_depth);
    let opts = NutsOptions {
        num_warmup: 0,
        num_samples: draws,
        fixed_step_size: fixed_eps,
        adapt_mass: false,
        target_accept: settings.target_accept,
        init_step_size: 0.1,
        seed: settings.seed,
    };
    let res = run_chain(&mut sampler, &init_z(dim, settings.seed), &opts)?;
    Ok(Row {
        label: format!("stepwise + {}ms py-dispatch (sim) f32", penalty_us as f64 / 1e3),
        ms_per_leapfrog: res.ms_per_leapfrog(),
        sample_leapfrogs: res.sample_leapfrogs,
        dispatches: 0,
        draws,
        divergences: res.divergences,
    })
}

fn has_artifact(engine: &Engine, model: &str, kind: &str, dtype: &str) -> bool {
    engine.manifest.find(model, kind, dtype).is_ok()
}

pub fn run(engine: &Engine, settings: &Settings, model_filter: Option<&str>) -> Result<String> {
    let mut out = String::new();
    out.push_str("Table 2a — time (ms) per leapfrog step\n");
    out.push_str("(paper: Stan 0.53 / Pyro 30.51 / NumPyro-32 0.09 / NumPyro-64 0.15 on HMM;\n");
    out.push_str(" Stan 135.94 / Pyro-CPU 32.76 / NumPyro-32 30.11 / NumPyro-64 71.18 on COVTYPE)\n\n");

    let models: Vec<(&str, usize, usize, Option<f64>, usize)> = vec![
        // (model, paper warmup, paper samples, fixed eps, stepwise draws)
        ("hmm", 1000, 1000, None, 40),
        ("covtype", 0, 40, Some(0.0015), 40),
        ("covtype_small", 0, 40, Some(0.0015), 40),
    ];

    for (model, p_warm, p_samp, fixed_eps, stepwise_draws) in models {
        if let Some(f) = model_filter {
            if f != model && !(f == "covtype" && model == "covtype") {
                if model != f {
                    continue;
                }
            }
        }
        if !has_artifact(engine, model, "nuts_step", "f32")
            && !has_artifact(engine, model, "nuts_step", "f64")
        {
            continue;
        }
        let (warmup, samples) = settings.budget(p_warm, p_samp);
        let warmup = if p_warm == 0 { 0 } else { warmup };
        out.push_str(&format!("== {model} (warmup {warmup}, draws {samples}) ==\n"));
        out.push_str(&format!(
            "{:<30} {:>14} {:>12} {:>11} {:>6}\n",
            "framework", "ms/leapfrog", "leapfrogs", "dispatches", "div"
        ));

        let mut rows: Vec<Row> = Vec::new();
        // native (Stan architecture) runs in f64 like Stan
        match measure(engine, model, Backend::Native, "f64", warmup, samples, fixed_eps, settings)
        {
            Ok(r) => rows.push(Row {
                label: format!("{:<24} f64", Backend::Native.paper_name()),
                ..r
            }),
            Err(e) => out.push_str(&format!("  native failed: {e:#}\n")),
        }
        // stepwise (Pyro architecture): reduced draws, fixed eps (paper
        // fixes eps=0.1 for Pyro's HMM runs)
        let sw_eps = fixed_eps.or(Some(0.1));
        let sw_draws = if settings.quick {
            stepwise_draws.min(10)
        } else {
            stepwise_draws
        };
        if has_artifact(engine, model, "potential_and_grad", "f32") {
            match measure(engine, model, Backend::Stepwise, "f32", 0, sw_draws, sw_eps, settings) {
                Ok(r) => rows.push(r),
                Err(e) => out.push_str(&format!("  stepwise failed: {e:#}\n")),
            }
            // the paper's actual Pyro regime: the same architecture with
            // the 2019 testbed's ~1 ms host-language (Python) overhead
            // per leapfrog simulated explicitly (DESIGN.md §5)
            match measure_penalized(engine, model, sw_draws.min(20), sw_eps, settings, 1_000) {
                Ok(r) => rows.push(r),
                Err(e) => out.push_str(&format!("  stepwise(py-sim) failed: {e:#}\n")),
            }
        }
        // fused (NumPyro architecture), both precisions where lowered
        for dtype in ["f32", "f64"] {
            if has_artifact(engine, model, "nuts_step", dtype) {
                match measure(engine, model, Backend::Fused, dtype, warmup, samples, fixed_eps, settings)
                {
                    Ok(r) => rows.push(r),
                    Err(e) => out.push_str(&format!("  fused {dtype} failed: {e:#}\n")),
                }
            }
        }

        for r in &rows {
            out.push_str(&format!(
                "{:<30} {:>14.4} {:>12} {:>11} {:>6}\n",
                r.label, r.ms_per_leapfrog, r.sample_leapfrogs, r.dispatches, r.divergences
            ));
        }

        // shape checks
        let find = |needle: &str| rows.iter().find(|r| r.label.contains(needle));
        if let (Some(fused), Some(stepwise)) = (
            rows.iter().find(|r| r.label.contains("fused") && r.label.contains("f32")),
            find("stepwise"),
        ) {
            let speedup = stepwise.ms_per_leapfrog / fused.ms_per_leapfrog;
            out.push_str(&format!(
                "  -> fused f32 is {speedup:.1}x faster per leapfrog than stepwise (paper: ~340x HMM, ~1.1x COVTYPE-CPU)\n"
            ));
        }
        out.push('\n');
    }
    Ok(out)
}
