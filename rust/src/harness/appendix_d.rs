//! Appendix D (E6): SVI with the vectorized (vmapped-particle) ELBO on
//! logistic regression — compiled `elbo_and_grad` artifact + native
//! Adam.  Shape check: the ELBO increases and the guide means correlate
//! with the NUTS posterior means.

use anyhow::Result;

use crate::config::Settings;
use crate::coordinator::{run_chain, FusedSampler, NutsOptions};
use crate::harness::builders::{init_z, Workload};
use crate::runtime::engine::Engine;
use crate::runtime::NutsStep;
use crate::svi::run_svi;

pub fn run(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("Appendix D — SVI with vectorized ELBO (E6)\n\n");
    let model = "covtype_small";
    let dtype = "f32";
    let workload = Workload::for_model(engine, model, settings.seed)?;
    let entry = engine.manifest.find(model, "nuts_step", dtype)?;
    let dt = entry.inputs[1].dtype;

    let steps = if settings.quick { 150 } else { 800 };
    let svi = run_svi(
        engine,
        &format!("covtype_elbo_and_grad_{dtype}"),
        &workload.tensors(dt)?,
        steps,
        0.05,
        settings.seed,
    )?;
    let first = svi.elbo_trace.iter().take(10).sum::<f64>() / 10.0;
    let last = svi.elbo_trace.iter().rev().take(10).sum::<f64>() / 10.0;
    out.push_str(&format!(
        "SVI: {} steps in {:.2}s; ELBO {:.1} -> {:.1}\n",
        svi.steps, svi.secs, first, last
    ));

    // compare guide means with a short NUTS posterior
    let step = NutsStep::new(
        engine,
        &format!("{model}_nuts_step_{dtype}"),
        &workload.tensors(dt)?,
    )?;
    let dim = step.dim;
    let mut sampler = FusedSampler::new(step);
    let (warmup, samples) = settings.budget(300, 300);
    let opts = NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        seed: settings.seed,
        ..Default::default()
    };
    let res = run_chain(&mut sampler, &init_z(dim, settings.seed), &opts)?;
    let mut post_mean = vec![0.0; dim];
    for row in res.samples.chunks(dim) {
        for (a, b) in post_mean.iter_mut().zip(row) {
            *a += b;
        }
    }
    for a in post_mean.iter_mut() {
        *a /= samples as f64;
    }

    // guide layout is (m..., b) = model sites in flat order (m, b) while
    // NUTS layout is [b, m...]; align before correlating
    let d = dim - 1;
    let mut guide_aligned = vec![0.0; dim];
    guide_aligned[0] = svi.loc[d];
    guide_aligned[1..].copy_from_slice(&svi.loc[..d]);

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (gm, pm) = (mean(&guide_aligned), mean(&post_mean));
    let mut num = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..dim {
        let a = guide_aligned[i] - gm;
        let b = post_mean[i] - pm;
        num += a * b;
        va += a * a;
        vb += b * b;
    }
    let corr = num / (va.sqrt() * vb.sqrt());
    out.push_str(&format!(
        "corr(guide mean, NUTS posterior mean) = {corr:.3}\n"
    ));
    out.push_str(&format!(
        "\n-> shape check: ELBO improved ({}) and corr > 0.9 ({})\n",
        last > first,
        corr > 0.9
    ));
    Ok(out)
}
