//! Design-choice ablations called out in DESIGN.md:
//!
//! * E7 (`ablate-vmap`, §3.2): K chains per dispatch via the vmapped
//!   artifact vs K sequential dispatches.
//! * E8 (`ablate-tree`, §3.1/Appendix A): iterative vs recursive tree
//!   building over the *same* native potential — the paper claims the
//!   iterative formulation's overhead is "insignificant".

use anyhow::Result;

use crate::config::Settings;
use crate::coordinator::{run_chain, NutsOptions, TreeAlgorithm};
use crate::coordinator::NativeSampler;
use crate::harness::builders::{init_z, Workload};
use crate::runtime::engine::Engine;
use crate::runtime::NutsStep;
use crate::rng::Rng;

pub fn ablate_vmap(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("E7 — vmapped multi-chain NUTS vs sequential dispatches (§3.2)\n\n");
    let model = "covtype_small";
    let dtype = "f32";
    let vmap_name = format!("{model}_nuts_step_vmap4_{dtype}");
    let entry = engine.manifest.get(&vmap_name)?;
    let chains = entry.meta_usize("chains").unwrap_or(4);
    let dim = entry.dim;
    let workload = Workload::for_model(engine, model, settings.seed)?;
    let dt = entry.inputs[4].dtype; // data dtype (x)
    let draws = if settings.quick { 20 } else { 100 };

    // vmapped: one dispatch advances all chains
    let mut vstep = NutsStep::new(engine, &vmap_name, &workload.tensors(dt)?)?;
    let mut rng = Rng::new(settings.seed);
    let mut zs = vec![0.0; chains * dim];
    for z in zs.iter_mut() {
        *z = rng.uniform_in(-2.0, 2.0);
    }
    let step_sizes = vec![0.05; chains];
    let inv_masses = vec![1.0; chains * dim];
    let t0 = std::time::Instant::now();
    let mut total_leapfrogs = 0u64;
    for _ in 0..draws {
        let keys: Vec<[u32; 2]> = (0..chains)
            .map(|_| {
                [
                    (rng.next_u64() >> 32) as u32,
                    (rng.next_u64() & 0xFFFF_FFFF) as u32,
                ]
            })
            .collect();
        let trs = vstep.step_vmap(&keys, &zs, &step_sizes, &inv_masses)?;
        for (c, tr) in trs.iter().enumerate() {
            zs[c * dim..(c + 1) * dim].copy_from_slice(&tr.z);
            total_leapfrogs += tr.num_leapfrog as u64;
        }
    }
    let vmap_secs = t0.elapsed().as_secs_f64();
    out.push_str(&format!(
        "vmap{chains}: {draws} draws x {chains} chains in {vmap_secs:.3}s ({} leapfrogs, {} dispatches)\n",
        total_leapfrogs, vstep.dispatches
    ));

    // sequential: chains advanced one dispatch each
    let mut sstep = NutsStep::new(
        engine,
        &format!("{model}_nuts_step_{dtype}"),
        &workload.tensors(dt)?,
    )?;
    let mut zs2 = zs.clone();
    let t0 = std::time::Instant::now();
    let mut seq_leapfrogs = 0u64;
    for _ in 0..draws {
        for c in 0..chains {
            let key = [
                (rng.next_u64() >> 32) as u32,
                (rng.next_u64() & 0xFFFF_FFFF) as u32,
            ];
            let tr = sstep.step(key, &zs2[c * dim..(c + 1) * dim].to_vec(), 0.05, &vec![1.0; dim])?;
            zs2[c * dim..(c + 1) * dim].copy_from_slice(&tr.z);
            seq_leapfrogs += tr.num_leapfrog as u64;
        }
    }
    let seq_secs = t0.elapsed().as_secs_f64();
    out.push_str(&format!(
        "sequential: {draws} draws x {chains} chains in {seq_secs:.3}s ({} leapfrogs, {} dispatches)\n",
        seq_leapfrogs, sstep.dispatches
    ));
    out.push_str(&format!(
        "\n-> per-(draw*chain) time: vmap {:.3} ms vs sequential {:.3} ms (dispatch amortization {:.2}x)\n",
        1e3 * vmap_secs / (draws * chains) as f64,
        1e3 * seq_secs / (draws * chains) as f64,
        seq_secs / vmap_secs,
    ));
    Ok(out)
}

pub fn ablate_kernel(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("Kernel-impl ablation — interpret-mode Pallas vs XLA-fused reference\n");
    out.push_str("(same density; the wallclock ratio is the CPU interpreter tax.\n");
    out.push_str(" On real TPU the Pallas variant compiles to Mosaic and is the fast path.)\n\n");
    out.push_str(&format!(
        "{:<28} {:>14} {:>14} {:>12}\n",
        "model", "U (ref)", "U (pallas)", "ms ratio"
    ));
    let variants: Vec<String> = engine
        .manifest
        .models()
        .iter()
        .filter(|m| m.ends_with("_pallas"))
        .cloned()
        .collect();
    if variants.is_empty() {
        out.push_str("(no *_pallas artifacts in manifest; re-run make artifacts)\n");
        return Ok(out);
    }
    for pallas_model in variants {
        let base = pallas_model.strip_suffix("_pallas").unwrap().to_string();
        let workload = Workload::for_model(engine, &base, settings.seed)?;
        let mut times = Vec::new();
        let mut potentials = Vec::new();
        for model in [&base, &pallas_model] {
            let name = format!("{model}_potential_and_grad_f32");
            let entry = engine.manifest.get(&name)?.clone();
            let dt = entry.inputs[0].dtype;
            let mut pot =
                crate::runtime::PjrtPotential::new(engine, &name, &workload.tensors(dt)?)?;
            let dim = entry.dim;
            let z = vec![0.1; dim];
            let mut g = vec![0.0; dim];
            let reps = if settings.quick { 5 } else { 20 };
            let timing = crate::util::timer::bench(2, reps, || {
                let _ = pot.eval(&z, &mut g).unwrap();
            });
            times.push(timing.median_s);
            potentials.push(pot.eval(&z, &mut g)?);
        }
        out.push_str(&format!(
            "{:<28} {:>14.4} {:>14.4} {:>11.1}x\n",
            base,
            potentials[0],
            potentials[1],
            times[1] / times[0]
        ));
        let rel = (potentials[0] - potentials[1]).abs() / (1.0 + potentials[0].abs());
        anyhow::ensure!(rel < 1e-4, "{base}: pallas and ref densities diverge");
    }
    out.push_str("\n-> identical densities; ratio = interpret-mode cost on CPU (DESIGN.md §6)\n");
    Ok(out)
}

pub fn ablate_tree(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("E8 — iterative (Alg. 2) vs recursive (Alg. 1) tree building,\n");
    out.push_str("same native HMM potential (paper: overhead 'insignificant')\n\n");
    let workload = Workload::for_model(engine, "hmm", settings.seed)?;
    let (warmup, samples) = settings.budget(400, 400);

    out.push_str(&format!(
        "{:<12} {:>14} {:>12} {:>10}\n",
        "algorithm", "ms/leapfrog", "leapfrogs", "sample s"
    ));
    let mut ms: Vec<f64> = Vec::new();
    for (label, alg) in [
        ("iterative", TreeAlgorithm::Iterative),
        ("recursive", TreeAlgorithm::Recursive),
    ] {
        struct BoxedPotential(Box<dyn crate::mcmc::Potential>);
        impl crate::mcmc::Potential for BoxedPotential {
            fn dim(&self) -> usize {
                self.0.dim()
            }
            fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
                self.0.value_and_grad(z, grad)
            }
        }
        let pot = BoxedPotential(workload.native_potential()?);
        let mut sampler = NativeSampler::new(pot, alg, settings.max_tree_depth);
        let dim = 33;
        let opts = NutsOptions {
            num_warmup: warmup,
            num_samples: samples,
            seed: settings.seed,
            ..Default::default()
        };
        let res = run_chain(&mut sampler, &init_z(dim, settings.seed), &opts)?;
        out.push_str(&format!(
            "{:<12} {:>14.4} {:>12} {:>10.3}\n",
            label,
            res.ms_per_leapfrog(),
            res.sample_leapfrogs,
            res.sample_secs
        ));
        ms.push(res.ms_per_leapfrog());
    }
    out.push_str(&format!(
        "\n-> iterative / recursive per-leapfrog ratio: {:.3} (paper: ~1)\n",
        ms[0] / ms[1]
    ));
    Ok(out)
}
