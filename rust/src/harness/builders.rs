//! Shared builders: construct samplers for (model, backend, dtype)
//! triples with workload data generated to match the artifact manifest's
//! static shapes, so the native and PJRT pipelines see the *same* data.

use anyhow::{bail, Result};

use crate::coordinator::{FusedSampler, NativeSampler, Sampler, TreeAlgorithm};
use crate::data;
use crate::models::{HmmNative, LogisticNative, SkimNative};
use crate::models::skim::SkimHypers;
use crate::runtime::engine::{Engine, HostTensor};
use crate::runtime::manifest::DType;
use crate::runtime::{NutsStep, PjrtPotential};

/// The three architectures of Table 2a.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// NumPyro: fused end-to-end `nuts_step` artifact.
    Fused,
    /// Pyro: recursive host tree + `potential_and_grad` dispatch per leapfrog.
    Stepwise,
    /// Stan: native Rust autodiff potential + iterative host tree.
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "fused" | "numpyro" => Backend::Fused,
            "stepwise" | "pyro" => Backend::Stepwise,
            "native" | "stan" => Backend::Native,
            other => bail!("unknown backend '{other}' (fused|stepwise|native)"),
        })
    }

    pub fn paper_name(&self) -> &'static str {
        match self {
            Backend::Fused => "fused (NumPyro arch)",
            Backend::Stepwise => "stepwise (Pyro arch)",
            Backend::Native => "native (Stan arch)",
        }
    }
}

/// Workload data for one model, generated to the manifest's shapes.
pub enum Workload {
    Hmm(data::HmmData),
    Logistic(data::LogisticData),
    Skim(data::SkimData),
}

impl Workload {
    /// Generate the workload for a model name using the nuts_step
    /// entry's static metadata.  `*_pallas` variants share their base
    /// model's workload.
    pub fn for_model(engine: &Engine, model: &str, seed: u64) -> Result<Workload> {
        // dtype tag irrelevant for shapes; prefer f32 entry, fall back f64
        let entry = engine
            .manifest
            .find(model, "nuts_step", "f32")
            .or_else(|_| engine.manifest.find(model, "nuts_step", "f64"))?;
        let model = model.strip_suffix("_pallas").unwrap_or(model);
        Ok(if model == "hmm" {
            let t = entry.meta_usize("seq_len").unwrap_or(600);
            let s = entry.meta_usize("num_supervised").unwrap_or(100);
            Workload::Hmm(data::make_hmm(seed, t, s, 3, 10))
        } else if model.starts_with("covtype") {
            let n = entry.meta_usize("n").unwrap_or(2000);
            let d = entry.meta_usize("d").unwrap_or(54);
            Workload::Logistic(data::make_covtype_like(seed, n, d))
        } else if model.starts_with("skim") {
            let n = entry.meta_usize("n").unwrap_or(200);
            let p = entry.meta_usize("p").unwrap_or(100);
            Workload::Skim(data::make_skim(seed, n, p, 3))
        } else {
            bail!("unknown model '{model}'")
        })
    }

    pub fn tensors(&self, dtype: DType) -> Result<Vec<HostTensor>> {
        Ok(match self {
            Workload::Hmm(d) => d.tensors(),
            Workload::Logistic(d) => d.tensors(dtype)?,
            Workload::Skim(d) => d.tensors(dtype)?,
        })
    }

    /// Native (Stan-architecture) potential over the same data.
    pub fn native_potential(&self) -> Result<Box<dyn crate::mcmc::Potential>> {
        Ok(match self {
            Workload::Hmm(d) => Box::new(HmmNative::new(
                d.obs.clone(),
                d.sup_states.clone(),
                d.num_states,
                d.num_categories,
            )),
            Workload::Logistic(d) => Box::new(LogisticNative::new(
                d.x.clone(),
                d.y.clone(),
                d.n,
                d.d,
            )),
            Workload::Skim(d) => Box::new(SkimNative::new(
                d.x.clone(),
                d.y.clone(),
                d.n,
                d.p,
                SkimHypers::default(),
            )),
        })
    }
}

fn float_dtype_of(engine: &Engine, model: &str, kind: &str, tag: &str) -> Result<DType> {
    let entry = engine.manifest.find(model, kind, tag)?;
    Ok(entry.inputs[if kind == "potential_and_grad" { 0 } else { 1 }].dtype)
}

/// Build a sampler for (model, backend, dtype tag).
pub fn build_sampler(
    engine: &Engine,
    model: &str,
    backend: Backend,
    dtype_tag: &str,
    workload: &Workload,
    max_tree_depth: u32,
) -> Result<Box<dyn Sampler>> {
    Ok(match backend {
        Backend::Fused => {
            let name = format!("{model}_nuts_step_{dtype_tag}");
            let dt = float_dtype_of(engine, model, "nuts_step", dtype_tag)?;
            let step = NutsStep::new(engine, &name, &workload.tensors(dt)?)?;
            Box::new(FusedSampler::new(step))
        }
        Backend::Stepwise => {
            let name = format!("{model}_potential_and_grad_{dtype_tag}");
            let dt = float_dtype_of(engine, model, "potential_and_grad", dtype_tag)?;
            let pot = PjrtPotential::new(engine, &name, &workload.tensors(dt)?)?;
            Box::new(NativeSampler::new(pot, TreeAlgorithm::Recursive, max_tree_depth))
        }
        Backend::Native => {
            struct BoxedPotential(Box<dyn crate::mcmc::Potential>);
            impl crate::mcmc::Potential for BoxedPotential {
                fn dim(&self) -> usize {
                    self.0.dim()
                }
                fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
                    self.0.value_and_grad(z, grad)
                }
                fn num_evals(&self) -> u64 {
                    self.0.num_evals()
                }
            }
            let pot = BoxedPotential(workload.native_potential()?);
            Box::new(NativeSampler::new(pot, TreeAlgorithm::Iterative, max_tree_depth))
        }
    })
}

/// Wraps a potential with a busy-wait per evaluation, emulating the
/// host-language dispatch cost of the paper's Pyro baseline (~30 ms of
/// Python overhead per leapfrog on the 2019 testbed; our Rust host loop
/// pays only ~µs of PJRT dispatch, so the paper's regime is simulated
/// explicitly — DESIGN.md §5).
pub struct PenalizedPotential<P> {
    pub inner: P,
    pub penalty: std::time::Duration,
}

impl<P: crate::mcmc::Potential> crate::mcmc::Potential for PenalizedPotential<P> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        let t0 = std::time::Instant::now();
        let u = self.inner.value_and_grad(z, grad);
        while t0.elapsed() < self.penalty {
            std::hint::spin_loop();
        }
        u
    }
    fn num_evals(&self) -> u64 {
        self.inner.num_evals()
    }
}

/// Uniform(-2,2) init, matching NumPyro's init_to_uniform.
pub fn init_z(dim: usize, seed: u64) -> Vec<f64> {
    let mut rng = crate::rng::Rng::new(seed ^ 0xC0FFEE);
    (0..dim).map(|_| rng.uniform_in(-2.0, 2.0)).collect()
}
