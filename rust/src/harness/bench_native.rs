//! `fugue bench` — the native-substrate performance baseline.
//!
//! Times the zero-allocation NUTS hot path on the three native models
//! (logistic / HMM / SKIM) without needing artifacts or PJRT:
//!
//! 1. **ms per leapfrog** at a small fixed step size (full-depth trees,
//!    so the measurement is dominated by `value_and_grad` + tree
//!    bookkeeping, not by U-turn luck).  For the logistic model the
//!    same run also times a faithful *pre-optimization baseline*
//!    (fresh tape per gradient, separate sigmoid/softplus exps, serial
//!    dot product, per-draw workspace allocation — the seed code), so
//!    every future PR has a like-for-like speedup number, plus the
//!    [`crate::compile`] **model-compiler** version of the same density
//!    (`compiled_ms_per_leapfrog` / `compiled_overhead_vs_hand`): the
//!    price of sampling a pure `sample`/`observe` program instead of a
//!    hand-fused potential.
//! 2. **multi-chain scaling** 1..K chains through
//!    [`ParallelChainRunner`], reporting wall-clock, draws/sec,
//!    parallel efficiency and the cross-chain split-R̂ of the pooled
//!    results, plus a bitwise reproducibility check (two identical
//!    K-chain runs must agree exactly).
//! 3. **chain-method comparison** on the compiled logistic model:
//!    sequential vs thread-parallel vs the SIMD-lane **vectorized**
//!    engine ([`crate::coordinator::run_chains_vectorized`]) at every
//!    chain count, recording `vectorized_speedup_vs_parallel` /
//!    `vectorized_speedup_vs_sequential` and asserting the three
//!    methods' chains are bitwise equal.
//! 4. **frozen-program speedups** per compiled zoo model
//!    (eight-schools / horseshoe / normal-mean / logistic): the
//!    record-once / replay-many fast path vs the tape-interpreter
//!    replay, recorded as `frozen_vs_replay` rows plus a
//!    `frozen_speedup_vs_replay` field on the logistic model.
//! 5. **native SVI** ([`crate::svi`]): ms/step of the reparameterized
//!    ADVI engine with the K ELBO particles run as a scalar-potential
//!    loop vs one fused multi-lane sweep (`svi_particle_batch_speedup`,
//!    bitwise-equality asserted), plus the fitted guide's posterior
//!    means vs NUTS means on the logistic zoo model (within 6x MCSE) —
//!    the `svi_native` section.
//! 6. **robustness overhead**: ms/leapfrog of the plain single-chain
//!    runner vs the containment-bearing checkpoint runner
//!    ([`crate::coordinator::run_chains_checkpointed`] with no
//!    checkpoint path, so only the cursor bookkeeping, finiteness
//!    guards and budget checks are in the loop) on the compiled
//!    logistic model — the `robustness_overhead` row
//!    (`ms_per_eval_raw` / `ms_per_eval_checked` / `overhead_frac`,
//!    target < 1%).
//! 7. **lane scaling** (`lane_scaling`): ms/leapfrog-per-lane of the
//!    tiled massive-lane engine
//!    ([`crate::mcmc::TiledBatchPotential`]) on the compiled logistic
//!    across K ∈ {8, 32, 128, 512, 1024} lanes, each K gated by a
//!    bitwise-equality `ensure!` against the single-program
//!    `BatchTape` path (`tiled_bitwise_equal`), plus the per-lane cost
//!    ratio K=512 vs K=8 (`per_lane_ratio_512_vs_8`, target < 2x).
//! 8. **subsampling** ([`crate::coordinator::run_svi_subsampled`]):
//!    minibatch SVI throughput on the streaming synthetic logistic
//!    dataset ([`crate::data::SyntheticLogisticStream`] — rows
//!    generated on demand, never materialized), reported as
//!    `rows_per_sec` (minibatch rows consumed per wall-clock second)
//!    and ms/step, gated by a bitwise-equality `ensure!` that the
//!    `B = N` subsampled run reproduces the plain full-batch SVI path
//!    exactly (`full_batch_bitwise_equal`).
//! 9. **optimizing tape compiler** (`tape_opt`): per compiled zoo
//!    model, ms/leapfrog with the `ExecPlan` threaded-code path (the
//!    default) vs the frozen node-per-node interpreter
//!    (`set_optimized(false)`), recorded as
//!    `opt_speedup_vs_interpreted` plus the plan statistics
//!    ([`crate::autodiff::PlanStats`]), and the same comparison on the
//!    lane-minor batch programs at K ∈ {8, 512}.  Every row is
//!    preceded by a **fatal** bitwise `ensure!` against the
//!    interpreter oracle (`opt_bitwise_equal`).
//! 10. **observability overhead** (`observability_overhead`):
//!    ms/leapfrog of the compiled logistic model with the flight
//!    recorder ([`crate::obs`]) disabled vs installed, gated by a
//!    **fatal** bitwise `ensure!` that the two runs' draws are
//!    identical (`recorder_bitwise_equal` — the recorder must never
//!    consume RNG or reorder sampler arithmetic), with a < 1%
//!    `overhead_frac` warning bar.
//!
//! Results are written as machine-readable JSON (`BENCH_native.json` at
//! the repo root by default) so the perf trajectory is diffable across
//! PRs.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::autodiff::{Tape, Var};
use crate::compile::zoo::{EightSchools, Horseshoe, LogisticModel, NormalMean};
use crate::compile::{compile, compile_batched, tiled_from_layout, EffModel, SiteLayout};
use crate::config::Settings;
use crate::coordinator::{
    run_chain, run_chains_checkpointed, run_compiled_chains_method, run_svi_native,
    ChainMethod, ChainResult, CheckpointConfig, NativeSampler, NutsOptions,
    ParallelChainRunner, Sampler, TreeAlgorithm,
};
use crate::data;
use crate::diagnostics::summary::{max_cross_chain_rhat, summarize};
use crate::svi::{
    BatchedParticles, NativeSvi, OptimKind, ScalarParticles, StepSchedule, SviOptions,
};
use crate::mcmc::batch_nuts::{draw_batch, BatchTreeWorkspace};
use crate::mcmc::{
    auto_tile_width, nuts_iterative, BatchPotential, DrawStats, Potential, Transition,
};
use crate::models::skim::SkimHypers;
use crate::models::{HmmNative, LogisticNative, SkimNative};
use crate::ppl::special::{sigmoid, softplus, LN_2PI};
use crate::rng::Rng;
use crate::util::json::Json;

/// Tree-depth cap for the fixed-eps timing runs: a small step size then
/// yields full 2^depth-leaf trees, so leapfrog counts are stable.
const TIMING_DEPTH: u32 = 6;

// ---------------------------------------------------------------------------
// pre-optimization baseline (seed replica)
// ---------------------------------------------------------------------------

/// The seed's logistic potential, kept verbatim as the measured
/// baseline: a fresh tape + fresh `Vec`s every evaluation, a dead
/// `z_buf` write, separate sigmoid/softplus (two `exp`s per row) and a
/// serial dot product.
struct BaselineLogistic {
    x: Vec<f64>,
    y: Vec<f64>,
    n: usize,
    d: usize,
    z_buf: Vec<f64>,
    evals: u64,
}

impl BaselineLogistic {
    fn new(x: Vec<f64>, y: Vec<f64>, n: usize, d: usize) -> Self {
        BaselineLogistic {
            x,
            y,
            n,
            d,
            z_buf: vec![0.0; n],
            evals: 0,
        }
    }
}

impl Potential for BaselineLogistic {
    fn dim(&self) -> usize {
        self.d + 1
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        self.evals += 1;
        let d = self.d;
        let b_val = z[0];
        let m_vals = &z[1..];

        let mut t = Tape::new();
        let b = t.input(b_val);
        let m: Vec<Var> = m_vals.iter().map(|&v| t.input(v)).collect();

        let mut prior_terms = Vec::with_capacity(d + 1);
        for &v in std::iter::once(&b).chain(m.iter()) {
            let sq = t.square(v);
            let half = t.scale(sq, -0.5);
            prior_terms.push(t.offset(half, -0.5 * LN_2PI));
        }
        let log_prior = t.sum(&prior_terms);

        let mut partials = vec![0.0; d + 1];
        let mut value = 0.0;
        for i in 0..self.n {
            let xi = &self.x[i * d..(i + 1) * d];
            let mut zl = b_val;
            for j in 0..d {
                zl += xi[j] * m_vals[j];
            }
            self.z_buf[i] = zl; // the seed's dead write
            value += self.y[i] * zl - softplus(zl);
            let r = self.y[i] - sigmoid(zl);
            for j in 0..d {
                partials[j] += r * xi[j];
            }
            partials[d] += r;
        }
        let mut parents: Vec<Var> = m.clone();
        parents.push(b);
        let log_lik = t.composite(&parents, &partials, value);

        let logp = t.add(log_prior, log_lik);
        let u = t.neg(logp);
        let uval = t.value(u);
        let adj = t.grad(u);
        grad[0] = adj[b.0 as usize];
        for j in 0..d {
            grad[1 + j] = adj[m[j].0 as usize];
        }
        uval
    }

    fn num_evals(&self) -> u64 {
        self.evals
    }
}

/// Seed-style iterative sampler: a fresh tree workspace allocated every
/// draw (the pre-optimization behaviour of `nuts_iterative::draw`).
struct AllocatingIterativeSampler<P: Potential> {
    potential: P,
    max_tree_depth: u32,
}

impl<P: Potential> Sampler for AllocatingIterativeSampler<P> {
    fn dim(&self) -> usize {
        self.potential.dim()
    }

    fn draw(
        &mut self,
        rng: &mut Rng,
        z: &[f64],
        step_size: f64,
        inv_mass: &[f64],
    ) -> Result<Transition> {
        Ok(nuts_iterative::draw(
            &mut self.potential,
            rng,
            z,
            step_size,
            inv_mass,
            self.max_tree_depth,
        ))
    }
}

// ---------------------------------------------------------------------------
// measurement helpers
// ---------------------------------------------------------------------------

/// Fixed-eps, unit-mass, no-warmup run; returns (ms/leapfrog, leapfrogs).
fn time_fixed_eps<S: Sampler>(
    sampler: &mut S,
    eps: f64,
    draws: usize,
    seed: u64,
) -> Result<(f64, u64)> {
    let dim = sampler.dim();
    let opts = NutsOptions {
        num_warmup: 0,
        num_samples: draws,
        target_accept: 0.8,
        init_step_size: eps,
        fixed_step_size: Some(eps),
        adapt_mass: false,
        seed,
    };
    let init = vec![0.1; dim];
    let res = run_chain(sampler, &init, &opts)?;
    Ok((res.ms_per_leapfrog(), res.sample_leapfrogs))
}

fn run_parallel<F>(
    make_pot: &F,
    chains: usize,
    max_depth: u32,
    opts: &NutsOptions,
) -> Result<(Vec<ChainResult>, f64)>
where
    F: Fn() -> Box<dyn Potential> + Sync,
{
    let factory =
        |_c: usize| Ok(NativeSampler::new(make_pot(), TreeAlgorithm::Iterative, max_depth));
    let t0 = std::time::Instant::now();
    let results = ParallelChainRunner::new(chains).run(factory, opts)?;
    Ok((results, t0.elapsed().as_secs_f64()))
}

fn jnum(v: f64) -> Json {
    Json::Num(v)
}

/// ms/leapfrog of a compiled zoo model with the frozen fast path on or
/// off (`frozen = false` re-runs the tape interpreter per gradient —
/// the pre-freeze cost model).
fn time_compiled_frozen<M: EffModel + Clone>(
    model: &M,
    frozen: bool,
    eps: f64,
    draws: usize,
    seed: u64,
) -> Result<f64> {
    let mut pot = compile(model.clone(), seed)?;
    pot.set_frozen(frozen);
    let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, TIMING_DEPTH);
    let (ms, _) = time_fixed_eps(&mut sampler, eps, draws, seed)?;
    Ok(ms)
}

/// Time one zoo model frozen-vs-replay, append the report line, and
/// record the JSON row.  Returns the speedup.
#[allow(clippy::too_many_arguments)]
fn bench_frozen_vs_replay<M: EffModel + Clone>(
    name: &str,
    model: &M,
    eps: f64,
    draws: usize,
    seed: u64,
    report: &mut String,
    rows: &mut BTreeMap<String, Json>,
) -> Result<f64> {
    let frozen_ms = time_compiled_frozen(model, true, eps, draws, seed)?;
    let replay_ms = time_compiled_frozen(model, false, eps, draws, seed)?;
    let speedup = replay_ms / frozen_ms.max(1e-12);
    report.push_str(&format!(
        "  {name}: frozen {frozen_ms:.5} ms/leapfrog | replay {replay_ms:.5} ms/leapfrog \
         -> {speedup:.2}x\n"
    ));
    rows.insert(
        name.to_string(),
        jobj(vec![
            ("frozen_ms_per_leapfrog", jnum(frozen_ms)),
            ("replay_ms_per_leapfrog", jnum(replay_ms)),
            ("frozen_speedup_vs_replay", jnum(speedup)),
        ]),
    );
    Ok(speedup)
}

fn jobj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<String, Json>>(),
    )
}

/// ms/leapfrog of a compiled zoo model with the optimizing tape
/// compiler on or off.  Both paths serve the *frozen* program;
/// `optimized = false` falls back to the node-by-node interpreter (the
/// pre-PR-9 frozen cost model), so the delta is exactly the payoff of
/// DCE + fusion + re-slotting.
fn time_compiled_optimized<M: EffModel + Clone>(
    model: &M,
    optimized: bool,
    eps: f64,
    draws: usize,
    seed: u64,
) -> Result<f64> {
    let mut pot = compile(model.clone(), seed)?;
    pot.set_optimized(optimized);
    let mut sampler = NativeSampler::new(pot, TreeAlgorithm::Iterative, TIMING_DEPTH);
    let (ms, _) = time_fixed_eps(&mut sampler, eps, draws, seed)?;
    Ok(ms)
}

/// Time one zoo model optimized-vs-interpreted on the frozen program,
/// enforce the bitwise oracle fatally at several probe points, append
/// the report line, and record the JSON row (including the `ExecPlan`
/// statistics).  Returns the speedup.
#[allow(clippy::too_many_arguments)]
fn bench_tape_opt<M: EffModel + Clone>(
    name: &str,
    model: &M,
    eps: f64,
    draws: usize,
    seed: u64,
    report: &mut String,
    rows: &mut BTreeMap<String, Json>,
) -> Result<f64> {
    // bitwise oracle: the optimized plan must reproduce the frozen
    // interpreter exactly — value and every gradient component — at
    // every probe point, or the bench aborts
    let mut opt_pot = compile(model.clone(), seed)?;
    let mut int_pot = compile(model.clone(), seed)?;
    int_pot.set_optimized(false);
    let dim = opt_pot.dim();
    let mut zrng = Rng::new(seed ^ 0x09A7 ^ name.len() as u64);
    let mut g_o = vec![0.0; dim];
    let mut g_i = vec![0.0; dim];
    for probe in 0..4 {
        let z: Vec<f64> = (0..dim).map(|_| 0.3 * zrng.normal()).collect();
        let u_o = opt_pot.value_and_grad(&z, &mut g_o);
        let u_i = int_pot.value_and_grad(&z, &mut g_i);
        let same = u_o.to_bits() == u_i.to_bits()
            && g_o.iter().zip(&g_i).all(|(a, b)| a.to_bits() == b.to_bits());
        anyhow::ensure!(
            same,
            "optimized plan diverged bitwise from the frozen interpreter on {name} \
             (probe {probe}) — the tape compiler must be IEEE-transparent"
        );
    }
    anyhow::ensure!(
        opt_pot.is_optimized(),
        "optimizer did not engage on {name} — the frozen program was never compiled to a plan"
    );
    let stats = opt_pot
        .plan_stats()
        .ok_or_else(|| anyhow::anyhow!("plan stats missing on {name} after optimization"))?;

    let opt_ms = time_compiled_optimized(model, true, eps, draws, seed)?;
    let int_ms = time_compiled_optimized(model, false, eps, draws, seed)?;
    let speedup = int_ms / opt_ms.max(1e-12);
    report.push_str(&format!(
        "  {name}: optimized {opt_ms:.5} ms/leapfrog | interpreted {int_ms:.5} ms/leapfrog \
         -> {speedup:.2}x  [live {}/{}, fused runs {}, micro-ops {}, val slots {}]\n",
        stats.nodes_live,
        stats.nodes_total,
        stats.fused_runs,
        stats.micro_ops,
        stats.peak_val_slots
    ));
    rows.insert(
        name.to_string(),
        jobj(vec![
            ("interpreted_ms_per_leapfrog", jnum(int_ms)),
            ("optimized_ms_per_leapfrog", jnum(opt_ms)),
            ("opt_speedup_vs_interpreted", jnum(speedup)),
            // the per-probe ensure! above aborts the bench on any
            // divergence, so reaching this row implies equality
            ("opt_bitwise_equal", Json::Bool(true)),
            (
                "plan",
                jobj(vec![
                    ("nodes_total", jnum(stats.nodes_total as f64)),
                    ("nodes_live", jnum(stats.nodes_live as f64)),
                    ("nodes_folded", jnum(stats.nodes_folded as f64)),
                    ("fused_runs", jnum(stats.fused_runs as f64)),
                    ("micro_ops", jnum(stats.micro_ops as f64)),
                    ("composites", jnum(stats.composites as f64)),
                    ("fwd_instrs", jnum(stats.fwd_instrs as f64)),
                    ("bwd_instrs", jnum(stats.bwd_instrs as f64)),
                    ("peak_val_slots", jnum(stats.peak_val_slots as f64)),
                    ("peak_adj_slots", jnum(stats.peak_adj_slots as f64)),
                ]),
            ),
        ]),
    );
    Ok(speedup)
}

// ---------------------------------------------------------------------------
// per-model bench
// ---------------------------------------------------------------------------

struct ModelBench {
    json: Json,
    text: String,
}

#[allow(clippy::too_many_arguments)]
fn bench_model<F>(
    name: &str,
    meta: Vec<(&str, Json)>,
    make_pot: F,
    eps: f64,
    timing_draws: usize,
    chain_counts: &[usize],
    settings: &Settings,
    baseline_ms: Option<f64>,
    chain_budget: (usize, usize),
    chain_depth: u32,
) -> Result<ModelBench>
where
    F: Fn() -> Box<dyn Potential> + Sync,
{
    let dim = make_pot().dim();
    let mut text = String::new();
    text.push_str(&format!("== {name} (dim {dim}) ==\n"));

    // 1. ms per leapfrog, optimized hot path
    let mut sampler = NativeSampler::new(make_pot(), TreeAlgorithm::Iterative, TIMING_DEPTH);
    let (ms_opt, leapfrogs) = time_fixed_eps(&mut sampler, eps, timing_draws, settings.seed)?;
    text.push_str(&format!(
        "  optimized: {ms_opt:.5} ms/leapfrog ({leapfrogs} leapfrogs @ eps={eps})\n"
    ));
    let mut fields: Vec<(&str, Json)> = meta;
    fields.push(("dim", jnum(dim as f64)));
    fields.push(("eps", jnum(eps)));
    fields.push(("timing_leapfrogs", jnum(leapfrogs as f64)));
    fields.push(("ms_per_leapfrog", jnum(ms_opt)));
    if let Some(base) = baseline_ms {
        let speedup = base / ms_opt;
        text.push_str(&format!(
            "  baseline (seed replica): {base:.5} ms/leapfrog -> speedup {speedup:.2}x\n"
        ));
        fields.push(("baseline_ms_per_leapfrog", jnum(base)));
        fields.push(("speedup_vs_baseline", jnum(speedup)));
    }

    // 2. multi-chain scaling with adaptation on
    let (warmup, samples) = settings.budget(chain_budget.0, chain_budget.1);
    let opts = NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        seed: settings.seed,
        ..Default::default()
    };
    let mut chain_json: Vec<Json> = Vec::new();
    let mut last_results: Option<Vec<ChainResult>> = None;
    let mut first_wall: Option<f64> = None;
    let mut last_wall = 0.0;
    for &k in chain_counts {
        let (results, wall_s) = run_parallel(&make_pot, k, chain_depth, &opts)?;
        let pooled: Vec<Vec<f64>> = results.iter().map(|r| r.samples.clone()).collect();
        let max_rhat = if k > 1 {
            max_cross_chain_rhat(&pooled, dim)
        } else {
            f64::NAN
        };
        // wall_s spans warmup + sampling, so count every draw
        let draws_per_sec = (k * (warmup + samples)) as f64 / wall_s.max(1e-12);
        text.push_str(&format!(
            "  {k} chain(s): {wall_s:.3}s wall, {draws_per_sec:.0} draws/s{}\n",
            if max_rhat.is_finite() {
                format!(", max split-Rhat {max_rhat:.3}")
            } else {
                String::new()
            }
        ));
        let mut cj = vec![
            ("chains", jnum(k as f64)),
            ("wall_s", jnum(wall_s)),
            ("draws_per_sec", jnum(draws_per_sec)),
        ];
        if max_rhat.is_finite() {
            cj.push(("max_split_rhat", jnum(max_rhat)));
        }
        chain_json.push(jobj(cj));
        first_wall.get_or_insert(wall_s);
        last_wall = wall_s;
        if k == *chain_counts.last().unwrap() {
            last_results = Some(results);
        }
    }

    // parallel efficiency: K-chain wall vs 1-chain wall
    let max_k = *chain_counts.last().unwrap();
    if let Some(one) = first_wall {
        if max_k > chain_counts[0] {
            let ratio = last_wall / one;
            text.push_str(&format!(
                "  {max_k}-chain wall-clock = {ratio:.2}x single-chain (ideal 1.0)\n"
            ));
            fields.push(("wall_ratio_max_chains_vs_1", jnum(ratio)));
        }
    }

    // 3. bitwise reproducibility of the parallel runner
    let (rerun, _) = run_parallel(&make_pot, max_k, chain_depth, &opts)?;
    let reproducible = match &last_results {
        Some(prev) => prev
            .iter()
            .zip(&rerun)
            .all(|(a, b)| a.samples == b.samples && a.step_size == b.step_size),
        None => false,
    };
    text.push_str(&format!(
        "  reproducible across reruns: {reproducible}\n"
    ));
    fields.push(("reproducible", Json::Bool(reproducible)));
    fields.push(("chains", Json::Arr(chain_json)));

    Ok(ModelBench {
        json: jobj(fields),
        text,
    })
}

// ---------------------------------------------------------------------------
// entry point
// ---------------------------------------------------------------------------

/// Run the native bench suite and write `out_path` (JSON).  Returns the
/// human-readable report.
pub fn run(settings: &Settings, max_chains: usize, out_path: &str) -> Result<String> {
    let mut report = String::new();
    report.push_str("fugue bench — native NUTS hot path (no artifacts needed)\n\n");

    let timing_draws = if settings.quick { 12 } else { 40 };
    let mut chain_counts: Vec<usize> = vec![1, 2, 4]
        .into_iter()
        .filter(|&k| k <= max_chains)
        .collect();
    if chain_counts.last() != Some(&max_chains) {
        chain_counts.push(max_chains);
    }

    let mut models = BTreeMap::new();

    // --- logistic (the acceptance workload: n=5000, d=16) ---
    {
        let (n, d) = if settings.quick { (2000, 16) } else { (5000, 16) };
        let dset = data::make_covtype_like(settings.seed, n, d);
        let (x, y) = (dset.x, dset.y);

        // pre-optimization baseline, measured in this same run
        let mut base_sampler = AllocatingIterativeSampler {
            potential: BaselineLogistic::new(x.clone(), y.clone(), n, d),
            max_tree_depth: TIMING_DEPTH,
        };
        let (base_ms, _) = time_fixed_eps(&mut base_sampler, 1e-3, timing_draws, settings.seed)?;

        // keep a copy for the model-compiler comparison below (x/y move
        // into the `make` closure)
        let (cx, cy) = (x.clone(), y.clone());
        let make = move || -> Box<dyn Potential> {
            Box::new(LogisticNative::new(x.clone(), y.clone(), n, d))
        };
        let mut bench = bench_model(
            "logistic",
            vec![("n", jnum(n as f64)), ("d", jnum(d as f64))],
            make,
            1e-3,
            timing_draws,
            &chain_counts,
            settings,
            Some(base_ms),
            (150, 300),
            10,
        )?;

        // model-compiler comparison: the same density compiled from a
        // pure sample/observe program (no hand-written gradient) — the
        // overhead ratio is the price of generality
        let mut comp_sampler = NativeSampler::new(
            compile(
                LogisticModel {
                    x: cx,
                    y: cy,
                    n,
                    d,
                },
                settings.seed,
            )?,
            TreeAlgorithm::Iterative,
            TIMING_DEPTH,
        );
        let (comp_ms, _) = time_fixed_eps(&mut comp_sampler, 1e-3, timing_draws, settings.seed)?;
        if let Json::Obj(map) = &mut bench.json {
            let overhead = match map.get("ms_per_leapfrog") {
                Some(Json::Num(opt_ms)) if *opt_ms > 0.0 => comp_ms / opt_ms,
                _ => f64::NAN,
            };
            bench.text.push_str(&format!(
                "  compiled (model compiler): {comp_ms:.5} ms/leapfrog -> {overhead:.2}x hand-fused\n"
            ));
            map.insert("compiled_ms_per_leapfrog".to_string(), jnum(comp_ms));
            if overhead.is_finite() {
                map.insert("compiled_overhead_vs_hand".to_string(), jnum(overhead));
            }
        }

        // vectorized chain engine: the same compiled logistic density
        // run sequential vs thread-parallel vs SIMD-lane vectorized at
        // each chain count — the cross-method perf datapoint
        // (`vectorized_speedup_vs_parallel`).  All three methods
        // produce bitwise-identical chains, which the bench asserts.
        {
            let (vn, vd) = if settings.quick { (800, 16) } else { (2000, 16) };
            let dset = data::make_covtype_like(settings.seed ^ 0x51D, vn, vd);
            let model = LogisticModel {
                x: dset.x,
                y: dset.y,
                n: vn,
                d: vd,
            };
            let (vwarm, vsamp) = settings.budget(100, 200);
            let vopts = NutsOptions {
                num_warmup: vwarm,
                num_samples: vsamp,
                seed: settings.seed,
                ..Default::default()
            };
            bench.text.push_str(&format!(
                "  vectorized chain engine (compiled logistic n={vn} d={vd}, {vwarm}+{vsamp} draws):\n"
            ));
            let mut rows: Vec<Json> = Vec::new();
            let mut final_vs_par = f64::NAN;
            let mut final_vs_seq = f64::NAN;
            for &k in &chain_counts {
                let t0 = std::time::Instant::now();
                let (_, seq) =
                    run_compiled_chains_method(&model, ChainMethod::Sequential, k, 10, &vopts)?;
                let seq_wall = t0.elapsed().as_secs_f64();
                let t0 = std::time::Instant::now();
                let (_, par) =
                    run_compiled_chains_method(&model, ChainMethod::Parallel, k, 10, &vopts)?;
                let par_wall = t0.elapsed().as_secs_f64();
                let t0 = std::time::Instant::now();
                let (_, vec_res) =
                    run_compiled_chains_method(&model, ChainMethod::Vectorized, k, 10, &vopts)?;
                let vec_wall = t0.elapsed().as_secs_f64();
                let equal = seq
                    .iter()
                    .zip(&par)
                    .zip(&vec_res)
                    .all(|((s, p), v)| s.samples == p.samples && s.samples == v.samples);
                anyhow::ensure!(
                    equal,
                    "chain methods diverged bitwise at {k} chains on the compiled logistic — \
                     sequential/parallel/vectorized must produce identical chains"
                );
                let vs_par = par_wall / vec_wall.max(1e-12);
                let vs_seq = seq_wall / vec_wall.max(1e-12);
                bench.text.push_str(&format!(
                    "    {k} chain(s): seq {seq_wall:.3}s | par {par_wall:.3}s | vec {vec_wall:.3}s \
                     -> {vs_par:.2}x vs parallel, {vs_seq:.2}x vs sequential (bitwise equal: {equal})\n"
                ));
                rows.push(jobj(vec![
                    ("chains", jnum(k as f64)),
                    ("sequential_wall_s", jnum(seq_wall)),
                    ("parallel_wall_s", jnum(par_wall)),
                    ("vectorized_wall_s", jnum(vec_wall)),
                    ("vectorized_speedup_vs_parallel", jnum(vs_par)),
                    ("vectorized_speedup_vs_sequential", jnum(vs_seq)),
                    ("methods_bitwise_equal", Json::Bool(equal)),
                ]));
                final_vs_par = vs_par;
                final_vs_seq = vs_seq;
            }
            if let Json::Obj(map) = &mut bench.json {
                map.insert("vectorized_chain_engine".to_string(), Json::Arr(rows));
                if final_vs_par.is_finite() {
                    map.insert(
                        "vectorized_speedup_vs_parallel".to_string(),
                        jnum(final_vs_par),
                    );
                }
                if final_vs_seq.is_finite() {
                    map.insert(
                        "vectorized_speedup_vs_sequential".to_string(),
                        jnum(final_vs_seq),
                    );
                }
            }
        }
        report.push_str(&bench.text);
        report.push('\n');
        models.insert("logistic".to_string(), bench.json);
    }

    // --- hmm (T=600, 100 supervised, K=3, V=10) ---
    {
        let (t_len, t_sup) = if settings.quick { (200, 40) } else { (600, 100) };
        let dset = data::make_hmm(settings.seed, t_len, t_sup, 3, 10);
        let (obs, sup) = (dset.obs, dset.sup_states);
        let make = move || -> Box<dyn Potential> {
            Box::new(HmmNative::new(obs.clone(), sup.clone(), 3, 10))
        };
        let bench = bench_model(
            "hmm",
            vec![("seq_len", jnum(t_len as f64)), ("num_supervised", jnum(t_sup as f64))],
            make,
            1e-2,
            timing_draws,
            &chain_counts,
            settings,
            None,
            (150, 300),
            10,
        )?;
        report.push_str(&bench.text);
        report.push('\n');
        models.insert("hmm".to_string(), bench.json);
    }

    // --- skim (kept small: the marginal is O(n^3) per gradient) ---
    {
        let (n, p) = if settings.quick { (30, 6) } else { (50, 10) };
        let dset = data::make_skim(settings.seed, n, p, 2);
        let (x, y) = (dset.x, dset.y);
        let make = move || -> Box<dyn Potential> {
            Box::new(SkimNative::new(x.clone(), y.clone(), n, p, SkimHypers::default()))
        };
        let bench = bench_model(
            "skim",
            vec![("n", jnum(n as f64)), ("p", jnum(p as f64))],
            make,
            5e-3,
            timing_draws,
            &chain_counts,
            settings,
            None,
            (80, 120),
            7,
        )?;
        report.push_str(&bench.text);
        report.push('\n');
        models.insert("skim".to_string(), bench.json);
    }

    // --- frozen tape programs: record once, replay many ---
    // Per zoo model: ms/leapfrog with the frozen fast path (the
    // default) vs the interpreter-replay path (`set_frozen(false)`,
    // the pre-freeze cost model).  The logistic speedup is also
    // mirrored into models.logistic as `frozen_speedup_vs_replay` —
    // the acceptance datapoint for the record-once refactor.
    let mut frozen_rows: BTreeMap<String, Json> = BTreeMap::new();
    {
        report.push_str("== frozen tape programs (record once, replay many) ==\n");
        let draws = timing_draws;
        bench_frozen_vs_replay(
            "eight_schools",
            &EightSchools::classic(),
            1e-2,
            draws,
            settings.seed,
            &mut report,
            &mut frozen_rows,
        )?;
        bench_frozen_vs_replay(
            "horseshoe",
            &Horseshoe::synthetic(settings.seed, 60, 8, 2),
            5e-3,
            draws,
            settings.seed,
            &mut report,
            &mut frozen_rows,
        )?;
        let mut nm_rng = Rng::new(settings.seed ^ 0xF0F0);
        let nm = NormalMean {
            y: (0..64).map(|_| 0.4 + nm_rng.normal()).collect(),
            sigma: 1.2,
        };
        bench_frozen_vs_replay(
            "normal_mean",
            &nm,
            2e-2,
            draws,
            settings.seed,
            &mut report,
            &mut frozen_rows,
        )?;
        let (fn_, fd_) = if settings.quick { (800, 16) } else { (2000, 16) };
        let dset = data::make_covtype_like(settings.seed ^ 0xF42, fn_, fd_);
        let lm = LogisticModel {
            x: dset.x,
            y: dset.y,
            n: fn_,
            d: fd_,
        };
        let logi_speedup = bench_frozen_vs_replay(
            "logistic",
            &lm,
            1e-3,
            draws,
            settings.seed,
            &mut report,
            &mut frozen_rows,
        )?;
        if let Some(Json::Obj(map)) = models.get_mut("logistic") {
            map.insert("frozen_speedup_vs_replay".to_string(), jnum(logi_speedup));
        }
        // the acceptance bar is > 1.0; timing ratios are too noisy for
        // a hard abort, so flag regressions loudly in the report and
        // let the JSON artifact carry the number
        if logi_speedup <= 1.0 {
            report.push_str(&format!(
                "  WARNING: logistic frozen_speedup_vs_replay = {logi_speedup:.2} <= 1.0 — \
                 the frozen fast path regressed below the interpreter replay\n"
            ));
        }
        report.push('\n');
    }

    // --- optimizing tape compiler: fuse, prune, re-slot ---
    // Per zoo model: ms/leapfrog with the ExecPlan threaded-code path
    // (the default) vs the frozen node-by-node interpreter
    // (`set_optimized(false)`).  The interpreter is the bitwise oracle:
    // every comparison below is a fatal `ensure!`, so a published
    // artifact always carries `opt_bitwise_equal: true` honestly.
    let tape_opt_json = {
        report.push_str("== optimizing tape compiler (DCE + fusion + re-slotting) ==\n");
        let draws = timing_draws;
        let mut opt_rows: BTreeMap<String, Json> = BTreeMap::new();
        bench_tape_opt(
            "eight_schools",
            &EightSchools::classic(),
            1e-2,
            draws,
            settings.seed,
            &mut report,
            &mut opt_rows,
        )?;
        bench_tape_opt(
            "horseshoe",
            &Horseshoe::synthetic(settings.seed, 60, 8, 2),
            5e-3,
            draws,
            settings.seed,
            &mut report,
            &mut opt_rows,
        )?;
        let mut nm_rng = Rng::new(settings.seed ^ 0x0F0F);
        let nm = NormalMean {
            y: (0..64).map(|_| 0.4 + nm_rng.normal()).collect(),
            sigma: 1.2,
        };
        bench_tape_opt(
            "normal_mean",
            &nm,
            2e-2,
            draws,
            settings.seed,
            &mut report,
            &mut opt_rows,
        )?;
        let (on_, od_) = if settings.quick { (800, 16) } else { (2000, 16) };
        let dset = data::make_covtype_like(settings.seed ^ 0x9F42, on_, od_);
        let lm = LogisticModel {
            x: dset.x,
            y: dset.y,
            n: on_,
            d: od_,
        };
        let logi_opt_speedup = bench_tape_opt(
            "logistic",
            &lm,
            1e-3,
            draws,
            settings.seed,
            &mut report,
            &mut opt_rows,
        )?;
        if let Some(Json::Obj(map)) = models.get_mut("logistic") {
            map.insert(
                "opt_speedup_vs_interpreted".to_string(),
                jnum(logi_opt_speedup),
            );
        }
        if logi_opt_speedup <= 1.0 {
            report.push_str(&format!(
                "  WARNING: logistic opt_speedup_vs_interpreted = {logi_opt_speedup:.2} <= 1.0 — \
                 the ExecPlan path regressed below the frozen interpreter\n"
            ));
        }

        // batched lanes: the same plan compiles the lane-minor
        // BatchTapeProgram.  K=8 runs the single wide program, the
        // large K runs the tiled thread-per-tile engine — the two
        // engine shapes NUTS actually uses at those widths.
        fn time_batch<BP: BatchPotential>(
            pot: &mut BP,
            z0: &[f64],
            u: &mut [f64],
            g: &mut [f64],
            evals: usize,
        ) -> f64 {
            let t0 = std::time::Instant::now();
            for _ in 0..evals {
                pot.value_and_grad_batch(z0, u, g);
            }
            t0.elapsed().as_secs_f64() * 1e3 / evals as f64
        }
        let (bn, bd) = if settings.quick { (400, 8) } else { (1000, 16) };
        let bset = data::make_covtype_like(settings.seed ^ 0x0B47, bn, bd);
        let bmodel = LogisticModel {
            x: bset.x,
            y: bset.y,
            n: bn,
            d: bd,
        };
        let blayout = SiteLayout::trace(&bmodel, settings.seed)?;
        let bdim = blayout.dim;
        let ks: &[usize] = if settings.quick { &[8, 32] } else { &[8, 512] };
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut lane_rows: Vec<Json> = Vec::new();
        for &k in ks {
            let mut zrng = Rng::new(settings.seed ^ 0x0B17 ^ k as u64);
            let z0: Vec<f64> = (0..bdim * k).map(|_| 0.05 * zrng.normal()).collect();
            let mut u_o = vec![0.0; k];
            let mut g_o = vec![0.0; bdim * k];
            let mut u_i = vec![0.0; k];
            let mut g_i = vec![0.0; bdim * k];
            let evals = if settings.quick { 24 } else { 64 };
            // warm both engines (record + freeze + plan build), check
            // the optimizer engaged, then time steady-state sweeps
            let (opt_ms, int_ms, engaged) = if k > 64 {
                let tile = auto_tile_width(k, threads);
                let mut on = tiled_from_layout(&bmodel, &blayout, k, tile);
                let mut off = tiled_from_layout(&bmodel, &blayout, k, tile);
                off.set_optimized(false);
                on.value_and_grad_batch(&z0, &mut u_o, &mut g_o);
                off.value_and_grad_batch(&z0, &mut u_i, &mut g_i);
                let engaged = on.is_optimized() && !off.is_optimized();
                (
                    time_batch(&mut on, &z0, &mut u_o, &mut g_o, evals),
                    time_batch(&mut off, &z0, &mut u_i, &mut g_i, evals),
                    engaged,
                )
            } else {
                let mut on = compile_batched(bmodel.clone(), settings.seed, k)?;
                let mut off = compile_batched(bmodel.clone(), settings.seed, k)?;
                off.set_optimized(false);
                on.value_and_grad_batch(&z0, &mut u_o, &mut g_o);
                off.value_and_grad_batch(&z0, &mut u_i, &mut g_i);
                let engaged = on.is_optimized() && !off.is_optimized();
                (
                    time_batch(&mut on, &z0, &mut u_o, &mut g_o, evals),
                    time_batch(&mut off, &z0, &mut u_i, &mut g_i, evals),
                    engaged,
                )
            };
            // the timed sweeps re-evaluate the same z0, so the warmup
            // results left in the buffers are exactly comparable
            let bitwise = u_o
                .iter()
                .zip(&u_i)
                .all(|(a, b)| a.to_bits() == b.to_bits())
                && g_o.iter().zip(&g_i).all(|(a, b)| a.to_bits() == b.to_bits());
            anyhow::ensure!(
                bitwise,
                "optimized batch plan diverged bitwise from the frozen batch interpreter at \
                 K={k} on the compiled logistic"
            );
            anyhow::ensure!(
                engaged,
                "batched optimizer state wrong at K={k}: expected on-engine optimized and \
                 off-engine interpreted"
            );
            let speedup = int_ms / opt_ms.max(1e-12);
            report.push_str(&format!(
                "  K={k:4}: optimized {:.6} ms/eval/lane | interpreted {:.6} ms/eval/lane \
                 -> {speedup:.2}x (bitwise equal: {bitwise})\n",
                opt_ms / k as f64,
                int_ms / k as f64
            ));
            lane_rows.push(jobj(vec![
                ("k", jnum(k as f64)),
                ("interpreted_ms_per_eval_per_lane", jnum(int_ms / k as f64)),
                ("optimized_ms_per_eval_per_lane", jnum(opt_ms / k as f64)),
                ("opt_speedup_vs_interpreted", jnum(speedup)),
                ("opt_bitwise_equal", Json::Bool(bitwise)),
            ]));
        }
        report.push('\n');
        jobj(vec![
            ("models", Json::Obj(opt_rows)),
            (
                "batched",
                jobj(vec![
                    ("n", jnum(bn as f64)),
                    ("d", jnum(bd as f64)),
                    ("lanes", Json::Arr(lane_rows)),
                ]),
            ),
            // every scalar probe and batched lane comparison above is a
            // fatal ensure!, so this flag cannot be published as true
            // unless every path actually matched the interpreter
            ("opt_bitwise_equal", Json::Bool(true)),
        ])
    };

    // --- robustness overhead: containment + checkpoint bookkeeping ---
    // The fault-contained runner threads every draw through a
    // ChainCursor (divergence quarantine accounting, wall-clock budget
    // checks, checkpoint cadence counter).  With no checkpoint path
    // configured there is no I/O in the loop, so the delta vs the plain
    // runner is exactly the steady-state price of containment — the
    // acceptance bar is < 1% ms/leapfrog.
    let robustness_json = {
        report.push_str("== robustness overhead (containment + checkpoint bookkeeping) ==\n");
        let (rn, rd) = if settings.quick { (800, 16) } else { (2000, 16) };
        let dset = data::make_covtype_like(settings.seed ^ 0xB057, rn, rd);
        let model = LogisticModel {
            x: dset.x,
            y: dset.y,
            n: rn,
            d: rd,
        };
        let eps = 1e-3;

        // raw: the plain single-chain runner (fixed eps, full-depth
        // trees — same protocol as the ms/leapfrog rows above)
        let mut raw_sampler = NativeSampler::new(
            compile(model.clone(), settings.seed)?,
            TreeAlgorithm::Iterative,
            TIMING_DEPTH,
        );
        let (raw_ms, raw_lf) =
            time_fixed_eps(&mut raw_sampler, eps, timing_draws, settings.seed)?;

        // checked: identical draw count through the checkpoint-capable
        // runner; path=None keeps serialization out of the measurement
        let opts = NutsOptions {
            num_warmup: 0,
            num_samples: timing_draws,
            target_accept: 0.8,
            init_step_size: eps,
            fixed_step_size: Some(eps),
            adapt_mass: false,
            seed: settings.seed,
        };
        let cfg = CheckpointConfig {
            path: None,
            resume: false,
            every: 64,
            max_seconds: None,
        };
        let mut chk_sampler = NativeSampler::new(
            compile(model.clone(), settings.seed)?,
            TreeAlgorithm::Iterative,
            TIMING_DEPTH,
        );
        let (chk_res, _) = run_chains_checkpointed(&mut chk_sampler, 1, &opts, &cfg)?;
        let chk_ms = chk_res[0].ms_per_leapfrog();

        let overhead = chk_ms / raw_ms.max(1e-12) - 1.0;
        report.push_str(&format!(
            "  logistic n={rn} d={rd}: raw {raw_ms:.5} ms/leapfrog | checked {chk_ms:.5} \
             ms/leapfrog -> overhead {:+.2}%\n",
            100.0 * overhead
        ));
        if overhead > 0.01 {
            report.push_str(&format!(
                "  WARNING: robustness overhead {:.2}% > 1% — containment checks or \
                 checkpoint bookkeeping regressed the hot path\n",
                100.0 * overhead
            ));
        }
        report.push('\n');
        jobj(vec![
            ("model", Json::Str("logistic".to_string())),
            ("n", jnum(rn as f64)),
            ("d", jnum(rd as f64)),
            ("timing_leapfrogs", jnum(raw_lf as f64)),
            ("ms_per_eval_raw", jnum(raw_ms)),
            ("ms_per_eval_checked", jnum(chk_ms)),
            ("overhead_frac", jnum(overhead)),
        ])
    };

    // --- observability overhead: flight recorder on vs off ---
    // When disabled the recorder is one relaxed atomic-pointer load per
    // draw; when enabled it only stores values the sampler already
    // computed.  Both contracts are gated here: the on/off runs must be
    // bitwise identical (fatal — the recorder may not consume RNG or
    // reorder floating-point work), and the ms/leapfrog delta must stay
    // under 1% (warning, not fatal, to keep shared-runner noise from
    // flaking the bench).
    let observability_json = {
        report.push_str("== observability overhead (flight recorder on vs off) ==\n");
        let (obn, obd) = if settings.quick { (800, 16) } else { (2000, 16) };
        let dset = data::make_covtype_like(settings.seed ^ 0x0B5E, obn, obd);
        let model = LogisticModel {
            x: dset.x,
            y: dset.y,
            n: obn,
            d: obd,
        };
        let eps = 1e-3;
        let opts = NutsOptions {
            num_warmup: 0,
            num_samples: timing_draws,
            target_accept: 0.8,
            init_step_size: eps,
            fixed_step_size: Some(eps),
            adapt_mass: false,
            seed: settings.seed,
        };

        // off: make sure no registry is installed, then run the plain
        // single-chain protocol (same as the ms/leapfrog rows above)
        crate::obs::uninstall();
        let mut off_sampler = NativeSampler::new(
            compile(model.clone(), settings.seed)?,
            TreeAlgorithm::Iterative,
            TIMING_DEPTH,
        );
        let init = vec![0.1; off_sampler.dim()];
        let off_res = run_chain(&mut off_sampler, &init, &opts)?;
        let off_ms = off_res.ms_per_leapfrog();

        // on: install a live registry *before* constructing the sampler
        // so every workspace picks up the enabled recorder handle
        crate::obs::install();
        let mut on_sampler = NativeSampler::new(
            compile(model.clone(), settings.seed)?,
            TreeAlgorithm::Iterative,
            TIMING_DEPTH,
        );
        let on_res = run_chain(&mut on_sampler, &init, &opts)?;
        crate::obs::uninstall();
        let on_ms = on_res.ms_per_leapfrog();

        anyhow::ensure!(
            off_res.samples.len() == on_res.samples.len()
                && off_res
                    .samples
                    .iter()
                    .zip(&on_res.samples)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
                && off_res.sample_leapfrogs == on_res.sample_leapfrogs,
            "flight recorder perturbed the sample path: recorder-on draws are not \
             bitwise identical to recorder-off (n={obn} d={obd} draws={timing_draws})"
        );

        let overhead = on_ms / off_ms.max(1e-12) - 1.0;
        report.push_str(&format!(
            "  logistic n={obn} d={obd}: off {off_ms:.5} ms/leapfrog | on {on_ms:.5} \
             ms/leapfrog -> overhead {:+.2}% (bitwise equal)\n",
            100.0 * overhead
        ));
        if overhead > 0.01 {
            report.push_str(&format!(
                "  WARNING: recorder overhead {:.2}% > 1% — instrumentation regressed \
                 the hot path\n",
                100.0 * overhead
            ));
        }
        report.push('\n');
        jobj(vec![
            ("model", Json::Str("logistic".to_string())),
            ("n", jnum(obn as f64)),
            ("d", jnum(obd as f64)),
            ("timing_leapfrogs", jnum(off_res.sample_leapfrogs as f64)),
            ("recorder_off_ms_per_leapfrog", jnum(off_ms)),
            ("recorder_on_ms_per_leapfrog", jnum(on_ms)),
            ("overhead_frac", jnum(overhead)),
            // the ensure! above aborts the bench on any divergence, and
            // rust/tests/observability.rs pins the same contract across
            // every chain method plus SVI and subsampled SVI
            ("recorder_bitwise_equal", Json::Bool(true)),
        ])
    };

    // --- native SVI: reparameterized ADVI over the frozen tape ---
    // 1. ms/step with the K particles evaluated as a scalar-potential
    //    loop vs one fused multi-lane sweep (`svi_particle_batch_speedup`
    //    is the acceptance datapoint, K = 8).  Both backends consume the
    //    same RNG stream, so their ELBO traces must agree bitwise — the
    //    bench asserts it.
    // 2. posterior agreement: the fitted guide's means on the logistic
    //    zoo model vs NUTS means, per parameter, within 6x the NUTS
    //    Monte-Carlo standard error.
    let svi_json = {
        report.push_str("== native SVI (reparameterized ADVI, mean-field guide) ==\n");
        let (sn, sdim) = if settings.quick { (400, 8) } else { (1000, 8) };
        let dset = data::make_covtype_like(settings.seed ^ 0x51A, sn, sdim);
        let model = LogisticModel {
            x: dset.x,
            y: dset.y,
            n: sn,
            d: sdim,
        };
        let steps = if settings.quick { 60 } else { 250 };
        let mut fields: Vec<(&str, Json)> = vec![
            ("model", Json::Str("logistic".to_string())),
            ("n", jnum(sn as f64)),
            ("d", jnum(sdim as f64)),
            ("steps", jnum(steps as f64)),
        ];
        let mut rows: Vec<Json> = Vec::new();
        let mut final_speedup = f64::NAN;
        for &k in &[4usize, 8] {
            // drive the step loop directly so the one-time tape
            // record+freeze (the first step) stays OUTSIDE the timed
            // window — the per-step numbers measure the steady state
            let opts = SviOptions {
                num_steps: steps + 1,
                num_particles: k,
                lr: 0.02,
                seed: settings.seed,
                optimizer: OptimKind::Adam,
                schedule: StepSchedule::Constant,
                vectorize_particles: false,
                convergence: None,
                tail_average: 0.0,
            };
            let spot = compile(model.clone(), settings.seed)?;
            let mut s_svi = NativeSvi::new(ScalarParticles::new(spot, k), &opts)?;
            s_svi.step();
            let t0 = std::time::Instant::now();
            for _ in 0..steps {
                s_svi.step();
            }
            let scalar_ms = 1e3 * t0.elapsed().as_secs_f64() / steps as f64;

            let bpot = compile_batched(model.clone(), settings.seed, k)?;
            let mut b_svi = NativeSvi::new(BatchedParticles::new(bpot), &opts)?;
            b_svi.step();
            let t0 = std::time::Instant::now();
            for _ in 0..steps {
                b_svi.step();
            }
            let batched_ms = 1e3 * t0.elapsed().as_secs_f64() / steps as f64;

            let equal = s_svi
                .elbo_trace()
                .iter()
                .zip(b_svi.elbo_trace())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            anyhow::ensure!(
                equal,
                "scalar and batched particle ELBOs diverged bitwise at K={k} — \
                 the lanes must reproduce the scalar loop exactly"
            );
            let speedup = scalar_ms / batched_ms.max(1e-12);
            report.push_str(&format!(
                "  {k} particles: scalar {scalar_ms:.4} ms/step | batched {batched_ms:.4} ms/step \
                 -> {speedup:.2}x (bitwise equal: {equal})\n"
            ));
            rows.push(jobj(vec![
                ("particles", jnum(k as f64)),
                ("scalar_ms_per_step", jnum(scalar_ms)),
                ("batched_ms_per_step", jnum(batched_ms)),
                ("svi_particle_batch_speedup", jnum(speedup)),
                ("bitwise_equal", Json::Bool(equal)),
            ]));
            final_speedup = speedup;
        }
        fields.push(("particle_rows", Json::Arr(rows)));
        if final_speedup.is_finite() {
            fields.push(("svi_particle_batch_speedup", jnum(final_speedup)));
        }
        if final_speedup <= 1.0 {
            report.push_str(&format!(
                "  WARNING: svi_particle_batch_speedup = {final_speedup:.2} <= 1.0 — \
                 fused particle lanes regressed below the scalar loop\n"
            ));
        }

        // ELBO-vs-NUTS posterior agreement on a chain-test-sized
        // logistic model (identity transforms: guide locs are the
        // posterior means directly)
        let (an, ad) = (120, 3);
        let aset = data::make_covtype_like(settings.seed ^ 0xA91, an, ad);
        let amodel = LogisticModel {
            x: aset.x,
            y: aset.y,
            n: an,
            d: ad,
        };
        let (nwarm, nsamp) = settings.budget(200, 400);
        let nopts = NutsOptions {
            num_warmup: nwarm,
            num_samples: nsamp,
            seed: settings.seed,
            ..Default::default()
        };
        let (_, nuts) =
            run_compiled_chains_method(&amodel, ChainMethod::Vectorized, 4, 10, &nopts)?;
        let svi_steps = if settings.quick { 1200 } else { 3000 };
        let sopts = SviOptions {
            num_steps: svi_steps,
            num_particles: 8,
            lr: 0.05,
            seed: settings.seed,
            optimizer: OptimKind::Adam,
            schedule: StepSchedule::ExponentialDecay {
                rate: 0.02,
                over: svi_steps,
            },
            vectorize_particles: true,
            convergence: None,
            tail_average: 0.25,
        };
        let (layout, fit) = run_svi_native(&amodel, &sopts)?;
        let dim = layout.dim;
        let pooled: Vec<Vec<f64>> = nuts.iter().map(|r| r.samples.clone()).collect();
        let nuts_rows = summarize(&pooled, dim, &[]);
        let mut agree = true;
        let mut max_over_mcse = 0.0f64;
        for (d, row) in nuts_rows.iter().enumerate() {
            let mcse = row.sd / row.ess.max(4.0).sqrt();
            let diff = (fit.guide.loc()[d] - row.mean).abs();
            max_over_mcse = max_over_mcse.max(diff / mcse.max(1e-12));
            if diff > 6.0 * mcse + 1e-3 {
                agree = false;
            }
        }
        let final_elbo = fit.final_elbo(100);
        report.push_str(&format!(
            "  posterior agreement (logistic n={an} d={ad}): max |SVI - NUTS| / MCSE = \
             {max_over_mcse:.2} -> within 6x MCSE: {agree} | final ELBO {final_elbo:.3}\n\n"
        ));
        if !agree {
            report.push_str(
                "  WARNING: native SVI means disagree with NUTS beyond 6x MCSE on the logistic model\n",
            );
        }
        fields.push((
            "agreement",
            jobj(vec![
                ("n", jnum(an as f64)),
                ("d", jnum(ad as f64)),
                ("nuts_chains", jnum(4.0)),
                ("svi_steps", jnum(svi_steps as f64)),
                ("max_abs_diff_over_mcse", jnum(max_over_mcse)),
                ("agrees_within_6_mcse", Json::Bool(agree)),
                ("final_elbo", jnum(final_elbo)),
            ]),
        ));
        jobj(fields)
    };

    // --- subsampling: minibatch SVI over streaming data ---
    // Throughput of the minibatch engine on the on-demand synthetic
    // logistic stream (resident memory O(B*D) regardless of N), plus
    // the identity gate: B = N through the subsampled path must be
    // bitwise equal to the plain full-batch SVI path.
    let subsampling_json = {
        use crate::compile::SubsampledLogistic;
        use crate::coordinator::run_svi_subsampled;
        use crate::data::{InMemoryRows, SyntheticLogisticStream};

        report.push_str("== subsampling (minibatch SVI, streaming data) ==\n");

        // identity gate first: it is the correctness contract the
        // throughput number rests on
        let (gn, gd) = (200, 4);
        let gset = data::make_covtype_like(settings.seed ^ 0x5B5A, gn, gd);
        let gopts = SviOptions {
            num_steps: if settings.quick { 40 } else { 120 },
            num_particles: 8,
            lr: 0.05,
            seed: settings.seed,
            optimizer: OptimKind::Adam,
            schedule: StepSchedule::Constant,
            vectorize_particles: true,
            convergence: None,
            tail_average: 0.0,
        };
        let full_model = LogisticModel {
            x: gset.x.clone(),
            y: gset.y.clone(),
            n: gn,
            d: gd,
        };
        let sub_model =
            SubsampledLogistic::new(InMemoryRows::new(gset.x, gset.y, gn, gd), gn);
        let (_, full_fit) = run_svi_native(&full_model, &gopts)?;
        let (_, sub_fit) = run_svi_subsampled(&sub_model, &gopts)?;
        let full_batch_equal = full_fit
            .elbo_trace
            .iter()
            .zip(&sub_fit.elbo_trace)
            .all(|(a, b)| a.to_bits() == b.to_bits())
            && full_fit
                .guide
                .params()
                .iter()
                .zip(sub_fit.guide.params())
                .all(|(a, b)| a.to_bits() == b.to_bits());
        anyhow::ensure!(
            full_batch_equal,
            "subsampled SVI with B = N diverged bitwise from the plain full-batch path — \
             the minibatch machinery must be invisible at full batch"
        );
        report.push_str(&format!(
            "  identity gate (n={gn} d={gd}, B=N): bitwise equal to full-batch path: \
             {full_batch_equal}\n"
        ));

        // throughput: streaming synthetic logistic, minibatch B per step
        let (rows, dim_s, batch) = if settings.quick {
            (100_000, 8, 256)
        } else {
            (1_000_000, 8, 1024)
        };
        let steps = if settings.quick { 40 } else { 150 };
        let loader = SyntheticLogisticStream::new(settings.seed ^ 0x10C1, rows, dim_s);
        let model = SubsampledLogistic::new(loader, batch);
        let opts = SviOptions {
            num_steps: steps,
            num_particles: 8,
            lr: 0.02,
            seed: settings.seed,
            optimizer: OptimKind::Adam,
            schedule: StepSchedule::Constant,
            vectorize_particles: true,
            convergence: None,
            tail_average: 0.0,
        };
        let t0 = std::time::Instant::now();
        let (_, fit) = run_svi_subsampled(&model, &opts)?;
        let wall_s = t0.elapsed().as_secs_f64();
        let ms_per_step = 1e3 * wall_s / fit.steps.max(1) as f64;
        let rows_per_sec = (fit.steps * batch) as f64 / wall_s.max(1e-12);
        report.push_str(&format!(
            "  streaming logistic N={rows} D={dim_s} B={batch}: {} steps in {wall_s:.3}s \
             -> {ms_per_step:.3} ms/step, {rows_per_sec:.0} rows/s (scale N/B = {:.0})\n\n",
            fit.steps,
            rows as f64 / batch as f64
        ));
        jobj(vec![
            ("model", Json::Str("logistic_stream".to_string())),
            ("rows", jnum(rows as f64)),
            ("d", jnum(dim_s as f64)),
            ("batch", jnum(batch as f64)),
            ("particles", jnum(8.0)),
            ("steps", jnum(fit.steps as f64)),
            ("wall_s", jnum(wall_s)),
            ("ms_per_step", jnum(ms_per_step)),
            ("rows_per_sec", jnum(rows_per_sec)),
            ("likelihood_scale", jnum(rows as f64 / batch as f64)),
            ("full_batch_bitwise_equal", Json::Bool(full_batch_equal)),
        ])
    };

    // --- lane scaling: the tiled massive-lane engine ---
    // ms/leapfrog-per-lane of the two-level (tile-per-thread x
    // micro-lane SIMD) engine across the K sweep, with a bitwise
    // equality gate against the single-program BatchTape at every K
    let lane_scaling_json = {
        report.push_str("lane scaling — tiled massive-lane engine (compiled logistic)\n");
        let (ln, ld) = if settings.quick { (400, 8) } else { (1000, 16) };
        let dset = data::make_covtype_like(settings.seed ^ 0xA4E, ln, ld);
        let model = LogisticModel {
            x: dset.x,
            y: dset.y,
            n: ln,
            d: ld,
        };
        let layout = SiteLayout::trace(&model, settings.seed)?;
        let dim = layout.dim;
        let ks: &[usize] = if settings.quick {
            &[8, 32, 128]
        } else {
            &[8, 32, 128, 512, 1024]
        };
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut rows: Vec<Json> = Vec::new();
        let mut per_lane: BTreeMap<usize, f64> = BTreeMap::new();
        for &k in ks {
            let tile = auto_tile_width(k, threads);
            let mut tiled = tiled_from_layout(&model, &layout, k, tile);
            let mut wide = compile_batched(model.clone(), settings.seed, k)?;

            // deterministic lane-minor state shared by both engines
            let mut zrng = Rng::new(settings.seed ^ 0x1A7E ^ k as u64);
            let z0: Vec<f64> = (0..dim * k).map(|_| 0.05 * zrng.normal()).collect();
            let mut u_t = vec![0.0; k];
            let mut g_t = vec![0.0; dim * k];
            let mut u_w = vec![0.0; k];
            let mut g_w = vec![0.0; dim * k];
            tiled.value_and_grad_batch(&z0, &mut u_t, &mut g_t);
            wide.value_and_grad_batch(&z0, &mut u_w, &mut g_w);
            let mut bitwise = u_t
                .iter()
                .zip(&u_w)
                .all(|(a, b)| a.to_bits() == b.to_bits())
                && g_t.iter().zip(&g_w).all(|(a, b)| a.to_bits() == b.to_bits());

            // one full NUTS transition per engine with identical
            // per-lane RNG streams: the proposals must agree bit for bit
            let mut ws = BatchTreeWorkspace::new(dim, k, TIMING_DEPTH);
            let inv_mass = vec![1.0; dim * k];
            let step_szs = vec![1e-2; k];
            let mut stats = vec![
                DrawStats {
                    accept_prob: 0.0,
                    num_leapfrog: 0,
                    potential: 0.0,
                    diverging: false,
                    depth: 0,
                    poisoned: false,
                };
                k
            ];
            let mut rngs_t: Vec<Rng> =
                (0..k).map(|j| Rng::new(settings.seed + j as u64)).collect();
            let mut rngs_w: Vec<Rng> =
                (0..k).map(|j| Rng::new(settings.seed + j as u64)).collect();
            draw_batch(
                &mut tiled,
                &mut rngs_t,
                &mut ws,
                &z0,
                &step_szs,
                &inv_mass,
                TIMING_DEPTH,
                &mut stats,
            );
            let prop_t = ws.proposal().to_vec();
            draw_batch(
                &mut wide,
                &mut rngs_w,
                &mut ws,
                &z0,
                &step_szs,
                &inv_mass,
                TIMING_DEPTH,
                &mut stats,
            );
            bitwise &= ws
                .proposal()
                .iter()
                .zip(&prop_t)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            anyhow::ensure!(
                bitwise,
                "tiled engine diverged bitwise from the single-program BatchTape at K={k} \
                 on the compiled logistic — every lane must be exactly a scalar chain"
            );

            // timed draws through the tiled engine (small fixed eps →
            // full 2^depth trees, so leapfrog counts are stable)
            let draws = if settings.quick { 2 } else { 4 };
            let e0 = tiled.num_evals();
            let t0 = std::time::Instant::now();
            for _ in 0..draws {
                draw_batch(
                    &mut tiled,
                    &mut rngs_t,
                    &mut ws,
                    &z0,
                    &step_szs,
                    &inv_mass,
                    TIMING_DEPTH,
                    &mut stats,
                );
            }
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let evals = (tiled.num_evals() - e0).max(1);
            let ms_lf_lane = wall_ms / evals as f64 / k as f64;
            per_lane.insert(k, ms_lf_lane);
            report.push_str(&format!(
                "  K={k:5} tile={tile:4} threads={threads}: {ms_lf_lane:.6} ms/leapfrog/lane \
                 over {evals} batched leapfrogs (bitwise equal: {bitwise})\n"
            ));
            rows.push(jobj(vec![
                ("k", jnum(k as f64)),
                ("tile", jnum(tile as f64)),
                ("threads", jnum(threads as f64)),
                ("batched_leapfrogs", jnum(evals as f64)),
                ("ms_per_leapfrog_per_lane", jnum(ms_lf_lane)),
                ("tiled_bitwise_equal", Json::Bool(bitwise)),
            ]));
        }
        let ratio = match (per_lane.get(&512), per_lane.get(&8)) {
            (Some(a), Some(b)) if *b > 0.0 => a / b,
            _ => f64::NAN,
        };
        if ratio.is_finite() {
            report.push_str(&format!(
                "  per-lane cost ratio K=512 / K=8: {ratio:.2}x (target < 2x)\n"
            ));
        }
        report.push('\n');
        let mut fields = vec![
            ("n", jnum(ln as f64)),
            ("d", jnum(ld as f64)),
            ("lanes", Json::Arr(rows)),
            // the per-K ensure! above aborts the bench on any divergence,
            // and rust/tests/lane_scaling.rs pins the same contract across
            // random models, seeds, K and tile widths
            ("tiled_bitwise_equal", Json::Bool(true)),
        ];
        if ratio.is_finite() {
            fields.push(("per_lane_ratio_512_vs_8", jnum(ratio)));
        }
        jobj(fields)
    };

    let root = Json::Obj(
        [
            ("schema".to_string(), Json::Str("fugue-bench-native/v1".to_string())),
            ("seed".to_string(), jnum(settings.seed as f64)),
            ("quick".to_string(), Json::Bool(settings.quick)),
            ("max_chains".to_string(), jnum(max_chains as f64)),
            ("frozen_vs_replay".to_string(), Json::Obj(frozen_rows)),
            ("tape_opt".to_string(), tape_opt_json),
            ("robustness_overhead".to_string(), robustness_json),
            ("observability_overhead".to_string(), observability_json),
            ("svi_native".to_string(), svi_json),
            ("subsampling".to_string(), subsampling_json),
            ("lane_scaling".to_string(), lane_scaling_json),
            ("models".to_string(), Json::Obj(models)),
        ]
        .into_iter()
        .collect::<BTreeMap<String, Json>>(),
    );
    std::fs::write(out_path, root.to_string_pretty())?;
    report.push_str(&format!("[saved {out_path}]\n"));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_logistic_matches_optimized_density() {
        let dset = data::make_covtype_like(3, 60, 4);
        let mut base = BaselineLogistic::new(dset.x.clone(), dset.y.clone(), 60, 4);
        let mut opt = LogisticNative::new(dset.x, dset.y, 60, 4);
        let z = [0.2, -0.4, 0.7, 0.05, -0.3];
        let mut gb = vec![0.0; 5];
        let mut go = vec![0.0; 5];
        let ub = base.value_and_grad(&z, &mut gb);
        let uo = opt.value_and_grad(&z, &mut go);
        assert!((ub - uo).abs() < 1e-9 * (1.0 + ub.abs()), "{ub} vs {uo}");
        for i in 0..5 {
            assert!((gb[i] - go[i]).abs() < 1e-9 * (1.0 + gb[i].abs()));
        }
    }
}
