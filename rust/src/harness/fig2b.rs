//! Fig 2b: time (ms) per effective sample for SKIM as dimensionality p
//! varies (E3).  Paper protocol: N = 200, p swept, 1000 warmup + 1000
//! draws, time/ESS averaged over runs; Stan vs NumPyro.
//!
//! Shape check: the fused (NumPyro-architecture) series sits below the
//! native (Stan-architecture) series at every p — "consistently lower
//! overhead" — with both growing in p.

use anyhow::Result;

use crate::config::Settings;
use crate::coordinator::{run_chain, NutsOptions};
use crate::diagnostics::summary::{mean_ess, min_ess, summarize};
use crate::harness::builders::{build_sampler, init_z, Backend, Workload};
use crate::runtime::engine::Engine;

pub struct Point {
    pub p: usize,
    pub backend: &'static str,
    pub ms_per_ess: f64,
    pub mean_ess: f64,
    pub sample_secs: f64,
}

fn measure(
    engine: &Engine,
    model: &str,
    p: usize,
    backend: Backend,
    dtype: &str,
    warmup: usize,
    samples: usize,
    settings: &Settings,
) -> Result<Point> {
    let workload = Workload::for_model(engine, model, settings.seed)?;
    let mut sampler = build_sampler(engine, model, backend, dtype, &workload, settings.max_tree_depth)?;
    let dim = sampler.dim();
    let opts = NutsOptions {
        num_warmup: warmup,
        num_samples: samples,
        target_accept: settings.target_accept,
        init_step_size: 0.1,
        fixed_step_size: None,
        adapt_mass: true,
        seed: settings.seed,
    };
    let res = run_chain(&mut sampler, &init_z(dim, settings.seed), &opts)?;
    let rows = summarize(&[res.samples.clone()], dim, &[]);
    let ess = min_ess(&rows).max(1.0);
    Ok(Point {
        p,
        backend: backend.paper_name(),
        ms_per_ess: 1e3 * res.sample_secs / ess,
        mean_ess: mean_ess(&rows),
        sample_secs: res.sample_secs,
    })
}

pub fn run(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig 2b — SKIM: time (ms) per effective sample vs dimensionality p\n");
    out.push_str("(paper: NumPyro consistently below Stan; both grow with p)\n\n");
    let (warmup, samples) = settings.budget(1000, 1000);
    out.push_str(&format!("warmup {warmup}, draws {samples}\n"));
    out.push_str(&format!(
        "{:>6} {:<26} {:>12} {:>10} {:>10}\n",
        "p", "backend", "ms/ESS(min)", "mean ESS", "sample s"
    ));

    // sweep every skim_p* model present in the manifest
    let mut ps: Vec<usize> = engine
        .manifest
        .models()
        .iter()
        .filter_map(|m| m.strip_prefix("skim_p").and_then(|s| s.parse().ok()))
        .collect();
    ps.sort_unstable();
    if settings.quick {
        ps.truncate(2);
    }

    let mut series: Vec<Point> = Vec::new();
    for &p in &ps {
        let model = format!("skim_p{p}");
        for (backend, dtype) in [(Backend::Native, "f64"), (Backend::Fused, "f32")] {
            match measure(engine, &model, p, backend, dtype, warmup, samples, settings) {
                Ok(pt) => {
                    out.push_str(&format!(
                        "{:>6} {:<26} {:>12.3} {:>10.1} {:>10.3}\n",
                        pt.p, pt.backend, pt.ms_per_ess, pt.mean_ess, pt.sample_secs
                    ));
                    series.push(pt);
                }
                Err(e) => out.push_str(&format!("{p:>6} {}: failed: {e:#}\n", backend.paper_name())),
            }
        }
    }

    // shape check: fused below native at each p
    let mut wins = 0;
    let mut total = 0;
    for &p in &ps {
        let native = series.iter().find(|s| s.p == p && s.backend.contains("native"));
        let fused = series.iter().find(|s| s.p == p && s.backend.contains("fused"));
        if let (Some(n), Some(f)) = (native, fused) {
            total += 1;
            if f.ms_per_ess < n.ms_per_ess {
                wins += 1;
            }
        }
    }
    out.push_str(&format!(
        "\n-> fused wins on {wins}/{total} dimensionalities (paper: all)\n"
    ));
    Ok(out)
}
