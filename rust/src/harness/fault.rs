//! Deterministic fault injection — the adversary half of the
//! fault-containment layer.
//!
//! A [`FaultPlan`] names exactly *which* gradient evaluations get
//! corrupted and *how*: a forward-sweep fault replaces the returned
//! potential `U` with NaN/±Inf, an adjoint-sweep fault poisons one
//! gradient coordinate.  The plan is driven purely by the wrapper's own
//! evaluation counter, so a given (plan, model, seed) triple injects
//! the identical fault sequence on every run — the chaos suite
//! (`rust/tests/chaos.rs`) relies on this to compare faulted runs
//! against clean ones bitwise.
//!
//! The wrappers sit **outside** the tape: [`FaultyPotential`] and
//! [`FaultyBatchPotential`] decorate any [`Potential`] /
//! [`BatchPotential`] after its (frozen, audited) sweep has finished.
//! That exercises the exact containment surface production code has —
//! a non-finite `U`/gradient arriving at the sampler — without
//! invalidating the frozen-tape bitwise audit against the interpreter.

use crate::mcmc::{BatchPotential, Potential};
use crate::rng::Rng;

/// Which half of the gradient evaluation the fault corrupts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Replace the returned potential `U` (forward sweep output).
    Forward,
    /// Poison gradient coordinate `index % dim` (adjoint sweep output).
    Adjoint { index: usize },
}

/// One scheduled injection.
#[derive(Debug, Clone)]
pub struct Fault {
    /// 0-based index of the `value_and_grad` call to corrupt, counted
    /// by the wrapper itself.
    pub at_eval: u64,
    pub site: FaultSite,
    /// The corrupting value (NaN, +Inf, -Inf — anything non-finite).
    pub value: f64,
    /// Batch wrappers only: restrict the fault to one lane
    /// (`None` poisons every lane of the targeted evaluation).
    pub lane: Option<usize>,
}

/// A deterministic schedule of injections.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// NaN the forward sweep at each listed evaluation.
    pub fn nan_forward_at(evals: &[u64]) -> FaultPlan {
        FaultPlan {
            faults: evals
                .iter()
                .map(|&e| Fault {
                    at_eval: e,
                    site: FaultSite::Forward,
                    value: f64::NAN,
                    lane: None,
                })
                .collect(),
        }
    }

    /// +Inf the forward sweep at each listed evaluation.
    pub fn inf_forward_at(evals: &[u64]) -> FaultPlan {
        FaultPlan {
            faults: evals
                .iter()
                .map(|&e| Fault {
                    at_eval: e,
                    site: FaultSite::Forward,
                    value: f64::INFINITY,
                    lane: None,
                })
                .collect(),
        }
    }

    /// NaN one adjoint (gradient) coordinate at each listed evaluation.
    pub fn nan_adjoint_at(evals: &[u64], index: usize) -> FaultPlan {
        FaultPlan {
            faults: evals
                .iter()
                .map(|&e| Fault {
                    at_eval: e,
                    site: FaultSite::Adjoint { index },
                    value: f64::NAN,
                    lane: None,
                })
                .collect(),
        }
    }

    /// NaN the forward sweep of a single lane at one evaluation — the
    /// lane-quarantine scenario.
    pub fn lane_nan_forward(at_eval: u64, lane: usize) -> FaultPlan {
        FaultPlan {
            faults: vec![Fault {
                at_eval,
                site: FaultSite::Forward,
                value: f64::NAN,
                lane: Some(lane),
            }],
        }
    }

    /// `n` seeded, reproducible faults with evaluation indices drawn
    /// uniformly from `[0, eval_range)`, alternating forward/adjoint
    /// sites and NaN/+Inf values.  Same seed → same plan, always.
    pub fn seeded(seed: u64, n: usize, eval_range: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let faults = (0..n)
            .map(|i| {
                let at_eval = rng.next_u64() % eval_range.max(1);
                let site = if i % 2 == 0 {
                    FaultSite::Forward
                } else {
                    FaultSite::Adjoint {
                        index: (rng.next_u64() % 64) as usize,
                    }
                };
                Fault {
                    at_eval,
                    site,
                    value: if i % 3 == 0 { f64::INFINITY } else { f64::NAN },
                    lane: None,
                }
            })
            .collect();
        FaultPlan { faults }
    }

    fn fault_for(&self, eval: u64) -> Option<&Fault> {
        self.faults.iter().find(|f| f.at_eval == eval)
    }
}

/// A scalar [`Potential`] with scheduled corruption of its outputs.
pub struct FaultyPotential<P: Potential> {
    inner: P,
    plan: FaultPlan,
    evals: u64,
    /// Faults actually delivered so far (assert on this to prove the
    /// adversary fired).
    pub injected: u64,
}

impl<P: Potential> FaultyPotential<P> {
    pub fn new(inner: P, plan: FaultPlan) -> FaultyPotential<P> {
        FaultyPotential {
            inner,
            plan,
            evals: 0,
            injected: 0,
        }
    }

    /// Total evaluations routed through the wrapper.
    pub fn evals(&self) -> u64 {
        self.evals
    }
}

impl<P: Potential> Potential for FaultyPotential<P> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
        let u = self.inner.value_and_grad(z, grad);
        let e = self.evals;
        self.evals += 1;
        if let Some(f) = self.plan.fault_for(e) {
            self.injected += 1;
            match f.site {
                FaultSite::Forward => return f.value,
                FaultSite::Adjoint { index } => {
                    grad[index % grad.len().max(1)] = f.value;
                }
            }
        }
        u
    }

    fn num_evals(&self) -> u64 {
        self.inner.num_evals()
    }
}

/// A [`BatchPotential`] with scheduled corruption, optionally scoped to
/// a single lane — the adversary the lane-quarantine invariants are
/// proven against.
pub struct FaultyBatchPotential<BP: BatchPotential> {
    inner: BP,
    plan: FaultPlan,
    evals: u64,
    pub injected: u64,
}

impl<BP: BatchPotential> FaultyBatchPotential<BP> {
    pub fn new(inner: BP, plan: FaultPlan) -> FaultyBatchPotential<BP> {
        FaultyBatchPotential {
            inner,
            plan,
            evals: 0,
            injected: 0,
        }
    }

    pub fn evals(&self) -> u64 {
        self.evals
    }
}

impl<BP: BatchPotential> BatchPotential for FaultyBatchPotential<BP> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn lanes(&self) -> usize {
        self.inner.lanes()
    }

    fn value_and_grad_batch(&mut self, z: &[f64], u: &mut [f64], grad: &mut [f64]) {
        self.inner.value_and_grad_batch(z, u, grad);
        let e = self.evals;
        self.evals += 1;
        let (dim, lanes) = (self.inner.dim(), self.inner.lanes());
        if let Some(f) = self.plan.fault_for(e) {
            self.injected += 1;
            let targets: Vec<usize> = match f.lane {
                Some(k) => vec![k % lanes],
                None => (0..lanes).collect(),
            };
            for k in targets {
                match f.site {
                    FaultSite::Forward => u[k] = f.value,
                    FaultSite::Adjoint { index } => {
                        grad[(index % dim.max(1)) * lanes + k] = f.value;
                    }
                }
            }
        }
    }

    fn num_evals(&self) -> u64 {
        self.inner.num_evals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Gauss;
    impl Potential for Gauss {
        fn dim(&self) -> usize {
            2
        }
        fn value_and_grad(&mut self, z: &[f64], grad: &mut [f64]) -> f64 {
            grad.copy_from_slice(z);
            0.5 * (z[0] * z[0] + z[1] * z[1])
        }
    }

    #[test]
    fn corrupts_only_configured_evals() {
        let mut p = FaultyPotential::new(Gauss, FaultPlan::nan_forward_at(&[1]));
        let mut g = [0.0; 2];
        assert!(p.value_and_grad(&[1.0, 1.0], &mut g).is_finite());
        assert!(p.value_and_grad(&[1.0, 1.0], &mut g).is_nan());
        assert!(p.value_and_grad(&[1.0, 1.0], &mut g).is_finite());
        assert_eq!(p.injected, 1);
        assert_eq!(p.evals(), 3);
        // gradient untouched by a forward fault
        assert_eq!(g, [1.0, 1.0]);
    }

    #[test]
    fn adjoint_fault_poisons_one_coordinate() {
        let mut p = FaultyPotential::new(Gauss, FaultPlan::nan_adjoint_at(&[0], 1));
        let mut g = [0.0; 2];
        let u = p.value_and_grad(&[1.0, 2.0], &mut g);
        assert!(u.is_finite(), "forward value untouched by adjoint fault");
        assert_eq!(g[0], 1.0);
        assert!(g[1].is_nan());
    }

    #[test]
    fn lane_fault_leaves_sibling_lanes_untouched() {
        use crate::mcmc::ScalarLanes;
        let mut p = FaultyBatchPotential::new(
            ScalarLanes::new(vec![Gauss, Gauss, Gauss]),
            FaultPlan::lane_nan_forward(0, 1),
        );
        let z = [1.0, 1.0, 1.0, 2.0, 2.0, 2.0]; // lane-minor, dim=2, lanes=3
        let mut u = [0.0; 3];
        let mut g = [0.0; 6];
        p.value_and_grad_batch(&z, &mut u, &mut g);
        assert!(u[0].is_finite());
        assert!(u[1].is_nan());
        assert!(u[2].is_finite());
        assert!(g.iter().all(|x| x.is_finite()), "gradients untouched");
        assert_eq!(p.injected, 1);
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded(42, 5, 1000);
        let b = FaultPlan::seeded(42, 5, 1000);
        assert_eq!(a.faults.len(), 5);
        for (x, y) in a.faults.iter().zip(&b.faults) {
            assert_eq!(x.at_eval, y.at_eval);
            assert_eq!(x.site, y.site);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
        let c = FaultPlan::seeded(43, 5, 1000);
        assert!(
            a.faults.iter().zip(&c.faults).any(|(x, y)| x.at_eval != y.at_eval),
            "different seeds should differ"
        );
    }
}
