//! Footnote 6: effective sample size on the HMM across 5 random seeds,
//! 32-bit vs 64-bit (E4).  Paper: average ESS 652 (Stan), 556
//! (NumPyro-32), 788 (NumPyro-64) — i.e. f64 samples better per draw
//! but slower per second (the Fig 2b trade-off).

use anyhow::Result;

use crate::config::Settings;
use crate::coordinator::{run_chain, NutsOptions};
use crate::diagnostics::summary::{mean_ess, summarize};
use crate::harness::builders::{build_sampler, init_z, Backend, Workload};
use crate::runtime::engine::Engine;

pub fn run(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("Footnote 6 — HMM mean ESS across 5 seeds (1000 warmup + 1000 draws)\n");
    out.push_str("(paper: Stan 652, NumPyro 32-bit 556, NumPyro 64-bit 788)\n\n");
    let (warmup, samples) = settings.budget(1000, 1000);
    let seeds: Vec<u64> = (0..5).map(|i| settings.seed + i).collect();

    let mut table: Vec<(String, Vec<f64>, f64)> = Vec::new();
    let configs: Vec<(&str, Backend, &str)> = vec![
        ("native (Stan arch) f64", Backend::Native, "f64"),
        ("fused (NumPyro arch) f32", Backend::Fused, "f32"),
        ("fused (NumPyro arch) f64", Backend::Fused, "f64"),
    ];

    for (label, backend, dtype) in configs {
        if backend == Backend::Fused
            && engine.manifest.find("hmm", "nuts_step", dtype).is_err()
        {
            continue;
        }
        let mut esses = Vec::new();
        let mut secs = 0.0;
        for &seed in &seeds {
            let mut s = settings.clone();
            s.seed = seed;
            let workload = Workload::for_model(engine, "hmm", seed)?;
            let mut sampler =
                build_sampler(engine, "hmm", backend, dtype, &workload, s.max_tree_depth)?;
            let dim = sampler.dim();
            let opts = NutsOptions {
                num_warmup: warmup,
                num_samples: samples,
                target_accept: s.target_accept,
                seed,
                ..Default::default()
            };
            let res = run_chain(&mut sampler, &init_z(dim, seed), &opts)?;
            let rows = summarize(&[res.samples.clone()], dim, &[]);
            esses.push(mean_ess(&rows));
            secs += res.sample_secs;
        }
        table.push((label.to_string(), esses, secs / seeds.len() as f64));
    }

    out.push_str(&format!(
        "{:<28} {:>10} {:>28} {:>12}\n",
        "config", "mean ESS", "per-seed ESS", "sample s"
    ));
    for (label, esses, secs) in &table {
        let mean = esses.iter().sum::<f64>() / esses.len() as f64;
        let per: Vec<String> = esses.iter().map(|e| format!("{e:.0}")).collect();
        out.push_str(&format!(
            "{:<28} {:>10.0} {:>28} {:>12.2}\n",
            label,
            mean,
            per.join(","),
            secs
        ));
    }

    // shape check: f64 >= f32 in ESS (paper: 788 vs 556)
    let f32_ess = table
        .iter()
        .find(|(l, _, _)| l.contains("f32"))
        .map(|(_, e, _)| e.iter().sum::<f64>() / e.len() as f64);
    let f64_ess = table
        .iter()
        .find(|(l, _, _)| l.contains("fused") && l.contains("f64"))
        .map(|(_, e, _)| e.iter().sum::<f64>() / e.len() as f64);
    if let (Some(a), Some(b)) = (f32_ess, f64_ess) {
        out.push_str(&format!(
            "\n-> fused f64 / f32 ESS ratio = {:.2} (paper: 788/556 = 1.42)\n",
            b / a
        ));
    }
    Ok(out)
}
