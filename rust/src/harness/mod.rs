//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §4 experiment index).
//!
//! | experiment  | paper artifact | module        |
//! |-------------|----------------|---------------|
//! | E1/E2       | Table 2a       | [`table2a`]   |
//! | E3          | Fig 2b         | [`fig2b`]     |
//! | E4          | footnote 6     | [`footnote6`] |
//! | E5          | Fig 1 / App. B | [`fig1`]      |
//! | E6          | Appendix D     | [`appendix_d`]|
//! | E7, E8      | §3.1/§3.2      | [`ablations`] |
//!
//! Every experiment returns a plain-text report (also written under
//! `results/`), with the measured *shape* checks described in
//! EXPERIMENTS.md.

pub mod ablations;
pub mod appendix_d;
pub mod bench_native;
pub mod builders;
pub mod fault;
pub mod fig1;
pub mod fig2b;
pub mod footnote6;
pub mod table2a;

use anyhow::Result;

use crate::config::Settings;

/// Write a report under `results/` and echo it.
pub fn emit(settings: &Settings, name: &str, report: &str) -> Result<()> {
    std::fs::create_dir_all(&settings.results_dir)?;
    let path = format!("{}/{}.txt", settings.results_dir, name);
    std::fs::write(&path, report)?;
    println!("{report}");
    println!("[saved {path}]");
    Ok(())
}
