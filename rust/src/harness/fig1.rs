//! Fig 1 / Appendix B (E5): vectorized prior-predictive,
//! posterior-predictive and log-likelihood for logistic regression via
//! `vmap` composed with the `seed`/`condition`/`trace` handlers — all
//! compiled into the `covtype_predict` / `covtype_loglik` artifacts.
//!
//! The driver: run a short fused-NUTS chain on `covtype_small`, feed the
//! posterior draws through the predictive artifacts, report
//! posterior-predictive accuracy and the expected log-likelihood
//! (logsumexp(ll) - log S, Fig 1c line 8).

use anyhow::Result;

use crate::config::Settings;
use crate::coordinator::{run_chain, FusedSampler, NutsOptions};
use crate::harness::builders::{init_z, Workload};
use crate::ppl::special::log_sum_exp;
use crate::runtime::engine::{literal_to_f64, Engine, HostTensor};
use crate::runtime::NutsStep;
use crate::rng::Rng;

pub fn run(engine: &Engine, settings: &Settings) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig 1 / Appendix B — vectorized prediction & log-likelihood (E5)\n\n");
    let model = "covtype_small";
    let dtype_tag = "f32";

    // 1. posterior samples from the fused chain
    let workload = Workload::for_model(engine, model, settings.seed)?;
    let entry = engine.manifest.find(model, "nuts_step", dtype_tag)?;
    let dt = entry.inputs[1].dtype;
    let step = NutsStep::new(
        engine,
        &format!("{model}_nuts_step_{dtype_tag}"),
        &workload.tensors(dt)?,
    )?;
    let dim = step.dim;
    let mut sampler = FusedSampler::new(step);
    let predict_entry = engine.manifest.get(&format!("covtype_predict_{dtype_tag}"))?;
    let num_draws = predict_entry.meta_usize("num_samples").unwrap_or(100);
    let (warmup, _) = settings.budget(300, 0);
    let opts = NutsOptions {
        num_warmup: warmup,
        num_samples: num_draws,
        seed: settings.seed,
        ..Default::default()
    };
    let res = run_chain(&mut sampler, &init_z(dim, settings.seed), &opts)?;
    out.push_str(&format!(
        "posterior: {} draws (step size {:.4}, {} divergences)\n",
        num_draws, res.step_size, res.divergences
    ));

    // layout: [b, m...] — split flat draws into (m_samples, b_samples)
    let d = dim - 1;
    let mut m_samples = Vec::with_capacity(num_draws * d);
    let mut b_samples = Vec::with_capacity(num_draws);
    for row in res.samples.chunks(dim) {
        b_samples.push(row[0]);
        m_samples.extend_from_slice(&row[1..]);
    }

    let (x, y, n) = match &workload {
        Workload::Logistic(l) => (l.x.clone(), l.y.clone(), l.n),
        _ => unreachable!(),
    };

    // 2. posterior predictive via the compiled vmap(seed(condition(...)))
    let predict = engine.executable(&format!("covtype_predict_{dtype_tag}"))?;
    let mut rng = Rng::new(settings.seed ^ 0xFEED);
    let keys: Vec<u32> = (0..num_draws)
        .flat_map(|_| {
            vec![
                (rng.next_u64() >> 32) as u32,
                (rng.next_u64() & 0xFFFF_FFFF) as u32,
            ]
        })
        .collect();
    let fdt = predict.entry.inputs[1].dtype;
    let keys_b = engine.upload(&HostTensor::U32(keys, vec![num_draws, 2]))?;
    let m_b = engine.upload(&HostTensor::from_f64(&m_samples, &[num_draws, d], fdt)?)?;
    let bb = engine.upload(&HostTensor::from_f64(&b_samples, &[num_draws], fdt)?)?;
    let x_b = engine.upload(&HostTensor::from_f64(&x, &[n, d], fdt)?)?;
    let outs = predict.run_buffers(&[&keys_b, &m_b, &bb, &x_b])?;
    let y_pred = literal_to_f64(&outs[0])?; // (S, N)

    // majority vote across draws
    let mut correct = 0usize;
    for i in 0..n {
        let mut votes = 0.0;
        for s in 0..num_draws {
            votes += y_pred[s * n + i];
        }
        let pred = if votes / num_draws as f64 > 0.5 { 1.0 } else { 0.0 };
        if (pred - y[i]).abs() < 0.5 {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    out.push_str(&format!("posterior predictive accuracy: {:.3}\n", acc));

    // 3. log-likelihood via the compiled vmap(trace(substitute(...)))
    let loglik = engine.executable(&format!("covtype_loglik_{dtype_tag}"))?;
    let y_i32: Vec<i32> = y.iter().map(|&v| v as i32).collect();
    let y_b = engine.upload(&HostTensor::I32(y_i32, vec![n]))?;
    let outs = loglik.run_buffers(&[&m_b, &bb, &x_b, &y_b])?;
    let lls = literal_to_f64(&outs[0])?;
    let expected_ll = log_sum_exp(&lls) - (num_draws as f64).ln();
    out.push_str(&format!(
        "expected log-likelihood (logsumexp - log S): {:.2}\n",
        expected_ll
    ));
    let naive_ll = (n as f64) * 0.5f64.ln();
    out.push_str(&format!(
        "coin-flip baseline log-likelihood: {:.2}\n",
        naive_ll
    ));
    out.push_str(&format!(
        "\n-> shape check: accuracy > 0.5 ({}) and E[ll] > coin-flip ({})\n",
        acc > 0.5,
        expected_ll > naive_ll
    ));
    Ok(out)
}
