//! Shrink-free property-testing driver (the offline crate set has no
//! `proptest`).  Properties run against many seeded random cases; on
//! failure the seed and case index are reported so the case replays
//! deterministically.

use crate::rng::Rng;

/// Run `prop` on `cases` random cases.  Panics with the failing seed on
/// the first violation.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    let base = 0x5EED_u64;
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {i} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert |a - b| <= atol + rtol * |b| with a labelled error.
pub fn close(a: f64, b: f64, atol: f64, rtol: f64, label: &str) -> Result<(), String> {
    if !a.is_finite() || !b.is_finite() {
        return Err(format!("{label}: non-finite ({a} vs {b})"));
    }
    let tol = atol + rtol * b.abs();
    if (a - b).abs() > tol {
        return Err(format!("{label}: {a} vs {b} (|diff| = {} > {tol})", (a - b).abs()));
    }
    Ok(())
}

/// Elementwise [`close`] over slices.
pub fn all_close(a: &[f64], b: &[f64], atol: f64, rtol: f64, label: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        close(*x, *y, atol, rtol, &format!("{label}[{i}]"))?;
    }
    Ok(())
}
