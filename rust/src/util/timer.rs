//! Timing helpers for the benchmark harness (no `criterion` in the
//! offline crate set): warmup + repeated timed runs with simple robust
//! statistics.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub reps: usize,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }
}

/// Time `f` after `warmup` unmeasured calls; `reps` measured calls.
pub fn bench<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Run `f` once, returning (elapsed seconds, result).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

pub fn summarize(samples: &[f64]) -> Timing {
    assert!(!samples.is_empty());
    let mut sorted = samples.to_vec();
    // total_cmp: NaN-safe ordering (a poisoned timing must not panic
    // the harness; NaNs sort last and show up in max_s)
    sorted.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        mean_s: mean,
        median_s: sorted[sorted.len() / 2],
        min_s: sorted[0],
        max_s: sorted[sorted.len() - 1],
        reps: samples.len(),
    }
}
