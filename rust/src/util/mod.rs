//! Small self-built substrates: JSON (no serde in the offline crate
//! set), timing helpers, and a shrink-free property-testing driver used
//! by the test suite.

pub mod json;
pub mod linalg;
pub mod npy;
pub mod prop;
pub mod timer;
