//! Minimal JSON parser + writer.
//!
//! The offline crate set has no `serde_json`, so the artifact manifest
//! (`artifacts/manifest.json`) and experiment configs are handled by
//! this hand-rolled implementation.  It supports the full JSON grammar
//! minus exotic number forms; numbers parse to `f64` (adequate: the
//! manifest only stores shapes, names and small metadata).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: copy raw continuation bytes
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"entries": [{"name": "hmm_nuts_step_f32", "shape": [2, 3], "ok": true, "x": null}]}"#;
        let j = Json::parse(src).unwrap();
        let s = j.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
    }
}
