//! Dense linear algebra for the SKIM marginal likelihood: Cholesky,
//! triangular solves, SPD inverse.  Row-major `n x n` matrices in flat
//! `Vec<f64>`.

/// In-place lower Cholesky: A (row-major, SPD) -> L with A = L L^T.
/// Returns Err on a non-positive pivot.
pub fn cholesky(a: &mut [f64], n: usize) -> Result<(), String> {
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(format!("cholesky: non-PD pivot {d} at {j}"));
        }
        let ljj = d.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / ljj;
        }
        for k in (j + 1)..n {
            a[j * n + k] = 0.0;
        }
    }
    Ok(())
}

/// Solve L x = b (lower triangular), in place on `b`.
pub fn solve_lower(l: &[f64], n: usize, b: &mut [f64]) {
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

/// Solve L^T x = b (upper triangular via the stored lower factor).
pub fn solve_lower_t(l: &[f64], n: usize, b: &mut [f64]) {
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

/// SPD inverse from the Cholesky factor: K^{-1} = L^{-T} L^{-1}.
pub fn spd_inverse_from_chol(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0.0; n * n];
    let mut col = vec![0.0; n];
    spd_inverse_from_chol_into(l, n, &mut inv, &mut col);
    inv
}

/// Allocation-free [`spd_inverse_from_chol`]: writes K^{-1} into `inv`
/// (n*n) using `col` (n) as scratch.
pub fn spd_inverse_from_chol_into(l: &[f64], n: usize, inv: &mut [f64], col: &mut [f64]) {
    // Solve K x_j = e_j column by column (O(n^3), fine at n = 200).
    for j in 0..n {
        col.iter_mut().for_each(|v| *v = 0.0);
        col[j] = 1.0;
        solve_lower(l, n, col);
        solve_lower_t(l, n, col);
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
    }
}

/// log |K| from the Cholesky factor.
pub fn log_det_from_chol(l: &[f64], n: usize) -> f64 {
    (0..n).map(|i| l[i * n + i].ln()).sum::<f64>() * 2.0
}

/// C = A * B^T for (n x p) row-major A, B — the Gram pattern.
pub fn gram(a: &[f64], b: &[f64], n: usize, p: usize, out: &mut [f64]) {
    for i in 0..n {
        let ai = &a[i * p..(i + 1) * p];
        for j in 0..n {
            let bj = &b[j * p..(j + 1) * p];
            out[i * n + j] = ai.iter().zip(bj).map(|(x, y)| x * y).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut b = vec![0.0; n * n];
        rng.fill_normal(&mut b);
        let mut a = vec![0.0; n * n];
        // A = B B^T + n I
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(3);
        let n = 12;
        let a = random_spd(&mut rng, n);
        let mut l = a.clone();
        cholesky(&mut l, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn solves_match_direct() {
        let mut rng = Rng::new(4);
        let n = 8;
        let a = random_spd(&mut rng, n);
        let mut l = a.clone();
        cholesky(&mut l, n).unwrap();
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        let mut x = b.clone();
        solve_lower(&l, n, &mut x);
        solve_lower_t(&l, n, &mut x);
        // check A x == b
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += a[i * n + j] * x[j];
            }
            assert!((s - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::new(5);
        let n = 6;
        let a = random_spd(&mut rng, n);
        let mut l = a.clone();
        cholesky(&mut l, n).unwrap();
        let inv = spd_inverse_from_chol(&l, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-8, "({i},{j}): {s}");
            }
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let det: f64 = 4.0 * 3.0 - 2.0 * 2.0;
        cholesky(&mut a, 2).unwrap();
        assert!((log_det_from_chol(&a, 2) - det.ln()).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // indefinite
        assert!(cholesky(&mut a, 2).is_err());
    }
}
