//! Minimal NumPy `.npy` (format version 1.0) writer/reader for f64
//! arrays — posterior samples saved by `fugue run --out` load directly
//! with `numpy.load`, closing the loop back to the Python side.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Write a little-endian f64 C-order array.
pub fn write_f64(path: impl AsRef<Path>, data: &[f64], shape: &[usize]) -> Result<()> {
    let elements: usize = shape.iter().product();
    if elements != data.len() {
        bail!("npy: shape {:?} != data length {}", shape, data.len());
    }
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f8', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(6) + version(2) + len(2) + header is 64-aligned
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read back a little-endian f64 C-order array written by [`write_f64`]
/// (or by numpy.save of such an array).
pub fn read_f64(path: impl AsRef<Path>) -> Result<(Vec<f64>, Vec<usize>)> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != MAGIC {
        bail!("not an npy file");
    }
    let mut len_bytes = [0u8; 2];
    f.read_exact(&mut len_bytes)?;
    let header_len = u16::from_le_bytes(len_bytes) as usize;
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("npy header not utf-8")?;
    if !header.contains("'<f8'") {
        bail!("npy: only <f8 supported, header: {header}");
    }
    if header.contains("'fortran_order': True") {
        bail!("npy: fortran order not supported");
    }
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .context("npy: malformed shape")?;
    let mut shape: Vec<usize> = Vec::new();
    for t in shape_part.split(',') {
        let t = t.trim();
        if t.is_empty() {
            continue;
        }
        shape.push(
            t.parse()
                .with_context(|| format!("npy: bad shape token '{t}' in header"))?,
        );
    }
    let elements: usize = shape.iter().product();
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() < elements * 8 {
        bail!(
            "npy: truncated data in {}: {} bytes for {} elements",
            path.as_ref().display(),
            bytes.len(),
            elements
        );
    }
    let data = bytes[..elements * 8]
        .chunks_exact(8)
        .map(|c| {
            let mut b = [0u8; 8];
            b.copy_from_slice(c);
            f64::from_le_bytes(b)
        })
        .collect();
    Ok((data, shape))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_2d() {
        let dir = std::env::temp_dir().join("fugue_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.npy");
        let data: Vec<f64> = (0..12).map(|i| i as f64 * 0.5).collect();
        write_f64(&path, &data, &[3, 4]).unwrap();
        let (back, shape) = read_f64(&path).unwrap();
        assert_eq!(shape, vec![3, 4]);
        assert_eq!(back, data);
    }

    #[test]
    fn roundtrip_1d() {
        let dir = std::env::temp_dir().join("fugue_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.npy");
        write_f64(&path, &[1.5, -2.5], &[2]).unwrap();
        let (back, shape) = read_f64(&path).unwrap();
        assert_eq!(shape, vec![2]);
        assert_eq!(back, vec![1.5, -2.5]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let dir = std::env::temp_dir().join("fugue_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(write_f64(dir.join("c.npy"), &[1.0], &[2]).is_err());
    }

    #[test]
    fn header_is_64_aligned() {
        let dir = std::env::temp_dir().join("fugue_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("d.npy");
        write_f64(&path, &[0.0; 7], &[7]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // data starts at a multiple of 64
        assert_eq!((bytes.len() - 7 * 8) % 64, 0);
    }
}
