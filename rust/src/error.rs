//! The inference-fault taxonomy: every way a run can go wrong that the
//! runtime *contains* instead of panicking on.
//!
//! The hot paths (tape sweeps, leapfrog loops, ELBO steps) never
//! allocate or early-return through `Result` — a non-finite value there
//! is folded into the sampler's own control flow (a counted divergence,
//! a rejected proposal, a skipped SVI step with step-size backoff, a
//! quarantined batch lane).  `InferenceError` is for the *cold* edges
//! of the stack: setup validation, checkpoint I/O, wall-clock budgets —
//! places where failing loudly with context is the robust behavior.
//!
//! The crate deliberately avoids `thiserror` (offline dependency set:
//! `anyhow` only), so `Display`/`Error` are hand-implemented.  All
//! variants convert into `anyhow::Error` for the CLI surface.

use std::fmt;

/// A contained inference fault.  See the module docs for which faults
/// surface here versus being absorbed by sampler control flow.
#[derive(Debug, Clone, PartialEq)]
pub enum InferenceError {
    /// The potential evaluated to NaN/±Inf where a finite value is
    /// required (e.g. at chain initialization — mid-trajectory
    /// non-finite energies become counted divergences instead).
    NonFinitePotential {
        /// Value observed (NaN or ±Inf).
        value: f64,
        /// Where it happened ("chain 3 init", "svi step 120", ...).
        context: String,
    },
    /// A gradient entry evaluated to NaN/±Inf where finite values are
    /// required.
    NonFiniteGradient {
        /// First offending coordinate.
        index: usize,
        /// Value observed at that coordinate.
        value: f64,
        /// Where it happened.
        context: String,
    },
    /// Structural mismatch: a buffer/layout/shape disagreed with what
    /// the model or checkpoint declares.
    LayoutViolation {
        expected: String,
        got: String,
        context: String,
    },
    /// The per-run wall-clock budget (`--max-seconds`) ran out.  The
    /// runner degrades to partial results plus a checkpoint; this
    /// variant reports the cut so callers can distinguish "finished"
    /// from "truncated".
    BudgetExhausted {
        budget_secs: f64,
        /// Draws/steps completed before the cut.
        completed: usize,
        /// Draws/steps the run asked for.
        requested: usize,
    },
    /// A checkpoint file could not be read, parsed, or matched to the
    /// requested run configuration.
    Checkpoint { path: String, msg: String },
}

impl fmt::Display for InferenceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InferenceError::NonFinitePotential { value, context } => {
                write!(f, "non-finite potential ({value}) at {context}")
            }
            InferenceError::NonFiniteGradient {
                index,
                value,
                context,
            } => write!(
                f,
                "non-finite gradient ({value} at coordinate {index}) at {context}"
            ),
            InferenceError::LayoutViolation {
                expected,
                got,
                context,
            } => write!(
                f,
                "layout violation at {context}: expected {expected}, got {got}"
            ),
            InferenceError::BudgetExhausted {
                budget_secs,
                completed,
                requested,
            } => write!(
                f,
                "wall-clock budget of {budget_secs}s exhausted after {completed}/{requested} iterations"
            ),
            InferenceError::Checkpoint { path, msg } => {
                write!(f, "checkpoint {path}: {msg}")
            }
        }
    }
}

impl std::error::Error for InferenceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_carry_context() {
        let e = InferenceError::NonFinitePotential {
            value: f64::NAN,
            context: "chain 2 init".into(),
        };
        let s = e.to_string();
        assert!(s.contains("chain 2 init"), "{s}");
        assert!(s.contains("NaN"), "{s}");

        let e = InferenceError::BudgetExhausted {
            budget_secs: 1.5,
            completed: 40,
            requested: 100,
        };
        assert!(e.to_string().contains("40/100"), "{e}");
    }

    #[test]
    fn converts_into_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(InferenceError::Checkpoint {
                path: "x.json".into(),
                msg: "truncated".into(),
            }
            .into())
        }
        let err = fails().unwrap_err();
        assert!(format!("{err}").contains("x.json"));
    }
}
