//! Tape-based reverse-mode automatic differentiation.
//!
//! This is the *Stan substrate* of the benchmark suite (DESIGN.md §3):
//! Stan's performance profile comes from compiled native code running a
//! reverse-mode sweep over an expression tape, with heavy lifting done
//! by fused vector primitives (`bernoulli_logit_glm_lpmf`, cholesky
//! rev-rules, ...).  We reproduce exactly that architecture:
//!
//! * scalar nodes for the (low-dimensional) prior/transform algebra;
//! * [`Tape::composite`] nodes — scalar-valued primitives with
//!   *precomputed partials* wrt each parent — for the model hot paths
//!   (GLM likelihood, HMM forward algorithm, SKIM marginal), mirroring
//!   Stan's fused math-library rev rules.
//!
//! The native NUTS sampler ([`crate::mcmc`]) consumes this through the
//! [`crate::mcmc::Potential`] trait; every evaluation builds a fresh
//! tape (like Stan's per-leapfrog nested autodiff region).

/// Handle to a node on a [`Tape`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub u32);

#[derive(Debug)]
enum Op {
    /// Leaf (input or constant): no parents.
    Leaf,
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    Neg(u32),
    Exp(u32),
    Ln(u32),
    Log1p(u32),
    Sqrt(u32),
    Sigmoid(u32),
    Softplus(u32),
    Tanh(u32),
    Powi(u32, i32),
    /// value = c * parent
    Scale(u32, f64),
    /// value = parent + c
    Offset(u32),
    /// Scalar-valued fused primitive with precomputed partials.
    Composite {
        parents: Box<[u32]>,
        partials: Box<[f64]>,
    },
}

struct Node {
    op: Op,
    value: f64,
}

/// Reverse-mode tape. Build the expression with the `Tape` methods, then
/// call [`Tape::grad`] on the output.
pub struct Tape {
    nodes: Vec<Node>,
}

impl Default for Tape {
    fn default() -> Self {
        Self::new()
    }
}

impl Tape {
    pub fn new() -> Self {
        Tape {
            nodes: Vec::with_capacity(1024),
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn value(&self, v: Var) -> f64 {
        self.nodes[v.0 as usize].value
    }

    fn push(&mut self, op: Op, value: f64) -> Var {
        let idx = self.nodes.len() as u32;
        self.nodes.push(Node { op, value });
        Var(idx)
    }

    /// Differentiable input leaf.
    pub fn input(&mut self, value: f64) -> Var {
        self.push(Op::Leaf, value)
    }

    /// Constant leaf (gradient is computed but conventionally unused).
    pub fn constant(&mut self, value: f64) -> Var {
        self.push(Op::Leaf, value)
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) + self.value(b);
        self.push(Op::Add(a.0, b.0), v)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) - self.value(b);
        self.push(Op::Sub(a.0, b.0), v)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) * self.value(b);
        self.push(Op::Mul(a.0, b.0), v)
    }

    pub fn div(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) / self.value(b);
        self.push(Op::Div(a.0, b.0), v)
    }

    pub fn neg(&mut self, a: Var) -> Var {
        let v = -self.value(a);
        self.push(Op::Neg(a.0), v)
    }

    pub fn exp(&mut self, a: Var) -> Var {
        let v = self.value(a).exp();
        self.push(Op::Exp(a.0), v)
    }

    pub fn ln(&mut self, a: Var) -> Var {
        let v = self.value(a).ln();
        self.push(Op::Ln(a.0), v)
    }

    pub fn log1p(&mut self, a: Var) -> Var {
        let v = self.value(a).ln_1p();
        self.push(Op::Log1p(a.0), v)
    }

    pub fn sqrt(&mut self, a: Var) -> Var {
        let v = self.value(a).sqrt();
        self.push(Op::Sqrt(a.0), v)
    }

    pub fn sigmoid(&mut self, a: Var) -> Var {
        let x = self.value(a);
        let v = if x >= 0.0 {
            1.0 / (1.0 + (-x).exp())
        } else {
            let e = x.exp();
            e / (1.0 + e)
        };
        self.push(Op::Sigmoid(a.0), v)
    }

    /// log(1 + e^x), overflow-safe.
    pub fn softplus(&mut self, a: Var) -> Var {
        let x = self.value(a);
        let v = if x > 30.0 { x } else { x.exp().ln_1p() };
        self.push(Op::Softplus(a.0), v)
    }

    pub fn tanh(&mut self, a: Var) -> Var {
        let v = self.value(a).tanh();
        self.push(Op::Tanh(a.0), v)
    }

    pub fn powi(&mut self, a: Var, n: i32) -> Var {
        let v = self.value(a).powi(n);
        self.push(Op::Powi(a.0, n), v)
    }

    pub fn square(&mut self, a: Var) -> Var {
        self.powi(a, 2)
    }

    /// c / x for constant numerator.
    pub fn div_const_by(&mut self, c: f64, x: Var) -> Var {
        let cv = self.constant(c);
        self.div(cv, x)
    }

    pub fn scale(&mut self, a: Var, c: f64) -> Var {
        let v = c * self.value(a);
        self.push(Op::Scale(a.0, c), v)
    }

    pub fn offset(&mut self, a: Var, c: f64) -> Var {
        let v = self.value(a) + c;
        self.push(Op::Offset(a.0), v)
    }

    pub fn sum(&mut self, xs: &[Var]) -> Var {
        let value: f64 = xs.iter().map(|v| self.value(*v)).sum();
        let partials = vec![1.0; xs.len()];
        self.composite(xs, &partials, value)
    }

    /// dot(w, c) for constant coefficients c.
    pub fn dot_const(&mut self, w: &[Var], c: &[f64]) -> Var {
        assert_eq!(w.len(), c.len());
        let value: f64 = w.iter().zip(c).map(|(v, x)| self.value(*v) * x).sum();
        self.composite(w, c, value)
    }

    /// Numerically-stable logsumexp with exact partials (softmax).
    pub fn logsumexp(&mut self, xs: &[Var]) -> Var {
        let vals: Vec<f64> = xs.iter().map(|v| self.value(*v)).collect();
        let m = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if m == f64::NEG_INFINITY {
            return self.constant(f64::NEG_INFINITY);
        }
        let sum: f64 = vals.iter().map(|v| (v - m).exp()).sum();
        let value = m + sum.ln();
        let partials: Vec<f64> = vals.iter().map(|v| (v - m).exp() / sum).collect();
        self.composite(xs, &partials, value)
    }

    /// Scalar-valued fused primitive: `value` with `partials[i] =
    /// d value / d parents[i]` computed by the caller (the Stan
    /// math-library pattern).
    pub fn composite(&mut self, parents: &[Var], partials: &[f64], value: f64) -> Var {
        assert_eq!(parents.len(), partials.len());
        let parents: Box<[u32]> = parents.iter().map(|v| v.0).collect();
        self.push(
            Op::Composite {
                parents,
                partials: partials.into(),
            },
            value,
        )
    }

    /// Reverse sweep from `output`; returns the adjoint of every node
    /// (index with `Var.0`).
    pub fn grad(&self, output: Var) -> Vec<f64> {
        let mut adj = vec![0.0; self.nodes.len()];
        adj[output.0 as usize] = 1.0;
        for i in (0..self.nodes.len()).rev() {
            let a = adj[i];
            if a == 0.0 {
                continue;
            }
            let node = &self.nodes[i];
            match &node.op {
                Op::Leaf => {}
                Op::Add(x, y) => {
                    adj[*x as usize] += a;
                    adj[*y as usize] += a;
                }
                Op::Sub(x, y) => {
                    adj[*x as usize] += a;
                    adj[*y as usize] -= a;
                }
                Op::Mul(x, y) => {
                    let (vx, vy) = (self.nodes[*x as usize].value, self.nodes[*y as usize].value);
                    adj[*x as usize] += a * vy;
                    adj[*y as usize] += a * vx;
                }
                Op::Div(x, y) => {
                    let (vx, vy) = (self.nodes[*x as usize].value, self.nodes[*y as usize].value);
                    adj[*x as usize] += a / vy;
                    adj[*y as usize] -= a * vx / (vy * vy);
                }
                Op::Neg(x) => adj[*x as usize] -= a,
                Op::Exp(x) => adj[*x as usize] += a * node.value,
                Op::Ln(x) => adj[*x as usize] += a / self.nodes[*x as usize].value,
                Op::Log1p(x) => adj[*x as usize] += a / (1.0 + self.nodes[*x as usize].value),
                Op::Sqrt(x) => adj[*x as usize] += a * 0.5 / node.value,
                Op::Sigmoid(x) => adj[*x as usize] += a * node.value * (1.0 - node.value),
                Op::Softplus(x) => {
                    let xv = self.nodes[*x as usize].value;
                    let s = if xv >= 0.0 {
                        1.0 / (1.0 + (-xv).exp())
                    } else {
                        let e = xv.exp();
                        e / (1.0 + e)
                    };
                    adj[*x as usize] += a * s;
                }
                Op::Tanh(x) => adj[*x as usize] += a * (1.0 - node.value * node.value),
                Op::Powi(x, n) => {
                    let xv = self.nodes[*x as usize].value;
                    adj[*x as usize] += a * (*n as f64) * xv.powi(n - 1);
                }
                Op::Scale(x, c) => adj[*x as usize] += a * c,
                Op::Offset(x) => adj[*x as usize] += a,
                Op::Composite { parents, partials } => {
                    for (p, g) in parents.iter().zip(partials.iter()) {
                        adj[*p as usize] += a * g;
                    }
                }
            }
        }
        adj
    }
}

/// Gradient of `f` at `x` by central finite differences (test utility).
pub fn finite_diff<F: FnMut(&[f64]) -> f64>(x: &[f64], mut f: F, h: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let hi = h * (1.0 + x[i].abs());
        xp[i] = x[i] + hi;
        let fp = f(&xp);
        xp[i] = x[i] - hi;
        let fm = f(&xp);
        xp[i] = x[i];
        g[i] = (fp - fm) / (2.0 * hi);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_of<F: Fn(&mut Tape, &[Var]) -> Var>(x: &[f64], build: F) -> (f64, Vec<f64>) {
        let mut t = Tape::new();
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let out = build(&mut t, &vars);
        let adj = t.grad(out);
        (t.value(out), vars.iter().map(|v| adj[v.0 as usize]).collect())
    }

    #[test]
    fn basic_ops_match_finite_diff() {
        let f = |t: &mut Tape, v: &[Var]| {
            // sin-free smoke: ((x*y + exp(x)) / sqrt(y)) - softplus(x)
            let xy = t.mul(v[0], v[1]);
            let ex = t.exp(v[0]);
            let num = t.add(xy, ex);
            let sq = t.sqrt(v[1]);
            let frac = t.div(num, sq);
            let sp = t.softplus(v[0]);
            t.sub(frac, sp)
        };
        let x = [0.7, 2.3];
        let (_, g) = grad_of(&x, f);
        let fd = finite_diff(&x, |x| grad_of(x, f).0, 1e-6);
        for i in 0..2 {
            assert!((g[i] - fd[i]).abs() < 1e-6, "{} vs {}", g[i], fd[i]);
        }
    }

    #[test]
    fn logsumexp_matches_finite_diff() {
        let f = |t: &mut Tape, v: &[Var]| t.logsumexp(v);
        let x = [1.0, -2.0, 0.5, 3.0];
        let (val, g) = grad_of(&x, f);
        let expect = x.iter().map(|v| v.exp()).sum::<f64>().ln();
        assert!((val - expect).abs() < 1e-12);
        let fd = finite_diff(&x, |x| grad_of(x, f).0, 1e-6);
        for i in 0..x.len() {
            assert!((g[i] - fd[i]).abs() < 1e-6);
        }
        assert!((g.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fanout_accumulates() {
        // y = x*x + x  => dy/dx = 2x + 1
        let (v, g) = grad_of(&[3.0], |t, v| {
            let sq = t.mul(v[0], v[0]);
            t.add(sq, v[0])
        });
        assert_eq!(v, 12.0);
        assert_eq!(g[0], 7.0);
    }

    #[test]
    fn composite_partials_flow() {
        // composite computing 2x + 3y with explicit partials
        let (v, g) = grad_of(&[5.0, 7.0], |t, v| {
            let value = 2.0 * t.value(v[0]) + 3.0 * t.value(v[1]);
            t.composite(v, &[2.0, 3.0], value)
        });
        assert_eq!(v, 31.0);
        assert_eq!(g, vec![2.0, 3.0]);
    }

    #[test]
    fn dot_const_and_sum() {
        let (v, g) = grad_of(&[1.0, 2.0, 3.0], |t, v| {
            let d = t.dot_const(v, &[4.0, 5.0, 6.0]);
            let s = t.sum(v);
            t.add(d, s)
        });
        assert_eq!(v, 4.0 + 10.0 + 18.0 + 6.0);
        assert_eq!(g, vec![5.0, 6.0, 7.0]);
    }

    #[test]
    fn powi_negative_exponent() {
        let (v, g) = grad_of(&[2.0], |t, v| t.powi(v[0], -2));
        assert!((v - 0.25).abs() < 1e-15);
        assert!((g[0] + 0.25).abs() < 1e-12);
    }
}
