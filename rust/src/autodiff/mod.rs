//! Tape-based reverse-mode automatic differentiation.
//!
//! This is the *Stan substrate* of the benchmark suite (DESIGN.md §3):
//! Stan's performance profile comes from compiled native code running a
//! reverse-mode sweep over an expression tape, with heavy lifting done
//! by fused vector primitives (`bernoulli_logit_glm_lpmf`, cholesky
//! rev-rules, ...).  We reproduce exactly that architecture:
//!
//! * scalar nodes for the (low-dimensional) prior/transform algebra;
//! * [`Tape::composite`] nodes — scalar-valued primitives with
//!   *precomputed partials* wrt each parent — for the model hot paths
//!   (GLM likelihood, HMM forward algorithm, SKIM marginal), mirroring
//!   Stan's fused math-library rev rules.
//!
//! The native NUTS sampler ([`crate::mcmc`]) consumes this through the
//! [`crate::mcmc::Potential`] trait.  The tape is *reusable* across
//! evaluations (Stan's nested autodiff region with a recovered memory
//! arena): [`Tape::reset`] clears the node list, the composite arena
//! and the adjoint scratch while keeping their capacity, so the steady
//! state of a sampling run performs **zero heap allocations** per
//! gradient evaluation.  Composite parents/partials live in one shared
//! arena (two flat `Vec`s indexed by `(start, len)`) instead of a boxed
//! slice per node, and the reverse sweep writes into an adjoint buffer
//! owned by the tape.

pub mod batch;

pub use batch::BatchTape;

/// Handle to a node on a [`Tape`] (or, lane-wise, on a
/// [`batch::BatchTape`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub u32);

/// Node operation.  `Copy`, with composite parents/partials stored
/// out-of-line in the tape's arena so the op list is a flat `Vec`.
#[derive(Debug, Clone, Copy)]
enum Op {
    /// Leaf (input or constant): no parents.
    Leaf,
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    Neg(u32),
    Exp(u32),
    Ln(u32),
    Log1p(u32),
    Sqrt(u32),
    Sigmoid(u32),
    Softplus(u32),
    Tanh(u32),
    Powi(u32, i32),
    /// value = c * parent
    Scale(u32, f64),
    /// value = parent + c
    Offset(u32),
    /// Scalar-valued fused primitive; parents/partials at
    /// `arena[start..start+len]`.
    Composite { start: u32, len: u32 },
}

/// Reverse-mode tape. Build the expression with the `Tape` methods, then
/// call [`Tape::grad`] on the output.  Call [`Tape::reset`] between
/// evaluations to reuse all storage.
pub struct Tape {
    ops: Vec<Op>,
    values: Vec<f64>,
    arena_parents: Vec<u32>,
    arena_partials: Vec<f64>,
    /// adjoint scratch for the reverse sweep (sized lazily in `grad`)
    adj: Vec<f64>,
}

impl Default for Tape {
    /// Cheap empty tape — **no allocation**.  This is the placeholder
    /// `std::mem::take` installs while a potential temporarily moves
    /// its tape out for an evaluation, so it must not touch the heap
    /// (the zero-allocation steady state depends on it).  Use
    /// [`Tape::new`] for a working tape with pre-sized buffers.
    fn default() -> Self {
        Tape {
            ops: Vec::new(),
            values: Vec::new(),
            arena_parents: Vec::new(),
            arena_partials: Vec::new(),
            adj: Vec::new(),
        }
    }
}

impl Tape {
    pub fn new() -> Self {
        Tape {
            ops: Vec::with_capacity(1024),
            values: Vec::with_capacity(1024),
            arena_parents: Vec::with_capacity(1024),
            arena_partials: Vec::with_capacity(1024),
            adj: Vec::new(),
        }
    }

    /// Clear the tape for the next evaluation, keeping every buffer's
    /// capacity (the zero-allocation steady state).
    pub fn reset(&mut self) {
        self.ops.clear();
        self.values.clear();
        self.arena_parents.clear();
        self.arena_partials.clear();
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Node-storage capacity watermark (regression guard for tape
    /// reuse: must not grow across steady-state evaluations).
    pub fn node_capacity(&self) -> usize {
        self.values.capacity()
    }

    /// Composite-arena capacity watermark.
    pub fn arena_capacity(&self) -> usize {
        self.arena_partials.capacity()
    }

    #[inline]
    pub fn value(&self, v: Var) -> f64 {
        self.values[v.0 as usize]
    }

    #[inline]
    fn push(&mut self, op: Op, value: f64) -> Var {
        let idx = self.ops.len() as u32;
        self.ops.push(op);
        self.values.push(value);
        Var(idx)
    }

    /// Differentiable input leaf.
    pub fn input(&mut self, value: f64) -> Var {
        self.push(Op::Leaf, value)
    }

    /// Constant leaf (gradient is computed but conventionally unused).
    pub fn constant(&mut self, value: f64) -> Var {
        self.push(Op::Leaf, value)
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) + self.value(b);
        self.push(Op::Add(a.0, b.0), v)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) - self.value(b);
        self.push(Op::Sub(a.0, b.0), v)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) * self.value(b);
        self.push(Op::Mul(a.0, b.0), v)
    }

    pub fn div(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) / self.value(b);
        self.push(Op::Div(a.0, b.0), v)
    }

    pub fn neg(&mut self, a: Var) -> Var {
        let v = -self.value(a);
        self.push(Op::Neg(a.0), v)
    }

    pub fn exp(&mut self, a: Var) -> Var {
        let v = self.value(a).exp();
        self.push(Op::Exp(a.0), v)
    }

    pub fn ln(&mut self, a: Var) -> Var {
        let v = self.value(a).ln();
        self.push(Op::Ln(a.0), v)
    }

    pub fn log1p(&mut self, a: Var) -> Var {
        let v = self.value(a).ln_1p();
        self.push(Op::Log1p(a.0), v)
    }

    pub fn sqrt(&mut self, a: Var) -> Var {
        let v = self.value(a).sqrt();
        self.push(Op::Sqrt(a.0), v)
    }

    pub fn sigmoid(&mut self, a: Var) -> Var {
        let x = self.value(a);
        let v = if x >= 0.0 {
            1.0 / (1.0 + (-x).exp())
        } else {
            let e = x.exp();
            e / (1.0 + e)
        };
        self.push(Op::Sigmoid(a.0), v)
    }

    /// log(1 + e^x), overflow-safe.
    pub fn softplus(&mut self, a: Var) -> Var {
        let x = self.value(a);
        let v = if x > 30.0 { x } else { x.exp().ln_1p() };
        self.push(Op::Softplus(a.0), v)
    }

    pub fn tanh(&mut self, a: Var) -> Var {
        let v = self.value(a).tanh();
        self.push(Op::Tanh(a.0), v)
    }

    pub fn powi(&mut self, a: Var, n: i32) -> Var {
        let v = self.value(a).powi(n);
        self.push(Op::Powi(a.0, n), v)
    }

    pub fn square(&mut self, a: Var) -> Var {
        self.powi(a, 2)
    }

    /// c / x for constant numerator.
    pub fn div_const_by(&mut self, c: f64, x: Var) -> Var {
        let cv = self.constant(c);
        self.div(cv, x)
    }

    pub fn scale(&mut self, a: Var, c: f64) -> Var {
        let v = c * self.value(a);
        self.push(Op::Scale(a.0, c), v)
    }

    pub fn offset(&mut self, a: Var, c: f64) -> Var {
        let v = self.value(a) + c;
        self.push(Op::Offset(a.0), v)
    }

    pub fn sum(&mut self, xs: &[Var]) -> Var {
        let value: f64 = xs.iter().map(|v| self.value(*v)).sum();
        let start = self.arena_parents.len() as u32;
        self.arena_parents.extend(xs.iter().map(|v| v.0));
        self.arena_partials
            .resize(self.arena_partials.len() + xs.len(), 1.0);
        self.push(
            Op::Composite {
                start,
                len: xs.len() as u32,
            },
            value,
        )
    }

    /// dot(w, c) for constant coefficients c.
    pub fn dot_const(&mut self, w: &[Var], c: &[f64]) -> Var {
        assert_eq!(w.len(), c.len());
        let value: f64 = w.iter().zip(c).map(|(v, x)| self.value(*v) * x).sum();
        self.composite(w, c, value)
    }

    /// Numerically-stable logsumexp with exact partials (softmax).
    pub fn logsumexp(&mut self, xs: &[Var]) -> Var {
        let mut m = f64::NEG_INFINITY;
        for v in xs {
            m = m.max(self.value(*v));
        }
        if m == f64::NEG_INFINITY {
            return self.constant(f64::NEG_INFINITY);
        }
        let mut sum = 0.0;
        for v in xs {
            sum += (self.value(*v) - m).exp();
        }
        let value = m + sum.ln();
        let start = self.arena_parents.len() as u32;
        for v in xs {
            let p = (self.value(*v) - m).exp() / sum;
            self.arena_parents.push(v.0);
            self.arena_partials.push(p);
        }
        self.push(
            Op::Composite {
                start,
                len: xs.len() as u32,
            },
            value,
        )
    }

    /// Scalar-valued fused primitive: `value` with `partials[i] =
    /// d value / d parents[i]` computed by the caller (the Stan
    /// math-library pattern).  Parents/partials are copied into the
    /// tape's shared arena.
    pub fn composite(&mut self, parents: &[Var], partials: &[f64], value: f64) -> Var {
        assert_eq!(parents.len(), partials.len());
        let start = self.arena_parents.len() as u32;
        self.arena_parents.extend(parents.iter().map(|v| v.0));
        self.arena_partials.extend_from_slice(partials);
        self.push(
            Op::Composite {
                start,
                len: parents.len() as u32,
            },
            value,
        )
    }

    /// Reverse sweep from `output`; returns the adjoint of every node
    /// (index with `Var.0`).  The returned slice borrows the tape's own
    /// scratch buffer — copy out what you need before the next tape
    /// operation.
    pub fn grad(&mut self, output: Var) -> &[f64] {
        let n = self.ops.len();
        self.adj.clear();
        self.adj.resize(n, 0.0);
        self.adj[output.0 as usize] = 1.0;
        let Tape {
            ops,
            values,
            arena_parents,
            arena_partials,
            adj,
        } = self;
        for i in (0..n).rev() {
            let a = adj[i];
            if a == 0.0 {
                continue;
            }
            match ops[i] {
                Op::Leaf => {}
                Op::Add(x, y) => {
                    adj[x as usize] += a;
                    adj[y as usize] += a;
                }
                Op::Sub(x, y) => {
                    adj[x as usize] += a;
                    adj[y as usize] -= a;
                }
                Op::Mul(x, y) => {
                    let (vx, vy) = (values[x as usize], values[y as usize]);
                    adj[x as usize] += a * vy;
                    adj[y as usize] += a * vx;
                }
                Op::Div(x, y) => {
                    let (vx, vy) = (values[x as usize], values[y as usize]);
                    adj[x as usize] += a / vy;
                    adj[y as usize] -= a * vx / (vy * vy);
                }
                Op::Neg(x) => adj[x as usize] -= a,
                Op::Exp(x) => adj[x as usize] += a * values[i],
                Op::Ln(x) => adj[x as usize] += a / values[x as usize],
                Op::Log1p(x) => adj[x as usize] += a / (1.0 + values[x as usize]),
                Op::Sqrt(x) => adj[x as usize] += a * 0.5 / values[i],
                Op::Sigmoid(x) => adj[x as usize] += a * values[i] * (1.0 - values[i]),
                Op::Softplus(x) => {
                    let xv = values[x as usize];
                    let s = if xv >= 0.0 {
                        1.0 / (1.0 + (-xv).exp())
                    } else {
                        let e = xv.exp();
                        e / (1.0 + e)
                    };
                    adj[x as usize] += a * s;
                }
                Op::Tanh(x) => adj[x as usize] += a * (1.0 - values[i] * values[i]),
                Op::Powi(x, n) => {
                    let xv = values[x as usize];
                    adj[x as usize] += a * (n as f64) * xv.powi(n - 1);
                }
                Op::Scale(x, c) => adj[x as usize] += a * c,
                Op::Offset(x) => adj[x as usize] += a,
                Op::Composite { start, len } => {
                    let (s, l) = (start as usize, len as usize);
                    for k in s..s + l {
                        adj[arena_parents[k] as usize] += a * arena_partials[k];
                    }
                }
            }
        }
        &self.adj
    }
}

// ---------------------------------------------------------------------------
// Scalar algebra abstraction (the model compiler's value domain)
// ---------------------------------------------------------------------------

/// Scalar algebra that generic model code can be evaluated over.
///
/// The model compiler ([`crate::compile`]) runs the *same* probabilistic
/// program in two value domains: plain `f64` ([`F64Alg`], used by the
/// trace pass that discovers sites and shapes) and tape nodes (`impl
/// Alg for Tape`, used by the evaluation pass so the joint log-density
/// comes out differentiable).  Every operation threads through `&mut
/// self` because the tape instance records each node.
///
/// Implementations must agree numerically: for any program `p`,
/// evaluating `p` over [`F64Alg`] and reading [`Alg::val`] of the result
/// over a [`Tape`] must produce the same floating-point values (the
/// tape ops are defined in terms of the identical `f64` arithmetic).
pub trait Alg {
    /// Value handle: `f64` itself, or a [`Var`] on a tape.
    type V: Copy + std::fmt::Debug;

    /// Embed a constant.
    fn lit(&mut self, x: f64) -> Self::V;
    /// Primal (forward) value of `v`.
    fn val(&self, v: Self::V) -> f64;

    fn add(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn sub(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn mul(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn div(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn neg(&mut self, a: Self::V) -> Self::V;
    fn exp(&mut self, a: Self::V) -> Self::V;
    fn ln(&mut self, a: Self::V) -> Self::V;
    /// ln(1 + a).
    fn log1p(&mut self, a: Self::V) -> Self::V;
    fn sqrt(&mut self, a: Self::V) -> Self::V;
    /// log(1 + e^a), overflow-safe.
    fn softplus(&mut self, a: Self::V) -> Self::V;
    fn powi(&mut self, a: Self::V, n: i32) -> Self::V;
    /// c * a for a constant c.
    fn scale(&mut self, a: Self::V, c: f64) -> Self::V;
    /// a + c for a constant c.
    fn offset(&mut self, a: Self::V, c: f64) -> Self::V;

    fn square(&mut self, a: Self::V) -> Self::V {
        self.powi(a, 2)
    }
}

/// Plain-`f64` instance of [`Alg`]: zero-sized, no recording.  The
/// model compiler's trace pass and any prior-simulation path run over
/// this algebra.
#[derive(Debug, Default, Clone, Copy)]
pub struct F64Alg;

impl Alg for F64Alg {
    type V = f64;

    fn lit(&mut self, x: f64) -> f64 {
        x
    }
    fn val(&self, v: f64) -> f64 {
        v
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        a + b
    }
    fn sub(&mut self, a: f64, b: f64) -> f64 {
        a - b
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        a * b
    }
    fn div(&mut self, a: f64, b: f64) -> f64 {
        a / b
    }
    fn neg(&mut self, a: f64) -> f64 {
        -a
    }
    fn exp(&mut self, a: f64) -> f64 {
        a.exp()
    }
    fn ln(&mut self, a: f64) -> f64 {
        a.ln()
    }
    fn log1p(&mut self, a: f64) -> f64 {
        a.ln_1p()
    }
    fn sqrt(&mut self, a: f64) -> f64 {
        a.sqrt()
    }
    fn softplus(&mut self, a: f64) -> f64 {
        // same branch structure as [`Tape::softplus`] so the two value
        // domains agree bitwise
        if a > 30.0 {
            a
        } else {
            a.exp().ln_1p()
        }
    }
    fn powi(&mut self, a: f64, n: i32) -> f64 {
        a.powi(n)
    }
    fn scale(&mut self, a: f64, c: f64) -> f64 {
        c * a
    }
    fn offset(&mut self, a: f64, c: f64) -> f64 {
        a + c
    }
}

/// The tape itself is the differentiable instance of [`Alg`]: each
/// operation appends a node, so a program evaluated through this impl
/// leaves a complete reverse-mode graph behind.
impl Alg for Tape {
    type V = Var;

    fn lit(&mut self, x: f64) -> Var {
        Tape::constant(self, x)
    }
    fn val(&self, v: Var) -> f64 {
        Tape::value(self, v)
    }
    fn add(&mut self, a: Var, b: Var) -> Var {
        Tape::add(self, a, b)
    }
    fn sub(&mut self, a: Var, b: Var) -> Var {
        Tape::sub(self, a, b)
    }
    fn mul(&mut self, a: Var, b: Var) -> Var {
        Tape::mul(self, a, b)
    }
    fn div(&mut self, a: Var, b: Var) -> Var {
        Tape::div(self, a, b)
    }
    fn neg(&mut self, a: Var) -> Var {
        Tape::neg(self, a)
    }
    fn exp(&mut self, a: Var) -> Var {
        Tape::exp(self, a)
    }
    fn ln(&mut self, a: Var) -> Var {
        Tape::ln(self, a)
    }
    fn log1p(&mut self, a: Var) -> Var {
        Tape::log1p(self, a)
    }
    fn sqrt(&mut self, a: Var) -> Var {
        Tape::sqrt(self, a)
    }
    fn softplus(&mut self, a: Var) -> Var {
        Tape::softplus(self, a)
    }
    fn powi(&mut self, a: Var, n: i32) -> Var {
        Tape::powi(self, a, n)
    }
    fn scale(&mut self, a: Var, c: f64) -> Var {
        Tape::scale(self, a, c)
    }
    fn offset(&mut self, a: Var, c: f64) -> Var {
        Tape::offset(self, a, c)
    }
    fn square(&mut self, a: Var) -> Var {
        Tape::square(self, a)
    }
}

/// Gradient of `f` at `x` by central finite differences (test utility).
pub fn finite_diff<F: FnMut(&[f64]) -> f64>(x: &[f64], mut f: F, h: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let hi = h * (1.0 + x[i].abs());
        xp[i] = x[i] + hi;
        let fp = f(&xp);
        xp[i] = x[i] - hi;
        let fm = f(&xp);
        xp[i] = x[i];
        g[i] = (fp - fm) / (2.0 * hi);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_of<F: Fn(&mut Tape, &[Var]) -> Var>(x: &[f64], build: F) -> (f64, Vec<f64>) {
        let mut t = Tape::new();
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let out = build(&mut t, &vars);
        let val = t.value(out);
        let adj = t.grad(out);
        (val, vars.iter().map(|v| adj[v.0 as usize]).collect())
    }

    #[test]
    fn basic_ops_match_finite_diff() {
        let f = |t: &mut Tape, v: &[Var]| {
            // sin-free smoke: ((x*y + exp(x)) / sqrt(y)) - softplus(x)
            let xy = t.mul(v[0], v[1]);
            let ex = t.exp(v[0]);
            let num = t.add(xy, ex);
            let sq = t.sqrt(v[1]);
            let frac = t.div(num, sq);
            let sp = t.softplus(v[0]);
            t.sub(frac, sp)
        };
        let x = [0.7, 2.3];
        let (_, g) = grad_of(&x, f);
        let fd = finite_diff(&x, |x| grad_of(x, f).0, 1e-6);
        for i in 0..2 {
            assert!((g[i] - fd[i]).abs() < 1e-6, "{} vs {}", g[i], fd[i]);
        }
    }

    #[test]
    fn logsumexp_matches_finite_diff() {
        let f = |t: &mut Tape, v: &[Var]| t.logsumexp(v);
        let x = [1.0, -2.0, 0.5, 3.0];
        let (val, g) = grad_of(&x, f);
        let expect = x.iter().map(|v| v.exp()).sum::<f64>().ln();
        assert!((val - expect).abs() < 1e-12);
        let fd = finite_diff(&x, |x| grad_of(x, f).0, 1e-6);
        for i in 0..x.len() {
            assert!((g[i] - fd[i]).abs() < 1e-6);
        }
        assert!((g.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fanout_accumulates() {
        // y = x*x + x  => dy/dx = 2x + 1
        let (v, g) = grad_of(&[3.0], |t, v| {
            let sq = t.mul(v[0], v[0]);
            t.add(sq, v[0])
        });
        assert_eq!(v, 12.0);
        assert_eq!(g[0], 7.0);
    }

    #[test]
    fn composite_partials_flow() {
        // composite computing 2x + 3y with explicit partials
        let (v, g) = grad_of(&[5.0, 7.0], |t, v| {
            let value = 2.0 * t.value(v[0]) + 3.0 * t.value(v[1]);
            t.composite(v, &[2.0, 3.0], value)
        });
        assert_eq!(v, 31.0);
        assert_eq!(g, vec![2.0, 3.0]);
    }

    #[test]
    fn dot_const_and_sum() {
        let (v, g) = grad_of(&[1.0, 2.0, 3.0], |t, v| {
            let d = t.dot_const(v, &[4.0, 5.0, 6.0]);
            let s = t.sum(v);
            t.add(d, s)
        });
        assert_eq!(v, 4.0 + 10.0 + 18.0 + 6.0);
        assert_eq!(g, vec![5.0, 6.0, 7.0]);
    }

    #[test]
    fn powi_negative_exponent() {
        let (v, g) = grad_of(&[2.0], |t, v| t.powi(v[0], -2));
        assert!((v - 0.25).abs() < 1e-15);
        assert!((g[0] + 0.25).abs() < 1e-12);
    }

    fn build_mixed(t: &mut Tape, x: &[f64]) -> (Vec<Var>, Var) {
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let lse = t.logsumexp(&vars);
        let s = t.sum(&vars);
        let d = t.dot_const(&vars, &[0.5, -1.5, 2.0]);
        let m = t.mul(lse, s);
        let out = t.add(m, d);
        (vars, out)
    }

    #[test]
    fn reset_matches_fresh_tape_bitwise() {
        let x = [0.3, -1.2, 0.9];

        let mut fresh = Tape::new();
        let (fvars, fout) = build_mixed(&mut fresh, &x);
        let fval = fresh.value(fout);
        let fgrad: Vec<f64> = {
            let adj = fresh.grad(fout);
            fvars.iter().map(|v| adj[v.0 as usize]).collect()
        };

        let mut reused = Tape::new();
        // pollute with an unrelated expression, then reset
        let a = reused.input(9.0);
        let b = reused.exp(a);
        let c = reused.mul(a, b);
        let _ = reused.grad(c);
        reused.reset();

        let (rvars, rout) = build_mixed(&mut reused, &x);
        assert_eq!(reused.len(), fresh.len());
        assert_eq!(reused.value(rout), fval);
        let adj = reused.grad(rout);
        let rgrad: Vec<f64> = rvars.iter().map(|v| adj[v.0 as usize]).collect();
        assert_eq!(rgrad, fgrad);
    }

    /// The same generic program evaluated over F64Alg and over a tape
    /// must agree bitwise (the model compiler's correctness hinge).
    fn alg_program<A: Alg>(a: &mut A, x: A::V, y: A::V) -> A::V {
        let s = a.add(x, y);
        let e = a.exp(s);
        let l = a.log1p(e);
        let q = a.square(x);
        let sc = a.scale(q, -0.5);
        let sp = a.softplus(y);
        let d = a.div(sc, sp);
        let m = a.mul(l, d);
        let sq = a.sqrt(e);
        let n = a.neg(sq);
        let o = a.offset(m, 0.25);
        let p = a.powi(y, 3);
        let t = a.sub(o, n);
        let ln = a.ln(e);
        let u = a.add(t, p);
        a.add(u, ln)
    }

    #[test]
    fn alg_domains_agree_bitwise() {
        for &(x, y) in &[(0.3, -1.2), (2.0, 0.5), (-0.7, 31.5)] {
            let mut fa = F64Alg;
            let plain = alg_program(&mut fa, x, y);
            let mut t = Tape::new();
            let (vx, vy) = (t.input(x), t.input(y));
            let out = alg_program(&mut t, vx, vy);
            assert_eq!(t.value(out), plain, "x={x} y={y}");
        }
    }

    #[test]
    fn reset_keeps_capacity_watermark() {
        let mut t = Tape::new();
        let x = [0.1, 0.2, 0.3];
        // establish the steady state with one evaluation
        let (_, out) = build_mixed(&mut t, &x);
        let _ = t.grad(out);
        let (nodes, arena) = (t.node_capacity(), t.arena_capacity());
        for _ in 0..10 {
            t.reset();
            let (_, out) = build_mixed(&mut t, &x);
            let _ = t.grad(out);
            assert_eq!(t.node_capacity(), nodes);
            assert_eq!(t.arena_capacity(), arena);
        }
    }
}
