//! Tape-based reverse-mode automatic differentiation.
//!
//! This is the *Stan substrate* of the benchmark suite (DESIGN.md §3):
//! Stan's performance profile comes from compiled native code running a
//! reverse-mode sweep over an expression tape, with heavy lifting done
//! by fused vector primitives (`bernoulli_logit_glm_lpmf`, cholesky
//! rev-rules, ...).  We reproduce exactly that architecture:
//!
//! * scalar nodes for the (low-dimensional) prior/transform algebra;
//! * [`Tape::composite`] nodes — scalar-valued primitives with
//!   *precomputed partials* wrt each parent — for the model hot paths
//!   (GLM likelihood, HMM forward algorithm, SKIM marginal), mirroring
//!   Stan's fused math-library rev rules.
//!
//! The native NUTS sampler ([`crate::mcmc`]) consumes this through the
//! [`crate::mcmc::Potential`] trait.  The tape is *reusable* across
//! evaluations (Stan's nested autodiff region with a recovered memory
//! arena): [`Tape::reset`] clears the node list, the composite arena
//! and the adjoint scratch while keeping their capacity, so the steady
//! state of a sampling run performs **zero heap allocations** per
//! gradient evaluation.  Composite parents/partials live in one shared
//! arena (two flat `Vec`s indexed by `(start, len)`) instead of a boxed
//! slice per node, and the reverse sweep writes into an adjoint buffer
//! owned by the tape.
//!
//! # Record once, replay many
//!
//! The tape is split into a **recorded topology** (`Topology`: op
//! kinds, argument node ids, the composite parent arena, composite
//! *kernel descriptors* and their constant data) and **per-evaluation
//! value/adjoint storage**.  For programs with static structure the
//! topology is identical on every evaluation, so re-interpreting the
//! program through the tape builder per gradient is pure overhead.
//! [`Tape::freeze`] snapshots the topology into a [`TapeProgram`]: a
//! flat instruction stream whose [`TapeProgram::forward`] /
//! [`TapeProgram::backward`] sweeps recompute every value, composite
//! partial and adjoint directly from the stored op codes — no [`Alg`]
//! dispatch, no interpreter, no allocation.  Composite nodes re-run
//! their fused likelihood kernels (the *same* kernel functions the
//! record path uses, so frozen results are **bitwise identical** to a
//! fresh tape replay — `rust/tests/frozen_tape.rs` pins this on every
//! zoo model).  Only the raw [`Tape::composite`] escape hatch — whose
//! partials are caller-computed and therefore not recomputable — cannot
//! be frozen; [`Tape::freeze`] panics with a descriptive message if one
//! is present.

pub mod batch;
pub mod opt;

pub use batch::{BatchTape, BatchTapeProgram, MICRO_LANES};
pub use opt::{OptBatchTapeProgram, OptTapeProgram, PlanStats};

use crate::ppl::special::{softplus_sigmoid, LN_2PI};

/// Handle to a node on a [`Tape`] (or, lane-wise, on a
/// [`batch::BatchTape`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub u32);

/// Node operation.  `Copy`, with composite parents/partials stored
/// out-of-line in the tape's arena so the op list is a flat `Vec`.
/// Every op carries enough constant data to *recompute* its value from
/// its parents' values — the frozen-program forward sweep depends on
/// this (which is why [`Op::Offset`] stores its constant even though
/// the reverse sweep never needs it).
#[derive(Debug, Clone, Copy)]
enum Op {
    /// Constant leaf: value fixed at record time.
    Leaf,
    /// Differentiable input leaf: value rebound on every frozen replay.
    Input,
    Add(u32, u32),
    Sub(u32, u32),
    Mul(u32, u32),
    Div(u32, u32),
    Neg(u32),
    Exp(u32),
    Ln(u32),
    Log1p(u32),
    Sqrt(u32),
    Sigmoid(u32),
    Softplus(u32),
    Tanh(u32),
    Powi(u32, i32),
    /// value = c * parent
    Scale(u32, f64),
    /// value = parent + c
    Offset(u32, f64),
    /// Scalar-valued fused primitive; parents/partials at
    /// `arena[start..start+len]`, kernel descriptor in
    /// `Topology::comp_kinds` (one entry per composite, in node order).
    Composite { start: u32, len: u32 },
}

/// How a composite node recomputes its value and partials from fresh
/// parent values — the kernel descriptor recorded next to each
/// composite so a frozen program can re-run the fused math instead of
/// replaying the model.  Shared by the scalar and batched tapes.
#[derive(Debug, Clone, Copy)]
pub(crate) enum CompKind {
    /// Raw [`Tape::composite`]: partials were computed by the caller
    /// and cannot be recomputed — blocks [`Tape::freeze`].
    Opaque,
    /// value = Σ partials[j] · parents[j] with *constant* partials
    /// (`sum`, `dot_const`).
    Affine,
    /// Numerically-stable logsumexp with softmax partials.
    LogSumExp,
    /// i.i.d. Normal plate with shared latent (loc, scale) parents;
    /// observations at `consts[c..c+n]`.
    NormalIid { c: u32, n: u32 },
    /// i.i.d. Bernoulli-logits plate with one shared latent logit;
    /// observations at `consts[c..c+n]`.
    BernoulliIid { c: u32, n: u32 },
    /// Normal plate with per-element latent locations and a shared
    /// latent scale (parents `[locs; n, scale]`); observations at
    /// `consts[c..c+n]`.
    NormalPlate { c: u32, n: u32 },
    /// Normal plate with per-element latent locations and *known*
    /// per-element scales; `consts[c..c+2n]` interleaves
    /// `[sigma_0, y_0, sigma_1, y_1, ...]`.
    NormalFixedPlate { c: u32, n: u32 },
    /// Bernoulli plate with per-element latent logits; observations at
    /// `consts[c..c+n]`.
    BernoulliPlate { c: u32, n: u32 },
}

/// Which backing store a [`DataSlot`]'s payload lives in.  The slot
/// machinery is shared by the scalar and batched tapes; the stores map
/// onto backend-specific arenas.
#[derive(Debug, Clone, Copy)]
pub(crate) enum SlotStore {
    /// Constant composite coefficients (`dot_const`): the scalar tape's
    /// partial arena (cloned into [`TapeProgram`]) or the batched
    /// tape's lane-shared arena.
    Coeffs,
    /// Fused-observation constants: `consts[start..start+len]`.
    Consts,
    /// Per-element constant leaves: node ids at
    /// `slot_nodes[start..start+len]`; rebinding overwrites the nodes'
    /// recorded values (lane-uniform on the batched tape).
    Nodes,
}

/// One rebindable span of observation data inside a recorded program —
/// the index-gather view that lets subsampling SVI swap the minibatch
/// under a frozen [`TapeProgram`] / [`batch::BatchTapeProgram`] without
/// re-recording or re-freezing.  Slots are registered in record order
/// while a data region (see [`Tape::begin_data_region`]) is active.
#[derive(Debug, Clone, Copy)]
pub(crate) struct DataSlot {
    pub(crate) store: SlotStore,
    pub(crate) start: u32,
    pub(crate) len: u32,
}

/// The recorded half of a tape: everything that is a pure function of
/// the *program structure* (op kinds, argument node ids, composite
/// parents, kernel descriptors, observation constants, input slots) and
/// therefore identical across evaluations of a static-structure model.
/// [`Tape::freeze`] clones this into a [`TapeProgram`].
#[derive(Debug, Clone, Default)]
struct Topology {
    ops: Vec<Op>,
    arena_parents: Vec<u32>,
    /// kernel descriptor per composite node, in node order
    comp_kinds: Vec<CompKind>,
    /// fused-kernel constant data (observations, known scales)
    consts: Vec<f64>,
    /// node ids of [`Op::Input`] leaves, in record order
    inputs: Vec<u32>,
    /// minibatch-rebindable data spans, in record order
    data_slots: Vec<DataSlot>,
    /// node ids referenced by [`SlotStore::Nodes`] slots
    slot_nodes: Vec<u32>,
}

/// Reverse-mode tape. Build the expression with the `Tape` methods, then
/// call [`Tape::grad`] on the output.  Call [`Tape::reset`] between
/// evaluations to reuse all storage, or [`Tape::freeze`] the recorded
/// program once and replay it without the builder.
pub struct Tape {
    topo: Topology,
    /// per-eval primal values, one per node
    values: Vec<f64>,
    /// recorded composite partials (constant for `Affine`/`Opaque`,
    /// recomputed in-place by the fused kernels)
    arena_partials: Vec<f64>,
    /// adjoint scratch for the reverse sweep (sized lazily in `grad`)
    adj: Vec<f64>,
    /// while true, data-bearing builders register rebindable slots
    data_region: bool,
}

impl Default for Tape {
    /// Cheap empty tape — **no allocation**.  This is the placeholder
    /// `std::mem::take` installs while a potential temporarily moves
    /// its tape out for an evaluation, so it must not touch the heap
    /// (the zero-allocation steady state depends on it).  Use
    /// [`Tape::new`] for a working tape with pre-sized buffers.
    fn default() -> Self {
        Tape {
            topo: Topology::default(),
            values: Vec::new(),
            arena_partials: Vec::new(),
            adj: Vec::new(),
            data_region: false,
        }
    }
}

/// Logistic sigmoid with the tape's branch structure — delegates to
/// the crate's one canonical implementation
/// ([`crate::ppl::special::sigmoid`]) so the record path, the frozen
/// forward sweep, the batched tape and every ppl-side consumer agree
/// bitwise by construction.
#[inline(always)]
pub(crate) fn sigmoid_val(x: f64) -> f64 {
    crate::ppl::special::sigmoid(x)
}

/// Overflow-safe `log(1 + e^x)` with the tape's branch structure
/// (shared like [`sigmoid_val`]).
#[inline(always)]
pub(crate) fn softplus_val(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        x.exp().ln_1p()
    }
}

/// Recompute a composite node's value and (for recomputing kinds) its
/// partials from fresh parent values — the **one** kernel
/// implementation shared by the record-time builders and
/// [`TapeProgram::forward`], which is what makes frozen replays bitwise
/// identical to tape replays.
///
/// `parents`/`partials` are the full arenas; this composite's span is
/// `[start, start + len)`.  Returns the node value.
fn scalar_composite_forward(
    kind: CompKind,
    start: usize,
    len: usize,
    parents: &[u32],
    consts: &[f64],
    values: &[f64],
    partials: &mut [f64],
) -> f64 {
    match kind {
        CompKind::Opaque => {
            unreachable!("opaque composites cannot be recomputed (freeze() rejects them)")
        }
        CompKind::Affine => {
            let mut acc = 0.0;
            for k in start..start + len {
                acc += partials[k] * values[parents[k] as usize];
            }
            acc
        }
        CompKind::LogSumExp => {
            let mut m = f64::NEG_INFINITY;
            for k in start..start + len {
                m = m.max(values[parents[k] as usize]);
            }
            if m == f64::NEG_INFINITY {
                // mirror Tape::logsumexp's all-(-inf) early return: the
                // record path emits a -inf constant (no gradient flow),
                // so the frozen recompute must yield -inf with zero
                // partials rather than exp(-inf - -inf) = NaN
                for k in start..start + len {
                    partials[k] = 0.0;
                }
                return f64::NEG_INFINITY;
            }
            let mut sum = 0.0;
            for k in start..start + len {
                sum += (values[parents[k] as usize] - m).exp();
            }
            for k in start..start + len {
                partials[k] = (values[parents[k] as usize] - m).exp() / sum;
            }
            m + sum.ln()
        }
        CompKind::NormalIid { c, n } => {
            let ys = &consts[c as usize..c as usize + n as usize];
            let nf = n as f64;
            let lv = values[parents[start] as usize];
            let sv = values[parents[start + 1] as usize];
            let inv2 = 1.0 / (sv * sv);
            let mut value = 0.0;
            let mut sr = 0.0;
            let mut sr2 = 0.0;
            for &y in ys {
                let r = y - lv;
                value += -0.5 * r * r * inv2;
                sr += r;
                sr2 += r * r;
            }
            value += -nf * sv.ln() - 0.5 * nf * LN_2PI;
            partials[start] = sr * inv2;
            partials[start + 1] = sr2 / (sv * sv * sv) - nf / sv;
            value
        }
        CompKind::BernoulliIid { c, n } => {
            let ys = &consts[c as usize..c as usize + n as usize];
            let nf = n as f64;
            let zl = values[parents[start] as usize];
            let (sp, sig) = softplus_sigmoid(zl);
            let sum_y: f64 = ys.iter().sum();
            partials[start] = sum_y - nf * sig;
            sum_y * zl - nf * sp
        }
        CompKind::NormalPlate { c, n } => {
            let nn = n as usize;
            let ys = &consts[c as usize..c as usize + nn];
            let nf = n as f64;
            let sv = values[parents[start + nn] as usize];
            let inv2 = 1.0 / (sv * sv);
            let mut value = 0.0;
            let mut sr2 = 0.0;
            for (i, &y) in ys.iter().enumerate() {
                let lv = values[parents[start + i] as usize];
                let r = y - lv;
                value += -0.5 * r * r * inv2;
                sr2 += r * r;
                partials[start + i] = r * inv2;
            }
            value += -nf * sv.ln() - 0.5 * nf * LN_2PI;
            partials[start + nn] = sr2 / (sv * sv * sv) - nf / sv;
            value
        }
        CompKind::NormalFixedPlate { c, n } => {
            let nn = n as usize;
            let sy = &consts[c as usize..c as usize + 2 * nn];
            let mut value = 0.0;
            for i in 0..nn {
                let s = sy[2 * i];
                let y = sy[2 * i + 1];
                let inv2 = 1.0 / (s * s);
                let lv = values[parents[start + i] as usize];
                let r = y - lv;
                value += -0.5 * r * r * inv2 - s.ln() - 0.5 * LN_2PI;
                partials[start + i] = r * inv2;
            }
            value
        }
        CompKind::BernoulliPlate { c, n } => {
            let ys = &consts[c as usize..c as usize + n as usize];
            let mut value = 0.0;
            for (i, &y) in ys.iter().enumerate() {
                let zl = values[parents[start + i] as usize];
                let (sp, sig) = softplus_sigmoid(zl);
                value += y * zl - sp;
                partials[start + i] = y - sig;
            }
            value
        }
    }
}

impl Tape {
    pub fn new() -> Self {
        Tape {
            topo: Topology {
                ops: Vec::with_capacity(1024),
                arena_parents: Vec::with_capacity(1024),
                comp_kinds: Vec::with_capacity(64),
                consts: Vec::with_capacity(256),
                inputs: Vec::with_capacity(64),
                data_slots: Vec::new(),
                slot_nodes: Vec::new(),
            },
            values: Vec::with_capacity(1024),
            arena_partials: Vec::with_capacity(1024),
            adj: Vec::new(),
            data_region: false,
        }
    }

    /// Clear the tape *and* release its backing storage.  For owners
    /// that froze the recorded program and will not interpret again
    /// (release builds of compiled models): the frozen
    /// [`TapeProgram`] carries its own copies, so keeping the
    /// recording buffers alive would roughly double steady-state
    /// memory.  A later replay (e.g. after `set_frozen(false)`)
    /// simply regrows the buffers.
    pub fn clear_and_shrink(&mut self) {
        self.reset();
        self.topo.ops.shrink_to_fit();
        self.topo.arena_parents.shrink_to_fit();
        self.topo.comp_kinds.shrink_to_fit();
        self.topo.consts.shrink_to_fit();
        self.topo.inputs.shrink_to_fit();
        self.topo.data_slots.shrink_to_fit();
        self.topo.slot_nodes.shrink_to_fit();
        self.values.shrink_to_fit();
        self.arena_partials.shrink_to_fit();
        self.adj = Vec::new();
    }

    /// Clear the tape for the next evaluation, keeping every buffer's
    /// capacity (the zero-allocation steady state).
    pub fn reset(&mut self) {
        self.topo.ops.clear();
        self.topo.arena_parents.clear();
        self.topo.comp_kinds.clear();
        self.topo.consts.clear();
        self.topo.inputs.clear();
        self.topo.data_slots.clear();
        self.topo.slot_nodes.clear();
        self.values.clear();
        self.arena_partials.clear();
        self.data_region = false;
    }

    pub fn len(&self) -> usize {
        self.topo.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.topo.ops.is_empty()
    }

    /// Node-storage capacity watermark (regression guard for tape
    /// reuse: must not grow across steady-state evaluations).
    pub fn node_capacity(&self) -> usize {
        self.values.capacity()
    }

    /// Composite-arena capacity watermark.
    pub fn arena_capacity(&self) -> usize {
        self.arena_partials.capacity()
    }

    #[inline]
    pub fn value(&self, v: Var) -> f64 {
        self.values[v.0 as usize]
    }

    #[inline]
    fn push(&mut self, op: Op, value: f64) -> Var {
        let idx = self.topo.ops.len() as u32;
        self.topo.ops.push(op);
        self.values.push(value);
        Var(idx)
    }

    /// Differentiable input leaf.  Inputs are remembered in record
    /// order: they are the slots [`TapeProgram::forward`] rebinds.
    pub fn input(&mut self, value: f64) -> Var {
        let idx = self.topo.ops.len() as u32;
        self.topo.inputs.push(idx);
        self.push(Op::Input, value)
    }

    /// Constant leaf (gradient is computed but conventionally unused).
    pub fn constant(&mut self, value: f64) -> Var {
        self.push(Op::Leaf, value)
    }

    /// Start a **data region**: until [`Tape::end_data_region`], every
    /// data-bearing builder (`dot_const`, the fused observation plates,
    /// [`Tape::register_data_nodes`]) also records a rebindable
    /// [`DataSlot`] describing where its constant data landed.  After
    /// [`Tape::freeze`], [`TapeProgram::rebind_data_slot`] can then
    /// swap that data (a fresh minibatch) without re-recording — the
    /// index-gather view subsampling SVI rides on.
    pub fn begin_data_region(&mut self) {
        self.data_region = true;
    }

    /// End the active data region (see [`Tape::begin_data_region`]).
    pub fn end_data_region(&mut self) {
        self.data_region = false;
    }

    /// Number of rebindable data slots recorded so far.
    pub fn num_data_slots(&self) -> usize {
        self.topo.data_slots.len()
    }

    fn register_slot(&mut self, store: SlotStore, start: usize, len: usize) {
        if self.data_region {
            self.topo.data_slots.push(DataSlot {
                store,
                start: start as u32,
                len: len as u32,
            });
        }
    }

    /// Register previously pushed constant leaves as one rebindable
    /// node slot (the generic per-element observation fallback, whose
    /// data lives in node values rather than the const arena).  No-op
    /// outside a data region.
    pub fn register_data_nodes(&mut self, nodes: &[Var]) {
        if !self.data_region {
            return;
        }
        let start = self.topo.slot_nodes.len();
        self.topo.slot_nodes.extend(nodes.iter().map(|v| v.0));
        self.topo.data_slots.push(DataSlot {
            store: SlotStore::Nodes,
            start: start as u32,
            len: nodes.len() as u32,
        });
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) + self.value(b);
        self.push(Op::Add(a.0, b.0), v)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) - self.value(b);
        self.push(Op::Sub(a.0, b.0), v)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) * self.value(b);
        self.push(Op::Mul(a.0, b.0), v)
    }

    pub fn div(&mut self, a: Var, b: Var) -> Var {
        let v = self.value(a) / self.value(b);
        self.push(Op::Div(a.0, b.0), v)
    }

    pub fn neg(&mut self, a: Var) -> Var {
        let v = -self.value(a);
        self.push(Op::Neg(a.0), v)
    }

    pub fn exp(&mut self, a: Var) -> Var {
        let v = self.value(a).exp();
        self.push(Op::Exp(a.0), v)
    }

    pub fn ln(&mut self, a: Var) -> Var {
        let v = self.value(a).ln();
        self.push(Op::Ln(a.0), v)
    }

    pub fn log1p(&mut self, a: Var) -> Var {
        let v = self.value(a).ln_1p();
        self.push(Op::Log1p(a.0), v)
    }

    pub fn sqrt(&mut self, a: Var) -> Var {
        let v = self.value(a).sqrt();
        self.push(Op::Sqrt(a.0), v)
    }

    pub fn sigmoid(&mut self, a: Var) -> Var {
        let v = sigmoid_val(self.value(a));
        self.push(Op::Sigmoid(a.0), v)
    }

    /// log(1 + e^x), overflow-safe.
    pub fn softplus(&mut self, a: Var) -> Var {
        let v = softplus_val(self.value(a));
        self.push(Op::Softplus(a.0), v)
    }

    pub fn tanh(&mut self, a: Var) -> Var {
        let v = self.value(a).tanh();
        self.push(Op::Tanh(a.0), v)
    }

    pub fn powi(&mut self, a: Var, n: i32) -> Var {
        let v = self.value(a).powi(n);
        self.push(Op::Powi(a.0, n), v)
    }

    pub fn square(&mut self, a: Var) -> Var {
        self.powi(a, 2)
    }

    /// c / x for constant numerator.
    pub fn div_const_by(&mut self, c: f64, x: Var) -> Var {
        let cv = self.constant(c);
        self.div(cv, x)
    }

    pub fn scale(&mut self, a: Var, c: f64) -> Var {
        let v = c * self.value(a);
        self.push(Op::Scale(a.0, c), v)
    }

    pub fn offset(&mut self, a: Var, c: f64) -> Var {
        let v = self.value(a) + c;
        self.push(Op::Offset(a.0, c), v)
    }

    pub fn sum(&mut self, xs: &[Var]) -> Var {
        let value: f64 = xs.iter().map(|v| self.value(*v)).sum();
        let start = self.topo.arena_parents.len() as u32;
        self.topo.arena_parents.extend(xs.iter().map(|v| v.0));
        self.arena_partials
            .resize(self.arena_partials.len() + xs.len(), 1.0);
        self.topo.comp_kinds.push(CompKind::Affine);
        self.push(
            Op::Composite {
                start,
                len: xs.len() as u32,
            },
            value,
        )
    }

    /// dot(w, c) for constant coefficients c.
    pub fn dot_const(&mut self, w: &[Var], c: &[f64]) -> Var {
        assert_eq!(w.len(), c.len());
        let value: f64 = w.iter().zip(c).map(|(v, x)| self.value(*v) * x).sum();
        let start = self.topo.arena_parents.len() as u32;
        self.register_slot(SlotStore::Coeffs, start as usize, w.len());
        self.topo.arena_parents.extend(w.iter().map(|v| v.0));
        self.arena_partials.extend_from_slice(c);
        self.topo.comp_kinds.push(CompKind::Affine);
        self.push(
            Op::Composite {
                start,
                len: w.len() as u32,
            },
            value,
        )
    }

    /// Numerically-stable logsumexp with exact partials (softmax).
    ///
    /// Freezing caveat: if *every* argument is `-inf` at record time
    /// the node degenerates to a `-inf` constant (no composite is
    /// recorded), so a frozen program would keep returning `-inf` at
    /// other inputs — record at a point where the node is live.  The
    /// frozen kernel mirrors the early return for points where all
    /// arguments underflow *after* freezing (value `-inf`, zero
    /// partials, no NaN).
    pub fn logsumexp(&mut self, xs: &[Var]) -> Var {
        let mut m = f64::NEG_INFINITY;
        for v in xs {
            m = m.max(self.value(*v));
        }
        if m == f64::NEG_INFINITY {
            return self.constant(f64::NEG_INFINITY);
        }
        let mut sum = 0.0;
        for v in xs {
            sum += (self.value(*v) - m).exp();
        }
        let value = m + sum.ln();
        let start = self.topo.arena_parents.len() as u32;
        for v in xs {
            let p = (self.value(*v) - m).exp() / sum;
            self.topo.arena_parents.push(v.0);
            self.arena_partials.push(p);
        }
        self.topo.comp_kinds.push(CompKind::LogSumExp);
        self.push(
            Op::Composite {
                start,
                len: xs.len() as u32,
            },
            value,
        )
    }

    /// Scalar-valued fused primitive: `value` with `partials[i] =
    /// d value / d parents[i]` computed by the caller (the Stan
    /// math-library pattern).  Parents/partials are copied into the
    /// tape's shared arena.  **Not freezable**: the tape cannot
    /// recompute caller-side partials, so [`Tape::freeze`] rejects
    /// tapes containing these nodes (the hand-fused model potentials
    /// rebuild their tape per evaluation and never freeze).
    pub fn composite(&mut self, parents: &[Var], partials: &[f64], value: f64) -> Var {
        assert_eq!(parents.len(), partials.len());
        let start = self.topo.arena_parents.len() as u32;
        self.topo.arena_parents.extend(parents.iter().map(|v| v.0));
        self.arena_partials.extend_from_slice(partials);
        self.topo.comp_kinds.push(CompKind::Opaque);
        self.push(
            Op::Composite {
                start,
                len: parents.len() as u32,
            },
            value,
        )
    }

    /// Record a replayable fused composite: reserve the arena span,
    /// stash constants + kernel descriptor, then run the shared kernel
    /// to fill value and partials.
    fn fused(&mut self, kind: CompKind, num_parents: usize) -> Var {
        self.topo.comp_kinds.push(kind);
        let start = self.topo.arena_parents.len() - num_parents;
        self.arena_partials
            .resize(self.topo.arena_parents.len(), 0.0);
        let Tape {
            topo,
            values,
            arena_partials,
            ..
        } = self;
        let value = scalar_composite_forward(
            kind,
            start,
            num_parents,
            &topo.arena_parents,
            &topo.consts,
            values,
            arena_partials,
        );
        self.push(
            Op::Composite {
                start: start as u32,
                len: num_parents as u32,
            },
            value,
        )
    }

    /// Fused i.i.d. Normal observation plate: `ys[i] ~ N(loc, scale)`
    /// with shared latent parameters.  One replayable composite node.
    pub fn normal_iid_obs(&mut self, loc: Var, scale: Var, ys: &[f64]) -> Var {
        let c = self.topo.consts.len();
        let kind = CompKind::NormalIid {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.push(loc.0);
        self.topo.arena_parents.push(scale.0);
        self.fused(kind, 2)
    }

    /// Fused i.i.d. Bernoulli observation plate with one shared latent
    /// logit.  One replayable composite node.
    pub fn bernoulli_logits_iid_obs(&mut self, logits: Var, ys: &[f64]) -> Var {
        let c = self.topo.consts.len();
        let kind = CompKind::BernoulliIid {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.push(logits.0);
        self.fused(kind, 1)
    }

    /// Fused Normal observation plate with per-element latent locations
    /// and a shared latent scale: `ys[i] ~ N(locs[i], scale)`.
    pub fn normal_plate_obs(&mut self, locs: &[Var], scale: Var, ys: &[f64]) -> Var {
        assert_eq!(locs.len(), ys.len());
        let c = self.topo.consts.len();
        let kind = CompKind::NormalPlate {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.extend(locs.iter().map(|v| v.0));
        self.topo.arena_parents.push(scale.0);
        self.fused(kind, locs.len() + 1)
    }

    /// Fused Normal observation plate with per-element latent locations
    /// and *known* per-element scales: `ys[i] ~ N(locs[i], sigmas[i])`.
    pub fn normal_fixed_plate_obs(&mut self, locs: &[Var], sigmas: &[f64], ys: &[f64]) -> Var {
        assert_eq!(locs.len(), ys.len());
        assert_eq!(sigmas.len(), ys.len());
        let c = self.topo.consts.len();
        let kind = CompKind::NormalFixedPlate {
            c: c as u32,
            n: ys.len() as u32,
        };
        // the slot spans the whole interleaved [sigma_0, y_0, ...]
        // region: rebinding supplies both per-row scales and labels
        self.register_slot(SlotStore::Consts, c, 2 * ys.len());
        for (s, y) in sigmas.iter().zip(ys) {
            self.topo.consts.push(*s);
            self.topo.consts.push(*y);
        }
        self.topo.arena_parents.extend(locs.iter().map(|v| v.0));
        self.fused(kind, locs.len())
    }

    /// Fused Bernoulli observation plate with per-element latent logits
    /// (the GLM fast path: partials `y_i - σ(z_i)`).
    pub fn bernoulli_logits_plate_obs(&mut self, logits: &[Var], ys: &[f64]) -> Var {
        assert_eq!(logits.len(), ys.len());
        let c = self.topo.consts.len();
        let kind = CompKind::BernoulliPlate {
            c: c as u32,
            n: ys.len() as u32,
        };
        self.register_slot(SlotStore::Consts, c, ys.len());
        self.topo.consts.extend_from_slice(ys);
        self.topo.arena_parents.extend(logits.iter().map(|v| v.0));
        self.fused(kind, logits.len())
    }

    /// Reverse sweep from `output`; returns the adjoint of every node
    /// (index with `Var.0`).  The returned slice borrows the tape's own
    /// scratch buffer — copy out what you need before the next tape
    /// operation.
    pub fn grad(&mut self, output: Var) -> &[f64] {
        let n = self.topo.ops.len();
        self.adj.clear();
        self.adj.resize(n, 0.0);
        self.adj[output.0 as usize] = 1.0;
        reverse_sweep(
            &self.topo.ops,
            &self.values,
            &self.topo.arena_parents,
            &self.arena_partials,
            &mut self.adj,
        );
        &self.adj
    }

    /// Snapshot the recorded program into a [`TapeProgram`] whose
    /// forward/backward sweeps are bitwise-identical to replaying the
    /// same program on this tape, with `output` as the differentiated
    /// node.  Panics if the tape contains a raw (non-replayable)
    /// [`Tape::composite`] node.
    pub fn freeze(&self, output: Var) -> TapeProgram {
        assert!(
            (output.0 as usize) < self.topo.ops.len(),
            "freeze: output node out of range"
        );
        assert!(
            !self
                .topo
                .comp_kinds
                .iter()
                .any(|&k| matches!(k, CompKind::Opaque)),
            "Tape::freeze: tape contains a raw Tape::composite node whose caller-computed \
             partials cannot be recomputed; record fused likelihoods through the replayable \
             builders (normal_iid_obs, normal_plate_obs, ...) instead"
        );
        TapeProgram {
            topo: self.topo.clone(),
            output: output.0,
            values: self.values.clone(),
            partials: self.arena_partials.clone(),
            adj: vec![0.0; self.topo.ops.len()],
        }
    }
}

/// The reverse sweep over a flat op stream — shared by [`Tape::grad`]
/// and [`TapeProgram::backward`] so the two are bitwise identical by
/// construction (including the zero-adjoint skip).
fn reverse_sweep(
    ops: &[Op],
    values: &[f64],
    arena_parents: &[u32],
    arena_partials: &[f64],
    adj: &mut [f64],
) {
    for i in (0..ops.len()).rev() {
        let a = adj[i];
        if a == 0.0 {
            continue;
        }
        match ops[i] {
            Op::Leaf | Op::Input => {}
            Op::Add(x, y) => {
                adj[x as usize] += a;
                adj[y as usize] += a;
            }
            Op::Sub(x, y) => {
                adj[x as usize] += a;
                adj[y as usize] -= a;
            }
            Op::Mul(x, y) => {
                let (vx, vy) = (values[x as usize], values[y as usize]);
                adj[x as usize] += a * vy;
                adj[y as usize] += a * vx;
            }
            Op::Div(x, y) => {
                let (vx, vy) = (values[x as usize], values[y as usize]);
                adj[x as usize] += a / vy;
                adj[y as usize] -= a * vx / (vy * vy);
            }
            Op::Neg(x) => adj[x as usize] -= a,
            Op::Exp(x) => adj[x as usize] += a * values[i],
            Op::Ln(x) => adj[x as usize] += a / values[x as usize],
            Op::Log1p(x) => adj[x as usize] += a / (1.0 + values[x as usize]),
            Op::Sqrt(x) => adj[x as usize] += a * 0.5 / values[i],
            Op::Sigmoid(x) => adj[x as usize] += a * values[i] * (1.0 - values[i]),
            Op::Softplus(x) => {
                let s = sigmoid_val(values[x as usize]);
                adj[x as usize] += a * s;
            }
            Op::Tanh(x) => adj[x as usize] += a * (1.0 - values[i] * values[i]),
            Op::Powi(x, n) => {
                let xv = values[x as usize];
                adj[x as usize] += a * (n as f64) * xv.powi(n - 1);
            }
            Op::Scale(x, c) => adj[x as usize] += a * c,
            Op::Offset(x, _) => adj[x as usize] += a,
            Op::Composite { start, len } => {
                let (s, l) = (start as usize, len as usize);
                for k in s..s + l {
                    adj[arena_parents[k] as usize] += a * arena_partials[k];
                }
            }
        }
    }
}

/// A frozen tape: the recorded topology plus private per-evaluation
/// value/partial/adjoint storage.  [`TapeProgram::forward`] rebinds the
/// input leaves and sweeps the flat instruction stream (recomputing
/// fused-composite values *and* partials from the stored kernel
/// descriptors); [`TapeProgram::backward`] runs the reverse sweep.
/// Both are allocation-free and dispatch-free — no [`Alg`] trait, no
/// model interpretation — and bitwise-identical to replaying the same
/// program on a fresh [`Tape`].
pub struct TapeProgram {
    topo: Topology,
    output: u32,
    values: Vec<f64>,
    partials: Vec<f64>,
    adj: Vec<f64>,
}

impl TapeProgram {
    /// Number of input slots ([`Tape::input`] calls at record time).
    pub fn num_inputs(&self) -> usize {
        self.topo.inputs.len()
    }

    /// Number of instructions in the frozen stream.
    pub fn len(&self) -> usize {
        self.topo.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.topo.ops.is_empty()
    }

    /// Primal value of the output node after the last [`forward`].
    ///
    /// [`forward`]: TapeProgram::forward
    pub fn output_value(&self) -> f64 {
        self.values[self.output as usize]
    }

    /// Number of rebindable data slots recorded inside data regions
    /// (see [`Tape::begin_data_region`]).
    pub fn num_data_slots(&self) -> usize {
        self.topo.data_slots.len()
    }

    /// Element count of data slot `slot`.
    pub fn data_slot_len(&self, slot: usize) -> usize {
        self.topo.data_slots[slot].len as usize
    }

    /// Overwrite the data behind slot `slot` (a fresh minibatch row)
    /// without touching the program structure: the next [`forward`]
    /// recomputes against the new data.  `data.len()` must equal
    /// [`TapeProgram::data_slot_len`].
    ///
    /// [`forward`]: TapeProgram::forward
    pub fn rebind_data_slot(&mut self, slot: usize, data: &[f64]) {
        let DataSlot { store, start, len } = self.topo.data_slots[slot];
        let (s, l) = (start as usize, len as usize);
        assert_eq!(data.len(), l, "rebind_data_slot: length mismatch");
        match store {
            SlotStore::Coeffs => self.partials[s..s + l].copy_from_slice(data),
            SlotStore::Consts => self.topo.consts[s..s + l].copy_from_slice(data),
            SlotStore::Nodes => {
                for (j, &id) in self.topo.slot_nodes[s..s + l].iter().enumerate() {
                    self.values[id as usize] = data[j];
                }
            }
        }
    }

    /// Rebind the inputs and run the forward sweep; returns the output
    /// value.  Zero allocations, no interpretation: one pass over the
    /// flat op stream, with composite nodes re-running their fused
    /// kernels against the new values.
    pub fn forward(&mut self, inputs: &[f64]) -> f64 {
        assert_eq!(
            inputs.len(),
            self.topo.inputs.len(),
            "TapeProgram::forward: input count mismatch"
        );
        for (k, &id) in self.topo.inputs.iter().enumerate() {
            self.values[id as usize] = inputs[k];
        }
        let Topology {
            ops,
            arena_parents,
            comp_kinds,
            consts,
            ..
        } = &self.topo;
        let values = &mut self.values;
        let partials = &mut self.partials;
        let mut ci = 0usize;
        for i in 0..ops.len() {
            match ops[i] {
                // constants keep their recorded values, inputs were
                // rebound above
                Op::Leaf | Op::Input => {}
                Op::Add(x, y) => values[i] = values[x as usize] + values[y as usize],
                Op::Sub(x, y) => values[i] = values[x as usize] - values[y as usize],
                Op::Mul(x, y) => values[i] = values[x as usize] * values[y as usize],
                Op::Div(x, y) => values[i] = values[x as usize] / values[y as usize],
                Op::Neg(x) => values[i] = -values[x as usize],
                Op::Exp(x) => values[i] = values[x as usize].exp(),
                Op::Ln(x) => values[i] = values[x as usize].ln(),
                Op::Log1p(x) => values[i] = values[x as usize].ln_1p(),
                Op::Sqrt(x) => values[i] = values[x as usize].sqrt(),
                Op::Sigmoid(x) => values[i] = sigmoid_val(values[x as usize]),
                Op::Softplus(x) => values[i] = softplus_val(values[x as usize]),
                Op::Tanh(x) => values[i] = values[x as usize].tanh(),
                Op::Powi(x, n) => values[i] = values[x as usize].powi(n),
                Op::Scale(x, c) => values[i] = c * values[x as usize],
                Op::Offset(x, c) => values[i] = values[x as usize] + c,
                Op::Composite { start, len } => {
                    let kind = comp_kinds[ci];
                    ci += 1;
                    let v = scalar_composite_forward(
                        kind,
                        start as usize,
                        len as usize,
                        arena_parents,
                        consts,
                        values,
                        partials,
                    );
                    values[i] = v;
                }
            }
        }
        self.values[self.output as usize]
    }

    /// Reverse sweep seeded at the output (adjoint 1.0), using the
    /// values and composite partials left by the last [`forward`].
    ///
    /// [`forward`]: TapeProgram::forward
    pub fn backward(&mut self) {
        self.adj.iter_mut().for_each(|a| *a = 0.0);
        self.adj[self.output as usize] = 1.0;
        reverse_sweep(
            &self.topo.ops,
            &self.values,
            &self.topo.arena_parents,
            &self.partials,
            &mut self.adj,
        );
    }

    /// Copy the adjoints of the input slots (in record order) into
    /// `grad` after a [`backward`] sweep.
    ///
    /// [`backward`]: TapeProgram::backward
    pub fn input_adjoints(&self, grad: &mut [f64]) {
        for (g, &id) in grad.iter_mut().zip(self.topo.inputs.iter()) {
            *g = self.adj[id as usize];
        }
    }

    /// Adjoint of an arbitrary node after [`backward`].
    ///
    /// [`backward`]: TapeProgram::backward
    pub fn adjoint(&self, v: Var) -> f64 {
        self.adj[v.0 as usize]
    }
}

// ---------------------------------------------------------------------------
// Scalar algebra abstraction (the model compiler's value domain)
// ---------------------------------------------------------------------------

/// Scalar algebra that generic model code can be evaluated over.
///
/// The model compiler ([`crate::compile`]) runs the *same* probabilistic
/// program in two value domains: plain `f64` ([`F64Alg`], used by the
/// trace pass that discovers sites and shapes) and tape nodes (`impl
/// Alg for Tape`, used by the evaluation pass so the joint log-density
/// comes out differentiable).  Every operation threads through `&mut
/// self` because the tape instance records each node.
///
/// Implementations must agree numerically: for any program `p`,
/// evaluating `p` over [`F64Alg`] and reading [`Alg::val`] of the result
/// over a [`Tape`] must produce the same floating-point values (the
/// tape ops are defined in terms of the identical `f64` arithmetic).
pub trait Alg {
    /// Value handle: `f64` itself, or a [`Var`] on a tape.
    type V: Copy + std::fmt::Debug;

    /// Embed a constant.
    fn lit(&mut self, x: f64) -> Self::V;
    /// Primal (forward) value of `v`.
    fn val(&self, v: Self::V) -> f64;

    fn add(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn sub(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn mul(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn div(&mut self, a: Self::V, b: Self::V) -> Self::V;
    fn neg(&mut self, a: Self::V) -> Self::V;
    fn exp(&mut self, a: Self::V) -> Self::V;
    fn ln(&mut self, a: Self::V) -> Self::V;
    /// ln(1 + a).
    fn log1p(&mut self, a: Self::V) -> Self::V;
    fn sqrt(&mut self, a: Self::V) -> Self::V;
    /// log(1 + e^a), overflow-safe.
    fn softplus(&mut self, a: Self::V) -> Self::V;
    fn powi(&mut self, a: Self::V, n: i32) -> Self::V;
    /// c * a for a constant c.
    fn scale(&mut self, a: Self::V, c: f64) -> Self::V;
    /// a + c for a constant c.
    fn offset(&mut self, a: Self::V, c: f64) -> Self::V;

    fn square(&mut self, a: Self::V) -> Self::V {
        self.powi(a, 2)
    }
}

/// Plain-`f64` instance of [`Alg`]: zero-sized, no recording.  The
/// model compiler's trace pass and any prior-simulation path run over
/// this algebra.
#[derive(Debug, Default, Clone, Copy)]
pub struct F64Alg;

impl Alg for F64Alg {
    type V = f64;

    fn lit(&mut self, x: f64) -> f64 {
        x
    }
    fn val(&self, v: f64) -> f64 {
        v
    }
    fn add(&mut self, a: f64, b: f64) -> f64 {
        a + b
    }
    fn sub(&mut self, a: f64, b: f64) -> f64 {
        a - b
    }
    fn mul(&mut self, a: f64, b: f64) -> f64 {
        a * b
    }
    fn div(&mut self, a: f64, b: f64) -> f64 {
        a / b
    }
    fn neg(&mut self, a: f64) -> f64 {
        -a
    }
    fn exp(&mut self, a: f64) -> f64 {
        a.exp()
    }
    fn ln(&mut self, a: f64) -> f64 {
        a.ln()
    }
    fn log1p(&mut self, a: f64) -> f64 {
        a.ln_1p()
    }
    fn sqrt(&mut self, a: f64) -> f64 {
        a.sqrt()
    }
    fn softplus(&mut self, a: f64) -> f64 {
        // same branch structure as [`Tape::softplus`] so the two value
        // domains agree bitwise
        softplus_val(a)
    }
    fn powi(&mut self, a: f64, n: i32) -> f64 {
        a.powi(n)
    }
    fn scale(&mut self, a: f64, c: f64) -> f64 {
        c * a
    }
    fn offset(&mut self, a: f64, c: f64) -> f64 {
        a + c
    }
}

/// The tape itself is the differentiable instance of [`Alg`]: each
/// operation appends a node, so a program evaluated through this impl
/// leaves a complete reverse-mode graph behind.
impl Alg for Tape {
    type V = Var;

    fn lit(&mut self, x: f64) -> Var {
        Tape::constant(self, x)
    }
    fn val(&self, v: Var) -> f64 {
        Tape::value(self, v)
    }
    fn add(&mut self, a: Var, b: Var) -> Var {
        Tape::add(self, a, b)
    }
    fn sub(&mut self, a: Var, b: Var) -> Var {
        Tape::sub(self, a, b)
    }
    fn mul(&mut self, a: Var, b: Var) -> Var {
        Tape::mul(self, a, b)
    }
    fn div(&mut self, a: Var, b: Var) -> Var {
        Tape::div(self, a, b)
    }
    fn neg(&mut self, a: Var) -> Var {
        Tape::neg(self, a)
    }
    fn exp(&mut self, a: Var) -> Var {
        Tape::exp(self, a)
    }
    fn ln(&mut self, a: Var) -> Var {
        Tape::ln(self, a)
    }
    fn log1p(&mut self, a: Var) -> Var {
        Tape::log1p(self, a)
    }
    fn sqrt(&mut self, a: Var) -> Var {
        Tape::sqrt(self, a)
    }
    fn softplus(&mut self, a: Var) -> Var {
        Tape::softplus(self, a)
    }
    fn powi(&mut self, a: Var, n: i32) -> Var {
        Tape::powi(self, a, n)
    }
    fn scale(&mut self, a: Var, c: f64) -> Var {
        Tape::scale(self, a, c)
    }
    fn offset(&mut self, a: Var, c: f64) -> Var {
        Tape::offset(self, a, c)
    }
    fn square(&mut self, a: Var) -> Var {
        Tape::square(self, a)
    }
}

/// Gradient of `f` at `x` by central finite differences (test utility).
pub fn finite_diff<F: FnMut(&[f64]) -> f64>(x: &[f64], mut f: F, h: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let hi = h * (1.0 + x[i].abs());
        xp[i] = x[i] + hi;
        let fp = f(&xp);
        xp[i] = x[i] - hi;
        let fm = f(&xp);
        xp[i] = x[i];
        g[i] = (fp - fm) / (2.0 * hi);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_of<F: Fn(&mut Tape, &[Var]) -> Var>(x: &[f64], build: F) -> (f64, Vec<f64>) {
        let mut t = Tape::new();
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let out = build(&mut t, &vars);
        let val = t.value(out);
        let adj = t.grad(out);
        (val, vars.iter().map(|v| adj[v.0 as usize]).collect())
    }

    #[test]
    fn basic_ops_match_finite_diff() {
        let f = |t: &mut Tape, v: &[Var]| {
            // sin-free smoke: ((x*y + exp(x)) / sqrt(y)) - softplus(x)
            let xy = t.mul(v[0], v[1]);
            let ex = t.exp(v[0]);
            let num = t.add(xy, ex);
            let sq = t.sqrt(v[1]);
            let frac = t.div(num, sq);
            let sp = t.softplus(v[0]);
            t.sub(frac, sp)
        };
        let x = [0.7, 2.3];
        let (_, g) = grad_of(&x, f);
        let fd = finite_diff(&x, |x| grad_of(x, f).0, 1e-6);
        for i in 0..2 {
            assert!((g[i] - fd[i]).abs() < 1e-6, "{} vs {}", g[i], fd[i]);
        }
    }

    #[test]
    fn logsumexp_matches_finite_diff() {
        let f = |t: &mut Tape, v: &[Var]| t.logsumexp(v);
        let x = [1.0, -2.0, 0.5, 3.0];
        let (val, g) = grad_of(&x, f);
        let expect = x.iter().map(|v| v.exp()).sum::<f64>().ln();
        assert!((val - expect).abs() < 1e-12);
        let fd = finite_diff(&x, |x| grad_of(x, f).0, 1e-6);
        for i in 0..x.len() {
            assert!((g[i] - fd[i]).abs() < 1e-6);
        }
        assert!((g.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fanout_accumulates() {
        // y = x*x + x  => dy/dx = 2x + 1
        let (v, g) = grad_of(&[3.0], |t, v| {
            let sq = t.mul(v[0], v[0]);
            t.add(sq, v[0])
        });
        assert_eq!(v, 12.0);
        assert_eq!(g[0], 7.0);
    }

    #[test]
    fn composite_partials_flow() {
        // composite computing 2x + 3y with explicit partials
        let (v, g) = grad_of(&[5.0, 7.0], |t, v| {
            let value = 2.0 * t.value(v[0]) + 3.0 * t.value(v[1]);
            t.composite(v, &[2.0, 3.0], value)
        });
        assert_eq!(v, 31.0);
        assert_eq!(g, vec![2.0, 3.0]);
    }

    #[test]
    fn dot_const_and_sum() {
        let (v, g) = grad_of(&[1.0, 2.0, 3.0], |t, v| {
            let d = t.dot_const(v, &[4.0, 5.0, 6.0]);
            let s = t.sum(v);
            t.add(d, s)
        });
        assert_eq!(v, 4.0 + 10.0 + 18.0 + 6.0);
        assert_eq!(g, vec![5.0, 6.0, 7.0]);
    }

    #[test]
    fn powi_negative_exponent() {
        let (v, g) = grad_of(&[2.0], |t, v| t.powi(v[0], -2));
        assert!((v - 0.25).abs() < 1e-15);
        assert!((g[0] + 0.25).abs() < 1e-12);
    }

    fn build_mixed(t: &mut Tape, x: &[f64]) -> (Vec<Var>, Var) {
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let lse = t.logsumexp(&vars);
        let s = t.sum(&vars);
        let d = t.dot_const(&vars, &[0.5, -1.5, 2.0]);
        let m = t.mul(lse, s);
        let out = t.add(m, d);
        (vars, out)
    }

    #[test]
    fn reset_matches_fresh_tape_bitwise() {
        let x = [0.3, -1.2, 0.9];

        let mut fresh = Tape::new();
        let (fvars, fout) = build_mixed(&mut fresh, &x);
        let fval = fresh.value(fout);
        let fgrad: Vec<f64> = {
            let adj = fresh.grad(fout);
            fvars.iter().map(|v| adj[v.0 as usize]).collect()
        };

        let mut reused = Tape::new();
        // pollute with an unrelated expression, then reset
        let a = reused.input(9.0);
        let b = reused.exp(a);
        let c = reused.mul(a, b);
        let _ = reused.grad(c);
        reused.reset();

        let (rvars, rout) = build_mixed(&mut reused, &x);
        assert_eq!(reused.len(), fresh.len());
        assert_eq!(reused.value(rout), fval);
        let adj = reused.grad(rout);
        let rgrad: Vec<f64> = rvars.iter().map(|v| adj[v.0 as usize]).collect();
        assert_eq!(rgrad, fgrad);
    }

    /// The same generic program evaluated over F64Alg and over a tape
    /// must agree bitwise (the model compiler's correctness hinge).
    fn alg_program<A: Alg>(a: &mut A, x: A::V, y: A::V) -> A::V {
        let s = a.add(x, y);
        let e = a.exp(s);
        let l = a.log1p(e);
        let q = a.square(x);
        let sc = a.scale(q, -0.5);
        let sp = a.softplus(y);
        let d = a.div(sc, sp);
        let m = a.mul(l, d);
        let sq = a.sqrt(e);
        let n = a.neg(sq);
        let o = a.offset(m, 0.25);
        let p = a.powi(y, 3);
        let t = a.sub(o, n);
        let ln = a.ln(e);
        let u = a.add(t, p);
        a.add(u, ln)
    }

    #[test]
    fn alg_domains_agree_bitwise() {
        for &(x, y) in &[(0.3, -1.2), (2.0, 0.5), (-0.7, 31.5)] {
            let mut fa = F64Alg;
            let plain = alg_program(&mut fa, x, y);
            let mut t = Tape::new();
            let (vx, vy) = (t.input(x), t.input(y));
            let out = alg_program(&mut t, vx, vy);
            assert_eq!(t.value(out), plain, "x={x} y={y}");
        }
    }

    #[test]
    fn reset_keeps_capacity_watermark() {
        let mut t = Tape::new();
        let x = [0.1, 0.2, 0.3];
        // establish the steady state with one evaluation
        let (_, out) = build_mixed(&mut t, &x);
        let _ = t.grad(out);
        let (nodes, arena) = (t.node_capacity(), t.arena_capacity());
        for _ in 0..10 {
            t.reset();
            let (_, out) = build_mixed(&mut t, &x);
            let _ = t.grad(out);
            assert_eq!(t.node_capacity(), nodes);
            assert_eq!(t.arena_capacity(), arena);
        }
    }

    /// A program hitting every primitive op plus every replayable
    /// composite kind, for the freeze cross-checks.
    fn build_freezable(t: &mut Tape, x: &[f64]) -> (Vec<Var>, Var) {
        let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
        let (mixed_vars, mixed) = {
            let lse = t.logsumexp(&vars);
            let s = t.sum(&vars);
            let d = t.dot_const(&vars, &[0.5, -1.5, 2.0]);
            let m = t.mul(lse, s);
            (vars.clone(), t.add(m, d))
        };
        let sp0 = t.softplus(mixed_vars[0]);
        let sg1 = t.sigmoid(mixed_vars[1]);
        let th2 = t.tanh(mixed_vars[2]);
        let scale = t.exp(sp0);
        let n1 = t.normal_iid_obs(sg1, scale, &[0.4, -0.2, 1.1]);
        let n2 = t.bernoulli_logits_iid_obs(th2, &[1.0, 0.0, 1.0, 1.0]);
        let locs = [mixed_vars[0], mixed_vars[1]];
        let n3 = t.normal_plate_obs(&locs, scale, &[0.9, -0.7]);
        let n4 = t.normal_fixed_plate_obs(&locs, &[1.5, 0.7], &[0.2, 0.3]);
        let n5 = t.bernoulli_logits_plate_obs(&locs, &[0.0, 1.0]);
        let off = t.offset(mixed, -0.125);
        let s1 = t.add(off, n1);
        let s2 = t.add(s1, n2);
        let s3 = t.add(s2, n3);
        let s4 = t.add(s3, n4);
        let out = t.add(s4, n5);
        (mixed_vars, out)
    }

    /// The frozen program's forward/backward must bitwise-equal a tape
    /// replay of the same program at *different* input points (values
    /// and all input adjoints).
    #[test]
    fn frozen_program_matches_replay_bitwise() {
        let x0 = [0.3, -1.2, 0.9];
        let mut t = Tape::new();
        let (vars, out) = build_freezable(&mut t, &x0);
        let mut prog = t.freeze(out);
        assert_eq!(prog.num_inputs(), 3);
        assert!(!prog.is_empty());

        let points = [
            [0.3, -1.2, 0.9],
            [1.7, 0.2, -0.6],
            [-2.0, 3.1, 0.01],
            [31.5, -0.4, 2.2],
        ];
        for p in &points {
            // replay on a fresh tape
            let mut rt = Tape::new();
            let (rvars, rout) = build_freezable(&mut rt, p);
            let rval = rt.value(rout);
            let radj = rt.grad(rout).to_vec();

            let fval = prog.forward(p);
            assert_eq!(fval.to_bits(), rval.to_bits(), "value at {p:?}");
            assert_eq!(prog.output_value().to_bits(), rval.to_bits());
            prog.backward();
            let mut g = vec![0.0; 3];
            prog.input_adjoints(&mut g);
            for (i, v) in rvars.iter().enumerate() {
                assert_eq!(
                    g[i].to_bits(),
                    radj[v.0 as usize].to_bits(),
                    "grad[{i}] at {p:?}"
                );
                assert_eq!(prog.adjoint(vars[i]).to_bits(), radj[v.0 as usize].to_bits());
            }
        }
    }

    /// A frozen program with rebound data slots must bitwise-equal
    /// re-recording the same program against the new data — the
    /// subsampling index-gather contract, across all three slot stores
    /// (dot_const coefficients, fused-plate constants, node leaves).
    #[test]
    fn rebound_slots_match_rerecorded_tape_bitwise() {
        fn build(t: &mut Tape, x: &[f64], coef: &[f64], ys: &[f64], zs: &[f64]) -> (Vec<Var>, Var) {
            let vars: Vec<Var> = x.iter().map(|&v| t.input(v)).collect();
            t.begin_data_region();
            let d = t.dot_const(&vars, coef);
            let sg = t.sigmoid(vars[0]);
            let scale = t.exp(vars[1]);
            let n = t.normal_iid_obs(sg, scale, ys);
            // generic-fallback shape: observation data as constant leaves
            let leaves: Vec<Var> = zs.iter().map(|&z| t.constant(z)).collect();
            t.register_data_nodes(&leaves);
            let mut acc = d;
            for &lz in &leaves {
                let m = t.mul(lz, vars[0]);
                acc = t.add(acc, m);
            }
            t.end_data_region();
            let out = t.add(acc, n);
            (vars, out)
        }
        let x = [0.4, -0.3];
        let (c0, y0, z0) = ([0.5, -1.5], [0.1, 0.9, -0.4], [1.0, 2.0]);
        let (c1, y1, z1) = ([2.0, 0.25], [-0.6, 0.2, 1.3], [-3.0, 0.5]);

        let mut t = Tape::new();
        let (_, out) = build(&mut t, &x, &c0, &y0, &z0);
        assert_eq!(t.num_data_slots(), 3);
        let mut prog = t.freeze(out);
        assert_eq!(prog.num_data_slots(), 3);
        assert_eq!(prog.data_slot_len(0), 2);
        assert_eq!(prog.data_slot_len(1), 3);
        assert_eq!(prog.data_slot_len(2), 2);

        prog.rebind_data_slot(0, &c1);
        prog.rebind_data_slot(1, &y1);
        prog.rebind_data_slot(2, &z1);
        let v = prog.forward(&x);
        prog.backward();
        let mut g = vec![0.0; 2];
        prog.input_adjoints(&mut g);

        let mut rt = Tape::new();
        let (rvars, rout) = build(&mut rt, &x, &c1, &y1, &z1);
        let rval = rt.value(rout);
        let radj = rt.grad(rout).to_vec();
        assert_eq!(v.to_bits(), rval.to_bits());
        for (i, rv) in rvars.iter().enumerate() {
            assert_eq!(g[i].to_bits(), radj[rv.0 as usize].to_bits(), "grad[{i}]");
        }
    }

    #[test]
    #[should_panic(expected = "raw Tape::composite")]
    fn freeze_rejects_opaque_composites() {
        let mut t = Tape::new();
        let x = t.input(1.0);
        let c = t.composite(&[x], &[2.0], 2.0);
        let _ = t.freeze(c);
    }

    /// Fused observation builders must match the per-element generic
    /// construction to floating-point roundoff (gradients via fd).
    #[test]
    fn fused_builders_match_finite_diff() {
        let ys = [0.4, -0.2, 1.1, 0.6];
        let f = |t: &mut Tape, v: &[Var]| {
            let scale = t.exp(v[1]);
            t.normal_iid_obs(v[0], scale, &ys)
        };
        let x = [0.3, -0.4];
        let (_, g) = grad_of(&x, f);
        let fd = finite_diff(
            &x,
            |z| {
                let (loc, scale) = (z[0], z[1].exp());
                ys.iter()
                    .map(|y| {
                        let r = (y - loc) / scale;
                        -0.5 * r * r - scale.ln() - 0.5 * LN_2PI
                    })
                    .sum()
            },
            1e-6,
        );
        for i in 0..2 {
            assert!((g[i] - fd[i]).abs() < 1e-5, "grad[{i}] {} vs {}", g[i], fd[i]);
        }
    }
}
