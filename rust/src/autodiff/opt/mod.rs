//! Optimizing compiler for frozen tape programs.
//!
//! [`TapeProgram`] / [`BatchTapeProgram`] are flat, static IRs, but the
//! stock replay still *interprets* them: one match-dispatch and one
//! full-width value row per recorded node, every evaluation.  This
//! module compiles the frozen topology once into an
//! [`plan::ExecPlan`] — dead code eliminated, constants folded,
//! elementwise runs fused into superblocks, values/adjoints re-slotted
//! into a small recycled register file — and replays *that* through a
//! threaded-code dispatch loop ([`dispatch`]).
//!
//! The contract is the repo-wide bitwise discipline: **no pass is
//! allowed to change a single bit of any output**.  The interpreter
//! stays the oracle (the debug-mode replay audit in
//! `compile/{potential,batch_potential}.rs` now checks the optimized
//! path against a fresh tape replay as well), every pass preserves IEEE
//! evaluation order on the surviving computation, constant folding
//! pins *recorded* values instead of re-deriving them, and data-slot
//! rebinding survives re-slotting through explicit remap tables.
//! `rust/tests/tape_opt.rs` fuzzes 500 random programs across lane
//! counts against the interpreter, bit for bit.
//!
//! Entry points: [`TapeProgram::optimize`] /
//! [`BatchTapeProgram::optimize`], normally reached through
//! `CompiledModel::set_optimized` (on by default).

pub(crate) mod dispatch;
pub(crate) mod plan;

pub use plan::PlanStats;

use super::batch::{BOp, BatchTapeProgram};
use super::{BatchTape, DataSlot, Op, SlotStore, Tape, TapeProgram, Var};
use plan::{build_plan, ExecPlan, GOp, PlanInput};

fn gops_scalar(ops: &[Op]) -> Vec<GOp> {
    ops.iter()
        .map(|op| match *op {
            Op::Leaf => GOp::Leaf,
            Op::Input => GOp::Input,
            Op::Add(x, y) => GOp::Add(x, y),
            Op::Sub(x, y) => GOp::Sub(x, y),
            Op::Mul(x, y) => GOp::Mul(x, y),
            Op::Div(x, y) => GOp::Div(x, y),
            Op::Neg(x) => GOp::Neg(x),
            Op::Exp(x) => GOp::Exp(x),
            Op::Ln(x) => GOp::Ln(x),
            Op::Log1p(x) => GOp::Log1p(x),
            Op::Sqrt(x) => GOp::Sqrt(x),
            Op::Sigmoid(x) => GOp::Sigmoid(x),
            Op::Softplus(x) => GOp::Softplus(x),
            Op::Tanh(x) => GOp::Tanh(x),
            Op::Powi(x, n) => GOp::Powi(x, n),
            Op::Scale(x, c) => GOp::Scale(x, c),
            Op::Offset(x, c) => GOp::Offset(x, c),
            // the scalar arena interleaves parents and partials at the
            // same indices, so both spans start at `start`
            Op::Composite { start, len } => GOp::Composite {
                pstart: start,
                xstart: start,
                len,
            },
        })
        .collect()
}

fn gops_batch(ops: &[BOp]) -> Vec<GOp> {
    ops.iter()
        .map(|op| match *op {
            BOp::Leaf => GOp::Leaf,
            BOp::Input => GOp::Input,
            BOp::Add(x, y) => GOp::Add(x, y),
            BOp::Sub(x, y) => GOp::Sub(x, y),
            BOp::Mul(x, y) => GOp::Mul(x, y),
            BOp::Div(x, y) => GOp::Div(x, y),
            BOp::Neg(x) => GOp::Neg(x),
            BOp::Exp(x) => GOp::Exp(x),
            BOp::Ln(x) => GOp::Ln(x),
            BOp::Log1p(x) => GOp::Log1p(x),
            BOp::Sqrt(x) => GOp::Sqrt(x),
            BOp::Sigmoid(x) => GOp::Sigmoid(x),
            BOp::Softplus(x) => GOp::Softplus(x),
            BOp::Powi(x, n) => GOp::Powi(x, n),
            BOp::Scale(x, c) => GOp::Scale(x, c),
            BOp::Offset(x, c) => GOp::Offset(x, c),
            BOp::Composite { pstart, xstart, len } => GOp::Composite { pstart, xstart, len },
            BOp::CompositeShared { pstart, sstart, len } => {
                GOp::CompositeShared { pstart, sstart, len }
            }
        })
        .collect()
}

/// An optimized scalar gradient program: the [`plan::ExecPlan`]
/// compiled from a frozen [`TapeProgram`] plus its private register
/// file.  Drop-in replacement for the interpreted program — same
/// `forward`/`backward`/`input_adjoints`/`rebind_data_slot` surface,
/// bitwise-identical results, zero steady-state allocations.
pub struct OptTapeProgram {
    plan: ExecPlan,
    /// value register file (`num_val_slots`, pinned + recycled)
    regs: Vec<f64>,
    /// adjoint register file (`num_adj_slots`)
    adj: Vec<f64>,
    /// composite partial arena (full recorded width — not re-slotted,
    /// so `Coeffs` data slots rebind at their recorded indices)
    partials: Vec<f64>,
    /// fused-kernel constants (observations; `Consts` rebind target)
    consts: Vec<f64>,
}

impl OptTapeProgram {
    pub(crate) fn compile(prog: &TapeProgram) -> OptTapeProgram {
        let gops = gops_scalar(&prog.topo.ops);
        let plan = build_plan(&PlanInput {
            ops: &gops,
            comp_kinds: &prog.topo.comp_kinds,
            arena_parents: &prog.topo.arena_parents,
            inputs: &prog.topo.inputs,
            data_slots: &prog.topo.data_slots,
            slot_nodes: &prog.topo.slot_nodes,
            output: prog.output,
            rec_values: &prog.values,
        });
        let mut regs = vec![0.0; plan.num_val_slots];
        for &(s, v) in &plan.init_values {
            regs[s as usize] = v;
        }
        let adj = vec![0.0; plan.num_adj_slots];
        OptTapeProgram {
            regs,
            adj,
            partials: prog.partials.clone(),
            consts: prog.topo.consts.clone(),
            plan,
        }
    }

    /// Rebind the inputs and execute the forward plan; returns the
    /// output value.  Zero allocations.
    pub fn forward(&mut self, inputs: &[f64]) -> f64 {
        dispatch::scalar_forward(
            &self.plan,
            &mut self.regs,
            &mut self.partials,
            &self.consts,
            inputs,
        )
    }

    /// Execute the backward plan against the state left by the last
    /// [`forward`].
    ///
    /// [`forward`]: OptTapeProgram::forward
    pub fn backward(&mut self) {
        dispatch::scalar_backward(&self.plan, &self.regs, &self.partials, &mut self.adj)
    }

    /// Copy the input adjoints (record order) into `grad` after a
    /// [`backward`].
    ///
    /// [`backward`]: OptTapeProgram::backward
    pub fn input_adjoints(&self, grad: &mut [f64]) {
        for (g, &s) in grad.iter_mut().zip(self.plan.input_adj_slots.iter()) {
            *g = self.adj[s as usize];
        }
    }

    /// Output value left by the last [`forward`].
    ///
    /// [`forward`]: OptTapeProgram::forward
    pub fn output_value(&self) -> f64 {
        self.regs[self.plan.output_val_slot as usize]
    }

    pub fn num_inputs(&self) -> usize {
        self.plan.input_val_slots.len()
    }

    pub fn num_data_slots(&self) -> usize {
        self.plan.data_slots.len()
    }

    pub fn data_slot_len(&self, slot: usize) -> usize {
        self.plan.data_slots[slot].len as usize
    }

    /// Rebind a data slot — the optimized twin of
    /// [`TapeProgram::rebind_data_slot`].  `Coeffs`/`Consts` spans keep
    /// their recorded indices (those arenas are not re-slotted);
    /// `Nodes` spans route through the plan's slot-remap table.
    pub fn rebind_data_slot(&mut self, slot: usize, data: &[f64]) {
        let DataSlot { store, start, len } = self.plan.data_slots[slot];
        let (s, l) = (start as usize, len as usize);
        assert_eq!(data.len(), l, "rebind_data_slot: length mismatch");
        match store {
            SlotStore::Coeffs => self.partials[s..s + l].copy_from_slice(data),
            SlotStore::Consts => self.consts[s..s + l].copy_from_slice(data),
            SlotStore::Nodes => {
                for (j, &rs) in self.plan.slot_node_slots[s..s + l].iter().enumerate() {
                    self.regs[rs as usize] = data[j];
                }
            }
        }
    }

    /// Compile-time plan statistics (DCE/fusion/slot-reuse effect).
    pub fn stats(&self) -> PlanStats {
        self.plan.stats
    }
}

/// An optimized batched gradient program compiled from a frozen
/// [`BatchTapeProgram`]: same lane-minor layout and surface, executing
/// the fused plan on a recycled register file whose working set is
/// `peak_val_slots * lanes` instead of `nodes * lanes`.
pub struct OptBatchTapeProgram {
    lanes: usize,
    plan: ExecPlan,
    /// lane-minor value register file: `regs[slot * lanes + k]`
    regs: Vec<f64>,
    /// lane-minor adjoint register file
    adj: Vec<f64>,
    /// per-lane composite partial arena (full recorded width)
    partials: Vec<f64>,
    /// lane-shared composite coefficients (`Coeffs` rebind target)
    shared: Vec<f64>,
    /// fused-kernel constants (`Consts` rebind target)
    consts: Vec<f64>,
    /// lane-sized composite output scratch
    vals: Vec<f64>,
    /// lane-sized fused-kernel scratch
    acc_a: Vec<f64>,
    acc_b: Vec<f64>,
}

impl OptBatchTapeProgram {
    pub(crate) fn compile(prog: &BatchTapeProgram) -> OptBatchTapeProgram {
        let l = prog.lanes;
        let n = prog.topo.ops.len();
        // lane 0 stands in for the recorded value of every foldable
        // node: leaves are recorded lane-uniform (`constant`
        // broadcasts), and anything derived from uniform leaves by the
        // same per-lane op stays uniform
        let rec: Vec<f64> = (0..n).map(|i| prog.values[i * l]).collect();
        #[cfg(debug_assertions)]
        for i in 0..n {
            if matches!(prog.topo.ops[i], BOp::Leaf) {
                let b0 = prog.values[i * l].to_bits();
                assert!(
                    prog.values[i * l..(i + 1) * l]
                        .iter()
                        .all(|v| v.to_bits() == b0),
                    "OptBatchTapeProgram::compile: non-lane-uniform constant leaf {}",
                    i
                );
            }
        }
        let gops = gops_batch(&prog.topo.ops);
        let plan = build_plan(&PlanInput {
            ops: &gops,
            comp_kinds: &prog.topo.comp_kinds,
            arena_parents: &prog.topo.arena_parents,
            inputs: &prog.topo.inputs,
            data_slots: &prog.topo.data_slots,
            slot_nodes: &prog.topo.slot_nodes,
            output: prog.output,
            rec_values: &rec,
        });
        let mut regs = vec![0.0; plan.num_val_slots * l];
        for &(s, v) in &plan.init_values {
            let d = s as usize * l;
            regs[d..d + l].fill(v);
        }
        let adj = vec![0.0; plan.num_adj_slots * l];
        OptBatchTapeProgram {
            lanes: l,
            regs,
            adj,
            partials: prog.partials.clone(),
            shared: prog.topo.arena_shared.clone(),
            consts: prog.topo.consts.clone(),
            vals: vec![0.0; l],
            acc_a: vec![0.0; l],
            acc_b: vec![0.0; l],
            plan,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn num_inputs(&self) -> usize {
        self.plan.input_val_slots.len()
    }

    /// Rebind the inputs (input-major, lane-minor) and execute the
    /// forward plan.  Zero allocations.
    pub fn forward(&mut self, inputs: &[f64]) {
        dispatch::batch_forward(
            &self.plan,
            self.lanes,
            &mut self.regs,
            &mut self.partials,
            &self.shared,
            &self.consts,
            &mut self.vals,
            &mut self.acc_a,
            &mut self.acc_b,
            inputs,
        )
    }

    /// Execute the backward plan against the state left by the last
    /// [`forward`].
    ///
    /// [`forward`]: OptBatchTapeProgram::forward
    pub fn backward(&mut self) {
        dispatch::batch_backward(
            &self.plan,
            self.lanes,
            &self.regs,
            &self.partials,
            &self.shared,
            &mut self.adj,
        )
    }

    /// Lane values of the output after the last [`forward`].
    ///
    /// [`forward`]: OptBatchTapeProgram::forward
    pub fn output_values(&self) -> &[f64] {
        let s = self.plan.output_val_slot as usize * self.lanes;
        &self.regs[s..s + self.lanes]
    }

    /// Copy the input adjoints (input-major, lane-minor) into `grad`
    /// after a [`backward`].
    ///
    /// [`backward`]: OptBatchTapeProgram::backward
    pub fn input_adjoints(&self, grad: &mut [f64]) {
        let l = self.lanes;
        for (k, &s) in self.plan.input_adj_slots.iter().enumerate() {
            let a = s as usize * l;
            grad[k * l..(k + 1) * l].copy_from_slice(&self.adj[a..a + l]);
        }
    }

    pub fn num_data_slots(&self) -> usize {
        self.plan.data_slots.len()
    }

    pub fn data_slot_len(&self, slot: usize) -> usize {
        self.plan.data_slots[slot].len as usize
    }

    /// Rebind a data slot — the optimized twin of
    /// [`BatchTapeProgram::rebind_data_slot`] (node slots broadcast to
    /// every lane through the slot-remap table).
    pub fn rebind_data_slot(&mut self, slot: usize, data: &[f64]) {
        let DataSlot { store, start, len } = self.plan.data_slots[slot];
        let (s, l) = (start as usize, len as usize);
        assert_eq!(data.len(), l, "rebind_data_slot: length mismatch");
        match store {
            SlotStore::Coeffs => self.shared[s..s + l].copy_from_slice(data),
            SlotStore::Consts => self.consts[s..s + l].copy_from_slice(data),
            SlotStore::Nodes => {
                let lanes = self.lanes;
                for (j, &rs) in self.plan.slot_node_slots[s..s + l].iter().enumerate() {
                    let d = rs as usize * lanes;
                    self.regs[d..d + lanes].fill(data[j]);
                }
            }
        }
    }

    /// Compile-time plan statistics (DCE/fusion/slot-reuse effect).
    pub fn stats(&self) -> PlanStats {
        self.plan.stats
    }
}

impl TapeProgram {
    /// Compile this frozen program into an [`OptTapeProgram`]:
    /// DCE + constant folding, superblock fusion and register
    /// re-slotting, bitwise-identical to interpreted replay.
    pub fn optimize(&self) -> OptTapeProgram {
        OptTapeProgram::compile(self)
    }
}

impl BatchTapeProgram {
    /// Compile this frozen program into an [`OptBatchTapeProgram`]
    /// (see [`TapeProgram::optimize`]).
    pub fn optimize(&self) -> OptBatchTapeProgram {
        OptBatchTapeProgram::compile(self)
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(x: f64) -> u64 {
        x.to_bits()
    }

    /// Record a small mixed program: elementwise prologue, a fused
    /// observation composite, elementwise epilogue, plus a dead branch
    /// and a constant subexpression.
    fn record_mixed(tape: &mut Tape, x0: f64, x1: f64) -> Var {
        let a = tape.input(x0);
        let b = tape.input(x1);
        let c = tape.constant(2.5);
        let cc = tape.ln(c); // foldable: constant subexpression
        let s = tape.softplus(b);
        let loc = tape.mul(a, cc);
        let t = tape.tanh(loc);
        let dead = tape.exp(t); // never reaches the output
        let _ = tape.sqrt(dead); // dead chain
        let obs = tape.normal_iid_obs(loc, s, &[0.3, -1.2, 0.7]);
        let sc = tape.scale(obs, 1.0); // lik_scale == 1.0 shape: must survive
        let d = tape.div(sc, c);
        tape.add(d, t)
    }

    fn grads(prog: &mut TapeProgram, inputs: &[f64]) -> (f64, Vec<f64>) {
        let u = prog.forward(inputs);
        prog.backward();
        let mut g = vec![0.0; prog.num_inputs()];
        prog.input_adjoints(&mut g);
        (u, g)
    }

    fn opt_grads(prog: &mut OptTapeProgram, inputs: &[f64]) -> (f64, Vec<f64>) {
        let u = prog.forward(inputs);
        prog.backward();
        let mut g = vec![0.0; prog.num_inputs()];
        prog.input_adjoints(&mut g);
        (u, g)
    }

    #[test]
    fn optimized_matches_interpreter_bitwise() {
        let mut tape = Tape::new();
        let out = record_mixed(&mut tape, 0.4, -0.9);
        let mut prog = tape.freeze(out);
        let mut opt = prog.optimize();
        for pt in [[0.4, -0.9], [1.7, 2.2], [-3.1, 0.05], [0.0, 0.0]] {
            let (u_i, g_i) = grads(&mut prog, &pt);
            let (u_o, g_o) = opt_grads(&mut opt, &pt);
            assert_eq!(bits(u_i), bits(u_o), "forward value diverged at {:?}", pt);
            for (gi, go) in g_i.iter().zip(g_o.iter()) {
                assert_eq!(bits(*gi), bits(*go), "gradient diverged at {:?}", pt);
            }
            assert_eq!(bits(opt.output_value()), bits(u_i));
        }
    }

    #[test]
    fn dce_folding_and_slot_reuse_shrink_the_plan() {
        let mut tape = Tape::new();
        let out = record_mixed(&mut tape, 0.4, -0.9);
        let prog = tape.freeze(out);
        let opt = prog.optimize();
        let st = opt.stats();
        assert_eq!(st.nodes_total, prog.len());
        // the exp/sqrt dead chain must be eliminated
        assert!(st.nodes_live < st.nodes_total, "DCE found nothing: {:?}", st);
        // ln(2.5) must be folded
        assert!(st.nodes_folded >= 1, "constant folding found nothing: {:?}", st);
        // prologue and epilogue fuse around the one composite
        assert_eq!(st.composites, 1);
        assert!(st.fused_runs >= 2, "expected >= 2 superblocks: {:?}", st);
        assert!(st.micro_ops < st.nodes_live);
        // the register file must be narrower than one row per node
        assert!(st.peak_val_slots < st.nodes_total, "no slot reuse: {:?}", st);
        assert!(st.peak_adj_slots <= st.nodes_total);
    }

    #[test]
    fn output_is_input_and_constant_output_edge_cases() {
        // output == input: forward is the identity, gradient is 1
        let mut tape = Tape::new();
        let x = tape.input(0.7);
        let _ = tape.exp(x); // dead
        let mut prog = tape.freeze(x);
        let mut opt = prog.optimize();
        let (u_i, g_i) = grads(&mut prog, &[2.25]);
        let (u_o, g_o) = opt_grads(&mut opt, &[2.25]);
        assert_eq!(bits(u_i), bits(u_o));
        assert_eq!(bits(g_i[0]), bits(g_o[0]));
        assert_eq!(g_o[0], 1.0);

        // constant output: gradient of every input is exactly 0
        let mut tape = Tape::new();
        let _x = tape.input(0.3);
        let c = tape.constant(4.0);
        let out = tape.sqrt(c);
        let mut prog = tape.freeze(out);
        let mut opt = prog.optimize();
        let (u_i, g_i) = grads(&mut prog, &[9.9]);
        let (u_o, g_o) = opt_grads(&mut opt, &[9.9]);
        assert_eq!(bits(u_i), bits(u_o));
        assert_eq!(bits(g_i[0]), bits(g_o[0]));
        assert_eq!(g_o[0], 0.0);
    }

    #[test]
    fn node_slot_rebinding_survives_reslotting() {
        // per-element observation leaves registered as a Nodes slot:
        // rebinding after optimization must hit the remapped registers
        let build = |ys: &[f64]| {
            let mut tape = Tape::new();
            let mu = tape.input(0.2);
            tape.begin_data_region();
            let leaves: Vec<Var> = ys.iter().map(|&y| tape.constant(y)).collect();
            tape.register_data_nodes(&leaves);
            tape.end_data_region();
            let mut acc = tape.constant(0.0);
            for &leaf in &leaves {
                let r = tape.sub(leaf, mu);
                let r2 = tape.square(r);
                acc = tape.add(acc, r2);
            }
            let out = tape.scale(acc, -0.5);
            tape.freeze(out)
        };
        let mut prog = build(&[1.0, 2.0, 3.0]);
        let mut opt = prog.optimize();
        // rebind both paths to a fresh "minibatch" and compare against
        // a program recorded directly on that data
        let fresh = [0.25, -1.5, 4.0];
        prog.rebind_data_slot(0, &fresh);
        opt.rebind_data_slot(0, &fresh);
        let mut oracle = build(&fresh);
        for pt in [[0.2], [-1.4], [3.3]] {
            let (u_i, g_i) = grads(&mut prog, &pt);
            let (u_o, g_o) = opt_grads(&mut opt, &pt);
            let (u_f, g_f) = grads(&mut oracle, &pt);
            assert_eq!(bits(u_i), bits(u_o));
            assert_eq!(bits(u_f), bits(u_o));
            assert_eq!(bits(g_i[0]), bits(g_o[0]));
            assert_eq!(bits(g_f[0]), bits(g_o[0]));
        }
    }

    #[test]
    fn coeffs_slot_rebinding_survives_optimization() {
        // dot_const coefficients live in the partial arena, which is
        // *not* re-slotted — rebinding must keep working on both paths
        let mut tape = Tape::new();
        let w0 = tape.input(0.5);
        let w1 = tape.input(-0.25);
        tape.begin_data_region();
        let dot = tape.dot_const(&[w0, w1], &[1.0, 2.0]);
        tape.end_data_region();
        let out = tape.softplus(dot);
        let mut prog = tape.freeze(out);
        let mut opt = prog.optimize();
        prog.rebind_data_slot(0, &[-3.0, 0.75]);
        opt.rebind_data_slot(0, &[-3.0, 0.75]);
        for pt in [[0.5, -0.25], [2.0, 2.0]] {
            let (u_i, g_i) = grads(&mut prog, &pt);
            let (u_o, g_o) = opt_grads(&mut opt, &pt);
            assert_eq!(bits(u_i), bits(u_o));
            for (gi, go) in g_i.iter().zip(g_o.iter()) {
                assert_eq!(bits(*gi), bits(*go));
            }
        }
    }

    #[test]
    fn batched_optimized_matches_interpreter_bitwise() {
        let lanes = 4usize;
        let mut tape = BatchTape::new(lanes);
        let a = tape.input(&[0.4, 1.7, -3.1, 0.0]);
        let b = tape.input(&[-0.9, 2.2, 0.05, 0.0]);
        let c = tape.constant(2.5);
        let cc = tape.ln(c);
        let s = tape.softplus(b);
        let loc = tape.mul(a, cc);
        let dead = tape.exp(loc);
        let _ = tape.sqrt(dead);
        let obs = tape.normal_iid_obs(loc, s, &[0.3, -1.2, 0.7]);
        let sum = tape.sum(&[obs, loc]);
        let out = tape.scale(sum, 1.0);
        let mut prog = tape.freeze(out);
        let mut opt = prog.optimize();
        let n_in = prog.num_inputs();
        let inputs: Vec<f64> = (0..n_in * lanes).map(|i| 0.3 * i as f64 - 1.1).collect();
        prog.forward(&inputs);
        prog.backward();
        let mut g_i = vec![0.0; n_in * lanes];
        prog.input_adjoints(&mut g_i);
        opt.forward(&inputs);
        opt.backward();
        let mut g_o = vec![0.0; n_in * lanes];
        opt.input_adjoints(&mut g_o);
        for (ui, uo) in prog.output_values().iter().zip(opt.output_values()) {
            assert_eq!(bits(*ui), bits(*uo));
        }
        for (gi, go) in g_i.iter().zip(g_o.iter()) {
            assert_eq!(bits(*gi), bits(*go));
        }
        let st = opt.stats();
        assert!(st.nodes_live < st.nodes_total);
        assert!(st.peak_val_slots < st.nodes_total);
    }
}
