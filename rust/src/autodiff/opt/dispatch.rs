//! Threaded-code executors for an [`ExecPlan`] — the scalar and
//! lane-minor batched forward/backward dispatch loops.
//!
//! Every arithmetic statement here is a transcription of the matching
//! interpreter rule (`TapeProgram::forward` / `reverse_sweep` /
//! `BatchTapeProgram::forward` / `batch_reverse_sweep`) with node rows
//! replaced by register slots: same expressions, same operand order,
//! same zero-adjoint skips, same composite kernels
//! (`scalar_composite_forward` / `batch_composite_forward` are shared,
//! not reimplemented).  That transcription — plus the plan builder's
//! guarantee that fused runs preserve recorded op order — is what makes
//! the optimized path bitwise-identical to interpreted replay.
//!
//! Register aliasing is safe by construction: a destination register
//! may recycle a parent that dies at the same node, and every
//! elementwise statement reads its operands before writing (per lane),
//! while composite kernels finish reading before their result is
//! stored.  Adjoint registers for a node and its parents are always
//! distinct (a node's register is recycled only after its backward
//! instruction is emitted).

use super::plan::{BwdInstr, ExecPlan, FwdInstr, MicroOp, NONE};
use crate::autodiff::batch::{batch_composite_forward, MICRO_LANES};
use crate::autodiff::{scalar_composite_forward, sigmoid_val, softplus_val};

#[inline(always)]
fn micro_scalar(m: MicroOp, regs: &mut [f64]) {
    match m {
        MicroOp::Add { x, y, d } => regs[d as usize] = regs[x as usize] + regs[y as usize],
        MicroOp::Sub { x, y, d } => regs[d as usize] = regs[x as usize] - regs[y as usize],
        MicroOp::Mul { x, y, d } => regs[d as usize] = regs[x as usize] * regs[y as usize],
        MicroOp::Div { x, y, d } => regs[d as usize] = regs[x as usize] / regs[y as usize],
        MicroOp::Neg { x, d } => regs[d as usize] = -regs[x as usize],
        MicroOp::Exp { x, d } => regs[d as usize] = regs[x as usize].exp(),
        MicroOp::Ln { x, d } => regs[d as usize] = regs[x as usize].ln(),
        MicroOp::Log1p { x, d } => regs[d as usize] = regs[x as usize].ln_1p(),
        MicroOp::Sqrt { x, d } => regs[d as usize] = regs[x as usize].sqrt(),
        MicroOp::Sigmoid { x, d } => regs[d as usize] = sigmoid_val(regs[x as usize]),
        MicroOp::Softplus { x, d } => regs[d as usize] = softplus_val(regs[x as usize]),
        MicroOp::Tanh { x, d } => regs[d as usize] = regs[x as usize].tanh(),
        MicroOp::Powi { x, d, n } => regs[d as usize] = regs[x as usize].powi(n),
        MicroOp::Scale { x, d, c } => regs[d as usize] = c * regs[x as usize],
        MicroOp::Offset { x, d, c } => regs[d as usize] = regs[x as usize] + c,
    }
}

/// Execute the forward plan on the scalar register file; returns the
/// output value.  Zero allocations.
pub(super) fn scalar_forward(
    plan: &ExecPlan,
    regs: &mut [f64],
    partials: &mut [f64],
    consts: &[f64],
    inputs: &[f64],
) -> f64 {
    debug_assert_eq!(inputs.len(), plan.input_val_slots.len());
    for (k, &s) in plan.input_val_slots.iter().enumerate() {
        regs[s as usize] = inputs[k];
    }
    for instr in &plan.fwd {
        match *instr {
            FwdInstr::Run { start, len } => {
                for &m in &plan.micro[start as usize..(start + len) as usize] {
                    micro_scalar(m, regs);
                }
            }
            FwdInstr::Composite { dst, kind, pstart, len, .. } => {
                let v = scalar_composite_forward(
                    kind,
                    pstart as usize,
                    len as usize,
                    &plan.parents,
                    consts,
                    regs,
                    partials,
                );
                regs[dst as usize] = v;
            }
            FwdInstr::CompositeShared { .. } => {
                unreachable!("CompositeShared only occurs in batched programs")
            }
        }
    }
    regs[plan.output_val_slot as usize]
}

/// Execute the backward plan on the scalar register file.  `regs` and
/// `partials` are the state left by [`scalar_forward`].
pub(super) fn scalar_backward(plan: &ExecPlan, regs: &[f64], partials: &[f64], adj: &mut [f64]) {
    for instr in &plan.bwd {
        match *instr {
            BwdInstr::Zero { a } => adj[a as usize] = 0.0,
            BwdInstr::Seed { a } => adj[a as usize] = 1.0,
            BwdInstr::Add { a, ax, ay } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                if ax != NONE {
                    adj[ax as usize] += av;
                }
                if ay != NONE {
                    adj[ay as usize] += av;
                }
            }
            BwdInstr::Sub { a, ax, ay } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                if ax != NONE {
                    adj[ax as usize] += av;
                }
                if ay != NONE {
                    adj[ay as usize] -= av;
                }
            }
            BwdInstr::Mul { a, ax, ay, vx, vy } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                if ax != NONE {
                    adj[ax as usize] += av * regs[vy as usize];
                }
                if ay != NONE {
                    adj[ay as usize] += av * regs[vx as usize];
                }
            }
            BwdInstr::Div { a, ax, ay, vx, vy } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                if ax != NONE {
                    adj[ax as usize] += av / regs[vy as usize];
                }
                if ay != NONE {
                    let vyv = regs[vy as usize];
                    adj[ay as usize] -= av * regs[vx as usize] / (vyv * vyv);
                }
            }
            BwdInstr::Neg { a, ax } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] -= av;
            }
            BwdInstr::Exp { a, ax, v } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] += av * regs[v as usize];
            }
            BwdInstr::Sqrt { a, ax, v } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] += av * 0.5 / regs[v as usize];
            }
            BwdInstr::Sigmoid { a, ax, v } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                let vi = regs[v as usize];
                adj[ax as usize] += av * vi * (1.0 - vi);
            }
            BwdInstr::Tanh { a, ax, v } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                let vi = regs[v as usize];
                adj[ax as usize] += av * (1.0 - vi * vi);
            }
            BwdInstr::Ln { a, ax, vx } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] += av / regs[vx as usize];
            }
            BwdInstr::Log1p { a, ax, vx } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] += av / (1.0 + regs[vx as usize]);
            }
            BwdInstr::Softplus { a, ax, vx } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                let s = sigmoid_val(regs[vx as usize]);
                adj[ax as usize] += av * s;
            }
            BwdInstr::Powi { a, ax, vx, n } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                let xv = regs[vx as usize];
                adj[ax as usize] += av * (n as f64) * xv.powi(n - 1);
            }
            BwdInstr::Scale { a, ax, c } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] += av * c;
            }
            BwdInstr::Offset { a, ax } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                adj[ax as usize] += av;
            }
            BwdInstr::Composite { a, estart, elen } => {
                let av = adj[a as usize];
                if av == 0.0 {
                    continue;
                }
                for e in estart as usize..(estart + elen) as usize {
                    adj[plan.edge_adj[e] as usize] +=
                        av * partials[plan.edge_partial[e] as usize];
                }
            }
            BwdInstr::CompositeShared { .. } => {
                unreachable!("CompositeShared only occurs in batched programs")
            }
        }
    }
}

#[inline(always)]
fn micro_batch(m: MicroOp, regs: &mut [f64], base: usize, w: usize, l: usize) {
    match m {
        MicroOp::Add { x, y, d } => {
            let (xs, ys, ds) = (
                x as usize * l + base,
                y as usize * l + base,
                d as usize * l + base,
            );
            for j in 0..w {
                regs[ds + j] = regs[xs + j] + regs[ys + j];
            }
        }
        MicroOp::Sub { x, y, d } => {
            let (xs, ys, ds) = (
                x as usize * l + base,
                y as usize * l + base,
                d as usize * l + base,
            );
            for j in 0..w {
                regs[ds + j] = regs[xs + j] - regs[ys + j];
            }
        }
        MicroOp::Mul { x, y, d } => {
            let (xs, ys, ds) = (
                x as usize * l + base,
                y as usize * l + base,
                d as usize * l + base,
            );
            for j in 0..w {
                regs[ds + j] = regs[xs + j] * regs[ys + j];
            }
        }
        MicroOp::Div { x, y, d } => {
            let (xs, ys, ds) = (
                x as usize * l + base,
                y as usize * l + base,
                d as usize * l + base,
            );
            for j in 0..w {
                regs[ds + j] = regs[xs + j] / regs[ys + j];
            }
        }
        MicroOp::Neg { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = -regs[xs + j];
            }
        }
        MicroOp::Exp { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j].exp();
            }
        }
        MicroOp::Ln { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j].ln();
            }
        }
        MicroOp::Log1p { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j].ln_1p();
            }
        }
        MicroOp::Sqrt { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j].sqrt();
            }
        }
        MicroOp::Sigmoid { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = sigmoid_val(regs[xs + j]);
            }
        }
        MicroOp::Softplus { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = softplus_val(regs[xs + j]);
            }
        }
        MicroOp::Tanh { x, d } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j].tanh();
            }
        }
        MicroOp::Powi { x, d, n } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j].powi(n);
            }
        }
        MicroOp::Scale { x, d, c } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = c * regs[xs + j];
            }
        }
        MicroOp::Offset { x, d, c } => {
            let (xs, ds) = (x as usize * l + base, d as usize * l + base);
            for j in 0..w {
                regs[ds + j] = regs[xs + j] + c;
            }
        }
    }
}

/// Execute the forward plan on the lane-minor batched register file
/// (`regs[slot * lanes + k]`).  Fused runs sweep in `MICRO_LANES`
/// blocks with the run's ops applied per block (block-major loop
/// interchange — bitwise-safe because lanes are independent), with a
/// ragged remainder block.  Zero allocations.
#[allow(clippy::too_many_arguments)]
pub(super) fn batch_forward(
    plan: &ExecPlan,
    lanes: usize,
    regs: &mut [f64],
    partials: &mut [f64],
    shared: &[f64],
    consts: &[f64],
    vals: &mut [f64],
    acc_a: &mut [f64],
    acc_b: &mut [f64],
    inputs: &[f64],
) {
    let l = lanes;
    debug_assert_eq!(inputs.len(), plan.input_val_slots.len() * l);
    for (k, &s) in plan.input_val_slots.iter().enumerate() {
        let d = s as usize * l;
        regs[d..d + l].copy_from_slice(&inputs[k * l..(k + 1) * l]);
    }
    for instr in &plan.fwd {
        match *instr {
            FwdInstr::Run { start, len } => {
                let ops = &plan.micro[start as usize..(start + len) as usize];
                let mut base = 0usize;
                while base + MICRO_LANES <= l {
                    for &m in ops {
                        micro_batch(m, regs, base, MICRO_LANES, l);
                    }
                    base += MICRO_LANES;
                }
                if base < l {
                    let w = l - base;
                    for &m in ops {
                        micro_batch(m, regs, base, w, l);
                    }
                }
            }
            FwdInstr::Composite { dst, kind, pstart, xstart, .. } => {
                batch_composite_forward(
                    kind,
                    l,
                    pstart as usize,
                    xstart as usize,
                    &plan.parents,
                    consts,
                    regs,
                    partials,
                    vals,
                    acc_a,
                    acc_b,
                );
                let d = dst as usize * l;
                regs[d..d + l].copy_from_slice(vals);
            }
            FwdInstr::CompositeShared { dst, pstart, sstart, len } => {
                for v in vals.iter_mut() {
                    *v = 0.0;
                }
                for j in 0..len as usize {
                    let p = shared[sstart as usize + j];
                    let s = plan.parents[pstart as usize + j] as usize * l;
                    for k in 0..l {
                        vals[k] += p * regs[s + k];
                    }
                }
                let d = dst as usize * l;
                regs[d..d + l].copy_from_slice(vals);
            }
        }
    }
}

/// Execute the backward plan on the lane-minor batched register file.
/// Adjoint registers for a node and its parents are disjoint, so plain
/// sequential indexing reproduces the interpreter's
/// `split_at_mut`-based sweep exactly (per-lane reads of the node
/// adjoint precede the parent accumulation, edge loops run x-block
/// then y-block, and the all-lanes-zero skip is preserved).
pub(super) fn batch_backward(
    plan: &ExecPlan,
    lanes: usize,
    regs: &[f64],
    partials: &[f64],
    shared: &[f64],
    adj: &mut [f64],
) {
    let l = lanes;
    for instr in &plan.bwd {
        match *instr {
            BwdInstr::Zero { a } => {
                let s = a as usize * l;
                for v in &mut adj[s..s + l] {
                    *v = 0.0;
                }
            }
            BwdInstr::Seed { a } => {
                let s = a as usize * l;
                for v in &mut adj[s..s + l] {
                    *v = 1.0;
                }
            }
            BwdInstr::Add { a, ax, ay } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                if ax != NONE {
                    let xs = ax as usize * l;
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[xs + k] += ak;
                        }
                    }
                }
                if ay != NONE {
                    let ys = ay as usize * l;
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[ys + k] += ak;
                        }
                    }
                }
            }
            BwdInstr::Sub { a, ax, ay } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                if ax != NONE {
                    let xs = ax as usize * l;
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[xs + k] += ak;
                        }
                    }
                }
                if ay != NONE {
                    let ys = ay as usize * l;
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[ys + k] -= ak;
                        }
                    }
                }
            }
            BwdInstr::Mul { a, ax, ay, vx, vy } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                if ax != NONE {
                    let (xs, vys) = (ax as usize * l, vy as usize * l);
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[xs + k] += ak * regs[vys + k];
                        }
                    }
                }
                if ay != NONE {
                    let (ys, vxs) = (ay as usize * l, vx as usize * l);
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[ys + k] += ak * regs[vxs + k];
                        }
                    }
                }
            }
            BwdInstr::Div { a, ax, ay, vx, vy } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                if ax != NONE {
                    let (xs, vys) = (ax as usize * l, vy as usize * l);
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[xs + k] += ak / regs[vys + k];
                        }
                    }
                }
                if ay != NONE {
                    let (ys, vxs, vys) = (ay as usize * l, vx as usize * l, vy as usize * l);
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            let vyk = regs[vys + k];
                            adj[ys + k] -= ak * regs[vxs + k] / (vyk * vyk);
                        }
                    }
                }
            }
            BwdInstr::Neg { a, ax } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let xs = ax as usize * l;
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] -= ak;
                    }
                }
            }
            BwdInstr::Exp { a, ax, v } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vs) = (ax as usize * l, v as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] += ak * regs[vs + k];
                    }
                }
            }
            BwdInstr::Sqrt { a, ax, v } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vs) = (ax as usize * l, v as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] += ak * 0.5 / regs[vs + k];
                    }
                }
            }
            BwdInstr::Sigmoid { a, ax, v } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vs) = (ax as usize * l, v as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        let vi = regs[vs + k];
                        adj[xs + k] += ak * vi * (1.0 - vi);
                    }
                }
            }
            BwdInstr::Tanh { a, ax, v } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vs) = (ax as usize * l, v as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        let vi = regs[vs + k];
                        adj[xs + k] += ak * (1.0 - vi * vi);
                    }
                }
            }
            BwdInstr::Ln { a, ax, vx } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vxs) = (ax as usize * l, vx as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] += ak / regs[vxs + k];
                    }
                }
            }
            BwdInstr::Log1p { a, ax, vx } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vxs) = (ax as usize * l, vx as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] += ak / (1.0 + regs[vxs + k]);
                    }
                }
            }
            BwdInstr::Softplus { a, ax, vx } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vxs) = (ax as usize * l, vx as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        let s = sigmoid_val(regs[vxs + k]);
                        adj[xs + k] += ak * s;
                    }
                }
            }
            BwdInstr::Powi { a, ax, vx, n } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let (xs, vxs) = (ax as usize * l, vx as usize * l);
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        let xv = regs[vxs + k];
                        adj[xs + k] += ak * (n as f64) * xv.powi(n - 1);
                    }
                }
            }
            BwdInstr::Scale { a, ax, c } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let xs = ax as usize * l;
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] += ak * c;
                    }
                }
            }
            BwdInstr::Offset { a, ax } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                let xs = ax as usize * l;
                for k in 0..l {
                    let ak = adj[as_ + k];
                    if ak != 0.0 {
                        adj[xs + k] += ak;
                    }
                }
            }
            BwdInstr::Composite { a, estart, elen } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                for e in estart as usize..(estart + elen) as usize {
                    let ps = plan.edge_adj[e] as usize * l;
                    let xs = plan.edge_partial[e] as usize * l;
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[ps + k] += ak * partials[xs + k];
                        }
                    }
                }
            }
            BwdInstr::CompositeShared { a, estart, elen } => {
                let as_ = a as usize * l;
                if adj[as_..as_ + l].iter().all(|&x| x == 0.0) {
                    continue;
                }
                for e in estart as usize..(estart + elen) as usize {
                    let ps = plan.edge_adj[e] as usize * l;
                    let p = shared[plan.edge_partial[e] as usize];
                    for k in 0..l {
                        let ak = adj[as_ + k];
                        if ak != 0.0 {
                            adj[ps + k] += ak * p;
                        }
                    }
                }
            }
        }
    }
}
